"""Error-feedback int8 gradient compression for DP all-reduce.

Classic EF-SGD/1-bit-Adam style: quantize (grad + residual) to int8 blocks,
all-reduce the quantized values (here: psum of dequantized int8 — on real
hardware the int8 payload crosses the wire, an 4x collective-bytes saving),
keep the quantization error as local residual for the next step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import _quantize, _dequantize


class EFState(NamedTuple):
    residual: any


def ef_init(params):
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), params))


def compress_grads(grads, ef: EFState, block: int = 256):
    """Returns (quantized pytree of (q, scale), new EFState)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x, block)
        deq = _dequantize(q, s, g.shape)
        return (q, s), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            EFState(residual=treedef.unflatten([o[1] for o in out])))


def decompress_grads(qgrads, shapes_like):
    flat_q, treedef = jax.tree.flatten(shapes_like)
    flat_pairs = treedef.flatten_up_to(qgrads)
    return treedef.unflatten([
        _dequantize(q, s, ref.shape)
        for (q, s), ref in zip(flat_pairs, flat_q)])


def psum_compressed(grads, ef: EFState, axis_name, block: int = 256):
    """Error-feedback compressed data-parallel gradient reduction.

    Inside shard_map/pjit: quantize locally, reduce, dequantize.  The psum
    operand is the int8 payload (cast to int32 for the reduction), i.e. the
    wire format is 1 byte + 4/block scale bytes per element.
    """
    qg, ef = compress_grads(grads, ef, block)

    def reduce_one(pair):
        q, s = pair
        q32 = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s32 = jax.lax.psum(s, axis_name)
        # mean of dequantized shards == deq(q_sum, s_sum)/D only if scales
        # equal; reconstruct exactly instead: psum(q*s) in fp32 per block
        return q32, s32

    # exact formulation: psum the per-block dequantized payload in fp32 is
    # what XLA would do anyway for fp32; for wire savings we reduce q and s
    # separately and accept the scale-mixing approximation (standard EF-SGD
    # practice; the residual absorbs the error next step).
    reduced = jax.tree.map(
        lambda pair: reduce_one(pair), qg,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))
    n_dev = jax.lax.psum(1, axis_name)

    def deq(pair, ref):
        q32, s32 = pair
        q = q32.astype(jnp.float32) / n_dev
        s = s32 / n_dev
        return (_dequantize(q, s, ref.shape)).astype(jnp.float32)

    flat_ref, treedef = jax.tree.flatten(grads)
    flat_red = treedef.flatten_up_to(reduced)
    mean_g = treedef.unflatten(
        [deq(p, r) for p, r in zip(flat_red, flat_ref)])
    return mean_g, ef

"""Device-resident RR pipeline: DeviceRRStore equivalence with the host
compaction, fused-selection parity with the numpy oracle, and the
transfer-guard regression over a full IMM solve."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov, oracle
from repro.core.engine import make_engine
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem


def _wc_graph(n=40, m=200, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _random_batch(rng, n, count, max_len=8, allow_empty=False):
    lens = rng.integers(0 if allow_empty else 1, max_len, count)
    w = max(int(lens.max()), 1)
    nodes = np.zeros((count, w), np.int64)
    for i, ln in enumerate(lens):
        nodes[i, :ln] = rng.choice(n, size=ln, replace=False)
    return nodes, lens


# --------------------------------------------------- store equivalence

def test_device_store_matches_host_store_random_batches():
    """Device rank-scatter compaction == host numpy compaction, element for
    element, across doubling growth, empty rows, and varying widths."""
    rng = np.random.default_rng(0)
    n = 37
    dev = cov.DeviceRRStore(n, capacity=4)       # force repeated doubling
    host = cov.IncrementalRRStore(n, capacity=4)
    rr_all = []
    for i in range(6):
        nodes, lens = _random_batch(rng, n, int(rng.integers(1, 24)),
                                    allow_empty=(i % 2 == 0))
        dev.append_batch((nodes, lens))
        host.append_batch((nodes, lens))
        rr_all += [nodes[j, :lens[j]].tolist()
                   for j in range(len(lens)) if lens[j]]
    ds, hs = dev.snapshot(), host.snapshot()
    assert ds.n_rr == hs.n_rr == len(rr_all) == dev.n_rr
    np.testing.assert_array_equal(np.asarray(ds.rr_flat),
                                  np.asarray(hs.rr_flat))
    np.testing.assert_array_equal(np.asarray(ds.rr_ids),
                                  np.asarray(hs.rr_ids))
    assert np.asarray(ds.valid).all()
    # the buffers beyond the live extent stay sentinel/invalid (the pool
    # buffers carry a leading shard dim; this store is the mesh=1 case)
    assert dev.capacity >= dev.n_elems
    assert not np.asarray(dev._valid)[0, dev.n_elems:].any()


def test_device_store_matches_build_store_single_batch():
    rng = np.random.default_rng(1)
    n = 29
    nodes, lens = _random_batch(rng, n, 17)
    dev = cov.DeviceRRStore(n)
    dev.append_batch((nodes, lens))
    ref = cov.build_store((nodes, lens), n)
    snap = dev.snapshot()
    assert snap.n_rr == ref.n_rr
    np.testing.assert_array_equal(np.asarray(snap.rr_flat),
                                  np.asarray(ref.rr_flat))
    np.testing.assert_array_equal(np.asarray(snap.rr_ids),
                                  np.asarray(ref.rr_ids))


def test_store_no_mirror_drift_when_every_row_overflowed():
    """Regression: a batch whose *every* row overflowed may report lengths
    beyond the materialized width (truncated nodes, true pre-truncation
    length).  The device store clamps to the width; the host compaction
    previously repeated row ids by the raw length while masking elements by
    width — the counts drifted apart and ``IncrementalRRStore.append_batch``
    crashed with a broadcast error.  Both stores must clamp identically."""
    rng = np.random.default_rng(11)
    n = 40
    nodes = rng.integers(0, n, (6, 4))
    lens = np.full(6, 9)                    # every row overflowed: 9 > width 4
    dev = cov.DeviceRRStore(n, capacity=4)
    dev.append_batch((nodes, lens))
    host = cov.IncrementalRRStore(n, capacity=4)
    host.append_batch((nodes, lens))        # used to raise ValueError
    td, nd = (int(x.sum()) for x in jax.device_get((dev._t_dev,
                                                    dev._nrr_dev)))
    assert (dev.n_elems, dev.n_rr) == (td, nd) == (24, 6)
    assert (host._t, host.n_rr) == (24, 6)
    np.testing.assert_array_equal(np.asarray(dev.snapshot().rr_flat),
                                  np.asarray(host.snapshot().rr_flat))
    np.testing.assert_array_equal(np.asarray(dev.snapshot().rr_ids),
                                  np.asarray(host.snapshot().rr_ids))
    # build_store shares the compaction; its counts must agree too
    ref = cov.build_store((nodes, lens), n)
    assert ref.n_rr == 6 and int(ref.rr_flat.shape[0]) == 24


def test_device_store_accepts_overflowed_truncated_rows():
    """Overflowed lanes deliver truncated rows (length == qcap); the store
    must take them verbatim like the host path does."""
    g = _wc_graph(n=30, m=300, seed=2)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("queue", g_rev, batch=32, qcap=2)   # force overflow
    b = eng.sample(jax.random.key(0))
    assert bool(np.asarray(b.overflowed).any())
    dev = cov.DeviceRRStore(30)
    host = cov.IncrementalRRStore(30)
    dev.append_batch(b)
    host.append_batch((np.asarray(b.nodes), np.asarray(b.lengths)))
    np.testing.assert_array_equal(np.asarray(dev.snapshot().rr_flat),
                                  np.asarray(host.snapshot().rr_flat))
    assert dev.n_rr == host.n_rr


# ----------------------------------------------- fused selection parity

@pytest.mark.parametrize("method", ("flat", "bitset", "auto"))
def test_fused_selection_matches_oracle(method):
    rng = np.random.default_rng(3)
    n, k = 50, 6
    dev = cov.DeviceRRStore(n, capacity=8)
    rr_all = []
    for _ in range(4):
        nodes, lens = _random_batch(rng, n, 60)
        dev.append_batch((nodes, lens))
        rr_all += [nodes[j, :lens[j]].tolist() for j in range(len(lens))]
    res = dev.select(k, method=method)
    seeds_o, frac_o = oracle.greedy_max_coverage(rr_all, n, k)
    assert np.asarray(res.seeds).tolist() == seeds_o
    assert float(res.frac) == pytest.approx(frac_o, abs=1e-6)


def test_fused_selection_matches_oracle_on_random_graph_batches():
    g = _wc_graph(n=45, m=220, seed=4)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("queue", g_rev, batch=48)
    dev = cov.DeviceRRStore(45)
    rr_all = []
    for i in range(3):
        b = eng.sample(jax.random.key(i))
        dev.append_batch(b)
        nodes, lens = np.asarray(b.nodes), np.asarray(b.lengths)
        rr_all += [nodes[j, :lens[j]].tolist() for j in range(b.n_sets)]
    for method in ("flat", "bitset"):
        res = dev.select(5, method=method)
        seeds_o, frac_o = oracle.greedy_max_coverage(rr_all, 45, 5)
        assert np.asarray(res.seeds).tolist() == seeds_o, method
        assert float(res.frac) == pytest.approx(frac_o, abs=1e-6)


# --------------------------------------------- transfer-guard regression

@pytest.mark.parametrize("engine", ("queue", "refill"))
def test_solve_runs_under_transfer_guard(engine):
    """The whole sampling+selection loop must be device-resident: an outer
    ``transfer_guard("disallow")`` held over solve() raises on any implicit
    host↔device transfer (the old pipeline bounced the pool through numpy
    every round)."""
    g = _wc_graph(n=50, m=250, seed=5)
    solver = IMMSolver(g, engine=engine, batch=64, seed=0)
    with jax.transfer_guard("disallow"):
        res = solver.solve(IMProblem(k=3, eps=0.5, max_theta=256))
    seeds, est, stats = res.seeds, res.spread, res.stats
    assert len(set(seeds.tolist())) == 3
    assert est > 0 and stats.theta > 0
    assert stats.n_rr_sampled >= min(stats.theta, 256)


def test_solve_quality_unchanged_vs_oracle_greedy():
    """End-to-end: fused device pipeline and the plain select_seeds on the
    final snapshot agree on the same pool."""
    g = _wc_graph(n=60, m=300, seed=6)
    solver = IMMSolver(g, engine="queue", batch=64, seed=3)
    res = solver.solve(IMProblem(k=4, eps=0.5))
    seeds, est = res.seeds, res.spread
    snap = solver.store.snapshot()
    ref = cov.select_seeds(snap, 4)
    assert seeds.tolist() == np.asarray(ref.seeds).tolist()
    assert est == pytest.approx(g.n_nodes * float(ref.frac), rel=1e-5)


def test_refill_sample_device_padding_rows():
    """sample_device returns fixed-shape batches whose zero-length rows are
    dropped by the store; real sets match the host unpack exactly."""
    g = _wc_graph(n=40, m=200, seed=7)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("refill", g_rev, batch=32)
    bd = eng.sample_device(jax.random.key(5))
    bh = eng.sample(jax.random.key(5))
    lens_d = np.asarray(bd.lengths)
    dev = cov.DeviceRRStore(40)
    dev.append_batch(bd)
    assert dev.n_rr == int((lens_d > 0).sum()) == bh.n_sets
    host = cov.IncrementalRRStore(40)
    host.append_batch((np.asarray(bh.nodes), np.asarray(bh.lengths)))
    np.testing.assert_array_equal(np.asarray(dev.snapshot().rr_flat),
                                  np.asarray(host.snapshot().rr_flat))
    np.testing.assert_array_equal(np.asarray(dev.snapshot().rr_ids),
                                  np.asarray(host.snapshot().rr_ids))


# ------------------------------------------------------ satellite bits

def test_interpret_defaults_to_backend(monkeypatch):
    from repro.kernels import ops
    monkeypatch.delenv(ops._ENV_FLAG, raising=False)
    assert ops.INTERPRET is None                 # auto, no import side effect
    assert ops.resolve_interpret() == (jax.default_backend() == "cpu")
    assert ops.resolve_interpret(True) is True   # per-call wins
    try:
        ops.INTERPRET = False                    # module override for tests
        assert ops.resolve_interpret() is False
        assert ops.resolve_interpret(True) is True
    finally:
        ops.INTERPRET = None
    # env override (the CI interpret-mode job): below the module override,
    # above the backend default
    monkeypatch.setenv(ops._ENV_FLAG, "1")
    assert ops.resolve_interpret() is True
    monkeypatch.setenv(ops._ENV_FLAG, "false")
    assert ops.resolve_interpret() is False
    assert ops.resolve_interpret(True) is True
    try:
        ops.INTERPRET = True
        monkeypatch.setenv(ops._ENV_FLAG, "0")
        assert ops.resolve_interpret() is True   # module override wins
    finally:
        ops.INTERPRET = None


def test_masked_occur_kernel():
    from repro.kernels import ops as kops
    rng = np.random.default_rng(8)
    rows, n_words = 16, 3
    words = jnp.asarray(rng.integers(0, 2**32, (rows, n_words),
                                     dtype=np.uint64).astype(np.uint32))
    mask = jnp.asarray(rng.random(rows) < 0.5)
    got = np.asarray(kops.occur_from_bitset_masked(words, mask))
    bits = np.unpackbits(
        np.asarray(words).view(np.uint8).reshape(rows, -1),
        axis=1, bitorder="little")
    expect = (bits * np.asarray(mask)[:, None]).sum(axis=0)
    np.testing.assert_array_equal(got, expect)


def test_coalesce_ic_merges_parallel_edges_exactly():
    src = np.array([0, 0, 0, 1, 1, 2])
    dst = np.array([1, 1, 2, 2, 2, 0])
    w = np.array([0.5, 0.5, 0.3, 1.0, 0.2, 0.4], np.float32)
    g = csr_mod.from_edges(src, dst, 3, weights=w)
    gc = csr_mod.coalesce_ic(g)
    s2, d2, w2 = csr_mod.to_edges(gc)
    ew = dict(zip(zip(s2.tolist(), d2.tolist()), w2.tolist()))
    assert len(s2) == 4
    assert ew[(0, 1)] == pytest.approx(0.75)      # 1 - (1-0.5)^2
    assert ew[(0, 2)] == pytest.approx(0.3)
    assert ew[(1, 2)] == 1.0                      # contains a p=1 edge
    assert ew[(2, 0)] == pytest.approx(0.4)
    # simple sorted graphs come back unchanged (same object)
    assert csr_mod.coalesce_ic(gc) is gc


def test_dedup_mode_detection():
    from repro.core.rrset import detect_dedup_mode
    src, dst = generators.erdos_renyi(40, 200, seed=1)
    g_rev = csr_mod.reverse(weights.wc_weights(
        csr_mod.from_edges(src, dst, 40)))
    assert csr_mod.rows_dst_sorted(g_rev)
    mode = detect_dedup_mode(g_rev)
    assert mode in ("none", "segmented")
    # coalescing always yields a simple graph -> no dedup needed
    assert detect_dedup_mode(csr_mod.coalesce_ic(g_rev)) == "none"
    # unsorted multigraph -> sort fallback
    gm = csr_mod.from_edges(np.array([0, 0, 0]), np.array([2, 1, 2]), 3,
                            sort=False)
    assert detect_dedup_mode(gm) == "sort"


def test_queue_chunk_dedup_no_duplicates_on_multigraph():
    """Multi-edges within one EC chunk must still enqueue a node once
    (sort-based first-occurrence dedup, paper §3.1)."""
    src = np.repeat(np.arange(1, 20), 6)         # 6 parallel edges each
    dst = np.tile([0], src.shape[0])
    src = np.concatenate([src, np.zeros(19, np.int64)])
    dst = np.concatenate([dst, np.arange(1, 20)])
    g = weights.uniform_weights(csr_mod.from_edges(src, dst, 20), p=1.0)
    g_rev = csr_mod.reverse(g)
    from repro.core import rrset
    s = rrset.sample_rrsets_queue(jax.random.key(0), g_rev, batch=16,
                                  qcap=20, ec=8)
    nodes, lens = np.asarray(s.nodes), np.asarray(s.lengths)
    for i in range(16):
        row = nodes[i, :lens[i]].tolist()
        assert len(set(row)) == len(row)

"""Multi-round influence maximization (paper §4.8; CR-NAIMM of Sun et al.'18).

Influence propagates over T independent rounds; we pick k seeds *per round* to
maximize the number of nodes influenced at least once.  Per the paper: "after
selecting a random node, we initiate a random BFS originating from the
selected node as many times as the number of rounds.  Each element in a random
RR set is a tuple of node-id and round number."

Implementation: the T per-round BFS of one RR sample run as T adjacent lanes
of the queue engine sharing one root; elements are encoded as
``round * n + node`` so the whole coverage machinery (occur histogram,
membership scan, decrement) is reused verbatim on an item space of size n·T —
with one addition: the greedy argmax masks out rounds whose per-round budget k
is exhausted (cross-round greedy of CR-NAIMM).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, reverse
from repro.core import rrset as rr_queue
from repro.core import coverage as cov
from repro.core.engine import MRIMEngine, make_engine, split_key as _split_key


def sample_mrim_round(key, g_rev: CSRGraph, batch: int, t_rounds: int,
                      qcap: int, ec: int = rr_queue.EC_DEFAULT):
    """Sample ``batch`` MRIM RR sets (each = T tagged BFS from a shared root).

    Thin compatibility wrapper over :class:`~repro.core.engine.MRIMEngine`.
    Returns (nodes (B, W) encoded ids, lengths (B,), overflowed (B,)).
    """
    eng = MRIMEngine(g_rev, MRIMEngine.Config(batch=batch, t_rounds=t_rounds,
                                              qcap=qcap, ec=ec))
    b = eng.sample(key)
    return np.asarray(b.nodes), np.asarray(b.lengths), np.asarray(b.overflowed)


@functools.partial(jax.jit, static_argnames=("n_rr", "n", "t_rounds", "k"))
def _greedy_mrim(rr_flat, rr_ids, valid, occur0, *, n_rr, n, t_rounds, k):
    items = n * t_rounds

    def step(carry, _):
        occur, covered, budget = carry
        # mask rounds with exhausted budget
        round_of = jnp.arange(items, dtype=jnp.int32) // n
        ok = budget[round_of] > 0
        masked = jnp.where(ok, occur, -1)
        u = jnp.argmax(masked).astype(jnp.int32)
        match = (rr_flat == u) & valid
        row_has = jax.ops.segment_max(match.astype(jnp.int32), rr_ids,
                                      num_segments=n_rr + 1,
                                      indices_are_sorted=True)[:n_rr] > 0
        newly = row_has & ~covered
        elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
            jnp.clip(rr_ids, 0, n_rr)] & valid
        dec = jnp.zeros(items + 1, jnp.int32).at[rr_flat].add(
            elem_newly.astype(jnp.int32), mode="drop")[:items]
        budget = budget.at[u // n].add(-1)
        gain = newly.sum(dtype=jnp.int32)
        return (occur - dec, covered | row_has, budget), (u, gain)

    budget0 = jnp.full((t_rounds,), k, jnp.int32)
    covered0 = jnp.zeros(n_rr, bool)
    (_, covered, _), (seeds, gains) = jax.lax.scan(
        step, (occur0, covered0, budget0), None, length=k * t_rounds)
    return seeds, gains


class MRIMResult(NamedTuple):
    seeds_per_round: list    # T lists of k node ids
    spread_estimate: float
    n_rr: int


def solve_mrim(g: CSRGraph, k: int, t_rounds: int, n_rr: int, *,
               qcap: int | None = None, batch: int = 64, seed: int = 0):
    """Fixed-θ MRIM solve (the paper's Table-3 experiment uses fixed ε; the
    IMM θ machinery composes identically — see IMMSolver — so the benchmark
    isolates the sampling/selection engines)."""
    g_rev = reverse(g)
    n = g.n_nodes
    key = jax.random.key(seed)
    eng = make_engine("mrim", g_rev, batch=batch, t_rounds=t_rounds, qcap=qcap)
    inc = cov.DeviceRRStore(eng.item_space)
    with jax.transfer_guard("disallow"):     # device-resident sampling loop
        while inc.n_rr < n_rr:
            key, sub = _split_key(key)
            inc.append_batch(eng.sample(sub))
    store = inc.snapshot()
    occur0 = cov.occur_histogram(store)
    seeds, gains = _greedy_mrim(store.rr_flat, store.rr_ids, store.valid,
                                occur0, n_rr=store.n_rr, n=n,
                                t_rounds=t_rounds, k=k)
    seeds = np.asarray(seeds)
    per_round = [sorted((seeds[seeds // n == t] % n).tolist())
                 for t in range(t_rounds)]
    frac = float(np.asarray(gains).sum()) / max(store.n_rr, 1)
    return MRIMResult(seeds_per_round=per_round, spread_estimate=n * frac,
                      n_rr=store.n_rr)

"""``repro.serve.net`` — the stdlib-asyncio HTTP/1.1 serving surface.

Wire protocol (DESIGN.md §11):

    POST /v1/solve   body {"graph": name, "problem": {IMProblem state},
                          "deadline_s"?: float}
                     -> 200 {"result": {...}, "cached", "batch_size",
                             "queued_s", "solve_s", "degraded"}
    GET  /healthz    -> 200 {"status": "ok"}          (process liveness)
    GET  /readyz     -> 200 / 503 while draining      (admission readiness)
    GET  /statsz     -> 200 ServeStats + registry/cache/breaker counters,
                        per-entry pool footprints and the exact/approximate
                        footprint ratio, as JSON

The problem body is the :func:`repro.core.problem.problem_state` encoding —
the *full* ``IMProblem`` surface (k/eps/theta/candidates/node_weights/
costs/budget/t_rounds/mode) travels as JSON with dtype-tagged arrays, and
floats round-trip exactly through ``json`` (shortest-repr), so θ-pinned
answers read off the wire bit-identical to in-process
``IMService.submit``.  A per-request deadline rides either the
``X-Deadline-S`` header or the body's ``deadline_s``.

Every :class:`~repro.serve.front.ServeError` subclass maps to a *distinct*
HTTP status (:data:`ERROR_STATUS`) with a typed error body
``{"error": {"code", "type", "message"}}`` — clients rebuild the exact
exception class from ``code`` (:mod:`repro.serve.client`).

Graceful drain (SIGTERM/SIGINT): ``/readyz`` flips to 503 and ``/v1/solve``
rejects new work with a typed 503 body, in-flight batches flush through
``IMService.drain()``, warm pools spill via the registry's durable
spill-on-evict path, then the listener closes.  The server fronts either a
single :class:`~repro.serve.front.IMService` or a
:class:`~repro.serve.cluster.IMCluster` (both expose the same
submit/drain/stop/spill_pools surface).
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import signal
from typing import Optional, Tuple

import numpy as np

from repro.core.problem import IMProblem, IMResult, problem_from_state
from repro.serve.front import (CircuitOpenError, DeadlineExpiredError,
                               IMService, InvalidProblemError, QueueFullError,
                               ServeConfig, ServeError, SolverFailedError,
                               UnknownGraphError, build_service)

# every ServeError subclass -> a DISTINCT status; the exhaustiveness (no
# subclass silently falling through to 500) is asserted by
# tests/test_serve_net.py against ServeError.__subclasses__()
ERROR_STATUS = {
    InvalidProblemError: 400,     # malformed / unsatisfiable problem body
    UnknownGraphError: 404,       # graph name not registered
    QueueFullError: 429,          # admission queue at capacity (shed)
    SolverFailedError: 500,       # solver died after isolation retry
    CircuitOpenError: 503,        # key's breaker open — back off
    DeadlineExpiredError: 504,    # deadline passed before/while solving
}

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            429: "Too Many Requests", 500: "Internal Server Error",
            503: "Service Unavailable", 504: "Gateway Timeout"}

_MAX_BODY = 64 << 20


def status_for(err: ServeError) -> int:
    """HTTP status for a typed serve error (nearest ancestor wins)."""
    for cls in type(err).__mro__:
        if cls in ERROR_STATUS:
            return ERROR_STATUS[cls]
    return 500


def error_body(err: ServeError) -> dict:
    return {"error": {"code": err.code, "type": type(err).__name__,
                      "message": str(err)}}


def decode_problem(doc) -> IMProblem:
    """``problem_state`` JSON -> IMProblem; every malformation (wrong
    types, unknown fields, constraint violations from __post_init__)
    surfaces as the typed 400."""
    if not isinstance(doc, dict):
        raise InvalidProblemError("problem must be a JSON object")
    try:
        return problem_from_state(doc)
    except (TypeError, ValueError) as e:
        raise InvalidProblemError(str(e)) from e


def result_state(res: IMResult) -> dict:
    """JSON encoding of an IMResult.  Seeds/gains as lists, the float32
    frac/spread as exact-repr floats — the parity tests compare these
    against in-process results bit for bit."""
    st = res.stats
    return {
        "seeds": np.asarray(res.seeds).tolist(),
        "gains": np.asarray(res.gains).tolist(),
        "spread": float(res.spread),
        "frac": float(res.frac),
        "cost": float(res.cost),
        "degraded": bool(res.degraded),
        "spread_bounds": (None if res.spread_bounds is None else
                          [float(res.spread_bounds[0]),
                           float(res.spread_bounds[1])]),
        "stats": {"theta": int(st.theta), "rounds": int(st.rounds),
                  "n_rr_sampled": int(st.n_rr_sampled),
                  "selection": st.selection, "variant": st.variant},
    }


def service_statsz(svc: IMService, *, draining: bool = False) -> dict:
    """/statsz payload for one service: the full ServeStats tree plus
    per-entry pool footprints and the exact-vs-approximate footprint ratio
    (the ε-tolerant tier's memory win, PR 9) under the shared budget."""
    d = dataclasses.asdict(svc.stats())
    entries, exact_b, approx_b = [], [], []
    for e in svc.registry.entries.values():
        mode = e.problem.mode
        entries.append({"graph": e.key[0], "theta": e.key[2], "mode": mode,
                        "bytes": e.bytes, "solves": e.solves,
                        "staleness": e.staleness})
        (approx_b if mode == "approximate" else exact_b).append(e.bytes)
    ratio = None
    if exact_b and approx_b and sum(approx_b) > 0:
        ratio = ((sum(exact_b) / len(exact_b))
                 / (sum(approx_b) / len(approx_b)))
    return {"serve": d, "entries": entries, "draining": draining,
            "approx_footprint": {
                "exact_entries": len(exact_b),
                "approx_entries": len(approx_b),
                "exact_bytes_mean": (sum(exact_b) / len(exact_b)
                                     if exact_b else None),
                "approx_bytes_mean": (sum(approx_b) / len(approx_b)
                                      if approx_b else None),
                "exact_over_approx_ratio": ratio}}


class IMNetServer:
    """HTTP/1.1 front over an ``IMService`` (or ``IMCluster``) target.

    ``await start()`` binds (port 0 picks an ephemeral port, read back from
    ``self.port``) and starts the target; ``await shutdown()`` runs the
    drain protocol.  The HTTP layer is a deliberate minimal stdlib parse —
    request line, headers, Content-Length body, keep-alive — because the
    container bakes no HTTP dependency and the wire format is fully under
    test.
    """

    def __init__(self, target, *, host: str = "127.0.0.1", port: int = 0):
        self.target = target
        self.host = host
        self.port = port
        self.draining = False
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "IMNetServer":
        if self._server is not None:
            raise RuntimeError("server already started")
        if hasattr(self.target, "start"):
            await self.target.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def shutdown(self, *, spill: bool = True) -> None:
        """Graceful drain: stop admission (readyz -> 503, solve -> typed
        503), flush in-flight batches, spill warm pools, stop the target,
        close the listener."""
        if self.draining:
            return
        self.draining = True
        if self._server is not None:
            self._server.close()          # no new connections
        await self.target.drain()         # flush everything admitted
        if spill and hasattr(self.target, "spill_pools"):
            self.target.spill_pools()
        await self.target.stop()
        if self._server is not None:
            await self._server.wait_closed()

    # -- HTTP plumbing ------------------------------------------------------
    async def _read_request(self, reader):
        line = await reader.readline()
        if not line or line in (b"\r\n", b"\n"):
            return None
        try:
            method, path, _version = line.decode("latin1").split()
        except ValueError:
            raise _BadRequest("malformed request line")
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, value = h.decode("latin1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", 0) or 0)
        if length > _MAX_BODY:
            raise _BadRequest("body too large", status=413)
        body = await reader.readexactly(length) if length else b""
        return method, path, headers, body

    async def _handle(self, reader, writer):
        try:
            while True:
                try:
                    req = await self._read_request(reader)
                except _BadRequest as e:
                    self._write(writer, e.status,
                                {"error": {"code": "bad_request",
                                           "message": str(e)}},
                                keep=False)
                    await writer.drain()
                    break
                if req is None:
                    break
                method, path, headers, body = req
                keep = headers.get("connection", "").lower() != "close"
                status, payload = await self._route(method, path, headers,
                                                    body)
                self._write(writer, status, payload, keep=keep)
                await writer.drain()
                if not keep:
                    break
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:
                pass

    @staticmethod
    def _write(writer, status: int, payload: dict, *, keep: bool) -> None:
        data = json.dumps(payload).encode()
        writer.write((
            f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
            f"content-type: application/json\r\n"
            f"content-length: {len(data)}\r\n"
            f"connection: {'keep-alive' if keep else 'close'}\r\n"
            f"\r\n").encode("latin1"))
        writer.write(data)

    # -- routes -------------------------------------------------------------
    async def _route(self, method, path, headers, body
                     ) -> Tuple[int, dict]:
        if path == "/healthz":
            return 200, {"status": "ok"}
        if path == "/readyz":
            if self.draining:
                return 503, {"ready": False, "draining": True}
            return 200, {"ready": True, "draining": False}
        if path == "/statsz":
            return 200, await self._stats_payload()
        if path == "/v1/solve":
            if method != "POST":
                return 405, {"error": {"code": "method_not_allowed",
                                       "message": "POST /v1/solve"}}
            return await self._solve(headers, body)
        return 404, {"error": {"code": "not_found",
                               "message": f"no route {method} {path}"}}

    async def _solve(self, headers, body) -> Tuple[int, dict]:
        if self.draining:
            return 503, {"error": {"code": "draining",
                                   "message": "server is draining"}}
        try:
            doc = json.loads(body.decode() or "{}")
            if not isinstance(doc, dict):
                raise InvalidProblemError("body must be a JSON object")
            graph = doc.get("graph")
            if not isinstance(graph, str):
                raise InvalidProblemError("body needs a string 'graph'")
            problem = decode_problem(doc.get("problem"))
            deadline = doc.get("deadline_s")
            if "x-deadline-s" in headers:
                deadline = float(headers["x-deadline-s"])
            if deadline is not None:
                deadline = float(deadline)
        except ServeError as e:
            return status_for(e), error_body(e)
        except Exception as e:
            e = InvalidProblemError(f"{type(e).__name__}: {e}")
            return status_for(e), error_body(e)
        try:
            resp = await self.target.submit(graph, problem,
                                            deadline_s=deadline)
        except ServeError as e:
            return status_for(e), error_body(e)
        return 200, {"result": result_state(resp.result),
                     "cached": resp.cached, "batch_size": resp.batch_size,
                     "queued_s": resp.queued_s, "solve_s": resp.solve_s,
                     "degraded": resp.degraded}

    async def _stats_payload(self) -> dict:
        if hasattr(self.target, "statsz"):     # cluster target
            return await self.target.statsz(draining=self.draining)
        return service_statsz(self.target, draining=self.draining)


class _BadRequest(Exception):
    def __init__(self, msg: str, status: int = 400):
        super().__init__(msg)
        self.status = status


# -- CLI ---------------------------------------------------------------------

def _build_graph(n: int, r: int, seed: int):
    """The benchmarks' deterministic BA graph (same construction as
    ``benchmarks.common.ba_graph``), so an out-of-process client can run
    θ-pinned parity checks against a locally built twin."""
    from repro.graph import csr as csr_mod
    from repro.graph import generators, weights
    src, dst = generators.barabasi_albert(n, r, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


async def serve_main(args) -> None:
    g = _build_graph(args.n, args.r, args.graph_seed)
    cfg = ServeConfig(
        max_batch=args.max_batch, queue_cap=args.queue_cap,
        batch_window_s=args.batch_window,
        solver_opts={"batch": args.batch, "seed": args.seed},
        stacked_selection=not args.no_stacked,
        spill_dir=args.spill_dir)
    if args.workers > 1:
        from repro.serve.cluster import IMCluster
        target = IMCluster({"graph": g}, cfg, workers=args.workers)
    else:
        target = build_service({"graph": g}, cfg)
    server = IMNetServer(target, host=args.host, port=args.port)
    await server.start()
    print(f"serving graph(n={args.n}) on http://{server.host}:{server.port}",
          flush=True)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for s in (signal.SIGTERM, signal.SIGINT):
        loop.add_signal_handler(s, stop.set)
    await stop.wait()
    print("drain: admission stopped, flushing in-flight batches", flush=True)
    await server.shutdown()
    print("drained" + (", warm pools spilled" if args.spill_dir else ""),
          flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="IM serving over HTTP (repro.serve.net)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--n", type=int, default=2000,
                    help="BA graph size (served as graph name 'graph')")
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--graph-seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=1,
                    help=">1 runs the consistent-hash cluster")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--queue-cap", type=int, default=256)
    ap.add_argument("--batch-window", type=float, default=0.002)
    ap.add_argument("--batch", type=int, default=64,
                    help="solver sampling batch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-stacked", action="store_true",
                    help="disable batched stacked selection (baseline)")
    ap.add_argument("--spill-dir", default=None)
    args = ap.parse_args(argv)
    asyncio.run(serve_main(args))


if __name__ == "__main__":
    main()

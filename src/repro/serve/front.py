"""Async request front for IM-as-a-service (stdlib asyncio, no new deps).

Request lifecycle (DESIGN.md §7 has the diagram):

    submit(graph, problem)
      ├─ validate            → UnknownGraphError / InvalidProblemError
      ├─ result cache probe  → cached ServeResponse (no queue, no solver)
      ├─ admission           → QueueFullError when the bounded queue is full
      └─ enqueue ── worker ──┐
                             ├─ drain ≤ max_batch requests, group by
                             │  registry key (compatible = same graph +
                             │  pool signature + θ-mode)
                             ├─ drop expired requests → DeadlineExpiredError
                             ├─ execute_batch() per group on the group's
                             │  warm solver, on the single worker thread
                             └─ cache fills + respond

Admission control is three knobs: ``queue_cap`` (bounded queue —
overload sheds *at the door* with a typed error instead of growing
latency unboundedly), per-request deadlines (expired work is dropped
*before* it wastes solver time), and the registry's device-memory budget
(LRU pool eviction).  The solve itself runs on a dedicated
single-thread executor, so the event loop keeps admitting/shedding while
a batch computes — and jax only ever sees one caller thread.

Failure isolation (DESIGN.md §8): a batch whose execution dies does NOT
fail every request in it.  The entry that was executing is *quarantined*
(its possibly partially-appended pool must never serve again), then each
request re-runs alone on a fresh entry — a poisoned request fails with a
typed error by itself while its batch-mates still get served.  A
per-registry-key circuit breaker (closed → open after N consecutive
failures → half-open probe after a cooldown) stops a persistently failing
key from burning executor time, and requests carrying deadlines degrade
mid-solve to certified sketch-bound answers (``ServeResponse.degraded``)
instead of expiring.  Every outcome is typed: served, degraded, or a
``ServeError`` subclass — submit() never hangs and never returns an
unlabelled partial answer.
"""
from __future__ import annotations

import asyncio
import functools
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.problem import IMProblem, IMResult
from repro.ft.failures import DeadlineExceeded
from repro.serve.batching import execute_batch
from repro.serve.cache import CacheStats, ResultCache
from repro.serve.registry import RegistryStats, WarmSolverRegistry


# -- typed errors ------------------------------------------------------------

class ServeError(Exception):
    """Base of the typed request-rejection responses."""
    code = "error"


class UnknownGraphError(ServeError):
    code = "unknown_graph"


class InvalidProblemError(ServeError):
    code = "invalid_problem"


class QueueFullError(ServeError):
    """Load shed: the bounded admission queue is full."""
    code = "queue_full"


class DeadlineExpiredError(ServeError):
    """The request's deadline passed before a solver picked it up (or
    expired mid-solve on an objective with no degraded answer)."""
    code = "deadline_expired"


class SolverFailedError(ServeError):
    """The request's solve raised even when run in isolation; the original
    error type/message is preserved in ``str(e)``."""
    code = "solver_failed"


class CircuitOpenError(ServeError):
    """The request's registry key has failed repeatedly and its circuit
    breaker is open; retry after the cooldown (a half-open probe will test
    the key again)."""
    code = "circuit_open"


# -- request/response envelopes ---------------------------------------------

@dataclass
class ServeConfig:
    """Admission-control + batching knobs (DESIGN.md §7)."""
    max_batch: int = 16           # requests drained into one micro-batch
    queue_cap: int = 64           # bounded admission queue (shed beyond)
    batch_window_s: float = 0.0   # linger after the first dequeue to let
    #                               a batch accumulate (0 = drain-only)
    default_deadline_s: Optional[float] = None   # None = no deadline
    cache_entries: int = 1024
    memory_budget_bytes: Optional[int] = None
    max_solvers: Optional[int] = None
    solver_opts: dict = field(default_factory=dict)
    # fault handling (DESIGN.md §8)
    breaker_threshold: int = 3    # consecutive failures that open a key's
    #                               circuit breaker
    breaker_cooldown_s: float = 1.0   # open -> half-open probe delay
    spill_dir: Optional[str] = None   # registry spill-on-evict directory
    # ε-driven resample watermark (DESIGN.md §9): an entry whose θ-less
    # shared pool has served this many requests since it was last sampled
    # fresh is refreshed (pool dropped + resampled) before serving more.
    # None = unbounded (the historical drift this knob exists to stop).
    max_pool_staleness: Optional[int] = None
    # batched on-device selection (DESIGN.md §11): fixed-θ requests in one
    # micro-batch share a single stacked selection scan instead of one scan
    # each.  Bit-identical either way — purely a throughput knob.
    stacked_selection: bool = True


@dataclass
class ServeResponse:
    result: IMResult
    cached: bool                  # served from the result cache
    batch_size: int               # occupancy of the batch that computed it
    queued_s: float               # admission -> execution start
    solve_s: float                # execution wall time of the batch
    degraded: bool = False        # deadline-clipped sketch answer: the
    #                               result carries certified spread_bounds
    #                               and is never cached


@dataclass
class _Breaker:
    """Per-registry-key circuit breaker.  closed → (threshold consecutive
    failures) → open → (cooldown) → half-open, where exactly one probe
    attempt runs: success closes the breaker, failure re-opens it.  The
    worker is single-threaded, so no locking is needed."""
    threshold: int
    cooldown_s: float
    state: str = "closed"
    failures: int = 0             # consecutive
    opened_at: float = 0.0
    trips: int = 0

    def allow(self, now: float) -> bool:
        if self.state == "open" and now - self.opened_at >= self.cooldown_s:
            self.state = "half-open"
        return self.state != "open"

    def record(self, ok: bool, now: float) -> None:
        if ok:
            self.state = "closed"
            self.failures = 0
            return
        self.failures += 1
        if self.state == "half-open" or self.failures >= self.threshold:
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self.opened_at = now
            self.failures = 0


@dataclass
class _Pending:
    graph: str
    problem: IMProblem
    deadline: Optional[float]     # absolute loop time
    t_submit: float
    future: "asyncio.Future[ServeResponse]"


@dataclass(frozen=True)
class ServeStats:
    """Point-in-time service counters (plus cache/registry snapshots)."""
    submitted: int
    served: int
    cache_hits: int
    shed: int
    expired: int
    failed: int
    batches: int
    batch_occupancy_mean: float
    batch_occupancy_max: int
    occur_fastpath: int
    cache: CacheStats
    registry: RegistryStats
    # fault handling (DESIGN.md §8)
    degraded: int = 0             # deadline-clipped sketch answers served
    quarantines: int = 0          # entries dropped after a mid-flight death
    isolated_retries: int = 0     # requests re-run alone after a batch died
    solver_retries: int = 0       # in-solver FaultPolicy retries (shared)
    breaker_trips: int = 0        # closed/half-open -> open transitions
    breakers_open: int = 0        # keys currently open or half-open
    # ε-driven pool staleness (DESIGN.md §9)
    pool_staleness: int = 0       # worst current staleness across entries
    refreshes: int = 0            # watermark-forced pool resamples
    # batched on-device selection (DESIGN.md §11)
    stacked_batches: int = 0      # micro-batches that ran a stacked scan
    stacked_requests: int = 0     # requests answered by a stacked scan


def build_service(graphs: dict, config: Optional[ServeConfig] = None
                  ) -> "IMService":
    """Construct a registry from ``config`` and wrap it in a service."""
    config = config or ServeConfig()
    registry = WarmSolverRegistry(
        memory_budget_bytes=config.memory_budget_bytes,
        max_solvers=config.max_solvers,
        solver_opts=config.solver_opts,
        spill_dir=config.spill_dir)
    for name, g in graphs.items():
        registry.add_graph(name, g)
    return IMService(registry, config)


class IMService:
    """The micro-batched request front over a :class:`WarmSolverRegistry`.

    Use as an async context manager (or call ``start()``/``stop()``)::

        registry = WarmSolverRegistry(solver_opts={"batch": 64})
        registry.add_graph("social", g)
        async with IMService(registry, ServeConfig(max_batch=8)) as svc:
            res = await svc.submit("social", IMProblem(k=5, theta=4096))
    """

    def __init__(self, registry: WarmSolverRegistry,
                 config: Optional[ServeConfig] = None):
        self.registry = registry
        self.config = config or ServeConfig()
        self.cache = ResultCache(self.config.cache_entries)
        self._queue: "asyncio.Queue[_Pending] | None" = None
        self._worker_task: Optional[asyncio.Task] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        # counters
        self.submitted = 0
        self.served = 0
        self.cache_hits = 0
        self.shed = 0
        self.expired = 0
        self.failed = 0
        self.batches = 0
        self.occupancy_sum = 0
        self.occupancy_max = 0
        self.occur_fastpath = 0
        self.stacked_batches = 0
        self.stacked_requests = 0
        self.degraded = 0
        self.quarantines = 0
        self.isolated_retries = 0
        self._breakers: "dict[tuple, _Breaker]" = {}
        # shared in-solver fault policy (chaos injection + retry counters):
        # the registry forwards it to every solver it builds
        self._policy = self.config.solver_opts.get("fault_policy")

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "IMService":
        if self._worker_task is not None:
            raise RuntimeError("service already started")
        self._queue = asyncio.Queue(maxsize=self.config.queue_cap)
        # one worker thread: batches execute strictly in order and jax is
        # only ever entered from a single thread
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="im-serve")
        if (self._policy is not None
                and self.registry.evict_coldest not in self._policy.on_oom):
            # growth-OOM recovery: free cold warm pools, then retry the
            # append that hit the allocation failure
            self._policy.on_oom.append(self.registry.evict_coldest)
        self._worker_task = asyncio.get_running_loop().create_task(
            self._worker())
        return self

    async def stop(self) -> None:
        if self._worker_task is None:
            return
        await self.drain()
        self._worker_task.cancel()
        try:
            await self._worker_task
        except asyncio.CancelledError:
            pass
        self._worker_task = None
        self._executor.shutdown(wait=True)
        self._executor = None

    async def drain(self) -> None:
        """Wait until every admitted request has been responded to."""
        await self._queue.join()

    async def __aenter__(self) -> "IMService":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- submission --------------------------------------------------------
    async def submit(self, graph: str, problem: IMProblem,
                     deadline_s: Optional[float] = None) -> ServeResponse:
        """Admit one request and await its typed response.

        Raises :class:`UnknownGraphError` / :class:`InvalidProblemError`
        immediately, :class:`QueueFullError` when admission sheds, and
        :class:`DeadlineExpiredError` when the deadline passes in-queue.
        """
        if self._queue is None:
            raise RuntimeError("service not started")
        self.submitted += 1
        if not self.registry.has_graph(graph):
            self.failed += 1
            raise UnknownGraphError(f"graph {graph!r} is not registered")
        if not isinstance(problem, IMProblem):
            self.failed += 1
            raise InvalidProblemError(
                f"expected an IMProblem, got {type(problem).__name__}")
        try:
            # validate against the concrete graph up front so malformed
            # requests never consume queue or solver capacity
            problem.resolve(self.registry.graph(graph).n_nodes)
        except ValueError as e:
            self.failed += 1
            raise InvalidProblemError(str(e)) from e
        hit = self.cache.get(self.registry.cache_key(graph, problem))
        if hit is not None:
            self.cache_hits += 1
            self.served += 1
            return ServeResponse(result=hit, cached=True, batch_size=0,
                                 queued_s=0.0, solve_s=0.0)
        loop = asyncio.get_running_loop()
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        pending = _Pending(
            graph=graph, problem=problem,
            deadline=(None if deadline_s is None
                      else loop.time() + deadline_s),
            t_submit=loop.time(), future=loop.create_future())
        try:
            self._queue.put_nowait(pending)
        except asyncio.QueueFull:
            self.shed += 1
            raise QueueFullError(
                f"admission queue full ({self.config.queue_cap} pending)"
            ) from None
        return await pending.future

    # -- worker ------------------------------------------------------------
    async def _worker(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            batch: List[_Pending] = [await self._queue.get()]
            if self.config.batch_window_s > 0:
                # linger so concurrent arrivals can share the batch
                await asyncio.sleep(self.config.batch_window_s)
            while len(batch) < self.config.max_batch:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                # compatible requests share a warm solver: group by
                # registry key, preserving arrival order within groups
                groups: "dict[tuple, List[_Pending]]" = {}
                for p in batch:
                    key = self.registry.solver_key(p.graph, p.problem)
                    groups.setdefault(key, []).append(p)
                for group in groups.values():
                    await self._run_group(loop, group)
            finally:
                for _ in batch:
                    self._queue.task_done()

    async def _run_group(self, loop, group: List[_Pending]) -> None:
        now = loop.time()
        live: List[_Pending] = []
        for p in group:
            if p.deadline is not None and now > p.deadline:
                self.expired += 1
                self.failed += 1
                p.future.set_exception(DeadlineExpiredError(
                    f"deadline passed {now - p.deadline:.3f}s before "
                    "execution"))
            else:
                live.append(p)
        if not live:
            return
        # second cache probe: an identical request earlier in this very
        # run of batches may have just filled the entry
        todo: List[_Pending] = []
        for p in live:
            hit = self.cache.get(self.registry.cache_key(p.graph, p.problem))
            if hit is not None:
                self.cache_hits += 1
                self.served += 1
                p.future.set_result(ServeResponse(
                    result=hit, cached=True, batch_size=0,
                    queued_s=now - p.t_submit, solve_s=0.0))
            else:
                todo.append(p)
        if not todo:
            return
        key = self.registry.solver_key(todo[0].graph, todo[0].problem)
        breaker = self._breakers.get(key)
        if breaker is None:
            breaker = self._breakers[key] = _Breaker(
                self.config.breaker_threshold,
                self.config.breaker_cooldown_s)
        if not breaker.allow(loop.time()):
            self.failed += len(todo)
            for p in todo:
                p.future.set_exception(CircuitOpenError(
                    f"registry key {key[0]!r}/... is failing; circuit open "
                    f"for {self.config.breaker_cooldown_s:.1f}s"))
            return
        try:
            self._respond(todo, *await self._execute(loop, key, todo))
            breaker.record(True, loop.time())
            return
        except asyncio.CancelledError:
            raise
        except BaseException:
            # batch attempt died: the shared entry has been quarantined by
            # _execute.  Isolate the blast radius — re-run each request
            # alone on a fresh entry so a poisoned request fails by itself
            # while its batch-mates still get served.
            breaker.record(False, loop.time())
        for p in todo:
            if p.future.done():
                continue
            self.isolated_retries += 1
            try:
                self._respond([p], *await self._execute(loop, key, [p]))
                breaker.record(True, loop.time())
            except asyncio.CancelledError:
                raise
            except BaseException as e:
                breaker.record(False, loop.time())
                self.failed += 1
                p.future.set_exception(self._typed(e))

    async def _execute(self, loop, key, reqs: List[_Pending]):
        """One executor attempt over requests sharing a registry key.
        Returns ``(results, t0, solve_s)``; on ANY failure the executing
        entry is quarantined first (its pool may be partially appended and
        must never serve again — DESIGN.md §8), then the error propagates
        to the caller's isolation/breaker logic."""
        entry = self.registry.get(reqs[0].graph, reqs[0].problem)
        if (key[2] is None and self.config.max_pool_staleness is not None
                and entry.staleness >= self.config.max_pool_staleness):
            # ε-driven entries answer off one shared growing pool; past the
            # resample watermark the pool is dropped and sampled fresh so
            # pool-reuse staleness stays bounded (DESIGN.md §9)
            self.registry.refresh_pool(entry)
        entry.in_use = True
        problems = [p.problem for p in reqs]
        t0 = loop.time()
        # per-request remaining budget at attempt start: solver-side
        # monotonic seconds (loop.time() is only valid on this loop)
        deadlines = [None if p.deadline is None
                     else max(0.0, p.deadline - t0) for p in reqs]
        try:
            fast_before = self._fastpath_probe(entry.solver, problems)
            if self._policy is not None:
                # chaos boundary standing in for an executor-side death
                self._policy.check("executor", {"n": len(reqs)})
            stack_stats: dict = {}
            results = await loop.run_in_executor(
                self._executor, functools.partial(
                    execute_batch, entry.solver, problems, deadlines,
                    stacked=self.config.stacked_selection,
                    stats_out=stack_stats))
        except BaseException:
            entry.in_use = False
            self.registry.quarantine(key)
            self.quarantines += 1
            raise
        entry.in_use = False
        solve_s = loop.time() - t0
        self.occur_fastpath += fast_before
        self.stacked_batches += stack_stats.get("stacked_batches", 0)
        self.stacked_requests += stack_stats.get("stacked_requests", 0)
        entry.solves += len(reqs)
        if key[2] is None:
            entry.staleness += len(reqs)
        self.registry.account(entry)
        self.batches += 1
        self.occupancy_sum += len(reqs)
        self.occupancy_max = max(self.occupancy_max, len(reqs))
        return results, t0, solve_s

    def _respond(self, reqs: List[_Pending], results, t0, solve_s) -> None:
        for p, res in zip(reqs, results):
            if res.degraded:
                # labelled partial answer: never cached (a later request
                # with more budget deserves the exact result)
                self.degraded += 1
            else:
                self.cache.put(self.registry.cache_key(p.graph, p.problem),
                               res)
            self.served += 1
            p.future.set_result(ServeResponse(
                result=res, cached=False, batch_size=len(reqs),
                queued_s=t0 - p.t_submit, solve_s=solve_s,
                degraded=res.degraded))

    @staticmethod
    def _typed(e: BaseException) -> ServeError:
        """Map an isolation-run failure to the typed error surface."""
        if isinstance(e, ServeError):
            return e
        if isinstance(e, DeadlineExceeded):
            return DeadlineExpiredError(str(e))
        return SolverFailedError(f"{type(e).__name__}: {e}")

    @staticmethod
    def _fastpath_probe(solver, problems) -> int:
        from repro.serve.batching import occur_fastpath_eligible
        return sum(1 for p in problems
                   if occur_fastpath_eligible(solver, p))

    def spill_pools(self) -> int:
        """Drain-time pool spill (the network server's SIGTERM path): evict
        every idle warm entry through the registry's spill-on-evict path.
        Call only after ``drain()`` — pinned entries are skipped."""
        return self.registry.spill_all()

    # -- stats -------------------------------------------------------------
    def stats(self) -> ServeStats:
        return ServeStats(
            submitted=self.submitted, served=self.served,
            cache_hits=self.cache_hits, shed=self.shed,
            expired=self.expired, failed=self.failed, batches=self.batches,
            batch_occupancy_mean=(self.occupancy_sum / self.batches
                                  if self.batches else 0.0),
            batch_occupancy_max=self.occupancy_max,
            occur_fastpath=self.occur_fastpath,
            cache=self.cache.snapshot(),
            registry=self.registry.snapshot(),
            degraded=self.degraded, quarantines=self.quarantines,
            isolated_retries=self.isolated_retries,
            solver_retries=(self._policy.retries
                            if self._policy is not None else 0),
            breaker_trips=sum(b.trips for b in self._breakers.values()),
            breakers_open=sum(1 for b in self._breakers.values()
                              if b.state != "closed"),
            pool_staleness=max(
                (e.staleness for e in self.registry.entries.values()),
                default=0),
            refreshes=self.registry.pool_refreshes,
            stacked_batches=self.stacked_batches,
            stacked_requests=self.stacked_requests)

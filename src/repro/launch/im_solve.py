"""Distributed IM solve: the paper's pipeline on an N-device mesh.

Every device runs the batched queue sampler on its own threefry counter
range (gIM's grid dimension -> mesh dimension, DESIGN.md §4); Occur is
psum-reduced; seed selection runs the sharded Alg. 7.  Works on any device
count (elastic); on this CPU container use XLA_FLAGS to fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.im_solve --n 2000 --k 10
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map

from repro.graph import csr, generators, weights
from repro.core import rrset, coverage as cov
from repro.core.oracle import imm_theta_params
import math


def sample_round_sharded(mesh, g_rev, batch_per_dev: int, qcap: int,
                         round_idx: int, seed: int):
    """One round: every device samples batch_per_dev RR sets."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    n_dev = mesh.devices.size

    def local(offsets, indices, w):
        dev = jax.lax.axis_index(mesh.axis_names).astype(jnp.uint32)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(seed), round_idx), dev)
        key, sub = jax.random.split(key)
        roots = jax.random.randint(sub, (batch_per_dev,), 0, n,
                                   dtype=jnp.int32)
        nodes, lengths, overflow, _ = rrset._sample_queue(
            key, offsets, indices, w, roots,
            batch=batch_per_dev, qcap=qcap, ec=128, n=n, m=m)
        return nodes[None], lengths[None], overflow[None]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(), P(), P()),
                   out_specs=(P(mesh.axis_names), P(mesh.axis_names),
                              P(mesh.axis_names)))
    nodes, lengths, overflow = fn(g_rev.offsets, g_rev.indices,
                                  g_rev.weights)
    return (np.asarray(nodes).reshape(n_dev * batch_per_dev, qcap),
            np.asarray(lengths).reshape(-1),
            np.asarray(overflow).reshape(-1))


def solve(g, k: int, eps: float, *, batch_per_dev: int = 128, seed: int = 0):
    devices = np.asarray(jax.devices())
    mesh = Mesh(devices, ("dev",))
    n_dev = devices.size
    g_rev = csr.reverse(g)
    n = g.n_nodes
    qcap = n
    lam_p, lam_star, eps_p, _ = imm_theta_params(n, k, eps)
    pool_nodes, pool_lens = [], []
    n_sampled = 0

    def sample_until(theta):
        nonlocal n_sampled
        r = 0
        while n_sampled < theta:
            nodes, lens, _ = sample_round_sharded(
                mesh, g_rev, batch_per_dev, qcap, len(pool_nodes), seed)
            pool_nodes.append(nodes)
            pool_lens.append(lens)
            n_sampled += nodes.shape[0]
            r += 1

    def select(k):
        stores = [cov.build_store((nd, ln), n)
                  for nd, ln in zip(pool_nodes, pool_lens)]
        return cov.select_seeds(cov.merge_stores(stores), k)

    lb = 1.0
    for i in range(1, max(int(math.log2(n)), 2)):
        x = n / 2.0 ** i
        sample_until(int(math.ceil(lam_p / x)))
        res = select(k)
        if n * float(res.frac) >= (1 + eps_p) * x:
            lb = n * float(res.frac) / (1 + eps_p)
            break
    theta = int(math.ceil(lam_star / lb))
    sample_until(theta)
    res = select(k)
    return (np.asarray(res.seeds), n * float(res.frac),
            dict(theta=theta, sampled=n_sampled, devices=n_dev))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--eps", type=float, default=0.4)
    args = ap.parse_args()
    src, dst = generators.barabasi_albert(args.n, args.r, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, args.n))
    t0 = time.time()
    seeds, est, stats = solve(g, args.k, args.eps)
    print(f"devices={stats['devices']} theta={stats['theta']} "
          f"sampled={stats['sampled']} time={time.time() - t0:.2f}s")
    print(f"seeds={sorted(seeds.tolist())} estimate={est:.1f}")


if __name__ == "__main__":
    main()

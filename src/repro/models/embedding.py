"""Embedding lookup library for recsys-scale sparse tables.

JAX has no native EmbeddingBag or CSR sparse — per the assignment this IS
part of the system:

* :func:`embedding_bag` — gather (``jnp.take``) + ``jax.ops.segment_sum``
  (sum/mean modes) over a flat multi-hot id list with offsets-style segments.
* :func:`sharded_lookup` — mod/row-sharded tables: each device holds a
  contiguous row slice; lookup = masked local gather + ``psum`` over the
  table axis (DLRM-style model-parallel embeddings).  Used inside shard_map
  (import it from :mod:`repro.compat` — its home moved across jax releases).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, ids, segment_ids, num_segments, *, mode="sum",
                  valid=None):
    """table (R, D); ids (N,) int32; segment_ids (N,) sorted int32.
    Returns (num_segments, D)."""
    vals = jnp.take(table, ids, axis=0)
    if valid is not None:
        vals = jnp.where(valid[:, None], vals, 0)
    out = jax.ops.segment_sum(vals, segment_ids, num_segments=num_segments,
                              indices_are_sorted=True)
    if mode == "mean":
        ones = (valid.astype(table.dtype) if valid is not None
                else jnp.ones(ids.shape[0], table.dtype))
        cnt = jax.ops.segment_sum(ones, segment_ids,
                                  num_segments=num_segments,
                                  indices_are_sorted=True)
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


def sharded_lookup(table_local, ids, axis_name: str):
    """Row-sharded lookup inside shard_map.

    table_local: (R/D, dim) this device's contiguous row slice;
    ids: (..., ) global row ids (replicated across the table axis).
    Returns (..., dim) — psum-combined; cost = one psum(batch·dim) per call.
    """
    shard = jax.lax.axis_index(axis_name)
    rows_local = table_local.shape[0]
    local = ids - shard * rows_local
    mine = (local >= 0) & (local < rows_local)
    vals = jnp.take(table_local, jnp.clip(local, 0, rows_local - 1), axis=0)
    vals = jnp.where(mine[..., None], vals, 0)
    return jax.lax.psum(vals, axis_name)

"""Compressed-sparse-row graph representation (paper §3.2, Fig. 1).

The paper stores G as three arrays: row offsets ``R`` (n+1), column indices
``C`` (m) and edge weights ``W`` (m), in input order (no pre-sorting).  We keep
exactly that layout.  Construction happens host-side in numpy; the resulting
arrays are ordinary jnp arrays usable inside jit/shard_map.

RR-set sampling runs a randomized BFS on the *transposed* instance graph
(paper §3.1), so :func:`reverse` builds the CSC/transpose with the original
edge weight p_uv carried onto the reversed edge (v -> u).
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp


class CSRGraph(NamedTuple):
    """CSR adjacency. ``offsets[i]:offsets[i+1]`` indexes node i's out-edges."""

    offsets: jnp.ndarray  # (n+1,) int32
    indices: jnp.ndarray  # (m,)  int32
    weights: jnp.ndarray  # (m,)  float32

    @property
    def n_nodes(self) -> int:
        return int(self.offsets.shape[0]) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self):
        return self.offsets[1:] - self.offsets[:-1]


def from_edges(src, dst, n: int, weights=None, sort: bool = True,
               sort_rows: bool = False) -> CSRGraph:
    """Build CSR from an edge list (numpy, host-side).

    ``sort=True`` groups edges by source (stable, preserving relative input
    order within a row, matching the paper's no-reordering statement).
    ``sort_rows=True`` additionally orders each row by destination —
    multi-edge duplicates become adjacent, which lets the samplers' chunk
    dedup run as a segmented scan instead of a sort (see core/rrset.py);
    used for the *reverse* sampling graph, where edge order carries no
    semantic weight (Bernoulli trials and LT categorical draws are
    order-free).

    ``sort=False`` requires the input to already be grouped by source
    (``src`` non-decreasing): the offsets come from ``np.bincount(src)``
    while the indices stay in input order, so ungrouped input would pair
    row i's offset span with some *other* row's destinations — a silently
    corrupt CSR.  The groupedness is validated (one monotone pass) and
    violated input raises ``ValueError``.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError("src/dst shape mismatch")
    m = src.shape[0]
    if weights is None:
        weights = np.ones(m, dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    if m and (src.min() < 0 or src.max() >= n or dst.min() < 0 or dst.max() >= n):
        raise ValueError("edge endpoint out of range")
    if sort_rows and m:
        order = np.lexsort((dst, src))
        src, dst, weights = src[order], dst[order], weights[order]
    elif sort and m:
        order = np.argsort(src, kind="stable")
        src, dst, weights = src[order], dst[order], weights[order]
    elif m and not (np.diff(src) >= 0).all():
        # bincount-built offsets + input-order indices only agree when the
        # edges arrive grouped by source; anything else silently mispairs
        # rows with destinations (the accidental-safety trap of the
        # graph/weights.py callers)
        raise ValueError(
            "from_edges(sort=False) requires source-grouped input (src "
            "non-decreasing); pass sort=True to group arbitrary edge lists")
    counts = np.bincount(src, minlength=n).astype(np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSRGraph(
        offsets=jnp.asarray(offsets, dtype=jnp.int32),
        indices=jnp.asarray(dst, dtype=jnp.int32),
        weights=jnp.asarray(weights, dtype=jnp.float32),
    )


def to_edges(g: CSRGraph):
    """Return (src, dst, w) numpy edge arrays."""
    offsets = np.asarray(g.offsets, dtype=np.int64)
    n = offsets.shape[0] - 1
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(offsets))
    return src, np.asarray(g.indices, dtype=np.int64), np.asarray(g.weights)


def reverse(g: CSRGraph) -> CSRGraph:
    """Transpose: edge (u,v,w) becomes (v,u,w).  RR sampling runs on this.

    Rows come back destination-sorted (``sort_rows``): the samplers' chunk
    dedup then reduces to a segmented neighbour scan (O(EC log EC), no
    sort inside the hot loop).
    """
    src, dst, w = to_edges(g)
    return from_edges(dst, src, g.n_nodes, weights=w, sort_rows=True)


def coalesce_ic(g: CSRGraph) -> CSRGraph:
    """Merge parallel edges under the IC equivalence p' = 1 - ∏(1 - p_i).

    Under independent-cascade, k parallel (u, v) edges with probabilities
    p_1..p_k activate exactly like one edge with p'; merging is therefore
    *distribution-exact* for every IC sampler.  The IC engines coalesce
    their reverse graph once at construction — afterwards rows are simple
    (and destination-sorted), so the per-chunk duplicate dedup vanishes
    from the sampling micro-step entirely (``detect_dedup_mode`` returns
    ``"none"``).  Returns ``g`` unchanged when it is already simple and
    destination-sorted.
    """
    offs = np.asarray(g.offsets, dtype=np.int64)
    idx = np.asarray(g.indices, dtype=np.int64)
    w = np.asarray(g.weights, dtype=np.float64)
    n = len(offs) - 1
    if idx.size == 0:
        return g
    row_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(offs))
    if rows_dst_sorted(g):
        # already sorted: duplicates are adjacent, no O(m log m) sort needed
        r, d, p = row_of, idx, w
    else:
        order = np.lexsort((idx, row_of))
        r, d, p = row_of[order], idx[order], w[order]
    head = np.ones(len(r), bool)
    head[1:] = (r[1:] != r[:-1]) | (d[1:] != d[:-1])
    if head.all() and r is row_of:
        return g                                 # simple + sorted: unchanged
    starts = np.nonzero(head)[0]
    # p = 1 edges make log1p(-p) singular: clip for the product, then
    # force those groups to exactly 1
    has_one = np.maximum.reduceat(p, starts) >= 1.0
    lg = np.log1p(-np.clip(p, 0.0, 1.0 - 1e-12))
    merged_p = np.where(has_one, 1.0, -np.expm1(np.add.reduceat(lg, starts)))
    return from_edges(r[starts], d[starts], n,
                      weights=merged_p.astype(np.float32), sort_rows=True)


def rows_dst_sorted(g: CSRGraph) -> bool:
    """Host check: is every CSR row non-decreasing in destination?  Engines
    run this once at construction to pick the fast segmented chunk dedup
    (see core/rrset.py); graphs from :func:`reverse` always qualify."""
    offs = np.asarray(g.offsets, dtype=np.int64)
    idx = np.asarray(g.indices, dtype=np.int64)
    if idx.size <= 1:
        return True
    nd = np.diff(idx) >= 0
    row_starts = offs[1:-1]
    inner = row_starts[(row_starts > 0) & (row_starts < idx.size)]
    nd[inner - 1] = True                     # decreases across rows are fine
    return bool(nd.all())


def graph_digest(g: CSRGraph) -> str:
    """Content hash of a CSR graph: sha256 over dtype + shape + raw bytes
    of offsets/indices/weights.  Two graphs share a digest iff they are the
    same topology with the same edge probabilities, so this is the identity
    the serving layer keys warm pools and cached results on — a mutated or
    re-registered graph can never alias a stale entry (``repro.serve``,
    ``repro.core.stream``).  Stable across processes (no python ``hash``).
    """
    h = hashlib.sha256(b"CSRGraph:")
    for name, arr in (("offsets", g.offsets), ("indices", g.indices),
                      ("weights", g.weights)):
        a = np.asarray(arr)
        h.update(name.encode())
        h.update(b"=")
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
        h.update(b";")
    return h.hexdigest()


def degrees(g: CSRGraph):
    """(out_degree, in_degree) as numpy int64 arrays."""
    offsets = np.asarray(g.offsets, dtype=np.int64)
    out_deg = np.diff(offsets)
    in_deg = np.bincount(np.asarray(g.indices, dtype=np.int64),
                         minlength=offsets.shape[0] - 1)
    return out_deg, in_deg


def max_out_degree(g: CSRGraph) -> int:
    out_deg, _ = degrees(g)
    return int(out_deg.max()) if out_deg.size else 0

"""Greedy max-coverage seed selection (paper Alg. 1 L6-10 / Alg. 7), TPU-adapted.

RR sets are stored exactly like the paper's memory-optimized layout (Alg. 6):
one flat concatenated array ``rr_flat`` plus ``rr_offsets`` (CSR-of-RR).  For
vectorized processing we carry ``rr_ids`` = the row id of every flat element
(the inverse of Offsets_RR), so the Alg. 7 kernel becomes:

  argmax(Occur)                 -> jnp.argmax of the psum-reduced histogram
  per-RR membership scan of u   -> equality scan + segment_max by rr_ids
  Covered flag + decrement      -> mask + segment scatter-sub on Occur

Distributed mode: RR rows are sharded across devices (each device keeps the
rows it sampled); ``Occur`` is psum-reduced, argmax is replicated math, and
coverage updates stay local — per seed the only collective is one psum(n).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class RRStore(NamedTuple):
    """CSR-of-RR.  ``rr_flat[rr_offsets[i]:rr_offsets[i+1]]`` is RR set i."""
    rr_flat: jnp.ndarray     # (T,) int32 node ids (padded tail = n, masked out)
    rr_ids: jnp.ndarray      # (T,) int32 row id per element
    valid: jnp.ndarray       # (T,) bool
    n_rr: int                # number of RR sets
    n_nodes: int


def _compact_padded(nodes, lens, base: int = 0):
    """(B, W) padded rows + lengths -> (flat elements, row ids + base), the
    CSR-of-RR compaction shared by ``build_store`` and the incremental
    store (paper Alg. 6 lines 4-11, vectorized)."""
    nodes = np.asarray(nodes)
    lens = np.asarray(lens, dtype=np.int64)
    mask = np.arange(nodes.shape[1])[None, :] < lens[:, None]
    flat = nodes[mask].astype(np.int64)
    ids = np.repeat(np.arange(len(lens), dtype=np.int64) + base, lens)
    return flat, ids, lens


def build_store(rr_lists_or_arrays, n: int, pad_to: int | None = None) -> RRStore:
    """Host-side compaction (paper Alg. 6 lines 4-11)."""
    if isinstance(rr_lists_or_arrays, list):
        lens = np.asarray([len(r) for r in rr_lists_or_arrays], dtype=np.int64)
        flat = (np.concatenate([np.asarray(r, dtype=np.int64)
                                for r in rr_lists_or_arrays])
                if lens.sum() else np.zeros(0, np.int64))
        ids = np.repeat(np.arange(len(lens), dtype=np.int64), lens)
    else:  # (nodes (B, Q), lengths (B,)) padded arrays from the samplers
        flat, ids, lens = _compact_padded(*rr_lists_or_arrays)
    t = flat.shape[0]
    t_pad = pad_to if pad_to is not None else t
    if t_pad < t:
        raise ValueError("pad_to smaller than payload")
    valid = np.zeros(t_pad, bool); valid[:t] = True
    flat = np.concatenate([flat, np.full(t_pad - t, n, np.int64)])
    ids = np.concatenate([ids, np.full(t_pad - t, len(lens), np.int64)])
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(ids, jnp.int32),
                   valid=jnp.asarray(valid),
                   n_rr=int(len(lens)), n_nodes=n)


class IncrementalRRStore:
    """Growing CSR-of-RR with amortized-O(1)-per-element ``append_batch``.

    The Alg. 2 LB loop selects seeds after every θ_i escalation; rebuilding
    the store from the per-round pool each time is O(rounds · T) host work
    per selection (O(rounds²) over the loop).  Here each round's batch is
    compacted exactly once into doubling flat/ids buffers, and ``snapshot``
    returns a cached device-resident :class:`RRStore` view (invalidated only
    by the next append).
    """

    def __init__(self, n_nodes: int, capacity: int = 1024):
        self.n_nodes = n_nodes
        self._flat = np.empty(max(capacity, 1), np.int64)
        self._ids = np.empty(max(capacity, 1), np.int64)
        self._t = 0
        self._n_rr = 0
        self._cache: RRStore | None = None

    @property
    def n_rr(self) -> int:
        return self._n_rr

    def _reserve(self, extra: int):
        need = self._t + extra
        if need <= self._flat.shape[0]:
            return
        cap = self._flat.shape[0]
        while cap < need:
            cap *= 2
        for name in ("_flat", "_ids"):
            buf = np.empty(cap, np.int64)
            buf[:self._t] = getattr(self, name)[:self._t]
            setattr(self, name, buf)

    def append_batch(self, batch) -> None:
        """Append one engine batch: an ``RRBatch`` or a ``(nodes, lengths)``
        pair of padded arrays (the ``build_store`` array form)."""
        nodes, lens = (batch.nodes, batch.lengths) if hasattr(batch, "nodes") \
            else batch
        flat, ids, lens = _compact_padded(nodes, lens, base=self._n_rr)
        self._reserve(flat.shape[0])
        self._flat[self._t:self._t + flat.shape[0]] = flat
        self._ids[self._t:self._t + flat.shape[0]] = ids
        self._t += flat.shape[0]
        self._n_rr += len(lens)
        self._cache = None

    def snapshot(self) -> RRStore:
        if self._cache is None:
            self._cache = RRStore(
                rr_flat=jnp.asarray(self._flat[:self._t], jnp.int32),
                rr_ids=jnp.asarray(self._ids[:self._t], jnp.int32),
                valid=jnp.ones(self._t, bool),
                n_rr=self._n_rr, n_nodes=self.n_nodes)
        return self._cache


def merge_stores(stores: list[RRStore]) -> RRStore:
    n = stores[0].n_nodes
    flats, ids, valids, base = [], [], [], 0
    for s in stores:
        flats.append(np.asarray(s.rr_flat)[np.asarray(s.valid)])
        ids.append(np.asarray(s.rr_ids)[np.asarray(s.valid)] + base)
        base += s.n_rr
    flat = np.concatenate(flats) if flats else np.zeros(0, np.int64)
    rid = np.concatenate(ids) if ids else np.zeros(0, np.int64)
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(rid, jnp.int32),
                   valid=jnp.ones(flat.shape[0], bool),
                   n_rr=base, n_nodes=n)


def occur_histogram(store: RRStore) -> jnp.ndarray:
    """Occur[n]: #RR sets containing each node (elements are row-unique)."""
    ones = store.valid.astype(jnp.int32)
    return jnp.zeros(store.n_nodes + 1, jnp.int32).at[store.rr_flat].add(
        ones, mode="drop")[:store.n_nodes]


@functools.partial(jax.jit, static_argnames=("n_rr", "n", "k"))
def _greedy(rr_flat, rr_ids, valid, occur0, *, n_rr, n, k):
    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        match = (rr_flat == u) & valid                       # membership scan
        row_has = jax.ops.segment_max(match.astype(jnp.int32), rr_ids,
                                      num_segments=n_rr + 1,
                                      indices_are_sorted=True)[:n_rr] > 0
        newly = row_has & ~covered
        elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
            jnp.clip(rr_ids, 0, n_rr)] & valid
        dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            elem_newly.astype(jnp.int32), mode="drop")[:n]
        occur = occur - dec
        covered = covered | row_has
        gain = newly.sum(dtype=jnp.int32)
        return (occur, covered), (u, gain)

    covered = jnp.zeros(n_rr, bool)
    (occur, covered), (seeds, gains) = jax.lax.scan(
        step, (occur0, covered), None, length=k)
    return seeds, gains, covered


class CoverageResult(NamedTuple):
    seeds: jnp.ndarray    # (k,) int32
    gains: jnp.ndarray    # (k,) int32 — newly covered RR sets per seed
    frac: jnp.ndarray     # () float32 — F_R(S): covered fraction


def select_seeds(store: RRStore, k: int) -> CoverageResult:
    occur0 = occur_histogram(store)
    seeds, gains, covered = _greedy(store.rr_flat, store.rr_ids, store.valid,
                                    occur0, n_rr=store.n_rr,
                                    n=store.n_nodes, k=k)
    frac = gains.sum() / jnp.maximum(store.n_rr, 1)
    return CoverageResult(seeds=seeds, gains=gains, frac=frac.astype(jnp.float32))


class PaddedStore(NamedTuple):
    """2D tile layout for the Pallas membership kernel (DESIGN.md §2):
    TPU prefers rectangular VMEM tiles over the GPU's ragged flat array."""
    rows: jnp.ndarray     # (R, L) int32, padded with n
    lengths: jnp.ndarray  # (R,) int32
    n_nodes: int


def build_padded_store(rr_lists, n: int, row_len: int | None = None,
                       pad_rows_to: int = 8) -> PaddedStore:
    lens = np.asarray([len(r) for r in rr_lists], dtype=np.int64)
    l = row_len if row_len is not None else int(max(lens.max(), 1))
    l = ((l + 127) // 128) * 128                       # lane-align
    r = ((len(rr_lists) + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
    rows = np.full((r, l), n, dtype=np.int32)
    for i, rr in enumerate(rr_lists):
        if len(rr) > l:
            raise ValueError("row_len too small")
        rows[i, :len(rr)] = rr
    lengths = np.zeros(r, np.int32)
    lengths[:len(lens)] = lens
    return PaddedStore(rows=jnp.asarray(rows), lengths=jnp.asarray(lengths),
                       n_nodes=n)


def select_seeds_padded(store: PaddedStore, k: int) -> CoverageResult:
    """Greedy selection with the Pallas membership kernel as the Alg. 7 scan.

    The scan (the hot part: R×L element compares per seed) runs in the
    kernel; Covered flags and the Occur decrement (scatter-add) stay in XLA,
    which lowers scatter natively on TPU.
    """
    from repro.kernels import ops as kops
    rows, lengths, n = store.rows, store.lengths, store.n_nodes
    r, l = rows.shape
    lane = jnp.arange(l, dtype=jnp.int32)[None, :]
    valid = lane < lengths[:, None]
    occur = jnp.zeros(n + 1, jnp.int32).at[rows].add(
        valid.astype(jnp.int32), mode="drop")[:n]
    covered = jnp.zeros(r, bool)
    seeds, gains = [], []
    for _ in range(k):
        u = jnp.argmax(occur).astype(jnp.int32)
        hit = kops.membership_rows(rows, lengths, u)
        newly = hit & ~covered
        dec = jnp.zeros(n + 1, jnp.int32).at[rows].add(
            (valid & newly[:, None]).astype(jnp.int32), mode="drop")[:n]
        occur = occur - dec
        covered = covered | hit
        seeds.append(u)
        gains.append(newly.sum(dtype=jnp.int32))
    n_rr = int((lengths > 0).sum())
    gains = jnp.stack(gains)
    return CoverageResult(seeds=jnp.stack(seeds), gains=gains,
                          frac=(gains.sum() / jnp.maximum(n_rr, 1)
                                ).astype(jnp.float32))


def shard_stores(per_shard_rr: list[list[list[int]]], n: int) -> RRStore:
    """Stack per-device RR pools into a leading-shard-dim RRStore.

    Pads every shard to the max flat length and max row count so the arrays
    stack; ``n_rr`` becomes rows-per-shard (uniform after padding with empty
    rows, which are never covered and never matched).
    """
    n_shards = len(per_shard_rr)
    rows = max(len(p) for p in per_shard_rr)
    per_shard_rr = [p + [[]] * (rows - len(p)) for p in per_shard_rr]
    stores = [build_store(p, n) for p in per_shard_rr]
    t_max = max(int(s.rr_flat.shape[0]) for s in stores)
    stores = [build_store(p, n, pad_to=t_max) for p in per_shard_rr]
    return RRStore(
        rr_flat=jnp.stack([s.rr_flat for s in stores]),
        rr_ids=jnp.stack([s.rr_ids for s in stores]),
        valid=jnp.stack([s.valid for s in stores]),
        n_rr=rows, n_nodes=n)


# ---------------------------------------------------------------------------
# Distributed (shard_map) variant: RR rows sharded, Occur psum-reduced.
# ---------------------------------------------------------------------------

def select_seeds_sharded(mesh, store_shards, k: int, n: int, axis_names):
    """store_shards: RRStore pytree whose arrays carry a leading shard dim
    equal to the mesh size (one row per device); rr_ids are *local* row ids.
    Per-seed collective cost: one psum over (n,) int32 — see DESIGN.md §4.
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map, pvary

    local_n_rr = store_shards.n_rr  # rows per shard (uniform)

    def local_fn(rr_flat, rr_ids, valid):
        rr_flat, rr_ids, valid = rr_flat[0], rr_ids[0], valid[0]
        occur = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            valid.astype(jnp.int32), mode="drop")[:n]
        occur = jax.lax.psum(occur, axis_names)

        def step(carry, _):
            occur, covered = carry
            u = jnp.argmax(occur).astype(jnp.int32)
            match = (rr_flat == u) & valid
            row_has = jax.ops.segment_max(
                match.astype(jnp.int32), rr_ids,
                num_segments=local_n_rr + 1,
                indices_are_sorted=True)[:local_n_rr] > 0
            newly = row_has & ~covered
            elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
                jnp.clip(rr_ids, 0, local_n_rr)] & valid
            dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
                elem_newly.astype(jnp.int32), mode="drop")[:n]
            occur = occur - jax.lax.psum(dec, axis_names)
            gain = jax.lax.psum(newly.sum(dtype=jnp.int32), axis_names)
            return (occur, covered | row_has), (u, gain)

        covered = pvary(jnp.zeros(local_n_rr, bool), axis_names)
        (_, covered), (seeds, gains) = jax.lax.scan(
            step, (occur, covered), None, length=k)
        return seeds[None], gains[None]

    specs = P(axis_names if isinstance(axis_names, str) else tuple(axis_names))
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(specs, specs, specs),
                   out_specs=(specs, specs))
    seeds, gains = fn(store_shards.rr_flat, store_shards.rr_ids,
                      store_shards.valid)
    return seeds[0], gains[0]

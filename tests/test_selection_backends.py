"""Selection backends: CELF-sketch identity with the fused scan, sketch
estimator guarantees, the solver's ``selection=`` knob end-to-end, and the
per-path seed-quality regression against the numpy IMM oracle."""
import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov, forward, oracle, sketch as sk
from repro.core.engine import make_engine
from repro.core.imm import IMMSolver, imm
from repro.core.problem import IMProblem


def _wc_graph(n=40, m=200, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _random_pool(rng, n, batches=4, count=60, max_len=8, sketch_k=None):
    dev = cov.DeviceRRStore(n, capacity=8, sketch_k=sketch_k)
    rr_all = []
    for _ in range(batches):
        lens = rng.integers(1, max_len, count)
        nodes = np.zeros((count, int(lens.max())), np.int64)
        for i, ln in enumerate(lens):
            nodes[i, :ln] = rng.choice(n, size=ln, replace=False)
        dev.append_batch((nodes, lens))
        rr_all += [nodes[j, :lens[j]].tolist() for j in range(count)]
    return dev, rr_all


# ----------------------------------------------------- celf == fused scan

def test_celf_identical_to_fused_with_exact_sketch():
    """Acceptance bar: sketch size >= n_rr (mod bucketing is injective) =>
    estimates are exact and the CELF path returns the fused-scan seed set,
    gains and covered fraction, bit for bit."""
    rng = np.random.default_rng(3)
    n, k = 50, 6
    dev, rr_all = _random_pool(rng, n, sketch_k=256)   # 240 rows < 256 buckets
    assert dev.n_rr <= dev.sketch_k
    res_c = cov.select_seeds_celf(dev, k)
    res_f = dev.select(k, method="flat")
    seeds_o, frac_o = oracle.greedy_max_coverage(rr_all, n, k)
    assert np.asarray(res_c.seeds).tolist() == \
        np.asarray(res_f.seeds).tolist() == seeds_o
    np.testing.assert_array_equal(np.asarray(res_c.gains),
                                  np.asarray(res_f.gains))
    assert float(res_c.frac) == pytest.approx(frac_o, abs=1e-6)


@pytest.mark.parametrize("sketch_k", (32, 64, None))
def test_celf_identical_for_any_sketch_size(sketch_k):
    """Correctness is structural: lossy sketches only change how many exact
    evaluations happen, never the selected seeds (submodular upper bounds +
    exact top-candidate re-evaluation)."""
    rng = np.random.default_rng(7)
    n, k = 45, 5
    dev, rr_all = _random_pool(rng, n, sketch_k=sketch_k)
    stats = {}
    res_c = cov.select_seeds_celf(dev, k, stats_out=stats, eval_batch=4)
    res_f = dev.select(k, method="flat")
    assert np.asarray(res_c.seeds).tolist() == np.asarray(res_f.seeds).tolist()
    np.testing.assert_array_equal(np.asarray(res_c.gains),
                                  np.asarray(res_f.gains))
    # lazy: strictly fewer exact evals than full greedy's k * n
    assert 0 < stats["n_exact_evals"] < k * n


def test_celf_on_engine_batches_matches_oracle():
    g = _wc_graph(n=45, m=220, seed=4)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("queue", g_rev, batch=48)
    dev = cov.DeviceRRStore(45, sketch_k=256)
    rr_all = []
    for i in range(3):
        b = eng.sample(jax.random.key(i))
        dev.append_batch(b)
        nodes, lens = np.asarray(b.nodes), np.asarray(b.lengths)
        rr_all += [nodes[j, :lens[j]].tolist() for j in range(b.n_sets)]
    res = dev.select(5, method="celf")
    seeds_o, frac_o = oracle.greedy_max_coverage(rr_all, 45, 5)
    assert np.asarray(res.seeds).tolist() == seeds_o
    assert float(res.frac) == pytest.approx(frac_o, abs=1e-6)


# ------------------------------------------------ sketch estimator bounds

def test_sketch_gains_are_lower_bounds_and_exact_when_wide():
    """Δocc(v | ∅) <= exact Occur[v] always; equality when the bucketing is
    injective (sketch_k >= n_rr, mod hashing)."""
    rng = np.random.default_rng(5)
    n = 30
    for sketch_k, exact in ((256, True), (32, False)):
        dev, rr_all = _random_pool(rng, n, batches=2, count=50,
                                   sketch_k=sketch_k)
        occur = np.zeros(n, np.int64)
        for rr in rr_all:
            for v in rr:
                occur[v] += 1
        cov_sk = jax.device_put(np.zeros(dev.sketch_k // 32, np.uint32))
        deltas = np.asarray(jax.device_get(
            sk.union_gains(dev.sketch_words(), cov_sk)))[:n]
        assert (deltas <= occur).all()
        if exact:
            np.testing.assert_array_equal(deltas, occur)


def test_sketch_from_flat_matches_incremental():
    """A sketch rebuilt from the live flat pool equals the incrementally
    maintained one (same bucketing, same row ids)."""
    rng = np.random.default_rng(9)
    n, k = 35, 64
    dev, _ = _random_pool(rng, n, batches=3, count=20, sketch_k=k)
    occ = sk.sketch_from_flat(dev._flat[0], dev._ids[0], dev._valid[0],
                              n=n, k=dev.sketch_k, mode="mod")
    rebuilt = sk.pack_sketch(occ, words=dev.sketch_k // 32)
    np.testing.assert_array_equal(np.asarray(rebuilt),
                                  np.asarray(dev.sketch_words()))


def test_celf_identical_with_mix_hash_mode():
    """The Knuth-multiplicative bucketing is just another lossy sketch:
    seeds stay identical to the fused scan, and the incremental mix-mode
    sketch matches its flat rebuild."""
    rng = np.random.default_rng(21)
    n, k = 40, 4
    dev = cov.DeviceRRStore(n, capacity=8, sketch_k=64, sketch_mode="mix")
    for _ in range(3):
        lens = rng.integers(1, 7, 40)
        nodes = np.zeros((40, int(lens.max())), np.int64)
        for i, ln in enumerate(lens):
            nodes[i, :ln] = rng.choice(n, size=ln, replace=False)
        dev.append_batch((nodes, lens))
    res_c = cov.select_seeds_celf(dev, k)
    res_f = dev.select(k, method="flat")
    assert np.asarray(res_c.seeds).tolist() == np.asarray(res_f.seeds).tolist()
    occ = sk.sketch_from_flat(dev._flat[0], dev._ids[0], dev._valid[0],
                              n=n, k=dev.sketch_k, mode="mix")
    np.testing.assert_array_equal(
        np.asarray(sk.pack_sketch(occ, words=dev.sketch_k // 32)),
        np.asarray(dev.sketch_words()))


def test_linear_count_estimator():
    assert sk.linear_count(0, 64) == pytest.approx(0.0)
    # small occupancy ~ cardinality; high occupancy corrects upward
    assert sk.linear_count(4, 256) == pytest.approx(4.0, rel=0.02)
    assert sk.linear_count(60, 64) > 60
    assert np.isfinite(sk.linear_count(64, 64))


def test_union_popcount_kernel_matches_numpy():
    from repro.kernels import ops as kops
    rng = np.random.default_rng(13)
    rows, w = 37, 4
    words = rng.integers(0, 2**32, (rows, w), dtype=np.uint64).astype(np.uint32)
    covw = rng.integers(0, 2**32, (w,), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(kops.sketch_union_popcount(words, covw))
    expect = np.array([
        bin(int.from_bytes((words[i] | covw).tobytes(), "little")).count("1")
        for i in range(rows)])
    np.testing.assert_array_equal(got, expect)


# ------------------------------------------- solver knob + transfer guard

@pytest.mark.parametrize("selection", ("fused", "bitset", "celf-sketch"))
def test_solver_selection_knob_under_transfer_guard(selection):
    """Every selection backend must run device-resident end-to-end: the
    outer guard raises on any implicit host<->device transfer."""
    g = _wc_graph(n=50, m=250, seed=5)
    solver = IMMSolver(g, engine="queue", batch=64, seed=0,
                       selection=selection)
    with jax.transfer_guard("disallow"):
        res = solver.solve(IMProblem(k=3, eps=0.5, max_theta=256))
    assert len(set(res.seeds.tolist())) == 3
    assert res.spread > 0 and res.stats.selection == selection


def test_solver_selection_paths_agree():
    g = _wc_graph(n=60, m=300, seed=6)
    results = {}
    for sel in ("fused", "bitset", "celf-sketch"):
        seeds, est, _ = imm(g, 4, 0.5, engine="queue", batch=64, seed=3,
                            selection=sel)
        results[sel] = (seeds.tolist(), round(est, 4))
    assert results["fused"] == results["bitset"] == results["celf-sketch"]


def test_solver_rejects_unknown_selection():
    g = _wc_graph(n=20, m=60, seed=1)
    with pytest.raises(ValueError, match="selection"):
        IMMSolver(g, selection="nope")


# ------------------------------------------ seed-quality regression (MC)

@pytest.mark.parametrize("selection", ("fused", "bitset", "celf-sketch"))
def test_seed_quality_within_guarantee_vs_oracle(selection):
    """Empirical spread of each path's seeds (forward MC) clears the
    (1 - 1/e - eps) bound against the serial numpy oracle's seeds on a
    fixed-RNG graph (10% slack absorbs the MC noise on both sides)."""
    n, k, eps = 30, 3, 0.3
    g = _wc_graph(n=n, m=150, seed=12)
    g_rev = csr_mod.reverse(g)
    seeds_oracle, _, _ = oracle.imm_oracle(
        np.asarray(g_rev.offsets), np.asarray(g_rev.indices),
        np.asarray(g_rev.weights), n, k, eps, seed=0, max_theta=2048)
    seeds, _, _ = imm(g, k, eps, engine="queue", batch=64, seed=2,
                      selection=selection, max_theta=2048)
    spread_sel = forward.ic_spread(jax.random.key(7), g, seeds.tolist(),
                                   n_sims=2048)
    spread_ora = forward.ic_spread(jax.random.key(8), g, seeds_oracle,
                                   n_sims=2048)
    bound = (1.0 - 1.0 / np.e - eps) * spread_ora
    assert spread_sel >= bound * 0.9, (selection, spread_sel, spread_ora)

"""mode="approximate" (pool-free DiFuseR mode) conformance suite.

Four contracts, per DESIGN.md §10:

* **Validation** — the mode rejects every pool-needing feature
  (node_weights / budget / t_rounds) at the problem layer, and
  ``resolve_incremental`` refuses to patch a pool that doesn't exist.
* **Saturation** — a fully-occupied linear-counting row carries no
  information beyond its k·ln(k) ceiling: the estimate is clamped + flagged
  and ``IMResult.spread_bounds`` widens to the trivial upper bound instead
  of reporting a silently-finite number.
* **Exact regime** — while ``n_rr <= sketch_k`` under "mod" bucketing the
  bucketing is injective and Δocc == exact marginal gain, so the
  approximate path must be *bit-identical* to the fused exact scan (store
  level and end-to-end, where the FusedSketchEngine wrapper must also
  preserve the sampling RNG stream).
* **Quality** — MC-evaluated seed quality clears
  ``(1 − 1/e − ε − ε_cert)·OPT_oracle`` where ε_cert is the realized
  certified relative error from the returned bounds; and the certified
  interval itself brackets the forward-MC spread (with MC slack).

Plus the durability and distribution legs: im-pool v2 sketch checkpoints
round-trip bit-identically, and an 8-fake-device subprocess pins mesh
bit-identity of the fold + selection (devices are locked at first jax init,
so that check runs out of process like test_sharded_store's).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod, generators, weights
from repro.core import coverage as cov
from repro.core import forward
from repro.core import oracle
from repro.core import sketch as sketch_mod
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem


def _graph(n=60, m=300, seed=6):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _batches(n=50, rounds=4, seed=7):
    r = np.random.default_rng(seed)
    out = []
    for _ in range(rounds):
        lens = r.integers(0, 8, 61)              # empty rows + odd count
        w = max(int(lens.max()), 1)
        nodes = np.zeros((61, w), np.int64)
        for i, ln in enumerate(lens):
            if ln:
                nodes[i, :ln] = r.choice(n, size=ln, replace=False)
        out.append((nodes, lens))
    return out


# --------------------------------------------------------------- validation

def test_mode_validation():
    with pytest.raises(ValueError, match="unknown mode"):
        IMProblem(k=2, eps=0.5, mode="sketchy")
    with pytest.raises(ValueError, match="node_weights"):
        IMProblem(k=2, eps=0.5, mode="approximate",
                  node_weights=np.ones(8, np.float32))
    with pytest.raises(ValueError, match="budget"):
        IMProblem(k=2, eps=0.5, mode="approximate",
                  costs=np.ones(8, np.float32), budget=3.0)
    with pytest.raises(ValueError, match="t_rounds"):
        IMProblem(k=2, eps=0.5, mode="approximate", t_rounds=3)


def test_mode_keys_the_pool_signature():
    # "mode" is a pool field: approximate requests must never share a
    # warm pool (or a serving batch) with exact ones
    a = IMProblem(k=2, eps=0.5).pool_digest(model="ic")
    b = IMProblem(k=2, eps=0.5, mode="approximate").pool_digest(model="ic")
    assert a != b


def test_resolve_incremental_rejects_approximate():
    g = _graph()
    from repro.core import stream as stream_mod
    s = IMMSolver(g, engine="queue", batch=64, seed=0)
    s.solve(IMProblem(k=2, theta=256, mode="approximate"))
    deltas = stream_mod.EdgeDeltas(
        add_src=np.asarray([0], np.int32),
        add_dst=np.asarray([1], np.int32),
        add_p=np.asarray([0.5], np.float32),
        rm_src=np.asarray([], np.int32), rm_dst=np.asarray([], np.int32))
    with pytest.raises(ValueError, match="approximate"):
        s.resolve_incremental(
            IMProblem(k=2, theta=256, mode="approximate"), deltas)


def test_occur_fastpath_excludes_approximate():
    from repro.serve.batching import occur_fastpath_eligible
    g = _graph()
    s = IMMSolver(g, engine="queue", batch=64, seed=0)
    assert occur_fastpath_eligible(s, IMProblem(k=1, theta=64))
    assert not occur_fastpath_eligible(
        s, IMProblem(k=1, theta=64, mode="approximate"))


# --------------------------------------------------------------- saturation

def test_linear_count_saturation_clamped_and_flagged():
    k = 128
    est, sat = sketch_mod.linear_count_saturated([0, 64, k, k + 5], k)
    assert not sat[0] and not sat[1] and sat[2] and sat[3]
    assert est[0] == 0.0
    assert np.all(np.isfinite(est))
    assert est[2] == pytest.approx(k * np.log(k))  # the clamp, not inf
    assert est[3] == est[2]
    # certified error stays finite at the ceiling too
    assert np.all(np.isfinite(sketch_mod.linear_count_rel_error(est, k)))


def test_auto_sketch_k_sizing():
    with pytest.raises(ValueError):
        sketch_mod.auto_sketch_k(0.0, 100)
    with pytest.raises(ValueError):
        sketch_mod.auto_sketch_k(1.5, 100)
    k1 = sketch_mod.auto_sketch_k(0.5, 10**6)
    k2 = sketch_mod.auto_sketch_k(0.1, 10**6)
    assert k2 > k1                       # tighter eps -> bigger sketch
    assert k1 % 32 == 0 and k2 % 32 == 0
    assert sketch_mod.auto_sketch_k(0.01, 100) <= 128  # clamped near n


def test_saturation_widens_spread_bounds():
    # theta >> sketch_k saturates the union row: the result must flag the
    # widened (trivial) upper bound rather than a silently-finite estimate
    g = _graph()
    n = g.n_nodes
    s = IMMSolver(g, engine="queue", batch=64, seed=0, sketch_k=64)
    res = s.solve(IMProblem(k=4, theta=4096, mode="approximate"))
    assert res.spread_bounds is not None
    lo, hi = res.spread_bounds
    assert s._sketch_info["saturated"]
    assert hi == pytest.approx(float(n))  # widened to scale * n_rr/n_rr
    assert 0.0 < lo <= res.spread <= hi


# ------------------------------------------------------------- exact regime

def test_exact_regime_store_level_identity():
    # n_rr <= sketch_k under "mod": Δocc is the exact marginal, so greedy
    # on sketches must match the fused flat scan seed-for-seed/gain-for-gain
    n, k = 50, 6
    exact = cov.ShardedDeviceRRStore(n, capacity=8)
    sk = cov.SketchRRStore(n, sketch_k=256)
    for b in _batches(n=n):
        exact.append_batch(b)
        sk.append_batch(b)
    assert exact.n_rr == sk.n_rr and sk.n_rr <= sk.sketch_k
    r_exact = exact.select(k, method="flat")
    info = {}
    r_sk = cov.select_seeds_sketch(sk, k, info_out=info)
    a, b_ = jax.device_get(((r_exact.seeds, r_exact.gains, r_exact.frac),
                            (r_sk.seeds, r_sk.gains, r_sk.frac)))
    assert info["exact_regime"] and info["rel_error"] == 0.0
    assert np.array_equal(np.asarray(a[0]), np.asarray(b_[0]))
    assert np.array_equal(np.asarray(a[1]), np.asarray(b_[1]))
    assert float(a[2]) == pytest.approx(float(b_[2]), rel=1e-6)
    assert info["lo_rows"] == info["hi_rows"] == info["occ_union"]


def test_exact_regime_end_to_end_identity():
    # same theta, same seed: the FusedSketchEngine preserves the sampling
    # RNG stream, and with theta <= sketch_k the selection is injective —
    # the whole approximate solve is bit-identical to fused exact
    g = _graph()
    theta = 192
    se = IMMSolver(g, engine="queue", batch=64, seed=3, selection="fused")
    re_ = se.solve(IMProblem(k=4, theta=theta))
    sa = IMMSolver(g, engine="queue", batch=64, seed=3, sketch_k=256)
    ra = sa.solve(IMProblem(k=4, theta=theta, mode="approximate"))
    assert np.array_equal(np.asarray(re_.seeds), np.asarray(ra.seeds))
    assert re_.spread == pytest.approx(ra.spread, rel=1e-6)
    lo, hi = ra.spread_bounds
    assert lo == pytest.approx(ra.spread, rel=1e-6)
    assert hi == pytest.approx(ra.spread, rel=1e-6)
    assert sa.store.per_device_pool_bytes() == 0


def test_candidate_mask_and_degenerate_k():
    g = _graph()
    cand = np.zeros(g.n_nodes, bool)
    cand[:3] = True
    s = IMMSolver(g, engine="queue", batch=64, seed=0, sketch_k=256)
    res = s.solve(IMProblem(k=5, theta=192, mode="approximate",
                            candidates=np.flatnonzero(cand)))
    seeds = np.asarray(res.seeds)
    assert len(seeds) <= 3 and set(seeds.tolist()) <= {0, 1, 2}


# ------------------------------------------------------------------ quality

def test_mc_quality_clears_certified_bound():
    # genuine approximation regime (n_rr > sketch_k, unsaturated): seeds
    # must clear (1 - 1/e - eps - eps_cert) x oracle quality under MC, and
    # the certified interval must bracket the MC spread
    g = _graph()
    n, k, eps = g.n_nodes, 4, 0.3
    s = IMMSolver(g, engine="queue", batch=64, seed=3, sketch_k=1024)
    res = s.solve(IMProblem(k=k, eps=eps, max_theta=4096,
                            mode="approximate"))
    assert s.store.n_rr > 1024, "params must exercise the estimate regime"
    assert not s._sketch_info["saturated"]
    lo, hi = res.spread_bounds
    assert lo <= res.spread <= hi

    g_fwd = g  # forward.ic_spread wants the forward graph
    got = forward.ic_spread(jax.random.key(7), g_fwd,
                            np.asarray(res.seeds).tolist(), n_sims=2048)
    rev = csr_mod.reverse(g)
    o_seeds, _, _ = oracle.imm_oracle(
        np.asarray(rev.offsets), np.asarray(rev.indices),
        np.asarray(rev.weights), n, k, eps, seed=11, max_theta=4096)
    best = forward.ic_spread(jax.random.key(8), g_fwd, list(o_seeds),
                             n_sims=2048)
    eps_cert = (res.spread - lo) / max(res.spread, 1e-9)
    bound = (1.0 - 1.0 / np.e - eps - eps_cert) * best
    assert got >= bound * 0.9, (got, bound, best, eps_cert)
    # the certificate brackets the MC spread (30% slack for MC noise)
    assert lo * 0.7 <= got <= hi * 1.3, (lo, got, hi)


# --------------------------------------------------------------- durability

def test_pool_checkpoint_v2_roundtrip(tmp_path):
    from repro.ckpt import checkpoint as ckpt_mod
    g = _graph()
    d = str(tmp_path / "pool")
    p = IMProblem(k=4, theta=1024, mode="approximate")
    s1 = IMMSolver(g, engine="queue", batch=64, seed=5, sketch_k=128)
    s1.prepare(p)
    s1.sample_until(400)
    s1.save_pool(d)
    meta = ckpt_mod.load_manifest(d, ckpt_mod.latest_step(d))["meta"]
    assert meta["version"] == IMMSolver.POOL_CKPT_VERSION_SKETCH
    assert meta["store"]["kind"] == "sketch"

    s2 = IMMSolver(g, engine="queue", batch=64, seed=5, sketch_k=128)
    s2.restore_pool(d)
    assert isinstance(s2.store, cov.SketchRRStore)
    r1 = s1.solve_problem(p)
    r2 = s2.solve_problem(p)
    assert np.array_equal(np.asarray(r1.seeds), np.asarray(r2.seeds))
    assert r1.spread == pytest.approx(r2.spread, rel=1e-7)
    assert r1.spread_bounds == pytest.approx(r2.spread_bounds, rel=1e-7)


def test_restore_rejects_sketch_size_mismatch(tmp_path):
    # a differently-sized sketch is a different estimator: restoring it
    # into a solver configured for another sketch_k must refuse, not
    # silently serve looser (or phantom-tighter) bounds
    g = _graph()
    d = str(tmp_path / "pool")
    s1 = IMMSolver(g, engine="queue", batch=64, seed=5, sketch_k=128)
    s1.prepare(IMProblem(k=4, theta=512, mode="approximate"))
    s1.sample_until(128)
    s1.save_pool(d)
    s2 = IMMSolver(g, engine="queue", batch=64, seed=5, sketch_k=256)
    with pytest.raises(ValueError, match="signature"):
        s2.restore_pool(d)


# -------------------------------------- 8-way mesh bit-identity (subprocess)

MESH8_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import coverage as cov
from repro.graph import csr as csr_mod, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem

assert len(jax.devices()) == 8
mesh8 = Mesh(np.asarray(jax.devices()), ("samples",))
n, k = 50, 6

def batches():
    r = np.random.default_rng(7)
    out = []
    for _ in range(4):
        lens = r.integers(0, 8, 61)
        w = max(int(lens.max()), 1)
        nodes = np.zeros((61, w), np.int64)
        for i, ln in enumerate(lens):
            if ln:
                nodes[i, :ln] = r.choice(n, size=ln, replace=False)
        out.append((nodes, lens))
    return out

# store level: fold + selection bit-identical on 1-dev vs 8-dev meshes,
# in and out of the exact regime, all under the transfer guard
for sketch_k in (64, 256):
    d1 = cov.SketchRRStore(n, sketch_k=sketch_k)
    d8 = cov.SketchRRStore(n, sketch_k=sketch_k, mesh=mesh8)
    with jax.transfer_guard("disallow"):
        for b in batches():
            d1.append_batch(b)
            d8.append_batch(b)
        assert d1.n_rr == d8.n_rr and d1.n_elems == d8.n_elems
        s1, s8 = jax.device_get((d1.sketch_words(), d8.sketch_words()))
        assert np.array_equal(np.asarray(s1), np.asarray(s8)), \
            ("frontier fold diverged across mesh sizes", sketch_k)
        i1, i8 = {}, {}
        r1 = cov.select_seeds_sketch(d1, k, info_out=i1)
        r8 = cov.select_seeds_sketch(d8, k, info_out=i8)
        a, b_ = jax.device_get(((r1.seeds, r1.gains, r1.frac),
                                (r8.seeds, r8.gains, r8.frac)))
        assert np.array_equal(a[0], b_[0]), (sketch_k, a[0], b_[0])
        assert np.array_equal(a[1], b_[1]) and a[2] == b_[2]
        assert i1 == i8, (i1, i8)

# end to end: same engine stream, pool-free solve, 1-dev vs 8-dev
src, dst = generators.erdos_renyi(60, 300, seed=6)
g = weights.wc_weights(csr_mod.from_edges(src, dst, 60))
res = {}
p = IMProblem(k=4, theta=1024, mode="approximate")
for mesh in (None, mesh8):
    solver = IMMSolver(g, engine="queue", batch=64, seed=3, sketch_k=128,
                       mesh=mesh)
    solver.prepare(p)   # host-side construction outside the guard
    with jax.transfer_guard("disallow"):
        r = solver.solve(p)
    res[r.stats.pool_sharding] = (r.seeds.tolist(), round(r.spread, 6),
                                  tuple(round(b, 6) for b in r.spread_bounds))
assert res["samples:1"] == res["samples:8"], res
print("OK", res["samples:8"])
"""


def test_approximate_bit_identical_across_mesh_sizes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", MESH8_SCRIPT], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "OK" in r.stdout

"""Attention variants: GQA (opt. QKV bias / sliding window) and MLA.

All functions are pure; the causal/window masks are built from positions so
the same code serves train (full seq), prefill, and single-token decode with a
KV cache (mask over cache positions).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init


# --------------------------------------------------------------------- GQA

def gqa_init(key, d_model, n_heads, n_kv_heads, head_dim, *, qkv_bias=False,
             dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wk": dense_init(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wv": dense_init(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def _sdpa(q, k, v, q_pos, k_pos, window, softmax_scale, shard=None):
    """q:(B,Sq,H,D) k,v:(B,Sk,Hkv,D); causal + optional window.

    GQA is computed repeat-KV style (K/V expanded to H heads) so the head
    dim stays a single shardable axis — the Megatron rule for tp > n_kv
    (KV duplicated across the TP group instead of sharding the contraction,
    which would all-reduce S² logits).  ``window < 0`` = global attention.
    ``shard``: optional (dp_axes, tp_axis, tp_size) activation constraints.
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    causal = q_pos[:, None, :] >= k_pos[:, :, None]              # (B, Sk, Sq)
    if window is not None:
        in_win = (q_pos[:, None, :] - k_pos[:, :, None]) < window
        win_mask = jnp.where(window < 0, causal, causal & in_win)
    else:
        win_mask = causal
    mask = win_mask.transpose(0, 2, 1)                           # (B, Sq, Sk)

    # Single-token decode, or no TP context: grouped einsum (no KV repeat,
    # KV keeps its input sharding — critical for sequence-sharded caches).
    if shard is None or sq == 1:
        qg = q.reshape(b, sq, hkv, group, d)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)
        logits = logits.astype(jnp.float32) * softmax_scale
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return out.reshape(b, sq, h, d)

    # Train/prefill with TP: repeat-KV (Megatron rule for tp > n_kv) so the
    # head dim is a single shardable axis.
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    def con(x):
        dp, tp, tp_size = shard
        if dp is None:
            return x
        head_ax = tp if (tp is not None and h % tp_size == 0) else None
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(dp, None, head_ax,
                                                     None))

    q, k, v = con(q), con(k), con(v)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits * softmax_scale
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return con(out)


def gqa_apply(p, x, positions, *, n_heads, n_kv_heads, head_dim,
              rope_theta=10000.0, window=None, cache=None, shard=None,
              chunk=None):
    """cache: optional (k (B,S,Hkv,D), v (B,S,Hkv,D), k_pos (B,S)).
    Returns (out, new_cache).  shard: (dp_axes, tp_axis, tp_size);
    chunk: flash-style chunked attention block size (§Perf/H6)."""
    from repro.models.layers import dense
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if cache is not None:
        ck, cv, cpos = cache
        k_all = jnp.concatenate([ck, k], axis=1)
        v_all = jnp.concatenate([cv, v], axis=1)
        kpos_all = jnp.concatenate([cpos, positions], axis=1)
    else:
        k_all, v_all, kpos_all = k, v, positions
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    if chunk is not None and s > 1:
        out = sdpa_chunked(q, k_all, v_all, positions, kpos_all, window,
                           scale, chunk=chunk, shard=shard)
    else:
        out = _sdpa(q, k_all, v_all, positions, kpos_all, window, scale,
                    shard=shard)
    out = dense(p["wo"], out.reshape(b, s, n_heads * head_dim))
    return out, (k_all, v_all, kpos_all)


def sdpa_chunked(q, k, v, q_pos, k_pos, window, softmax_scale,
                 chunk: int = 1024, shard=None):
    """Flash-style attention: lax.scan over KV chunks with an online
    softmax — O(Sq·chunk) live logits instead of O(Sq·Sk) (§Perf/H6).

    Numerically identical to `_sdpa` (same masking semantics); the running
    (max, sum, acc) recurrence is the standard streaming-softmax update.
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    if shard is not None:
        dp, tp, tp_size = shard
        if dp is not None:
            from jax.sharding import PartitionSpec as P
            head_ax = tp if (tp is not None and h % tp_size == 0) else None
            con = lambda x: jax.lax.with_sharding_constraint(
                x, P(dp, None, head_ax, None))
            q, k, v = con(q), con(k), con(v)
    sk = k.shape[1]
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.int32(2 ** 30))
    nc = (sk + pad) // chunk
    kc = k.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kj).astype(jnp.float32)
        logits = logits * softmax_scale
        causal = q_pos[:, None, :, None] >= pj[:, None, None, :]
        if window is not None:
            in_win = (q_pos[:, None, :, None] - pj[:, None, None, :]) < window
            mask = jnp.where(window < 0, causal, causal & in_win)
        else:
            mask = causal
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = (acc * corr.transpose(0, 2, 1)[..., None]
               + jnp.einsum("bhqk,bkhd->bqhd", p,
                            vj.astype(jnp.float32)))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, h, d), jnp.float32)   # fp32 accumulator
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, pc))
    denom = jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
    return (acc / denom).astype(q.dtype)


# --------------------------------------------------------------------- MLA

class MLAConfig(NamedTuple):
    """DeepSeek-V3 multi-head latent attention [arXiv:2412.19437]."""
    n_heads: int = 128
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


def mla_init(key, d_model, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    h, r = cfg.n_heads, cfg
    return {
        "wq_down": dense_init(ks[0], d_model, r.q_lora_rank, dtype=dtype),
        "wq_up": dense_init(ks[1], r.q_lora_rank,
                            h * (r.qk_nope_head_dim + r.qk_rope_head_dim),
                            dtype=dtype),
        "wkv_down": dense_init(ks[2], d_model,
                               r.kv_lora_rank + r.qk_rope_head_dim,
                               dtype=dtype),
        "wk_up": dense_init(ks[3], r.kv_lora_rank,
                            h * r.qk_nope_head_dim, dtype=dtype),
        "wv_up": dense_init(ks[4], r.kv_lora_rank, h * r.v_head_dim,
                            dtype=dtype),
        "wo": dense_init(ks[5], h * r.v_head_dim, d_model, dtype=dtype),
    }


def _head_constrain(x, shard, n_heads):
    """Pin (B, S, H, D) activations: head dim on tp when divisible."""
    if shard is None:
        return x
    dp, tp, tp_size = shard
    if dp is None:
        return x
    from jax.sharding import PartitionSpec as P
    head_ax = tp if (tp is not None and n_heads % tp_size == 0) else None
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 3)), head_ax, None))


def mla_apply(p, x, positions, cfg: MLAConfig, *, rope_theta=10000.0,
              cache=None, shard=None):
    """MLA with the *latent* KV cache: what is cached per token is the
    kv_lora_rank-dim latent + the shared rope key (576 dims for V3), not the
    per-head K/V — the 500k-context enabler (DESIGN.md §6).

    cache: optional (c_kv (B,S,r_kv), k_rope (B,S,1,Dr), pos (B,S)).
    """
    from repro.models.layers import dense
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    # queries
    q = dense(p["wq_up"], dense(p["wq_down"], x))
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    # latent kv + shared rope key
    kv = dense(p["wkv_down"], x)                           # (B,S,r_kv+Dr)
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)
    if cache is not None:
        pc, pk, ppos = cache
        c_kv = jnp.concatenate([pc, c_kv], axis=1)
        k_rope = jnp.concatenate([pk, k_rope], axis=1)
        kpos = jnp.concatenate([ppos, positions], axis=1)
    else:
        kpos = positions
    sk = c_kv.shape[1]
    # expand latents to per-head keys/values (decode: absorbed matmuls)
    k_nope = dense(p["wk_up"], c_kv).reshape(b, sk, h, dn)
    v = dense(p["wv_up"], c_kv).reshape(b, sk, h, dv)
    q_nope = _head_constrain(q_nope, shard, h)
    q_rope = _head_constrain(q_rope, shard, h)
    k_nope = _head_constrain(k_nope, shard, h)
    v = _head_constrain(v, shard, h)
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope[:, :, 0, :])
              ).astype(jnp.float32) * scale
    causal = (positions[:, :, None] >= kpos[:, None, :])[:, None, :, :]
    logits = jnp.where(causal, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, h * dv)
    return dense(p["wo"], out), (c_kv, k_rope, kpos)

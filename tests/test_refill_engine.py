"""Persistent-lane (refill) sampler: correctness + utilization win."""
import numpy as np
import jax
import networkx as nx

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import rrset


def _wc_graph(n=60, m=240, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def test_refill_p1_sets_are_reverse_reachable():
    src, dst = generators.erdos_renyi(40, 160, seed=1)
    g = weights.uniform_weights(csr_mod.from_edges(src, dst, 40), p=1.0)
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_refill(jax.random.key(0), g_rev, batch=4,
                                   quota=12, out_cap=6 * 40)
    assert not bool(np.asarray(s.overflowed).any())
    assert int(np.asarray(s.n_done).sum()) >= 12
    G = nx.DiGraph()
    G.add_nodes_from(range(40))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    for row in rrset.refill_to_lists(s):
        root = row[0]
        assert set(row) == (nx.ancestors(G, root) | {root})
        assert len(set(row)) == len(row)


def test_refill_statistics_match_round_engine():
    g = _wc_graph(n=40, m=200, seed=2)
    g_rev = csr_mod.reverse(g)
    occ_ref = np.zeros(40)
    occ_rf = np.zeros(40)
    total = 0
    for i in range(4):
        sr = rrset.sample_rrsets_queue(jax.random.key(i), g_rev, 256,
                                       qcap=40)
        for row in rrset.to_lists(sr):
            occ_ref[row] += 1
        sf = rrset.sample_rrsets_refill(jax.random.key(100 + i), g_rev,
                                        batch=64, quota=256,
                                        out_cap=40 * 8)
        rows = rrset.refill_to_lists(sf)
        total += len(rows)
        for row in rows:
            occ_rf[row] += 1
    p1, p2 = occ_ref / 1024, occ_rf / total
    se = np.sqrt((p1 * (1 - p1) + p2 * (1 - p2)) / min(1024, total)) + 1e-9
    assert (np.abs(p1 - p2) / se).max() < 4.5


def test_refill_uses_fewer_lane_steps():
    """The §Perf/IM hypothesis: refill needs far fewer micro-steps than the
    round engine for the same number of RR sets (tail-latency removal)."""
    src, dst = generators.barabasi_albert(5000, 6, seed=0)
    g = weights.wc_weights(csr_mod.from_edges(src, dst, 5000))
    g_rev = csr_mod.reverse(g)
    # 512 RR sets each way
    steps_round = 0
    for i in range(4):
        s = rrset.sample_rrsets_queue(jax.random.key(i), g_rev, 128,
                                      qcap=5000)
        steps_round += int(s.steps)
    sf = rrset.sample_rrsets_refill(jax.random.key(9), g_rev, batch=128,
                                    quota=512, out_cap=8192)
    assert not bool(np.asarray(sf.overflowed).any())
    assert int(np.asarray(sf.n_done).sum()) >= 512
    steps_refill = int(sf.steps)
    assert steps_refill < 0.75 * steps_round, (steps_refill, steps_round)

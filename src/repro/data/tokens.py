"""Deterministic sharded synthetic LM data pipeline.

Production properties kept: (a) per-(step, shard) deterministic batches —
restart/elastic-safe (a resumed job at step t on any device count sees the
same global batch); (b) zero host I/O (synthetic zipf-ish token stream keeps
the loss landscape non-trivial); (c) double-buffered prefetch helper.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def global_batch_at(step: int, *, global_batch: int, seq_len: int,
                    vocab: int, seed: int = 0) -> np.ndarray:
    """The full logical batch for a step (host, numpy).  Zipf-distributed
    tokens with per-row Markov repetition so next-token prediction is
    learnable."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ranks = rng.zipf(1.3, size=(global_batch, seq_len)).astype(np.int64)
    tokens = np.minimum(ranks, vocab - 1)
    # inject learnable bigram structure: with p=0.5 repeat previous token
    rep = rng.random((global_batch, seq_len)) < 0.5
    for j in range(1, seq_len):
        tokens[:, j] = np.where(rep[:, j], tokens[:, j - 1], tokens[:, j])
    return tokens.astype(np.int32)


def shard_for(step: int, shard: int, n_shards: int, **kw) -> np.ndarray:
    """This shard's rows of the step's global batch."""
    gb = global_batch_at(step, **kw)
    rows = gb.shape[0] // n_shards
    return gb[shard * rows:(shard + 1) * rows]


def batch_stream(start_step: int, *, global_batch: int, seq_len: int,
                 vocab: int, seed: int = 0) -> Iterator[np.ndarray]:
    step = start_step
    while True:
        yield global_batch_at(step, global_batch=global_batch,
                              seq_len=seq_len, vocab=vocab, seed=seed)
        step += 1


def prefetch(iterator, size: int = 2):
    """Device-put ahead-of-use (double buffering)."""
    import collections
    buf = collections.deque()
    for x in iterator:
        buf.append(jax.device_put(x))
        if len(buf) > size:
            yield buf.popleft()
    while buf:
        yield buf.popleft()

"""Model-zoo unit tests (reduced configs, CPU): shapes, NaNs, invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import transformer as T
from repro.models import gnn, deepfm, embedding


def test_rms_and_nonparam_norm():
    x = jax.random.normal(jax.random.key(0), (4, 8)) * 3 + 1
    y = L.rms_norm(x, jnp.zeros(8))
    assert np.allclose(np.mean(np.asarray(y) ** 2, -1), 1.0, atol=1e-4)
    z = L.nonparametric_layer_norm(x)
    assert np.allclose(np.asarray(z).mean(-1), 0.0, atol=1e-5)
    assert np.allclose(np.asarray(z).std(-1), 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(jax.random.key(1), (2, 6, 4, 8))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    y = A.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.key(2), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.key(3), (1, 1, 1, 8))
    def dot_at(p, d):
        qr = A.apply_rope(q, jnp.asarray([[p]]))
        kr = A.apply_rope(k, jnp.asarray([[p + d]]))
        return float((qr * kr).sum())
    assert abs(dot_at(0, 3) - dot_at(10, 3)) < 1e-4


def test_gqa_causality():
    """Perturbing future tokens must not change past outputs."""
    cfg = dict(n_heads=4, n_kv_heads=2, head_dim=8)
    p = A.gqa_init(jax.random.key(0), 16, 4, 2, 8)
    x = jax.random.normal(jax.random.key(1), (1, 6, 16))
    pos = jnp.arange(6)[None]
    out1, _ = A.gqa_apply(p, x, pos, **cfg)
    x2 = x.at[0, 4:].add(1.0)
    out2, _ = A.gqa_apply(p, x2, pos, **cfg)
    np.testing.assert_allclose(np.asarray(out1[0, :4]),
                               np.asarray(out2[0, :4]), atol=1e-5)


def test_sliding_window_masks_far_tokens():
    p = A.gqa_init(jax.random.key(0), 16, 4, 4, 8)
    x = jax.random.normal(jax.random.key(1), (1, 10, 16))
    pos = jnp.arange(10)[None]
    kw = dict(n_heads=4, n_kv_heads=4, head_dim=8)
    out_w, _ = A.gqa_apply(p, x, pos, window=2, **kw)
    # perturb token 0: with window=2, token 9 cannot see it
    x2 = x.at[0, 0].add(5.0)
    out_w2, _ = A.gqa_apply(p, x2, pos, window=2, **kw)
    np.testing.assert_allclose(np.asarray(out_w[0, 9]),
                               np.asarray(out_w2[0, 9]), atol=1e-5)
    # but with global attention it can
    out_g, _ = A.gqa_apply(p, x, pos, window=None, **kw)
    out_g2, _ = A.gqa_apply(p, x2, pos, window=None, **kw)
    assert np.abs(np.asarray(out_g[0, 9]) - np.asarray(out_g2[0, 9])).max() > 1e-4


def test_mla_shapes_and_causality():
    mcfg = A.MLAConfig(n_heads=4, q_lora_rank=12, kv_lora_rank=8,
                       qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)
    p = A.mla_init(jax.random.key(0), 16, mcfg)
    x = jax.random.normal(jax.random.key(1), (2, 5, 16))
    pos = jnp.broadcast_to(jnp.arange(5)[None], (2, 5))
    out, (c_kv, k_rope, _) = A.mla_apply(p, x, pos, mcfg)
    assert out.shape == (2, 5, 16)
    assert c_kv.shape == (2, 5, 8)          # latent cache, not per-head
    assert k_rope.shape == (2, 5, 1, 4)
    x2 = x.at[:, 3:].add(1.0)
    out2, _ = A.mla_apply(p, x2, pos, mcfg)
    np.testing.assert_allclose(np.asarray(out[:, :3]),
                               np.asarray(out2[:, :3]), atol=1e-5)


def test_moe_routes_and_shapes():
    cfg = M.MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      capacity_factor=2.0)
    p = M.moe_init(jax.random.key(0), 16, cfg)
    x = jax.random.normal(jax.random.key(1), (4, 6, 16))
    y, aux = M.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_moe_capacity_one_expert_degenerate():
    """top-1 of 1 expert with big capacity == plain FFN + shared."""
    cfg = M.MoEConfig(n_experts=1, top_k=1, d_ff_expert=32, n_shared=0,
                      capacity_factor=4.0)
    p = M.moe_init(jax.random.key(0), 16, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 3, 16))
    y, _ = M.moe_apply(p, x, cfg)
    expert0 = jax.tree.map(lambda a: a[0], p["experts"])
    want = L.ffn(expert0, x.reshape(-1, 16)).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


TINY = dict(n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
            d_ff=64, vocab=128)


@pytest.mark.parametrize("variant", ["qwen2", "olmo", "gemma3", "deepseek",
                                     "llama4"])
def test_tiny_lm_forward_and_loss(variant):
    kw = dict(TINY)
    if variant == "qwen2":
        cfg = T.LMConfig(name="tiny-qwen2", qkv_bias=True, **kw)
    elif variant == "olmo":
        cfg = T.LMConfig(name="tiny-olmo", norm="nonparam",
                         tie_embeddings=False, **kw)
    elif variant == "gemma3":
        cfg = T.LMConfig(name="tiny-gemma3", act="geglu",
                         local_global=(1, 4), **kw)
    elif variant == "deepseek":
        cfg = T.LMConfig(
            name="tiny-deepseek",
            mla=A.MLAConfig(n_heads=4, q_lora_rank=16, kv_lora_rank=8,
                            qk_nope_head_dim=8, qk_rope_head_dim=4,
                            v_head_dim=8),
            moe=M.MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                            router_score="sigmoid", capacity_factor=2.0),
            n_dense_layers=1, d_ff_dense=64, mtp=True, **kw)
    else:
        cfg = T.LMConfig(
            name="tiny-llama4",
            moe=M.MoEConfig(n_experts=4, top_k=1, d_ff_expert=32, n_shared=1,
                            router_score="sigmoid", capacity_factor=2.0), **kw)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab)
    hidden, aux, _ = T.lm_backbone(params, cfg, tokens)
    assert hidden.shape == (2, 10, cfg.d_model)
    logits = T.lm_logits(params, cfg, hidden)
    assert logits.shape == (2, 10, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss = T.lm_loss(params, cfg, tokens)
    assert np.isfinite(float(loss))
    # gradients flow
    g = jax.grad(lambda p: T.lm_loss(p, cfg, tokens))(params)
    gnorm = sum(float((x ** 2).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


def test_decode_matches_forward():
    """serve_step token-by-token reproduces the full-forward logits."""
    cfg = T.LMConfig(name="tiny-qwen2", qkv_bias=True, **TINY)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    hidden, _, _ = T.lm_backbone(params, cfg, tokens)
    full_logits = T.lm_logits(params, cfg, hidden)
    caches = T.init_cache(cfg, batch=2, max_len=16)
    for t in range(8):
        logits, caches = T.serve_step(params, cfg, tokens[:, t:t + 1], caches,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=1e-4)


def test_decode_matches_forward_gemma_pattern():
    cfg = T.LMConfig(name="tiny-gemma3", act="geglu", local_global=(1, 4),
                     **TINY)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab)
    hidden, _, _ = T.lm_backbone(params, cfg, tokens)
    full_logits = T.lm_logits(params, cfg, hidden)
    caches = T.init_cache(cfg, batch=1, max_len=8)
    for t in range(8):
        logits, caches = T.serve_step(params, cfg, tokens[:, t:t + 1], caches,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=1e-4)


# ----------------------------------------------------------------- GNN/rec

def _toy_graph(n=12, m=40, seed=0):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    mask = jnp.ones(m, bool)
    return src, dst, mask


def test_gat_shapes():
    cfg = gnn.GATConfig(n_layers=2, d_hidden=8, n_heads=8, d_in=20,
                        n_classes=7)
    p = gnn.gat_init(jax.random.key(0), cfg)
    src, dst, mask = _toy_graph()
    x = jax.random.normal(jax.random.key(1), (12, 20))
    out = gnn.gat_apply(p, cfg, x, src, dst, mask)
    assert out.shape == (12, 7)
    assert np.isfinite(np.asarray(out)).all()


def test_gin_sum_aggregation_counts():
    """GIN with identity-ish MLP distinguishes node degree (sum agg)."""
    cfg = gnn.GINConfig(n_layers=1, d_hidden=4, d_in=1, n_classes=2)
    p = gnn.gin_init(jax.random.key(0), cfg)
    src = jnp.asarray([0, 1, 2], jnp.int32)
    dst = jnp.asarray([3, 3, 3], jnp.int32)
    mask = jnp.ones(3, bool)
    x = jnp.ones((4, 1))
    out = gnn.gin_apply(p, cfg, x, src, dst, mask)
    assert out.shape == (4, 2)


def test_egnn_equivariance():
    """Rotating+translating inputs rotates+translates coordinate outputs."""
    cfg = gnn.EGNNConfig(n_layers=2, d_hidden=16, d_in=8)
    p = gnn.egnn_init(jax.random.key(0), cfg)
    src, dst, mask = _toy_graph(n=10, m=30, seed=2)
    h = jax.random.normal(jax.random.key(1), (10, 8))
    x = jax.random.normal(jax.random.key(2), (10, 3))
    # random rotation via QR
    q, _ = np.linalg.qr(np.random.default_rng(3).normal(size=(3, 3)))
    q = jnp.asarray(q * np.sign(np.linalg.det(q)), jnp.float32)
    t = jnp.asarray([1.0, -2.0, 0.5])
    h1, x1 = gnn.egnn_apply(p, cfg, h, x, src, dst, mask)
    h2, x2 = gnn.egnn_apply(p, cfg, h, x @ q.T + t, src, dst, mask)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(x1 @ q.T + t), np.asarray(x2),
                               atol=1e-3)


def test_graphcast_residual_stack():
    cfg = gnn.GraphCastConfig(n_layers=3, d_hidden=16, d_in=10, d_out=10)
    p = gnn.graphcast_init(jax.random.key(0), cfg)
    src, dst, mask = _toy_graph(n=15, m=50, seed=4)
    x = jax.random.normal(jax.random.key(1), (15, 10))
    out = gnn.graphcast_apply(p, cfg, x, src, dst, mask)
    assert out.shape == (15, 10)
    assert np.isfinite(np.asarray(out)).all()


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([0, 1, 2, 5], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = embedding.embedding_bag(table, ids, seg, 2, mode="sum")
    np.testing.assert_allclose(np.asarray(out),
                               [[2., 4.], [14., 16.]])
    out = embedding.embedding_bag(table, ids, seg, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(out), [[1., 2.], [7., 8.]])


def test_sharded_lookup_matches_take():
    import subprocess, sys, os
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.models.embedding import sharded_lookup
table = jnp.asarray(np.random.default_rng(0).normal(size=(32, 4)),
                    jnp.float32)
ids = jnp.asarray([0, 5, 31, 8, 17, 16], jnp.int32)
mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("model",))
fn = shard_map(lambda t, i: sharded_lookup(t, i, "model"), mesh=mesh,
               in_specs=(P("model", None), P()), out_specs=P())
out = fn(table, ids)
np.testing.assert_allclose(np.asarray(out),
                           np.asarray(jnp.take(table, ids, axis=0)),
                           rtol=1e-6)
print("OK")
"""
    env = dict(os.environ); env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]


def test_deepfm_forward_and_fm_term():
    vocabs = tuple([50] * 5)
    cfg = deepfm.DeepFMConfig(n_sparse=5, embed_dim=4, mlp_dims=(16, 8),
                              field_vocabs=vocabs, n_dense_feats=3)
    p = deepfm.deepfm_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 50, (6, 5))
                      + cfg.field_offsets[None, :], jnp.int32)
    dense_x = jnp.asarray(rng.normal(size=(6, 3)), jnp.float32)
    logits = deepfm.deepfm_logits(p, cfg, ids, dense_x)
    assert logits.shape == (6,)
    labels = jnp.asarray(rng.integers(0, 2, 6), jnp.float32)
    loss = deepfm.deepfm_loss(p, cfg, ids, dense_x, labels)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda pp: deepfm.deepfm_loss(pp, cfg, ids, dense_x,
                                               labels))(p)
    assert np.isfinite(sum(float((x ** 2).sum())
                           for x in jax.tree.leaves(g)))


def test_retrieval_topk():
    rng = np.random.default_rng(1)
    cand = jnp.asarray(rng.normal(size=(1000, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    vals, idx = deepfm.retrieval_topk(q, cand, 10)
    scores = np.asarray(cand) @ np.asarray(q)
    np.testing.assert_allclose(np.asarray(vals), np.sort(scores)[::-1][:10],
                               rtol=1e-5)

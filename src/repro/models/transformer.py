"""Unified LM transformer covering the five assigned architectures.

Feature matrix (all first-class config switches):
  qwen2-0.5b    GQA (kv=2) + QKV bias, RMSNorm, SwiGLU, tied embeddings
  olmo-1b       GQA (kv=16=MHA), non-parametric LN, SwiGLU, untied
  gemma3-12b    GQA (kv=8), 5:1 local:global sliding window (w=1024), GeGLU
  deepseek-v3   MLA + MoE (1 shared + 256 routed, top-8), 3 leading dense
                layers, MTP head
  llama4-scout  GQA (kv=8) + MoE (1 shared + 16 routed, top-1)

Layers are grouped into homogeneous *blocks* scanned with ``jax.lax.scan``
(stacked params) to keep HLO size O(1) in depth; heterogeneous structure
(DeepSeek's 3 dense layers) becomes a separate block.  Per-layer sliding
windows are a scanned int array, so gemma's 5:1 pattern lives in data, not
in program structure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    norm: str = "rms"                    # "rms" | "nonparam"
    act: str = "swiglu"                  # "swiglu" | "geglu"
    rope_theta: float = 10000.0
    local_global: Optional[tuple[int, int]] = None   # (n_local_per_global, window)
    moe: Optional[M.MoEConfig] = None
    n_dense_layers: int = 0              # leading dense layers (deepseek: 3)
    d_ff_dense: Optional[int] = None
    mla: Optional[A.MLAConfig] = None
    tie_embeddings: bool = True
    mtp: bool = False
    dtype: str = "float32"
    remat: bool = False   # per-layer activation checkpointing (scan body)
    # activation sharding constraints (None = let XLA propagate; set by the
    # launcher): act_dp = batch axes, act_tp = tensor axis
    act_dp: Optional[tuple] = None
    act_tp: Optional[str] = None
    tp_size: int = 1      # size of the act_tp mesh axis (head shardability)
    unroll: bool = False  # python-loop layers (cost probes; HLO grows O(L))
    # decode-cache write strategy: iota-compare select instead of
    # dynamic-update-slice — keeps a sequence-sharded cache shard-local
    # (GSPMD "involuntary full rematerialization" avoidance, §Perf/H2)
    scatter_cache_update: bool = False
    # remat policy: None = save nothing (full recompute); "moe_save" =
    # keep the MoE dispatch/output buffers (skips re-running the dispatch
    # collectives in the backward pass, §Perf/H1c)
    remat_policy: Optional[str] = None
    # MLA decode: absorb wk_up into Q and wv_up into the output so the
    # latent cache is attended directly — never expands (S, H, d_nope)
    # per step (§Perf/H5, DeepSeek-V2 "absorbed" inference formulation)
    absorbed_mla_decode: bool = False
    # flash-style chunked attention block size for train/prefill (§Perf/H6;
    # None = materialize full S^2 logits)
    attn_chunk: Optional[int] = None

    @property
    def attn_shard(self):
        if self.act_dp is None:
            return None
        return (self.act_dp, self.act_tp, self.tp_size)

    def constrain(self, x, *tail):
        """Pin activation sharding to P(act_dp, *tail) when configured."""
        if self.act_dp is None:
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(self.act_dp, *tail))

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def windows(self) -> np.ndarray:
        """Per-layer attention window; -1 = global."""
        w = np.full(self.n_layers, -1, dtype=np.int32)
        if self.local_global is not None:
            n_local, win = self.local_global
            for i in range(self.n_layers):
                if (i + 1) % (n_local + 1) != 0:   # every (n+1)th is global
                    w[i] = win
        return w


# ------------------------------------------------------------------ params

def _layer_init(key, cfg: LMConfig, *, is_moe: bool, d_ff: int):
    ka, kf = jax.random.split(key)
    dt = cfg.param_dtype
    p = {}
    if cfg.mla is not None:
        p["attn"] = A.mla_init(ka, cfg.d_model, cfg.mla, dtype=dt)
    else:
        p["attn"] = A.gqa_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, qkv_bias=cfg.qkv_bias, dtype=dt)
    if is_moe:
        p["moe"] = M.moe_init(kf, cfg.d_model, cfg.moe, dtype=dt)
    else:
        p["ffn"] = L.ffn_init(kf, cfg.d_model, d_ff, dtype=dt)
    if cfg.norm == "rms":
        p["norm_attn"] = jnp.zeros((cfg.d_model,), dt)
        p["norm_ffn"] = jnp.zeros((cfg.d_model,), dt)
    return p


def blocks_of(cfg: LMConfig) -> list[dict]:
    """Homogeneous scan groups: [{'count', 'is_moe', 'd_ff', 'windows'}]."""
    wins = cfg.windows()
    out = []
    if cfg.moe is not None and cfg.n_dense_layers > 0:
        out.append(dict(count=cfg.n_dense_layers, is_moe=False,
                        d_ff=cfg.d_ff_dense or cfg.d_ff,
                        windows=wins[:cfg.n_dense_layers]))
        out.append(dict(count=cfg.n_layers - cfg.n_dense_layers, is_moe=True,
                        d_ff=cfg.d_ff, windows=wins[cfg.n_dense_layers:]))
    elif cfg.moe is not None:
        out.append(dict(count=cfg.n_layers, is_moe=True, d_ff=cfg.d_ff,
                        windows=wins))
    else:
        out.append(dict(count=cfg.n_layers, is_moe=False, d_ff=cfg.d_ff,
                        windows=wins))
    return out


def lm_init(key, cfg: LMConfig):
    dt = cfg.param_dtype
    keys = jax.random.split(key, 4 + len(blocks_of(cfg)))
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
    }
    if cfg.norm == "rms":
        params["norm_final"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1],
                                               (cfg.d_model, cfg.vocab))
                             * 0.02).astype(dt)
    for bi, blk in enumerate(blocks_of(cfg)):
        bkeys = jax.random.split(keys[2 + bi], blk["count"])
        params[f"block{bi}"] = jax.vmap(
            lambda k: _layer_init(k, cfg, is_moe=blk["is_moe"],
                                  d_ff=blk["d_ff"]))(bkeys)
    if cfg.mtp:
        kl, kp = jax.random.split(keys[-1])
        params["mtp_layer"] = _layer_init(kl, cfg, is_moe=False,
                                          d_ff=cfg.d_ff_dense or cfg.d_ff)
        params["mtp_proj"] = L.dense_init(kp, 2 * cfg.d_model, cfg.d_model,
                                          dtype=dt)
    return params


# ----------------------------------------------------------------- forward

def _norm(cfg, x, scale):
    if cfg.norm == "rms":
        return L.rms_norm(x, scale)
    return L.nonparametric_layer_norm(x)


def _layer_apply(cfg: LMConfig, p, x, positions, window, *, is_moe: bool,
                 cache=None):
    h = _norm(cfg, x, p.get("norm_attn"))
    if cfg.mla is not None:
        a, new_cache = A.mla_apply(p["attn"], h, positions, cfg.mla,
                                   rope_theta=cfg.rope_theta, cache=cache,
                                   shard=cfg.attn_shard)
    else:
        a, new_cache = A.gqa_apply(p["attn"], h, positions,
                                   n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim,
                                   rope_theta=cfg.rope_theta,
                                   window=window, cache=cache,
                                   shard=cfg.attn_shard,
                                   chunk=cfg.attn_chunk)
    x = cfg.constrain(x + a, None, None)
    h = _norm(cfg, x, p.get("norm_ffn"))
    if is_moe:
        f, aux = M.moe_apply(p["moe"], h, cfg.moe, act=cfg.act,
                             ep_axis=cfg.act_tp, dp_axis=cfg.act_dp)
    else:
        f, aux = L.ffn(p["ffn"], h, act=cfg.act), jnp.float32(0.0)
    return cfg.constrain(x + f, None, None), aux, new_cache


def lm_backbone(params, cfg: LMConfig, tokens, positions=None, caches=None):
    """Returns (hidden (B,S,d), aux_loss, new_caches)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :],
                                     (b, s))
    x = cfg.constrain(params["embed"][tokens].astype(cfg.param_dtype),
                      None, None)
    if cfg.name.startswith("gemma"):
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    aux_total = jnp.float32(0.0)
    new_caches = []
    for bi, blk in enumerate(blocks_of(cfg)):
        bp = params[f"block{bi}"]
        wins = jnp.asarray(blk["windows"], jnp.int32)
        cache_b = caches[bi] if caches is not None else None

        def scan_fn(carry, xs):
            x, aux = carry
            if cache_b is not None:
                lp, w, lc = xs
                x, a, nc = _layer_apply(cfg, lp, x, positions, w,
                                        is_moe=blk["is_moe"], cache=lc)
            else:
                lp, w = xs
                x, a, nc = _layer_apply(cfg, lp, x, positions, w,
                                        is_moe=blk["is_moe"], cache=None)
                nc = 0
            return (x, aux + a), nc

        xs = (bp, wins, cache_b) if cache_b is not None else (bp, wins)
        if cfg.remat and cfg.remat_policy == "moe_save":
            body = jax.checkpoint(
                scan_fn, policy=jax.checkpoint_policies
                .save_only_these_names("moe_dispatch", "moe_out"))
        elif cfg.remat:
            body = jax.checkpoint(scan_fn)
        else:
            body = scan_fn
        if cfg.unroll:
            ncs = []
            for li in range(blk["count"]):
                xsl = jax.tree_util.tree_map(lambda a: a[li], xs)
                (x, aux_total), nci = body((x, aux_total), xsl)
                ncs.append(nci)
            nc = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ncs)
                  if cache_b is not None else 0)
        else:
            (x, aux_total), nc = jax.lax.scan(body, (x, aux_total), xs)
        new_caches.append(nc if cache_b is not None else None)
    x = _norm(cfg, x, params.get("norm_final"))
    return x, aux_total, new_caches


def lm_logits(params, cfg: LMConfig, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return cfg.constrain(hidden @ head.astype(hidden.dtype),
                         None, cfg.act_tp)


def lm_loss(params, cfg: LMConfig, tokens, *, aux_weight=0.01,
            mtp_weight=0.3):
    """Next-token CE (+ MoE aux + optional MTP loss).  tokens: (B, S)."""
    hidden, aux, _ = lm_backbone(params, cfg, tokens)
    logits = lm_logits(params, cfg, hidden[:, :-1])
    loss = L.cross_entropy_loss(logits, tokens[:, 1:])
    if cfg.mtp:
        # predict t+2 from (h_t, embed(token_{t+1})) — DeepSeek-V3 §2.2
        h = hidden[:, :-2]
        emb_next = params["embed"][tokens[:, 1:-1]].astype(h.dtype)
        mtp_in = L.dense(params["mtp_proj"],
                         jnp.concatenate([h, emb_next], axis=-1))
        b, s2, _ = mtp_in.shape
        pos = jnp.broadcast_to(jnp.arange(s2, dtype=jnp.int32)[None], (b, s2))
        mtp_h, mtp_aux, _ = _layer_apply(
            cfg, params["mtp_layer"], mtp_in, pos, jnp.int32(-1),
            is_moe=False, cache=None)
        mtp_logits = lm_logits(params, cfg, mtp_h)
        loss = loss + mtp_weight * L.cross_entropy_loss(mtp_logits,
                                                        tokens[:, 2:])
    return loss + aux_weight * aux


# ------------------------------------------------------------------ decode

class BlockCache(NamedTuple):
    """Per-block stacked KV cache.  GQA: k/v (L,B,S,Hkv,D); MLA: latent."""
    a: jnp.ndarray
    b: jnp.ndarray
    pos: jnp.ndarray   # (L, B, S) slot positions (-2^30 = empty)


def init_cache(cfg: LMConfig, batch: int, max_len: int, *, filled: bool = False):
    caches = []
    dt = cfg.param_dtype
    for blk in blocks_of(cfg):
        lcount = blk["count"]
        if filled:
            pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None, None],
                (lcount, batch, max_len))
        else:
            # empty slots carry +2^30 so the causal test q_pos >= k_pos
            # masks them out until written
            pos = jnp.full((lcount, batch, max_len), jnp.int32(2 ** 30))
        if cfg.mla is not None:
            r = cfg.mla
            caches.append(BlockCache(
                a=jnp.zeros((lcount, batch, max_len, r.kv_lora_rank), dt),
                b=jnp.zeros((lcount, batch, max_len, 1, r.qk_rope_head_dim),
                            dt),
                pos=pos))
        else:
            shape = (lcount, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            caches.append(BlockCache(a=jnp.zeros(shape, dt),
                                     b=jnp.zeros(shape, dt), pos=pos))
    return caches


def _cache_write(cfg: LMConfig, buf, new, slot):
    """Write ``new`` (B, 1, ...) at ring slot into ``buf`` (B, S, ...)."""
    if not cfg.scatter_cache_update:
        start = (0, slot) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                            start)
    hit = (jnp.arange(buf.shape[1], dtype=jnp.int32) == slot)
    hit = hit.reshape((1, -1) + (1,) * (buf.ndim - 2))
    return jnp.where(hit, new.astype(buf.dtype), buf)


def _layer_decode(cfg: LMConfig, p, x, positions, window, cache: dict,
                  write_slot, *, is_moe: bool):
    """One-token decode against a fixed-capacity ring cache."""
    h = _norm(cfg, x, p.get("norm_attn"))
    ck, cv, cpos = cache["a"], cache["b"], cache["pos"]
    if cfg.mla is not None:
        r = cfg.mla
        from repro.models.layers import dense
        b, s, _ = h.shape
        dn, dr = r.qk_nope_head_dim, r.qk_rope_head_dim
        q = dense(p["attn"]["wq_up"], dense(p["attn"]["wq_down"], h))
        q = q.reshape(b, s, r.n_heads, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = A.apply_rope(q_rope, positions, cfg.rope_theta)
        kv = dense(p["attn"]["wkv_down"], h)
        c_new, kr_new = kv[..., :r.kv_lora_rank], kv[..., r.kv_lora_rank:]
        kr_new = A.apply_rope(kr_new[:, :, None, :], positions, cfg.rope_theta)
        ck = _cache_write(cfg, ck, c_new, write_slot)
        cv = _cache_write(cfg, cv, kr_new, write_slot)
        cpos = _cache_write(cfg, cpos, positions.astype(cpos.dtype),
                            write_slot)
        sk = ck.shape[1]
        scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
        mask = (positions[:, :, None] >= cpos[:, None, :])[:, None]
        if cfg.absorbed_mla_decode:
            # fold wk_up into q: q_abs (B,1,H,r_kv); attend latents directly
            wk = p["attn"]["wk_up"]["w"].reshape(r.kv_lora_rank, r.n_heads,
                                                 dn).astype(h.dtype)
            q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk)
            logits = (jnp.einsum("bqhr,bkr->bhqk", q_abs, ck)
                      + jnp.einsum("bqhd,bkd->bhqk", q_rope, cv[:, :, 0, :])
                      ).astype(jnp.float32) * scale
            logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1).astype(h.dtype)
            lat = jnp.einsum("bhqk,bkr->bqhr", probs, ck)
            wv = p["attn"]["wv_up"]["w"].reshape(r.kv_lora_rank, r.n_heads,
                                                 r.v_head_dim).astype(h.dtype)
            a = jnp.einsum("bqhr,rhd->bqhd", lat, wv).reshape(
                b, s, r.n_heads * r.v_head_dim)
        else:
            k_nope = dense(p["attn"]["wk_up"], ck).reshape(b, sk, r.n_heads,
                                                           dn)
            v = dense(p["attn"]["wv_up"], ck).reshape(b, sk, r.n_heads,
                                                      r.v_head_dim)
            logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
                      + jnp.einsum("bqhd,bkd->bhqk", q_rope, cv[:, :, 0, :])
                      ).astype(jnp.float32) * scale
            logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1).astype(h.dtype)
            a = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(
                b, s, r.n_heads * r.v_head_dim)
        a = dense(p["attn"]["wo"], a)
    else:
        from repro.models.layers import dense
        b, s, _ = h.shape
        q = dense(p["attn"]["wq"], h).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = dense(p["attn"]["wk"], h).reshape(b, s, cfg.n_kv_heads,
                                              cfg.head_dim)
        v = dense(p["attn"]["wv"], h).reshape(b, s, cfg.n_kv_heads,
                                              cfg.head_dim)
        q = A.apply_rope(q, positions, cfg.rope_theta)
        k = A.apply_rope(k, positions, cfg.rope_theta)
        ck = _cache_write(cfg, ck, k, write_slot)
        cv = _cache_write(cfg, cv, v, write_slot)
        cpos = _cache_write(cfg, cpos, positions.astype(cpos.dtype),
                            write_slot)
        a = A._sdpa(q, ck, cv, positions, cpos, window,
                    1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32),
                    shard=cfg.attn_shard)
        a = dense(p["attn"]["wo"], a.reshape(b, s,
                                             cfg.n_heads * cfg.head_dim))
    x = x + a
    h = _norm(cfg, x, p.get("norm_ffn"))
    if is_moe:
        f, _ = M.moe_apply(p["moe"], h, cfg.moe, act=cfg.act)
    else:
        f = L.ffn(p["ffn"], h, act=cfg.act)
    return x + f, {"a": ck, "b": cv, "pos": cpos}


def serve_step(params, cfg: LMConfig, tokens, caches, cur_pos):
    """Decode one token.  tokens (B,1); caches from init_cache; cur_pos ()
    int32 = logical position of this token; ring slot = cur_pos % capacity.
    Returns (logits (B,1,V), new caches)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(cur_pos[None, None].astype(jnp.int32),
                                 (b, s))
    x = cfg.constrain(params["embed"][tokens].astype(cfg.param_dtype),
                      None, None)
    if cfg.name.startswith("gemma"):
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    new_caches = []
    for bi, blk in enumerate(blocks_of(cfg)):
        bp = params[f"block{bi}"]
        wins = jnp.asarray(blk["windows"], jnp.int32)
        cap = caches[bi].pos.shape[-1]
        slot = (cur_pos % cap).astype(jnp.int32)

        def scan_fn(x, xs):
            lp, w, ca, cb, cp = xs
            x, nc = _layer_decode(cfg, lp, x, positions, w,
                                  {"a": ca, "b": cb, "pos": cp}, slot,
                                  is_moe=blk["is_moe"])
            return x, (nc["a"], nc["b"], nc["pos"])

        xs = (bp, wins, caches[bi].a, caches[bi].b, caches[bi].pos)
        if cfg.unroll:
            outs = []
            for li in range(blk["count"]):
                xsl = jax.tree_util.tree_map(lambda a: a[li], xs)
                x, o = scan_fn(x, xsl)
                outs.append(o)
            na, nb, npos = jax.tree_util.tree_map(lambda *a: jnp.stack(a),
                                                  *outs)
        else:
            x, (na, nb, npos) = jax.lax.scan(scan_fn, x, xs)
        new_caches.append(BlockCache(a=na, b=nb, pos=npos))
    x = _norm(cfg, x, params.get("norm_final"))
    return lm_logits(params, cfg, x), new_caches

"""End-to-end driver: the paper's full pipeline at benchmark scale.

Solves IM on a Barabasi-Albert stand-in of soc-Epinions1 (n=75,879 scaled
down for CPU by --scale), under both IC and LT models, with checkpointed
sampling state (kill & re-run to see it resume), and cross-validates the
RIS estimate against forward Monte-Carlo.

    PYTHONPATH=src python examples/im_endtoend.py --scale 0.2
"""
import argparse
import os
import time

import numpy as np
import jax

from repro.graph import csr, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.core import forward
from repro.ckpt import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--eps", type=float, default=0.35)
    ap.add_argument("--model", choices=["ic", "lt"], default="ic")
    ap.add_argument("--engine", choices=["queue", "dense", "refill"],
                    default="queue")
    ap.add_argument("--ckpt", default="/tmp/repro_im_ckpt")
    args = ap.parse_args()

    n = int(75879 * args.scale)
    src, dst = generators.barabasi_albert(n, 4, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, n))
    print(f"[graph] epinions-like stand-in n={g.n_nodes} m={g.n_edges}")

    solver = IMMSolver(g, engine=args.engine, batch=512, seed=0)
    t0 = time.time()
    res = solver.solve(IMProblem(k=args.k, eps=args.eps, model=args.model))
    seeds, est, stats = res.seeds, res.spread, res.stats
    dt = time.time() - t0
    print(f"[solve] {dt:.2f}s  theta={stats.theta} "
          f"sampled={stats.n_rr_sampled} rounds={stats.rounds} "
          f"LB={stats.lb:.1f} overflow={stats.overflow_fraction:.4f}")
    print(f"[seeds] {sorted(seeds.tolist())}")
    print(f"[spread] RIS estimate = {est:.1f} "
          f"({100 * est / n:.2f}% of graph)")

    key = jax.random.key(11)
    mc = (forward.ic_spread if args.model == "ic" else forward.lt_spread)(
        key, g, seeds.tolist(), n_sims=256)
    print(f"[spread] forward MC   = {mc:.1f}  "
          f"(rel err {abs(est - mc) / mc:.2%})")

    # persist the solution + solver statistics
    ckpt.save(args.ckpt, stats.theta,
              {"seeds": np.asarray(seeds), "estimate": np.float32(est)})
    print(f"[ckpt] saved under {args.ckpt}")


if __name__ == "__main__":
    main()

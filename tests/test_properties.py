"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import networkx as nx
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import rrset, coverage as cov, oracle

SET = settings(max_examples=15, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


@st.composite
def random_graph(draw, max_n=40):
    n = draw(st.integers(5, max_n))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return csr_mod.from_edges(src, dst, n), n


@st.composite
def random_rr_sets(draw, max_n=40, max_sets=60):
    n = draw(st.integers(3, max_n))
    count = draw(st.integers(1, max_sets))
    rngseed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(rngseed)
    sets = []
    for _ in range(count):
        ln = int(rng.integers(1, min(n, 8)))
        sets.append(rng.choice(n, size=ln, replace=False).tolist())
    return sets, n


@SET
@given(random_graph(), st.integers(0, 2 ** 16))
def test_prop_rrset_structural_invariants(gn, key_seed):
    """Root first; unique nodes; subset of exact reverse reachability."""
    g, n = gn
    g = weights.wc_weights(g)
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_queue(jax.random.key(key_seed), g_rev, batch=8,
                                  qcap=n)
    src, dst, _ = csr_mod.to_edges(g)
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    for row, root in zip(rrset.to_lists(s), np.asarray(s.roots)):
        assert row[0] == int(root)
        assert len(set(row)) == len(row)
        assert set(row) <= (nx.ancestors(G, int(root)) | {int(root)})


@SET
@given(random_rr_sets(), st.integers(1, 6))
def test_prop_greedy_matches_oracle(rrn, k):
    """JAX greedy == numpy greedy for any RR multiset (exact, incl. ties)."""
    rr, n = rrn
    k = min(k, n)
    store = cov.build_store(rr, n)
    res = cov.select_seeds(store, k)
    seeds_o, frac_o = oracle.greedy_max_coverage(rr, n, k)
    assert np.asarray(res.seeds).tolist() == seeds_o
    assert abs(float(res.frac) - frac_o) < 1e-6


@SET
@given(random_rr_sets())
def test_prop_store_roundtrip(rrn):
    rr, n = rrn
    store = cov.build_store(rr, n)
    flat = np.asarray(store.rr_flat)[np.asarray(store.valid)]
    ids = np.asarray(store.rr_ids)[np.asarray(store.valid)]
    rebuilt = [[] for _ in range(store.n_rr)]
    for v, i in zip(flat, ids):
        rebuilt[i].append(int(v))
    assert rebuilt == [list(map(int, r)) for r in rr]


@SET
@given(st.integers(10, 10_000), st.integers(1, 50),
       st.floats(0.05, 0.9), st.floats(0.05, 0.9))
def test_prop_theta_monotone_in_eps(n, k, e1, e2):
    """Smaller ε ⇒ larger λ' and λ* (θ inverse-quadratic in ε, §4.5)."""
    k = min(k, n - 1)
    lo, hi = sorted((e1, e2))
    if hi - lo < 1e-3:
        return
    lp_hi, ls_hi, _, _ = oracle.imm_theta_params(n, k, hi)
    lp_lo, ls_lo, _, _ = oracle.imm_theta_params(n, k, lo)
    assert lp_lo > lp_hi
    assert ls_lo > ls_hi


@SET
@given(random_rr_sets(), st.integers(1, 4))
def test_prop_gains_monotone_nonincreasing(rrn, k):
    """Greedy marginal gains are non-increasing (submodularity)."""
    rr, n = rrn
    k = min(k, n)
    res = cov.select_seeds(cov.build_store(rr, n), k)
    gains = np.asarray(res.gains)
    assert all(gains[i] >= gains[i + 1] for i in range(len(gains) - 1))


@SET
@given(st.integers(2, 8), st.integers(1, 4), st.integers(0, 2 ** 16))
def test_prop_grouped_moe_matches_global(n_tok_per_group, groups, seed):
    """Group-local dispatch == global dispatch at generous capacity."""
    import jax.numpy as jnp
    from repro.models import moe as M
    cfg0 = M.MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                       capacity_factor=8.0)
    cfgg = cfg0._replace(dispatch_groups=groups)
    p = M.moe_init(jax.random.key(seed), 8, cfg0)
    x = jax.random.normal(jax.random.key(seed + 1),
                          (groups * n_tok_per_group, 8))
    y0, _ = M.moe_apply(p, x, cfg0)
    yg, _ = M.moe_apply(p, x, cfgg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yg), atol=3e-5)


@SET
@given(st.integers(4, 24), st.integers(1, 8), st.integers(0, 2 ** 16))
def test_prop_chunked_attention_matches_full(s, chunk, seed):
    import jax.numpy as jnp
    from repro.models import attention as A
    b, h, d = 1, 2, 8
    q = jax.random.normal(jax.random.key(seed), (b, s, h, d))
    k = jax.random.normal(jax.random.key(seed + 1), (b, s, h, d))
    v = jax.random.normal(jax.random.key(seed + 2), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = A._sdpa(q, k, v, pos, pos, None, 0.35)
    chk = A.sdpa_chunked(q, k, v, pos, pos, None, 0.35, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               atol=3e-5, rtol=1e-4)


@SET
@given(random_graph(max_n=30), st.integers(0, 2 ** 16))
def test_prop_lt_walks_are_paths(gn, key_seed):
    """LT RR sets are simple reverse paths (frontier never exceeds 1)."""
    import jax
    from repro.core import lt as lt_mod
    g, n = gn
    g = weights.wc_weights(g)
    g_rev = csr_mod.reverse(g)
    s = lt_mod.sample_rrsets_lt(jax.random.key(key_seed), g_rev, batch=8,
                                qcap=n)
    nodes = np.asarray(s.nodes); lens = np.asarray(s.lengths)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    for b in range(8):
        row = nodes[b, :lens[b]].tolist()
        assert len(set(row)) == len(row)
        for u, v in zip(row, row[1:]):
            assert v in idx[offs[u]:offs[u + 1]].tolist()

"""LT-model RR sampler (paper §3.7).

Under LT, every node activates via at most one incoming edge, chosen with
probability proportional to edge weight (Σ w ≤ 1; remainder = stop).  A
reverse RR "set" is therefore a *walk*: repeatedly pick one in-edge of the
current node (or stop), terminating on stop or revisit.

The paper implements the in-edge choice as a warp-parallel prefix scan over
the row's weights + first-hit broadcast.  TPU adaptation: per-row cumulative
weights are precomputed once (a segmented scan over W), and the per-step
choice is a vectorized binary search over the row slice — the scan moves from
the inner loop to a one-time O(m) preprocessing pass, and the frontier queue
degenerates to a single register (paper: "the size of the frontier queue never
exceeds one"), so lanes carry only (current node, length).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.roots import draw_roots


class LTSample(NamedTuple):
    nodes: jnp.ndarray       # (B, Qcap) int32 walk nodes (visit order)
    lengths: jnp.ndarray     # (B,) int32
    roots: jnp.ndarray       # (B,) int32
    overflowed: jnp.ndarray  # (B,) bool
    steps: jnp.ndarray       # () int32


def row_cumweights(g: CSRGraph) -> jnp.ndarray:
    """Segmented inclusive cumsum of weights within each CSR row."""
    w = np.asarray(g.weights, dtype=np.float64)
    offs = np.asarray(g.offsets, dtype=np.int64)
    cs = np.cumsum(w)
    base = np.concatenate([[0.0], cs])[offs[:-1]]
    rowcum = cs - np.repeat(base, np.diff(offs))
    return jnp.asarray(rowcum, jnp.float32)


def _bit_test(words, nodes):
    """words: (B, W) uint32; nodes: (B,) int32 -> (B,) bool."""
    got = jnp.take_along_axis(words, (nodes >> 5)[:, None], axis=1)[:, 0]
    return ((got >> (nodes & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0


@functools.partial(jax.jit, static_argnames=("batch", "qcap", "n", "m"))
def _sample_lt(key, offsets, indices, rowcum, roots, *, batch, qcap, n, m):
    n_words = (n + 31) // 32
    lane = jnp.arange(batch, dtype=jnp.int32)
    walk = jnp.zeros((batch, qcap), jnp.int32).at[:, 0].set(roots)
    visited = jnp.zeros((batch, n_words), jnp.uint32)
    visited = visited.at[lane, roots >> 5].set(
        jnp.left_shift(jnp.uint32(1), (roots & 31).astype(jnp.uint32)))
    cur = roots
    length = jnp.ones_like(roots)      # varying-safe under shard_map
    done = roots < 0
    overflow = roots < 0
    bisect_iters = max(int(np.ceil(np.log2(max(m, 2)))) + 1, 1)

    def cond(st):
        return ~st[4].all()

    def body(st):
        walk, visited, cur, length, done, overflow, key, step = st
        s = offsets[cur]
        e = offsets[cur + 1]
        key, sub = jax.random.split(key)
        r = jax.random.uniform(sub, (batch,))
        empty = e == s
        total = jnp.where(empty, 0.0, rowcum[jnp.clip(e - 1, 0, m - 1)])
        stop = empty | (r >= total)
        # binary search: smallest j in [s, e) with rowcum[j] > r
        lo, hi = s, jnp.maximum(e - 1, s)
        for _ in range(bisect_iters):
            mid = (lo + hi) // 2
            go_right = rowcum[jnp.clip(mid, 0, m - 1)] <= r
            lo = jnp.where(go_right, jnp.minimum(mid + 1, hi), lo)
            hi = jnp.where(go_right, hi, mid)
        v = indices[jnp.clip(lo, 0, m - 1)]
        seen = _bit_test(visited, v)
        stop = stop | seen
        fits = length < qcap
        take = ~done & ~stop
        overflow = overflow | (take & ~fits)
        take = take & fits
        walk = walk.at[lane, jnp.where(take, length, qcap)].set(v, mode="drop")
        visited = visited.at[
            lane, jnp.where(take, v >> 5, n_words)].add(
            jnp.where(take,
                      jnp.left_shift(jnp.uint32(1), (v & 31).astype(jnp.uint32)),
                      jnp.uint32(0)), mode="drop")
        length = length + take.astype(jnp.int32)
        cur = jnp.where(take, v, cur)
        done = done | (~take)
        return walk, visited, cur, length, done, overflow, key, step + 1

    walk, visited, cur, length, done, overflow, key, steps = (
        jax.lax.while_loop(cond, body,
                           (walk, visited, cur, length, done, overflow, key,
                            jnp.int32(0))))
    return walk, length, overflow, steps


@functools.partial(jax.jit, static_argnames=("batch", "qcap", "n", "m"))
def _lt_round(key, offsets, indices, rowcum, root_table, *, batch, qcap, n,
              m):
    """Root draw + LT walk as ONE jit — the device-resident engine path.
    ``rowcum`` is the precomputed segmented cumsum (engine-owned, computed
    once; the historical wrapper recomputed it on the host every round).
    Key-split structure matches :func:`sample_rrsets_lt` exactly
    (``root_table=None`` -> the identical uniform randint)."""
    key, sub = jax.random.split(key)
    roots = draw_roots(sub, batch, n, root_table)
    nodes, lengths, overflowed, steps = _sample_lt(
        key, offsets, indices, rowcum, roots,
        batch=batch, qcap=qcap, n=n, m=m)
    return nodes, lengths, roots, overflowed, steps


def sample_rrsets_lt(key, g_rev: CSRGraph, batch: int, qcap: int,
                     root_table=None) -> LTSample:
    n, m = g_rev.n_nodes, g_rev.n_edges
    rowcum = row_cumweights(g_rev)
    nodes, lengths, roots, overflowed, steps = _lt_round(
        key, g_rev.offsets, g_rev.indices, rowcum, root_table,
        batch=batch, qcap=qcap, n=n, m=m)
    return LTSample(nodes=nodes, lengths=lengths, roots=roots,
                    overflowed=overflowed, steps=steps)

"""Distributed IM solve: the paper's pipeline on an N-device mesh.

Every device runs the batched queue sampler on its own threefry counter
range (gIM's grid dimension -> mesh dimension, DESIGN.md §4); the per-device
rows are stacked into one canonical :class:`~repro.core.engine.RRBatch`, so
the whole pipeline is just ``IMMSolver`` driving a ``SamplerEngine`` whose
``sample()`` happens to fan out over the mesh.  Works on any device count
(elastic); on this CPU container use XLA_FLAGS to fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.im_solve --n 2000 --k 10
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked
from repro.graph import csr, generators, weights
from repro.core import rrset
from repro.core.engine import RRBatch, register_engine, resolve_qcap
from repro.core.imm import IMMSolver
from repro.launch.mesh import make_sample_mesh


@register_engine("queue_sharded")
class ShardedQueueEngine:
    """Queue engine fanned out over a device mesh (one lane block per device).

    ``batch`` is per-device; a ``sample()`` returns ``n_dev * batch`` rows.
    Per-device keys are derived by folding the device index into the caller's
    key, mirroring gIM's per-block curand streams.
    """

    device_resident = True           # sample() is one jitted shard_map call

    @dataclass(frozen=True)
    class Config:
        batch: int = 128             # RR sets per device per round
        qcap: Optional[int] = None
        ec: int = rrset.EC_DEFAULT

    def __init__(self, g_rev, config: Optional[Config] = None,
                 mesh: Optional[Mesh] = None):
        self.g_rev = csr.coalesce_ic(g_rev)
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, self.g_rev)
        self._dedup = rrset.detect_dedup_mode(self.g_rev)
        self.mesh = mesh if mesh is not None else Mesh(
            np.asarray(jax.devices()), ("dev",))
        self._fn = None

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def _build(self):
        g_rev, mesh = self.g_rev, self.mesh
        n, m = g_rev.n_nodes, g_rev.n_edges
        axis = mesh.axis_names[0]
        bpd, qcap, ec = self.config.batch, self.qcap, self.config.ec
        dedup = self._dedup

        def local(offsets, indices, w, keydata):
            # full 128-bit key state travels as raw uint32 data (typed keys
            # don't cross shard_map on older jax); fold_in(dev) gives each
            # device its own collision-free stream, like gIM's per-block
            # curand sequences
            dev = jax.lax.axis_index(axis).astype(jnp.uint32)
            key = jax.random.fold_in(jax.random.wrap_key_data(keydata), dev)
            key, sub = jax.random.split(key)
            roots = jax.random.randint(sub, (bpd,), 0, n, dtype=jnp.int32)
            nodes, lengths, overflow, steps = rrset._sample_queue(
                key, offsets, indices, w, roots,
                batch=bpd, qcap=qcap, ec=ec, n=n, m=m, dedup=dedup)
            return nodes[None], lengths[None], overflow[None], steps[None]

        # jit the shard_map so rounds hit a compiled executable (no
        # per-round retrace); graph operands are pre-placed replicated so
        # the per-round call does no *implicit* cross-device transfer (the
        # IMM driver holds transfer_guard("disallow") over the hot loop)
        rep = NamedSharding(mesh, P())
        self._replicated = tuple(
            jax.device_put(x, rep)
            for x in (g_rev.offsets, g_rev.indices, g_rev.weights))
        self._rep_sharding = rep
        return jax.jit(shard_map_unchecked(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P()),
            out_specs=(P(axis), P(axis), P(axis), P(axis))))

    def _sample_raw(self, key):
        if self._fn is None:
            self._fn = self._build()
        # the key broadcast is the fan-out's inherent data movement — an
        # *explicit* device_put (permitted under the transfer guard)
        keydata = jax.device_put(jax.random.key_data(key),
                                 self._rep_sharding)
        return self._fn(*self._replicated, keydata)

    def sample(self, key) -> RRBatch:
        nodes, lengths, overflow, steps = self._sample_raw(key)
        n_dev = self.mesh.devices.size
        dev0 = self.mesh.devices.reshape(-1)[0]
        # gather the per-device rows onto one device for a single-device
        # consumer (explicit device_puts, guard-legal)
        nodes, lengths, overflow, steps = (
            jax.device_put(x, dev0)
            for x in (nodes, lengths, overflow, steps))
        # devices run concurrently: the batch's parallel-time cost is the
        # slowest device's lockstep count, not the sum
        return RRBatch.make(nodes.reshape(n_dev * self.config.batch, -1),
                            lengths.reshape(-1), overflow.reshape(-1),
                            steps.max())

    def sample_sharded(self, key) -> RRBatch:
        """Mesh-native sample: the batch's *pool* arrays (nodes/lengths)
        stay sharded over the mesh — each device's rows resident where they
        were sampled, no dev0 gather.  A
        :class:`~repro.core.coverage.ShardedDeviceRRStore` on the same mesh
        re-lays them out with one explicit device_put.  Only the per-round
        *stats* (the steps scalar and the per-lane overflow flags) are
        explicitly gathered to one device for the solver's accumulators —
        O(lanes) bools instead of the O(rows·width) node gather ``sample``
        performs."""
        nodes, lengths, overflow, steps = self._sample_raw(key)
        n_dev = self.mesh.devices.size
        dev0 = self.mesh.devices.reshape(-1)[0]
        overflow, steps = (jax.device_put(x, dev0)
                           for x in (overflow, steps))
        return RRBatch.make(nodes.reshape(n_dev * self.config.batch, -1),
                            lengths.reshape(-1), overflow.reshape(-1),
                            steps.max())


def solve(g, k: int, eps: float, *, batch_per_dev: int = 128, seed: int = 0,
          selection: str = "auto", mesh=None):
    """Distributed IMM solve: sampler fan-out AND pool/selection sharing one
    mesh.  ``mesh=None`` builds a mesh over every local device; the engine
    samples on it, the solver's pool is sharded over it (``samples`` axis),
    and the per-device rows never leave the device that sampled them
    (``sample_sharded``)."""
    mesh = mesh if mesh is not None else make_sample_mesh(None)
    g_rev = csr.reverse(g)
    engine = ShardedQueueEngine(
        g_rev, ShardedQueueEngine.Config(batch=batch_per_dev), mesh=mesh)
    solver = IMMSolver(g, engine=engine, seed=seed, selection=selection,
                       mesh=mesh)
    seeds, est, stats = solver.solve(k, eps)
    return seeds, est, dict(theta=stats.theta, sampled=stats.n_rr_sampled,
                            selection=stats.selection,
                            devices=engine.mesh.devices.size,
                            mesh_shape=stats.mesh_shape,
                            pool_sharding=stats.pool_sharding,
                            per_device_pool_bytes=stats.per_device_pool_bytes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--eps", type=float, default=0.4)
    ap.add_argument("--selection", default="auto",
                    choices=("auto", "fused", "bitset", "celf-sketch"),
                    help="seed-selection backend (DESIGN.md §3)")
    ap.add_argument("--mesh", default=None,
                    help="device count or axis spec for the sampling mesh "
                         "(e.g. '4' or 'samples:8'; default: all devices)")
    args = ap.parse_args()
    src, dst = generators.barabasi_albert(args.n, args.r, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, args.n))
    t0 = time.time()
    seeds, est, stats = solve(g, args.k, args.eps, selection=args.selection,
                              mesh=make_sample_mesh(args.mesh))
    print(f"devices={stats['devices']} mesh={stats['pool_sharding']} "
          f"pool_bytes/dev={stats['per_device_pool_bytes']} "
          f"theta={stats['theta']} sampled={stats['sampled']} "
          f"selection={stats['selection']} time={time.time() - t0:.2f}s")
    print(f"seeds={sorted(seeds.tolist())} estimate={est:.1f}")


if __name__ == "__main__":
    main()

"""Paper Figs. 4/5: runtime vs. k (speedup roughly k-independent; gIM's
runtime can *drop* with k when the Alg. 2 LB loop exits an iteration early)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import imm
from repro.core import oracle
from repro.graph import csr as csr_mod

N, R, EPS = 6000, 6, 0.4


def main():
    g = ba_graph(N, R)
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rows = []
    for k in (5, 10, 20, 35, 50):
        t0 = time.perf_counter()
        _, _, theta = oracle.imm_oracle(offs, idx, w, N, k, EPS, seed=0)
        t_o = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, _, st = imm(g, k, EPS, engine="queue", batch=512, seed=0)
        t_j = time.perf_counter() - t0
        rows.append([k, theta, st.theta, round(t_o, 3), round(t_j, 3),
                     round(t_o / t_j, 2)])
        report(f"fig45/k={k}", t_j * 1e6, f"speedup={t_o / t_j:.2f}x")
    write_csv("fig45_k_sweep", ["k", "theta_oracle", "theta_gim",
                                "t_imm_s", "t_gim_s", "speedup"], rows)


if __name__ == "__main__":
    main()

"""Edge partitioning for distributed (sharded) message passing.

The GNN full-batch-large path shards the *edge list* evenly across devices and
reduces node states with a collective (psum baseline; reduce-scatter
optimization in §Perf).  This module provides the host-side padding/partition
and the flat COO views used by shard_map.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.graph.csr import CSRGraph, to_edges


class EdgeShards(NamedTuple):
    src: jnp.ndarray    # (S, m_pad/S) int32
    dst: jnp.ndarray    # (S, m_pad/S) int32
    w: jnp.ndarray      # (S, m_pad/S) float32
    mask: jnp.ndarray   # (S, m_pad/S) bool
    n_nodes: int


def partition_edges(g: CSRGraph, n_shards: int, sort_by_dst: bool = False) -> EdgeShards:
    """Pad m to a multiple of n_shards and split contiguously.

    ``sort_by_dst=True`` groups each shard's scatter targets (locality for the
    reduce-scatter combine — a beyond-paper optimization; baseline keeps input
    order like the paper's no-reordering rule).
    """
    src, dst, w = to_edges(g)
    if sort_by_dst:
        order = np.argsort(dst, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
    m = src.shape[0]
    m_pad = ((m + n_shards - 1) // n_shards) * n_shards
    pad = m_pad - m
    src = np.concatenate([src, np.zeros(pad, dtype=src.dtype)])
    dst = np.concatenate([dst, np.zeros(pad, dtype=dst.dtype)])
    w = np.concatenate([w, np.zeros(pad, dtype=w.dtype)])
    mask = np.concatenate([np.ones(m, bool), np.zeros(pad, bool)])
    shape = (n_shards, m_pad // n_shards)
    return EdgeShards(
        src=jnp.asarray(src.reshape(shape), jnp.int32),
        dst=jnp.asarray(dst.reshape(shape), jnp.int32),
        w=jnp.asarray(w.reshape(shape), jnp.float32),
        mask=jnp.asarray(mask.reshape(shape)),
        n_nodes=g.n_nodes,
    )

"""Shared neural-net layers (functional style: params are plain dict pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out, *, bias=False, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rms_norm(x, scale=None, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def nonparametric_layer_norm(x, eps=1e-5):
    """OLMo: LayerNorm without learnable scale/bias [arXiv:2402.00838]."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate.astype(x.dtype)) * (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def geglu(x, w_gate, w_up, w_down):
    h = jax.nn.gelu(x @ w_gate.astype(x.dtype), approximate=True) * \
        (x @ w_up.astype(x.dtype))
    return h @ w_down.astype(x.dtype)


def ffn_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def ffn(p, x, act="swiglu"):
    f = swiglu if act == "swiglu" else geglu
    return f(x, p["w_gate"], p["w_up"], p["w_down"])


def rope_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token CE; logits (..., V), labels (...) int32."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))

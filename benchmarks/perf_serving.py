"""§Perf/Serving: load test for the IM-as-a-service front (DESIGN.md §7).

An asyncio open-loop load generator drives the micro-batched request front
with a mixed θ-pinned workload — varying ``k``, candidate restrictions, and
repeated requests (the cache's food) — at ≥2 offered QPS levels, and
records per-level:

* latency percentiles (p50/p95/p99) and mean, measured submit→response;
* achieved throughput (served requests / wall time);
* batch occupancy (mean/max requests per executed micro-batch);
* cache-hit rate and shed/expired counts.

Before the load levels run, a **parity gate** solves a probe subset of the
workload on *fresh single-request solvers* (same solver_opts) and asserts
the served seeds/gains/spread are bit-identical — the θ-in-key contract
the registry guarantees (ISSUE 6 acceptance criterion).

Writes ``experiments/bench/BENCH_serving.json``.

``--smoke`` (CI's serve-smoke job): small graph, ~50 requests, asserts
nonzero cache hits and zero shed requests, then exits 0.

CPU-container scaling note (benchmarks/common.py): offered QPS here
exercises the *front* (admission, batching, cache) — per-request solve cost
on this single scalar core is milliseconds, so the interesting numbers are
occupancy and hit-rate, not absolute latency.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np

from benchmarks.common import OUT_DIR, ba_graph
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.serve import ServeConfig, build_service

SOLVER_OPTS = {"batch": 64, "seed": 0}


def make_workload(g, requests: int, theta: int, seed: int = 0):
    """Mixed θ-pinned request stream: varying k, two candidate pools, and a
    zipf-ish repeat pattern so the cache sees realistic re-asks."""
    deg = np.diff(np.asarray(g.offsets))
    top = np.argsort(-deg, kind="stable")
    distinct = [IMProblem(k=k, theta=theta) for k in (1, 2, 5, 10)]
    distinct += [IMProblem(k=1, theta=theta, candidates=top[:m])
                 for m in (g.n_nodes // 4, g.n_nodes // 2)]
    distinct += [IMProblem(k=3, theta=theta,
                           candidates=top[:g.n_nodes // 4])]
    rng = np.random.default_rng(seed)
    # zipf-like popularity: low indices re-asked often
    idx = np.minimum(rng.zipf(1.5, size=requests) - 1, len(distinct) - 1)
    return [distinct[i] for i in idx], distinct


def parity_gate(g, probe, served_by_digest):
    """Assert serving answers == fresh single-request cold solves."""
    for p in probe:
        fresh = IMMSolver(g, **SOLVER_OPTS).solve(p)
        got = served_by_digest[p.signature_digest()]
        np.testing.assert_array_equal(fresh.seeds, got.seeds)
        np.testing.assert_array_equal(fresh.gains, got.gains)
        assert fresh.frac == got.frac
        assert fresh.spread == got.spread
    return len(probe)


async def run_level(g, workload, qps: float, *, max_batch: int,
                    deadline_s=None, queue_cap: int = 256):
    """Open-loop load: submit at the offered rate regardless of completion
    (closed-loop load generators hide queueing collapse)."""
    svc = build_service({"g": g}, ServeConfig(
        max_batch=max_batch, queue_cap=queue_cap, batch_window_s=0.002,
        default_deadline_s=deadline_s, solver_opts=SOLVER_OPTS))
    lat, shed, results = [], 0, {}

    async def one(p):
        nonlocal shed
        t0 = time.perf_counter()
        try:
            resp = await svc.submit("g", p)
        except Exception:
            shed += 1
            return
        lat.append(time.perf_counter() - t0)
        results[p.signature_digest()] = resp.result

    interval = 1.0 / qps
    t_start = time.perf_counter()
    async with svc:
        tasks = []
        for i, p in enumerate(workload):
            # open loop: sleep to the scheduled submit time, don't await
            lag = t_start + i * interval - time.perf_counter()
            if lag > 0:
                await asyncio.sleep(lag)
            tasks.append(asyncio.ensure_future(one(p)))
        await asyncio.gather(*tasks)
        wall = time.perf_counter() - t_start
        st = svc.stats()
    lat_ms = np.asarray(sorted(lat)) * 1e3
    pct = (lambda q: float(np.percentile(lat_ms, q)) if lat_ms.size else 0.0)
    return {
        "offered_qps": qps,
        "requests": len(workload),
        "served": st.served,
        "shed": st.shed,
        "expired": st.expired,
        "achieved_qps": st.served / wall if wall > 0 else 0.0,
        "latency_ms": {"p50": pct(50), "p95": pct(95), "p99": pct(99),
                       "mean": float(lat_ms.mean()) if lat_ms.size else 0.0},
        "batches": st.batches,
        "batch_occupancy_mean": st.batch_occupancy_mean,
        "batch_occupancy_max": st.batch_occupancy_max,
        "occur_fastpath": st.occur_fastpath,
        "cache_hit_rate": st.cache.hit_rate,
        "cache_hits": st.cache_hits,
        "registry_solvers": st.registry.solvers,
        "registry_bytes": st.registry.bytes_in_use,
    }, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small graph, ~50 requests, assert "
                         "cache hits > 0 and shed == 0")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--theta", type=int, default=None)
    ap.add_argument("--qps", type=float, nargs="+", default=None,
                    help="offered load levels (default: two levels)")
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    n = args.n or (300 if args.smoke else 2000)
    requests = args.requests or (50 if args.smoke else 200)
    theta = args.theta or (1024 if args.smoke else 4096)
    qps_levels = args.qps or ([200.0, 1000.0] if args.smoke
                              else [100.0, 500.0])

    g = ba_graph(n, 4)
    workload, distinct = make_workload(g, requests, theta)

    levels = []
    results = {}
    for qps in qps_levels:
        level, res = asyncio.run(run_level(
            g, workload, qps, max_batch=args.max_batch))
        results.update(res)
        levels.append(level)
        print(f"serving qps={qps:g}: "
              f"p50={level['latency_ms']['p50']:.1f}ms "
              f"p99={level['latency_ms']['p99']:.1f}ms "
              f"achieved={level['achieved_qps']:.0f}/s "
              f"occ={level['batch_occupancy_mean']:.2f} "
              f"hit={level['cache_hit_rate']:.2f} shed={level['shed']}")

    # bit-identity parity gate: every distinct problem that was actually
    # served vs a fresh cold solver
    probe = [p for p in distinct if p.signature_digest() in results]
    n_checked = parity_gate(g, probe, results)
    print(f"serving parity: {n_checked}/{len(distinct)} distinct requests "
          "bit-identical to fresh solvers")

    out = {
        "config": {"n": n, "r": 4, "theta": theta, "requests": requests,
                   "max_batch": args.max_batch, "solver_opts": SOLVER_OPTS,
                   "distinct_problems": len(distinct)},
        "levels": levels,
        "parity": {"checked": n_checked, "bit_identical": True},
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.relpath(path)}")

    if args.smoke:
        total_hits = sum(l["cache_hits"] for l in levels)
        total_shed = sum(l["shed"] for l in levels)
        assert total_hits > 0, "smoke: expected nonzero cache hits"
        assert total_shed == 0, f"smoke: {total_shed} requests shed"
        print(f"smoke OK: cache_hits={total_hits} shed=0 "
              f"parity={n_checked}")


if __name__ == "__main__":
    main()

"""Pallas TPU kernel: RR-set membership scan (paper Alg. 7, lines 3-10).

Given the padded RR matrix ``rows`` (R, L) and the newly selected seed ``u``,
produce ``hit[r] = any(rows[r, :len_r] == u)`` — the per-RR "does this set
contain the seed" flag that drives Covered marking and Occur decrement.

TPU adaptation of gIM's flat-array warp scan: the GPU handles ragged rows with
a thread-strided loop; TPU wants rectangular VMEM tiles, so RR sets live in a
(R, L) padded matrix and the scan is a masked equality + row-reduction over
lane-aligned tiles.  Block shape (BR, L): L is the padded row length (kept a
multiple of 128 lanes); BR rows per grid step.

The seed u and the true lengths arrive as SMEM operands (scalars / small
vectors), the row payload streams through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _membership_kernel(u_ref, rows_ref, len_ref, hit_ref):
    u = u_ref[0]
    rows = rows_ref[...]                      # (BR, L) int32
    br, l = rows.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (br, l), 1)
    valid = lane < len_ref[...][:, None]
    match = (rows == u) & valid
    hit_ref[...] = match.any(axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def membership_rows(rows: jnp.ndarray, lengths: jnp.ndarray, u: jnp.ndarray,
                    *, block_rows: int = 256, interpret: bool = True):
    """hit (R,) bool — which padded RR rows contain node u."""
    r, l = rows.shape
    br = min(block_rows, r)
    grid = (pl.cdiv(r, br),)
    return pl.pallas_call(
        _membership_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # u (scalar operand)
            pl.BlockSpec((br, l), lambda i: (i, 0)),  # RR row tile -> VMEM
            pl.BlockSpec((br,), lambda i: (i,)),      # lengths
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.bool_),
        interpret=interpret,
    )(jnp.asarray(u, jnp.int32).reshape(1), rows, lengths)

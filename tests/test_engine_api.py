"""SamplerEngine protocol: registry, RRBatch contract, engine parity with the
numpy oracle, incremental-store equivalence, and unified stats accounting."""
import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov, oracle
from repro.core.engine import (RRBatch, SamplerEngine, get_engine,
                               make_engine, list_engines, register_engine,
                               resolve_engine_name)
from repro.core.imm import IMMSolver, imm
from repro.core.problem import IMProblem

CORE_ENGINES = ("queue", "dense", "refill", "lt", "mrim")


def _wc_graph(n=40, m=200, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


# ------------------------------------------------------------------ registry

def test_registry_round_trip():
    assert set(CORE_ENGINES) <= set(list_engines())
    for name in CORE_ENGINES:
        cls = get_engine(name)
        assert cls.name == name
        eng = make_engine(name, csr_mod.reverse(_wc_graph()), batch=16)
        assert isinstance(eng, SamplerEngine)
        assert eng.item_space >= 1


def test_get_engine_unknown_name():
    with pytest.raises(KeyError, match="unknown engine"):
        get_engine("definitely-not-registered")


def test_register_engine_decorator():
    from repro.core import engine as engine_mod
    try:
        @register_engine("_test_dummy")
        class Dummy:
            class Config:
                pass
        assert get_engine("_test_dummy") is Dummy
        assert Dummy.name == "_test_dummy"
    finally:
        engine_mod._ENGINES.pop("_test_dummy", None)  # keep registry clean


def test_resolve_engine_name():
    assert resolve_engine_name("queue", "ic") == "queue"
    assert resolve_engine_name("dense", "ic") == "dense"
    assert resolve_engine_name("queue", "lt") == "lt"


def test_make_engine_filters_foreign_options():
    # dense has no qcap/ec: a uniform caller option set must still work
    g_rev = csr_mod.reverse(_wc_graph())
    eng = make_engine("dense", g_rev, batch=8, qcap=999, ec=64, lanes=None)
    assert eng.config.batch == 8


# ----------------------------------------------------- RRBatch contract

@pytest.mark.parametrize("name", ("queue", "dense", "refill", "lt"))
def test_engine_batch_contract_and_oracle_parity(name):
    n = 40
    g = _wc_graph(n=n, m=200, seed=1)
    g_rev = csr_mod.reverse(g)
    eng = make_engine(name, g_rev, batch=64)
    b = eng.sample(jax.random.key(0))
    assert isinstance(b, RRBatch)
    nodes, lens = np.asarray(b.nodes), np.asarray(b.lengths)
    assert nodes.ndim == 2
    assert lens.shape == (b.n_sets,) == (nodes.shape[0],)
    assert (lens >= 1).all() and int(lens.max()) <= nodes.shape[1]
    rr = [nodes[i, :lens[i]].tolist() for i in range(b.n_sets)]
    for row in rr:
        assert len(set(row)) == len(row)           # row-unique elements
        assert all(0 <= v < n for v in row)
    # parity: greedy on the canonical batch == numpy oracle on the same sets
    res = cov.select_seeds(cov.build_store((nodes, lens), n), 4)
    _, frac_o = oracle.greedy_max_coverage(rr, n, 4)
    assert abs(float(res.frac) - frac_o) < 1e-6
    assert abs(n * float(res.frac) - n * frac_o) < 1e-3


def test_mrim_engine_item_space_and_tags():
    n, t = 40, 3
    g_rev = csr_mod.reverse(_wc_graph(n=n, m=200, seed=2))
    eng = make_engine("mrim", g_rev, batch=16, t_rounds=t)
    assert eng.item_space == n * t
    b = eng.sample(jax.random.key(0))
    nodes, lens = np.asarray(b.nodes), np.asarray(b.lengths)
    assert b.n_sets == 16
    for i in range(b.n_sets):
        row = nodes[i, :lens[i]]
        assert len(set(row.tolist())) == len(row)  # (node, round) unique
        assert (row >= 0).all() and (row < n * t).all()
        # every round contributes at least the root
        assert set(row // n) == set(range(t))


# --------------------------------------------------- incremental store

def test_incremental_store_matches_merge_stores():
    g_rev = csr_mod.reverse(_wc_graph(n=30, m=150, seed=3))
    eng = make_engine("queue", g_rev, batch=24)
    inc = cov.IncrementalRRStore(30, capacity=4)   # force buffer doubling
    per_round = []
    for i in range(4):
        b = eng.sample(jax.random.key(i))
        inc.append_batch(b)
        per_round.append(cov.build_store(
            (np.asarray(b.nodes), np.asarray(b.lengths)), 30))
    merged = cov.merge_stores(per_round)
    snap = inc.snapshot()
    assert snap.n_rr == merged.n_rr == inc.n_rr
    valid = np.asarray(merged.valid)
    np.testing.assert_array_equal(np.asarray(snap.rr_flat),
                                  np.asarray(merged.rr_flat)[valid])
    np.testing.assert_array_equal(np.asarray(snap.rr_ids),
                                  np.asarray(merged.rr_ids)[valid])
    assert np.asarray(snap.valid).all()
    # identical seed selection
    r1 = cov.select_seeds(snap, 3)
    r2 = cov.select_seeds(merged, 3)
    assert np.asarray(r1.seeds).tolist() == np.asarray(r2.seeds).tolist()
    assert float(r1.frac) == pytest.approx(float(r2.frac))


def test_incremental_store_snapshot_cached():
    inc = cov.IncrementalRRStore(10)
    inc.append_batch((np.asarray([[1, 2, 0]]), np.asarray([2])))
    s1 = inc.snapshot()
    assert inc.snapshot() is s1                    # cached between appends
    inc.append_batch((np.asarray([[3]]), np.asarray([1])))
    s2 = inc.snapshot()
    assert s2 is not s1 and s2.n_rr == 2


# ------------------------------------------------- unified stats accounting

class _SpyEngine:
    """Wraps an engine, recording every batch it hands the solver."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"spy:{inner.name}"
        self.batches = []

    @property
    def item_space(self):
        return self.inner.item_space

    def sample(self, key):
        b = self.inner.sample(key)
        self.batches.append(b)
        return b


@pytest.mark.parametrize("name", ("queue", "refill", "dense", "lt"))
def test_round_stats_accounting_is_engine_uniform(name):
    """Regression for the old refill branch's duplicated stats bookkeeping:
    every engine's stats must follow the one shared accounting tail."""
    g = _wc_graph(n=30, m=150, seed=4)
    spy = _SpyEngine(make_engine(name, csr_mod.reverse(g), batch=32))
    solver = IMMSolver(g, engine=spy, seed=0)
    for _ in range(3):
        solver._round()
    st = solver.stats
    assert st.rounds == len(spy.batches) == 3
    assert st.n_rr_sampled == sum(b.n_sets for b in spy.batches)
    assert st.n_rr_sampled == solver.store.n_rr
    assert st.sampling_steps == sum(int(b.steps) for b in spy.batches)
    means = [float(np.asarray(b.overflowed).mean()) for b in spy.batches]
    assert st.overflow_fraction == pytest.approx(np.mean(means))


def test_imm_refill_matches_queue_quality():
    g = _wc_graph(n=60, m=300, seed=5)
    s_q, e_q, st_q = imm(g, 4, 0.45, engine="queue", batch=128, seed=1)
    s_r, e_r, st_r = imm(g, 4, 0.45, engine="refill", batch=128, seed=1)
    assert len(set(s_r.tolist())) == 4
    assert st_r.n_rr_sampled >= st_r.theta > 0
    assert 0.0 <= st_r.overflow_fraction <= 1.0
    # same estimator, same θ schedule -> estimates agree within tolerance
    assert abs(e_r - e_q) / e_q < 0.2, (e_r, e_q)


def test_solver_rejects_tagged_item_space():
    g = _wc_graph(n=30, m=150, seed=7)
    with pytest.raises(ValueError, match="item space"):
        IMMSolver(g, engine="mrim")         # round*n+node ids must not leak
    with pytest.raises(ValueError, match="no effect"):
        eng = make_engine("queue", csr_mod.reverse(g), batch=16)
        IMMSolver(g, engine=eng, batch=16)  # options + instance conflict


def test_solver_accepts_engine_instance():
    g = _wc_graph(n=30, m=150, seed=6)
    eng = make_engine("queue", csr_mod.reverse(g), batch=32)
    solver = IMMSolver(g, engine=eng, seed=0)
    assert solver.engine is eng
    res = solver.solve(IMProblem(k=2, eps=0.5, max_theta=128))
    assert len(set(res.seeds.tolist())) == 2 and res.spread > 0

"""train_step / serve_step builders: the units the launcher jits and shards.

``build_lm_train_step`` returns a pure (state, batch) -> (state, metrics)
function with: remat policy over layers (scan already bounds HLO size; remat
bounds activation memory), AdamW (optionally int8 states), grad accumulation
microbatching, optional error-feedback compressed DP reduction.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                         cosine_with_warmup)


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: any
    opt: AdamWState


def init_train_state(key, cfg, opt_cfg: AdamWConfig) -> TrainState:
    params = T.lm_init(key, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=adamw_init(params, opt_cfg))


def build_lm_train_step(cfg, opt_cfg: AdamWConfig, *, remat: bool = True,
                        microbatches: int = 1, schedule=None):
    import dataclasses
    if remat and not cfg.remat:
        cfg = dataclasses.replace(cfg, remat=True)   # per-layer scan remat
    loss_fn = T.lm_loss

    def compute_grads(params, tokens):
        if microbatches == 1:
            return jax.value_and_grad(lambda p: loss_fn(p, cfg, tokens))(params)
        mb = tokens.reshape(microbatches, -1, tokens.shape[-1])

        def acc(carry, batch):
            loss_sum, g_sum = carry
            l, g = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch))(params)
            return (loss_sum + l,
                    jax.tree.map(jnp.add, g_sum, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0), zeros), mb)
        scale = 1.0 / microbatches
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(state: TrainState, tokens):
        loss, grads = compute_grads(state.params, tokens)
        ocfg = opt_cfg
        if schedule is not None:
            ocfg = opt_cfg._replace(lr=schedule(state.step))
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           ocfg)
        return (TrainState(step=state.step + 1, params=new_params,
                           opt=new_opt),
                {"loss": loss.astype(jnp.float32)})

    return train_step


def build_lm_serve_step(cfg):
    def serve_step(params, tokens, caches, cur_pos):
        return T.serve_step(params, cfg, tokens, caches, cur_pos)
    return serve_step


def build_lm_prefill(cfg):
    def prefill(params, tokens):
        hidden, _, _ = T.lm_backbone(params, cfg, tokens)
        return T.lm_logits(params, cfg, hidden[:, -1:])
    return prefill

"""input_specs(): ShapeDtypeStruct stand-ins per (arch × shape) cell.

No device allocation happens here — these drive ``jit(...).lower()`` in the
multi-pod dry-run.  ``build_cell`` returns (step_fn, arg_specs dict) where
step_fn's signature matches the specs in order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import registry, gnn_archs, recsys
from repro.configs.shapes import LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train import steps as tsteps

SDS = jax.ShapeDtypeStruct


def _pad512(n: int) -> int:
    """Pad counts to a multiple of 512 so arrays shard on both production
    meshes (256- and 512-chip); masks neutralize padded entries."""
    return ((n + 511) // 512) * 512


def _lm_opt_cfg(arch_id: str) -> AdamWConfig:
    # int8 optimizer states for the MoE giants (the pod-fit enabler),
    # fp32 moments for the small dense archs
    big = arch_id in ("deepseek-v3-671b", "llama4-scout-17b-a16e",
                      "gemma3-12b")
    return AdamWConfig(int8_states=big)


def lm_state_specs(cfg, opt_cfg):
    """TrainState ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda: tsteps.init_train_state(jax.random.key(0), cfg, opt_cfg))


def build_cell(arch_id: str, shape_id: str, *, reduced: bool = False,
               mesh_axes=None, cfg_override=None, opt: bool = False):
    """mesh_axes: optional (dp_axes tuple, tp_axis str) for activation
    sharding constraints inside the model (dry-run/production path).
    cfg_override: LM-only, replaces the registry config (cost probes).
    opt: apply the beyond-baseline §Perf optimizations (see EXPERIMENTS)."""
    fam = registry.family_of(arch_id)
    if fam == "lm":
        return _build_lm_cell(arch_id, shape_id, reduced, mesh_axes,
                              cfg_override, opt)
    if fam == "gnn":
        return _build_gnn_cell(arch_id, shape_id, reduced, opt, mesh_axes)
    return _build_recsys_cell(arch_id, shape_id, reduced)


# ------------------------------------------------------------------- LM

def _build_lm_cell(arch_id, shape_id, reduced, mesh_axes=None,
                   cfg_override=None, opt=False):
    import dataclasses
    cfg = cfg_override or registry.lm_config(arch_id, reduced=reduced)
    opt_cfg = _lm_opt_cfg(arch_id)
    sh = dict(LM_SHAPES[shape_id])
    if reduced:
        sh.update(seq_len=min(sh["seq_len"], 32),
                  global_batch=min(sh["global_batch"], 4))
    b, s = sh["global_batch"], sh["seq_len"]
    n_dp = 1
    if mesh_axes is not None and b > 1:
        dp, tp = mesh_axes
        n_dp = 16 * (2 if "pod" in dp else 1)
        cfg = dataclasses.replace(cfg, act_dp=tuple(dp), act_tp=tp,
                                  tp_size=16)
    if opt and cfg.moe is not None and b > 1 and mesh_axes is not None:
        # §Perf/H1 + H1b: group-local MoE dispatch (one group per data
        # shard) with scatter-based combine.  (H1c "moe_save" remat policy
        # was measured and REFUTED — see EXPERIMENTS.md §Perf.)
        cfg = dataclasses.replace(
            cfg, moe=cfg.moe._replace(dispatch_groups=n_dp))
    if opt and LM_SHAPES[shape_id]["kind"] == "decode":
        # §Perf/H2: iota-select ring-cache writes (no dynamic-update-slice
        # resharding of the sequence-sharded cache); §Perf/H5: absorbed MLA
        cfg = dataclasses.replace(cfg, scatter_cache_update=True,
                                  absorbed_mla_decode=cfg.mla is not None)
    if opt and LM_SHAPES[shape_id]["kind"] in ("prefill", "train") \
            and cfg.mla is None:
        # §Perf/H6: flash-style chunked attention (no S^2 logits buffer)
        cfg = dataclasses.replace(cfg, attn_chunk=1024)
    if sh["kind"] == "train":
        step = tsteps.build_lm_train_step(cfg, opt_cfg)
        state = lm_state_specs(cfg, opt_cfg)
        args = (state, SDS((b, s), jnp.int32))
        return step, args, dict(kind="train", cfg=cfg)
    if sh["kind"] == "prefill":
        step = tsteps.build_lm_prefill(cfg)
        params = jax.eval_shape(lambda: T.lm_init(jax.random.key(0), cfg))
        args = (params, SDS((b, s), jnp.int32))
        return step, args, dict(kind="prefill", cfg=cfg)
    # decode: one new token against a KV cache of seq_len
    step = tsteps.build_lm_serve_step(cfg)
    params = jax.eval_shape(lambda: T.lm_init(jax.random.key(0), cfg))
    caches = jax.eval_shape(
        lambda: T.init_cache(cfg, batch=b, max_len=s, filled=True))
    args = (params, SDS((b, 1), jnp.int32), caches, SDS((), jnp.int32))
    return step, args, dict(kind="decode", cfg=cfg)


# ------------------------------------------------------------------ GNN

def _build_gnn_cell(arch_id, shape_id, reduced, opt=False, mesh_axes=None):
    sh = dict(GNN_SHAPES[shape_id])
    opt_cfg = AdamWConfig()
    cfg = gnn_archs.make_arch(arch_id, sh, reduced=reduced)
    n_cls = sh["n_classes"]
    f32, i32 = jnp.float32, jnp.int32
    # §Perf/H4b: bf16 mixed-precision message passing (graphcast full-batch)
    # — halves every collective payload (H4a node-shard constraints were
    # measured and REFUTED: the src-gather re-replicates h, so constraints
    # only added all-gathers; see EXPERIMENTS.md §Perf)
    gnn_opt = {}
    param_dtype = jnp.float32
    if opt and arch_id == "graphcast" and mesh_axes is not None:
        param_dtype = jnp.bfloat16

    def params_specs():
        def mk():
            p = gnn_archs.init_params(arch_id, jax.random.key(0), cfg, n_cls,
                                      dtype=param_dtype)
            return p, adamw_init(p, opt_cfg)
        return jax.eval_shape(mk)

    if shape_id in ("full_graph_sm", "ogb_products"):
        n, m = sh["n_nodes"], sh["n_edges"]
        if reduced:
            n, m = 64, 256
        else:
            n, m = _pad512(n), _pad512(m)
        step = gnn_archs.build_node_train_step(arch_id, cfg, opt_cfg,
                                               **gnn_opt)
        args = (params_specs(),
                SDS((n, sh["d_feat"]), f32), SDS((m,), i32), SDS((m,), i32),
                SDS((m,), jnp.bool_), SDS((n,), i32), SDS((n, 3), f32))
        return step, args, dict(kind="train", cfg=cfg)
    if shape_id == "minibatch_lg":
        nn, ne = gnn_archs.minibatch_union_sizes(sh)
        n_lab = sh["batch_nodes"]
        if reduced:
            nn, ne, n_lab = 64, 60, 4
        else:
            nn, ne = _pad512(nn), _pad512(ne)
        step = gnn_archs.build_node_train_step(arch_id, cfg, opt_cfg,
                                               n_labeled=n_lab)
        args = (params_specs(),
                SDS((nn, sh["d_feat"]), f32), SDS((ne,), i32),
                SDS((ne,), i32), SDS((ne,), jnp.bool_), SDS((n_lab,), i32),
                SDS((nn, 3), f32))
        return step, args, dict(kind="train", cfg=cfg)
    # molecule: batch of small graphs
    bsz, n, m = sh["batch"], sh["n_nodes"], sh["n_edges"]
    if reduced:
        bsz = 4
    step = gnn_archs.build_molecule_train_step(arch_id, cfg, opt_cfg)
    args = (params_specs(),
            SDS((bsz, n, sh["d_feat"]), f32), SDS((bsz, m), i32),
            SDS((bsz, m), i32), SDS((bsz, m), jnp.bool_), SDS((bsz,), i32),
            SDS((bsz, n, 3), f32))
    return step, args, dict(kind="train", cfg=cfg)


# --------------------------------------------------------------- recsys

def _build_recsys_cell(arch_id, shape_id, reduced):
    sh = dict(RECSYS_SHAPES[shape_id])
    cfg = recsys.make_deepfm(reduced=reduced)
    opt_cfg = AdamWConfig()
    f32, i32 = jnp.float32, jnp.int32
    bsz = sh.get("batch", 1)
    if reduced:
        bsz = min(bsz, 8)
        sh["n_candidates"] = min(sh.get("n_candidates", 0), 512)
    if sh["kind"] == "train":
        step = recsys.build_train_step(cfg, opt_cfg)
        from repro.models.deepfm import deepfm_init
        state = jax.eval_shape(lambda: (
            deepfm_init(jax.random.key(0), cfg),
            adamw_init(deepfm_init(jax.random.key(0), cfg), opt_cfg)))
        args = (state, SDS((bsz, cfg.n_sparse), i32),
                SDS((bsz, cfg.n_dense_feats), f32), SDS((bsz,), f32))
        return step, args, dict(kind="train", cfg=cfg)
    if sh["kind"] == "serve":
        from repro.models.deepfm import deepfm_init
        step = recsys.build_serve_step(cfg)
        params = jax.eval_shape(lambda: deepfm_init(jax.random.key(0), cfg))
        args = (params, SDS((bsz, cfg.n_sparse), i32),
                SDS((bsz, cfg.n_dense_feats), f32))
        return step, args, dict(kind="serve", cfg=cfg)
    # retrieval: 1 query vs n_candidates, batched dot + top-k
    step = recsys.build_retrieval_step(sh["top_k"])
    n_cand = sh["n_candidates"] if reduced else _pad512(sh["n_candidates"])
    args = (SDS((cfg.embed_dim,), f32),
            SDS((n_cand, cfg.embed_dim), f32))
    return step, args, dict(kind="retrieval", cfg=cfg)

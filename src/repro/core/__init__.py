from repro.core.imm import imm, imm_result, IMMSolver
from repro.core.problem import IMProblem, IMResult
from repro.core.engine import (SamplerEngine, RRBatch, register_engine,
                               get_engine, make_engine, list_engines,
                               resolve_engine_name, build_alias_table,
                               draw_roots)
from repro.core.coverage import (RRStore, IncrementalRRStore, DeviceRRStore,
                                 ShardedDeviceRRStore, SelectionSpec,
                                 build_store, merge_stores, occur_histogram,
                                 select_seeds, select_seeds_device,
                                 select_seeds_celf, select_variant)
from repro.core.rrset import sample_rrsets_queue, to_lists
from repro.core.dense import (sample_rrsets_dense, membership_to_lists,
                              membership_to_padded)
from repro.core.lt import sample_rrsets_lt
from repro.core.forward import ic_spread, lt_spread
from repro.core.mrim import solve_mrim

__all__ = [
    "imm", "imm_result", "IMMSolver", "IMProblem", "IMResult",
    "SamplerEngine", "RRBatch", "register_engine", "get_engine",
    "make_engine", "list_engines", "resolve_engine_name",
    "build_alias_table", "draw_roots",
    "RRStore", "IncrementalRRStore", "DeviceRRStore",
    "ShardedDeviceRRStore", "SelectionSpec", "build_store",
    "merge_stores", "occur_histogram", "select_seeds", "select_seeds_device",
    "select_seeds_celf", "select_variant",
    "sample_rrsets_queue", "to_lists",
    "sample_rrsets_dense", "membership_to_lists", "membership_to_padded",
    "sample_rrsets_lt", "ic_spread", "lt_spread", "solve_mrim",
]

"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_sample_mesh(spec=None, *, axis: str = "samples"):
    """Mesh for the RR-sampling pipeline from a ``--mesh`` style spec.

    ``spec``: ``None``/``""``/``0`` -> all local devices; an int (or int
    string) N -> the first N devices; ``"name:N"`` -> N devices on a custom
    axis name.  The returned 1-axis mesh is what ``ShardedDeviceRRStore``
    shards the pool's ``samples`` dimension over — a 1-device spec yields
    the mesh=1 special case, not a different code path.
    """
    import numpy as np
    devs = jax.devices()
    if spec in (None, "", 0, "0"):
        n = len(devs)
    else:
        s = str(spec)
        if ":" in s:
            axis, s = s.split(":", 1)
        n = int(s)
    if not 1 <= n <= len(devs):
        raise ValueError(f"mesh spec {spec!r} wants {n} devices; "
                         f"{len(devs)} available")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)

"""Pallas kernels for the bottom-k / one-permutation coverage sketches.

The sketch subsystem (``core/sketch.py``) summarises, for every node v, the
set of RR rows containing v as a k-bit hashed occupancy bitmap packed into
``k/32`` uint32 words — the same packed-bitset layout the Visited structures
use (``kernels/bitset.py``), so these kernels are thin recombinations of
that plumbing:

* :func:`sketch_union_popcount` — per-node ``popcount(sketch[v] | covered)``,
  the inner product of the CELF sketch estimate: the union-cardinality proxy
  for ``|rows(v) ∪ rows(S)|`` evaluated for *all* nodes in one cross-row
  popcount sweep (grid over node blocks, SWAR popcount per word).
* :func:`sketch_scatter_or` — scatter-OR of (row, bucket) bit pairs into
  the packed (R, k/32) occupancy words, the sketch *fold*.  XLA has no
  scatter-or, so the portable fold (``core/sketch.scatter_or_bits``)
  lexsorts + dedups + scatter-adds; this kernel is the accelerator-native
  alternative — a serial read-modify-write loop per block, the moral
  equivalent of gIM's ``atomicOr`` — and is property-tested bit-identical
  to the sort-based fold.

The matching ``popcount(covered)`` baseline is one :func:`_popcount` call on
a (W,) vector — not worth a kernel.  Estimation (linear counting) happens in
``core/sketch.py``; the kernels only produce occupancy counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitset import _popcount


def _union_popcount_kernel(words_ref, cov_ref, out_ref):
    words = words_ref[...]                        # (BB, W) uint32
    cov = cov_ref[...]                            # (1, W) uint32, replicated
    out_ref[...] = _popcount(words | cov).sum(axis=1).astype(jnp.int32)


def _resolve(interpret: bool | None) -> bool:
    # defer to the shared kernel dispatch policy (per-call > module override
    # > env > backend default).  Resolution happens *here*, outside the
    # jitted implementations: ``interpret`` is a static argname, so the
    # concrete bool is the jit cache key — a later env/override change gets
    # a fresh resolution instead of a stale cached trace.  Lazy import: ops
    # imports this module back (lazily) for its public wrappers.
    from repro.kernels.ops import resolve_interpret
    return resolve_interpret(interpret)


def sketch_union_popcount(words, cov, *, block_b: int = 256,
                          interpret: bool | None = None):
    """``out[v] = popcount(words[v] | cov)`` for every sketch row.

    ``words``: (R, W) uint32 packed per-node sketches; ``cov``: (W,) uint32
    packed union sketch of the selected seed set.  Returns (R,) int32 —
    the occupancy of each candidate union, from which the CELF path derives
    estimated marginal coverage (see ``core/sketch.py``).

    ``interpret=None`` (default) defers to ``ops.resolve_interpret`` like
    every other kernel: interpret mode on CPU, compiled Mosaic on an
    accelerator backend.
    """
    return _union_popcount(words, cov, block_b=block_b,
                           interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _union_popcount(words, cov, *, block_b: int, interpret: bool):
    r, w = words.shape
    if cov.shape != (w,):
        raise ValueError("cov must be a (W,) vector matching the sketch "
                         "word width")
    bb = min(block_b, r)
    return pl.pallas_call(
        _union_popcount_kernel,
        grid=(pl.cdiv(r, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.int32),
        interpret=interpret,
    )(words, cov.reshape(1, w))


def _scatter_or_kernel(words_ref, v_ref, w_ref, bit_ref, out_ref):
    out_ref[...] = words_ref[...]

    def body(e, carry):
        vv = v_ref[e]
        wi = w_ref[e]
        cur = pl.load(out_ref, (vv, wi))
        pl.store(out_ref, (vv, wi), cur | bit_ref[e])
        return carry

    jax.lax.fori_loop(0, v_ref.shape[0], body, 0)


def sketch_scatter_or(words, v, bucket, *, interpret: bool | None = None):
    """``out[v[e], bucket[e]//32] |= 1 << (bucket[e] % 32)`` for every pair.

    ``words``: (R, W) uint32 packed occupancy; ``v``/``bucket``: (E,) int32.
    Pairs with ``v`` out of ``[0, R)`` are dropped.  OR is idempotent, so
    duplicates need no dedup — this is the scatter-OR the sort-based fold
    (``core/sketch.scatter_or_bits``) emulates; a serial RMW loop stands in
    for the GPU's ``atomicOr`` (one pallas block owns the whole matrix, so
    the loop is race-free by construction).

    ``interpret=None`` (default) defers to ``ops.resolve_interpret``; the
    compiled Mosaic path is reachable without an explicit flag on
    accelerator backends.
    """
    return _scatter_or(words, v, bucket, interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _scatter_or(words, v, bucket, *, interpret: bool):
    r, w = words.shape
    valid = (v >= 0) & (v < r)
    v_safe = jnp.where(valid, v, 0).astype(jnp.int32)
    wi = jnp.where(valid, bucket >> 5, 0).astype(jnp.int32)
    bit = jnp.where(
        valid, jnp.uint32(1) << (bucket & 31).astype(jnp.uint32),
        jnp.uint32(0))
    return pl.pallas_call(
        _scatter_or_kernel,
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(words, v_safe, wi, bit)

"""§Perf/IM: engine comparison in *parallel time* (lockstep micro-steps).

On this single scalar core the vectorized engines run their B×EC lanes
sequentially, so CPU wall-clock says nothing about TPU/GPU throughput
(table2 reports it anyway, honestly).  The hardware-transferable metric is
the number of lockstep micro-steps: one micro-step = one EC-wide chunk on
every lane = one parallel time unit on width-B vector hardware.

  modelled parallel speedup = serial edge-operations / engine micro-steps

which is exactly the quantity the paper's GPU measures (they report 33-220x
on a 2560-warp V100; we report the same ratio for the 512-lane config).
Also measures the round->refill utilization win (paper Alg. 6 structure).

Both engines are driven through the SamplerEngine protocol: the benchmark
sees only ``engine.sample(key) -> RRBatch`` and the canonical ``steps``
counter, so any registered engine can be dropped into the comparison.

Second half (``BENCH_pipeline.json``): *wall-clock* end-to-end ``imm()`` per
engine on the default benchmark graph — the device-resident pipeline's
figure of merit.  Wall time on this CPU container is meaningful here because
it measures exactly what the device pipeline changed: host↔device bounces,
per-round recompiles, and the O(EC²) dedup — not vector throughput.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from benchmarks.common import OUT_DIR, ba_graph, write_csv, report
from repro.graph import csr as csr_mod
from repro.core import coverage as cov
from repro.core.engine import make_engine
from repro.core.imm import imm

N, R, QUOTA, B = 20000, 8, 2048, 512
PIPELINE_ENGINES = ("queue", "refill", "dense", "lt")
SELECTION_PATHS = ("fused", "bitset", "celf-sketch")


def bench_selection(n=2000, r=4, k=10, pool_rows=2048, batch=256,
                    sketch_k=512, reps=3, seed=0,
                    eval_batches=(8, 32, 128)):
    """Time the three selection backends on one shared RR pool.

    The pool is sampled once (queue engine) into a ``DeviceRRStore`` with an
    incremental coverage sketch; each path then selects the same k seeds.
    First call per path is reported separately as compile+run; steady-state
    is the min over ``reps`` repeats.  The celf-sketch path additionally
    sweeps the exact-verification batch width (``IMMSolver(eval_batch=)`` /
    ``--eval-batch``): wider batches amortize sweep launches against wasted
    speculative exact evals, and the sweep records where that trade lands
    on this pool.  Writes BENCH_selection.json.
    """
    g = ba_graph(n, r)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("queue", g_rev, batch=batch)
    store = cov.DeviceRRStore(n, sketch_k=sketch_k)
    i = 0
    while store.n_rr < pool_rows:
        store.append_batch(eng.sample(jax.random.key(seed * 100003 + i)))
        i += 1
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "pool": {"rows": store.n_rr, "elements": store.n_elems,
                    "sketch_k": store.sketch_k, "batch": batch},
           "params": {"k": k, "reps": reps, "seed": seed},
           "paths": {}}
    seeds_by_path = {}
    for path in SELECTION_PATHS:
        method = {"fused": "flat", "bitset": "bitset",
                  "celf-sketch": "celf"}[path]
        t0 = time.perf_counter()
        if method == "celf":
            stats = {}
            res = cov.select_seeds_celf(store, k, stats_out=stats)
        else:
            res = store.select(k, method=method)
        jax.block_until_ready(res.seeds)
        first = time.perf_counter() - t0
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            if method == "celf":
                res = cov.select_seeds_celf(store, k)
            else:
                res = store.select(k, method=method)
            jax.block_until_ready(res.seeds)
            best = min(best, time.perf_counter() - t0)
        seeds = np.asarray(res.seeds).tolist()
        seeds_by_path[path] = seeds
        out["paths"][path] = {
            "first_call_s": round(first, 4),
            "steady_s": round(best, 4),
            "seeds": seeds,
            "frac": round(float(res.frac), 6),
        }
        if method == "celf":
            out["paths"][path]["exact_evals"] = stats["n_exact_evals"]
            out["paths"][path]["eval_calls"] = stats["n_eval_calls"]
            sweep = {}
            for eb in eval_batches:
                st = {}
                res_eb = cov.select_seeds_celf(store, k, eval_batch=eb,
                                               stats_out=st)
                jax.block_until_ready(res_eb.seeds)   # compile pass
                best_eb = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    res_eb = cov.select_seeds_celf(store, k, eval_batch=eb)
                    jax.block_until_ready(res_eb.seeds)
                    best_eb = min(best_eb, time.perf_counter() - t0)
                assert (np.asarray(res_eb.seeds).tolist()
                        == seeds), "eval_batch must not change seeds"
                sweep[str(eb)] = {
                    "steady_s": round(best_eb, 4),
                    "exact_evals": st["n_exact_evals"],
                    "eval_calls": st["n_eval_calls"],
                }
                report(f"perf_im/selection/celf-eb{eb}", best_eb * 1e6,
                       f"steady={best_eb * 1e3:.1f}ms;"
                       f"evals={st['n_exact_evals']}")
            out["paths"][path]["eval_batch_sweep"] = sweep
        report(f"perf_im/selection/{path}", best * 1e6,
               f"steady={best * 1e3:.1f}ms;first={first:.2f}s")
    out["seeds_identical"] = all(
        s == seeds_by_path[SELECTION_PATHS[0]] for s in seeds_by_path.values())
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_selection.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def bench_sharded(n=100_000, rows=1 << 20, k=10, sketch_k=1024,
                  batch_rows=8192, mean_len=8, mesh_spec=None, seed=0):
    """Selection at the post-bitset-matrix scale on the mesh-sharded pool.

    Builds a synthetic RR pool past the point where the packed bitset
    matrix no longer fits (default n=1e5, θ=2^20 ≈ 1e6: the matrix would be
    ``row_capacity · ceil(n/32) · 4`` ≈ 13 GB), then times the fused scan
    and CELF-sketch selection on the sharded store.  Synthetic sets (random
    base + stride, row-unique by construction) keep pool-building O(rows)
    — selection cost does not depend on how the sets were sampled.

    Also the acceptance check for the packed-word sketch: asserts that *no*
    (n+1, k) bool occupancy buffer exists anywhere (store attribute and a
    live-array scan) and records the bool-vs-packed memory comparison.
    Writes ``experiments/bench/BENCH_sharded.json``.
    """
    from repro.launch.mesh import make_sample_mesh
    mesh = make_sample_mesh(mesh_spec)
    store = cov.ShardedDeviceRRStore(n, capacity=batch_rows * mean_len,
                                     sketch_k=sketch_k, mesh=mesh)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    stride = max(n // (2 * mean_len + 2), 1)
    while store.n_rr < rows:
        cnt = min(batch_rows, rows - store.n_rr)
        lens = rng.integers(1, 2 * mean_len, cnt)
        base = rng.integers(0, n, cnt)
        nodes = (base[:, None]
                 + np.arange(lens.max(), dtype=np.int64)[None, :] * stride) % n
        store.append_batch((nodes, lens))
    build_s = time.perf_counter() - t0
    # ---- acceptance: packed-word occupancy end to end, no bool buffer
    assert not hasattr(store, "_occ"), "bool occupancy resurrected"
    packed_bytes = store.sketch_bytes()
    bool_bytes = store.sketch_rows * store.sketch_k          # 1 byte/bucket
    assert packed_bytes * 8 == bool_bytes
    assert not any(
        a.dtype == bool and a.ndim >= 2 and store.sketch_k in a.shape[1:]
        for a in jax.live_arrays()), "live (..., k) bool occupancy found"
    n_words = (n + 31) // 32
    bitset_bytes = store.row_capacity() * n_words * 4 * store.n_shards
    out = {"graph": {"kind": "synthetic", "n": n, "mean_len": mean_len},
           "mesh": {"devices": store.n_shards,
                    "pool_sharding": f"{store.axis}:{store.n_shards}",
                    "per_device_pool_bytes": store.per_device_pool_bytes()},
           "pool": {"rows": store.n_rr, "elements": store.n_elems,
                    "build_s": round(build_s, 2)},
           "sketch_memory": {
               "packed_bytes": packed_bytes, "bool_bytes": bool_bytes,
               "ratio": bool_bytes / max(packed_bytes, 1),
               "sketch_k": store.sketch_k},
           "bitset_matrix_bytes": bitset_bytes,
           "bitset_skipped": bitset_bytes > (1 << 31),
           "params": {"k": k, "seed": seed}, "paths": {}}
    seeds_by = {}
    for path, fn in (("fused", lambda: store.select(k, method="flat")),
                     ("celf-sketch",
                      lambda: cov.select_seeds_celf(store, k))):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res.seeds)
        dt = time.perf_counter() - t0
        seeds_by[path] = np.asarray(res.seeds).tolist()
        out["paths"][path] = {"wall_s": round(dt, 3),
                              "seeds": seeds_by[path],
                              "frac": round(float(res.frac), 6)}
        report(f"perf_im/sharded/{path}", dt * 1e6, f"wall={dt:.2f}s")
    out["seeds_identical"] = seeds_by["fused"] == seeds_by["celf-sketch"]
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "BENCH_sharded.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


def bench_fused(n=100_000, rows=1 << 20, k=10, sketch_k=1024,
                batch_rows=None, mean_len=8, mesh_spec=None, seed=0,
                quality_n=5000, quality_r=4, quality_k=8,
                quality_theta=16384, quality_sketch_k=8192,
                quality_batch=512, min_mem_ratio=10.0):
    """Pool-free fused sample→sketch pipeline vs the exact sharded pipeline
    (the ``mode="approximate"`` acceptance benchmark).  Three legs, one
    JSON (``experiments/bench/BENCH_fused.json``):

    * **scale** — identical synthetic frontier batches drive a pool-free
      :class:`SketchRRStore` and an exact :class:`ShardedDeviceRRStore`
      side by side at the post-bitset-matrix scale (default n=1e5,
      θ=2^20).  The sketch leg runs *first* and a live-array scan then
      proves the flat pool was never allocated: no int32/bool device
      array at pool scale exists anywhere.  The memory ratio (exact
      per-device pool bytes / per-replica sketch bytes) is **asserted**
      ≥ ``min_mem_ratio``; cold (compile included) and steady build+select
      wall-clock ratios are both recorded.
    * **quality** — end-to-end ``mode="approximate"`` solve on a real WC
      graph in the genuine estimate regime (θ ≫ sketch_k).  The fused
      sketch engine preserves the sampling RNG stream, so the exact twin
      solve materialises *the same* RR pool the approximate solve folded
      away; re-scoring the approximate seeds on that pool must land inside
      the certified ``[lo_rows, hi_rows]`` interval (hard assert), and the
      MC spread must lie within the certified spread bounds (30 % slack
      for MC noise, matching the conformance test).
    * **exact-regime** — θ ≤ sketch_k ⇒ the approximate solve is asserted
      bit-identical to the fused-exact solve (injective mod bucketing).
    """
    from repro.core import forward
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem
    from repro.launch.mesh import make_sample_mesh
    if batch_rows is None:
        batch_rows = max(256, min(8192, rows // 128))
    mesh = make_sample_mesh(mesh_spec)

    def feed(store):
        """Identical synthetic batch stream for both stores (same rng
        seed): selection cost does not depend on how rows were sampled."""
        rng = np.random.default_rng(seed)
        stride = max(n // (2 * mean_len + 2), 1)
        t0 = time.perf_counter()
        while store.n_rr < rows:
            cnt = min(batch_rows, rows - store.n_rr)
            lens = rng.integers(1, 2 * mean_len, cnt)
            base = rng.integers(0, n, cnt)
            nodes = (base[:, None]
                     + np.arange(lens.max(), dtype=np.int64)[None, :]
                     * stride) % n
            store.append_batch((nodes, lens))
        return time.perf_counter() - t0

    # ---- sketch pipeline first: the pool must never exist ---------------
    sk_store = cov.SketchRRStore(n, sketch_k=sketch_k, mesh=mesh)
    sk_build = feed(sk_store)
    assert sk_store.pool_free and sk_store.per_device_pool_bytes() == 0
    t0 = time.perf_counter()
    res_sk = cov.select_seeds_sketch(sk_store, k)
    jax.block_until_ready(res_sk.seeds)
    sk_sel_cold = time.perf_counter() - t0
    info = {}
    t0 = time.perf_counter()
    res_sk = cov.select_seeds_sketch(sk_store, k, info_out=info)
    jax.block_until_ready(res_sk.seeds)
    sk_sel = time.perf_counter() - t0
    # acceptance: nothing pool-shaped is live anywhere on device.  The
    # batch feed stays below pool scale (batch_rows·2·mean_len < rows), so
    # any int32/bool array with ≥ rows elements could only be the pool.
    assert batch_rows * 2 * mean_len < rows, "feed batches reach pool scale"
    leaked = [a.shape for a in jax.live_arrays()
              if a.dtype in (np.dtype(np.int32), np.dtype(bool))
              and a.size >= rows]
    assert not leaked, f"pool-scale arrays live in pool-free mode: {leaked}"

    # ---- exact pipeline on the same batch stream -------------------------
    ex_store = cov.ShardedDeviceRRStore(n, capacity=batch_rows * mean_len,
                                        sketch_k=sketch_k, mesh=mesh)
    ex_build = feed(ex_store)
    assert ex_store.n_rr == sk_store.n_rr == rows
    t0 = time.perf_counter()
    res_ex = ex_store.select(k, method="flat")
    jax.block_until_ready(res_ex.seeds)
    ex_sel_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_ex = ex_store.select(k, method="flat")
    jax.block_until_ready(res_ex.seeds)
    ex_sel = time.perf_counter() - t0

    mem_ratio = ex_store.per_device_pool_bytes() / max(
        sk_store.sketch_bytes(), 1)
    assert mem_ratio >= min_mem_ratio, (
        f"memory ratio {mem_ratio:.1f}x < {min_mem_ratio}x")
    wall_cold = (ex_build + ex_sel_cold) / max(sk_build + sk_sel_cold, 1e-9)
    wall_steady = (ex_build + ex_sel) / max(sk_build + sk_sel, 1e-9)
    seeds_sk = np.asarray(res_sk.seeds).tolist()
    seeds_ex = np.asarray(res_ex.seeds).tolist()
    report("perf_im/fused/scale", (sk_build + sk_sel) * 1e6,
           f"mem={mem_ratio:.1f}x;wall={wall_steady:.2f}x")

    out = {
        "scale": {
            "graph": {"kind": "synthetic", "n": n, "mean_len": mean_len},
            "mesh": {"devices": sk_store.n_shards},
            "rows": rows, "sketch_k": sk_store.sketch_k,
            "pool_free_live_scan": "passed",
            "memory": {
                "exact_per_device_pool_bytes":
                    ex_store.per_device_pool_bytes(),
                "sketch_bytes_per_replica": sk_store.sketch_bytes(),
                "ratio": round(mem_ratio, 2),
                "min_ratio_asserted": min_mem_ratio},
            "wall_s": {
                "sketch": {"build": round(sk_build, 2),
                           "select_cold": round(sk_sel_cold, 3),
                           "select": round(sk_sel, 3)},
                "exact": {"build": round(ex_build, 2),
                          "select_cold": round(ex_sel_cold, 3),
                          "select": round(ex_sel, 3)},
                "ratio_cold": round(wall_cold, 2),
                "ratio_steady": round(wall_steady, 2)},
            "seeds": {"sketch": seeds_sk, "exact": seeds_ex,
                      "overlap": len(set(seeds_sk) & set(seeds_ex))},
            "estimate": {kk: (float(info[kk]) if kk != "saturated"
                              else bool(info[kk]))
                         for kk in ("occ_union", "est_rows", "lo_rows",
                                    "hi_rows", "rel_error", "saturated")},
        },
        "params": {"k": k, "seed": seed, "batch_rows": batch_rows},
    }

    # ---- quality: certified interval vs the real (re-materialised) pool --
    gq = ba_graph(quality_n, quality_r)
    se = IMMSolver(gq, engine="queue", batch=quality_batch, seed=seed + 1,
                   selection="fused")
    t0 = time.perf_counter()
    r_ex = se.solve(IMProblem(k=quality_k, theta=quality_theta))
    q_ex_wall = time.perf_counter() - t0
    sa = IMMSolver(gq, engine="queue", batch=quality_batch, seed=seed + 1,
                   sketch_k=quality_sketch_k)
    t0 = time.perf_counter()
    r_ap = sa.solve(IMProblem(k=quality_k, theta=quality_theta,
                              mode="approximate"))
    q_ap_wall = time.perf_counter() - t0
    info_q = dict(sa._sketch_info)
    assert sa.store.per_device_pool_bytes() == 0
    assert se.store.n_rr == sa.store.n_rr   # same RNG stream, same θ walk
    # the exact twin's pool IS the approximate solve's never-materialised
    # pool: the approximate seeds' true coverage on it must respect the
    # certificate
    snap = se.store.snapshot()
    flat, ids, valid = (np.asarray(x) for x in
                        (snap.rr_flat, snap.rr_ids, snap.valid))
    hit = np.isin(flat, np.asarray(r_ap.seeds)) & valid
    rows_cov = int(np.unique(ids[hit]).size)
    assert info_q["lo_rows"] <= rows_cov <= info_q["hi_rows"], (
        rows_cov, info_q)
    lo, hi = r_ap.spread_bounds
    mc_ap = forward.ic_spread(jax.random.key(123), gq,
                              np.asarray(r_ap.seeds).tolist(), n_sims=256)
    mc_ex = forward.ic_spread(jax.random.key(123), gq,
                              np.asarray(r_ex.seeds).tolist(), n_sims=256)
    assert lo * 0.7 <= mc_ap <= hi * 1.3, (lo, mc_ap, hi)
    report("perf_im/fused/quality", q_ap_wall * 1e6,
           f"mc={mc_ap:.0f}∈[{lo:.0f},{hi:.0f}];exact_mc={mc_ex:.0f}")
    out["quality"] = {
        "graph": {"kind": "barabasi_albert", "n": quality_n,
                  "r": quality_r, "weights": "wc"},
        "theta": quality_theta, "sketch_k": quality_sketch_k,
        "k": quality_k, "n_rr": int(sa.store.n_rr),
        "wall_s": {"approximate": round(q_ap_wall, 2),
                   "exact": round(q_ex_wall, 2)},
        "rows_covered_on_exact_pool": rows_cov,
        "certified_rows": {"lo": float(info_q["lo_rows"]),
                           "est": float(info_q["est_rows"]),
                           "hi": float(info_q["hi_rows"]),
                           "saturated": bool(info_q["saturated"])},
        "spread_bounds": [round(lo, 1), round(hi, 1)],
        "mc_spread": {"approximate": round(float(mc_ap), 1),
                      "exact": round(float(mc_ex), 1)},
        "seeds": {"approximate": np.asarray(r_ap.seeds).tolist(),
                  "exact": np.asarray(r_ex.seeds).tolist()},
        "mc_within_bounds": bool(lo * 0.7 <= mc_ap <= hi * 1.3),
    }

    # ---- exact-regime identity: θ ≤ sketch_k ⇒ bit-identical seeds -------
    th0 = min(quality_sketch_k // 2, 1024)
    e1 = IMMSolver(gq, engine="queue", batch=quality_batch, seed=seed + 2,
                   selection="fused")
    r1 = e1.solve(IMProblem(k=quality_k, theta=th0))
    e2 = IMMSolver(gq, engine="queue", batch=quality_batch, seed=seed + 2,
                   sketch_k=quality_sketch_k)
    r2 = e2.solve(IMProblem(k=quality_k, theta=th0, mode="approximate"))
    identical = bool(np.array_equal(np.asarray(r1.seeds),
                                    np.asarray(r2.seeds)))
    assert identical, (np.asarray(r1.seeds), np.asarray(r2.seeds))
    out["exact_regime"] = {"theta": th0, "seeds_identical": identical}

    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "BENCH_fused.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


def bench_variants(n=2000, r=4, k=8, eps=0.4, max_theta=2048, batch=256,
                   seed=0):
    """End-to-end ``IMMSolver.solve(IMProblem)`` across the problem variants
    (plain / weighted / budgeted / candidate-restricted / MRIM) on one
    graph: wall time, θ, seed count, spread on each variant's scale, and
    budget spent.  Writes ``experiments/bench/BENCH_variants.json``.

    Weights are integer-valued so weighted solves stay bit-reproducible
    across mesh sizes (float32 sums exact — DESIGN.md §6).
    """
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem
    g = ba_graph(n, r)
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 9, n).astype(np.float32)
    costs = rng.integers(1, 5, n).astype(np.float32)
    deg = np.diff(np.asarray(g.offsets))
    cand = np.argsort(-deg, kind="stable")[:max(n // 10, k)]
    problems = {
        "plain": IMProblem(k=k, eps=eps, max_theta=max_theta),
        "weighted": IMProblem(k=k, eps=eps, max_theta=max_theta,
                              node_weights=w),
        "budgeted": IMProblem(eps=eps, max_theta=max_theta, costs=costs,
                              budget=float(2 * k)),
        "weighted+budgeted": IMProblem(eps=eps, max_theta=max_theta,
                                       node_weights=w, costs=costs,
                                       budget=float(2 * k)),
        "candidates": IMProblem(k=k, eps=eps, max_theta=max_theta,
                                candidates=cand),
        "mrim": IMProblem(k=max(k // 2, 1), t_rounds=2, theta=max_theta),
    }
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "params": {"k": k, "eps": eps, "max_theta": max_theta,
                      "batch": batch, "seed": seed,
                      "budget": float(2 * k)},
           "variants": {}}
    for name, problem in problems.items():
        t0 = time.perf_counter()
        res = IMMSolver(g, batch=batch, seed=seed).solve(problem)
        dt = time.perf_counter() - t0
        out["variants"][name] = {
            "wall_s": round(dt, 3),
            "theta": res.stats.theta,
            "rr_sets": res.stats.n_rr_sampled,
            "n_seeds": int(len(res.seeds)),
            "seeds": np.asarray(res.seeds).tolist(),
            "spread_estimate": round(float(res.spread), 1),
            "scale": ("sum_w" if problem.node_weights is not None else "n"),
            "cost": round(float(res.cost), 3),
        }
        report(f"perf_im/variants/{name}", dt * 1e6,
               f"wall={dt:.2f}s;seeds={len(res.seeds)};"
               f"spread={res.spread:.0f}")
    assert out["variants"]["budgeted"]["cost"] <= 2 * k + 1e-6
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "BENCH_variants.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


STREAM8_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from benchmarks.common import ba_graph
from repro.core import stream
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem

assert len(jax.devices()) == 8
mesh8 = Mesh(np.asarray(jax.devices()), ("samples",))
g = ba_graph(600, 4)
p = IMProblem(k=5, theta=1024)
rng = np.random.default_rng(3)
n = g.n_nodes
deltas = stream.make_deltas(adds=(
    rng.integers(0, n, 8), rng.integers(0, n, 8),
    (0.05 + 0.2 * rng.random(8)).astype(np.float32)))
res = {}
for mesh in (None, mesh8):
    solver = IMMSolver(g, engine="queue", batch=64, seed=9, mesh=mesh)
    solver.solve(p)
    r = solver.resolve_incremental(p, deltas)
    res[r.stats.pool_sharding] = (r.seeds.tolist(),
                                  round(float(r.spread), 6),
                                  solver.last_incremental["rows_kept"])
assert res["samples:1"] == res["samples:8"], res
print("STREAM-8DEV-OK", res["samples:8"])
"""


def bench_streaming(n=2000, r=4, k=8, theta=4096, batch=256, rounds=3,
                    edges=8, seed=0, mesh8=True):
    """Streaming graphs (DESIGN.md §9): incremental re-solve vs cold.

    One cold ``IMMSolver.solve`` at fixed θ, then ``rounds`` random
    edge-delta batches; each round times ``resolve_incremental`` (reusing
    every untouched RR row) against a cold solve of the post-delta graph
    and records the pool-reuse fraction, the wall-clock speedup, and the
    parity flags: graph-digest agreement plus seed *quality* — incremental
    seeds re-scored on the unbiased cold pool must sit within the
    documented residual-bias allowance β·P(touch) (DESIGN.md §9.5) plus 5σ
    sampling noise of the cold seeds' own score.  Raw pool-spread gaps are
    recorded but not asserted: the merged pool is a conditional-law
    mixture, so its own spread estimate is legitimately biased by up to
    β·P(touch).  A windowed-eviction section exercises ``evict_to_bytes``
    on the final cold pool (the incremental solver's round history is
    collapsed by eviction, so its own pool is the wrong demo subject), and
    a subprocess leg re-runs the
    incremental path on a forced 8-fake-device mesh asserting it is
    bit-identical to the 1-device mesh.  Writes
    ``experiments/bench/BENCH_streaming.json``.
    """
    from repro.core import stream
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem

    def pool_rows(slv):
        snap = slv.store.snapshot()
        flat = np.asarray(jax.device_get(snap.rr_flat))
        ids = np.asarray(jax.device_get(snap.rr_ids))
        valid = np.asarray(jax.device_get(snap.valid))
        return flat[valid], ids[valid], int(snap.n_rr)

    def hit_frac(flat, ids, n_rr, seed_set):
        hit = np.unique(ids[np.isin(flat, np.asarray(seed_set))]).size
        return hit / max(n_rr, 1)

    g = ba_graph(n, r)
    rng = np.random.default_rng(seed)
    p = IMProblem(k=k, theta=theta)
    solver = IMMSolver(g, engine="queue", batch=batch, seed=seed)
    t0 = time.perf_counter()
    res_cold0 = solver.solve(p)
    cold0_s = time.perf_counter() - t0
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "params": {"k": k, "theta": theta, "batch": batch,
                      "rounds": rounds, "edges_per_delta": edges,
                      "seed": seed},
           "cold": {"wall_s": round(cold0_s, 3),
                    "seeds": np.asarray(res_cold0.seeds).tolist(),
                    "spread_estimate": round(float(res_cold0.spread), 1)},
           "rounds": []}
    cur_g = g
    for i in range(rounds):
        deltas = stream.make_deltas(adds=(
            rng.integers(0, n, edges), rng.integers(0, n, edges),
            (0.05 + 0.25 * rng.random(edges)).astype(np.float32)))
        t0 = time.perf_counter()
        res_inc = solver.resolve_incremental(p, deltas)
        inc_s = time.perf_counter() - t0
        info = solver.last_incremental
        cur_g = stream.apply_edge_deltas(cur_g, deltas)
        t0 = time.perf_counter()
        cold_solver = IMMSolver(cur_g, engine="queue", batch=batch,
                                seed=seed + 7 * (i + 1))
        res_cold = cold_solver.solve(p)
        cold_s = time.perf_counter() - t0
        # parity: same post-delta graph content; incremental seeds re-scored
        # on the *cold* pool (unbiased under the post-delta law) must be
        # within the residual-bias allowance β·P(touch) plus 5σ noise of the
        # cold seeds' score.  The merged pool's own spread estimate is
        # biased by up to that same allowance, so it is recorded, not
        # asserted.
        flat_c, ids_c, n_c = pool_rows(cold_solver)
        q_inc = hit_frac(flat_c, ids_c, n_c,
                         np.asarray(res_inc.seeds))
        q_cold = hit_frac(flat_c, ids_c, n_c,
                          np.asarray(res_cold.seeds))
        p_touch = hit_frac(flat_c, ids_c, n_c,
                           np.asarray(sorted(
                               stream.affected_nodes(deltas))))
        beta = float(info["surviving_fraction"])
        se = np.sqrt(max(q_cold * (1 - q_cold), 1e-12) * (2.0 / n_c))
        quality_ok = q_cold - q_inc <= beta * p_touch + 5.0 * se
        digest_ok = (csr_mod.graph_digest(solver.g)
                     == csr_mod.graph_digest(cur_g))
        out["rounds"].append({
            "edges_added": edges,
            "affected_nodes": info["affected_nodes"],
            "surviving_fraction": round(info["surviving_fraction"], 4),
            "rows_kept": info["rows_kept"],
            "rows_dropped": info["rows_dropped"],
            "pool_reused": info["reused"],
            "incremental_wall_s": round(inc_s, 3),
            "cold_wall_s": round(cold_s, 3),
            "speedup_vs_cold": round(cold_s / max(inc_s, 1e-9), 2),
            "incremental_spread": round(float(res_inc.spread), 1),
            "cold_spread": round(float(res_cold.spread), 1),
            "cold_pool_quality_inc_seeds": round(q_inc, 4),
            "cold_pool_quality_cold_seeds": round(q_cold, 4),
            "residual_bias_allowance": round(beta * p_touch, 4),
            "graph_digest_parity": bool(digest_ok),
            "seed_quality_within_bound": bool(quality_ok),
        })
        report(f"perf_im/streaming/round{i}", inc_s * 1e6,
               f"inc={inc_s:.2f}s;cold={cold_s:.2f}s;"
               f"reuse={info['surviving_fraction']:.0%}")
    out["parity_ok"] = all(rr["graph_digest_parity"]
                           and rr["seed_quality_within_bound"]
                           and rr["pool_reused"] for rr in out["rounds"])
    out["mean_speedup_vs_cold"] = round(
        float(np.mean([rr["speedup_vs_cold"] for rr in out["rounds"]])), 2)
    # windowed eviction: bound the final cold pool to half its footprint.
    # The cold store still has its genuine per-round append history; the
    # incremental store's history was collapsed to one synthetic round by
    # evict_rows_containing, so it has nothing windowed left to drop.
    store = cold_solver.store
    before = store.per_device_pool_bytes()
    ev = store.evict_to_bytes(before // 2)
    out["window"] = {"bytes_before": before,
                     "bytes_after": store.per_device_pool_bytes(),
                     "bound": before // 2, "met": bool(ev["met"]),
                     "rows_dropped": int(ev["rows_dropped"])}
    if mesh8:
        import subprocess
        import sys
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        rp = subprocess.run([sys.executable, "-c", STREAM8_SCRIPT], env=env,
                            capture_output=True, text=True,
                            cwd=os.path.dirname(os.path.dirname(
                                os.path.abspath(__file__))), timeout=900)
        ok = rp.returncode == 0 and "STREAM-8DEV-OK" in rp.stdout
        out["mesh8"] = {"ok": bool(ok)}
        if not ok:
            out["mesh8"]["stdout"] = rp.stdout[-1000:]
            out["mesh8"]["stderr"] = rp.stderr[-2000:]
        report("perf_im/streaming/mesh8", 0.0,
               "ok" if ok else "FAILED")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "BENCH_streaming.json"), "w") as f:
        json.dump(out, f, indent=2)
    assert out["parity_ok"], "streaming parity flags failed"
    if mesh8:
        assert out["mesh8"]["ok"], "8-device streaming parity failed"
    return out


def bench_pipeline(n=N, r=R, k=10, eps=0.4, max_theta=4096, batch=512,
                   engines=PIPELINE_ENGINES, seed=0):
    """Time end-to-end ``imm()`` per engine; returns the result dict."""
    g = ba_graph(n, r)
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "params": {"k": k, "eps": eps, "max_theta": max_theta,
                      "batch": batch, "seed": seed},
           # same imm() call measured on the parent commit (host-pipeline
           # IncrementalRRStore + per-escalation recompiles + O(EC²) dedup),
           # same machine/config; recorded for the device-pipeline A/B
           "baseline_main": ({"queue": {"wall_s": 98.57},
                              "refill": {"wall_s": 34.54},
                              "commit": "5812556"}
                             if (n, r, k, eps, max_theta, batch) ==
                                (20000, 8, 10, 0.4, 4096, 512) else None),
           "engines": {}}
    for name in engines:
        t0 = time.perf_counter()
        seeds, est, stats = imm(g, k, eps, engine=name, batch=batch,
                                seed=seed, max_theta=max_theta)
        dt = time.perf_counter() - t0
        out["engines"][name] = {
            "wall_s": round(dt, 3),
            "theta": stats.theta,
            "rr_sets": stats.n_rr_sampled,
            "rounds": stats.rounds,
            "micro_steps": stats.sampling_steps,
            "lb_iters": stats.lb_iters,
            "spread_estimate": round(float(est), 1),
        }
        report(f"perf_im/pipeline/{name}", dt * 1e6,
               f"wall={dt:.2f}s;rr={stats.n_rr_sampled};"
               f"rounds={stats.rounds}")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main(n=N, r=R, quota=QUOTA, b=B, pipeline_kw=None, selection_kw=None):
    g = ba_graph(n, r)
    g_rev = csr_mod.reverse(g)
    deg = np.diff(np.asarray(g_rev.offsets))
    rows = []
    # serial work model: ops = nodes visited + edges examined (the oracle
    # walks each adjacency once per visited node)
    # --- round engine
    round_eng = make_engine("queue", g_rev, batch=b, qcap=n)
    steps_round = 0
    serial_ops = 0
    done = 0
    i = 0
    while done < quota:
        b_ = round_eng.sample(jax.random.key(i))
        steps_round += int(b_.steps)
        nodes = np.asarray(b_.nodes); lens = np.asarray(b_.lengths)
        for row in range(b_.n_sets):
            vis = nodes[row, :lens[row]]
            serial_ops += lens[row] + deg[vis].sum()
        done += b_.n_sets
        i += 1
    # --- refill engine (same quota, B persistent lanes)
    refill_eng = make_engine("refill", g_rev, batch=quota, lanes=b,
                             out_cap=8 * quota // b * 64)
    bf = refill_eng.sample(jax.random.key(99))
    steps_refill = int(bf.steps)
    n_sets = bf.n_sets
    speedup_round = serial_ops / max(steps_round, 1)
    speedup_refill = serial_ops / max(steps_refill, 1) * done / max(n_sets, 1)
    rows.append(["round", done, steps_round, int(serial_ops),
                 round(speedup_round, 1)])
    rows.append(["refill", n_sets, steps_refill, int(serial_ops),
                 round(speedup_refill, 1)])
    write_csv("perf_im_engines",
              ["engine", "rr_sets", "micro_steps", "serial_ops",
               "modelled_parallel_speedup"], rows)
    report("perf_im/round", steps_round, f"par_speedup={speedup_round:.0f}x")
    report("perf_im/refill", steps_refill,
           f"par_speedup={speedup_refill:.0f}x;"
           f"step_win={steps_round / max(steps_refill, 1):.2f}x")
    bench_pipeline(n=n, r=r, **(pipeline_kw or {}))
    bench_selection(**(selection_kw or {}))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=N)
    ap.add_argument("--r", type=int, default=R)
    ap.add_argument("--quota", type=int, default=QUOTA)
    ap.add_argument("--b", type=int, default=B)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--eps", type=float, default=0.4)
    ap.add_argument("--max-theta", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--engines", default=",".join(PIPELINE_ENGINES))
    ap.add_argument("--pipeline-only", action="store_true",
                    help="skip the micro-step section (CI smoke)")
    ap.add_argument("--selection-only", action="store_true",
                    help="run only the selection-backend comparison")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-sharded selection sweep past the bitset-"
                         "matrix limit (writes BENCH_sharded.json)")
    ap.add_argument("--fused-sketch", action="store_true",
                    help="pool-free fused sample→sketch vs exact pipeline: "
                         "memory ratio (asserted ≥10×), wall-clock, and "
                         "certified-quality legs (writes BENCH_fused.json)")
    ap.add_argument("--variants", action="store_true",
                    help="IMProblem variant sweep: plain/weighted/budgeted/"
                         "candidates/mrim (writes BENCH_variants.json)")
    ap.add_argument("--streaming", action="store_true",
                    help="streaming-graph sweep: incremental re-solve vs "
                         "cold after edge deltas, windowed eviction, and "
                         "the 8-fake-device parity leg (writes "
                         "BENCH_streaming.json)")
    ap.add_argument("--stream-rounds", type=int, default=3,
                    help="delta batches for --streaming (default 3)")
    ap.add_argument("--theta", type=int, default=4096,
                    help="fixed θ for --streaming solves (default 4096)")
    ap.add_argument("--pool-rows", type=int, default=2048,
                    help="RR pool size for --selection-only")
    ap.add_argument("--rows", type=int, default=None,
                    help="target pool rows for --sharded (default 2^20)")
    ap.add_argument("--sketch-k", type=int, default=512)
    ap.add_argument("--mesh", default=None,
                    help="--sharded mesh spec (device count or 'axis:N')")
    args = ap.parse_args()
    pkw = dict(k=args.k, eps=args.eps, max_theta=args.max_theta,
               batch=args.batch, engines=tuple(args.engines.split(",")))
    skw = dict(n=args.n, r=args.r, k=args.k, pool_rows=args.pool_rows,
               batch=args.batch, sketch_k=args.sketch_k)
    if args.streaming:
        bench_streaming(n=args.n, r=args.r, k=args.k, theta=args.theta,
                        batch=args.batch, rounds=args.stream_rounds)
    elif args.variants:
        bench_variants(n=args.n, r=args.r, k=args.k, eps=args.eps,
                       max_theta=args.max_theta, batch=args.batch)
    elif args.fused_sketch:
        rows = args.rows if args.rows is not None else 1 << 20
        bench_fused(n=args.n, rows=rows, k=args.k,
                    sketch_k=args.sketch_k, mesh_spec=args.mesh)
    elif args.sharded:
        rows = args.rows if args.rows is not None else 1 << 20
        bench_sharded(n=args.n, rows=rows, k=args.k,
                      sketch_k=args.sketch_k, mesh_spec=args.mesh)
    elif args.selection_only:
        bench_selection(**skw)
    elif args.pipeline_only:
        bench_pipeline(n=args.n, r=args.r, **pkw)
    else:
        main(n=args.n, r=args.r, quota=args.quota, b=args.b, pipeline_kw=pkw,
             selection_kw=skw)

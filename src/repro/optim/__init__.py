from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update, global_norm)
from repro.optim.compress import EFState, ef_init, compress_grads, \
    decompress_grads, psum_compressed
from repro.optim.schedule import cosine_with_warmup

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "EFState", "ef_init", "compress_grads",
           "decompress_grads", "psum_compressed", "cosine_with_warmup"]

"""Warm-solver registry: one prepared ``IMMSolver`` + device pool per
(graph, pool-signature, θ-mode) key, LRU-evicted under a device-memory
budget.

The expensive part of answering an IM request is the sampled RR pool, and
PR 5 already made the pool reusable across problems that share a sampling
signature (``IMProblem.pool_digest``: diffusion model, ``t_rounds``,
``node_weights``).  The registry turns that reuse into a *service*
resource: requests borrow a warm entry, solve on its pool, and the
registry accounts the pool bytes (``IMMSolver.pool_bytes``) against a
configurable budget, evicting least-recently-used entries when a new
pool would not fit.

**θ in the key.**  Fixed-θ problems get one warm solver per
``(graph, pool_digest, theta)``: the pool deterministically reaches
exactly θ rows (same RNG stream a fresh solver would walk) and stays
there, so every answer the entry ever returns is bit-identical to
solving that request alone on a cold solver — the contract the serving
front's micro-batches rely on.  ε-driven problems (``theta=None``) share
one growing pool per signature instead; their answers carry pool-reuse
semantics (selection over a ≥θ pool — statistically at least as good,
documented in DESIGN.md §7).

**Ownership.**  Eviction is an explicit pool-ownership transfer: the
registry calls :meth:`~repro.core.imm.IMMSolver.export_pool`, takes the
:class:`~repro.core.imm.PoolLease`, counts its bytes as freed, and drops
it — the lease is the only reference to the device buffers, so the
accelerator memory is released deterministically, not whenever a solver
object happens to be garbage-collected.

**Durability (DESIGN.md §8).**  With ``spill_dir`` set, eviction first
writes the pool as a durable checkpoint (``IMMSolver.save_pool``) keyed by
the entry's registry key, and a later miss on that key *rehydrates* the
spilled pool instead of resampling — eviction stops destroying the most
expensive state the service owns.  :meth:`quarantine` is the opposite
path: an entry whose solve died mid-flight may hold a partially-appended
pool (device buffers ahead of the host mirrors), so it is dropped without
spilling and can never serve again; any *pre-existing* spill snapshot
stays valid (snapshots are only ever written from committed, consistent
states).
"""
from __future__ import annotations

import hashlib
import itertools
import os
import shutil
from dataclasses import dataclass
from typing import Hashable, Optional

from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.graph.csr import graph_digest as _graph_digest

# solver constructor options a registry may carry (forwarded verbatim)
_SOLVER_OPTS = frozenset(("engine", "batch", "qcap", "ec", "model", "seed",
                          "selection", "sketch_k", "eval_batch", "mesh",
                          "fault_policy"))


@dataclass(frozen=True)
class RegistryStats:
    solvers: int
    created: int
    evictions: int
    bytes_in_use: int
    bytes_freed: int
    memory_budget_bytes: Optional[int]
    spills: int = 0
    rehydrations: int = 0
    rehydrate_failures: int = 0
    quarantined: int = 0
    graph_replacements: int = 0
    pool_refreshes: int = 0
    # consistent-hash ring handoff (repro.serve.cluster, DESIGN.md §11)
    handoffs_out: int = 0         # entries exported as leases to a peer
    handoffs_in: int = 0          # leases adopted warm from a peer
    handoff_drops: int = 0        # adoptions that fell back to a cold pool


@dataclass
class WarmEntry:
    """A registry slot: the prepared solver plus accounting state."""
    key: Hashable
    solver: IMMSolver
    problem: IMProblem            # signature template the entry serves
    bytes: int = 0
    solves: int = 0
    seq: int = 0                  # LRU clock (monotonic use counter)
    in_use: bool = False          # pinned while a batch executes on it
    # ε-driven staleness bookkeeping (DESIGN.md §9): solve epochs served
    # off this shared growing pool since it was last (re)sampled fresh,
    # and how often the resample watermark forced a refresh
    staleness: int = 0
    refreshes: int = 0


class WarmSolverRegistry:
    """Keyed warm solvers over a set of registered graphs.

    ``solver_opts`` configure every solver the registry builds
    (engine/batch/selection/seed/... — the :class:`IMMSolver` constructor
    surface); they are part of the service identity, so the bench's
    fresh-solver parity checks construct their reference solvers from the
    same dict.  ``memory_budget_bytes`` bounds the summed pool bytes
    (``None`` = unbounded); ``max_solvers`` bounds the entry count.
    """

    def __init__(self, *, memory_budget_bytes: Optional[int] = None,
                 max_solvers: Optional[int] = None,
                 solver_opts: Optional[dict] = None,
                 spill_dir: Optional[str] = None):
        if memory_budget_bytes is not None and memory_budget_bytes <= 0:
            raise ValueError("memory_budget_bytes must be positive")
        if max_solvers is not None and max_solvers < 1:
            raise ValueError("max_solvers must be >= 1")
        unknown = set(solver_opts or ()) - _SOLVER_OPTS
        if unknown:
            raise TypeError("unknown solver_opts: "
                            + ", ".join(sorted(unknown)))
        self.memory_budget_bytes = memory_budget_bytes
        self.max_solvers = max_solvers
        self.solver_opts = dict(solver_opts or {})
        self.spill_dir = spill_dir
        self._graphs: dict = {}
        self._digests: "dict[str, str]" = {}
        self._versions: "dict[str, int]" = {}
        self._entries: "dict[Hashable, WarmEntry]" = {}
        self._clock = itertools.count(1)
        self.created = 0
        self.evictions = 0
        self.bytes_freed = 0
        self.spills = 0
        self.rehydrations = 0
        self.rehydrate_failures = 0
        self.quarantines = 0
        self.graph_replacements = 0
        self.pool_refreshes = 0
        self.handoffs_out = 0
        self.handoffs_in = 0
        self.handoff_drops = 0

    # -- graphs ------------------------------------------------------------
    def add_graph(self, name: str, g) -> None:
        """Register — or *replace* — the graph behind ``name``.

        The name is only an address; the identity every key embeds is the
        content digest (:func:`repro.graph.csr.graph_digest`).  Replacing a
        name with different content bumps its monotone version and evicts
        every idle warm entry keyed to the old content: those keys are
        unreachable by new requests (their digest no longer matches), so
        their pools/spills would only leak.  In-flight entries finish their
        batch on the old content and age out via LRU — they can never serve
        a post-replacement request either, for the same key reason.
        """
        dig = _graph_digest(g)
        old = self._digests.get(name)
        if old is not None and old != dig:
            stale = [k for k, e in self._entries.items()
                     if k[0] == name and not e.in_use]
            for k in stale:
                entry = self._entries.pop(k)
                if entry.solver._sig is not None:
                    lease = entry.solver.export_pool()
                    self.bytes_freed += lease.pool_bytes()
                    del lease
                self.evictions += 1
                self.clear_spill(k)
            self.graph_replacements += 1
            self._versions[name] = self._versions.get(name, 0) + 1
        else:
            self._versions.setdefault(name, 0)
        self._graphs[name] = g
        self._digests[name] = dig

    def graph(self, name: str):
        return self._graphs[name]

    def has_graph(self, name: str) -> bool:
        return name in self._graphs

    def graph_version(self, name: str) -> int:
        """Monotone replacement counter for ``name`` (0 = first content)."""
        return self._versions[name]

    def graph_digest(self, name: str) -> str:
        """Content digest of the graph currently behind ``name``."""
        return self._digests[name]

    # -- keys --------------------------------------------------------------
    def _resolved_model(self, problem: IMProblem) -> str:
        if problem.model is not None:
            return problem.model
        return "lt" if self.solver_opts.get("model") == "lt" else "ic"

    def solver_key(self, graph: str, problem: IMProblem) -> tuple:
        """(graph, pool signature, θ) — requests mapping to the same key
        may share one warm solver *and* may be micro-batched together.

        The pool signature mixes in the registered graph's *content digest*
        (``pool_digest(graph_digest=...)``): an RR pool samples one
        concrete graph, so a replaced or delta-mutated graph hashes to a
        different key and can never borrow a pre-mutation pool (the
        stale-graph serving bug this fixed).
        """
        return (graph,
                problem.pool_digest(model=self._resolved_model(problem),
                                    graph_digest=self._digests.get(graph)),
                problem.theta)

    def cache_key(self, graph: str, problem: IMProblem) -> tuple:
        """Result-cache key: full problem content + the warm identity the
        result was computed under (graph name *and* content digest +
        resolved model; the registry's solver_opts are service-constant,
        so they need no per-key bits).  The digest keeps a re-registered
        graph from ever returning a pre-replacement cached ``IMResult``."""
        return (graph, self._digests.get(graph),
                self._resolved_model(problem), problem.signature_digest())

    # -- entries -----------------------------------------------------------
    @property
    def entries(self) -> "dict[Hashable, WarmEntry]":
        return self._entries

    def bytes_in_use(self) -> int:
        return sum(e.bytes for e in self._entries.values())

    def _spill_path(self, key: Hashable) -> Optional[str]:
        if self.spill_dir is None:
            return None
        tag = hashlib.sha256(repr(key).encode()).hexdigest()[:24]
        return os.path.join(self.spill_dir, tag)

    def get(self, graph: str, problem: IMProblem) -> WarmEntry:
        """Fetch-or-build the warm entry for (graph, problem); touches LRU
        and enforces the budgets (never evicting the returned entry).  A
        miss whose key has a spill snapshot rehydrates the saved pool
        instead of resampling; a corrupt/unreadable snapshot falls back to
        the cold path (the pool is always recomputable)."""
        if graph not in self._graphs:
            raise KeyError(f"unknown graph {graph!r}")
        key = self.solver_key(graph, problem)
        entry = self._entries.get(key)
        if entry is None:
            solver = IMMSolver(self._graphs[graph], **self.solver_opts)
            spill = self._spill_path(key)
            if spill is not None and os.path.isdir(spill):
                try:
                    solver.restore_pool(spill)
                    self.rehydrations += 1
                except Exception:
                    # cold-start instead: drop whatever half-state restore
                    # left and resample deterministically
                    solver.drop_pool()
                    self.rehydrate_failures += 1
            entry = WarmEntry(key=key, solver=solver, problem=problem)
            entry.bytes = solver.pool_bytes()
            self._entries[key] = entry
            self.created += 1
        entry.seq = next(self._clock)
        self._enforce(keep=key)
        return entry

    def account(self, entry: WarmEntry) -> None:
        """Refresh an entry's pool-byte accounting after a solve (pools
        grow via capacity doubling) and re-enforce the memory budget."""
        entry.bytes = entry.solver.pool_bytes()
        entry.seq = next(self._clock)
        self._enforce(keep=entry.key)

    def refresh_pool(self, entry: WarmEntry) -> int:
        """Resample watermark hit (DESIGN.md §9): drop an ε-driven entry's
        shared growing pool so its next solve resamples from scratch.
        Bounds the pool-reuse staleness ε-driven answers accumulate —
        without this the shared pool only ever grows and every answer's
        effective sampling law drifts further from a cold θ(ε) solve.
        Returns the bytes dropped; resets the entry's staleness clock."""
        freed = entry.solver.drop_pool()
        entry.bytes = entry.solver.pool_bytes()
        entry.staleness = 0
        entry.refreshes += 1
        self.pool_refreshes += 1
        self.bytes_freed += freed
        return freed

    def evict(self, key: Hashable) -> int:
        """Evict one entry; returns the pool bytes freed.  With a
        ``spill_dir``, the pool is first written as a durable checkpoint so
        a later miss rehydrates instead of resampling.  The device-memory
        transfer stays explicit: the solver's pool is exported into a
        lease the registry immediately drops — the last reference to the
        device buffers."""
        entry = self._entries.pop(key)
        freed = 0
        if entry.solver._sig is not None:
            spill = self._spill_path(key)
            if spill is not None:
                entry.solver.save_pool(spill, keep=1)
                self.spills += 1
            lease = entry.solver.export_pool()
            freed = lease.pool_bytes()
            del lease
        self.evictions += 1
        self.bytes_freed += freed
        return freed

    def quarantine(self, key: Hashable) -> int:
        """Drop an entry whose solve died mid-flight (DESIGN.md §8).  The
        pool may be partially appended, so — unlike :meth:`evict` — it is
        neither spilled nor exported: the buffers are dereferenced and the
        entry can never serve again.  A pre-existing spill snapshot is
        left in place (snapshots are only written from committed states,
        so rehydrating one later is sound).  Returns the bytes dropped;
        no-op (0) for unknown keys."""
        entry = self._entries.pop(key, None)
        if entry is None:
            return 0
        freed = entry.solver.drop_pool()
        self.quarantines += 1
        self.bytes_freed += freed
        return freed

    def evict_coldest(self) -> int:
        """Free the least-recently-used idle entry (0 if none is
        evictable).  Registered as a ``FaultPolicy.on_oom`` hook: when pool
        growth hits an allocation failure, the service frees cold pools
        and retries the append."""
        cands = [e for e in self._entries.values() if not e.in_use]
        if not cands:
            return 0
        return self.evict(min(cands, key=lambda e: e.seq).key)

    # -- cluster handoff (repro.serve.cluster, DESIGN.md §11) ---------------
    def export_entry(self, key: Hashable):
        """Detach one idle entry for a ring-rebalance handoff: pop it and
        return ``(problem, PoolLease)`` — the lease resumes bit-identically
        on the adopting worker (RNG cursor + stats travel with the pool).
        Returns ``None`` when there is nothing to move (unknown key, entry
        pinned by an executing batch, or no pool prepared yet); pinned
        entries are the *caller's* signal to drain first."""
        entry = self._entries.get(key)
        if entry is None or entry.in_use:
            return None
        del self._entries[key]
        if entry.solver._sig is None:
            return None
        lease = entry.solver.export_pool()
        self.handoffs_out += 1
        return entry.problem, lease

    def adopt_entry(self, graph: str, problem: IMProblem, lease
                    ) -> WarmEntry:
        """Install a handed-off pool as a warm entry on this registry (the
        receiving side of :meth:`export_entry`).  When the lease cannot be
        adopted — the workers run different device meshes, say — it is
        dropped and the entry starts cold instead: θ-pinned answers are
        pool-deterministic, so the served bits are identical either way and
        only the warm-up cost differs."""
        key = self.solver_key(graph, problem)
        solver = IMMSolver(self._graphs[graph], **self.solver_opts)
        try:
            solver.adopt_pool(lease)
            self.handoffs_in += 1
        except Exception:
            solver.drop_pool()
            self.handoff_drops += 1
        entry = WarmEntry(key=key, solver=solver, problem=problem)
        entry.bytes = solver.pool_bytes()
        self._entries[key] = entry
        self.created += 1
        entry.seq = next(self._clock)
        self._enforce(keep=key)
        return entry

    def spill_all(self) -> int:
        """Drain-time spill (SIGTERM path): evict every idle entry through
        the normal spill-on-evict path, so with a ``spill_dir`` configured
        each warm pool lands as a durable checkpoint a restarted server
        rehydrates from.  Returns the number of entries evicted."""
        keys = [k for k, e in self._entries.items() if not e.in_use]
        for k in keys:
            self.evict(k)
        return len(keys)

    def clear_spill(self, key: Hashable) -> None:
        """Delete a key's spill snapshot (used by tests/ops tooling)."""
        spill = self._spill_path(key)
        if spill is not None and os.path.isdir(spill):
            shutil.rmtree(spill, ignore_errors=True)

    def _enforce(self, keep: Hashable) -> None:
        def lru_victim():
            cands = [e for e in self._entries.values()
                     if e.key != keep and not e.in_use]
            return min(cands, key=lambda e: e.seq) if cands else None

        while (self.max_solvers is not None
               and len(self._entries) > self.max_solvers):
            victim = lru_victim()
            if victim is None:
                break
            self.evict(victim.key)
        while (self.memory_budget_bytes is not None
               and self.bytes_in_use() > self.memory_budget_bytes):
            victim = lru_victim()
            if victim is None:
                break
            self.evict(victim.key)

    def snapshot(self) -> RegistryStats:
        return RegistryStats(
            solvers=len(self._entries), created=self.created,
            evictions=self.evictions, bytes_in_use=self.bytes_in_use(),
            bytes_freed=self.bytes_freed,
            memory_budget_bytes=self.memory_budget_bytes,
            spills=self.spills, rehydrations=self.rehydrations,
            rehydrate_failures=self.rehydrate_failures,
            quarantined=self.quarantines,
            graph_replacements=self.graph_replacements,
            pool_refreshes=self.pool_refreshes,
            handoffs_out=self.handoffs_out, handoffs_in=self.handoffs_in,
            handoff_drops=self.handoff_drops)

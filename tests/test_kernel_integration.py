"""Kernel-backed system paths == pure-XLA paths (system-level integration)."""
import numpy as np
import jax
import jax.numpy as jnp
import networkx as nx

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov
from repro.core import dense, oracle


def _wc_graph(n=50, m=220, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def test_padded_kernel_selection_matches_flat():
    rng = np.random.default_rng(0)
    n, k = 60, 5
    rr = [rng.choice(n, size=int(rng.integers(1, 12)), replace=False).tolist()
          for _ in range(400)]
    flat_res = cov.select_seeds(cov.build_store(rr, n), k)
    pad_res = cov.select_seeds_padded(cov.build_padded_store(rr, n), k)
    assert np.asarray(flat_res.seeds).tolist() == np.asarray(pad_res.seeds).tolist()
    np.testing.assert_array_equal(np.asarray(flat_res.gains),
                                  np.asarray(pad_res.gains))
    # and both equal the numpy oracle
    seeds_o, _ = oracle.greedy_max_coverage(rr, n, k)
    assert np.asarray(pad_res.seeds).tolist() == seeds_o


def test_packed_engine_p1_exact():
    src, dst = generators.erdos_renyi(40, 160, seed=1)
    g = weights.uniform_weights(csr_mod.from_edges(src, dst, 40), p=1.0)
    g_rev = csr_mod.reverse(g)
    s = dense.sample_rrsets_dense_packed(jax.random.key(0), g_rev, batch=8)
    G = nx.DiGraph()
    G.add_nodes_from(range(40))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    words = np.asarray(s.words)
    for b, root in enumerate(np.asarray(s.roots)):
        members = {v for v in range(40)
                   if (int(words[b, v >> 5]) >> (v & 31)) & 1}
        assert members == (nx.ancestors(G, int(root)) | {int(root)})
    # occur == column sums of membership; sizes == row popcounts
    occ = np.asarray(s.occur)
    sizes = np.asarray(s.sizes)
    mem = np.zeros((8, 40), dtype=np.int32)
    for b in range(8):
        for v in range(40):
            mem[b, v] = (int(words[b, v >> 5]) >> (v & 31)) & 1
    np.testing.assert_array_equal(occ[:40], mem.sum(axis=0))
    np.testing.assert_array_equal(sizes, mem.sum(axis=1))


def test_packed_engine_statistics_match_bool_engine():
    g = _wc_graph(n=40, m=200, seed=2)
    g_rev = csr_mod.reverse(g)
    B, R = 64, 6
    occ_p = np.zeros(40)
    occ_b = np.zeros(40)
    for i in range(R):
        sp = dense.sample_rrsets_dense_packed(jax.random.key(i), g_rev, B,
                                              base_seed=i)
        occ_p += np.asarray(sp.occur)[:40]
        sb = dense.sample_rrsets_dense(jax.random.key(1000 + i), g_rev, B)
        occ_b += np.asarray(sb.membership).sum(axis=0)
    total = B * R
    p_p, p_b = occ_p / total, occ_b / total
    se = np.sqrt((p_p * (1 - p_p) + p_b * (1 - p_b)) / total) + 1e-9
    z = np.abs(p_p - p_b) / se
    assert z.max() < 4.5, f"max z={z.max():.2f}"

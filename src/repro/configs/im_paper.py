"""The paper's own workload configs (gIM Table 1/2 scale stand-ins).

SNAP datasets are not bundled offline; benchmarks use Barabasi-Albert
stand-ins at matched n/m (the paper's own §4.6 scalability methodology).
"""
DATASETS = {
    # name: (n_nodes, n_edges, ba_density r used for the synthetic stand-in)
    "epinions-like":  (75_879, 508_837, 4),
    "slashdot-like":  (77_360, 905_468, 6),
    "higgs-like":     (456_631, 14_855_875, 16),
    "pokec-like":     (1_632_803, 30_622_564, 10),
}
DEFAULTS = dict(k=50, eps=0.05, model="ic", engine="queue", batch=512)

"""Pallas TPU kernel: flash attention (tiled online-softmax, §Perf/H6).

The TPU-target counterpart of ``models/attention.py::sdpa_chunked``: the
S² logits never leave VMEM.  Grid = (batch·heads, Sq/bq, Sk/bk); the last
grid axis streams KV tiles while (m, l, acc) accumulate in VMEM scratch —
the standard Flash-Attention-2 recurrence mapped onto Mosaic's revisiting
output blocks.

Causal masking is positional (global indices reconstructed from the grid),
matching `_sdpa`'s semantics for a full (non-cached) sequence.  Validated
in interpret mode against the pure-jnp oracle across shapes/dtypes
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale: float, bq: int, bk: int, n_k: int, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ()))) * scale   # (bq, bk)
    if causal:
        qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        logits = jnp.where(qi >= ki, logits, -1e30)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    p = jnp.exp(logits - m_new[:, None])
    if causal:
        p = jnp.where(qi >= ki, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + p @ v
    m_s[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_s[...], 1e-20)[:, None]
        o_ref[0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (B, S, H, D) with equal H (repeat KV beforehand for GQA).
    Returns (B, S, H, D).  Full-sequence causal attention."""
    b, s, h, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    if s % bq or s % bk:
        raise ValueError("S must be a multiple of the block sizes")
    import math
    scale = 1.0 / math.sqrt(d)
    # (B*H, S, D) layout
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    n_q, n_k = s // bq, s // bk
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          n_k=n_k, causal=causal),
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

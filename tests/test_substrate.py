"""Optimizer / data / checkpoint / fault-tolerance / train-step tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update, ef_init,
                         compress_grads, decompress_grads,
                         cosine_with_warmup)
from repro.optim.adamw import _quantize, _dequantize
from repro.data import tokens as tok
from repro.ckpt import checkpoint as ckpt
from repro.ft import failures, straggler, elastic


# ------------------------------------------------------------------ optim

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = adamw_update(g, state, params, cfg)
    assert np.abs(np.asarray(params["x"])).max() < 1e-2


def test_int8_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)) * 10, jnp.float32)
    q, s = _quantize(x, 256)
    y = _dequantize(q, s, x.shape)
    err = np.abs(np.asarray(x) - np.asarray(y))
    # absmax int8: error <= scale/2 per block
    scales = np.asarray(s).ravel()
    assert err.max() <= scales.max() / 2 + 1e-6


def test_int8_adamw_tracks_fp32():
    cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0)
    cfg8 = AdamWConfig(lr=0.05, weight_decay=0.0, int8_states=True, block=64)
    p32 = {"x": jnp.asarray(np.linspace(-2, 2, 64), jnp.float32)}
    p8 = jax.tree.map(jnp.copy, p32)
    s32, s8 = adamw_init(p32, cfg32), adamw_init(p8, cfg8)
    loss = lambda p: jnp.sum((p["x"] - 1.0) ** 2)
    for _ in range(100):
        p32, s32 = adamw_update(jax.grad(loss)(p32), s32, p32, cfg32)
        p8, s8 = adamw_update(jax.grad(loss)(p8), s8, p8, cfg8)
    assert float(loss(p8)) < 0.05 * float(loss({"x": jnp.zeros(64)}))
    np.testing.assert_allclose(np.asarray(p8["x"]), np.asarray(p32["x"]),
                               atol=0.1)


def test_error_feedback_compression_converges():
    """EF residual makes the *cumulative* applied gradient unbiased."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
    ef = ef_init(g_true)
    applied = np.zeros(128)
    for t in range(50):
        qg, ef = compress_grads(g_true, ef, block=32)
        deq = decompress_grads(qg, g_true)
        applied += np.asarray(deq["w"])
    target = 50 * np.asarray(g_true["w"])
    # relative error of cumulative sum shrinks to quantization noise
    rel = np.abs(applied - target).max() / np.abs(target).max()
    assert rel < 0.02, rel


def test_cosine_schedule():
    lr0 = float(cosine_with_warmup(jnp.int32(0), peak_lr=1.0,
                                   warmup_steps=10, total_steps=100))
    lr_peak = float(cosine_with_warmup(jnp.int32(10), peak_lr=1.0,
                                       warmup_steps=10, total_steps=100))
    lr_end = float(cosine_with_warmup(jnp.int32(100), peak_lr=1.0,
                                      warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-5


# ------------------------------------------------------------------- data

def test_data_determinism_and_sharding():
    kw = dict(global_batch=8, seq_len=16, vocab=100, seed=3)
    b1 = tok.global_batch_at(5, **kw)
    b2 = tok.global_batch_at(5, **kw)
    np.testing.assert_array_equal(b1, b2)
    assert not np.array_equal(b1, tok.global_batch_at(6, **kw))
    shards = [tok.shard_for(5, s, 4, **kw) for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), b1)


# ------------------------------------------------------------------- ckpt

def test_checkpoint_roundtrip_and_rotation(tmp_path):
    d = str(tmp_path / "ck")
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, state, keep=2)
    assert ckpt.all_steps(d) == [3, 4]
    assert ckpt.latest_step(d) == 4
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = ckpt.restore(d, 4, like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["step"]) == 7
    # structure mismatch raises
    with pytest.raises(ValueError):
        ckpt.restore(d, 4, {"params": {"qq": jnp.zeros((2, 3))}})
    # no stray tmp dirs
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_resilient_solve_recovers_and_matches_uninterrupted(tmp_path):
    """The ft substrate on the IM pipeline (DESIGN.md §8): crashes in
    sampling rounds 3 & 9 -> process 'restart' (fresh solver) -> restore
    from the durable pool checkpoint -> the final result is bit-identical
    to an uninterrupted solve."""
    from repro.ft.runner import resilient_solve
    from repro.graph import csr as csr_mod
    from repro.graph import generators, weights
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem

    src, dst = generators.erdos_renyi(60, 300, seed=0)
    g = weights.wc_weights(csr_mod.from_edges(src, dst, 60))
    p = IMProblem(k=3, theta=512)
    clean = IMMSolver(g, batch=32, seed=7).solve(p)

    d = str(tmp_path / "ck")
    inj = failures.FaultInjector(fail_at={"sample": {3, 9}})

    def make_solver():
        # max_retries=0: every injected fault is fatal to its attempt, so
        # recovery must come from the restart + checkpoint path
        pol = failures.FaultPolicy(injector=inj, max_retries=0,
                                   sleep=lambda s: None)
        return IMMSolver(g, batch=32, seed=7, fault_policy=pol,
                         checkpoint_dir=d, checkpoint_every=2)

    got, report = resilient_solve(make_solver, p, d)
    assert report.completed and report.restarts == 2
    assert report.resumed_steps[0] is None          # cold start
    assert all(s is not None for s in report.resumed_steps[1:])
    np.testing.assert_array_equal(clean.seeds, got.seeds)
    np.testing.assert_array_equal(clean.gains, got.gains)
    assert clean.frac == got.frac and clean.spread == got.spread


def test_straggler_monitor():
    mon = straggler.ShardMonitor(n_shards=4)
    for r in range(10):
        for s in range(4):
            mon.report(s, 1.0 if s != 2 else 5.0)
    assert mon.stragglers() == [2]
    w = mon.work_weights()
    assert w[2] < w[0]
    assert abs(w.sum() - 1) < 1e-9
    alloc = elastic.rebalance_rounds(1000, w)
    assert sum(alloc) == 1000 and alloc[2] < alloc[0]


def test_elastic_mesh_shapes():
    assert elastic.best_mesh_shape(8, model_parallel=4) == (2, 4)
    assert elastic.best_mesh_shape(6, model_parallel=4) == (3, 2)
    assert elastic.best_mesh_shape(7, model_parallel=4) == (7, 1)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint from a 4-device mesh restores onto a 2-device mesh."""
    import subprocess, sys
    d = str(tmp_path / "ck")
    script_tpl = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.ckpt import checkpoint as ckpt
from repro.ft.elastic import make_elastic_mesh
mesh = make_elastic_mesh(model_parallel=2)
state = {{"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}}
sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)
if {save}:
    ckpt.save(r"{d}", 1, state)
    print("SAVED", mesh.shape)
else:
    like = {{"w": jnp.zeros((8, 4), jnp.float32)}}
    out = ckpt.restore(r"{d}", 1, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]).ravel(),
                                  np.arange(32, dtype=np.float32))
    print("RESTORED", mesh.shape)
"""
    env = dict(os.environ); env["PYTHONPATH"] = "src"
    r1 = subprocess.run([sys.executable, "-c",
                         script_tpl.format(n=4, save=1, d=d)],
                        env=env, capture_output=True, text=True,
                        cwd="/root/repo", timeout=300)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c",
                         script_tpl.format(n=2, save=0, d=d)],
                        env=env, capture_output=True, text=True,
                        cwd="/root/repo", timeout=300)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "RESTORED" in r2.stdout


# ------------------------------------------------------------- train step

def test_lm_train_step_learns_and_microbatch_equivalence():
    from repro.models import transformer as T
    from repro.train.steps import (init_train_state, build_lm_train_step)
    cfg = T.LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                     n_kv_heads=2, head_dim=8, d_ff=64, vocab=64)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = init_train_state(jax.random.key(0), cfg, ocfg)
    step1 = jax.jit(build_lm_train_step(cfg, ocfg))
    losses = []
    for s in range(30):
        batch = jnp.asarray(tok.global_batch_at(
            s, global_batch=8, seq_len=16, vocab=64, seed=0))
        state, metrics = step1(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
    # microbatched step produces (approximately) the same first-step loss
    state2 = init_train_state(jax.random.key(0), cfg, ocfg)
    step2 = jax.jit(build_lm_train_step(cfg, ocfg, microbatches=2))
    batch = jnp.asarray(tok.global_batch_at(
        0, global_batch=8, seq_len=16, vocab=64, seed=0))
    _, m1 = step1(init_train_state(jax.random.key(0), cfg, ocfg), batch)
    _, m2 = step2(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)

"""Streaming graphs (DESIGN.md §9): edge deltas, windowed pools,
incremental re-solve.

Contracts under test (ISSUE acceptance criteria):
* ``apply_edge_deltas`` is IC-exact — re-adding an edge merges through
  ``coalesce_ic`` (p' = 1 − ∏(1 − p_i)), removal drops the merged edge,
  strict mode rejects removals of absent edges and out-of-range
  endpoints;
* ``VersionedGraph`` versions are monotone and the digest tracks content;
* windowed eviction (``evict_earliest_rounds`` / ``evict_to_bytes``)
  keeps ``per_device_pool_bytes()`` under the bound, keeps *exactly* the
  later rounds' rows, and rebuilds the packed sketch **bit-identically**
  to a from-scratch ``sketch_packed_from_flat`` fold over the surviving
  flat pool — including after further appends continue the fold;
* the per-round watermark history survives a ``state``/``from_state``
  checkpoint round-trip;
* ``evict_rows_containing`` removes every RR row touching the
  invalidation frontier and nothing else structural (counts add up);
* ``IMMSolver.resolve_incremental`` reuses the surviving pool (tops θ
  back up on the post-delta graph), records its bookkeeping in
  ``last_incremental``, and falls back to a cold pool on signature
  mismatch or when the surviving fraction is below the floor.
"""
import numpy as np
import jax
import pytest

from repro.core import coverage as cov, sketch as sk, stream
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.graph import csr as csr_mod, generators, weights


def _graph(n=40, m=200, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _round_batch(rng, n, rows, max_len=6):
    lens = rng.integers(1, max_len, rows)
    w = int(lens.max())
    nodes = np.zeros((rows, w), np.int64)
    for i, ln in enumerate(lens):
        nodes[i, :ln] = rng.choice(n, size=ln, replace=False)
    return nodes, lens


# ------------------------------------------------------- edge deltas

def test_apply_edge_deltas_ic_merge_and_remove():
    src = np.array([0, 0, 1, 2])
    dst = np.array([1, 2, 2, 0])
    w = np.array([0.5, 0.25, 0.125, 1.0], np.float32)
    g = csr_mod.from_edges(src, dst, 3, weights=w)

    # re-adding (0, 1) strengthens IC-exactly: 1 - (1-0.5)(1-0.5) = 0.75
    g2 = stream.apply_edge_deltas(g, adds=([0], [1], [0.5]))
    s2, d2, w2 = csr_mod.to_edges(g2)
    got = {(int(a), int(b)): float(c) for a, b, c in zip(s2, d2, w2)}
    assert g2.n_edges == 4
    assert got[(0, 1)] == pytest.approx(0.75)
    assert got[(0, 2)] == pytest.approx(0.25)      # untouched edges intact

    # removal drops the merged edge entirely; adds of new edges append
    g3 = stream.apply_edge_deltas(g, adds=([2], [1], [0.625]),
                                  removes=([0], [2]))
    s3, d3, w3 = csr_mod.to_edges(g3)
    got = {(int(a), int(b)): float(c) for a, b, c in zip(s3, d3, w3)}
    assert (0, 2) not in got
    assert got[(2, 1)] == pytest.approx(0.625)
    assert g3.n_edges == 4

    # strict: absent removal raises and names the edge; lax mode ignores
    with pytest.raises(ValueError, match=r"\(1, 0\)"):
        stream.apply_edge_deltas(g, removes=([1], [0]))
    g4 = stream.apply_edge_deltas(g, removes=([1], [0]), strict=False)
    assert g4.n_edges == g.n_edges

    # endpoint validation
    with pytest.raises(ValueError, match="out of range"):
        stream.apply_edge_deltas(g, adds=([0], [3], [0.5]))
    with pytest.raises(ValueError, match="probabilities"):
        stream.make_deltas(adds=([0], [1], [1.5]))


def test_versioned_graph_and_affected_nodes():
    vg = stream.VersionedGraph.wrap(_graph())
    assert vg.version == 0 and vg.digest == csr_mod.graph_digest(vg.g)
    d = stream.make_deltas(adds=([1, 2], [5, 7], [0.5, 0.5]),
                           removes=None)
    vg2 = vg.apply(d)
    assert vg2.version == 1
    assert vg2.digest != vg.digest
    assert vg2.digest == csr_mod.graph_digest(vg2.g)
    # the frontier is the *destinations* (reverse-adjacency rows touched)
    np.testing.assert_array_equal(stream.affected_nodes(d), [5, 7])
    assert bool(d) and not bool(stream.make_deltas())


# ------------------------------------------- windowed eviction (tentpole)

def test_evict_earliest_rounds_keeps_exactly_later_rounds():
    rng = np.random.default_rng(3)
    n = 35
    store = cov.ShardedDeviceRRStore(n, capacity=8, sketch_k=64)
    rounds = [_round_batch(rng, n, rows) for rows in (7, 5, 9, 6)]
    for b in rounds:
        store.append_batch(b)
    assert store.n_rounds == 4 and store.n_rr == 27

    st = store.evict_earliest_rounds(2)
    assert st["rows_dropped"] == 12 and st["rows_kept"] == 15
    assert st["rounds_dropped"] == 2
    assert store.n_rr == 15 and store.n_rounds == 2

    # surviving content == rounds 2..3 verbatim, ids renumbered densely
    flat = np.asarray(jax.device_get(store._flat))[0]
    ids = np.asarray(jax.device_get(store._ids))[0]
    valid = np.asarray(jax.device_get(store._valid))[0]
    got = {}
    for f, i in zip(flat[valid], ids[valid]):
        got.setdefault(int(i), set()).add(int(f))
    want = {}
    rid = 0
    for nodes, lens in rounds[2:]:
        for r, ln in enumerate(lens):
            want[rid] = set(int(x) for x in nodes[r, :ln])
            rid += 1
    assert got == want

    # clamping: asking for more rounds than exist empties the pool
    st = store.evict_earliest_rounds(10)
    assert store.n_rr == 0 and store.n_rounds == 0
    assert store.evict_earliest_rounds(1)["rows_dropped"] == 0


def test_evict_to_bytes_bounds_per_device_pool_bytes():
    rng = np.random.default_rng(9)
    n = 35
    store = cov.ShardedDeviceRRStore(n, capacity=8)
    for rows in (20, 20, 20, 20, 20):
        store.append_batch(_round_batch(rng, n, rows))
    b0 = store.per_device_pool_bytes()
    bound = b0 // 2
    st = store.evict_to_bytes(bound)
    assert st["met"] is True
    assert store.per_device_pool_bytes() <= bound
    assert store.n_rounds >= 1 and store.n_rr > 0

    # a bound below one round's footprint is best-effort: latest round
    # always survives, met flag reports the miss honestly
    st = store.evict_to_bytes(1)
    assert st["met"] is False and store.n_rounds == 1 and store.n_rr > 0


def test_sketch_rebuild_bit_identical_to_from_flat_fold():
    """Acceptance: the post-eviction packed sketch equals a from-scratch
    ``sketch_packed_from_flat`` fold over the surviving flat pool, and a
    later append continues the incremental fold on top bit-identically."""
    rng = np.random.default_rng(17)
    n, k = 41, 64

    def reference(store):
        flat = store._flat[0]
        ids = store._ids[0]
        valid = store._valid[0]
        return np.asarray(jax.device_get(sk.sketch_packed_from_flat(
            flat, ids, valid, n_rows=store.sketch_rows, k=k, mode="mod")))

    for evict in ("rounds", "membership"):
        store = cov.ShardedDeviceRRStore(n, capacity=8, sketch_k=k,
                                         sketch_mode="mod")
        for rows in (9, 7, 11):
            store.append_batch(_round_batch(rng, n, rows))
        if evict == "rounds":
            store.evict_earliest_rounds(2)
        else:
            store.evict_rows_containing([3, 5, 8])
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(store.sketch_words())),
            reference(store))
        # the incremental fold composes with the rebuilt base
        store.append_batch(_round_batch(rng, n, 8))
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(store.sketch_words())),
            reference(store))


def test_round_history_survives_checkpoint_roundtrip():
    rng = np.random.default_rng(23)
    n = 30
    store = cov.ShardedDeviceRRStore(n, capacity=8, sketch_k=32)
    for rows in (6, 4, 8):
        store.append_batch(_round_batch(rng, n, rows))
    twin = cov.ShardedDeviceRRStore.from_state(store.state(), store.config())
    assert twin.n_rounds == 3 and twin.n_rr == store.n_rr
    a = store.evict_earliest_rounds(1)
    b = twin.evict_earliest_rounds(1)
    assert a == b and twin.n_rr == store.n_rr == 12
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(twin.sketch_words())),
        np.asarray(jax.device_get(store.sketch_words())))


def test_evict_rows_containing_removes_exactly_touched_rows():
    rng = np.random.default_rng(29)
    n = 35
    store = cov.ShardedDeviceRRStore(n, capacity=8)
    batches = [_round_batch(rng, n, rows) for rows in (10, 10)]
    for b in batches:
        store.append_batch(b)
    aff = np.array([2, 11, 19])
    touched = sum(
        1 for nodes, lens in batches for r, ln in enumerate(lens)
        if np.isin(nodes[r, :ln], aff).any())
    st = store.evict_rows_containing(aff)
    assert st["rows_dropped"] == touched
    assert st["rows_kept"] == 20 - touched == store.n_rr
    assert st["affected_nodes"] == 3
    flat = np.asarray(jax.device_get(store._flat))[0]
    valid = np.asarray(jax.device_get(store._valid))[0]
    assert not np.isin(flat[valid], aff).any()
    # membership eviction collapses the window history to one round
    assert store.n_rounds == (1 if store.n_rr else 0)


# --------------------------------------------- incremental re-solve

def test_resolve_incremental_reuses_surviving_pool():
    g = _graph(seed=2)
    p = IMProblem(k=3, theta=2048)
    solver = IMMSolver(g, engine="queue", batch=64, seed=5)
    solver.solve(p)
    assert solver.store.n_rr == 2048

    deltas = stream.make_deltas(adds=([0, 1, 2], [5, 9, 13],
                                      [0.4, 0.4, 0.4]))
    res = solver.resolve_incremental(p, deltas)
    info = solver.last_incremental
    assert info["reused"] is True
    assert info["n_rr_before"] == 2048
    assert info["rows_dropped"] + info["rows_kept"] == 2048
    assert 0.0 < info["surviving_fraction"] < 1.0
    assert info["affected_nodes"] == 3
    # θ topped back up on the post-delta graph (batch-granular: the kept
    # rows offset the stream, so the top-up may overshoot θ slightly)
    assert solver.store.n_rr >= 2048
    assert res.stats.theta == 2048 and len(res.seeds) == 3
    assert ("delta", info["rows_dropped"],
            info["rows_kept"]) in res.stats.history
    # the solver's graph moved forward
    want = stream.apply_edge_deltas(g, deltas)
    assert csr_mod.graph_digest(solver.g) == csr_mod.graph_digest(want)

    # surviving-fraction floor forces a cold restart
    solver2 = IMMSolver(g, engine="queue", batch=64, seed=5)
    solver2.solve(p)
    solver2.resolve_incremental(p, deltas, min_surviving_fraction=1.01)
    assert solver2.last_incremental["reused"] is False
    assert solver2.last_incremental["rows_dropped"] > 0
    assert solver2.store.n_rr == 2048


def test_resolve_incremental_signature_mismatch_goes_cold():
    g = _graph(seed=2)
    solver = IMMSolver(g, engine="queue", batch=64, seed=5)
    solver.solve(IMProblem(k=2, theta=1024))
    deltas = stream.make_deltas(adds=([4], [6], [0.5]))
    res = solver.resolve_incremental(IMProblem(k=2, theta=1024, model="lt"),
                                     deltas)
    assert solver.last_incremental["reused"] is False
    assert solver.last_incremental["n_rr_before"] == 0
    assert len(res.seeds) == 2

    with pytest.raises(ValueError, match="t_rounds"):
        solver.resolve_incremental(
            IMProblem(k=2, theta=512, t_rounds=2), deltas)

"""Flash-style chunked attention (§Perf/H6) == full attention, all modes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import attention as A
from repro.models import transformer as T


@pytest.mark.parametrize("window", [None, -1, 3])
@pytest.mark.parametrize("chunk", [2, 4, 16])
def test_chunked_equals_full(window, chunk):
    b, s, h, hkv, d = 2, 10, 4, 2, 8
    key = jax.random.key(0)
    q = jax.random.normal(jax.random.key(1), (b, s, h, d))
    k = jax.random.normal(jax.random.key(2), (b, s, hkv, d))
    v = jax.random.normal(jax.random.key(3), (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    w = None if window is None else jnp.int32(window)
    full = A._sdpa(q, k, v, pos, pos, w, 1.0 / np.sqrt(d))
    chk = A.sdpa_chunked(q, k, v, pos, pos, w, 1.0 / np.sqrt(d),
                         chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               atol=2e-5, rtol=1e-4)


def test_chunked_lm_forward_matches():
    import dataclasses
    cfg = T.LMConfig(name="tiny-q", n_layers=3, d_model=32, n_heads=4,
                     n_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
                     qkv_bias=True, local_global=(1, 4))
    cfg_c = dataclasses.replace(cfg, attn_chunk=4)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    h1, _, _ = T.lm_backbone(params, cfg, tokens)
    h2, _, _ = T.lm_backbone(params, cfg_c, tokens)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=5e-5,
                               rtol=1e-4)
    # gradients agree too
    g1 = jax.grad(lambda p: T.lm_loss(p, cfg, tokens))(params)
    g2 = jax.grad(lambda p: T.lm_loss(p, cfg_c, tokens))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=2e-3)


def test_chunked_nonmultiple_length():
    b, s, h, d = 1, 7, 2, 4
    q = jax.random.normal(jax.random.key(1), (b, s, h, d))
    k = jax.random.normal(jax.random.key(2), (b, s, h, d))
    v = jax.random.normal(jax.random.key(3), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = A._sdpa(q, k, v, pos, pos, None, 0.5)
    chk = A.sdpa_chunked(q, k, v, pos, pos, None, 0.5, chunk=3)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk), atol=2e-5,
                               rtol=1e-4)

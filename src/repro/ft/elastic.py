"""Elastic scaling: re-mesh a job onto a different device count.

Checkpoints are host-unsharded (ckpt/checkpoint.py), so elasticity is:
(1) detect the new device set, (2) build the largest valid mesh, (3) restore
with the new shardings.  Generic state re-shards through ``restore()``;
the one exception is a *pool* checkpoint (``IMMSolver.save_pool``), whose
rows carry shard-local ids — it restores bit-identically only onto a mesh
of the same shard count, which :func:`pool_restore_mesh` builds from
whatever devices the restarted process has.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def best_mesh_shape(n_devices: int, *, model_parallel: int = 1):
    """(data, model) factorization for an arbitrary device count."""
    model = math.gcd(model_parallel, n_devices)
    return (n_devices // model, model)


def make_elastic_mesh(axis_names=("data", "model"), *, model_parallel: int = 1,
                      devices=None):
    devices = devices if devices is not None else jax.devices()
    shape = best_mesh_shape(len(devices), model_parallel=model_parallel)
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axis_names)


def rebalance_rounds(total_sets: int, weights: np.ndarray) -> list[int]:
    """Split a sampling quota across shards proportional to throughput."""
    alloc = np.floor(total_sets * weights).astype(int)
    alloc[np.argmax(weights)] += total_sets - alloc.sum()
    return alloc.tolist()


def pool_restore_mesh(n_shards: int, *, axis_name: str = "samples",
                      devices=None):
    """1-axis mesh with exactly ``n_shards`` devices for restoring a pool
    checkpoint (rows carry shard-local ids, so the restore mesh must match
    the save-time shard count — ``ShardedDeviceRRStore.from_state``
    enforces it).  A restarted process with *more* devices restores onto
    the first ``n_shards``; with fewer it cannot restore bit-identically
    and this raises, pointing at a resample instead."""
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n_shards:
        raise ValueError(
            f"pool checkpoint needs {n_shards} device(s) to restore "
            f"bit-identically but only {len(devices)} are visible; "
            "resample instead of restoring")
    return jax.sharding.Mesh(
        np.asarray(devices[:n_shards]), (axis_name,))

"""Resilient IM solve driver: restart-from-checkpoint around ``IMMSolver``.

This is the process-level recovery layer above ``FaultPolicy`` (which
retries *within* a solver).  When a solve dies anyway — retries exhausted,
or a non-transient error the policy refuses to absorb would in production
be a process crash — the driver plays the restarted process: build a fresh
solver, ``restore_pool`` from the latest durable checkpoint, and re-enter
``solve``, which resumes from the saved round watermark (and, for
eps-driven problems, the saved LB-loop position) instead of resampling.
The conformance contract is that the final result is bit-identical to an
uninterrupted solve — tests/test_fault_tolerance.py drives this with
injected faults; the subprocess tests prove it across a real process
boundary.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.ckpt import checkpoint as ckpt_mod
from repro.ft.failures import is_transient


@dataclass
class SolveReport:
    """What the resilient driver did: restarts taken, the checkpoint step
    each restart resumed from (None = cold start), and the in-solver retry
    total summed over every attempt's fault policy."""
    restarts: int = 0
    resumed_steps: list = field(default_factory=list)
    policy_retries: int = 0
    completed: bool = False


def resilient_solve(make_solver: Callable, problem, ckpt_dir: str, *,
                    max_restarts: int = 3,
                    deadline_s: Optional[float] = None):
    """Run ``solve(problem)`` to completion across simulated process
    restarts.

    ``make_solver`` is a zero-arg factory returning a *fresh*, identically
    configured ``IMMSolver`` (same options/seed, ``checkpoint_dir`` +
    ``checkpoint_every`` pointed at ``ckpt_dir`` so progress is durable) —
    called once per attempt, exactly like a restarted process would
    construct it.  Transient failures (``is_transient``) consume a restart
    and resume from the latest checkpoint under ``ckpt_dir``; anything
    else propagates immediately.  Returns ``(IMResult, SolveReport)``.
    """
    report = SolveReport()
    attempt = 0
    while True:
        solver = make_solver()
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is not None:
            solver.restore_pool(ckpt_dir, step=step)
        report.resumed_steps.append(step)
        try:
            result = solver.solve_problem(problem, deadline_s=deadline_s)
        except BaseException as e:
            if solver.fault_policy is not None:
                report.policy_retries += solver.fault_policy.retries
            if not is_transient(e) or attempt >= max_restarts:
                raise
            attempt += 1
            report.restarts += 1
            continue
        if solver.fault_policy is not None:
            report.policy_retries += solver.fault_policy.retries
        report.completed = True
        return result, report

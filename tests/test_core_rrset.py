"""RR-set engine correctness: deterministic, structural, and statistical."""
import numpy as np
import jax
import jax.numpy as jnp
import networkx as nx
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import rrset, dense, coverage as cov
from repro.core import oracle


def _wc_graph(n=60, m=240, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _det_graph(p, n=40, m=160, seed=1):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.uniform_weights(csr_mod.from_edges(src, dst, n), p=p)


def _nx_reverse_reach(g, root):
    src, dst, _ = csr_mod.to_edges(g)
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n_nodes))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    return nx.ancestors(G, root) | {root}


@pytest.mark.parametrize("engine", ["queue", "dense"])
def test_p1_rrset_equals_reverse_reachability(engine):
    """With p=1 every edge survives: RR set == exact reverse-reachable set."""
    g = _det_graph(p=1.0)
    g_rev = csr_mod.reverse(g)
    key = jax.random.key(0)
    if engine == "queue":
        s = rrset.sample_rrsets_queue(key, g_rev, batch=16, qcap=g.n_nodes)
        rr = rrset.to_lists(s)
        roots = np.asarray(s.roots)
        assert not bool(np.asarray(s.overflowed).any())
    else:
        s = dense.sample_rrsets_dense(key, g_rev, batch=16)
        rr = dense.membership_to_lists(s.membership)
        roots = np.asarray(s.roots)
    for row, root in zip(rr, roots):
        assert set(row) == _nx_reverse_reach(g, int(root))


@pytest.mark.parametrize("engine", ["queue", "dense"])
def test_p0_rrset_is_singleton(engine):
    g = _det_graph(p=0.0)
    g_rev = csr_mod.reverse(g)
    key = jax.random.key(1)
    if engine == "queue":
        s = rrset.sample_rrsets_queue(key, g_rev, batch=8, qcap=g.n_nodes)
        rr = rrset.to_lists(s)
        roots = np.asarray(s.roots)
    else:
        s = dense.sample_rrsets_dense(key, g_rev, batch=8)
        rr = dense.membership_to_lists(s.membership)
        roots = np.asarray(s.roots)
    for row, root in zip(rr, roots):
        assert row == [int(root)]


def test_queue_rrsets_are_valid_and_unique():
    """Structural invariants: root first, no duplicates, all reverse-reachable."""
    g = _wc_graph()
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_queue(jax.random.key(2), g_rev, batch=64,
                                  qcap=g.n_nodes)
    rr = rrset.to_lists(s)
    roots = np.asarray(s.roots)
    for row, root in zip(rr, roots):
        assert row[0] == int(root)
        assert len(set(row)) == len(row)
        reach = _nx_reverse_reach(g, int(root))
        assert set(row) <= reach


def test_queue_small_chunk_matches_structure():
    """EC smaller than degrees exercises the multi-chunk path."""
    g = _det_graph(p=1.0, n=30, m=300, seed=3)
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_queue(jax.random.key(3), g_rev, batch=8,
                                  qcap=g.n_nodes, ec=4)
    rr = rrset.to_lists(s)
    for row, root in zip(rr, np.asarray(s.roots)):
        assert set(row) == _nx_reverse_reach(g, int(root))


def test_engines_agree_statistically():
    """Occur rates of both engines agree within CLT tolerance (same dist)."""
    g = _wc_graph(n=40, m=200, seed=5)
    g_rev = csr_mod.reverse(g)
    B, R = 128, 8
    occ_q = np.zeros(g.n_nodes)
    occ_d = np.zeros(g.n_nodes)
    for i in range(R):
        sq = rrset.sample_rrsets_queue(jax.random.key(10 + i), g_rev, B,
                                       qcap=g.n_nodes)
        for row in rrset.to_lists(sq):
            occ_q[row] += 1
        sd = dense.sample_rrsets_dense(jax.random.key(100 + i), g_rev, B)
        occ_d += np.asarray(sd.membership).sum(axis=0)
    total = B * R
    p_q, p_d = occ_q / total, occ_d / total
    se = np.sqrt((p_q * (1 - p_q) + p_d * (1 - p_d)) / total) + 1e-9
    z = np.abs(p_q - p_d) / se
    # 40 comparisons; allow 4.5 sigma
    assert z.max() < 4.5, f"max z={z.max():.2f}"


def test_queue_engine_matches_oracle_statistically():
    g = _wc_graph(n=40, m=200, seed=6)
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rng = np.random.default_rng(0)
    total = 1024
    occ_o = np.zeros(g.n_nodes)
    for _ in range(total):
        for v in oracle.rr_set_ic(offs, idx, w, int(rng.integers(g.n_nodes)), rng):
            occ_o[v] += 1
    occ_q = np.zeros(g.n_nodes)
    for i in range(total // 128):
        s = rrset.sample_rrsets_queue(jax.random.key(i), g_rev, 128,
                                      qcap=g.n_nodes)
        for row in rrset.to_lists(s):
            occ_q[row] += 1
    p_o, p_q = occ_o / total, occ_q / total
    se = np.sqrt((p_o * (1 - p_o) + p_q * (1 - p_q)) / total) + 1e-9
    z = np.abs(p_o - p_q) / se
    assert z.max() < 4.5, f"max z={z.max():.2f}"


def test_overflow_flag_set_when_qcap_too_small():
    g = _det_graph(p=1.0, n=50, m=400, seed=7)
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_queue(jax.random.key(4), g_rev, batch=32, qcap=2)
    rr = rrset.to_lists(s)
    # every produced row still fits the cap and is duplicate-free
    for row in rr:
        assert len(row) <= 2
        assert len(set(row)) == len(row)
    assert bool(np.asarray(s.overflowed).any())


def test_multi_edges_single_enqueue():
    """Parallel edges to one node: p=1 must not enqueue the node twice."""
    src = np.asarray([0, 0, 0, 0, 1, 1])
    dst = np.asarray([1, 1, 1, 2, 2, 2])
    g = csr_mod.from_edges(src, dst, 3,
                           weights=np.ones(6, dtype=np.float32))
    g_rev = csr_mod.reverse(g)
    # root=2 in reverse graph reaches 0 and 1 through parallel edges
    nodes, lengths, overflow, _ = rrset._sample_queue(
        jax.random.key(0), g_rev.offsets, g_rev.indices, g_rev.weights,
        jnp.asarray([2, 2, 2, 2], jnp.int32), batch=4, qcap=3, ec=8,
        n=3, m=6)
    for b in range(4):
        row = np.asarray(nodes[b, :int(lengths[b])])
        assert sorted(row.tolist()) == [0, 1, 2]
    assert not bool(np.asarray(overflow).any())

"""Queue-based RR-set engine — the gIM decomposition (paper Alg. 3/6), TPU-adapted.

Parallel decomposition (see DESIGN.md §2):

* gIM block  -> *lane*:    B RR sets sampled concurrently (vectorized batch dim)
* gIM warp   -> *chunk*:   the current node's CSR row is processed EC edges per
                           micro-step (EC=128 = VPU lane width; the paper's
                           ``for i = tx; i < deg; i += N_th`` loop, Alg. 3 L16)
* Q_shr+RR_tmp -> queue row: one fixed (Qcap,) row per lane.  In BFS the
  dequeued prefix *is* the RR set, so gIM's three structures (shared queue,
  reservoir, RR_tmp) collapse into one array + (head, tail) cursors.  Overflow
  (paper Alg. 4's reservoir trigger) is counted, not spilled: `overflowed`
  lanes are reported so callers can resample at larger Qcap (0 on all
  benchmark workloads at the default Qcap).
* Visited[n] byte array -> bit-packed (B, ceil(n/32)) uint32 (32x smaller).
* atomic_enqueue -> in-chunk prefix-sum slot assignment + masked scatter.
* curand        -> threefry key folded per micro-step (replay-deterministic).

Intra-chunk duplicate hazard (paper §3.1): within one EC chunk the same
destination may appear on several edges (multi-edges).  Each *edge* must get an
independent Bernoulli trial, but the node must be enqueued at most once.  We
therefore accept only the first successful occurrence per node per chunk
(O(EC^2) vectorized first-occurrence mask), which composes with the visited-bit
test-and-set across chunks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph

EC_DEFAULT = 128  # edge-chunk width (the paper's N_th=32, scaled to VPU lanes)


class QueueSample(NamedTuple):
    nodes: jnp.ndarray       # (B, Qcap) int32 — visit-order node ids per lane
    lengths: jnp.ndarray     # (B,) int32 — RR-set sizes
    roots: jnp.ndarray       # (B,) int32
    overflowed: jnp.ndarray  # (B,) bool — lane hit Qcap (RR set truncated)
    steps: jnp.ndarray       # () int32 — micro-steps executed


def _bit_test(words, nodes):
    """words: (B, W) uint32; nodes: (B, EC) int32 -> (B, EC) bool (bit set?)."""
    w = nodes >> 5
    b = (nodes & 31).astype(jnp.uint32)
    got = jnp.take_along_axis(words, w, axis=1)
    return ((got >> b) & jnp.uint32(1)) != 0


@functools.partial(jax.jit,
                   static_argnames=("batch", "qcap", "ec", "n", "m"))
def _sample_queue(key, offsets, indices, weights, roots, *,
                  batch, qcap, ec, n, m):
    n_words = (n + 31) // 32
    lane = jnp.arange(batch, dtype=jnp.int32)
    queue = jnp.zeros((batch, qcap), dtype=jnp.int32)
    queue = queue.at[:, 0].set(roots)
    visited = jnp.zeros((batch, n_words), dtype=jnp.uint32)
    visited = visited.at[lane, roots >> 5].set(
        jnp.left_shift(jnp.uint32(1), (roots & 31).astype(jnp.uint32)))
    # init derived from `roots` so device-varying types propagate when the
    # sampler runs inside shard_map (one lane batch per device)
    qhead = jnp.zeros_like(roots)
    qtail = jnp.ones_like(roots)
    ecur = jnp.zeros_like(roots)
    overflow = roots < 0
    arange_ec = jnp.arange(ec, dtype=jnp.int32)

    def cond(st):
        _, _, qhead, qtail, _, _, _, _ = st
        return (qhead < qtail).any()

    def body(st):
        queue, visited, qhead, qtail, ecur, overflow, key, step = st
        active = qhead < qtail
        u = queue[lane, jnp.clip(qhead, 0, qcap - 1)]            # current node
        s = offsets[u]
        deg = offsets[u + 1] - s
        pos = ecur[:, None] + arange_ec[None, :]                 # (B, EC)
        valid = (pos < deg[:, None]) & active[:, None]
        eidx = jnp.clip(s[:, None] + pos, 0, m - 1)
        nbr = indices[eidx]                                      # (B, EC)
        pw = weights[eidx]
        key, sub = jax.random.split(key)
        urand = jax.random.uniform(sub, (batch, ec))
        keep = (urand < pw) & valid                              # edge traversed
        unseen = ~_bit_test(visited, nbr)
        cand = keep & unseen
        # first-occurrence-per-node mask within the chunk
        same = nbr[:, :, None] == nbr[:, None, :]                # (B, EC, EC)
        earlier = same & cand[:, None, :] & (
            arange_ec[None, None, :] < arange_ec[None, :, None])
        accept = cand & ~earlier.any(-1)
        # slot assignment (the paper's atomic_enqueue, Alg. 3 L21)
        slot = qtail[:, None] + jnp.cumsum(accept, axis=1) - 1
        fits = slot < qcap
        overflow = overflow | (accept & ~fits).any(axis=1)
        acc = accept & fits
        slot_m = jnp.where(acc, slot, qcap)                      # OOB -> dropped
        queue = queue.at[lane[:, None], slot_m].set(nbr, mode="drop")
        w_idx = jnp.where(acc, nbr >> 5, n_words)
        bitval = jnp.where(
            acc, jnp.left_shift(jnp.uint32(1), (nbr & 31).astype(jnp.uint32)),
            jnp.uint32(0))
        # accepted nodes are chunk-unique -> bits within a word are distinct,
        # so scatter-add == scatter-or here
        visited = visited.at[lane[:, None], w_idx].add(bitval, mode="drop")
        qtail = qtail + acc.sum(axis=1, dtype=jnp.int32)
        # advance the edge cursor / pop the node (Alg. 3 L12)
        ecur2 = ecur + ec
        row_done = ecur2 >= deg
        qhead = jnp.where(active & row_done, qhead + 1, qhead)
        ecur = jnp.where(active & ~row_done, ecur2, 0)
        return queue, visited, qhead, qtail, ecur, overflow, key, step + 1

    queue, visited, qhead, qtail, ecur, overflow, key, steps = (
        jax.lax.while_loop(cond, body,
                           (queue, visited, qhead, qtail, ecur, overflow, key,
                            jnp.int32(0))))
    return queue, qtail, overflow, steps


def sample_rrsets_queue(key, g_rev: CSRGraph, batch: int, qcap: int,
                        ec: int = EC_DEFAULT) -> QueueSample:
    """Sample ``batch`` RR sets (one round) on the reverse CSR."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    key, sub = jax.random.split(key)
    roots = jax.random.randint(sub, (batch,), 0, n, dtype=jnp.int32)
    nodes, lengths, overflowed, steps = _sample_queue(
        key, g_rev.offsets, g_rev.indices, g_rev.weights, roots,
        batch=batch, qcap=qcap, ec=ec, n=n, m=m)
    return QueueSample(nodes=nodes, lengths=lengths, roots=roots,
                       overflowed=overflowed, steps=steps)


def to_lists(sample: QueueSample) -> list[list[int]]:
    nodes = np.asarray(sample.nodes)
    lens = np.asarray(sample.lengths)
    return [nodes[i, :lens[i]].tolist() for i in range(nodes.shape[0])]


# ---------------------------------------------------------------------------
# Persistent-lane ("refill") engine — the paper's Alg. 6 worker structure.
#
# The round-based sampler above retires a whole batch before starting new
# roots, so every lane waits for the round's largest RR set (measured lane
# utilization ~21% on WC/BA workloads — see EXPERIMENTS.md §Perf/IM).  Here
# a lane starts a new RR set the moment it finishes one, exactly like a gIM
# block looping "repeat ... until N_RR >= theta"; RR sets append into a flat
# per-lane output row (the paper's RR array + Offsets_RR).
# ---------------------------------------------------------------------------

class RefillSample(NamedTuple):
    flat: jnp.ndarray      # (B, OutCap) int32 — concatenated RR sets
    lengths: jnp.ndarray   # (B, sets_per_lane) int32 — per-set lengths
    n_done: jnp.ndarray    # (B,) int32 — completed sets per lane
    overflowed: jnp.ndarray  # (B,) bool — lane ran out of OutCap
    steps: jnp.ndarray     # () int32


@functools.partial(jax.jit,
                   static_argnames=("batch", "out_cap", "quota",
                                    "max_sets_per_lane", "ec", "n", "m"))
def _sample_refill(key, offsets, indices, weights, roots0, *,
                   batch, out_cap, quota, max_sets_per_lane, ec, n, m):
    n_words = (n + 31) // 32
    lane = jnp.arange(batch, dtype=jnp.int32)
    arange_ec = jnp.arange(ec, dtype=jnp.int32)
    sets_per_lane = max_sets_per_lane

    out = jnp.zeros((batch, out_cap), jnp.int32)
    out = out.at[:, 0].set(roots0)
    lengths = jnp.zeros((batch, sets_per_lane), jnp.int32)
    visited = jnp.zeros((batch, n_words), jnp.uint32)
    visited = visited.at[lane, roots0 >> 5].set(
        jnp.left_shift(jnp.uint32(1), (roots0 & 31).astype(jnp.uint32)))
    set_start = jnp.zeros_like(roots0)         # current set's base offset
    qhead = jnp.zeros_like(roots0)             # read head (relative)
    tail = jnp.ones_like(roots0)               # absolute write offset
    ecur = jnp.zeros_like(roots0)
    n_done = jnp.zeros_like(roots0)
    overflow = roots0 < 0
    in_set = roots0 >= 0            # lane currently building a set

    def cond(st):
        (_, _, _, _, _, _, _, _, overflow, in_set, _, _) = st
        return (in_set & ~overflow).any()

    def body(st):
        (out, lengths, visited, set_start, qhead, tail, ecur, n_done,
         overflow, in_set, key, step) = st
        working = (n_done < sets_per_lane) & ~overflow & in_set
        active = working & (set_start + qhead < tail)
        u = out[lane, jnp.clip(set_start + qhead, 0, out_cap - 1)]
        s = offsets[u]
        deg = offsets[u + 1] - s
        pos = ecur[:, None] + arange_ec[None, :]
        valid = (pos < deg[:, None]) & active[:, None]
        eidx = jnp.clip(s[:, None] + pos, 0, m - 1)
        nbr = indices[eidx]
        pw = weights[eidx]
        key, sub = jax.random.split(key)
        urand = jax.random.uniform(sub, (batch, ec))
        keep = (urand < pw) & valid
        unseen = ~_bit_test(visited, nbr)
        cand = keep & unseen
        same = nbr[:, :, None] == nbr[:, None, :]
        earlier = same & cand[:, None, :] & (
            arange_ec[None, None, :] < arange_ec[None, :, None])
        accept = cand & ~earlier.any(-1)
        slot = tail[:, None] + jnp.cumsum(accept, axis=1) - 1
        fits = slot < out_cap
        overflow = overflow | (accept & ~fits).any(axis=1)
        acc = accept & fits
        slot_m = jnp.where(acc, slot, out_cap)
        out = out.at[lane[:, None], slot_m].set(nbr, mode="drop")
        w_idx = jnp.where(acc, nbr >> 5, n_words)
        bitval = jnp.where(
            acc, jnp.left_shift(jnp.uint32(1), (nbr & 31).astype(jnp.uint32)),
            jnp.uint32(0))
        visited = visited.at[lane[:, None], w_idx].add(bitval, mode="drop")
        tail = tail + acc.sum(axis=1, dtype=jnp.int32)
        ecur2 = ecur + ec
        row_done = ecur2 >= deg
        qhead = jnp.where(active & row_done, qhead + 1, qhead)
        ecur = jnp.where(active & ~row_done, ecur2, 0)
        # --- lane refill: set finished when the read head catches the tail
        finished = working & (set_start + qhead >= tail)
        in_set = in_set & ~finished
        set_len = tail - set_start
        lengths = lengths.at[
            lane, jnp.where(finished, jnp.clip(n_done, 0, sets_per_lane - 1),
                            sets_per_lane)].set(set_len, mode="drop")
        n_done = n_done + finished.astype(jnp.int32)
        # global quota race (gIM Alg. 6: blocks loop until N_RR >= theta);
        # in-flight sets always complete (no size-biased discarding),
        # lanes just stop *starting* once the global count is met
        quota_open = n_done.sum() < quota
        more = finished & (n_done < sets_per_lane) & quota_open
        # room check for the new root
        has_room = tail < out_cap
        overflow = overflow | (more & ~has_room)
        start_new = more & has_room
        key, sub = jax.random.split(key)
        new_roots = jax.random.randint(sub, (batch,), 0, n, dtype=jnp.int32)
        # clear this lane's visited set and seed the new root
        visited = jnp.where(start_new[:, None], jnp.uint32(0), visited)
        visited = visited.at[
            lane, jnp.where(start_new, new_roots >> 5, n_words)].add(
            jnp.where(start_new,
                      jnp.left_shift(jnp.uint32(1),
                                     (new_roots & 31).astype(jnp.uint32)),
                      jnp.uint32(0)), mode="drop")
        out = out.at[lane, jnp.where(start_new, tail, out_cap)].set(
            new_roots, mode="drop")
        set_start = jnp.where(start_new, tail, set_start)
        qhead = jnp.where(start_new, 0, qhead)
        ecur = jnp.where(start_new, 0, ecur)
        tail = tail + start_new.astype(jnp.int32)
        in_set = in_set | start_new
        return (out, lengths, visited, set_start, qhead, tail, ecur,
                n_done, overflow, in_set, key, step + 1)

    st = (out, lengths, visited, set_start, qhead, tail, ecur, n_done,
          overflow, in_set, key, jnp.int32(0))
    (out, lengths, visited, set_start, qhead, tail, ecur, n_done, overflow,
     in_set, key, steps) = jax.lax.while_loop(cond, body, st)
    return out, lengths, n_done, overflow, steps


def sample_rrsets_refill(key, g_rev: CSRGraph, batch: int,
                         quota: int, out_cap: int,
                         max_sets_per_lane: int | None = None,
                         ec: int = EC_DEFAULT) -> RefillSample:
    """Persistent-lane sampling with a global quota: lanes refill with new
    roots until >= ``quota`` RR sets are complete across all lanes (the
    paper's Alg. 6 worker loop); in-flight sets always finish (unbiased)."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    if max_sets_per_lane is None:
        max_sets_per_lane = max(4 * quota // batch + 4, 4)
    key, sub = jax.random.split(key)
    roots = jax.random.randint(sub, (batch,), 0, n, dtype=jnp.int32)
    flat, lengths, n_done, overflow, steps = _sample_refill(
        key, g_rev.offsets, g_rev.indices, g_rev.weights, roots,
        batch=batch, out_cap=out_cap, quota=quota,
        max_sets_per_lane=max_sets_per_lane, ec=ec, n=n, m=m)
    return RefillSample(flat=flat, lengths=lengths, n_done=n_done,
                        overflowed=overflow, steps=steps)


def refill_to_lists(sample: RefillSample) -> list[list[int]]:
    flat = np.asarray(sample.flat)
    lengths = np.asarray(sample.lengths)
    n_done = np.asarray(sample.n_done)
    out = []
    for b in range(flat.shape[0]):
        off = 0
        for i in range(int(n_done[b])):
            ln = int(lengths[b, i])
            out.append(flat[b, off:off + ln].tolist())
            off += ln
    return out


def refill_to_padded(sample: RefillSample):
    """Vectorized unpack of a RefillSample into (nodes (R, W), lengths (R,)).

    R = total completed sets across lanes, W = max set size.  Sets are laid
    out contiguously per lane (root first), so per-set start offsets are an
    exclusive prefix sum of the recorded lengths; one broadcast gather plus a
    validity mask replaces the per-set python slicing loop.
    """
    flat = np.asarray(sample.flat)
    lengths = np.asarray(sample.lengths, np.int64)    # (B, S)
    n_done = np.asarray(sample.n_done, np.int64)      # (B,)
    b, s = lengths.shape
    set_valid = np.arange(s)[None, :] < n_done[:, None]
    if not set_valid.any():
        return np.zeros((0, 1), np.int64), np.zeros(0, np.int64)
    starts = np.concatenate(
        [np.zeros((b, 1), np.int64), lengths.cumsum(axis=1)[:, :-1]], axis=1)
    width = max(int(lengths[set_valid].max()), 1)
    idx = starts[:, :, None] + np.arange(width, dtype=np.int64)[None, None, :]
    rows = np.take_along_axis(flat[:, None, :],
                              np.clip(idx, 0, flat.shape[1] - 1), axis=2)
    col_valid = np.arange(width)[None, None, :] < lengths[:, :, None]
    rows = np.where(col_valid, rows, 0).reshape(b * s, width)
    keep = set_valid.reshape(b * s)
    return rows[keep].astype(np.int64), lengths[set_valid]

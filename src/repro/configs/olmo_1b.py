"""olmo-1b [arXiv:2402.00838]: 16L d2048 16H dff8192 v50304; non-param LN."""
from repro.configs.lm import olmo_1b as full_config, reduced_lm
ARCH_ID = "olmo-1b"
def reduced_config():
    return reduced_lm(full_config())

"""Serve a small LM with batched decode against a ring KV cache.

    PYTHONPATH=src python examples/serve_decode.py --tokens 32 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.transformer import LMConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache", type=int, default=128)
    args = ap.parse_args()

    cfg = LMConfig(name="gemma3-mini", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, head_dim=16, d_ff=512, vocab=2048,
                   act="geglu", local_global=(3, 16))
    params = T.lm_init(jax.random.key(0), cfg)
    serve = jax.jit(lambda p, t, c, i: T.serve_step(p, cfg, t, c, i))

    caches = T.init_cache(cfg, batch=args.batch, max_len=args.cache)
    toks = jax.random.randint(jax.random.key(1), (args.batch, 1), 0,
                              cfg.vocab)
    out = []
    t0 = time.time()
    for i in range(args.tokens):
        logits, caches = serve(params, toks, caches, jnp.int32(i))
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(toks[:, 0])
    dt = time.time() - t0
    seqs = jnp.stack(out, axis=1)
    print(f"decoded {args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  seq[{b}]: {seqs[b, :16].tolist()} ...")


if __name__ == "__main__":
    main()

"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import networkx as nx
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import rrset, coverage as cov, oracle

SET = settings(max_examples=15, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


@st.composite
def random_graph(draw, max_n=40):
    n = draw(st.integers(5, max_n))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return csr_mod.from_edges(src, dst, n), n


@st.composite
def random_rr_sets(draw, max_n=40, max_sets=60):
    n = draw(st.integers(3, max_n))
    count = draw(st.integers(1, max_sets))
    rngseed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(rngseed)
    sets = []
    for _ in range(count):
        ln = int(rng.integers(1, min(n, 8)))
        sets.append(rng.choice(n, size=ln, replace=False).tolist())
    return sets, n


@SET
@given(random_graph(), st.integers(0, 2 ** 16))
def test_prop_rrset_structural_invariants(gn, key_seed):
    """Root first; unique nodes; subset of exact reverse reachability."""
    g, n = gn
    g = weights.wc_weights(g)
    g_rev = csr_mod.reverse(g)
    s = rrset.sample_rrsets_queue(jax.random.key(key_seed), g_rev, batch=8,
                                  qcap=n)
    src, dst, _ = csr_mod.to_edges(g)
    G = nx.DiGraph()
    G.add_nodes_from(range(n))
    G.add_edges_from(zip(src.tolist(), dst.tolist()))
    for row, root in zip(rrset.to_lists(s), np.asarray(s.roots)):
        assert row[0] == int(root)
        assert len(set(row)) == len(row)
        assert set(row) <= (nx.ancestors(G, int(root)) | {int(root)})


@SET
@given(random_rr_sets(), st.integers(1, 6))
def test_prop_greedy_matches_oracle(rrn, k):
    """JAX greedy == numpy greedy for any RR multiset (exact, incl. ties)."""
    rr, n = rrn
    k = min(k, n)
    store = cov.build_store(rr, n)
    res = cov.select_seeds(store, k)
    seeds_o, frac_o = oracle.greedy_max_coverage(rr, n, k)
    assert np.asarray(res.seeds).tolist() == seeds_o
    assert abs(float(res.frac) - frac_o) < 1e-6


@SET
@given(random_rr_sets())
def test_prop_store_roundtrip(rrn):
    rr, n = rrn
    store = cov.build_store(rr, n)
    flat = np.asarray(store.rr_flat)[np.asarray(store.valid)]
    ids = np.asarray(store.rr_ids)[np.asarray(store.valid)]
    rebuilt = [[] for _ in range(store.n_rr)]
    for v, i in zip(flat, ids):
        rebuilt[i].append(int(v))
    assert rebuilt == [list(map(int, r)) for r in rr]


@SET
@given(st.integers(10, 10_000), st.integers(1, 50),
       st.floats(0.05, 0.9), st.floats(0.05, 0.9))
def test_prop_theta_monotone_in_eps(n, k, e1, e2):
    """Smaller ε ⇒ larger λ' and λ* (θ inverse-quadratic in ε, §4.5)."""
    k = min(k, n - 1)
    lo, hi = sorted((e1, e2))
    if hi - lo < 1e-3:
        return
    lp_hi, ls_hi, _, _ = oracle.imm_theta_params(n, k, hi)
    lp_lo, ls_lo, _, _ = oracle.imm_theta_params(n, k, lo)
    assert lp_lo > lp_hi
    assert ls_lo > ls_hi


@SET
@given(random_rr_sets(), st.integers(1, 4))
def test_prop_gains_monotone_nonincreasing(rrn, k):
    """Greedy marginal gains are non-increasing (submodularity)."""
    rr, n = rrn
    k = min(k, n)
    res = cov.select_seeds(cov.build_store(rr, n), k)
    gains = np.asarray(res.gains)
    assert all(gains[i] >= gains[i + 1] for i in range(len(gains) - 1))


@SET
@given(st.integers(2, 8), st.integers(1, 4), st.integers(0, 2 ** 16))
def test_prop_grouped_moe_matches_global(n_tok_per_group, groups, seed):
    """Group-local dispatch == global dispatch at generous capacity."""
    import jax.numpy as jnp
    from repro.models import moe as M
    cfg0 = M.MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared=0,
                       capacity_factor=8.0)
    cfgg = cfg0._replace(dispatch_groups=groups)
    p = M.moe_init(jax.random.key(seed), 8, cfg0)
    x = jax.random.normal(jax.random.key(seed + 1),
                          (groups * n_tok_per_group, 8))
    y0, _ = M.moe_apply(p, x, cfg0)
    yg, _ = M.moe_apply(p, x, cfgg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yg), atol=3e-5)


@SET
@given(st.integers(4, 24), st.integers(1, 8), st.integers(0, 2 ** 16))
def test_prop_chunked_attention_matches_full(s, chunk, seed):
    import jax.numpy as jnp
    from repro.models import attention as A
    b, h, d = 1, 2, 8
    q = jax.random.normal(jax.random.key(seed), (b, s, h, d))
    k = jax.random.normal(jax.random.key(seed + 1), (b, s, h, d))
    v = jax.random.normal(jax.random.key(seed + 2), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = A._sdpa(q, k, v, pos, pos, None, 0.35)
    chk = A.sdpa_chunked(q, k, v, pos, pos, None, 0.35, chunk=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               atol=3e-5, rtol=1e-4)


@st.composite
def random_multigraph(draw, max_n=12, max_m=80):
    """Edge list with deliberate parallel-edge collisions (small id space)."""
    n = draw(st.integers(3, max_n))
    m = draw(st.integers(1, max_m))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = (rng.random(m) * 0.95).astype(np.float32)
    return src, dst, w, n


@SET
@given(random_multigraph())
def test_prop_coalesce_ic_probability_equivalence(g4):
    """p' = 1 - prod(1 - p_i) per parallel-edge group, exactly; the merged
    graph is simple, destination-sorted and a coalesce fixed point."""
    src, dst, w, n = g4
    g = csr_mod.from_edges(src, dst, n, weights=w)
    gc = csr_mod.coalesce_ic(g)
    s2, d2, w2 = csr_mod.to_edges(gc)
    assert len(set(zip(s2.tolist(), d2.tolist()))) == len(s2)   # simple
    assert csr_mod.rows_dst_sorted(gc)
    got = dict(zip(zip(s2.tolist(), d2.tolist()), w2.tolist()))
    expect = {}
    for u, v, p in zip(src.tolist(), dst.tolist(), w.tolist()):
        expect[(u, v)] = 1.0 - (1.0 - expect.get((u, v), 0.0)) * (1.0 - p)
    assert set(got) == set(expect)
    for key, pv in expect.items():
        assert abs(got[key] - pv) < 1e-6
    assert csr_mod.coalesce_ic(gc) is gc                        # idempotent
    from repro.core.rrset import detect_dedup_mode
    assert detect_dedup_mode(gc) == "none"


@st.composite
def duplicate_chunks(draw, b=6, ec=16):
    """(nbr, cand) chunk pair with adversarial duplicate runs."""
    seed = draw(st.integers(0, 2 ** 16))
    nmax = draw(st.integers(2, 8))          # tiny id space -> heavy collisions
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, nmax, (b, ec)).astype(np.int32)
    cand = rng.random((b, ec)) < draw(st.floats(0.1, 0.9))
    return nbr, cand


@SET
@given(duplicate_chunks())
def test_prop_dedup_modes_agree_with_dense_reference(chunks):
    """segmented (on sorted rows) == sort == the O(EC^2) dense
    first-occurrence reference, for any duplicate pattern."""
    import jax.numpy as jnp
    from repro.core.rrset import _first_occurrence
    nbr_np, cand_np = chunks
    ar = jnp.arange(nbr_np.shape[1], dtype=jnp.int32)

    def dense_ref(nbr, cand):
        out = np.zeros_like(cand)
        for i in range(nbr.shape[0]):
            seen = set()
            for j in range(nbr.shape[1]):
                if cand[i, j] and nbr[i, j] not in seen:
                    out[i, j] = True
                    seen.add(nbr[i, j])
        return out

    # sort fallback: arbitrary order
    srt = np.asarray(_first_occurrence(jnp.asarray(nbr_np),
                                       jnp.asarray(cand_np), ar, mode="sort"))
    np.testing.assert_array_equal(srt, dense_ref(nbr_np, cand_np))
    # segmented: duplicates adjacent (the reverse-CSR layout contract)
    order = np.argsort(nbr_np, axis=1, kind="stable")
    nbr_s = np.take_along_axis(nbr_np, order, axis=1)
    cand_s = np.take_along_axis(cand_np, order, axis=1)
    seg = np.asarray(_first_occurrence(jnp.asarray(nbr_s),
                                       jnp.asarray(cand_s), ar,
                                       mode="segmented"))
    np.testing.assert_array_equal(seg, dense_ref(nbr_s, cand_s))


@SET
@given(random_multigraph(max_n=10, max_m=50), st.integers(0, 2 ** 16))
def test_prop_detect_dedup_mode_is_safe(g4, key_seed):
    """Whatever mode detection picks, sampled rows carry no duplicates."""
    import jax
    from repro.core import rrset
    src, dst, w, n = g4
    g_rev = csr_mod.reverse(csr_mod.from_edges(src, dst, n,
                                               weights=np.minimum(w, 0.8)))
    mode = rrset.detect_dedup_mode(g_rev)
    assert mode in ("none", "segmented", "sort")
    s = rrset.sample_rrsets_queue(jax.random.key(key_seed), g_rev, batch=8,
                                  qcap=n, ec=8)
    nodes, lens = np.asarray(s.nodes), np.asarray(s.lengths)
    for i in range(8):
        row = nodes[i, :lens[i]].tolist()
        assert len(set(row)) == len(row)


@SET
@given(random_graph(max_n=30), st.integers(0, 2 ** 16))
def test_prop_lt_walks_are_paths(gn, key_seed):
    """LT RR sets are simple reverse paths (frontier never exceeds 1)."""
    import jax
    from repro.core import lt as lt_mod
    g, n = gn
    g = weights.wc_weights(g)
    g_rev = csr_mod.reverse(g)
    s = lt_mod.sample_rrsets_lt(jax.random.key(key_seed), g_rev, batch=8,
                                qcap=n)
    nodes = np.asarray(s.nodes); lens = np.asarray(s.lengths)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    for b in range(8):
        row = nodes[b, :lens[b]].tolist()
        assert len(set(row)) == len(row)
        for u, v in zip(row, row[1:]):
            assert v in idx[offs[u]:offs[u + 1]].tolist()

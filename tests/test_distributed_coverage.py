"""Distributed seed selection == single-host selection (8 fake devices).

Device count is locked at first jax init, so the multi-device check runs in a
subprocess with XLA_FLAGS set (the suite itself must keep seeing 1 device).
"""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import coverage as cov
from repro.core import oracle

assert len(jax.devices()) == 8
rng = np.random.default_rng(0)
n, k = 64, 5
per_shard = []
all_rr = []
for s in range(8):
    pool = []
    for _ in range(40):
        ln = int(rng.integers(1, 9))
        pool.append(rng.choice(n, size=ln, replace=False).tolist())
    per_shard.append(pool)
    all_rr += pool
shards = cov.shard_stores(per_shard, n)
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("rr",))
seeds, gains = cov.select_seeds_sharded(mesh, shards, k, n, "rr")
seeds = np.asarray(seeds).tolist()
# oracle on the union (shard padding adds empty rows -> same greedy choice)
seeds_o, _ = oracle.greedy_max_coverage(all_rr, n, k)
assert seeds == seeds_o, (seeds, seeds_o)
print("OK", seeds)
"""


def test_sharded_selection_matches_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout

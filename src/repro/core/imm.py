"""IMM driver (paper Alg. 2 + θ sampling + seed selection), engine-agnostic.

The host orchestrates rounds of RR batches (exactly like gIM's persistent
N_b-block kernel relaunches, Alg. 6) against any registered
:class:`~repro.core.engine.SamplerEngine` — ``queue`` (gIM-faithful),
``dense`` (frontier-SpMV), ``refill`` (persistent lanes), ``lt`` (LT walks),
or a caller-supplied engine instance (e.g. the sharded launcher's).  Every
round is ``batch = engine.sample(key)`` → ``store.append_batch(batch)``; the
solver never inspects engine internals.

The hot loop is *mesh-resident*: the RR pool is a
:class:`~repro.core.coverage.ShardedDeviceRRStore` sharded over the device
mesh chosen once at solver construction (``mesh=`` — ``None`` is the
1-device mesh, the same code path), selection is the capacity-stable
psum-reduced greedy (:func:`~repro.core.coverage.select_seeds_device` /
``select_seeds_celf``), and for engines that declare ``device_resident``
the whole sampling+selection loop runs under
``jax.transfer_guard("disallow")`` on a mesh of any size.  The only
host↔device traffic per round is the store's explicit per-shard count
fetch — the same per-relaunch ``N_RR`` readback gIM's Alg. 6 host loop
performs; per-round stats (micro-steps, overflow) accumulate as device
scalars and materialize once per ``sample_until`` (or lazily on ``stats``
access).  Engines sharing the solver's mesh and exposing
``sample_sharded`` keep their rows on the device that sampled them.

All martingale math (λ', λ*, the Alg. 2 LB loop) follows IMM [Tang et al.'15]
and is shared with the numpy oracle (core/oracle.py) so both sides compute
identical θ schedules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, reverse
from repro.core import coverage as cov
from repro.core.oracle import imm_theta_params
from repro.core.engine import (SamplerEngine, make_engine, resolve_engine_name,
                               split_key as _split_key)


@jax.jit
def _accum_round_stats(steps_acc, ovf_acc, steps, overflowed):
    """Device-scalar stat accumulation — replaces the per-round blocking
    ``int(batch.steps)`` / ``np.asarray(batch.overflowed)`` syncs."""
    return (steps_acc + steps.astype(jnp.int32),
            ovf_acc + overflowed.sum(dtype=jnp.int32))


@dataclass
class IMMStats:
    theta: int = 0
    n_rr_sampled: int = 0
    lb: float = 1.0
    lb_iters: int = 0
    rounds: int = 0
    overflow_fraction: float = 0.0
    frac_covered: float = 0.0
    sampling_steps: int = 0
    selection: str = "auto"
    mesh_shape: tuple = (1,)
    pool_sharding: str = "samples:1"
    per_device_pool_bytes: int = 0
    history: list = field(default_factory=list)


# user-facing selection knob -> DeviceRRStore.select method.  "fused" is the
# single-scan flat path (the historical default), "bitset" the Pallas
# bit-matrix path, "celf-sketch" the lazy greedy over coverage sketches.
_SELECTION_METHODS = {
    "auto": "auto", "fused": "flat", "flat": "flat", "bitset": "bitset",
    "celf-sketch": "celf", "celf": "celf",
}


class IMMSolver:
    """Stateful solver: owns the RR pool so Alg. 2 reuses earlier samples.

    ``engine`` is a registered engine name or a ready ``SamplerEngine``
    instance; ``batch``/``qcap``/``ec`` are forwarded to the engine's config
    (each engine takes the subset it understands).  ``model="lt"`` keeps its
    historical meaning by resolving to the ``lt`` engine.
    """

    def __init__(self, g: CSRGraph, *,
                 engine: Union[str, SamplerEngine] = "queue",
                 batch: Optional[int] = None, qcap: Optional[int] = None,
                 ec: Optional[int] = None, model: Optional[str] = None,
                 selection: str = "auto", sketch_k: Optional[int] = None,
                 mesh=None, seed: int = 0):
        self.g = g
        self.n = g.n_nodes
        if isinstance(engine, str):
            name = resolve_engine_name(engine, model or "ic")
            self.g_rev = reverse(g)
            # None options fall through to each engine Config's own defaults
            self.engine: SamplerEngine = make_engine(
                name, self.g_rev, batch=batch, qcap=qcap, ec=ec)
        else:
            # engine instance passed in: it owns its graph + configuration,
            # so sampling options on the solver would be silently ignored
            if any(v is not None for v in (batch, qcap, ec, model)):
                raise ValueError(
                    "batch/qcap/ec/model have no effect when an engine "
                    "instance is passed; configure the engine instead")
            self.engine = engine
            self.g_rev = getattr(engine, "g_rev", None)
        if self.engine.item_space != self.n:
            # e.g. engine="mrim": its ids are round*n+node encodings that
            # would leak out of solve() as nonsense seeds — route those
            # through their own solver (solve_mrim)
            raise ValueError(
                f"engine {getattr(self.engine, 'name', '?')!r} samples an "
                f"item space of {self.engine.item_space}, not the graph's "
                f"{self.n} nodes; IMMSolver needs a plain node-id engine "
                "(tagged engines like 'mrim' have dedicated solvers)")
        self.engine_name = getattr(self.engine, "name",
                                   type(self.engine).__name__)
        if selection not in _SELECTION_METHODS:
            raise ValueError(f"unknown selection {selection!r}; one of "
                             f"{sorted(_SELECTION_METHODS)}")
        self.selection = selection
        self._sel_method = _SELECTION_METHODS[selection]
        # the celf path estimates from the incremental coverage sketch, so
        # the store maintains one from the first append on
        if self._sel_method == "celf" and sketch_k is None:
            sketch_k = cov.ShardedDeviceRRStore.DEFAULT_SKETCH_K
        self.key = jax.random.key(seed)
        # mesh placement is decided exactly once, here: the pool, the
        # sketch, and every selection backend live on this mesh for the
        # solver's lifetime (mesh=None -> the 1-device mesh special case)
        self.store = cov.ShardedDeviceRRStore(self.engine.item_space,
                                              sketch_k=sketch_k, mesh=mesh)
        self._stats = IMMStats(
            selection=selection,
            mesh_shape=tuple(int(s) for s in self.store.mesh.devices.shape),
            pool_sharding=f"{self.store.axis}:{self.store.n_shards}")
        self._stats_dirty = False
        # stats accumulate as device scalars; materialized once per
        # sample_until / on `stats` access, not per round
        self._steps_acc = jnp.zeros((), jnp.int32)
        self._ovf_acc = jnp.zeros((), jnp.int32)
        self._ovf_lanes = 0
        # engines advertising full device residency let the solver hold a
        # transfer guard over the whole hot loop; host-path engines (e.g.
        # third-party adapters) fall back to unguarded execution
        self._guard = ("disallow"
                       if getattr(self.engine, "device_resident", False)
                       else "allow")
        self._sample = getattr(self.engine, "sample_device",
                               self.engine.sample)
        # a sharded engine on the *same* mesh hands the store rows that are
        # already resident on their sampling device — no dev0 gather
        if (self.store.n_shards > 1
                and getattr(self.engine, "mesh", None) == self.store.mesh
                and hasattr(self.engine, "sample_sharded")):
            self._sample = self.engine.sample_sharded

    # -- stats -------------------------------------------------------------
    @property
    def stats(self) -> IMMStats:
        self._materialize_stats()
        return self._stats

    def _materialize_stats(self):
        if self._stats_dirty:
            steps, ovf = (int(x) for x in jax.device_get(
                (self._steps_acc, self._ovf_acc)))
            st = self._stats
            st.sampling_steps = steps
            st.n_rr_sampled = self.store.n_rr
            st.overflow_fraction = (ovf / self._ovf_lanes
                                    if self._ovf_lanes else 0.0)
            st.per_device_pool_bytes = self.store.per_device_pool_bytes()
            self._stats_dirty = False

    # -- sampling ----------------------------------------------------------
    def _round(self):
        self.key, sub = _split_key(self.key)
        batch = self._sample(sub)
        self.store.append_batch(batch)
        self._steps_acc, self._ovf_acc = _accum_round_stats(
            self._steps_acc, self._ovf_acc, batch.steps, batch.overflowed)
        self._ovf_lanes += int(np.prod(batch.overflowed.shape))
        self._stats.rounds += 1
        self._stats_dirty = True

    def sample_until(self, theta: int):
        # the loop condition reads the store's exact host-mirrored row count
        # (explicit scalar fetch per append — gIM's Alg. 6 N_RR readback);
        # no pool data crosses to the host
        while self.store.n_rr < theta:
            self._round()
        self._materialize_stats()

    def _store(self) -> cov.RRStore:
        return self.store.snapshot()

    # -- full IMM ----------------------------------------------------------
    def solve(self, k: int, eps: float, ell: float = 1.0,
              max_theta: Optional[int] = None):
        n = self.n
        lam_p, lam_star, eps_p, _ = imm_theta_params(n, k, eps, ell)
        lb = 1.0
        with jax.transfer_guard(self._guard):
            for i in range(1, max(int(math.log2(n)), 2)):       # Alg. 2
                x = n / (2.0 ** i)
                theta_i = int(math.ceil(lam_p / x))
                if max_theta:
                    theta_i = min(theta_i, max_theta)
                self.sample_until(theta_i)
                res = self.store.select(k, method=self._sel_method)
                # explicit scalar fetch: the Alg. 2 L7 break is host control
                est = n * float(jax.device_get(res.frac))
                self._stats.lb_iters = i
                self._stats.history.append(("lb_iter", i, theta_i, est))
                if est >= (1.0 + eps_p) * x:                     # Alg. 2 L7
                    lb = est / (1.0 + eps_p)                     # Alg. 2 L8
                    break
            theta = int(math.ceil(lam_star / lb))
            if max_theta:
                theta = min(theta, max_theta)
            self._stats.theta = theta
            self._stats.lb = lb
            self.sample_until(theta)
            res = self.store.select(k, method=self._sel_method)
        # final result materialization — the loop's only bulk transfer
        seeds, frac = jax.device_get((res.seeds, res.frac))
        self._stats.frac_covered = float(frac)
        spread_est = n * float(frac)                             # Eq. (3)
        return np.asarray(seeds), spread_est, self.stats


def imm(g: CSRGraph, k: int, eps: float, **kw):
    """One-shot convenience wrapper; returns (seeds, spread_estimate, stats)."""
    solver_kw = {k_: v for k_, v in kw.items()
                 if k_ in ("engine", "batch", "qcap", "ec", "model", "seed",
                           "selection", "sketch_k", "mesh")}
    solve_kw = {k_: v for k_, v in kw.items() if k_ in ("ell", "max_theta")}
    solver = IMMSolver(g, **solver_kw)
    return solver.solve(k, eps, **solve_kw)

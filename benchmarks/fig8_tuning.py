"""Paper Fig. 8: hardware-tuning sweep.

Fig. 8a's N_th (threads/block) maps to the EC edge-chunk width; Fig. 8b's
N_b (grid size, Eq. 5) maps to the lane batch B.  Reports sampling time for
a fixed θ, normalized to the default (EC=128, B=512).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import IMMSolver

N, R, THETA = 10000, 8, 2048


def sample_time(g, batch, ec):
    solver = IMMSolver(g, engine="queue", batch=batch, ec=ec, seed=0)
    t0 = time.perf_counter()
    solver.sample_until(THETA)
    return time.perf_counter() - t0


def main():
    g = ba_graph(N, R)
    base = sample_time(g, 512, 128)
    rows = []
    for ec in (32, 64, 128, 256):
        t = sample_time(g, 512, ec)
        rows.append(["ec", ec, round(t, 3), round(t / base, 3)])
        report(f"fig8a/ec={ec}", t * 1e6, f"norm={t / base:.3f}")
    for b in (64, 128, 256, 512, 1024):
        t = sample_time(g, b, 128)
        rows.append(["batch", b, round(t, 3), round(t / base, 3)])
        report(f"fig8b/B={b}", t * 1e6, f"norm={t / base:.3f}")
    write_csv("fig8_tuning", ["param", "value", "t_s", "normalized"], rows)


if __name__ == "__main__":
    main()

"""Per-arch smoke tests: reduced config, one real forward/train step on CPU,
asserting output shapes + no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation) — verified structurally here."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry, specs, gnn_archs, recsys
from repro.configs.shapes import GNN_SHAPES, RECSYS_SHAPES, cells
from repro.models import transformer as T
from repro.models.layers import count_params
from repro.optim import AdamWConfig, adamw_init
from repro.train import steps as tsteps

LM_ARCHS = [a for a, m in registry.ARCHS.items() if m["family"] == "lm"]
GNN_ARCHS = [a for a, m in registry.ARCHS.items() if m["family"] == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = registry.lm_config(arch, reduced=True)
    ocfg = AdamWConfig(lr=1e-3)
    state = tsteps.init_train_state(jax.random.key(0), cfg, ocfg)
    step = jax.jit(tsteps.build_lm_train_step(cfg, ocfg))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    state, metrics = step(state, tokens)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_step(arch):
    cfg = registry.lm_config(arch, reduced=True)
    params = T.lm_init(jax.random.key(0), cfg)
    caches = T.init_cache(cfg, batch=2, max_len=8, filled=False)
    step = jax.jit(tsteps.build_lm_serve_step(cfg))
    tok = jax.random.randint(jax.random.key(1), (2, 1), 0, cfg.vocab)
    logits, caches = step(params, tok, caches, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_full_config_param_count(arch):
    """Full configs instantiate *abstractly* and hit the expected scale."""
    cfg = registry.lm_config(arch)
    shapes = jax.eval_shape(lambda: T.lm_init(jax.random.key(0), cfg))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    expected = {
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "gemma3-12b": (10e9, 14e9),
        "deepseek-v3-671b": (630e9, 700e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),
    }[arch]
    assert expected[0] < n_params < expected[1], f"{arch}: {n_params/1e9:.2f}B"


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_id", ["full_graph_sm", "minibatch_lg",
                                      "molecule"])
def test_gnn_smoke_step(arch, shape_id):
    step, args, meta = specs.build_cell(arch, shape_id, reduced=True)
    rng = np.random.default_rng(0)

    def realize(sds):
        if sds.dtype == jnp.int32:
            hi = 4 if "labels" else 4
            return jnp.asarray(rng.integers(0, 4, sds.shape), jnp.int32)
        if sds.dtype == jnp.bool_:
            return jnp.ones(sds.shape, bool)
        return jnp.asarray(rng.normal(size=sds.shape) * 0.1, jnp.float32)

    state_specs, *arg_specs = args
    # realize params concretely via init (eval_shape structures match)
    sh = dict(GNN_SHAPES[shape_id])
    cfg = meta["cfg"]
    params = gnn_archs.init_params(arch, jax.random.key(0), cfg,
                                   sh["n_classes"])
    state = (params, adamw_init(params, AdamWConfig()))
    concrete = [realize(a) for a in arg_specs]
    # edge indices within node count; labels within n_classes
    if shape_id == "molecule":
        concrete[1] = concrete[1] % 4
        concrete[2] = concrete[2] % 4
    else:
        n = concrete[0].shape[0]
        concrete[1] = concrete[1] % n
        concrete[2] = concrete[2] % n
    concrete[4] = concrete[4] % sh["n_classes"]
    (params2, opt2), loss = jax.jit(step)(state, *concrete)
    assert np.isfinite(float(loss)), (arch, shape_id)
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params,
                     params2)
    assert max(jax.tree.leaves(d)) > 0


def test_deepfm_smoke_all_shapes():
    for shape_id in RECSYS_SHAPES:
        step, args, meta = specs.build_cell("deepfm", shape_id, reduced=True)
        cfg = meta["cfg"]
        rng = np.random.default_rng(0)
        if meta["kind"] == "train":
            from repro.models.deepfm import deepfm_init
            params = deepfm_init(jax.random.key(0), cfg)
            state = (params, adamw_init(params, AdamWConfig()))
            _, ids_s, dx_s, lb_s = args
            ids = jnp.asarray(rng.integers(0, cfg.total_rows, ids_s.shape),
                              jnp.int32)
            dx = jnp.asarray(rng.normal(size=dx_s.shape), jnp.float32)
            lb = jnp.asarray(rng.integers(0, 2, lb_s.shape), jnp.float32)
            (p2, _), loss = jax.jit(step)(state, ids, dx, lb)
            assert np.isfinite(float(loss))
        elif meta["kind"] == "serve":
            from repro.models.deepfm import deepfm_init
            params = deepfm_init(jax.random.key(0), cfg)
            _, ids_s, dx_s = args
            ids = jnp.asarray(rng.integers(0, cfg.total_rows, ids_s.shape),
                              jnp.int32)
            dx = jnp.asarray(rng.normal(size=dx_s.shape), jnp.float32)
            out = jax.jit(step)(params, ids, dx)
            assert out.shape == (ids_s.shape[0],)
        else:
            q_s, c_s = args
            q = jnp.asarray(rng.normal(size=q_s.shape), jnp.float32)
            c = jnp.asarray(rng.normal(size=c_s.shape), jnp.float32)
            vals, idx = jax.jit(step)(q, c)
            assert vals.shape[0] == RECSYS_SHAPES[shape_id]["top_k"]


def test_cells_enumeration():
    cs = cells()
    ids = {a for a, _ in cs}
    assert len(ids) == 10
    # 5 LM archs x 4 shapes - 3 long_500k skips + 4x4 GNN + 4 recsys
    assert len(cs) == 5 * 4 - 3 + 16 + 4, len(cs)
    assert ("gemma3-12b", "long_500k") in cs
    assert ("deepseek-v3-671b", "long_500k") in cs
    assert ("qwen2-0.5b", "long_500k") not in cs

"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs. pure-jnp ref oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


RNG = np.random.default_rng(0)


@pytest.mark.parametrize("r,l", [(16, 128), (100, 128), (257, 256),
                                 (1024, 512), (7, 384)])
def test_membership_sweep(r, l):
    rows = jnp.asarray(RNG.integers(0, 50, size=(r, l)), jnp.int32)
    lens = jnp.asarray(RNG.integers(0, l + 1, size=r), jnp.int32)
    for u in (0, 7, 49, 1000):
        got = ops.membership_rows(rows, lens, u)
        want = ref.membership_rows_ref(rows, lens, u)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("e", [128, 1000, 4096, 65536, 37])
@pytest.mark.parametrize("seed", [0, 1, 123456789])
def test_bernoulli_bitexact_sweep(e, seed):
    w = jnp.asarray(RNG.uniform(size=e), jnp.float32)
    got = ops.bernoulli_edges(w, seed)
    want = ref.bernoulli_edges_ref(w, seed)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bernoulli_statistics():
    """Mean keep-rate ~= p; streams differ across seeds."""
    e = 1 << 16
    for p in (0.1, 0.5, 0.9):
        w = jnp.full((e,), p, jnp.float32)
        keep = np.asarray(ops.bernoulli_edges(w, 7))
        assert abs(keep.mean() - p) < 4.5 * np.sqrt(p * (1 - p) / e)
    k1 = np.asarray(ops.bernoulli_edges(jnp.full((e,), 0.5, jnp.float32), 1))
    k2 = np.asarray(ops.bernoulli_edges(jnp.full((e,), 0.5, jnp.float32), 2))
    assert 0.4 < (k1 != k2).mean() < 0.6  # independent streams


def test_bernoulli_lane_independence():
    """Adjacent counters are uncorrelated (avalanche sanity)."""
    e = 1 << 16
    keep = np.asarray(ops.bernoulli_edges(jnp.full((e,), 0.5, jnp.float32), 3))
    a, b = keep[:-1], keep[1:]
    agree = (a == b).mean()
    assert 0.45 < agree < 0.55


@pytest.mark.parametrize("b,n", [(4, 32), (8, 128), (33, 1024), (128, 4096)])
def test_pack_bits_sweep(b, n):
    bits = jnp.asarray(RNG.integers(0, 2, size=(b, n)).astype(bool))
    got = ops.pack_bits(bits)
    want = ref.pack_bits_ref(bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,w", [(4, 4), (64, 32), (100, 100), (257, 8)])
def test_bitset_binary_and_popcount_sweep(b, w):
    a = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w), dtype=np.uint32))
    c = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w), dtype=np.uint32))
    np.testing.assert_array_equal(np.asarray(ops.bitset_or(a, c)),
                                  np.asarray(ref.bitset_or_ref(a, c)))
    np.testing.assert_array_equal(np.asarray(ops.bitset_andnot(a, c)),
                                  np.asarray(ref.bitset_andnot_ref(a, c)))
    got = np.asarray(ops.popcount_words(a))
    want = np.asarray(ref.popcount_words_ref(a))
    np.testing.assert_array_equal(got, want)
    # cross-check against python popcount
    assert got[0, 0] == bin(int(np.asarray(a)[0, 0])).count("1")


@pytest.mark.parametrize("b,w", [(8, 4), (64, 8), (100, 16), (16, 1)])
def test_occur_from_bitset_sweep(b, w):
    words = jnp.asarray(RNG.integers(0, 2 ** 32, size=(b, w), dtype=np.uint32))
    got = np.asarray(ops.occur_from_bitset(words))
    want = np.asarray(ref.occur_from_bitset_ref(words))
    np.testing.assert_array_equal(got, want)
    # equivalence with bool unpack + sum
    unpacked = np.zeros((b, w * 32), dtype=np.int32)
    wnp = np.asarray(words)
    for i in range(b):
        for j in range(w):
            for t in range(32):
                unpacked[i, j * 32 + t] = (int(wnp[i, j]) >> t) & 1
    np.testing.assert_array_equal(got, unpacked.sum(axis=0))


def test_membership_kernel_drives_coverage():
    """Kernel membership == the coverage module's segment-based scan."""
    from repro.core import coverage as cov
    rng = np.random.default_rng(5)
    n = 40
    rr = [rng.choice(n, size=int(rng.integers(1, 10)), replace=False).tolist()
          for _ in range(200)]
    l = 16
    rows = np.full((200, l), n, np.int32)
    lens = np.zeros(200, np.int32)
    for i, r in enumerate(rr):
        rows[i, :len(r)] = r
        lens[i] = len(r)
    store = cov.build_store(rr, n)
    for u in (0, 5, 39):
        hit_kernel = np.asarray(ops.membership_rows(
            jnp.asarray(rows), jnp.asarray(lens), u))
        match = (np.asarray(store.rr_flat) == u) & np.asarray(store.valid)
        hit_flat = np.zeros(200, bool)
        np.logical_or.at(hit_flat, np.asarray(store.rr_ids)[match], True)
        np.testing.assert_array_equal(hit_kernel, hit_flat)


@pytest.mark.parametrize("s,bq,bk", [(16, 8, 8), (32, 8, 16), (64, 64, 32)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_sweep(s, bq, bk, dtype):
    import jax
    dt = jnp.dtype(dtype)
    b, h, d = 2, 3, 16
    q = jax.random.normal(jax.random.key(1), (b, s, h, d)).astype(dt)
    k = jax.random.normal(jax.random.key(2), (b, s, h, d)).astype(dt)
    v = jax.random.normal(jax.random.key(3), (b, s, h, d)).astype(dt)
    got = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    import jax
    b, s, h, d = 1, 32, 2, 8
    q = jax.random.normal(jax.random.key(1), (b, s, h, d))
    k = jax.random.normal(jax.random.key(2), (b, s, h, d))
    v = jax.random.normal(jax.random.key(3), (b, s, h, d))
    got = ops.flash_attention(q, k, v, causal=False, bq=8, bk=8)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_sketch_kernels_defer_interpret_to_ops_policy(monkeypatch):
    # regression: the sketch kernels used to hardcode interpret=True, so the
    # compiled Mosaic path was unreachable on accelerator backends.  They
    # must resolve interpret=None through the shared ops policy (per-call >
    # module override > env > backend default) like every other kernel.
    from repro.kernels import sketch as sk
    seen = []
    real = ops.resolve_interpret

    def recorder(flag=None):
        seen.append(flag)
        return real(flag)

    monkeypatch.setattr(ops, "resolve_interpret", recorder)
    words = jnp.zeros((8, 2), jnp.uint32)
    out = sk.sketch_scatter_or(words, jnp.asarray([1, 3, 99], jnp.int32),
                               jnp.asarray([0, 33, 5], jnp.int32))
    assert seen == [None]           # default defers to the shared policy
    got = np.asarray(out)
    assert got[1, 0] == 1 and got[3, 1] == 2   # bit 0 / bit 33
    assert got.sum() == 3                       # oob row 99 dropped

    seen.clear()
    cov_words = jnp.asarray(np.asarray([1, 0], np.uint32))
    cnt = sk.sketch_union_popcount(out, cov_words, interpret=True)
    assert seen == [True]           # explicit flag still wins
    want = np.asarray([np.uint32(r[0] | 1).bit_count() + r[1].bit_count()
                       for r in got], np.int32)
    np.testing.assert_array_equal(np.asarray(cnt), want)

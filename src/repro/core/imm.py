"""IMM driver (paper Alg. 2 + θ sampling + seed selection), engine-agnostic.

The host orchestrates rounds of B RR sets (exactly like gIM's persistent
N_b-block kernel relaunches, Alg. 6) against either engine:

* ``engine="queue"`` — gIM-faithful work-efficient sampler (core/rrset.py)
* ``engine="dense"`` — dense-frontier sampler (core/dense.py)

All martingale math (λ', λ*, the Alg. 2 LB loop) follows IMM [Tang et al.'15]
and is shared with the numpy oracle (core/oracle.py) so both sides compute
identical θ schedules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, reverse
from repro.core import coverage as cov
from repro.core.oracle import imm_theta_params
from repro.core import rrset as rr_queue
from repro.core import dense as rr_dense
from repro.core import lt as rr_lt


@dataclass
class IMMStats:
    theta: int = 0
    n_rr_sampled: int = 0
    lb: float = 1.0
    lb_iters: int = 0
    rounds: int = 0
    overflow_fraction: float = 0.0
    frac_covered: float = 0.0
    sampling_steps: int = 0
    history: list = field(default_factory=list)


class IMMSolver:
    """Stateful solver: owns the RR pool so Alg. 2 reuses earlier samples."""

    def __init__(self, g: CSRGraph, *, engine: str = "queue", batch: int = 256,
                 qcap: Optional[int] = None, ec: int = rr_queue.EC_DEFAULT,
                 model: str = "ic", seed: int = 0):
        self.g = g
        self.g_rev = reverse(g)
        self.n = g.n_nodes
        self.engine = engine
        self.batch = batch
        self.qcap = qcap if qcap is not None else self.n
        self.ec = ec
        self.model = model
        self.key = jax.random.key(seed)
        self._pool_nodes: list[np.ndarray] = []
        self._pool_lens: list[np.ndarray] = []
        self.stats = IMMStats()

    # -- sampling ----------------------------------------------------------
    def _round(self):
        self.key, sub = jax.random.split(self.key)
        if self.model == "lt":
            s = rr_lt.sample_rrsets_lt(sub, self.g_rev, self.batch, self.qcap)
            nodes, lens = np.asarray(s.nodes), np.asarray(s.lengths)
            overflow = np.asarray(s.overflowed)
            self.stats.sampling_steps += int(s.steps)
        elif self.engine == "queue":
            s = rr_queue.sample_rrsets_queue(sub, self.g_rev, self.batch,
                                             self.qcap, self.ec)
            nodes, lens = np.asarray(s.nodes), np.asarray(s.lengths)
            overflow = np.asarray(s.overflowed)
            self.stats.sampling_steps += int(s.steps)
        elif self.engine == "refill":
            lanes = max(min(self.batch // 4, 256), 8)
            s = rr_queue.sample_rrsets_refill(
                sub, self.g_rev, lanes, quota=self.batch,
                out_cap=min(8 * self.batch // lanes, 64) * 64,
                ec=self.ec)
            rows = rr_queue.refill_to_lists(s)
            width = max(max((len(r) for r in rows), default=1), 1)
            nodes = np.zeros((len(rows), width), np.int64)
            lens = np.zeros(len(rows), np.int64)
            for i, r in enumerate(rows):
                nodes[i, :len(r)] = r
                lens[i] = len(r)
            overflow = np.asarray(s.overflowed)
            self.stats.sampling_steps += int(s.steps)
            self.stats.rounds += 1
            self.stats.n_rr_sampled += len(rows)
            self._pool_nodes.append(nodes)
            self._pool_lens.append(lens)
            self.stats.overflow_fraction = (
                (self.stats.overflow_fraction * (self.stats.rounds - 1)
                 + overflow.mean()) / self.stats.rounds)
            return
        else:
            s = rr_dense.sample_rrsets_dense(sub, self.g_rev, self.batch)
            mem = np.asarray(s.membership)
            lens = mem.sum(axis=1).astype(np.int64)
            width = max(int(lens.max()), 1)
            nodes = np.zeros((self.batch, width), dtype=np.int64)
            for i in range(self.batch):
                nz = np.nonzero(mem[i])[0]
                nodes[i, :len(nz)] = nz
            overflow = np.zeros(self.batch, bool)
            self.stats.sampling_steps += int(s.levels)
        self._pool_nodes.append(nodes)
        self._pool_lens.append(lens)
        self.stats.rounds += 1
        self.stats.n_rr_sampled += self.batch
        self.stats.overflow_fraction = (
            (self.stats.overflow_fraction * (self.stats.rounds - 1)
             + overflow.mean()) / self.stats.rounds)

    def sample_until(self, theta: int):
        while self.stats.n_rr_sampled < theta:
            self._round()

    def _store(self) -> cov.RRStore:
        stores = [cov.build_store((nd, ln), self.n)
                  for nd, ln in zip(self._pool_nodes, self._pool_lens)]
        return cov.merge_stores(stores)

    # -- full IMM ----------------------------------------------------------
    def solve(self, k: int, eps: float, ell: float = 1.0,
              max_theta: Optional[int] = None):
        n = self.n
        lam_p, lam_star, eps_p, _ = imm_theta_params(n, k, eps, ell)
        lb = 1.0
        for i in range(1, max(int(math.log2(n)), 2)):           # Alg. 2
            x = n / (2.0 ** i)
            theta_i = int(math.ceil(lam_p / x))
            if max_theta:
                theta_i = min(theta_i, max_theta)
            self.sample_until(theta_i)
            res = cov.select_seeds(self._store(), k)
            est = n * float(res.frac)
            self.stats.lb_iters = i
            self.stats.history.append(("lb_iter", i, theta_i, est))
            if est >= (1.0 + eps_p) * x:                         # Alg. 2 L7
                lb = est / (1.0 + eps_p)                         # Alg. 2 L8
                break
        theta = int(math.ceil(lam_star / lb))
        if max_theta:
            theta = min(theta, max_theta)
        self.stats.theta = theta
        self.stats.lb = lb
        self.sample_until(theta)
        res = cov.select_seeds(self._store(), k)
        self.stats.frac_covered = float(res.frac)
        spread_est = n * float(res.frac)                         # Eq. (3)
        return np.asarray(res.seeds), spread_est, self.stats


def imm(g: CSRGraph, k: int, eps: float, **kw):
    """One-shot convenience wrapper; returns (seeds, spread_estimate, stats)."""
    solver_kw = {k_: v for k_, v in kw.items()
                 if k_ in ("engine", "batch", "qcap", "ec", "model", "seed")}
    solve_kw = {k_: v for k_, v in kw.items() if k_ in ("ell", "max_theta")}
    solver = IMMSolver(g, **solver_kw)
    return solver.solve(k, eps, **solve_kw)

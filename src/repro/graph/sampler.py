"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` shape.

Produces fixed-shape "blocks" suitable for jit: for each layer l with fanout
f_l, every frontier node samples exactly f_l neighbors *with replacement*
(standard practice when degree < fanout; degree-0 nodes self-loop and are
masked).  Aggregation in the model then runs child -> parent via
``segment_sum`` on ``parent_idx``.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph


class SampledBlock(NamedTuple):
    nodes: jnp.ndarray        # (B_l,) int32 node ids of this layer's frontier
    parent_idx: jnp.ndarray   # (B_l,) int32 index into previous layer's nodes
    mask: jnp.ndarray         # (B_l,) bool — False for padded/self-loop entries


class SampledSubgraph(NamedTuple):
    seeds: jnp.ndarray              # (B,) int32
    blocks: tuple[SampledBlock, ...]  # one per hop, outermost hop last


def sample_neighbors(key, g: CSRGraph, frontier: jnp.ndarray, fanout: int) -> SampledBlock:
    """Sample ``fanout`` in-row neighbors per frontier node, with replacement."""
    deg = (g.offsets[frontier + 1] - g.offsets[frontier]).astype(jnp.int32)
    B = frontier.shape[0]
    r = jax.random.randint(key, (B, fanout), 0, jnp.maximum(deg, 1)[:, None])
    edge_pos = g.offsets[frontier][:, None] + r
    nbrs = jnp.where(deg[:, None] > 0, g.indices[edge_pos], frontier[:, None])
    parent = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, fanout))
    mask = jnp.broadcast_to(deg[:, None] > 0, (B, fanout))
    return SampledBlock(nodes=nbrs.reshape(-1).astype(jnp.int32),
                        parent_idx=parent.reshape(-1),
                        mask=mask.reshape(-1))


def sample_subgraph(key, g: CSRGraph, seeds: jnp.ndarray,
                    fanouts: Sequence[int]) -> SampledSubgraph:
    """Multi-hop fanout sampling, e.g. fanouts=(15, 10) for minibatch_lg."""
    blocks = []
    frontier = seeds.astype(jnp.int32)
    for i, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        blk = sample_neighbors(sub, g, frontier, f)
        blocks.append(blk)
        frontier = blk.nodes
    return SampledSubgraph(seeds=seeds.astype(jnp.int32), blocks=tuple(blocks))

"""Edge influence-probability schemes (paper §4.2).

Weighted Cascade (WC) is the paper's scheme: p_uv = 1 / indeg(v).  Incoming
probabilities then sum to exactly 1 per node, which also makes WC valid under
the LT model (paper §4.2).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, to_edges, from_edges


def wc_weights(g: CSRGraph) -> CSRGraph:
    """Weighted-cascade: p_uv = 1/indeg(v)."""
    src, dst, _ = to_edges(g)
    n = g.n_nodes
    indeg = np.bincount(dst, minlength=n).astype(np.float64)
    w = 1.0 / indeg[dst]
    return from_edges(src, dst, n, weights=w.astype(np.float32), sort=False)


def uniform_weights(g: CSRGraph, p: float | None = None, seed: int = 0) -> CSRGraph:
    """Constant p, or U(0,1) per edge when p is None (cuRipples' scheme)."""
    src, dst, _ = to_edges(g)
    m = src.shape[0]
    if p is None:
        rng = np.random.default_rng(seed)
        w = rng.uniform(size=m).astype(np.float32)
    else:
        w = np.full(m, p, dtype=np.float32)
    return from_edges(src, dst, g.n_nodes, weights=w, sort=False)


def trivalency_weights(g: CSRGraph, seed: int = 0) -> CSRGraph:
    """Random choice of {0.1, 0.01, 0.001} per edge (TRIVALENCY scheme)."""
    src, dst, _ = to_edges(g)
    rng = np.random.default_rng(seed)
    w = rng.choice(np.asarray([0.1, 0.01, 0.001], dtype=np.float32),
                   size=src.shape[0])
    return from_edges(src, dst, g.n_nodes, weights=w, sort=False)

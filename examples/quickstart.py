"""Quickstart: solve influence maximization on a small social graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.graph import csr, generators, weights
from repro.core.imm import imm
from repro.core import forward
from repro.core.engine import list_engines, make_engine


def main():
    # 1. build a scale-free social graph with weighted-cascade probabilities
    src, dst = generators.barabasi_albert(2000, 4, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, 2000))
    print(f"graph: n={g.n_nodes} m={g.n_edges}")

    # 2. run gIM (IMM accelerated by the batched queue engine).  Any name
    #    from the engine registry works here — see DESIGN.md §3.
    print(f"registered engines: {list_engines()}")
    seeds, spread_est, stats = imm(g, k=10, eps=0.35, engine="queue",
                                   batch=512, seed=0)
    print(f"seeds: {sorted(seeds.tolist())}")
    print(f"RIS spread estimate:  {spread_est:8.1f} "
          f"(theta={stats.theta}, rounds={stats.rounds})")

    # 2b. the engine protocol directly: sample one canonical RRBatch
    eng = make_engine("queue", csr.reverse(g), batch=8)
    batch = eng.sample(jax.random.key(0))
    print(f"one RRBatch: {batch.n_sets} sets, "
          f"max size {int(np.asarray(batch.lengths).max())}, "
          f"{int(batch.steps)} micro-steps")

    # 3. validate with forward Monte-Carlo (Kempe-style simulation)
    mc = forward.ic_spread(jax.random.key(7), g, seeds.tolist(), n_sims=512)
    print(f"forward MC spread:    {mc:8.1f}")
    # 4. compare against random seeds
    rnd = np.random.default_rng(0).choice(2000, size=10, replace=False)
    mc_rnd = forward.ic_spread(jax.random.key(8), g, rnd.tolist(),
                               n_sims=512)
    print(f"random-seed spread:   {mc_rnd:8.1f}  "
          f"(gIM advantage {mc / mc_rnd:.2f}x)")


if __name__ == "__main__":
    main()

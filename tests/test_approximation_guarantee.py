"""The paper's core contract: IMM returns a (1-1/e-ε)-approximate seed set.

On a brute-force-solvable graph we enumerate all size-k seed sets, estimate
each spread by forward MC, and check every engine's solution clears the
bound (with MC slack).  This validates the full estimator chain
(θ math + sampling + greedy), not just its pieces.
"""
import itertools

import numpy as np
import jax
import pytest

from repro.graph import csr, generators, weights
from repro.core.imm import imm
from repro.core import forward

N, K, EPS = 24, 2, 0.3


def _graph():
    src, dst = generators.erdos_renyi(N, 96, seed=5)
    return weights.wc_weights(csr.from_edges(src, dst, N))


@pytest.mark.parametrize("engine", ["queue", "dense", "refill"])
def test_imm_clears_approximation_bound(engine):
    g = _graph()
    # brute force: spread of every 2-subset by forward MC
    best, best_set = -1.0, None
    for i, pair in enumerate(itertools.combinations(range(N), K)):
        s = forward.ic_spread(jax.random.key(1000 + i), g, list(pair),
                              n_sims=192)
        if s > best:
            best, best_set = s, pair
    seeds, est, _ = imm(g, K, EPS, engine=engine, batch=128, seed=3)
    got = forward.ic_spread(jax.random.key(7), g, seeds.tolist(),
                            n_sims=2048)
    bound = (1.0 - 1.0 / np.e - EPS) * best
    # 10% slack absorbs the MC noise of `best` and `got`
    assert got >= bound * 0.9, (engine, got, bound, best, best_set)

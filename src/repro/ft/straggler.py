"""Straggler detection + bounded-staleness sampling rounds.

RR sampling is stateless, so straggler mitigation is scheduling, not
recomputation: work is issued in fixed-size rounds; a StepTimer tracks
per-round wall time and flags shards whose round time exceeds
``threshold × median``.  In bounded-staleness mode the driver stops waiting
for flagged shards after ``max_stale`` rounds — correctness is unaffected
because θ counts *arrived* RR sets (the martingale bound needs a count, not a
particular partition of who sampled what).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class StepTimer:
    window: int = 50
    times: list = field(default_factory=list)
    _t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        return dt

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0

    def is_straggler(self, dt: float, threshold: float = 2.0) -> bool:
        return bool(self.times) and dt > threshold * self.median


@dataclass
class ShardMonitor:
    """Tracks per-shard round throughput; flags persistent stragglers."""
    n_shards: int
    threshold: float = 2.0
    rounds: dict = field(default_factory=dict)

    def report(self, shard: int, dt: float):
        self.rounds.setdefault(shard, []).append(dt)

    def stragglers(self) -> list[int]:
        meds = {s: np.median(v) for s, v in self.rounds.items() if v}
        if not meds:
            return []
        overall = np.median(list(meds.values()))
        return [s for s, m in meds.items() if m > self.threshold * overall]

    def work_weights(self) -> np.ndarray:
        """Inverse-latency weights for rebalancing round sizes."""
        w = np.ones(self.n_shards)
        for s, v in self.rounds.items():
            if v:
                w[s] = 1.0 / max(np.median(v), 1e-9)
        return w / w.sum()

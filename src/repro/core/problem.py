"""Declarative IM problem spec: one ``solve(problem)`` surface for every
variant the paper claims the RIS pipeline covers (§variants).

The gIM paper closes on the observation that the same sampling+coverage
pipeline "can solve other variations of the IM problem, only by applying
minor modifications".  :class:`IMProblem` turns each of those modifications
into a declarative knob, and the solver stack (``core/imm.py``,
``core/coverage.py``, ``core/engine.py``) threads them through every layer:

* **plain IM** — ``IMProblem(k=10, eps=0.3)``: uniform roots, top-k greedy.
* **weighted IM** (Cohen et al., sketch-based IM with per-node utilities) —
  ``node_weights=w``: engines draw roots ∝ ``w`` through the shared alias
  table (:func:`repro.core.engine.draw_roots`), so Eq. 3 estimates
  ``Σ_v w_v · P[v influenced]`` and the spread scale becomes ``Σ w``.
* **budgeted IM** — ``costs=c, budget=B`` *replacing* ``k``: cost-ratio lazy
  greedy (argmax of marginal-gain / cost among affordable nodes) until the
  budget is exhausted.
* **candidate-restricted / targeted IM** — ``candidates=mask_or_ids``: the
  greedy argmax only ever picks inside the candidate set.
* **MRIM** (paper §4.8) — ``t_rounds=T``: T round-tagged BFS per sample on
  the ``round * n + node`` item space, per-round seed quota ``k`` (the
  cross-round greedy of CR-NAIMM as a *group-budget* constraint).

``theta=`` pins a fixed RR-pool size (skipping the Alg. 2 LB loop — the
fixed-ε benchmark mode of ``solve_mrim``); ``early_exit=`` gates the LB
escalation on the sketch's linear-counting coverage bound (see
``IMMSolver._early_exit_skip``), provably without changing the final
seeds/θ.

Everything here is host-side spec + validation; no jax imports.  The solver
resolves a problem once per solve into a :class:`ResolvedProblem` carrying
normalized numpy arrays.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, fields
from typing import Any, Optional

import numpy as np


def _as_node_array(x, n: int, name: str, dtype) -> np.ndarray:
    a = np.asarray(x, dtype=dtype)
    if a.shape != (n,):
        raise ValueError(f"{name} must have shape ({n},), got {a.shape}")
    return a


def candidates_mask(candidates, n: int) -> np.ndarray:
    """Normalize a candidate spec (bool mask or iterable of node ids) into
    an (n,) bool mask."""
    a = np.asarray(candidates)
    if a.dtype == bool:
        if a.shape != (n,):
            raise ValueError(f"candidates mask must have shape ({n},), "
                             f"got {a.shape}")
        mask = a.copy()
    else:
        ids = a.astype(np.int64).reshape(-1)
        if ids.size == 0:
            raise ValueError("candidates must be non-empty")
        if (ids < 0).any() or (ids >= n).any():
            raise ValueError(f"candidate ids must lie in [0, {n})")
        mask = np.zeros(n, bool)
        mask[ids] = True
    if not mask.any():
        raise ValueError("candidates must select at least one node")
    return mask


def _digest_value(h: "hashlib._Hash", name: str, value) -> None:
    """Fold one field into a content hash, collision-safely.

    Arrays contribute dtype + shape + raw bytes (two weight vectors with
    equal python ``hash`` of their id, or equal repr, still hash apart);
    scalars contribute their repr; every field is framed by its name and a
    terminator so adjacent fields can never alias.
    """
    h.update(name.encode())
    h.update(b"=")
    if value is None:
        h.update(b"None")
    elif isinstance(value, np.ndarray) or hasattr(value, "__array__") or \
            isinstance(value, (list, tuple)):
        a = np.asarray(value)
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    else:
        h.update(repr(value).encode())
    h.update(b";")


# fields whose value changes what the *sampler* produces (and therefore
# which engine + RR pool a solve needs): the diffusion model picks the
# engine, t_rounds the tagged item space, node_weights the root
# distribution, mode the store species itself (a pool-free sketch store
# can never back an exact solve or vice versa — keying it here separates
# warm-solver registry entries and serving micro-batches in one place).
# Everything else (k, eps, candidates, costs, budget, theta, ...) only
# changes selection / the θ schedule and can share a pool.
_POOL_FIELDS = ("model", "t_rounds", "node_weights", "mode")


@dataclass(frozen=True)
class IMProblem:
    """Declarative influence-maximization problem (see module docstring).

    Exactly one of ``k`` / ``budget`` must be given; ``budget`` implies the
    budgeted variant (``costs`` default to unit costs).  ``t_rounds``
    requires ``k`` (the per-round quota) and is incompatible with
    ``budget``.  ``candidates``/``node_weights``/``costs`` are specified
    over the *base* node space ``[0, n)`` — for MRIM they broadcast across
    rounds.
    """
    k: Optional[int] = None
    eps: float = 0.5
    model: Optional[str] = None        # None = inherit the solver's default
    node_weights: Optional[Any] = None
    costs: Optional[Any] = None
    budget: Optional[float] = None
    candidates: Optional[Any] = None
    t_rounds: Optional[int] = None
    ell: float = 1.0
    max_theta: Optional[int] = None
    theta: Optional[int] = None
    early_exit: bool = False
    mode: str = "exact"

    def __post_init__(self):
        if self.mode not in ("exact", "approximate"):
            raise ValueError(f"unknown mode {self.mode!r}; expected 'exact' "
                             "or 'approximate'")
        if self.mode == "approximate":
            # the pool-free engine scores seeds on row-count sketches only;
            # anything that weights rows or re-reads the pool after
            # sampling (budget ratios, MRIM round tags) needs the exact
            # store.  Candidate restriction is fine — it only masks the
            # sweep.
            if self.node_weights is not None:
                raise ValueError("mode='approximate' does not support "
                                 "node_weights (row-weighted pools need the "
                                 "exact store)")
            if self.budget is not None:
                raise ValueError("mode='approximate' does not support "
                                 "budget= (cost-ratio greedy needs exact "
                                 "marginals)")
            if self.t_rounds is not None:
                raise ValueError("mode='approximate' does not support "
                                 "t_rounds= (MRIM needs the tagged pool)")
        if (self.k is None) == (self.budget is None):
            raise ValueError("exactly one of k= (cardinality) or budget= "
                             "(budgeted IM) must be set")
        if self.k is not None and (not isinstance(self.k, (int, np.integer))
                                   or self.k < 1):
            raise ValueError(f"k must be a positive int, got {self.k!r}")
        if self.budget is not None:
            if self.budget <= 0:
                raise ValueError("budget must be positive")
            if self.t_rounds is not None:
                raise ValueError("budgeted MRIM (budget= with t_rounds=) is "
                                 "not supported; give a per-round k instead")
        if self.costs is not None and self.budget is None:
            raise ValueError("costs= requires budget= (budgeted IM)")
        if self.t_rounds is not None and self.t_rounds < 1:
            raise ValueError("t_rounds must be >= 1")
        if self.model not in (None, "ic", "lt"):
            raise ValueError(f"unknown diffusion model {self.model!r}")
        if self.model == "lt" and self.t_rounds is not None:
            raise ValueError("MRIM sampling is IC-only (paper §4.8)")
        if not (0.0 < self.eps < 1.0):
            raise ValueError("eps must lie in (0, 1)")
        if self.theta is not None and self.theta < 1:
            raise ValueError("theta must be >= 1")

    # -- derived -----------------------------------------------------------
    @property
    def is_plain(self) -> bool:
        """True iff the problem is exactly the historical top-k solve
        (selection and sampling take the untouched fast paths)."""
        return (self.node_weights is None and self.budget is None
                and self.candidates is None and self.t_rounds is None)

    @property
    def variant(self) -> str:
        knobs = []
        if self.node_weights is not None:
            knobs.append("weighted")
        if self.budget is not None:
            knobs.append("budgeted")
        if self.candidates is not None:
            knobs.append("candidates")
        if self.t_rounds is not None:
            knobs.append("mrim")
        return "+".join(knobs) if knobs else "plain"

    # -- canonical signatures ----------------------------------------------
    def signature_digest(self) -> str:
        """Frozen content hash of the *whole* problem — every field, arrays
        by dtype+shape+bytes.  Two problems share a digest iff they are the
        same problem, so this is the result-cache key (``repro.serve``) and
        the base of :meth:`pool_digest`.  Stable across processes (sha256,
        no python ``hash``)."""
        h = hashlib.sha256(b"IMProblem:")
        for f in fields(self):
            _digest_value(h, f.name, getattr(self, f.name))
        return h.hexdigest()

    def pool_digest(self, model: Optional[str] = None, *,
                    graph_digest: Optional[str] = None) -> str:
        """Content hash of the fields that determine the engine + RR pool
        a solve needs (``_POOL_FIELDS``: diffusion model, ``t_rounds``,
        ``node_weights``).  Problems with equal pool digests can share a
        warm solver's sampled pool; ``IMMSolver._prepare`` keys its
        engine/pool lifecycle on this (replacing the ad-hoc tuple key).

        ``model=`` supplies the solver-resolved model when the problem
        leaves ``model=None`` (inherit), so an explicit ``model="ic"`` and
        an inherited ic default share a pool.

        ``graph_digest=`` mixes in the graph's content identity
        (:func:`repro.graph.csr.graph_digest`): an RR pool is a sample of
        one concrete graph, so serving layers that key pools by name must
        also key them by content — a re-registered or delta-mutated graph
        then hashes to a different pool key and can never serve a stale
        pool (``repro.serve``, ``repro.core.stream``).
        """
        h = hashlib.sha256(b"IMPool:")
        vals = {f: getattr(self, f) for f in _POOL_FIELDS}
        if vals["model"] is None:
            vals["model"] = model
        for f in _POOL_FIELDS:
            _digest_value(h, f, vals[f])
        if graph_digest is not None:
            _digest_value(h, "graph", graph_digest)
        return h.hexdigest()

    def resolve(self, n: int) -> "ResolvedProblem":
        """Validate against a concrete graph size and normalize every array
        knob to numpy (weights float32 non-negative, costs float32 positive,
        candidates (n,) bool)."""
        w = None
        if self.node_weights is not None:
            w = _as_node_array(self.node_weights, n, "node_weights",
                               np.float32)
            if (w < 0).any() or not np.isfinite(w).all() or w.sum() <= 0:
                raise ValueError("node_weights must be non-negative, finite, "
                                 "and not all zero")
        costs = None
        if self.budget is not None:
            costs = (_as_node_array(self.costs, n, "costs", np.float32)
                     if self.costs is not None
                     else np.ones(n, np.float32))
            if (costs <= 0).any() or not np.isfinite(costs).all():
                raise ValueError("costs must be positive and finite")
        cand = (candidates_mask(self.candidates, n)
                if self.candidates is not None else None)
        t = self.t_rounds if self.t_rounds is not None else 1
        n_items = n * t
        if self.budget is not None:
            feas_costs = costs[cand] if cand is not None else costs
            affordable = feas_costs[feas_costs <= self.budget]
            if affordable.size == 0:
                raise ValueError("no candidate node is affordable under "
                                 "the given budget")
            # scan-length bound: can never pick more seeds than the budget
            # buys at the cheapest affordable cost (capped at the node set)
            k_steps = int(min(len(affordable),
                              self.budget // float(affordable.min())))
            k_steps = max(k_steps, 1)
        else:
            k_steps = self.k * t
        scale = float(w.sum()) if w is not None else float(n)
        return ResolvedProblem(
            problem=self, n_nodes=n, n_items=n_items, t_rounds=t,
            k_steps=k_steps, node_weights=w, costs=costs, cand_mask=cand,
            scale=scale)


@dataclass(frozen=True)
class ResolvedProblem:
    """An :class:`IMProblem` validated against a graph: normalized arrays
    plus the derived sizes the solver and the selection backends consume."""
    problem: IMProblem
    n_nodes: int
    n_items: int                       # n * t_rounds (the coverage id space)
    t_rounds: int
    k_steps: int                       # selection scan length / max seeds
    node_weights: Optional[np.ndarray]
    costs: Optional[np.ndarray]
    cand_mask: Optional[np.ndarray]    # (n_nodes,) bool over base nodes
    scale: float                       # Eq. 3 spread scale: Σw (or n)

    @property
    def cand_mask_items(self) -> Optional[np.ndarray]:
        """Candidate mask over the (possibly round-tagged) item space."""
        if self.cand_mask is None:
            return None
        return np.tile(self.cand_mask, self.t_rounds)


@dataclass
class IMResult:
    """Typed result of ``IMMSolver.solve(problem)``.

    ``seeds`` are item ids (round-tagged for MRIM — use
    :meth:`seeds_per_round`); ``gains`` are the per-seed marginal coverage
    gains (int32 rows covered, float32 covered weight for weighted
    problems); ``spread`` is the Eq. 3 estimate on the problem's scale
    (``Σ node_weights`` when weighted, else ``n``).  Budgeted solves stop
    early: ``len(seeds)`` is the number of seeds actually afforded and
    ``cost`` their total price.
    """
    seeds: np.ndarray
    spread: float
    gains: np.ndarray
    frac: float
    stats: Any
    problem: IMProblem
    n_nodes: int
    cost: float = 0.0
    # deadline-clipped sketch answer (DESIGN.md §8): seeds picked by
    # certified sketch lower bounds, spread_bounds = (lo, hi) bracketing the
    # true Eq. 3 spread (lo certified from sketch occupancy gains, hi a
    # union bound from the exact Occur histogram).  Exact results keep
    # degraded=False / spread_bounds=None — a degraded answer is labelled,
    # never silently substituted.
    degraded: bool = False
    spread_bounds: Optional[tuple] = None

    def seeds_per_round(self) -> list:
        """MRIM decode: T sorted per-round seed lists (plain problems: one
        list holding all seeds)."""
        t = self.problem.t_rounds or 1
        n = self.n_nodes
        s = np.asarray(self.seeds)
        return [sorted((s[s // n == r] % n).tolist()) for r in range(t)]


# -- checkpoint (de)serialization -------------------------------------------
def problem_state(p: IMProblem) -> dict:
    """json-serializable encoding of an :class:`IMProblem` for pool
    checkpoints.  Arrays round-trip through dtype-tagged nested lists;
    :func:`problem_from_state` rebuilds a problem with an identical
    ``signature_digest``."""
    out = {}
    for f in fields(p):
        v = getattr(p, f.name)
        if v is None or isinstance(v, (bool, int, float, str)):
            out[f.name] = v
        else:
            a = np.asarray(v)
            out[f.name] = {"__array__": True, "dtype": str(a.dtype),
                           "data": a.tolist()}
    return out


def problem_from_state(state: dict) -> IMProblem:
    kw = {}
    for name, v in state.items():
        if isinstance(v, dict) and v.get("__array__"):
            kw[name] = np.asarray(v["data"], dtype=np.dtype(v["dtype"]))
        else:
            kw[name] = v
    return IMProblem(**kw)

from repro.models import layers, attention, moe, transformer, gnn, deepfm, embedding

__all__ = ["layers", "attention", "moe", "transformer", "gnn", "deepfm",
           "embedding"]

"""Greedy max-coverage seed selection (paper Alg. 1 L6-10 / Alg. 7), TPU-adapted.

RR sets are stored exactly like the paper's memory-optimized layout (Alg. 6):
one flat concatenated array ``rr_flat`` plus ``rr_offsets`` (CSR-of-RR).  For
vectorized processing we carry ``rr_ids`` = the row id of every flat element
(the inverse of Offsets_RR), so the Alg. 7 kernel becomes:

  argmax(Occur)                 -> jnp.argmax of the psum-reduced histogram
  per-RR membership scan of u   -> equality scan + segment_max by rr_ids
  Covered flag + decrement      -> mask + segment scatter-sub on Occur

The pool itself is device-resident (:class:`DeviceRRStore`): appends are
jit'd rank-scatters into doubling donated buffers and the fused selection
(:func:`select_seeds_device`) runs on the capacity-padded live buffers, so
the whole IMM hot loop executes under ``jax.transfer_guard("disallow")``.

Distributed mode: RR rows are sharded across devices (each device keeps the
rows it sampled); ``Occur`` is psum-reduced, argmax is replicated math, and
coverage updates stay local — per seed the only collective is one psum(n).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sketch_mod
from repro.core.packing import rank_positions
from repro.kernels.bitset import _popcount


def _ceil_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


class RRStore(NamedTuple):
    """CSR-of-RR.  ``rr_flat[rr_offsets[i]:rr_offsets[i+1]]`` is RR set i."""
    rr_flat: jnp.ndarray     # (T,) int32 node ids (padded tail = n, masked out)
    rr_ids: jnp.ndarray      # (T,) int32 row id per element
    valid: jnp.ndarray       # (T,) bool
    n_rr: int                # number of RR sets
    n_nodes: int


def _compact_padded(nodes, lens, base: int = 0):
    """(B, W) padded rows + lengths -> (flat elements, row ids + base), the
    CSR-of-RR compaction shared by ``build_store`` and the incremental
    store (paper Alg. 6 lines 4-11, vectorized).

    Lengths are clamped to ``[0, W]`` exactly like the device append path
    (:func:`_append_scatter`): an overflowed lane may report its true
    pre-truncation length while ``nodes`` only materializes ``W`` columns —
    without the clamp the element count (masked by width) and the row-id
    count (repeated by raw length) drift apart and the host mirror
    diverges from the device store.
    """
    nodes = np.asarray(nodes)
    lens = np.clip(np.asarray(lens, dtype=np.int64), 0, nodes.shape[1])
    mask = np.arange(nodes.shape[1])[None, :] < lens[:, None]
    flat = nodes[mask].astype(np.int64)
    ids = np.repeat(np.arange(len(lens), dtype=np.int64) + base, lens)
    return flat, ids, lens


def build_store(rr_lists_or_arrays, n: int, pad_to: int | None = None) -> RRStore:
    """Host-side compaction (paper Alg. 6 lines 4-11)."""
    if isinstance(rr_lists_or_arrays, list):
        lens = np.asarray([len(r) for r in rr_lists_or_arrays], dtype=np.int64)
        flat = (np.concatenate([np.asarray(r, dtype=np.int64)
                                for r in rr_lists_or_arrays])
                if lens.sum() else np.zeros(0, np.int64))
        ids = np.repeat(np.arange(len(lens), dtype=np.int64), lens)
    else:  # (nodes (B, Q), lengths (B,)) padded arrays from the samplers
        flat, ids, lens = _compact_padded(*rr_lists_or_arrays)
    t = flat.shape[0]
    t_pad = pad_to if pad_to is not None else t
    if t_pad < t:
        raise ValueError("pad_to smaller than payload")
    valid = np.zeros(t_pad, bool); valid[:t] = True
    flat = np.concatenate([flat, np.full(t_pad - t, n, np.int64)])
    ids = np.concatenate([ids, np.full(t_pad - t, len(lens), np.int64)])
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(ids, jnp.int32),
                   valid=jnp.asarray(valid),
                   n_rr=int(len(lens)), n_nodes=n)


class IncrementalRRStore:
    """Growing CSR-of-RR with amortized-O(1)-per-element ``append_batch``.

    The Alg. 2 LB loop selects seeds after every θ_i escalation; rebuilding
    the store from the per-round pool each time is O(rounds · T) host work
    per selection (O(rounds²) over the loop).  Here each round's batch is
    compacted exactly once into doubling flat/ids buffers, and ``snapshot``
    returns a cached device-resident :class:`RRStore` view (invalidated only
    by the next append).
    """

    def __init__(self, n_nodes: int, capacity: int = 1024):
        self.n_nodes = n_nodes
        self._flat = np.empty(max(capacity, 1), np.int64)
        self._ids = np.empty(max(capacity, 1), np.int64)
        self._t = 0
        self._n_rr = 0
        self._cache: RRStore | None = None

    @property
    def n_rr(self) -> int:
        return self._n_rr

    def _reserve(self, extra: int):
        need = self._t + extra
        if need <= self._flat.shape[0]:
            return
        cap = self._flat.shape[0]
        while cap < need:
            cap *= 2
        for name in ("_flat", "_ids"):
            buf = np.empty(cap, np.int64)
            buf[:self._t] = getattr(self, name)[:self._t]
            setattr(self, name, buf)

    def append_batch(self, batch) -> None:
        """Append one engine batch: an ``RRBatch`` or a ``(nodes, lengths)``
        pair of padded arrays (the ``build_store`` array form).  Rows with
        length 0 are *padding rows* (no RR set — fixed-shape device engine
        paths emit them) and are dropped: they get no row id and do not count
        toward ``n_rr``."""
        nodes, lens = (batch.nodes, batch.lengths) if hasattr(batch, "nodes") \
            else batch
        flat, ids, lens = _compact_padded(nodes, lens)
        row_rank = np.cumsum(lens > 0) - 1           # compact out empty rows
        self._reserve(flat.shape[0])
        self._flat[self._t:self._t + flat.shape[0]] = flat
        self._ids[self._t:self._t + flat.shape[0]] = \
            self._n_rr + row_rank[ids]
        self._t += flat.shape[0]
        self._n_rr += int((lens > 0).sum())
        self._cache = None

    def snapshot(self) -> RRStore:
        if self._cache is None:
            self._cache = RRStore(
                rr_flat=jnp.asarray(self._flat[:self._t], jnp.int32),
                rr_ids=jnp.asarray(self._ids[:self._t], jnp.int32),
                valid=jnp.ones(self._t, bool),
                n_rr=self._n_rr, n_nodes=self.n_nodes)
        return self._cache


# ---------------------------------------------------------------------------
# Device-resident RR pool (paper §3.5 memory layout, kept on-accelerator).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("width",))
def _batch_counts(lens, *, width):
    """(elements, valid rows) of one padded batch, as a (2,) device vector."""
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), width)
    return jnp.stack([lens.sum(dtype=jnp.int32),
                      (lens > 0).sum(dtype=jnp.int32)])


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _append_scatter(flat, ids, valid, t, n_rr, nodes, lens):
    """Rank-scatter one padded batch into the live device buffers, in place.

    All five state operands are donated, so XLA updates the pool buffers
    without a copy; ``t``/``n_rr`` ride along as device scalars.  Element
    ranks are a row-major prefix sum of the validity mask (rows stay
    contiguous, matching the host compaction order exactly); rows with
    length 0 are padding and receive no row id.
    """
    cap = flat.shape[0]
    r, w = nodes.shape
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    fm = mask.reshape(-1)
    dest = t + jnp.cumsum(fm, dtype=jnp.int32) - 1
    dest = jnp.where(fm, dest, cap)                  # OOB -> dropped
    flat = flat.at[dest].set(nodes.reshape(-1).astype(jnp.int32), mode="drop")
    valid = valid.at[dest].set(True, mode="drop")
    row_valid = lens > 0
    rid = n_rr + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
    ids = ids.at[dest].set(
        jnp.broadcast_to(rid[:, None], (r, w)).reshape(-1), mode="drop")
    return (flat, ids, valid, t + fm.sum(dtype=jnp.int32),
            n_rr + row_valid.sum(dtype=jnp.int32))


_PACK = 1 << 15   # packed-append window (elements per DUS write)


@functools.partial(jax.jit, static_argnames=("pack", "n"),
                   donate_argnums=(0, 1, 2, 3, 4))
def _append_packed(flat, ids, valid, t, n_rr, nodes, lens, *, pack, n):
    """Rank-scatter append, packed variant for wide batches.

    XLA:CPU lowers scatter to a serial per-update loop, so the plain
    rank-scatter costs O(R·W) scatter updates even though only
    ``sum(lens)`` elements are real.  Here the valid elements are gathered
    into a ``pack``-wide window first (vectorized binary search over the
    mask prefix sum — log(R·W) gather steps) and written with *contiguous*
    ``dynamic_update_slice`` ops; positions past the batch's element count
    get the virgin-buffer values (sentinel/0/False), which the next append
    overwrites.  Host picks this path whenever R·W ≫ elements ≤ pack.
    """
    r, w = nodes.shape
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    fm = mask.reshape(-1)
    csum = jnp.cumsum(fm.astype(jnp.int32))
    total = csum[-1]
    size = r * w
    src = rank_positions(csum, pack, size)
    jvalid = jnp.arange(1, pack + 1, dtype=jnp.int32) <= total
    fnodes = nodes.reshape(-1).astype(jnp.int32)[src]
    row_valid = lens > 0
    rid = n_rr + jnp.cumsum(row_valid.astype(jnp.int32)) - 1
    upd_flat = jnp.where(jvalid, fnodes, n)
    upd_ids = jnp.where(jvalid, rid[src // w], 0)
    flat = jax.lax.dynamic_update_slice(flat, upd_flat, (t,))
    ids = jax.lax.dynamic_update_slice(ids, upd_ids, (t,))
    valid = jax.lax.dynamic_update_slice(valid, jvalid, (t,))
    return (flat, ids, valid, t + total,
            n_rr + row_valid.sum(dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("newcap", "n"))
def _grow_buffers(flat, ids, valid, *, newcap, n):
    # no donation: the outputs are larger than the inputs, so aliasing is
    # impossible — growth is the one amortized O(cap) device copy

    pad = newcap - flat.shape[0]
    return (jnp.concatenate([flat, jnp.full((pad,), n, jnp.int32)]),
            jnp.concatenate([ids, jnp.zeros((pad,), jnp.int32)]),
            jnp.concatenate([valid, jnp.zeros((pad,), bool)]))


@functools.partial(jax.jit, static_argnames=("num_rows", "n_words"))
def _bitset_from_flat(flat, ids, valid, *, num_rows, n_words):
    """Pack the flat pool into a (num_rows, n_words) membership bit matrix.

    Elements are row-unique (RRBatch contract), so within one (row, word)
    cell every scattered bit is distinct and scatter-add == scatter-or.
    """
    w = jnp.where(valid, flat >> 5, n_words)         # sentinel -> dropped
    bit = jnp.where(
        valid,
        jnp.left_shift(jnp.uint32(1), (flat & 31).astype(jnp.uint32)),
        jnp.uint32(0))
    return jnp.zeros((num_rows, n_words), jnp.uint32).at[
        jnp.clip(ids, 0, num_rows - 1), w].add(bit, mode="drop")


class DeviceRRStore:
    """Growing CSR-of-RR pool that *lives on the accelerator* (DESIGN.md §3).

    The numpy :class:`IncrementalRRStore` pulls every batch to the host and
    re-uploads the pool before each selection — exactly the host
    orchestration the paper's §3.5 layout avoids.  Here ``append_batch`` is
    one jit'd rank-scatter into doubling device buffers (``donate_argnums``
    ⇒ in-place, amortized O(1) growth) and selection runs directly on the
    capacity-padded live buffers, so shapes stay stable across rounds and
    the fused greedy compiles O(log rounds) times instead of every round.

    Host knowledge: the exact element/row counts are mirrored on the host
    via one *explicit* scalar fetch per append (``jax.device_get`` of a (2,)
    vector) — the same per-relaunch ``N_RR`` readback gIM's Alg. 6 host loop
    performs, and the only host↔device traffic an append causes.  Explicit
    transfers are permitted under ``jax.transfer_guard("disallow")``, which
    the IMM driver holds over the whole sampling+selection loop.

    ``snapshot()`` returns a classic :class:`RRStore` view sliced to the
    live extent (device-side slice, no host transfer) for compatibility;
    the fused selection (:func:`select_seeds_device`) bypasses it and reads
    the padded buffers directly.  A snapshot is valid until the next
    ``append_batch`` (donation retires the previous buffers).
    """

    DEFAULT_SKETCH_K = 1024

    def __init__(self, n_nodes: int, capacity: int = 4096,
                 sketch_k: int | None = None, sketch_mode: str = "mod"):
        if n_nodes >= np.iinfo(np.int32).max:
            raise ValueError("item space must fit int32")
        self.n_nodes = n_nodes
        cap = _ceil_pow2(max(capacity, 1))
        self._flat = jnp.full((cap,), n_nodes, jnp.int32)
        self._ids = jnp.zeros((cap,), jnp.int32)
        self._valid = jnp.zeros((cap,), bool)
        self._t_dev = jnp.zeros((), jnp.int32)
        self._nrr_dev = jnp.zeros((), jnp.int32)
        self._t = 0                      # host mirrors (exact)
        self._n_rr = 0
        self._cache: RRStore | None = None
        self._bitset = None              # (num_rows, n_words) cache
        # optional incremental coverage sketch (core/sketch.py): per-node
        # k-bucket hashed row-occupancy, folded in batch by batch
        self.sketch_mode = sketch_mode
        self.sketch_k = (sketch_mod.resolve_sketch_k(sketch_k)
                         if sketch_k is not None else None)
        self._occ = (jnp.zeros((n_nodes + 1, self.sketch_k), bool)
                     if self.sketch_k is not None else None)
        self._sk_words = None            # packed (n+1, k/32) cache

    @property
    def n_rr(self) -> int:
        return self._n_rr

    @property
    def n_elems(self) -> int:
        return self._t

    @property
    def capacity(self) -> int:
        return int(self._flat.shape[0])

    @property
    def n_rr_dev(self):
        """Row count as a device scalar (denominator of F_R under the guard)."""
        return self._nrr_dev

    def append_batch(self, batch) -> None:
        """Compact one batch (``RRBatch`` or ``(nodes, lengths)``) into the
        pool.  Zero-length rows are padding (fixed-shape device engine
        paths emit them) and are dropped."""
        nodes, lens = (batch.nodes, batch.lengths) if hasattr(batch, "nodes") \
            else batch
        nodes = jnp.asarray(nodes)
        lens = jnp.asarray(lens)
        if nodes.ndim != 2 or lens.shape != (nodes.shape[0],):
            raise ValueError("append_batch wants padded (R, W) nodes + (R,) "
                             "lengths")
        elems, rows = (int(x) for x in jax.device_get(
            _batch_counts(lens, width=nodes.shape[1])))
        r, w = nodes.shape
        if self._occ is not None:
            # fold the batch into the coverage sketch *before* the append
            # advances the device row counter (global row ids must match
            # the compaction's)
            self._occ = sketch_mod.sketch_append(
                self._occ, nodes, lens, self._nrr_dev,
                k=self.sketch_k, mode=self.sketch_mode)
        # wide batches (device engine padding ≫ payload) go through the
        # packed append: gather-pack + contiguous writes beat a serial
        # R·W-update scatter by orders of magnitude on CPU
        packed = r * w > _PACK and elems <= _PACK
        need = self._t + (max(elems, _PACK) if packed else elems)
        if need > self.capacity:
            newcap = self.capacity
            while newcap < need:
                newcap *= 2
            self._flat, self._ids, self._valid = _grow_buffers(
                self._flat, self._ids, self._valid,
                newcap=newcap, n=self.n_nodes)
        if packed:
            (self._flat, self._ids, self._valid, self._t_dev,
             self._nrr_dev) = _append_packed(
                self._flat, self._ids, self._valid, self._t_dev,
                self._nrr_dev, nodes, lens, pack=_PACK, n=self.n_nodes)
        else:
            (self._flat, self._ids, self._valid, self._t_dev,
             self._nrr_dev) = _append_scatter(
                self._flat, self._ids, self._valid, self._t_dev,
                self._nrr_dev, nodes, lens)
        self._t += elems
        self._n_rr += rows
        self._cache = None
        self._bitset = None
        self._sk_words = None

    def snapshot(self) -> RRStore:
        """Back-compat :class:`RRStore` view of the live extent (valid until
        the next append)."""
        if self._cache is None:
            t = self._t
            self._cache = RRStore(
                rr_flat=self._flat[:t], rr_ids=self._ids[:t],
                valid=self._valid[:t], n_rr=self._n_rr, n_nodes=self.n_nodes)
        return self._cache

    def row_capacity(self) -> int:
        """Static row bound for the fused selection: next power of two ≥
        n_rr (and ≥ 32 so the Covered bitset packs whole words).  Selection
        recompiles only when this doubles."""
        return max(32, _ceil_pow2(max(self._n_rr, 1)))

    def bitset_matrix(self):
        """(row_capacity, ceil(n/32)) packed membership matrix (cached)."""
        num_rows = self.row_capacity()
        n_words = (self.n_nodes + 31) // 32
        if self._bitset is None or self._bitset.shape != (num_rows, n_words):
            self._bitset = _bitset_from_flat(
                self._flat, self._ids, self._valid,
                num_rows=num_rows, n_words=n_words)
        return self._bitset

    def sketch_words(self, k: int | None = None):
        """Packed (n+1, k/32) uint32 per-node coverage sketch (cached).

        Stores constructed with ``sketch_k`` return the incrementally-built
        sketch; otherwise the sketch is built from the live flat pool on
        demand (one jit'd scatter over the elements).
        """
        if self._occ is not None:
            if k is not None and sketch_mod.resolve_sketch_k(k) != \
                    self.sketch_k:
                raise ValueError(
                    f"store maintains an incremental sketch of k="
                    f"{self.sketch_k}; requested k={k} cannot be honored")
            if self._sk_words is None:
                self._sk_words = sketch_mod.pack_sketch(
                    self._occ, words=self.sketch_k // 32)
            return self._sk_words
        kk = sketch_mod.resolve_sketch_k(k if k is not None
                                         else self.DEFAULT_SKETCH_K)
        if self._sk_words is None or self._sk_words.shape[1] != kk // 32:
            occ = sketch_mod.sketch_from_flat(
                self._flat, self._ids, self._valid,
                n=self.n_nodes, k=kk, mode=self.sketch_mode)
            self._sk_words = sketch_mod.pack_sketch(occ, words=kk // 32)
        return self._sk_words

    def select(self, k: int, method: str = "auto") -> "CoverageResult":
        if method in ("celf", "celf-sketch"):
            return select_seeds_celf(self, k)
        return select_seeds_device(self, k, method=method)


def merge_stores(stores: list[RRStore]) -> RRStore:
    n = stores[0].n_nodes
    flats, ids, valids, base = [], [], [], 0
    for s in stores:
        flats.append(np.asarray(s.rr_flat)[np.asarray(s.valid)])
        ids.append(np.asarray(s.rr_ids)[np.asarray(s.valid)] + base)
        base += s.n_rr
    flat = np.concatenate(flats) if flats else np.zeros(0, np.int64)
    rid = np.concatenate(ids) if ids else np.zeros(0, np.int64)
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(rid, jnp.int32),
                   valid=jnp.ones(flat.shape[0], bool),
                   n_rr=base, n_nodes=n)


def occur_histogram(store: RRStore) -> jnp.ndarray:
    """Occur[n]: #RR sets containing each node (elements are row-unique)."""
    ones = store.valid.astype(jnp.int32)
    return jnp.zeros(store.n_nodes + 1, jnp.int32).at[store.rr_flat].add(
        ones, mode="drop")[:store.n_nodes]


@functools.partial(jax.jit, static_argnames=("n_rr", "n", "k"))
def _greedy(rr_flat, rr_ids, valid, occur0, *, n_rr, n, k):
    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        match = (rr_flat == u) & valid                       # membership scan
        row_has = jax.ops.segment_max(match.astype(jnp.int32), rr_ids,
                                      num_segments=n_rr + 1,
                                      indices_are_sorted=True)[:n_rr] > 0
        newly = row_has & ~covered
        elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
            jnp.clip(rr_ids, 0, n_rr)] & valid
        dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            elem_newly.astype(jnp.int32), mode="drop")[:n]
        occur = occur - dec
        covered = covered | row_has
        gain = newly.sum(dtype=jnp.int32)
        return (occur, covered), (u, gain)

    covered = jnp.zeros(n_rr, bool)
    (occur, covered), (seeds, gains) = jax.lax.scan(
        step, (occur0, covered), None, length=k)
    return seeds, gains, covered


class CoverageResult(NamedTuple):
    seeds: jnp.ndarray    # (k,) int32
    gains: jnp.ndarray    # (k,) int32 — newly covered RR sets per seed
    frac: jnp.ndarray     # () float32 — F_R(S): covered fraction


def select_seeds(store: RRStore, k: int) -> CoverageResult:
    occur0 = occur_histogram(store)
    seeds, gains, covered = _greedy(store.rr_flat, store.rr_ids, store.valid,
                                    occur0, n_rr=store.n_rr,
                                    n=store.n_nodes, k=k)
    frac = gains.sum() / jnp.maximum(store.n_rr, 1)
    return CoverageResult(seeds=seeds, gains=gains, frac=frac.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Fused selection on the device-resident pool (capacity-stable shapes).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def _occur_flat(flat, valid, *, n):
    """Exact Occur histogram over the capacity-padded flat pool."""
    return jnp.zeros(n + 1, jnp.int32).at[flat].add(
        valid.astype(jnp.int32), mode="drop")[:n]


def _unpack_covered(cov_words):
    """(nw,) packed uint32 Covered bitset -> (nw*32,) bool rows."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (((cov_words[:, None] >> shifts[None, :])
             & jnp.uint32(1)) != 0).reshape(cov_words.shape[0] * 32)


def _pack_covered(rows):
    """(nw*32,) bool rows -> (nw,) packed uint32 words."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (rows.reshape(-1, 32).astype(jnp.uint32)
            << shifts[None, :]).sum(axis=1)


def _newly_rows(flat, ids, valid, covered, u):
    """Rows containing ``u`` that are not yet covered — THE membership pass.

    Single shared body for the fused scan step, the CELF exact-eval batch
    (vmapped over candidates) and the CELF commit: the celf==fused parity
    contract hangs on every path computing newly-covered rows identically.
    """
    match = (flat == u) & valid
    row_has = jax.ops.segment_max(match.astype(jnp.int32), ids,
                                  num_segments=covered.shape[0]) > 0
    return row_has & ~covered


@functools.partial(jax.jit, static_argnames=("num_rows", "n", "k"))
def _greedy_fused(flat, ids, valid, n_rr, *, num_rows, n, k):
    """Alg. 7 as ONE scan over the capacity-padded buffers.

    Differences from :func:`_greedy`: operands are the pool's *capacity*
    buffers (shapes change only at doublings, so the LB loop re-selects
    without recompiling), the row count arrives as a device scalar (only the
    F_R denominator needs it), and Covered lives as a packed
    ``(num_rows/32,)`` uint32 bitset — per-seed gains are popcount
    arithmetic on the newly-covered words.  The Occur decrement stays a
    masked scatter over the flat elements: on a sparse pool that is
    O(elements), strictly less work than any dense per-node pass (the
    bit-matrix decrement variant lives in :func:`_greedy_bitset`).
    """
    occur0 = _occur_flat(flat, valid, n=n)

    def step(carry, _):
        occur, cov_words = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        newly = _newly_rows(flat, ids, valid, _unpack_covered(cov_words), u)
        new_words = _pack_covered(newly)
        gain = _popcount(new_words).sum(dtype=jnp.int32)
        elem_newly = newly[jnp.clip(ids, 0, num_rows - 1)] & valid
        dec = jnp.zeros(n + 1, jnp.int32).at[flat].add(
            elem_newly.astype(jnp.int32), mode="drop")[:n]
        return (occur - dec, cov_words | new_words), (u, gain)

    cov0 = jnp.zeros(num_rows // 32, jnp.uint32)
    _, (seeds, gains) = jax.lax.scan(step, (occur0, cov0), None, length=k)
    frac = gains.sum(dtype=jnp.int32) / jnp.maximum(n_rr, 1)
    return seeds, gains, frac.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k",))
def _greedy_bitset(m_words, n_rr, *, k):
    """Alg. 7 on the packed membership matrix, via the Pallas bitset kernels.

    ``occur_from_bitset`` builds Occur as a cross-lane bit-column reduction
    and its row-masked variant computes the per-seed decrement over the
    newly covered rows — popcount arithmetic end to end, no flat scatter.
    Work per seed is O(num_rows · n/32) regardless of sparsity, so this
    path wins when RR sets are dense (mean size ≳ n/32) and the flat pool
    would be larger than the bit matrix; ``select_seeds_device`` picks per
    store.  Membership of the freshly selected seed is a bit-column test.
    """
    from repro.kernels import ops as kops
    num_rows = m_words.shape[0]
    occur0 = kops.occur_from_bitset(m_words)         # (n_words*32,)

    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        col = m_words[:, u >> 5]
        hit = ((col >> (u & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0
        newly = hit & ~covered
        dec = kops.occur_from_bitset_masked(m_words, newly)
        gain = newly.sum(dtype=jnp.int32)
        return (occur - dec, covered | hit), (u, gain)

    covered0 = jnp.zeros(num_rows, bool)
    _, (seeds, gains) = jax.lax.scan(step, (occur0, covered0), None, length=k)
    frac = gains.sum(dtype=jnp.int32) / jnp.maximum(n_rr, 1)
    return seeds, gains, frac.astype(jnp.float32)


def select_seeds_device(store: "DeviceRRStore", k: int,
                        method: str = "auto") -> CoverageResult:
    """Fused greedy selection directly on a :class:`DeviceRRStore`.

    ``method``: ``"flat"`` (scatter decrement, optimal for sparse RR pools),
    ``"bitset"`` (Pallas bit-matrix path, optimal for dense pools), or
    ``"auto"`` — bitset iff the bit matrix is no larger than the flat
    capacity buffers it replaces (i.e. mean RR size ≳ n/32).  Everything
    stays on device; the returned ``frac`` uses the device row count, so the
    call is legal under ``jax.transfer_guard("disallow")``.
    """
    num_rows = store.row_capacity()
    if method == "auto":
        n_words = (store.n_nodes + 31) // 32
        method = "bitset" if num_rows * n_words <= store.capacity else "flat"
    if method == "flat":
        seeds, gains, frac = _greedy_fused(
            store._flat, store._ids, store._valid, store.n_rr_dev,
            num_rows=num_rows, n=store.n_nodes, k=k)
    elif method == "bitset":
        seeds, gains, frac = _greedy_bitset(store.bitset_matrix(),
                                            store.n_rr_dev, k=k)
    else:
        raise ValueError(f"unknown selection method {method!r}")
    return CoverageResult(seeds=seeds, gains=gains, frac=frac)


# ---------------------------------------------------------------------------
# CELF lazy greedy over sketch estimates (third selection backend).
# ---------------------------------------------------------------------------

_EVAL_CHUNK = 8   # broadcast width of one exact-eval pass


@jax.jit
def _celf_eval_batch(flat, ids, valid, cov_words, cands):
    """Exact marginal coverage of C candidates against the covered bitset.

    One jit call evaluates the whole batch: the membership pass (equality
    scan + segment-max, the fused path's inner step) is broadcast over
    ``_EVAL_CHUNK`` candidates at a time under ``lax.map``, so peak memory
    is O(elements · _EVAL_CHUNK) — a *fixed* multiple of the pool,
    independent of ``eval_batch`` (a full (T, C) broadcast would scale the
    pool's footprint with the batch width, fatal exactly in the huge-pool
    regime this backend exists for).  ``cands`` may be padded with -1
    (matches nothing, gain 0).  Shapes are the pool's capacity buffers, so
    the call is capacity-stable like the fused scan.
    """
    covered = _unpack_covered(cov_words)
    c = cands.shape[0]
    pad = (-c) % _EVAL_CHUNK
    cands = jnp.concatenate(
        [cands, jnp.full((pad,), -1, cands.dtype)]) if pad else cands

    def chunk(cs):
        newly = jax.vmap(
            lambda u: _newly_rows(flat, ids, valid, covered, u))(cs)
        return newly.sum(axis=1, dtype=jnp.int32)

    gains = jax.lax.map(chunk, cands.reshape(-1, _EVAL_CHUNK))
    return gains.reshape(-1)[:c]


@jax.jit
def _celf_apply(flat, ids, valid, cov_words, u):
    """Commit seed ``u``: OR its rows into the packed Covered bitset and
    return (new cov_words, exact gain)."""
    newly = _newly_rows(flat, ids, valid, _unpack_covered(cov_words), u)
    new_words = _pack_covered(newly)
    gain = _popcount(new_words).sum(dtype=jnp.int32)
    return cov_words | new_words, gain


def select_seeds_celf(store: "DeviceRRStore", k: int, *,
                      eval_batch: int = 32, use_sketch: bool = True,
                      stats_out: dict | None = None) -> CoverageResult:
    """CELF lazy greedy selection with sketch-first candidate ordering.

    The fused scan pays one full O(elements) pool pass per argmax round.
    Here marginal gains are *lazily* verified: a host priority array holds
    each node's last exact marginal gain (initialized from the exact Occur
    histogram) — a valid upper bound under submodularity — and per seed only
    the candidates that could still win are re-evaluated exactly, in batches
    of ``eval_batch`` via :func:`_celf_eval_batch`.  The per-node coverage
    sketch (``core/sketch.py``) orders that verification: its union-estimate
    Δocc (one Pallas popcount sweep over all nodes) is a certified *lower*
    bound on the marginal gain, so the likeliest winners are verified first
    and acceptance usually triggers on the first pop.

    Correctness is structural, not statistical: a candidate is accepted only
    when its freshly-computed exact gain is ≥ every remaining upper bound
    (ties resolved to the lowest node id, matching ``jnp.argmax``), so the
    returned seeds are *identical* to the fused-scan path for any sketch
    size — the sketch only changes how many exact evaluations happen.  With
    ``sketch_k >= n_rr`` (mod bucketing) the estimates are themselves exact
    and one verification batch per seed suffices.  The (1−1/e−ε) guarantee
    of Alg. 2 is therefore preserved verbatim.

    All device interaction is explicit (``device_put``/``device_get``), so
    the call is legal under ``jax.transfer_guard("disallow")``; shapes are
    the pool's capacity buffers (compiles only at doublings, like the fused
    path) plus the fixed-size sketch.
    """
    n = store.n_nodes
    num_rows = store.row_capacity()
    nw = num_rows // 32
    flat, ids, valid = store._flat, store._ids, store._valid
    c = max(1, min(eval_batch, n))

    ub = np.asarray(jax.device_get(
        _occur_flat(flat, valid, n=n)), dtype=np.int64).copy()
    fresh = np.zeros(n, bool)
    # explicit placement: plain jnp.zeros is an implicit h2d transfer and
    # would trip the solver's transfer_guard("disallow")
    cov_words = jax.device_put(np.zeros(nw, np.uint32))
    if use_sketch:
        sk_words = store.sketch_words()
        cov_sk = jax.device_put(np.zeros(sk_words.shape[1], np.uint32))
    n_evals = 0
    n_eval_calls = 0
    node_ids = np.arange(n)

    def eval_exact(cands):
        nonlocal n_evals, n_eval_calls
        cands = np.asarray(cands, np.int32)
        pad = np.full(c, -1, np.int32)
        pad[:len(cands)] = cands
        g = np.asarray(jax.device_get(_celf_eval_batch(
            flat, ids, valid, cov_words, jax.device_put(pad))))
        ub[cands] = g[:len(cands)]
        fresh[cands] = True
        n_evals += len(cands)
        n_eval_calls += 1

    seeds, gains = [], []
    for _ in range(k):
        fresh[:] = False
        if use_sketch:
            # sketch sweep: Δocc lower bounds for every node in one kernel
            # call; verify the likeliest winners exactly before entering
            # the lazy loop (O(n) top-c selection — eval-batch composition
            # affects only the eval count, never the accepted seed)
            deltas = np.asarray(jax.device_get(
                sketch_mod.union_gains(sk_words, cov_sk)))[:n]
            key = deltas.astype(np.int64) * (n + 1) - node_ids
            eval_exact(np.argpartition(-key, c - 1)[:c])
        while True:
            u = int(np.argmax(ub))       # first max == lowest id on ties
            if fresh[u]:
                break
            # verify the c highest-bound stale candidates, lowest id first
            # on ties (they are the ones that block acceptance).  Composite
            # int64 key keeps this O(n) — ub <= n_rr and id < n both fit
            # int32, so ub*(n+1) - id cannot overflow.  The set always
            # contains the stale argmax, so the loop makes progress.
            stale_idx = node_ids[~fresh]
            cc = min(c, len(stale_idx))
            key = ub[stale_idx] * (n + 1) - stale_idx
            eval_exact(stale_idx[np.argpartition(-key, cc - 1)[:cc]])
        u_dev = jax.device_put(np.int32(u))
        cov_words, gain_dev = _celf_apply(flat, ids, valid, cov_words, u_dev)
        if use_sketch:
            cov_sk = sketch_mod.union_row(cov_sk, sk_words, u_dev)
        gain = int(jax.device_get(gain_dev))
        ub[u] = 0                        # exact: u's rows are now covered
        seeds.append(u)
        gains.append(gain)

    if stats_out is not None:
        stats_out.update(n_exact_evals=n_evals, n_eval_calls=n_eval_calls,
                         sketch_k=(int(store.sketch_words().shape[1]) * 32
                                   if use_sketch else 0),
                         n_rr=store.n_rr)
    frac = sum(gains) / max(store.n_rr, 1)
    return CoverageResult(
        seeds=jax.device_put(np.asarray(seeds, np.int32)),
        gains=jax.device_put(np.asarray(gains, np.int32)),
        frac=jax.device_put(np.float32(frac)))


class PaddedStore(NamedTuple):
    """2D tile layout for the Pallas membership kernel (DESIGN.md §2):
    TPU prefers rectangular VMEM tiles over the GPU's ragged flat array."""
    rows: jnp.ndarray     # (R, L) int32, padded with n
    lengths: jnp.ndarray  # (R,) int32
    n_nodes: int


def build_padded_store(rr_lists, n: int, row_len: int | None = None,
                       pad_rows_to: int = 8) -> PaddedStore:
    lens = np.asarray([len(r) for r in rr_lists], dtype=np.int64)
    l = row_len if row_len is not None else int(max(lens.max(), 1))
    l = ((l + 127) // 128) * 128                       # lane-align
    r = ((len(rr_lists) + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
    rows = np.full((r, l), n, dtype=np.int32)
    for i, rr in enumerate(rr_lists):
        if len(rr) > l:
            raise ValueError("row_len too small")
        rows[i, :len(rr)] = rr
    lengths = np.zeros(r, np.int32)
    lengths[:len(lens)] = lens
    return PaddedStore(rows=jnp.asarray(rows), lengths=jnp.asarray(lengths),
                       n_nodes=n)


def select_seeds_padded(store: PaddedStore, k: int) -> CoverageResult:
    """Greedy selection with the Pallas membership kernel as the Alg. 7 scan.

    One fused ``lax.scan`` over the k seeds (the former per-seed python loop
    unrolled k kernel launches and re-traced per call): the membership scan
    (R×L element compares per seed) runs in the kernel; Covered flags and
    the Occur decrement (scatter-add) stay in XLA, which lowers scatter
    natively on TPU.
    """
    from repro.kernels import ops as kops
    rows, lengths, n = store.rows, store.lengths, store.n_nodes
    r, l = rows.shape
    lane = jnp.arange(l, dtype=jnp.int32)[None, :]
    valid = lane < lengths[:, None]
    occur0 = jnp.zeros(n + 1, jnp.int32).at[rows].add(
        valid.astype(jnp.int32), mode="drop")[:n]

    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        hit = kops.membership_rows(rows, lengths, u)
        newly = hit & ~covered
        dec = jnp.zeros(n + 1, jnp.int32).at[rows].add(
            (valid & newly[:, None]).astype(jnp.int32), mode="drop")[:n]
        return (occur - dec, covered | hit), (u, newly.sum(dtype=jnp.int32))

    _, (seeds, gains) = jax.lax.scan(step, (occur0, jnp.zeros(r, bool)),
                                     None, length=k)
    n_rr = int((lengths > 0).sum())
    return CoverageResult(seeds=seeds, gains=gains,
                          frac=(gains.sum() / jnp.maximum(n_rr, 1)
                                ).astype(jnp.float32))


def shard_stores(per_shard_rr: list[list[list[int]]], n: int) -> RRStore:
    """Stack per-device RR pools into a leading-shard-dim RRStore.

    Pads every shard to the max flat length and max row count so the arrays
    stack; ``n_rr`` becomes rows-per-shard (uniform after padding with empty
    rows, which are never covered and never matched).
    """
    n_shards = len(per_shard_rr)
    rows = max(len(p) for p in per_shard_rr)
    per_shard_rr = [p + [[]] * (rows - len(p)) for p in per_shard_rr]
    stores = [build_store(p, n) for p in per_shard_rr]
    t_max = max(int(s.rr_flat.shape[0]) for s in stores)
    stores = [build_store(p, n, pad_to=t_max) for p in per_shard_rr]
    return RRStore(
        rr_flat=jnp.stack([s.rr_flat for s in stores]),
        rr_ids=jnp.stack([s.rr_ids for s in stores]),
        valid=jnp.stack([s.valid for s in stores]),
        n_rr=rows, n_nodes=n)


# ---------------------------------------------------------------------------
# Distributed (shard_map) variant: RR rows sharded, Occur psum-reduced.
# ---------------------------------------------------------------------------

def select_seeds_sharded(mesh, store_shards, k: int, n: int, axis_names):
    """store_shards: RRStore pytree whose arrays carry a leading shard dim
    equal to the mesh size (one row per device); rr_ids are *local* row ids.
    Per-seed collective cost: one psum over (n,) int32 — see DESIGN.md §4.
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map, pvary

    local_n_rr = store_shards.n_rr  # rows per shard (uniform)

    def local_fn(rr_flat, rr_ids, valid):
        rr_flat, rr_ids, valid = rr_flat[0], rr_ids[0], valid[0]
        occur = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            valid.astype(jnp.int32), mode="drop")[:n]
        occur = jax.lax.psum(occur, axis_names)

        def step(carry, _):
            occur, covered = carry
            u = jnp.argmax(occur).astype(jnp.int32)
            match = (rr_flat == u) & valid
            row_has = jax.ops.segment_max(
                match.astype(jnp.int32), rr_ids,
                num_segments=local_n_rr + 1,
                indices_are_sorted=True)[:local_n_rr] > 0
            newly = row_has & ~covered
            elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
                jnp.clip(rr_ids, 0, local_n_rr)] & valid
            dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
                elem_newly.astype(jnp.int32), mode="drop")[:n]
            occur = occur - jax.lax.psum(dec, axis_names)
            gain = jax.lax.psum(newly.sum(dtype=jnp.int32), axis_names)
            return (occur, covered | row_has), (u, gain)

        covered = pvary(jnp.zeros(local_n_rr, bool), axis_names)
        (_, covered), (seeds, gains) = jax.lax.scan(
            step, (occur, covered), None, length=k)
        return seeds[None], gains[None]

    specs = P(axis_names if isinstance(axis_names, str) else tuple(axis_names))
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(specs, specs, specs),
                   out_specs=(specs, specs))
    seeds, gains = fn(store_shards.rr_flat, store_shards.rr_ids,
                      store_shards.valid)
    return seeds[0], gains[0]

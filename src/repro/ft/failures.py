"""Fault injection + retry policy for the IM pipeline (DESIGN.md §8).

On real hardware a device loss or allocator pressure surfaces as an
``XlaRuntimeError`` (often ``RESOURCE_EXHAUSTED``) out of a jitted call in
the solver hot loop.  The recovery control flow — detect → classify →
backoff → retry from the last *committed* round watermark — is
hardware-independent, so it is what this module implements and what the
tests drive, with :class:`FaultInjector` standing in for the runtime error
at each boundary the real failures cross:

``sample``    the per-round engine sample in ``IMMSolver._round``
``append``    the store append of a sampled batch
``grow``      buffer allocation during the pool's capacity doubling
              (raises :class:`PoolAllocError`, the ``RESOURCE_EXHAUSTED``
              stand-in)
``select``    a selection launch (LB-loop or final)
``executor``  the serving front's batch executor (``repro.serve``)

Injection fires *at the boundary, before any device mutation*, which is
what makes the retry sound: a retried round re-runs with the same subkey
against unchanged buffers, so the fault-free and faulty streams are
bit-identical (the watermark-resume argument of DESIGN.md §8).  A real
error that strikes *mid*-append can leave device buffers ahead of the
host mirrors; that store must never serve again — the serving layer
quarantines it (``WarmSolverRegistry.quarantine``) instead of retrying.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

# the injection boundaries, in hot-loop order
SITES = ("sample", "append", "grow", "select", "executor")


class InjectedFailure(RuntimeError):
    """Transient stand-in for an ``XlaRuntimeError`` at a loop boundary."""


class PoolAllocError(RuntimeError):
    """Stand-in for ``RESOURCE_EXHAUSTED`` during pool capacity growth."""


class DeadlineExceeded(RuntimeError):
    """An in-solve deadline tripped and no degraded answer was possible
    (non-counting objective).  The serving front maps this to its typed
    ``DeadlineExpiredError``."""


def is_transient(e: BaseException) -> bool:
    """Retryable? Injected faults and alloc failures always are; real
    ``XlaRuntimeError``s only when they look like allocator pressure
    (``RESOURCE_EXHAUSTED``), where a retry after freeing memory can
    succeed — anything else propagates."""
    if isinstance(e, (InjectedFailure, PoolAllocError)):
        return True
    return (type(e).__name__ == "XlaRuntimeError"
            and "RESOURCE_EXHAUSTED" in str(e))


@dataclass
class FaultInjector:
    """Deterministic fault source, keyed by injection site.

    ``fail_at`` maps a site to 1-based *occurrence numbers* that fire
    exactly once each (``{"sample": {3}}`` fails the third sample boundary
    crossed); ``rate`` adds seeded Bernoulli chaos per check (scalar or
    per-site dict — the chaos bench's ~10% mode).  ``match`` gates firing
    on the checked context (e.g. only a specific problem — the poisoned
    request of the serving isolation test).  ``max_fires`` bounds total
    fires so bounded-retry loops terminate in chaos runs.
    """
    fail_at: dict = field(default_factory=dict)
    rate: object = 0.0                 # float or {site: float}
    seed: int = 0
    match: Optional[Callable] = None   # (site, ctx) -> bool
    max_fires: Optional[int] = None
    counts: dict = field(default_factory=dict)
    fires: int = 0
    fired_log: list = field(default_factory=list)

    def __post_init__(self):
        bad = set(self.fail_at) - set(SITES)
        if bad:
            raise ValueError(f"unknown injection site(s) {sorted(bad)}; "
                             f"valid sites: {SITES}")
        self.fail_at = {s: set(int(x) for x in v)
                        for s, v in self.fail_at.items()}
        self._rng = random.Random(self.seed)

    def _rate_for(self, site: str) -> float:
        if isinstance(self.rate, dict):
            return float(self.rate.get(site, 0.0))
        return float(self.rate)

    def check(self, site: str, ctx=None) -> None:
        """Count one boundary crossing; raise if this one is configured to
        fail.  ``grow`` raises :class:`PoolAllocError`, every other site
        :class:`InjectedFailure`."""
        self.counts[site] = c = self.counts.get(site, 0) + 1
        if self.match is not None and not self.match(site, ctx):
            return
        if self.max_fires is not None and self.fires >= self.max_fires:
            return
        rate = self._rate_for(site)
        fire = (c in self.fail_at.get(site, ())
                or (rate > 0.0 and self._rng.random() < rate))
        if not fire:
            return
        self.fires += 1
        self.fired_log.append((site, c))
        if site == "grow":
            raise PoolAllocError(
                f"injected RESOURCE_EXHAUSTED at grow crossing #{c}")
        raise InjectedFailure(f"injected failure at {site} crossing #{c}")


@dataclass
class FaultPolicy:
    """Capped-exponential-backoff retry wrapper for the solver hot loop.

    ``run(fn, site)`` checks the injector at the boundary, runs ``fn``, and
    on a transient failure sleeps ``min(cap, base·2^attempt)`` and retries,
    up to ``max_retries`` — each retry re-executes the *same* round/selection
    against the committed store state, so the result stream stays
    bit-identical to a fault-free run.  :class:`PoolAllocError` additionally
    runs the ``on_oom`` hooks first (the serving registry registers
    "evict cold entries" here) before retrying the append, whose growth
    path falls back to a smaller allocation on its own
    (``ShardedDeviceRRStore.append_batch``).

    Counters (``retries``/``oom_recoveries``/``gave_up``/
    ``straggler_rounds``) feed ``ServeStats`` and the chaos bench report.
    """
    injector: Optional[FaultInjector] = None
    max_retries: int = 6
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.25
    sleep: Callable[[float], None] = time.sleep
    on_oom: list = field(default_factory=list)   # zero-arg "free memory" hooks
    round_timer: object = None     # optional ft.straggler.StepTimer
    retries: int = 0
    oom_recoveries: int = 0
    gave_up: int = 0
    straggler_rounds: int = 0

    def check(self, site: str, ctx=None) -> None:
        if self.injector is not None:
            self.injector.check(site, ctx)

    def run(self, fn: Callable, site: str, ctx=None):
        attempt = 0
        while True:
            try:
                self.check(site, ctx)
                return fn()
            except BaseException as e:
                if not is_transient(e):
                    raise
                if isinstance(e, PoolAllocError):
                    freed = False
                    for hook in list(self.on_oom):
                        freed = bool(hook()) or freed
                    if freed:
                        self.oom_recoveries += 1
                attempt += 1
                self.retries += 1
                if attempt > self.max_retries:
                    self.gave_up += 1
                    raise
                self.sleep(min(self.backoff_cap_s,
                               self.backoff_base_s * (2.0 ** (attempt - 1))))

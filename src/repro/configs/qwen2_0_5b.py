"""qwen2-0.5b [arXiv:2407.10671]: 24L d896 14H GQA(kv=2) dff4864 v151936."""
from repro.configs.lm import qwen2_0_5b as full_config, reduced_lm
ARCH_ID = "qwen2-0.5b"
def reduced_config():
    return reduced_lm(full_config())

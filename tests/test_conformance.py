"""Statistical conformance suite: do the engines sample the right law?

Structural tests (root-first, uniqueness, reachability) cannot see a biased
sampler that emits *valid but wrongly distributed* RR sets — e.g. a dedup
micro-step that double-counts a multi-edge, or a refill lane that discards
in-flight sets (size-biased).  Here every registered engine's RR-set *size
distribution* is compared against the serial numpy oracle with a two-sample
Kolmogorov-Smirnov test on small fixed-RNG graphs.

KS on integer sizes is conservative (ties can only shrink the statistic),
so ``p > 0.01`` is a sound acceptance bar; a deliberately mismatched pair
(IC sizes vs LT sizes) is kept as a power control so the suite cannot pass
vacuously.  Engines and oracle use independent RNGs — this is a two-sample
test of laws, not a replay test.

Also here: deterministic conformance of the sampler micro-step rebuild —
segmented chunk dedup vs the sort fallback vs a dense reference on
adversarial duplicate patterns, and ``coalesce_ic`` probability equivalence
(the hypothesis-based twins live in test_properties.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from scipy import stats as sps

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import oracle, rrset
from repro.core.engine import make_engine

P_MIN = 0.01
N_SIZES = 320


def _graph(n=30, m=150, seed=2):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _engine_sizes(name, g_rev, count, *, key_seed=0, **opts):
    eng = make_engine(name, g_rev, **opts)
    sizes = []
    i = 0
    while len(sizes) < count:
        b = eng.sample(jax.random.key(key_seed + i))
        lens = np.asarray(b.lengths)
        sizes += lens[lens > 0].tolist()
        i += 1
    return np.asarray(sizes[:count])


def _oracle_sizes_ic(g_rev, count, seed=1):
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    return np.asarray([
        len(oracle.rr_set_ic(offs, idx, w, int(rng.integers(n)), rng))
        for _ in range(count)])


def _oracle_sizes_lt(g_rev, count, seed=1):
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    return np.asarray([
        len(oracle.rr_set_lt(offs, idx, w, int(rng.integers(n)), rng))
        for _ in range(count)])


def _oracle_sizes_mrim(g_rev, count, t_rounds, seed=1):
    """MRIM law: one shared root, T independent IC BFS, tagged union size ==
    sum of the per-round sizes (tags make all elements distinct)."""
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    out = []
    for _ in range(count):
        root = int(rng.integers(n))
        out.append(sum(len(oracle.rr_set_ic(offs, idx, w, root, rng))
                       for _ in range(t_rounds)))
    return np.asarray(out)


# ----------------------------------------------- KS suite: all six engines

@pytest.mark.parametrize("engine", ("queue", "dense", "refill",
                                    "queue_sharded"))
def test_ks_ic_engines_match_oracle(engine):
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes(engine, g_rev, N_SIZES, batch=64)
    ref = _oracle_sizes_ic(g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (engine, res, sizes.mean(), ref.mean())


def test_ks_lt_engine_matches_oracle():
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes("lt", g_rev, N_SIZES, batch=64)
    ref = _oracle_sizes_lt(g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (res, sizes.mean(), ref.mean())


def test_ks_mrim_engine_matches_oracle():
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes("mrim", g_rev, N_SIZES, batch=32, t_rounds=2)
    ref = _oracle_sizes_mrim(g_rev, N_SIZES, t_rounds=2)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (res, sizes.mean(), ref.mean())


@pytest.mark.parametrize("engine,model", (("queue", "ic"), ("lt", "lt")))
def test_ks_second_graph(engine, model):
    """Same laws on a denser second topology (BA attachment)."""
    src, dst = generators.barabasi_albert(40, 3, seed=7)
    g_rev = csr_mod.reverse(
        weights.wc_weights(csr_mod.from_edges(src, dst, 40)))
    sizes = _engine_sizes(engine, g_rev, N_SIZES, batch=64)
    ref = (_oracle_sizes_ic if model == "ic" else _oracle_sizes_lt)(
        g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (engine, res, sizes.mean(), ref.mean())


def test_ks_power_control_rejects_wrong_law():
    """The suite must be able to fail: IC BFS sizes vs LT walk sizes on the
    same graph are different laws and KS must reject them."""
    g_rev = csr_mod.reverse(_graph())
    ic = _oracle_sizes_ic(g_rev, N_SIZES, seed=3)
    lt = _oracle_sizes_lt(g_rev, N_SIZES, seed=4)
    res = sps.ks_2samp(ic, lt)
    assert res.pvalue < P_MIN, res


# ---------------------- spread-estimate conformance (Eq. 3, per engine)
#
# The KS suite above checks RR-set *size* laws; an engine can pass it while
# biasing *membership* (which nodes land in a set).  Eq. 3 turns membership
# into the spread estimate sigma_hat(S) = n * Pr[S hits a random RR set], so
# here every engine's hit-fraction for a fixed seed set is compared against
# an independent oracle sampler with a two-sample Bernoulli concentration
# bound (5 sigma of the pooled standard error — deterministic with fixed
# RNGs, false-alarm probability < 1e-6 per test).

SPREAD_T = 1024
SPREAD_SIGMA = 5.0


def _fixed_seed_set(g_rev, size=3):
    """Deterministic seed set (top row-degree of the reverse graph) — any
    fixed set works for the two-sample bound; this one just guarantees a
    hit fraction away from 0."""
    deg = np.diff(np.asarray(g_rev.offsets))
    return np.argsort(-deg, kind="stable")[:size].tolist()


def _engine_hit_fraction(name, g_rev, seed_set, count, *, key_seed=500,
                         **opts):
    """Fraction of engine-sampled RR sets intersecting ``seed_set``."""
    eng = make_engine(name, g_rev, **opts)
    s = np.asarray(seed_set)
    hits = total = 0
    i = 0
    while total < count:
        b = eng.sample(jax.random.key(key_seed + i))
        i += 1
        nodes, lens = np.asarray(b.nodes), np.asarray(b.lengths)
        mask = np.arange(nodes.shape[1])[None, :] < \
            np.clip(lens, 0, nodes.shape[1])[:, None]
        x = (np.isin(nodes, s) & mask).any(axis=1)
        keep = lens > 0
        take = min(int(keep.sum()), count - total)
        hits += int(x[keep][:take].sum())
        total += take
    return hits / count


def _oracle_hit_fraction(g_rev, seed_set, count, *, model="ic", seed=901):
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    sampler = oracle.rr_set_ic if model == "ic" else oracle.rr_set_lt
    s = set(seed_set)
    hits = 0
    for _ in range(count):
        rr = sampler(offs, idx, w, int(rng.integers(n)), rng)
        hits += bool(s & set(rr))
    return hits / count


def _assert_within_concentration(p1, t1, p2, t2, label):
    pool = (p1 * t1 + p2 * t2) / (t1 + t2)
    se = np.sqrt(max(pool * (1.0 - pool), 1e-12) * (1.0 / t1 + 1.0 / t2))
    assert abs(p1 - p2) <= SPREAD_SIGMA * se + 1e-12, \
        (label, p1, p2, se, abs(p1 - p2) / max(se, 1e-12))
    return se


@pytest.mark.parametrize("engine", ("queue", "dense", "refill",
                                    "queue_sharded"))
def test_spread_estimate_ic_engines_within_concentration(engine):
    g_rev = csr_mod.reverse(_graph())
    seed_set = _fixed_seed_set(g_rev)
    p_e = _engine_hit_fraction(engine, g_rev, seed_set, SPREAD_T, batch=64)
    p_o = _oracle_hit_fraction(g_rev, seed_set, SPREAD_T, model="ic")
    _assert_within_concentration(p_e, SPREAD_T, p_o, SPREAD_T, engine)


def test_spread_estimate_lt_engine_within_concentration():
    g_rev = csr_mod.reverse(_graph())
    seed_set = _fixed_seed_set(g_rev)
    p_e = _engine_hit_fraction("lt", g_rev, seed_set, SPREAD_T, batch=64)
    p_o = _oracle_hit_fraction(g_rev, seed_set, SPREAD_T, model="lt")
    _assert_within_concentration(p_e, SPREAD_T, p_o, SPREAD_T, "lt")


def test_spread_estimate_mrim_within_concentration():
    """MRIM spread law on the tagged item space: a (node, round) seed set
    hits a sample iff round r's BFS from the shared root reaches the node —
    engine fraction vs an oracle running T tagged BFS per sample."""
    t_rounds = 2
    g_rev = csr_mod.reverse(_graph())
    base = _fixed_seed_set(g_rev)
    n = g_rev.n_nodes
    tagged = [0 * n + base[0], 0 * n + base[1], 1 * n + base[2]]
    p_e = _engine_hit_fraction("mrim", g_rev, tagged, SPREAD_T,
                               batch=32, t_rounds=t_rounds)
    rng = np.random.default_rng(903)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    s = set(tagged)
    hits = 0
    for _ in range(SPREAD_T):
        root = int(rng.integers(n))
        enc = set()
        for r in range(t_rounds):
            enc |= {r * n + v
                    for v in oracle.rr_set_ic(offs, idx, w, root, rng)}
        hits += bool(s & enc)
    _assert_within_concentration(p_e, SPREAD_T, hits / SPREAD_T, SPREAD_T,
                                 "mrim")


def test_spread_estimate_anchor_vs_forward_mc():
    """Absolute anchor: the oracle RIS estimate n*p (Eq. 3) agrees with a
    forward Monte-Carlo spread of the same seed set, pinning the *scale* of
    every estimate above (per-simulation spread lies in [0, n], so the MC
    standard error is bounded by n / (2 sqrt(sims)))."""
    from repro.core import forward
    g = _graph()
    g_rev = csr_mod.reverse(g)
    n = g.n_nodes
    seed_set = _fixed_seed_set(g_rev)
    t = 1536
    p_o = _oracle_hit_fraction(g_rev, seed_set, t, model="ic", seed=905)
    sims = 3072
    mc = forward.ic_spread(jax.random.key(7), g, seed_set, n_sims=sims)
    se_ris = n * np.sqrt(max(p_o * (1 - p_o), 1e-12) / t)
    se_mc = n / (2.0 * np.sqrt(sims))
    assert abs(n * p_o - mc) <= SPREAD_SIGMA * (se_ris + se_mc), \
        (n * p_o, mc, se_ris, se_mc)


def test_spread_estimate_power_control_rejects_weak_seed_set():
    """The concentration bound must be able to fail: the hit fraction of
    the most influential seed set (top *out*-degree of the forward graph —
    RR sets are reverse-reachable, so out-edges drive membership) vs the
    least influential one must differ by far more than the two-sample
    bound."""
    g = _graph()
    g_rev = csr_mod.reverse(g)
    deg = np.diff(np.asarray(g.offsets))             # forward out-degree
    strong = np.argsort(-deg, kind="stable")[:3].tolist()
    weak = np.argsort(deg, kind="stable")[:3].tolist()
    p_s = _oracle_hit_fraction(g_rev, strong, SPREAD_T, model="ic", seed=907)
    p_w = _oracle_hit_fraction(g_rev, weak, SPREAD_T, model="ic", seed=908)
    pool = (p_s + p_w) / 2
    se = np.sqrt(max(pool * (1 - pool), 1e-12) * (2.0 / SPREAD_T))
    assert abs(p_s - p_w) > SPREAD_SIGMA * se, (p_s, p_w, se)


# ------------------- weighted / budgeted variant conformance (ISSUE 5)
#
# Weighted IM draws RR roots ∝ node_weights through the engines' shared
# alias table; Eq. 3 then estimates the *weighted* spread
# Σ_v w_v·P[v influenced] = W · Pr[S hits a weighted-root RR set].  The
# tests hold the weighted sampler to the same standards as the plain one:
# a two-sample 5-sigma concentration check against an independent
# weighted-root oracle sampler, and an absolute anchor against a
# weight-aware forward Monte-Carlo spread.  Budgeted selection is checked
# deterministically against the numpy cost-ratio greedy on the same pool.

def _oracle_hit_fraction_weighted(g_rev, seed_set, count, node_w, *,
                                  seed=911):
    """Oracle hit fraction with roots drawn ∝ node_w (numpy choice)."""
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    p = np.asarray(node_w, np.float64)
    p = p / p.sum()
    s = set(seed_set)
    hits = 0
    for _ in range(count):
        rr = oracle.rr_set_ic(offs, idx, w, int(rng.choice(len(p), p=p)),
                              rng)
        hits += bool(s & set(rr))
    return hits / count


def test_weighted_root_engines_match_weighted_oracle():
    """Engine hit fractions under weight-proportional root sampling agree
    with the independent weighted-root oracle (5-sigma two-sample bound)
    for the queue and dense engines."""
    g_rev = csr_mod.reverse(_graph())
    n = g_rev.n_nodes
    node_w = (np.arange(n) % 5 + 1).astype(np.float32)
    seed_set = _fixed_seed_set(g_rev)
    p_o = _oracle_hit_fraction_weighted(g_rev, seed_set, SPREAD_T, node_w)
    for engine in ("queue", "dense"):
        p_e = _engine_hit_fraction(engine, g_rev, seed_set, SPREAD_T,
                                   batch=64, root_weights=node_w)
        _assert_within_concentration(p_e, SPREAD_T, p_o, SPREAD_T,
                                     f"weighted-{engine}")


def test_weighted_spread_anchor_vs_weight_aware_forward_mc():
    """Absolute anchor for the weighted estimator: W · Pr[S hits a
    weighted-root RR set] agrees with the weight-aware forward Monte-Carlo
    spread E[Σ_{v∈I(S)} w_v] (per-simulation spread lies in [0, W], so the
    MC standard error is bounded by W / (2 sqrt(sims)))."""
    g = _graph()
    g_rev = csr_mod.reverse(g)
    n = g.n_nodes
    node_w = (np.arange(n) % 5 + 1).astype(np.float64)
    W = float(node_w.sum())
    seed_set = _fixed_seed_set(g_rev)
    t = 1536
    p_o = _oracle_hit_fraction_weighted(g_rev, seed_set, t, node_w, seed=913)
    sims = 3072
    rng = np.random.default_rng(915)
    mc = oracle.forward_ic_spread(
        np.asarray(g.offsets), np.asarray(g.indices),
        np.asarray(g.weights), seed_set, rng, n_sims=sims,
        node_weights=node_w)
    se_ris = W * np.sqrt(max(p_o * (1 - p_o), 1e-12) / t)
    se_mc = W / (2.0 * np.sqrt(sims))
    assert abs(W * p_o - mc) <= SPREAD_SIGMA * (se_ris + se_mc), \
        (W * p_o, mc, se_ris, se_mc)


def test_budgeted_selection_matches_numpy_cost_ratio_reference():
    """Budgeted greedy (cost-ratio lazy greedy in the variant backends) ==
    the serial numpy reference on the identical RR pool, and never
    overspends."""
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem
    g = _graph(n=40, m=200, seed=5)
    rng = np.random.default_rng(7)
    costs = rng.integers(1, 5, 40).astype(np.float32)
    budget = 6.0
    solver = IMMSolver(g, batch=64, seed=11)
    res = solver.solve(IMProblem(eps=0.5, theta=768, costs=costs,
                                 budget=budget))
    snap = solver.store.snapshot()
    flat = np.asarray(snap.rr_flat)[np.asarray(snap.valid)]
    ids = np.asarray(snap.rr_ids)[np.asarray(snap.valid)]
    rr = [flat[ids == i].tolist() for i in range(snap.n_rr)]
    ref_seeds, ref_frac, ref_spent = oracle.budgeted_greedy_cost_ratio(
        rr, 40, costs, budget)
    assert res.seeds.tolist() == ref_seeds
    assert res.frac == pytest.approx(ref_frac, abs=1e-6)
    assert res.cost == pytest.approx(ref_spent) and res.cost <= budget


# ------------------ 8-fake-device variant parity (subprocess, ISSUE 5)

VARIANT_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.graph import csr as csr_mod, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem

assert len(jax.devices()) == 8
mesh8 = Mesh(np.asarray(jax.devices()), ("samples",))
src, dst = generators.erdos_renyi(60, 300, seed=6)
g = weights.wc_weights(csr_mod.from_edges(src, dst, 60))
# integer-valued weights/costs: float32 partial sums are exact, so the
# psum association difference between mesh sizes cannot flip a bit
w = (np.arange(60) % 8 + 1).astype(np.float32)
costs = (np.arange(60) % 4 + 1).astype(np.float32)
problems = {
    "weighted": IMProblem(k=4, eps=0.5, max_theta=256, node_weights=w),
    "budgeted": IMProblem(eps=0.5, max_theta=256, costs=costs, budget=6.0),
    "candidates": IMProblem(k=4, eps=0.5, max_theta=256,
                            candidates=np.arange(0, 60, 2)),
    "mrim": IMProblem(k=2, t_rounds=2, theta=256),
}
for name, problem in problems.items():
    res = {}
    for mesh in (None, mesh8):
        solver = IMMSolver(g, engine="queue", batch=64, seed=3, mesh=mesh)
        solver.prepare(problem)
        with jax.transfer_guard("disallow"):
            r = solver.solve(problem)
        res[r.stats.pool_sharding] = (r.seeds.tolist(),
                                      np.asarray(r.gains).tolist(),
                                      round(float(r.spread), 6),
                                      round(float(r.cost), 6))
    assert res["samples:1"] == res["samples:8"], (name, res)
    print("OK", name, res["samples:8"][0])
print("ALL-OK")
"""


def test_variant_solves_bit_identical_across_mesh_sizes():
    """Weighted/budgeted/candidate/MRIM solves on a forced 8-way host mesh
    return seeds/gains/spread/cost bit-identical to the 1-device mesh,
    under the transfer guard (device count is locked at first jax init, so
    this runs in a subprocess like the plain-parity suite)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", VARIANT_PARITY_SCRIPT],
                       env=env, capture_output=True, text=True,
                       cwd="/root/repo", timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "ALL-OK" in r.stdout


# ------------------------------- micro-step conformance (deterministic)

def _dense_first_occurrence(nbr, cand):
    """O(EC^2) reference: j accepted iff it is the first candidate position
    in its lane carrying nbr[b, j] (the historical dense mask)."""
    b, ec = nbr.shape
    out = np.zeros_like(cand)
    for i in range(b):
        seen = set()
        for j in range(ec):
            if cand[i, j] and nbr[i, j] not in seen:
                out[i, j] = True
                seen.add(nbr[i, j])
    return out


def _adversarial_chunks(rng, b=8, ec=32, n=16):
    """Duplicate-heavy chunk: long runs of repeated destinations."""
    reps = []
    for _ in range(b):
        row, v = [], 0
        while len(row) < ec:
            run = int(rng.integers(1, 6))
            row += [v] * run
            v += int(rng.integers(0, 2))     # sometimes repeat across runs
        reps.append(row[:ec])
    nbr = np.asarray(reps, np.int32) % n
    cand = rng.random((b, ec)) < 0.6
    return nbr, cand


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_dedup_segmented_matches_sort_and_dense_reference(seed):
    rng = np.random.default_rng(seed)
    nbr_np, cand_np = _adversarial_chunks(rng)
    # segmented mode requires duplicates adjacent: runs are sorted per row
    order = np.argsort(nbr_np, axis=1, kind="stable")
    nbr_np = np.take_along_axis(nbr_np, order, axis=1)
    cand_np = np.take_along_axis(cand_np, order, axis=1)
    nbr, cand = jnp.asarray(nbr_np), jnp.asarray(cand_np)
    ar = jnp.arange(nbr.shape[1], dtype=jnp.int32)
    ref = _dense_first_occurrence(nbr_np, cand_np)
    seg = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="segmented"))
    srt = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="sort"))
    np.testing.assert_array_equal(seg, ref)
    np.testing.assert_array_equal(srt, ref)


def test_dedup_sort_handles_unsorted_chunks():
    rng = np.random.default_rng(3)
    nbr_np, cand_np = _adversarial_chunks(rng)    # NOT sorted: runs shuffled
    perm = rng.permutation(nbr_np.shape[1])
    nbr_np, cand_np = nbr_np[:, perm], cand_np[:, perm]
    nbr, cand = jnp.asarray(nbr_np), jnp.asarray(cand_np)
    ar = jnp.arange(nbr.shape[1], dtype=jnp.int32)
    srt = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="sort"))
    np.testing.assert_array_equal(srt, _dense_first_occurrence(nbr_np,
                                                               cand_np))


def test_coalesce_probability_equivalence_random_multigraph():
    """p' = 1 - prod(1 - p_i) for every parallel-edge group, and coalescing
    is idempotent (deterministic twin of the hypothesis property)."""
    rng = np.random.default_rng(6)
    n, m = 12, 160
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32) * 0.9
    g = csr_mod.from_edges(src, dst, n, weights=w)
    gc = csr_mod.coalesce_ic(g)
    s2, d2, w2 = csr_mod.to_edges(gc)
    got = dict(zip(zip(s2.tolist(), d2.tolist()), w2.tolist()))
    expect = {}
    for u, v, p in zip(src.tolist(), dst.tolist(), w.tolist()):
        expect[(u, v)] = 1.0 - (1.0 - expect.get((u, v), 0.0)) * (1.0 - p)
    assert set(got) == set(expect)
    for key in expect:
        assert got[key] == pytest.approx(expect[key], abs=1e-6), key
    assert csr_mod.coalesce_ic(gc) is gc            # idempotent, same object
    assert rrset.detect_dedup_mode(gc) == "none"


# ------------------- streaming incremental re-solve (DESIGN.md §9, ISSUE 8)
#
# resolve_incremental keeps every RR row the deltas provably never touched
# and tops θ back up on the post-delta graph.  Survivors are exact
# post-delta samples *conditioned* on avoiding the changed reverse rows, so
# the merged pool's law carries a residual term α·(law(·|A^c) − law) that
# shrinks with the delta footprint — these tests police it empirically:
# the merged pool must be KS-indistinguishable from the post-delta oracle
# size law, and its Eq. 3 hit fraction must sit within the 5σ two-sample
# bound of a cold post-delta solve's pool.

def _pool_rows(solver):
    snap = solver.store.snapshot()
    flat = np.asarray(jax.device_get(snap.rr_flat))
    ids = np.asarray(jax.device_get(snap.rr_ids))
    valid = np.asarray(jax.device_get(snap.valid))
    return flat[valid], ids[valid], snap.n_rr


def _pool_hit_fraction(flat, ids, n_rr, seed_set):
    return np.unique(ids[np.isin(flat, np.asarray(seed_set))]).size / n_rr


def test_streaming_incremental_resolve_matches_cold_post_delta_law():
    """Small-footprint delta (the documented operating regime): the merged
    pool is KS-indistinguishable from a cold post-delta pool, and the
    Eq. 3 hit fraction / solved spread agree to the 5σ two-sample bound."""
    from repro.core import stream
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem
    g = _graph()
    p = IMProblem(k=3, theta=SPREAD_T)
    inc = IMMSolver(g, engine="queue", batch=64, seed=21)
    inc.solve(p)

    # frontier = the least-frequent member of the solver's own pre-delta
    # pool, so P[row touches the frontier] — the bias scale — is minimal
    flat0, ids0, n0 = _pool_rows(inc)
    memb = np.array([np.unique(ids0[flat0 == v]).size
                     for v in range(g.n_nodes)])
    deltas = stream.make_deltas(adds=([3], [int(np.argmin(memb))], [0.3]))

    res_inc = inc.resolve_incremental(p, deltas)
    info = inc.last_incremental
    assert info["reused"] is True
    assert info["surviving_fraction"] > 0.85     # the reuse is real
    assert len(res_inc.seeds) == 3

    new_g = stream.apply_edge_deltas(g, deltas)
    assert csr_mod.graph_digest(inc.g) == csr_mod.graph_digest(new_g)
    new_rev = csr_mod.reverse(new_g)

    # KS: merged (survivors + top-up) pool sizes vs a cold post-delta
    # solve's pool sizes (independent RNG stream)
    cold = IMMSolver(new_g, engine="queue", batch=64, seed=77)
    res_cold = cold.solve(p)
    flat, ids, n_rr = _pool_rows(inc)
    flat_c, ids_c, n_c = _pool_rows(cold)
    sizes = np.bincount(ids, minlength=n_rr)
    sizes_c = np.bincount(ids_c, minlength=n_c)
    res = sps.ks_2samp(sizes, sizes_c)
    assert res.pvalue > P_MIN, (res, sizes.mean(), sizes_c.mean())

    # 5σ: Eq. 3 hit fraction of a fixed seed set, and the solved spreads
    # (spread is n · hit-fraction of the returned seeds)
    seed_set = _fixed_seed_set(new_rev)
    p_inc = _pool_hit_fraction(flat, ids, n_rr, seed_set)
    p_cold = _pool_hit_fraction(flat_c, ids_c, n_c, seed_set)
    _assert_within_concentration(p_inc, n_rr, p_cold, n_c, "streaming")
    n = new_g.n_nodes
    _assert_within_concentration(res_inc.spread / n, n_rr,
                                 res_cold.spread / n, n_c,
                                 "streaming-spread")


def test_streaming_residual_bias_within_documented_bound():
    """Larger delta footprint: the merged pool's law is *allowed* to drift
    from the cold law by the conditioning term — but no further.  DESIGN.md
    §9's bound is TV(merged, law) ≤ β·P[row touches frontier] (β = kept
    fraction), so the KS statistic must stay under that bound plus the
    two-sample noise quantile; a cold pool meanwhile must match the serial
    post-delta oracle outright (control: the sampler itself is unbiased)."""
    from repro.core import stream
    from repro.core.imm import IMMSolver
    from repro.core.problem import IMProblem
    g = _graph()
    indeg = np.diff(np.asarray(csr_mod.reverse(g).offsets))
    lo = np.argsort(indeg, kind="stable")[:2]
    s0, d0, _ = csr_mod.to_edges(g)
    j = int(np.argmin(indeg[d0]))
    deltas = stream.make_deltas(
        adds=([3, 12], [int(lo[0]), int(lo[1])], [0.35, 0.5]),
        removes=([int(s0[j])], [int(d0[j])]))
    aff = stream.affected_nodes(deltas)
    p = IMProblem(k=3, theta=SPREAD_T)

    inc = IMMSolver(g, engine="queue", batch=64, seed=21)
    inc.solve(p)
    inc.resolve_incremental(p, deltas)
    beta = inc.last_incremental["surviving_fraction"]
    assert inc.last_incremental["reused"] is True

    new_g = stream.apply_edge_deltas(g, deltas)
    cold = IMMSolver(new_g, engine="queue", batch=64, seed=77)
    cold.solve(p)
    flat, ids, n_rr = _pool_rows(inc)
    flat_c, ids_c, n_c = _pool_rows(cold)

    # control: the cold pool matches the serial post-delta oracle
    sizes_c = np.bincount(ids_c, minlength=n_c)
    ref = _oracle_sizes_ic(csr_mod.reverse(new_g), n_c, seed=31)
    res = sps.ks_2samp(sizes_c, ref)
    assert res.pvalue > P_MIN, (res, sizes_c.mean(), ref.mean())

    # policed bound: merged-vs-cold KS ≤ β·P(touch) + noise quantile
    sizes = np.bincount(ids, minlength=n_rr)
    d_obs = sps.ks_2samp(sizes, sizes_c).statistic
    p_touch = _pool_hit_fraction(flat_c, ids_c, n_c, aff)
    d_noise = 1.63 * np.sqrt(1.0 / n_rr + 1.0 / n_c)   # c(0.01)·√(1/t1+1/t2)
    assert d_obs <= beta * p_touch + d_noise, \
        (d_obs, beta, p_touch, d_noise)

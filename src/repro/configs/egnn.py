"""egnn [arXiv:2102.09844]: 4L d_hidden=64, E(n)-equivariant updates."""
from repro.configs.gnn_archs import make_arch
ARCH_ID = "egnn"
def full_config(shape):
    return make_arch(ARCH_ID, shape)
def reduced_config(shape):
    return make_arch(ARCH_ID, shape, reduced=True)

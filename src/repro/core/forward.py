"""Forward Monte-Carlo influence-spread estimators (Kempe et al.'s method).

These provide the simulation-based baseline (paper §1, approach I) and the
statistical validation target for Eq. (3): E[I(S)] = n · Pr[S ∩ RR ≠ ∅].
Vectorized over simulations: one lane per MC instance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.dense import _edge_src


@functools.partial(jax.jit, static_argnames=("n_sims", "n", "m"))
def _ic_forward(key, edge_src, edge_dst, edge_w, seed_mask, *, n_sims, n, m):
    active0 = jnp.broadcast_to(seed_mask[None, :], (n_sims, n))

    def cond(st):
        return st[0].any()

    def body(st):
        frontier, active, key = st
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, (n_sims, m))
        live = frontier[:, edge_src] & (u < edge_w[None, :])
        new = jnp.zeros((n_sims, n), bool).at[:, edge_dst].max(live)
        new = new & ~active
        return new, active | new, key

    _, active, _ = jax.lax.while_loop(cond, body, (active0, active0, key))
    return active.sum(axis=1)


def ic_spread(key, g: CSRGraph, seeds, n_sims: int = 256) -> float:
    """Forward IC E[I(S)] estimate on the forward CSR."""
    n, m = g.n_nodes, g.n_edges
    seed_mask = jnp.zeros(n, bool).at[jnp.asarray(seeds)].set(True)
    sizes = _ic_forward(key, _edge_src(g), g.indices, g.weights, seed_mask,
                        n_sims=n_sims, n=n, m=m)
    return float(sizes.mean())


@functools.partial(jax.jit, static_argnames=("n_sims", "n", "m"))
def _lt_forward(key, edge_src, edge_dst, edge_w, seed_mask, *, n_sims, n, m):
    tau = jax.random.uniform(key, (n_sims, n))
    active0 = jnp.broadcast_to(seed_mask[None, :], (n_sims, n))

    def cond(st):
        changed, _ = st
        return changed

    def body(st):
        _, active = st
        contrib = jnp.where(active[:, edge_src], edge_w[None, :], 0.0)
        mass = jnp.zeros((n_sims, n)).at[:, edge_dst].add(contrib)
        new_active = active | (mass >= tau)
        changed = (new_active != active).any()
        return changed, new_active

    _, active = jax.lax.while_loop(cond, body, (jnp.bool_(True), active0))
    return active.sum(axis=1)


def lt_spread(key, g: CSRGraph, seeds, n_sims: int = 256) -> float:
    """Forward LT E[I(S)] estimate (Eq. 1 threshold dynamics)."""
    n, m = g.n_nodes, g.n_edges
    seed_mask = jnp.zeros(n, bool).at[jnp.asarray(seeds)].set(True)
    sizes = _lt_forward(key, _edge_src(g), g.indices, g.weights, seed_mask,
                        n_sims=n_sims, n=n, m=m)
    return float(sizes.mean())

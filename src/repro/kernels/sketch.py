"""Pallas kernels for the bottom-k / one-permutation coverage sketches.

The sketch subsystem (``core/sketch.py``) summarises, for every node v, the
set of RR rows containing v as a k-bit hashed occupancy bitmap packed into
``k/32`` uint32 words — the same packed-bitset layout the Visited structures
use (``kernels/bitset.py``), so these kernels are thin recombinations of
that plumbing:

* :func:`sketch_union_popcount` — per-node ``popcount(sketch[v] | covered)``,
  the inner product of the CELF sketch estimate: the union-cardinality proxy
  for ``|rows(v) ∪ rows(S)|`` evaluated for *all* nodes in one cross-row
  popcount sweep (grid over node blocks, SWAR popcount per word).

The matching ``popcount(covered)`` baseline is one :func:`_popcount` call on
a (W,) vector — not worth a kernel.  Estimation (linear counting) happens in
``core/sketch.py``; the kernels only produce occupancy counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitset import _popcount


def _union_popcount_kernel(words_ref, cov_ref, out_ref):
    words = words_ref[...]                        # (BB, W) uint32
    cov = cov_ref[...]                            # (1, W) uint32, replicated
    out_ref[...] = _popcount(words | cov).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sketch_union_popcount(words, cov, *, block_b: int = 256,
                          interpret: bool = True):
    """``out[v] = popcount(words[v] | cov)`` for every sketch row.

    ``words``: (R, W) uint32 packed per-node sketches; ``cov``: (W,) uint32
    packed union sketch of the selected seed set.  Returns (R,) int32 —
    the occupancy of each candidate union, from which the CELF path derives
    estimated marginal coverage (see ``core/sketch.py``).
    """
    r, w = words.shape
    if cov.shape != (w,):
        raise ValueError("cov must be a (W,) vector matching the sketch "
                         "word width")
    bb = min(block_b, r)
    return pl.pallas_call(
        _union_popcount_kernel,
        grid=(pl.cdiv(r, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.int32),
        interpret=interpret,
    )(words, cov.reshape(1, w))

"""Dense-frontier ("GraphBLAS") RR-set engine.

Level-synchronous masked-SpMV BFS over *all* edges per level, vectorized over a
batch of B lanes (one RR set per lane).  This is the formulation the paper
argues against on GPU (§3.1: small frontiers starve SIMT warps); on TPU it is
a clean, fully-vectorized reference engine and the fast path for small graphs.

Correctness note (paper §3.1's duplicate-frontier hazard): the frontier here is
a *set* (boolean mask), so a node enters the frontier at most once and each
reverse edge is Bernoulli-evaluated at most once per lane — the probability
inflation 1-(1-p)^2 the paper warns about cannot occur.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.packing import pack_rows, pack_rows_device
from repro.core.roots import draw_roots


class DenseSample(NamedTuple):
    membership: jnp.ndarray  # (B, n) bool — RR-set membership per lane
    roots: jnp.ndarray       # (B,) int32
    levels: jnp.ndarray      # () int32 — BFS levels executed


def _edge_src(g: CSRGraph) -> jnp.ndarray:
    offs = np.asarray(g.offsets, dtype=np.int64)
    return jnp.asarray(np.repeat(np.arange(len(offs) - 1), np.diff(offs)),
                       dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("batch", "n", "m"))
def _sample_dense(key, edge_src, edge_dst, edge_w, roots, *, batch, n, m):
    visited = jnp.zeros((batch, n), dtype=bool)
    visited = visited.at[jnp.arange(batch), roots].set(True)
    frontier = visited

    def cond(state):
        frontier, _, _, _ = state
        return frontier.any()

    def body(state):
        frontier, visited, key, level = state
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, (batch, m))
        live = frontier[:, edge_src] & (u < edge_w[None, :])   # (B, m)
        new = jnp.zeros((batch, n), dtype=bool)
        new = new.at[:, edge_dst].max(live)  # scatter-or by destination
        new = new & ~visited
        return new, visited | new, key, level + 1

    frontier, visited, key, levels = jax.lax.while_loop(
        cond, body, (frontier, visited, key, jnp.int32(0)))
    return visited, levels


@functools.partial(jax.jit, static_argnames=("batch", "n", "m"))
def _dense_round(key, edge_src, edge_dst, edge_w, root_table, *, batch, n, m):
    """Root draw + frontier BFS + padded conversion as ONE jit — the
    device-resident engine path (``edge_src`` precomputed once at engine
    construction, no per-round host work).  Key-split structure matches
    :func:`sample_rrsets_dense` exactly (``root_table=None`` -> the
    identical uniform randint; weighted IM passes an alias table)."""
    key, sub = jax.random.split(key)
    roots = draw_roots(sub, batch, n, root_table)
    membership, levels = _sample_dense(key, edge_src, edge_dst, edge_w, roots,
                                       batch=batch, n=n, m=m)
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (batch, n))
    nodes, lens = pack_rows_device(cols, membership)
    overflow = jnp.zeros((batch,), bool)             # dense never truncates
    return nodes, lens, roots, overflow, levels


def sample_rrsets_dense(key, g_rev: CSRGraph, batch: int) -> DenseSample:
    """Sample ``batch`` RR sets on the reverse CSR.  Returns bool membership."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    key, sub = jax.random.split(key)
    roots = jax.random.randint(sub, (batch,), 0, n, dtype=jnp.int32)
    membership, levels = _sample_dense(
        key, _edge_src(g_rev), g_rev.indices, g_rev.weights, roots,
        batch=batch, n=n, m=m)
    return DenseSample(membership=membership, roots=roots, levels=levels)


def membership_to_lists(membership) -> list[list[int]]:
    """Convert (B, n) bool membership to python RR-set lists (tests/oracles)."""
    mem = np.asarray(membership)
    return [np.nonzero(row)[0].tolist() for row in mem]


def membership_to_padded(membership):
    """Vectorized (B, n) bool membership -> (nodes (B, W), lengths (B,)).

    W = max set size; rows are ascending node ids.  One rank-scatter instead
    of a per-row python ``nonzero`` loop (the engine-protocol hot path).
    """
    mem = np.asarray(membership, bool)
    cols = np.broadcast_to(np.arange(mem.shape[1], dtype=np.int64), mem.shape)
    return pack_rows(cols, mem)


# ---------------------------------------------------------------------------
# Bit-packed variant: visited/frontier live as (B, ceil(n/32)) uint32 words,
# maintained through the Pallas bitset kernels; Bernoulli trials through the
# fused counter-RNG kernel.  32x smaller resident state than the bool engine.
# ---------------------------------------------------------------------------

class PackedSample(NamedTuple):
    words: jnp.ndarray   # (B, W) uint32 packed membership
    occur: jnp.ndarray   # (n_pad,) int32 — per-node occurrence counts
    sizes: jnp.ndarray   # (B,) int32 — RR-set sizes
    roots: jnp.ndarray   # (B,) int32


def sample_rrsets_dense_packed(key, g_rev: CSRGraph, batch: int,
                               base_seed: int = 0) -> PackedSample:
    from repro.kernels import ops as kops
    n, m = g_rev.n_nodes, g_rev.n_edges
    n_pad = ((n + 31) // 32) * 32
    w_words = n_pad // 32
    edge_src = _edge_src(g_rev)
    edge_dst, edge_w = g_rev.indices, g_rev.weights
    key, sub = jax.random.split(key)
    roots = jax.random.randint(sub, (batch,), 0, n, dtype=jnp.int32)
    lane = jnp.arange(batch)
    visited0 = jnp.zeros((batch, n_pad), bool).at[lane, roots].set(True)
    visited = kops.pack_bits(visited0)
    frontier = visited

    def bit_gather(words, nodes):
        got = words[:, nodes >> 5]                     # (B, m)
        return ((got >> (nodes & 31).astype(jnp.uint32)) & jnp.uint32(1)) != 0

    def cond(st):
        frontier, _, _ = st
        return (frontier != 0).any()

    def body(st):
        frontier, visited, level = st
        # fused counter-RNG Bernoulli per (lane, edge): one kernel call per
        # lane-block via seed folding (lane id mixed into the seed)
        seeds = (jnp.uint32(base_seed) * jnp.uint32(2654435761)
                 + lane.astype(jnp.uint32) * jnp.uint32(40503)
                 + level.astype(jnp.uint32))
        keep = jax.vmap(lambda s: kops.bernoulli_edges(edge_w, s))(seeds)
        live = bit_gather(frontier, edge_src) & keep   # (B, m)
        new_bool = jnp.zeros((batch, n_pad), bool).at[:, edge_dst].max(live)
        new_words = kops.pack_bits(new_bool)
        new_words = kops.bitset_andnot(new_words, visited)
        visited2 = kops.bitset_or(visited, new_words)
        return new_words, visited2, level + 1

    frontier, visited, levels = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0)))
    occur = kops.occur_from_bitset(visited)
    sizes = kops.popcount_words(visited).sum(axis=1)
    return PackedSample(words=visited, occur=occur, sizes=sizes, roots=roots)

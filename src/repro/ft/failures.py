"""Fault-tolerance harness: failure injection, retrying step runner.

On a real cluster, node failure surfaces as a distributed-runtime error on
the jitted step; recovery = re-init the runtime on the surviving/replaced
nodes and restore the latest checkpoint.  The control flow (run -> detect ->
restore -> resume) is hardware-independent and is what we test here, with
``FailureInjector`` standing in for the runtime error.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.ckpt import checkpoint as ckpt


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises at configured step numbers (once each)."""
    fail_at: set = field(default_factory=set)
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class RunReport:
    steps_run: int = 0
    restarts: int = 0
    restored_from: list = field(default_factory=list)
    losses: list = field(default_factory=list)


def resilient_loop(*, init_state_fn: Callable[[], tuple],
                   step_fn: Callable, total_steps: int, ckpt_dir: str,
                   ckpt_every: int = 10, keep: int = 3,
                   injector: Optional[FailureInjector] = None,
                   max_restarts: int = 10) -> RunReport:
    """Checkpoint/restart training driver.

    ``init_state_fn() -> (step, state)`` builds fresh state;
    ``step_fn(step, state) -> (state, loss)`` runs one step.
    On failure: restore latest checkpoint and continue.  Restore path uses
    the same ``init_state_fn`` structure (mesh-agnostic host arrays).
    """
    report = RunReport()
    restarts = 0
    while True:
        try:
            latest = ckpt.latest_step(ckpt_dir)
            step0, state = init_state_fn()
            if latest is not None:
                state = ckpt.restore(ckpt_dir, latest, state)
                step0 = latest + 1
                report.restored_from.append(latest)
            step = step0
            while step < total_steps:
                if injector is not None:
                    injector.check(step)
                state, loss = step_fn(step, state)
                report.losses.append(float(loss))
                report.steps_run += 1
                if (step + 1) % ckpt_every == 0 or step == total_steps - 1:
                    ckpt.save(ckpt_dir, step, state, keep=keep)
                step += 1
            return report
        except InjectedFailure:
            restarts += 1
            report.restarts = restarts
            if restarts > max_restarts:
                raise

"""Host-side vectorized padded-row packing shared by the engine adapters.

Low-level (imports nothing from core) so both the samplers and the engine
layer can use it without cycles.
"""
from __future__ import annotations

import numpy as np


def pack_rows(values: np.ndarray, mask: np.ndarray):
    """Left-compact masked elements of each row into a padded matrix.

    values, mask: (B, C).  Returns (rows (B, W), lengths (B,)) where W is the
    max per-row count; column order is preserved.  Fully vectorized: rank =
    prefix count of the mask, then one scatter.
    """
    mask = np.asarray(mask, bool)
    values = np.asarray(values)
    lens = mask.sum(axis=1).astype(np.int64)
    width = max(int(lens.max()) if lens.size else 0, 1)
    out = np.zeros((mask.shape[0], width), values.dtype)
    rank = mask.cumsum(axis=1) - 1
    r, c = np.nonzero(mask)
    out[r, rank[r, c]] = values[r, c]
    return out, lens

"""deepseek-v3-671b [arXiv:2412.19437]: MLA + 1 shared/256 routed top-8 + MTP."""
from repro.configs.lm import deepseek_v3_671b as full_config, reduced_lm
ARCH_ID = "deepseek-v3-671b"
def reduced_config():
    return reduced_lm(full_config())

"""Mesh-sharded RR pool: packed-word sketch fold properties and the
single-device == multi-device parity contract.

The packed fold (sort+dedup+scatter-add in ``core/sketch.py``, and the
Pallas scatter-or kernel in ``kernels/sketch.py``) must be bit-identical to
the PR-3 bool-matrix fold it replaced; the sharded selection backends
(fused scan, Pallas bitset, CELF) must return seeds/gains/F_R bit-identical
to the 1-device mesh on a forced 8-way host-device mesh, with the whole
solve legal under ``jax.transfer_guard("disallow")``.  Device count is
locked at first jax init, so the multi-device checks run in a subprocess
with XLA_FLAGS set (the suite itself must keep seeing 1 device).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.core import coverage as cov, sketch as sk


def _random_batches(rng, n, batches=4, count=50, max_len=8,
                    with_empty=True, with_overflow=False):
    out = []
    for i in range(batches):
        lo = 0 if (with_empty and i % 2 == 0) else 1
        lens = rng.integers(lo, max_len, count)
        w = max(int(lens.max()), 1)
        nodes = np.zeros((count, w), np.int64)
        for j, ln in enumerate(lens):
            if ln:
                nodes[j, :ln] = rng.choice(n, size=min(ln, w), replace=False)
        if with_overflow and i == batches - 1:
            lens = lens + w          # overflowed lanes: raw length > width
        out.append((nodes, lens))
    return out


# ------------------------------------------- packed fold == bool fold

@pytest.mark.parametrize("seed,mode", [(0, "mod"), (1, "mod"), (2, "mix"),
                                       (3, "mod")])
def test_packed_fold_bit_identical_to_bool_matrix_fold(seed, mode):
    """Property: the incremental packed-word fold equals
    ``pack_sketch(bool fold)`` bit for bit, across appends with empty rows,
    overflowed lengths, and both hash modes (the PR-3 bool fold is the
    reference oracle; no production path materializes it anymore)."""
    rng = np.random.default_rng(seed)
    n, k = 41, 64
    store = cov.ShardedDeviceRRStore(n, capacity=8, sketch_k=k,
                                     sketch_mode=mode)
    for b in _random_batches(rng, n, with_overflow=(seed == 3)):
        store.append_batch(b)
    occ = sk.sketch_from_flat(store._flat[0], store._ids[0], store._valid[0],
                              n=n, k=store.sketch_k, mode=mode)
    ref = np.asarray(sk.pack_sketch(occ, words=store.sketch_k // 32))
    got = np.asarray(store.sketch_words())
    np.testing.assert_array_equal(got, ref)


def test_scatter_or_kernel_matches_sort_based_fold():
    """The Pallas scatter-or kernel (atomicOr-style RMW loop) and the
    portable lexsort fold commit identical words, including duplicate
    (row, bucket) pairs, bits already present, and dropped sentinels."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(11)
    rows, k, e = 37, 64, 500
    v = rng.integers(0, rows + 2, e).astype(np.int32)    # some OOB sentinels
    b = rng.integers(0, k, e).astype(np.int32)
    base = rng.integers(0, 2**32, (rows, k // 32),
                        dtype=np.uint64).astype(np.uint32)
    got_k = np.asarray(kops.sketch_scatter_or(base, v, b))
    got_s = np.asarray(sk.scatter_or_bits(
        jax.numpy.asarray(base), jax.numpy.asarray(v), jax.numpy.asarray(b)))
    ref = base.copy()
    for vv, bb in zip(v, b):
        if 0 <= vv < rows:
            ref[vv, bb >> 5] |= np.uint32(1) << (bb & 31)
    np.testing.assert_array_equal(got_k, ref)
    np.testing.assert_array_equal(got_s, ref)


def test_packed_from_flat_matches_bool_reference():
    rng = np.random.default_rng(5)
    n, k = 30, 32
    store = cov.ShardedDeviceRRStore(n, capacity=8)
    for b in _random_batches(rng, n, batches=2):
        store.append_batch(b)
    flat, ids, valid = store._flat[0], store._ids[0], store._valid[0]
    got = np.asarray(sk.sketch_packed_from_flat(
        flat, ids, valid, n_rows=n + 1, k=k, mode="mod"))
    ref = np.asarray(sk.pack_sketch(
        sk.sketch_from_flat(flat, ids, valid, n=n, k=k, mode="mod"),
        words=k // 32))
    np.testing.assert_array_equal(got, ref)


def test_no_bool_occupancy_on_append_path():
    """Acceptance: the sketch is packed-word end to end — the store keeps
    no (n+1, k) bool occupancy buffer, and the packed replica is exactly
    1/8th of the bool bytes the PR-3 fold held."""
    store = cov.ShardedDeviceRRStore(100, sketch_k=128)
    assert not hasattr(store, "_occ")
    assert store._sk_words.dtype == np.uint32
    assert store.sketch_bytes() * 8 == store.sketch_rows * store.sketch_k
    store.append_batch((np.array([[1, 2, 3]]), np.array([3])))
    assert not hasattr(store, "_occ")
    assert store._sk_words.dtype == np.uint32


def test_mesh1_solver_defaults_record_sharding():
    from repro.graph import csr as csr_mod, generators, weights
    from repro.core.imm import IMMSolver
    src, dst = generators.erdos_renyi(30, 120, seed=0)
    g = weights.wc_weights(csr_mod.from_edges(src, dst, 30))
    from repro.core.problem import IMProblem
    solver = IMMSolver(g, engine="queue", batch=32)
    stats = solver.solve(IMProblem(k=2, eps=0.5, max_theta=64)).stats
    assert stats.mesh_shape == (1,)
    assert stats.pool_sharding == "samples:1"
    assert stats.per_device_pool_bytes == \
        solver.store.capacity * (4 + 4 + 1)


# --------------------------------------- 8-way mesh parity (subprocess)

PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.core import coverage as cov
from repro.graph import csr as csr_mod, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem

assert len(jax.devices()) == 8
mesh8 = Mesh(np.asarray(jax.devices()), ("samples",))
n, k = 50, 6

def batches():
    r = np.random.default_rng(7)
    out = []
    for _ in range(4):
        lens = r.integers(0, 8, 61)          # empty rows + odd row count
        w = max(int(lens.max()), 1)
        nodes = np.zeros((61, w), np.int64)
        for i, ln in enumerate(lens):
            if ln:
                nodes[i, :ln] = r.choice(n, size=ln, replace=False)
        out.append((nodes, lens))
    return out

# identical pool on a 1-device and an 8-device mesh: every backend must be
# bit-identical, for every sketch size, all under the transfer guard
for sketch_k in (32, 256, None):
    d1 = cov.ShardedDeviceRRStore(n, capacity=8, sketch_k=sketch_k)
    d8 = cov.ShardedDeviceRRStore(n, capacity=64, sketch_k=sketch_k,
                                  mesh=mesh8)
    with jax.transfer_guard("disallow"):
        for b in batches():
            d1.append_batch(b)
            d8.append_batch(b)
        assert d1.n_rr == d8.n_rr and d1.n_elems == d8.n_elems
        if sketch_k is not None:
            s1, s8 = jax.device_get((d1.sketch_words(), d8.sketch_words()))
            assert np.array_equal(np.asarray(s1), np.asarray(s8)), \
                "incremental sketch fold diverged across mesh sizes"
        for method in ("flat", "bitset"):
            r1, r8 = d1.select(k, method=method), d8.select(k, method=method)
            a, b_ = jax.device_get(((r1.seeds, r1.gains, r1.frac),
                                    (r8.seeds, r8.gains, r8.frac)))
            assert np.array_equal(a[0], b_[0]), (method, a[0], b_[0])
            assert np.array_equal(a[1], b_[1]) and a[2] == b_[2], method
        c1 = cov.select_seeds_celf(d1, k)
        c8 = cov.select_seeds_celf(d8, k)
        a, b_ = jax.device_get(((c1.seeds, c1.gains, c1.frac),
                                (c8.seeds, c8.gains, c8.frac)))
        assert np.array_equal(a[0], b_[0]), ("celf", sketch_k, a[0], b_[0])
        assert np.array_equal(a[1], b_[1]) and a[2] == b_[2]

# full solve: same engine stream into a sharded vs single-device pool
src, dst = generators.erdos_renyi(60, 300, seed=6)
g = weights.wc_weights(csr_mod.from_edges(src, dst, 60))
res = {}
for mesh in (None, mesh8):
    solver = IMMSolver(g, engine="queue", batch=64, seed=3,
                       selection="celf-sketch", mesh=mesh)
    with jax.transfer_guard("disallow"):
        r = solver.solve(IMProblem(k=4, eps=0.5, max_theta=256))
    res[r.stats.pool_sharding] = (r.seeds.tolist(), round(r.spread, 6))
assert res["samples:1"] == res["samples:8"], res
print("OK", res["samples:8"])
"""


def test_sharded_backends_bit_identical_to_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", PARITY_SCRIPT], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "OK" in r.stdout

"""llama4-scout-17b-a16e [hf:meta-llama]: 48L d5120 40H kv8 MoE 16e top-1."""
from repro.configs.lm import llama4_scout as full_config, reduced_lm
ARCH_ID = "llama4-scout-17b-a16e"
def reduced_config():
    return reduced_lm(full_config())

"""Queue-based RR-set engine — the gIM decomposition (paper Alg. 3/6), TPU-adapted.

Parallel decomposition (see DESIGN.md §2):

* gIM block  -> *lane*:    B RR sets sampled concurrently (vectorized batch dim)
* gIM warp   -> *chunk*:   the current node's CSR row is processed EC edges per
                           micro-step (EC=128 = VPU lane width; the paper's
                           ``for i = tx; i < deg; i += N_th`` loop, Alg. 3 L16)
* Q_shr+RR_tmp -> queue row: one fixed (Qcap,) row per lane.  In BFS the
  dequeued prefix *is* the RR set, so gIM's three structures (shared queue,
  reservoir, RR_tmp) collapse into one array + (head, tail) cursors.  Overflow
  (paper Alg. 4's reservoir trigger) is counted, not spilled: `overflowed`
  lanes are reported so callers can resample at larger Qcap (0 on all
  benchmark workloads at the default Qcap).
* Visited[n] byte array -> bit-packed (B, ceil(n/32)) uint32 (32x smaller).
* atomic_enqueue -> in-chunk left-pack (prefix-sum rank + log-step binary
  search gather) + one contiguous dynamic_update_slice per lane.  XLA:CPU
  lowers scatter to a serial per-update loop, so the former (B, EC) masked
  scatters dominated the micro-step; the packed append writes a contiguous
  window into an EC-padded queue row instead, and the visited-bit update
  scatters only the first ACCEPT_CAP packed columns (full-width fallback
  via lax.cond when a chunk accepts more — e.g. p=1.0 stress graphs).
* curand        -> threefry key folded per micro-step (replay-deterministic).

Intra-chunk duplicate hazard (paper §3.1): within one EC chunk the same
destination may appear on several edges (multi-edges).  Each *edge* must get an
independent Bernoulli trial, but the node must be enqueued at most once.  We
accept only the first successful occurrence per node per chunk
(:func:`_first_occurrence`, O(EC log EC) per lane): on the
destination-sorted rows :func:`repro.graph.csr.reverse` produces, duplicates
are adjacent and the check is a segmented prefix-OR in log-step shifts; on
arbitrary row order it falls back to a stable sort + neighbour-difference
scan.  The earlier implementation materialized a dense ``(B, EC, EC)``
first-occurrence mask — O(EC^2) work *and* memory per micro-step; both new
paths keep the accept set (and accepted positions) bit-identical.  This
composes with the visited-bit test-and-set across chunks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.core.packing import rank_positions
from repro.core.roots import draw_roots, roots_from_uniform

EC_DEFAULT = 128  # edge-chunk width (the paper's N_th=32, scaled to VPU lanes)


class QueueSample(NamedTuple):
    nodes: jnp.ndarray       # (B, Qcap) int32 — visit-order node ids per lane
    lengths: jnp.ndarray     # (B,) int32 — RR-set sizes
    roots: jnp.ndarray       # (B,) int32
    overflowed: jnp.ndarray  # (B,) bool — lane hit Qcap (RR set truncated)
    steps: jnp.ndarray       # () int32 — micro-steps executed


def _bit_test(words, nodes):
    """words: (B, W) uint32; nodes: (B, EC) int32 -> (B, EC) bool (bit set?)."""
    w = nodes >> 5
    b = (nodes & 31).astype(jnp.uint32)
    got = jnp.take_along_axis(words, w, axis=1)
    return ((got >> b) & jnp.uint32(1)) != 0


ACCEPT_CAP = 32  # fast-path width of the per-chunk enqueue (pack + scatter)


def detect_dedup_mode(g_rev: CSRGraph) -> str:
    """Host preprocessing (engines run it once at construction): which chunk
    dedup the sampler needs for this graph.

    * ``"none"`` — no duplicate (u, v) edges anywhere: within a chunk all
      destinations are distinct, so accept == candidate and the dedup
      disappears from the micro-step entirely (the common case).
    * ``"segmented"`` — multi-edges exist but rows are destination-sorted
      (the :func:`repro.graph.csr.reverse` layout): duplicates are adjacent
      and first-occurrence is a segmented prefix-OR.
    * ``"sort"`` — multi-edges on arbitrarily ordered rows: stable in-chunk
      sort.
    """
    from repro.graph.csr import rows_dst_sorted
    offs = np.asarray(g_rev.offsets, dtype=np.int64)
    idx = np.asarray(g_rev.indices, dtype=np.int64)
    if idx.size <= 1:
        return "none"
    if rows_dst_sorted(g_rev):
        eq = np.diff(idx) == 0
        inner = offs[1:-1]
        inner = inner[(inner > 0) & (inner < idx.size)]
        eq[inner - 1] = False                    # row boundaries don't count
        return "segmented" if eq.any() else "none"
    row_of = np.repeat(np.arange(len(offs) - 1), np.diff(offs))
    order = np.lexsort((idx, row_of))
    si, sr = idx[order], row_of[order]
    dup = (np.diff(si) == 0) & (np.diff(sr) == 0)
    return "sort" if dup.any() else "none"


def _first_occurrence(nbr, cand, arange_ec, *, mode: str):
    """accept[b, j]: is j the first chunk position among the lane's candidates
    carrying destination ``nbr[b, j]``?  (paper §3.1 duplicate hazard.)

    ``mode`` comes from :func:`detect_dedup_mode`.  ``"segmented"``:
    duplicates are adjacent (destination-sorted rows), so first-occurrence
    is a segmented prefix-OR over equal-value runs — O(EC log EC) per lane
    in log-step shifts, no sort, no gather.  ``"sort"``: stable sort of
    (destination, position) + neighbour-difference scan, also O(EC log EC).
    Every path is bit-identical to the dense (EC, EC) first-occurrence mask
    this replaces.
    """
    if mode == "none":
        return cand
    if mode == "segmented":
        runhead = jnp.concatenate(
            [jnp.ones_like(nbr[:, :1], dtype=bool),
             nbr[:, 1:] != nbr[:, :-1]], axis=1)
        # segmented inclusive prefix-OR of `cand` (Hillis-Steele)
        val, seg = cand, runhead
        d = 1
        ec = nbr.shape[1]
        while d < ec:
            val = val | (jnp.pad(val[:, :-d], ((0, 0), (d, 0))) & ~seg)
            seg = seg | jnp.pad(seg[:, :-d], ((0, 0), (d, 0)),
                                constant_values=True)
            d *= 2
        prev = jnp.pad(val[:, :-1], ((0, 0), (1, 0)))   # OR up to j-1
        return cand & (runhead | ~prev)
    if mode != "sort":
        raise ValueError(f"unknown dedup mode {mode!r}")
    sentinel = jnp.iinfo(jnp.int32).max
    key = jnp.where(cand, nbr, sentinel)
    pos = jnp.broadcast_to(arange_ec[None, :], nbr.shape)
    skey, spos = jax.lax.sort_key_val(key, pos, dimension=1, is_stable=True)
    first = jnp.concatenate(
        [jnp.ones_like(skey[:, :1], dtype=bool),
         skey[:, 1:] != skey[:, :-1]], axis=1)
    accept_sorted = first & (skey != sentinel)
    rows = jnp.arange(nbr.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros_like(cand).at[rows, spos].set(accept_sorted)


def _pack_accepted(accept, nbr, n, ec, width):
    """Left-pack each lane's first ``width`` accepted destinations, order
    preserved.

    Returns packed (B, width) int32 — sentinel ``n`` beyond each lane's
    count.  The j-th accepted position is found by a vectorized binary
    search over the accept prefix sum (log EC gather steps), so the pack
    needs no scatter — XLA:CPU lowers scatter to a serial per-update loop,
    which made the old per-chunk scatters the dominant micro-step cost.
    """
    csum = jnp.cumsum(accept.astype(jnp.int32), axis=1)
    cnt = csum[:, -1]
    pos = jax.vmap(lambda c: rank_positions(c, width, ec))(csum)
    packed = jnp.take_along_axis(nbr, pos, axis=1)
    tgt = jnp.arange(1, width + 1, dtype=jnp.int32)[None, :]
    return jnp.where(tgt <= cnt[:, None], packed, n)


def _bits_write(visited, packed, n, n_words):
    """Set the visited bits of packed destinations (sentinel ``n`` rows are
    dropped).  Chunk-unique + previously-unseen nodes ⇒ all bits distinct ⇒
    scatter-add == scatter-or."""
    lane = jnp.arange(visited.shape[0], dtype=jnp.int32)
    valid = packed < n
    w = jnp.where(valid, packed >> 5, n_words)
    bit = jnp.where(
        valid,
        jnp.left_shift(jnp.uint32(1), (packed & 31).astype(jnp.uint32)),
        jnp.uint32(0))
    return visited.at[lane[:, None], w].add(bit, mode="drop")


def _rows_append(buf, packed, start):
    """Contiguous per-lane append: one dynamic_update_slice per lane instead
    of a scatter.  ``buf`` carries an EC-wide pad tail, so the slice window
    beyond a lane's accept count lands in scratch space that the next append
    (or the length mask) overwrites/ignores."""
    return jax.vmap(
        lambda row, upd, st: jax.lax.dynamic_update_slice(row, upd, (st,))
    )(buf, packed, start)


def _enqueue_chunk(buf, visited, accept, nbr, tail, cap, ec, n, n_words,
                   arange_ec):
    """The paper's atomic_enqueue (Alg. 3 L21) for one chunk: left-pack the
    accepted destinations, append them contiguously into each lane's row at
    ``tail``, and mark their visited bits.

    Fast path works at ACCEPT_CAP width — it covers every chunk whose
    accept count fits (the overwhelming case under sub-critical IC
    weights); a full-EC pass runs only when some lane accepted more (e.g.
    p=1.0 stress graphs), via ``lax.cond``.  Capacity: the first
    ``cap - tail`` accepted fit, exactly the old per-slot rule; the rest
    land in the pad tail and are dropped (overflow is flagged by the
    caller from the returned ``cnt``).

    Returns (buf, visited, cnt, take).
    """
    cnt = accept.sum(axis=1, dtype=jnp.int32)
    take = jnp.minimum(cnt, jnp.maximum(cap - tail, 0))
    kacc = min(ACCEPT_CAP, ec)
    packed = _pack_accepted(accept, nbr, n, ec, ec)
    # buffer append is a cheap contiguous write — always full width, and
    # crucially NOT routed through lax.cond: conditionals break XLA's
    # in-place buffer aliasing and would copy the whole row buffer per step
    buf = _rows_append(buf, packed, tail)
    vis_src = jnp.where(arange_ec[None, :] < take[:, None], packed, n)
    if kacc == ec:
        visited = _bits_write(visited, vis_src, n, n_words)
    else:
        # the visited scatter cost is per update entry, so cap its width;
        # only `visited` (small) crosses the cond boundary
        visited = jax.lax.cond(
            (cnt > kacc).any(),
            lambda v: _bits_write(v, vis_src, n, n_words),
            lambda v: _bits_write(v, vis_src[:, :kacc], n, n_words),
            visited)
    return buf, visited, cnt, take


@functools.partial(jax.jit,
                   static_argnames=("batch", "qcap", "ec", "n", "m",
                                    "dedup"))
def _sample_queue(key, offsets, indices, weights, roots, *,
                  batch, qcap, ec, n, m, dedup="sort"):
    n_words = (n + 31) // 32
    lane = jnp.arange(batch, dtype=jnp.int32)
    # EC-wide pad tail absorbs the contiguous-append slice windows
    queue = jnp.zeros((batch, qcap + ec), dtype=jnp.int32)
    queue = queue.at[:, 0].set(roots)
    visited = jnp.zeros((batch, n_words), dtype=jnp.uint32)
    visited = visited.at[lane, roots >> 5].set(
        jnp.left_shift(jnp.uint32(1), (roots & 31).astype(jnp.uint32)))
    # init derived from `roots` so device-varying types propagate when the
    # sampler runs inside shard_map (one lane batch per device)
    qhead = jnp.zeros_like(roots)
    qtail = jnp.ones_like(roots)
    ecur = jnp.zeros_like(roots)
    overflow = roots < 0
    arange_ec = jnp.arange(ec, dtype=jnp.int32)

    def cond(st):
        _, _, qhead, qtail, _, _, _, _ = st
        return (qhead < qtail).any()

    def body(st):
        queue, visited, qhead, qtail, ecur, overflow, key, step = st
        active = qhead < qtail
        u = queue[lane, jnp.clip(qhead, 0, qcap - 1)]            # current node
        s = offsets[u]
        deg = offsets[u + 1] - s
        pos = ecur[:, None] + arange_ec[None, :]                 # (B, EC)
        valid = (pos < deg[:, None]) & active[:, None]
        eidx = jnp.clip(s[:, None] + pos, 0, m - 1)
        nbr = indices[eidx]                                      # (B, EC)
        pw = weights[eidx]
        key, sub = jax.random.split(key)
        urand = jax.random.uniform(sub, (batch, ec))
        keep = (urand < pw) & valid                              # edge traversed
        unseen = ~_bit_test(visited, nbr)
        cand = keep & unseen
        # first successful occurrence per destination within the chunk
        accept = _first_occurrence(nbr, cand, arange_ec, mode=dedup)
        queue, visited, cnt, take = _enqueue_chunk(
            queue, visited, accept, nbr, qtail, qcap, ec, n, n_words,
            arange_ec)
        overflow = overflow | (cnt > take)
        qtail = qtail + take
        # advance the edge cursor / pop the node (Alg. 3 L12)
        ecur2 = ecur + ec
        row_done = ecur2 >= deg
        qhead = jnp.where(active & row_done, qhead + 1, qhead)
        ecur = jnp.where(active & ~row_done, ecur2, 0)
        return queue, visited, qhead, qtail, ecur, overflow, key, step + 1

    queue, visited, qhead, qtail, ecur, overflow, key, steps = (
        jax.lax.while_loop(cond, body,
                           (queue, visited, qhead, qtail, ecur, overflow, key,
                            jnp.int32(0))))
    return queue[:, :qcap], qtail, overflow, steps


@functools.partial(jax.jit,
                   static_argnames=("batch", "qcap", "ec", "n", "m",
                                    "dedup"))
def _queue_round(key, offsets, indices, weights, root_table, *, batch, qcap,
                 ec, n, m, dedup="sort"):
    """Root draw + queue BFS as ONE jit: every operand is a device array, so
    a round triggers no host↔device traffic (runs under
    ``jax.transfer_guard("disallow")``).  The key-split structure matches the
    historical host wrapper exactly, keeping sample streams bit-identical
    (``root_table=None`` -> the identical uniform randint)."""
    key, sub = jax.random.split(key)
    roots = draw_roots(sub, batch, n, root_table)
    nodes, lengths, overflowed, steps = _sample_queue(
        key, offsets, indices, weights, roots,
        batch=batch, qcap=qcap, ec=ec, n=n, m=m, dedup=dedup)
    return nodes, lengths, roots, overflowed, steps


def sample_rrsets_queue(key, g_rev: CSRGraph, batch: int, qcap: int,
                        ec: int = EC_DEFAULT,
                        dedup: str | None = None,
                        root_table=None) -> QueueSample:
    """Sample ``batch`` RR sets (one round) on the reverse CSR.

    ``dedup=None`` runs :func:`detect_dedup_mode` on the host once per call
    (engines cache the detection at construction).  ``root_table`` (an
    :class:`~repro.core.roots.AliasTable`) switches the root draw to
    weight-proportional sampling (weighted IM)."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    if dedup is None:
        dedup = detect_dedup_mode(g_rev)
    nodes, lengths, roots, overflowed, steps = _queue_round(
        key, g_rev.offsets, g_rev.indices, g_rev.weights, root_table,
        batch=batch, qcap=qcap, ec=ec, n=n, m=m, dedup=dedup)
    return QueueSample(nodes=nodes, lengths=lengths, roots=roots,
                       overflowed=overflowed, steps=steps)


def to_lists(sample: QueueSample) -> list[list[int]]:
    nodes = np.asarray(sample.nodes)
    lens = np.asarray(sample.lengths)
    return [nodes[i, :lens[i]].tolist() for i in range(nodes.shape[0])]


# ---------------------------------------------------------------------------
# Persistent-lane ("refill") engine — the paper's Alg. 6 worker structure.
#
# The round-based sampler above retires a whole batch before starting new
# roots, so every lane waits for the round's largest RR set (measured lane
# utilization ~21% on WC/BA workloads — see EXPERIMENTS.md §Perf/IM).  Here
# a lane starts a new RR set the moment it finishes one, exactly like a gIM
# block looping "repeat ... until N_RR >= theta"; RR sets append into a flat
# per-lane output row (the paper's RR array + Offsets_RR).
# ---------------------------------------------------------------------------

class RefillSample(NamedTuple):
    flat: jnp.ndarray      # (B, OutCap) int32 — concatenated RR sets
    lengths: jnp.ndarray   # (B, sets_per_lane) int32 — per-set lengths
    n_done: jnp.ndarray    # (B,) int32 — completed sets per lane
    overflowed: jnp.ndarray  # (B,) bool — lane ran out of OutCap
    steps: jnp.ndarray     # () int32


@functools.partial(jax.jit,
                   static_argnames=("batch", "out_cap", "quota",
                                    "max_sets_per_lane", "ec", "n", "m",
                                    "dedup"))
def _sample_refill(key, offsets, indices, weights, roots0, root_table, *,
                   batch, out_cap, quota, max_sets_per_lane, ec, n, m,
                   dedup="sort"):
    n_words = (n + 31) // 32
    lane = jnp.arange(batch, dtype=jnp.int32)
    arange_ec = jnp.arange(ec, dtype=jnp.int32)
    sets_per_lane = max_sets_per_lane

    # EC-wide pad tail absorbs the contiguous-append slice windows
    out = jnp.zeros((batch, out_cap + ec), jnp.int32)
    out = out.at[:, 0].set(roots0)
    lengths = jnp.zeros((batch, sets_per_lane), jnp.int32)
    visited = jnp.zeros((batch, n_words), jnp.uint32)
    visited = visited.at[lane, roots0 >> 5].set(
        jnp.left_shift(jnp.uint32(1), (roots0 & 31).astype(jnp.uint32)))
    set_start = jnp.zeros_like(roots0)         # current set's base offset
    qhead = jnp.zeros_like(roots0)             # read head (relative)
    tail = jnp.ones_like(roots0)               # absolute write offset
    ecur = jnp.zeros_like(roots0)
    n_done = jnp.zeros_like(roots0)
    overflow = roots0 < 0
    in_set = roots0 >= 0            # lane currently building a set

    def cond(st):
        (_, _, _, _, _, _, _, _, overflow, in_set, _, _) = st
        return (in_set & ~overflow).any()

    def body(st):
        (out, lengths, visited, set_start, qhead, tail, ecur, n_done,
         overflow, in_set, key, step) = st
        working = (n_done < sets_per_lane) & ~overflow & in_set
        active = working & (set_start + qhead < tail)
        u = out[lane, jnp.clip(set_start + qhead, 0, out_cap - 1)]
        s = offsets[u]
        deg = offsets[u + 1] - s
        pos = ecur[:, None] + arange_ec[None, :]
        valid = (pos < deg[:, None]) & active[:, None]
        eidx = jnp.clip(s[:, None] + pos, 0, m - 1)
        nbr = indices[eidx]
        pw = weights[eidx]
        # ONE uniform draw per micro-step: EC edge trials + 1 refill-root
        # column per lane (a second split+randint per step costs a whole
        # extra threefry dispatch)
        key, sub = jax.random.split(key)
        urand = jax.random.uniform(sub, (batch, ec + 1))
        keep = (urand[:, :ec] < pw) & valid
        unseen = ~_bit_test(visited, nbr)
        cand = keep & unseen
        accept = _first_occurrence(nbr, cand, arange_ec, mode=dedup)
        out, visited, cnt, take = _enqueue_chunk(
            out, visited, accept, nbr, tail, out_cap, ec, n, n_words,
            arange_ec)
        overflow = overflow | (cnt > take)
        tail = tail + take
        ecur2 = ecur + ec
        row_done = ecur2 >= deg
        qhead = jnp.where(active & row_done, qhead + 1, qhead)
        ecur = jnp.where(active & ~row_done, ecur2, 0)
        # --- lane refill: set finished when the read head catches the tail
        finished = working & (set_start + qhead >= tail)
        in_set = in_set & ~finished
        set_len = tail - set_start
        lengths = lengths.at[
            lane, jnp.where(finished, jnp.clip(n_done, 0, sets_per_lane - 1),
                            sets_per_lane)].set(set_len, mode="drop")
        n_done = n_done + finished.astype(jnp.int32)
        # global quota race (gIM Alg. 6: blocks loop until N_RR >= theta);
        # in-flight sets always complete (no size-biased discarding),
        # lanes just stop *starting* once the global count is met
        quota_open = n_done.sum() < quota
        more = finished & (n_done < sets_per_lane) & quota_open
        # room check for the new root
        has_room = tail < out_cap
        overflow = overflow | (more & ~has_room)
        start_new = more & has_room
        # refill roots from the step's spare uniform column: uniform when
        # root_table is None (bit-identical to the historical floor(u*n)),
        # weight-proportional through the alias table otherwise
        new_roots = roots_from_uniform(urand[:, ec], n, root_table)
        # clear this lane's visited set and seed the new root
        visited = jnp.where(start_new[:, None], jnp.uint32(0), visited)
        visited = visited.at[
            lane, jnp.where(start_new, new_roots >> 5, n_words)].add(
            jnp.where(start_new,
                      jnp.left_shift(jnp.uint32(1),
                                     (new_roots & 31).astype(jnp.uint32)),
                      jnp.uint32(0)), mode="drop")
        out = out.at[lane, jnp.where(start_new, tail, out_cap + ec)].set(
            new_roots, mode="drop")
        set_start = jnp.where(start_new, tail, set_start)
        qhead = jnp.where(start_new, 0, qhead)
        ecur = jnp.where(start_new, 0, ecur)
        tail = tail + start_new.astype(jnp.int32)
        in_set = in_set | start_new
        return (out, lengths, visited, set_start, qhead, tail, ecur,
                n_done, overflow, in_set, key, step + 1)

    st = (out, lengths, visited, set_start, qhead, tail, ecur, n_done,
          overflow, in_set, key, jnp.int32(0))
    (out, lengths, visited, set_start, qhead, tail, ecur, n_done, overflow,
     in_set, key, steps) = jax.lax.while_loop(cond, body, st)
    return out[:, :out_cap], lengths, n_done, overflow, steps


@functools.partial(jax.jit,
                   static_argnames=("batch", "out_cap", "quota",
                                    "max_sets_per_lane", "ec", "n", "m",
                                    "dedup"))
def _refill_round(key, offsets, indices, weights, root_table, *, batch,
                  out_cap, quota, max_sets_per_lane, ec, n, m, dedup="sort"):
    """Root draw + persistent-lane worker as ONE jit (see ``_queue_round``)."""
    key, sub = jax.random.split(key)
    roots = draw_roots(sub, batch, n, root_table)
    return _sample_refill(
        key, offsets, indices, weights, roots, root_table,
        batch=batch, out_cap=out_cap, quota=quota,
        max_sets_per_lane=max_sets_per_lane, ec=ec, n=n, m=m, dedup=dedup)


def sample_rrsets_refill(key, g_rev: CSRGraph, batch: int,
                         quota: int, out_cap: int,
                         max_sets_per_lane: int | None = None,
                         ec: int = EC_DEFAULT,
                         dedup: str | None = None,
                         root_table=None) -> RefillSample:
    """Persistent-lane sampling with a global quota: lanes refill with new
    roots until >= ``quota`` RR sets are complete across all lanes (the
    paper's Alg. 6 worker loop); in-flight sets always finish (unbiased)."""
    n, m = g_rev.n_nodes, g_rev.n_edges
    if max_sets_per_lane is None:
        max_sets_per_lane = max(4 * quota // batch + 4, 4)
    if dedup is None:
        dedup = detect_dedup_mode(g_rev)
    flat, lengths, n_done, overflow, steps = _refill_round(
        key, g_rev.offsets, g_rev.indices, g_rev.weights, root_table,
        batch=batch, out_cap=out_cap, quota=quota,
        max_sets_per_lane=max_sets_per_lane, ec=ec, n=n, m=m, dedup=dedup)
    return RefillSample(flat=flat, lengths=lengths, n_done=n_done,
                        overflowed=overflow, steps=steps)


def refill_to_lists(sample: RefillSample) -> list[list[int]]:
    flat = np.asarray(sample.flat)
    lengths = np.asarray(sample.lengths)
    n_done = np.asarray(sample.n_done)
    out = []
    for b in range(flat.shape[0]):
        off = 0
        for i in range(int(n_done[b])):
            ln = int(lengths[b, i])
            out.append(flat[b, off:off + ln].tolist())
            off += ln
    return out


@jax.jit
def refill_to_padded_device(flat, lengths, n_done):
    """Device-resident unpack of a RefillSample into fixed-shape padded rows.

    (B, OutCap), (B, S), (B,) -> rows (B*S, OutCap) + lengths (B*S,).  Unlike
    :func:`refill_to_padded` the row count is *static* (every lane slot
    becomes a row); slots beyond a lane's ``n_done`` come back with length 0
    — padding rows carrying no RR set, dropped by the device store's
    compaction.  This keeps the solver's per-round shapes stable and the
    whole unpack on device (no host round-trip, no recompiles).
    """
    b, s = lengths.shape
    out_cap = flat.shape[1]
    set_valid = jnp.arange(s, dtype=n_done.dtype)[None, :] < n_done[:, None]
    lens = jnp.where(set_valid, lengths, 0)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), lengths.dtype),
         jnp.cumsum(lengths, axis=1)[:, :-1]], axis=1)
    idx = starts[:, :, None] + jnp.arange(out_cap, dtype=starts.dtype)[
        None, None, :]
    rows = jnp.take_along_axis(flat[:, None, :],
                               jnp.clip(idx, 0, out_cap - 1), axis=2)
    col_valid = jnp.arange(out_cap)[None, None, :] < lens[:, :, None]
    rows = jnp.where(col_valid, rows, 0)
    return rows.reshape(b * s, out_cap), lens.reshape(b * s)


def refill_to_padded(sample: RefillSample):
    """Vectorized unpack of a RefillSample into (nodes (R, W), lengths (R,)).

    R = total completed sets across lanes, W = max set size.  Sets are laid
    out contiguously per lane (root first), so per-set start offsets are an
    exclusive prefix sum of the recorded lengths; one broadcast gather plus a
    validity mask replaces the per-set python slicing loop.
    """
    flat = np.asarray(sample.flat)
    lengths = np.asarray(sample.lengths, np.int64)    # (B, S)
    n_done = np.asarray(sample.n_done, np.int64)      # (B,)
    b, s = lengths.shape
    set_valid = np.arange(s)[None, :] < n_done[:, None]
    if not set_valid.any():
        return np.zeros((0, 1), np.int64), np.zeros(0, np.int64)
    starts = np.concatenate(
        [np.zeros((b, 1), np.int64), lengths.cumsum(axis=1)[:, :-1]], axis=1)
    width = max(int(lengths[set_valid].max()), 1)
    idx = starts[:, :, None] + np.arange(width, dtype=np.int64)[None, None, :]
    rows = np.take_along_axis(flat[:, None, :],
                              np.clip(idx, 0, flat.shape[1] - 1), axis=2)
    col_valid = np.arange(width)[None, None, :] < lengths[:, :, None]
    rows = np.where(col_valid, rows, 0).reshape(b * s, width)
    keep = set_valid.reshape(b * s)
    return rows[keep].astype(np.int64), lengths[set_valid]

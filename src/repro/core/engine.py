"""SamplerEngine protocol + registry: one API for every RR-sampling engine.

The paper's claim that "other variations of the IM problem need only minor
modifications" (§3.7 LT, §4.8 MRIM) becomes a first-class contract here:
every sampling engine — the gIM queue decomposition, the dense-frontier
reference, the persistent-lane refill worker, the LT walk sampler, and
MRIM's round-tagged variant — is an adapter class that

* is configured by a per-engine ``Config`` dataclass,
* is registered under a short name (``register_engine`` / ``get_engine``),
* returns one canonical :class:`RRBatch` from ``sample(key)``.

Downstream (``IMMSolver``, ``solve_mrim``, the sharded launch pipeline,
benchmarks) consumes only the protocol, so adding a diffusion model means
writing one adapter — no solver changes.  See DESIGN.md §3.

Layering: this module imports the low-level samplers (``rrset``, ``dense``,
``lt``); it is imported by the solvers (``imm``, ``mrim``) and launchers.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, coalesce_ic
from repro.core import rrset as rr_queue
from repro.core import dense as rr_dense
from repro.core import lt as rr_lt
from repro.core.packing import pack_rows_device
from repro.core.roots import (AliasTable, ONE_UNIFORM_MAX_N,  # noqa: F401
                              build_alias_table, draw_roots,
                              roots_from_uniform)


@jax.jit
def split_key(key):
    """Guard-safe (carry, sub) key split: the pair indexing happens inside
    the jit, so no host index scalar is committed under
    ``jax.transfer_guard("disallow")``.  Shared by the device-resident
    solvers (imm, mrim)."""
    ks = jax.random.split(key)
    return ks[0], ks[1]


# Weighted root sampling (weighted IM: roots drawn ∝ node_weights).  The
# implementation lives one layer down in ``core/roots.py`` (the samplers
# import it without a cycle); this module is the engine-facing surface.


class RRBatch(NamedTuple):
    """Canonical, device-resident result of one ``SamplerEngine.sample`` call.

    One row per completed RR set; rows are padded to the batch's max length.
    ``nodes`` entries beyond ``lengths[i]`` are undefined (consumers mask by
    length — ``coverage.build_store`` / ``IncrementalRRStore.append_batch``
    do).  Node ids live in the engine's ``item_space`` (plain engines:
    ``[0, n)``; MRIM: ``round * n + node`` in ``[0, n * t_rounds)``).

    ``overflowed`` is per *lane* (engines whose lanes each emit one set have
    lanes == rows; the refill engine reports its persistent lanes).
    ``steps`` is the scalar count of lockstep micro-steps this batch cost —
    the hardware-transferable parallel-time metric of §Perf/IM.

    ``sample()`` returns only real sets (every ``lengths[i] >= 1``).  The
    fixed-shape device paths (``sample_device``, preferred by the solvers
    under ``jax.transfer_guard("disallow")``) may additionally emit *padding
    rows* with ``lengths[i] == 0`` — no RR set at all — which the stores
    drop without assigning a row id.

    ``roots`` (optional) is the *base-space* root node of each row —
    undefined for padding rows.  Engines that know their roots report them
    so the solver can weight rows by ``node_weights[root]`` (the
    importance-weighted fallback for weighted problems on engines without
    weight-proportional root sampling); ``None`` is a valid value for
    third-party adapters.
    """
    nodes: jnp.ndarray       # (R, W) int32/int64, padded per-set node ids
    lengths: jnp.ndarray     # (R,) int — RR-set sizes (>= 1)
    overflowed: jnp.ndarray  # (L,) bool — per-lane truncation flags
    steps: jnp.ndarray       # () int — lockstep micro-steps executed
    roots: Optional[jnp.ndarray] = None  # (R,) int32 base-node root per row

    @property
    def n_sets(self) -> int:
        return int(self.lengths.shape[0])

    @classmethod
    def make(cls, nodes, lengths, overflowed, steps, roots=None) -> "RRBatch":
        return cls(nodes=jnp.asarray(nodes), lengths=jnp.asarray(lengths),
                   overflowed=jnp.asarray(overflowed),
                   steps=jnp.asarray(steps),
                   roots=None if roots is None else jnp.asarray(roots))


@runtime_checkable
class SamplerEngine(Protocol):
    """What the solvers require of an engine (structural — no inheritance).

    Optional extensions the solvers exploit when present:

    * ``device_resident = True`` — every op in ``sample`` runs on device
      (all operands are committed device arrays; host graph preprocessing
      happened at construction).  The IMM driver then holds
      ``jax.transfer_guard("disallow")`` over its whole hot loop.  Engines
      that do host work per sample simply omit the attribute and the driver
      falls back to unguarded execution — third-party adapters keep working.
    * ``sample_device(key)`` — fixed-shape variant of ``sample`` that may
      return zero-length padding rows (see :class:`RRBatch`); preferred by
      the solvers because stable shapes mean stable jit caches.
    * ``mesh`` + ``sample_sharded(key)`` — mesh-fanned engines expose the
      jax ``Mesh`` they sample over and a variant whose batch arrays stay
      *sharded* across it (per-device rows resident on the device that
      sampled them, no gather).  When the solver's pool shares the same
      mesh (``IMMSolver(mesh=...)``), it prefers this path and the rows
      never leave their sampling device.
    """
    name: str

    @property
    def item_space(self) -> int:
        """Size of the id space ``nodes`` draws from (coverage histogram n)."""
        ...

    def sample(self, key) -> RRBatch:
        """Sample one batch of RR sets; ``key`` is a jax PRNG key."""
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ENGINES: dict[str, type] = {}

# engines living outside core (to avoid core -> launch import cycles) are
# resolved by importing their home module on first lookup
_LAZY_ENGINES: dict[str, str] = {"queue_sharded": "repro.launch.im_solve"}


def register_engine(name: str):
    """Class decorator: register ``cls`` under ``name`` (sets ``cls.name``)."""
    def deco(cls):
        cls.name = name
        _ENGINES[name] = cls
        return cls
    return deco


def get_engine(name: str) -> type:
    if name not in _ENGINES and name in _LAZY_ENGINES:
        import importlib
        importlib.import_module(_LAZY_ENGINES[name])
    try:
        return _ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered: "
                       f"{sorted(set(_ENGINES) | set(_LAZY_ENGINES))}"
                       ) from None


def list_engines() -> list[str]:
    return sorted(set(_ENGINES) | set(_LAZY_ENGINES))


def make_engine(name: str, g_rev: CSRGraph, root_weights=None,
                **opts) -> "SamplerEngine":
    """Instantiate a registered engine on the reverse graph.

    ``opts`` may be a superset of the engine's ``Config`` fields — unknown
    keys and ``None`` values are dropped, so callers (``IMMSolver``) can pass
    one uniform option set (batch/qcap/ec/...) to any engine.

    ``root_weights`` (weighted IM) is forwarded to every registered engine:
    roots come out ∝ the weights through the shared alias table
    (:func:`draw_roots`); ``None`` keeps the historical uniform draw,
    bit-identical streams included.
    """
    cls = get_engine(name)
    fields = {f.name for f in dataclasses.fields(cls.Config)}
    cfg = cls.Config(**{k: v for k, v in opts.items()
                        if k in fields and v is not None})
    if root_weights is None:
        return cls(g_rev, cfg)
    return cls(g_rev, cfg, root_weights=root_weights)


def resolve_engine_name(engine: str, model: str = "ic") -> str:
    """Back-compat mapping from the old (engine, model) pair to an engine
    name: ``model="lt"`` overrides the IC engine choice (the LT walk sampler
    is the only LT engine)."""
    return "lt" if model == "lt" else engine


def resolve_qcap(qcap: Optional[int], g_rev: CSRGraph) -> int:
    """Default queue capacity: the whole node set (an RR set can never be
    larger, so the default never overflows)."""
    return qcap if qcap is not None else g_rev.n_nodes


class FusedSketchEngine:
    """Adapter marking an engine as the pool-free fused sample→sketch path
    (``IMProblem(mode="approximate")``, DESIGN.md §10).

    Sampling itself is untouched — every batch the inner engine emits is
    byte-for-byte what the exact path would have appended (so a fixed-θ
    approximate solve consumes the *identical* RNG stream as the exact
    one).  What changes is the destination: the solver pairs this adapter
    with a :class:`~repro.core.coverage.SketchRRStore`, whose
    ``append_batch`` folds the frontier straight into the packed per-node
    sketches and never allocates the flat pool.  The adapter exists so the
    solver signature / stats / checkpoints name the mode explicitly and
    so engine-specific extensions (``sample_device``, ``sample_sharded``,
    ``mesh``, ``device_resident``) pass through untouched.
    """

    def __init__(self, inner: "SamplerEngine"):
        self._inner = inner
        self.name = f"fused-sketch[{inner.name}]"

    def __getattr__(self, attr):
        # only consulted for attributes not set on the adapter itself —
        # delegates sample/sample_device/sample_sharded/mesh/
        # device_resident/... verbatim
        return getattr(self._inner, attr)

    @property
    def item_space(self) -> int:
        return self._inner.item_space


# ---------------------------------------------------------------------------
# Engine adapters
# ---------------------------------------------------------------------------

@jax.jit
def _row_roots(nodes):
    """First column of a root-first padded batch = per-row roots.  Jitted so
    the slice indices never cross host->device (legal under
    ``jax.transfer_guard("disallow")``)."""
    return nodes[:, 0].astype(jnp.int32)


def _resolve_root_table(root_weights):
    """(weights or None) -> (weights np array or None, AliasTable or None)."""
    if root_weights is None:
        return None, None
    w = np.asarray(root_weights, np.float32)
    return w, build_alias_table(w)


@register_engine("queue")
class QueueEngine:
    """gIM-faithful work-efficient sampler (paper Alg. 3/6; core/rrset.py).
    ``sample`` is one jit (root draw included) over device operands."""

    device_resident = True

    @dataclass(frozen=True)
    class Config:
        batch: int = 256
        qcap: Optional[int] = None   # default: n_nodes
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None,
                 root_weights=None):
        # IC equivalence: parallel edges merge to p' = 1-∏(1-p), making the
        # rows simple and the chunk dedup a no-op (detect returns "none")
        self.g_rev = coalesce_ic(g_rev)
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, self.g_rev)
        self._dedup = rr_queue.detect_dedup_mode(self.g_rev)
        self.root_weights, self._root_table = _resolve_root_table(root_weights)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        s = rr_queue.sample_rrsets_queue(key, self.g_rev, self.config.batch,
                                         self.qcap, self.config.ec,
                                         dedup=self._dedup,
                                         root_table=self._root_table)
        return RRBatch.make(s.nodes, s.lengths, s.overflowed, s.steps,
                            roots=s.roots)


@register_engine("dense")
class DenseEngine:
    """Dense-frontier masked-SpMV sampler (core/dense.py); membership is
    converted to padded rows by one device rank-scatter inside the same jit
    as the BFS (``edge_src`` is precomputed once here, not per round)."""

    device_resident = True

    @dataclass(frozen=True)
    class Config:
        batch: int = 256

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None,
                 root_weights=None):
        self.g_rev = coalesce_ic(g_rev)      # exact for IC, fewer edges
        self.config = config if config is not None else self.Config()
        self._edge_src = rr_dense._edge_src(self.g_rev)
        self.root_weights, self._root_table = _resolve_root_table(root_weights)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        g = self.g_rev
        nodes, lens, roots, overflow, levels = rr_dense._dense_round(
            key, self._edge_src, g.indices, g.weights, self._root_table,
            batch=self.config.batch, n=g.n_nodes, m=g.n_edges)
        return RRBatch.make(nodes, lens, overflow, levels, roots=roots)


@register_engine("refill")
class RefillEngine:
    """Persistent-lane worker (paper Alg. 6): lanes refill with fresh roots
    until ``batch`` RR sets are complete; a sample may return slightly more
    than ``batch`` rows (in-flight sets always finish, unbiased)."""

    device_resident = True

    @dataclass(frozen=True)
    class Config:
        batch: int = 256             # quota: target RR sets per sample()
        lanes: Optional[int] = None  # default: batch//2 clamped to [8, 512]
        out_cap: Optional[int] = None
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None,
                 root_weights=None):
        self.g_rev = coalesce_ic(g_rev)
        cfg = config if config is not None else self.Config()
        self.config = cfg
        # wide lane count: lockstep micro-steps (the dominant cost, fixed
        # overhead per step) scale ~1/lanes; the paper's Alg. 6 likewise
        # sizes persistent blocks to fill the machine
        self.lanes = (cfg.lanes if cfg.lanes is not None
                      else max(min(cfg.batch // 2, 512), 8))
        self.out_cap = (cfg.out_cap if cfg.out_cap is not None
                        else min(8 * cfg.batch // self.lanes, 64) * 64)
        self._dedup = rr_queue.detect_dedup_mode(self.g_rev)
        self.root_weights, self._root_table = _resolve_root_table(root_weights)
        if (self._root_table is not None
                and self.g_rev.n_nodes > ONE_UNIFORM_MAX_N):
            raise ValueError(
                "weighted refill roots use the one-uniform alias draw, "
                f"which is only exact for n <= {ONE_UNIFORM_MAX_N}; use the "
                "queue or dense engine for weighted IM on larger graphs")

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def _sample_raw(self, key):
        return rr_queue.sample_rrsets_refill(key, self.g_rev, self.lanes,
                                             quota=self.config.batch,
                                             out_cap=self.out_cap,
                                             ec=self.config.ec,
                                             dedup=self._dedup,
                                             root_table=self._root_table)

    def sample(self, key) -> RRBatch:
        s = self._sample_raw(key)
        nodes, lens = rr_queue.refill_to_padded(s)
        # refill rows are root-first (each set's segment starts with the
        # root that seeded the lane), so the row root is column 0
        return RRBatch.make(nodes, lens, s.overflowed, s.steps,
                            roots=_row_roots(jnp.asarray(nodes)))

    def sample_device(self, key) -> RRBatch:
        """Fixed-shape device unpack: every (lane, slot) becomes a row,
        unfinished slots as zero-length padding rows.  Same sample stream as
        ``sample`` (identical key splits), but no host round-trip and a
        shape that never depends on the data."""
        s = self._sample_raw(key)
        nodes, lens = rr_queue.refill_to_padded_device(s.flat, s.lengths,
                                                       s.n_done)
        return RRBatch.make(nodes, lens, s.overflowed, s.steps,
                            roots=_row_roots(nodes))


@register_engine("lt")
class LTEngine:
    """Linear-threshold walk sampler (paper §3.7; core/lt.py).  The
    segmented weight cumsum is built once here (the historical path redid
    that host pass — and its upload — every round)."""

    device_resident = True

    @dataclass(frozen=True)
    class Config:
        batch: int = 256
        qcap: Optional[int] = None

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None,
                 root_weights=None):
        self.g_rev = g_rev
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, g_rev)
        self._rowcum = rr_lt.row_cumweights(g_rev)
        self.root_weights, self._root_table = _resolve_root_table(root_weights)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        g = self.g_rev
        nodes, lengths, roots, overflowed, steps = rr_lt._lt_round(
            key, g.offsets, g.indices, self._rowcum, self._root_table,
            batch=self.config.batch, qcap=self.qcap,
            n=g.n_nodes, m=g.n_edges)
        return RRBatch.make(nodes, lengths, overflowed, steps, roots=roots)


@functools.partial(jax.jit,
                   static_argnames=("batch", "t", "qcap", "ec", "n", "m",
                                    "dedup"))
def _mrim_round(key, offsets, indices, weights, root_table, *, batch, t, qcap,
                ec, n, m, dedup="sort"):
    """Root draw + T tagged BFS + segment merge as ONE jit (device path).
    Key-split structure matches the historical host implementation, keeping
    sample streams bit-identical."""
    key, kroot, ksample = jax.random.split(key, 3)
    roots = draw_roots(kroot, batch, n, root_table)
    tiled_roots = jnp.repeat(roots, t)                # lane b*T+r -> root b
    nodes, lengths, overflowed, steps = rr_queue._sample_queue(
        ksample, offsets, indices, weights, tiled_roots,
        batch=batch * t, qcap=qcap, ec=ec, n=n, m=m, dedup=dedup)
    rounds = jnp.tile(jnp.arange(t, dtype=jnp.int32), batch)
    enc = (nodes + (rounds * n)[:, None]).reshape(batch, t * qcap)
    lane_len = lengths.reshape(batch, t)
    # valid positions: within each lane's segment, first lane_len entries
    seg = jnp.arange(t * qcap, dtype=jnp.int32) // qcap
    pos = jnp.arange(t * qcap, dtype=jnp.int32) % qcap
    mask = pos[None, :] < lane_len[:, seg]
    out_nodes, out_lens = pack_rows_device(enc, mask)
    overflow = overflowed.reshape(batch, t).any(axis=1)
    return out_nodes, out_lens, roots, overflow, steps


@register_engine("mrim")
class MRIMEngine:
    """Multi-round IM sampler (paper §4.8): each RR sample is T tagged BFS
    from a shared root, run as T adjacent queue-engine lanes; elements are
    encoded ``round * n + node`` so coverage machinery is reused verbatim on
    an item space of n·T.  Lane segments are merged into one padded row per
    sample by a device rank-scatter inside the sampling jit."""

    device_resident = True

    @dataclass(frozen=True)
    class Config:
        batch: int = 64
        t_rounds: int = 2
        qcap: Optional[int] = None
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None,
                 root_weights=None):
        self.g_rev = coalesce_ic(g_rev)
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, self.g_rev)
        self._dedup = rr_queue.detect_dedup_mode(self.g_rev)
        self.root_weights, self._root_table = _resolve_root_table(root_weights)
        if self.item_space >= np.iinfo(np.int32).max:
            raise ValueError("n_nodes * t_rounds must fit int32")

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes * self.config.t_rounds

    def sample(self, key) -> RRBatch:
        g, cfg = self.g_rev, self.config
        out_nodes, out_lens, roots, overflow, steps = _mrim_round(
            key, g.offsets, g.indices, g.weights, self._root_table,
            batch=cfg.batch, t=cfg.t_rounds, qcap=self.qcap, ec=cfg.ec,
            n=g.n_nodes, m=g.n_edges, dedup=self._dedup)
        return RRBatch.make(out_nodes, out_lens, overflow, steps, roots=roots)

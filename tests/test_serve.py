"""IM-as-a-service serving layer (DESIGN.md §7).

Contracts under test (ISSUE acceptance criteria):
* ``signature_digest``/``pool_digest`` are collision-safe content hashes —
  two problems differing only in node_weights *values* never share a
  solver pool or a cache entry;
* pool ownership transfers explicitly: ``export_pool`` empties the solver,
  ``adopt_pool`` resumes the RNG stream bit-identically;
* a batched multi-request run (mixed k/candidates, one fixed θ) returns
  seeds bit-identical to solving each request alone on a fresh solver;
* micro-batch grouping: requests batch together iff they share the
  registry key (graph, pool signature, θ) — differing θ or node_weights
  split;
* cache hits return the same object bit-identically, recomputes agree;
* admission control: queue-full sheds with ``QueueFullError``, expired
  deadlines raise ``DeadlineExpiredError``, both typed;
* ``execute_batch`` runs under an outer ``jax.transfer_guard("disallow")``;
* the registry evicts LRU under ``max_solvers`` and the byte budget;
* the im_solve CLI rejects out-of-range candidates / wrong-length weights
  with a clear one-line error (parse-time validation, no traceback).
"""
import asyncio
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.serve import (DeadlineExpiredError, IMService, InvalidProblemError,
                         QueueFullError, ResultCache, ServeConfig,
                         UnknownGraphError, WarmSolverRegistry, build_service,
                         execute_batch, occur_fastpath_eligible)

OPTS = {"batch": 32, "seed": 7}
THETA = 1024


def _wc_graph(n=60, m=300, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


@pytest.fixture(scope="module")
def g():
    return _wc_graph()


# ------------------------------------------------- digests (satellite a)

def test_digests_distinguish_node_weight_values(g):
    """Regression: the old tuple pool key hashed weights by identity-ish
    metadata; two problems differing only in node_weights *values* must
    never share a pool signature, a solver pool, or a cache entry."""
    w1 = np.ones(g.n_nodes, np.float32)
    w2 = np.ones(g.n_nodes, np.float32)
    w2[-1] = 2.0
    p1 = IMProblem(k=2, theta=THETA, node_weights=w1)
    p2 = IMProblem(k=2, theta=THETA, node_weights=w2)
    assert p1.pool_digest(model="ic") != p2.pool_digest(model="ic")
    assert p1.signature_digest() != p2.signature_digest()
    # same values -> equal digests (content, not object identity)
    assert p1.pool_digest(model="ic") == \
        IMProblem(k=5, theta=2 * THETA,
                  node_weights=w1.copy()).pool_digest(model="ic")

    reg = WarmSolverRegistry(solver_opts=OPTS)
    reg.add_graph("g", g)
    assert reg.solver_key("g", p1) != reg.solver_key("g", p2)
    assert reg.cache_key("g", p1) != reg.cache_key("g", p2)
    assert reg.get("g", p1) is not reg.get("g", p2)

    # the solver's own prepare key: switching weights drops the pool
    s = IMMSolver(g, **OPTS)
    s.prepare(p1)
    sig1 = s._sig
    s.prepare(p2)
    assert s._sig != sig1


def test_signature_digest_covers_every_field(g):
    base = IMProblem(k=2, theta=THETA)
    variants = [
        IMProblem(k=3, theta=THETA),
        IMProblem(k=2, theta=THETA + 1),
        IMProblem(k=2, theta=THETA, eps=0.3),
        IMProblem(k=2, theta=THETA, candidates=np.arange(5)),
        IMProblem(k=2, theta=THETA, model="lt"),
        IMProblem(k=2, theta=THETA, ell=2.0),
    ]
    digests = {p.signature_digest() for p in [base] + variants}
    assert len(digests) == len(variants) + 1


# ------------------------------------- pool ownership transfer (tentpole)

def test_export_adopt_pool_resumes_bit_identically(g):
    p = IMProblem(k=3, theta=THETA)
    ref = IMMSolver(g, **OPTS).solve(IMProblem(k=3, theta=2 * THETA))

    s1 = IMMSolver(g, **OPTS)
    s1.solve(p)                          # pool at θ=1024, RNG mid-stream
    lease = s1.export_pool()
    assert lease.pool_bytes() > 0
    assert s1.pool_bytes() == 0          # exporter no longer owns buffers
    with pytest.raises(RuntimeError):
        s1.export_pool()                 # nothing left to export

    s2 = IMMSolver(g, **OPTS)
    s2.adopt_pool(lease)
    got = s2.solve(IMProblem(k=3, theta=2 * THETA))   # resume 1024 -> 2048
    np.testing.assert_array_equal(ref.seeds, got.seeds)
    assert ref.spread == got.spread


# ------------------------------------------- batching (acceptance gate)

def test_batched_requests_bit_identical_to_fresh_solvers(g):
    cand = np.arange(10, 40)
    problems = [
        IMProblem(k=1, theta=THETA),
        IMProblem(k=5, theta=THETA),
        IMProblem(k=1, theta=THETA, candidates=cand),
        IMProblem(k=3, theta=THETA, candidates=cand),
    ]
    fresh = [IMMSolver(g, **OPTS).solve(p) for p in problems]
    warm = IMMSolver(g, **OPTS)
    assert occur_fastpath_eligible(warm, problems[0])
    assert occur_fastpath_eligible(warm, problems[2])
    assert not occur_fastpath_eligible(warm, problems[1])
    batched = execute_batch(warm, problems)
    for a, b in zip(fresh, batched):
        np.testing.assert_array_equal(a.seeds, b.seeds)
        np.testing.assert_array_equal(a.gains, b.gains)
        assert a.frac == b.frac and a.spread == b.spread
        assert a.seeds.dtype == b.seeds.dtype
        assert a.gains.dtype == b.gains.dtype


def test_execute_batch_under_transfer_guard(g):
    problems = [IMProblem(k=1, theta=THETA), IMProblem(k=2, theta=THETA)]
    solver = IMMSolver(g, **OPTS)
    with jax.transfer_guard("disallow"):
        got = execute_batch(solver, problems)
    ref = IMMSolver(g, **OPTS).solve(problems[1])
    np.testing.assert_array_equal(got[1].seeds, ref.seeds)


# ------------------------------------------- grouping / splitting rules

def test_solver_key_batches_compatible_splits_incompatible(g):
    reg = WarmSolverRegistry(solver_opts=OPTS)
    reg.add_graph("g", g)
    a = IMProblem(k=1, theta=THETA)
    assert reg.solver_key("g", a) == \
        reg.solver_key("g", IMProblem(k=9, theta=THETA))   # k differs: batch
    assert reg.solver_key("g", a) == reg.solver_key(
        "g", IMProblem(k=1, theta=THETA, candidates=np.arange(7)))
    # θ, node_weights, model, t_rounds split the batch
    assert reg.solver_key("g", a) != \
        reg.solver_key("g", IMProblem(k=1, theta=2 * THETA))
    assert reg.solver_key("g", a) != reg.solver_key(
        "g", IMProblem(k=1, theta=THETA,
                       node_weights=np.ones(g.n_nodes)))
    assert reg.solver_key("g", a) != \
        reg.solver_key("g", IMProblem(k=1, theta=THETA, model="lt"))
    assert reg.solver_key("g", a) != \
        reg.solver_key("g", IMProblem(k=1, eps=0.5))       # ε-driven


def test_incompatible_thetas_split_into_two_batches(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(
            max_batch=8, batch_window_s=0.01, solver_opts=OPTS))
        async with svc:
            await asyncio.gather(
                svc.submit("g", IMProblem(k=1, theta=THETA)),
                svc.submit("g", IMProblem(k=2, theta=THETA)),
                svc.submit("g", IMProblem(k=1, theta=2 * THETA)))
        return svc.stats()
    st = asyncio.run(run())
    assert st.served == 3 and st.batches == 2
    assert st.registry.solvers == 2      # one warm solver per θ
    assert st.batch_occupancy_max == 2


# ----------------------------------------------------- cache semantics

def test_cache_hit_bit_identical_to_recompute(g):
    p = IMProblem(k=4, theta=THETA)

    async def run():
        svc = build_service({"g": g}, ServeConfig(solver_opts=OPTS))
        async with svc:
            r1 = await svc.submit("g", p)
            r2 = await svc.submit("g", p)            # front-door cache hit
        # recompute on a fresh service (empty cache)
        svc2 = build_service({"g": g}, ServeConfig(solver_opts=OPTS))
        async with svc2:
            r3 = await svc2.submit("g", p)
        return r1, r2, r3
    r1, r2, r3 = asyncio.run(run())
    assert not r1.cached and r2.cached and not r3.cached
    assert r2.result is r1.result        # the cache returns the stored object
    np.testing.assert_array_equal(r1.result.seeds, r3.result.seeds)
    assert r1.result.spread == r3.result.spread


def test_result_cache_lru_eviction_counters():
    c = ResultCache(max_entries=2)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1               # touch: b becomes LRU
    c.put("c", 3)                        # evicts b
    assert c.get("b") is None
    s = c.snapshot()
    assert (s.hits, s.misses, s.evictions, s.entries) == (1, 1, 1, 2)
    assert s.hit_rate == 0.5


# ------------------------------------------------- admission control

def test_queue_full_sheds_with_typed_error(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(
            queue_cap=1, solver_opts=OPTS))
        # no worker: the queue cannot drain, so admission is deterministic
        svc._queue = asyncio.Queue(maxsize=1)
        first = asyncio.ensure_future(
            svc.submit("g", IMProblem(k=1, theta=THETA)))
        await asyncio.sleep(0)           # let it enqueue
        with pytest.raises(QueueFullError):
            await svc.submit("g", IMProblem(k=2, theta=THETA))
        assert svc.shed == 1
        first.cancel()
    asyncio.run(run())


def test_expired_deadline_raises_typed_error(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(solver_opts=OPTS))
        async with svc:
            with pytest.raises(DeadlineExpiredError):
                await svc.submit("g", IMProblem(k=1, theta=THETA),
                                 deadline_s=-0.001)
            ok = await svc.submit("g", IMProblem(k=1, theta=THETA),
                                  deadline_s=30.0)
        return svc.stats(), ok
    st, ok = asyncio.run(run())
    assert st.expired == 1 and st.served == 1
    assert len(ok.result.seeds) == 1


def test_invalid_requests_rejected_before_admission(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(solver_opts=OPTS))
        async with svc:
            with pytest.raises(UnknownGraphError):
                await svc.submit("nope", IMProblem(k=1, theta=THETA))
            with pytest.raises(InvalidProblemError):
                await svc.submit("g", IMProblem(
                    k=1, theta=THETA,
                    candidates=np.array([g.n_nodes + 5])))
        return svc.stats()
    st = asyncio.run(run())
    assert st.failed == 2 and st.served == 0 and st.batches == 0


# ------------------------------------------------- registry eviction

def test_registry_max_solvers_lru_eviction(g):
    reg = WarmSolverRegistry(max_solvers=2, solver_opts=OPTS)
    reg.add_graph("g", g)
    thetas = (THETA, 2 * THETA, 4 * THETA)
    for t in thetas:
        e = reg.get("g", IMProblem(k=1, theta=t))
        e.solver.solve(IMProblem(k=1, theta=t))
        reg.account(e)
    st = reg.snapshot()
    assert st.solvers == 2 and st.evictions == 1
    assert st.bytes_freed > 0
    # LRU: θ=1024 (oldest) was the victim
    keys = {k[2] for k in reg.entries}
    assert keys == {2 * THETA, 4 * THETA}


def test_registry_memory_budget_eviction(g):
    reg = WarmSolverRegistry(solver_opts=OPTS)
    reg.add_graph("g", g)
    e1 = reg.get("g", IMProblem(k=1, theta=THETA))
    e1.solver.solve(IMProblem(k=1, theta=THETA))
    reg.account(e1)
    one_pool = reg.bytes_in_use()
    assert one_pool == e1.solver.pool_bytes() > 0
    # the θ=2048 pool is ~2 pools' worth (capacity doubling); budget fits
    # it alone but not alongside the θ=1024 pool
    reg.memory_budget_bytes = int(2.5 * one_pool)
    e2 = reg.get("g", IMProblem(k=1, theta=2 * THETA))
    e2.solver.solve(IMProblem(k=1, theta=2 * THETA))
    reg.account(e2)
    st = reg.snapshot()
    assert st.evictions == 1 and st.solvers == 1
    assert st.bytes_in_use <= reg.memory_budget_bytes
    assert list(reg.entries.values())[0] is e2      # LRU kept the newest


# --------------------------------------------- im_solve CLI (satellite f)

def _run_cli(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.im_solve",
         "--n", "50", "--k", "2", *extra],
        env=env, capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))


def test_cli_rejects_out_of_range_candidates_and_bad_weights():
    r = _run_cli("--candidates", "5,49,50,120")
    assert r.returncode != 0
    assert "Traceback" not in r.stderr
    assert "out of range" in r.stderr and "n=50" in r.stderr
    r = _run_cli("--weights", "1,2,3")
    assert r.returncode != 0
    assert "Traceback" not in r.stderr
    assert "3 entries" in r.stderr and "n=50" in r.stderr


# --------------------------- streaming graphs (DESIGN.md §9, satellite a)

def test_reregistering_graph_with_new_edges_misses_cache_and_evicts(g):
    """Regression for the stale-graph serving bug: registry/cache keys used
    to embed only the graph *name*, so re-registering a name with different
    edges kept serving pre-replacement pools and cached results."""
    g2 = _wc_graph(seed=99)          # same n, different edges
    p = IMProblem(k=3, theta=THETA)

    reg = WarmSolverRegistry(solver_opts=OPTS)
    reg.add_graph("g", g)
    k1 = reg.solver_key("g", p)
    c1 = reg.cache_key("g", p)
    e = reg.get("g", p)
    e.solver.solve(p)
    reg.account(e)
    assert reg.graph_version("g") == 0

    reg.add_graph("g", g)            # identical content: no replacement
    assert reg.graph_version("g") == 0
    assert reg.snapshot().graph_replacements == 0
    assert k1 in reg.entries

    reg.add_graph("g", g2)           # new content: keys rotate, entry dies
    assert reg.graph_version("g") == 1
    st = reg.snapshot()
    assert st.graph_replacements == 1 and st.evictions == 1
    assert st.bytes_freed > 0
    assert k1 not in reg.entries and not reg.entries
    assert reg.solver_key("g", p) != k1
    assert reg.cache_key("g", p) != c1

    async def run():
        svc = build_service({"g": g}, ServeConfig(solver_opts=OPTS))
        async with svc:
            r1 = await svc.submit("g", p)
            r1b = await svc.submit("g", p)       # warm-path cache hit
            svc.registry.add_graph("g", g2)      # mutate behind the name
            r2 = await svc.submit("g", p)        # must MISS the stale cache
            r2b = await svc.submit("g", p)
        return r1, r1b, r2, r2b
    r1, r1b, r2, r2b = asyncio.run(run())
    assert not r1.cached and r1b.cached
    assert not r2.cached and r2b.cached
    assert r2b.result is r2.result
    # the post-replacement answer is the g2 answer, not a pre-delta relic
    ref = IMMSolver(g2, **OPTS).solve(p)
    np.testing.assert_array_equal(r2.result.seeds, ref.seeds)
    assert r2.result.spread == ref.spread


def test_eps_driven_pool_staleness_watermark_refreshes(g):
    """Satellite c: ε-driven entries share one growing pool; the resample
    watermark (``max_pool_staleness``) bounds how many solve epochs may be
    served off it before a forced fresh resample."""
    async def run():
        svc = build_service({"g": g}, ServeConfig(
            solver_opts=OPTS, max_pool_staleness=2))
        async with svc:
            for k in (1, 2, 3, 4, 5):            # distinct: no cache hits
                await svc.submit("g", IMProblem(k=k, eps=0.5))
            st = svc.stats()
        return st
    st = asyncio.run(run())
    # sequential submits -> staleness walks 1,2,(refresh)1,2,(refresh)1
    assert st.served == 5
    assert st.refreshes == 2
    assert st.pool_staleness == 1
    assert st.registry.pool_refreshes == 2
    assert st.registry.bytes_freed > 0

    # fixed-θ entries never trip the watermark (their pools are immutable
    # at θ rows; staleness is an ε-mode concept)
    async def run_theta():
        svc = build_service({"g": g}, ServeConfig(
            solver_opts=OPTS, max_pool_staleness=1))
        async with svc:
            for k in (1, 2, 3):
                await svc.submit("g", IMProblem(k=k, theta=THETA))
            st = svc.stats()
        return st
    st = asyncio.run(run_theta())
    assert st.served == 3 and st.refreshes == 0 and st.pool_staleness == 0

"""Distributed IM solve: the paper's pipeline on an N-device mesh.

Every device runs the batched queue sampler on its own threefry counter
range (gIM's grid dimension -> mesh dimension, DESIGN.md §4); the per-device
rows are stacked into one canonical :class:`~repro.core.engine.RRBatch`, so
the whole pipeline is just ``IMMSolver`` driving a ``SamplerEngine`` whose
``sample()`` happens to fan out over the mesh.  Works on any device count
(elastic); on this CPU container use XLA_FLAGS to fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.im_solve --n 2000 --k 10
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked
from repro.graph import csr, generators, weights
from repro.core import rrset
from repro.core.engine import (RRBatch, build_alias_table, draw_roots,
                               register_engine, resolve_qcap)
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.launch.mesh import make_sample_mesh


@register_engine("queue_sharded")
class ShardedQueueEngine:
    """Queue engine fanned out over a device mesh (one lane block per device).

    ``batch`` is per-device; a ``sample()`` returns ``n_dev * batch`` rows.
    Per-device keys are derived by folding the device index into the caller's
    key, mirroring gIM's per-block curand streams.
    """

    device_resident = True           # sample() is one jitted shard_map call

    @dataclass(frozen=True)
    class Config:
        batch: int = 128             # RR sets per device per round
        qcap: Optional[int] = None
        ec: int = rrset.EC_DEFAULT

    def __init__(self, g_rev, config: Optional[Config] = None,
                 mesh: Optional[Mesh] = None, root_weights=None):
        self.g_rev = csr.coalesce_ic(g_rev)
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, self.g_rev)
        self._dedup = rrset.detect_dedup_mode(self.g_rev)
        self.mesh = mesh if mesh is not None else Mesh(
            np.asarray(jax.devices()), ("dev",))
        self.root_weights = (None if root_weights is None
                             else np.asarray(root_weights, np.float32))
        self._table = (None if root_weights is None
                       else build_alias_table(self.root_weights))
        self._fn = None

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def _build(self):
        g_rev, mesh = self.g_rev, self.mesh
        n, m = g_rev.n_nodes, g_rev.n_edges
        axis = mesh.axis_names[0]
        bpd, qcap, ec = self.config.batch, self.qcap, self.config.ec
        dedup = self._dedup

        # the alias table joins the pre-placed replicated operands (graph
        # arrays below get the same treatment): closing over explicitly
        # replicated arrays keeps the per-round call free of implicit
        # cross-device transfers under the solver's transfer guard
        rep0 = NamedSharding(self.mesh, P())
        table = (None if self._table is None else type(self._table)(
            *(jax.device_put(x, rep0) for x in self._table)))

        def local(offsets, indices, w, keydata):
            # full 128-bit key state travels as raw uint32 data (typed keys
            # don't cross shard_map on older jax); fold_in(dev) gives each
            # device its own collision-free stream, like gIM's per-block
            # curand sequences
            dev = jax.lax.axis_index(axis).astype(jnp.uint32)
            key = jax.random.fold_in(jax.random.wrap_key_data(keydata), dev)
            key, sub = jax.random.split(key)
            # uniform (table=None) is the historical randint, bit-identical;
            # weighted IM draws ∝ node_weights through the alias table
            roots = draw_roots(sub, bpd, n, table)
            nodes, lengths, overflow, steps = rrset._sample_queue(
                key, offsets, indices, w, roots,
                batch=bpd, qcap=qcap, ec=ec, n=n, m=m, dedup=dedup)
            return nodes[None], lengths[None], overflow[None], steps[None]

        # jit the shard_map so rounds hit a compiled executable (no
        # per-round retrace); graph operands are pre-placed replicated so
        # the per-round call does no *implicit* cross-device transfer (the
        # IMM driver holds transfer_guard("disallow") over the hot loop)
        rep = NamedSharding(mesh, P())
        self._replicated = tuple(
            jax.device_put(x, rep)
            for x in (g_rev.offsets, g_rev.indices, g_rev.weights))
        self._rep_sharding = rep
        return jax.jit(shard_map_unchecked(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P()),
            out_specs=(P(axis), P(axis), P(axis), P(axis))))

    def _sample_raw(self, key):
        if self._fn is None:
            self._fn = self._build()
        # the key broadcast is the fan-out's inherent data movement — an
        # *explicit* device_put (permitted under the transfer guard)
        keydata = jax.device_put(jax.random.key_data(key),
                                 self._rep_sharding)
        return self._fn(*self._replicated, keydata)

    def sample(self, key) -> RRBatch:
        nodes, lengths, overflow, steps = self._sample_raw(key)
        n_dev = self.mesh.devices.size
        dev0 = self.mesh.devices.reshape(-1)[0]
        # gather the per-device rows onto one device for a single-device
        # consumer (explicit device_puts, guard-legal)
        nodes, lengths, overflow, steps = (
            jax.device_put(x, dev0)
            for x in (nodes, lengths, overflow, steps))
        # devices run concurrently: the batch's parallel-time cost is the
        # slowest device's lockstep count, not the sum
        return RRBatch.make(nodes.reshape(n_dev * self.config.batch, -1),
                            lengths.reshape(-1), overflow.reshape(-1),
                            steps.max())

    def sample_sharded(self, key) -> RRBatch:
        """Mesh-native sample: the batch's *pool* arrays (nodes/lengths)
        stay sharded over the mesh — each device's rows resident where they
        were sampled, no dev0 gather.  A
        :class:`~repro.core.coverage.ShardedDeviceRRStore` on the same mesh
        re-lays them out with one explicit device_put.  Only the per-round
        *stats* (the steps scalar and the per-lane overflow flags) are
        explicitly gathered to one device for the solver's accumulators —
        O(lanes) bools instead of the O(rows·width) node gather ``sample``
        performs."""
        nodes, lengths, overflow, steps = self._sample_raw(key)
        n_dev = self.mesh.devices.size
        dev0 = self.mesh.devices.reshape(-1)[0]
        overflow, steps = (jax.device_put(x, dev0)
                           for x in (overflow, steps))
        return RRBatch.make(nodes.reshape(n_dev * self.config.batch, -1),
                            lengths.reshape(-1), overflow.reshape(-1),
                            steps.max())


def solve(g, k: int | None = None, eps: float | None = None, *,
          batch_per_dev: int = 128, seed: int = 0, selection: str = "auto",
          eval_batch: int | None = None, mesh=None,
          problem: IMProblem | None = None, fault_policy=None,
          checkpoint_dir: str | None = None, checkpoint_every: int = 0):
    """Distributed IM solve: sampler fan-out AND pool/selection sharing one
    mesh.  ``mesh=None`` builds a mesh over every local device; the engine
    samples on it, the solver's pool is sharded over it (``samples`` axis),
    and the per-device rows never leave the device that sampled them
    (``sample_sharded``).

    ``problem`` routes any :class:`~repro.core.problem.IMProblem` variant
    through the same mesh (weighted problems hand the engine their alias
    table; MRIM needs the tagged engine and is served by ``imm()`` /
    ``IMMSolver`` directly, not the sharded queue fan-out).

    ``checkpoint_dir`` makes the solve durable (DESIGN.md §8): the pool is
    checkpointed every ``checkpoint_every`` sampling rounds, and a
    pre-existing checkpoint in the directory is restored before solving —
    the solve resumes from the saved round watermark and stays bit-identical
    to an uninterrupted run.  ``fault_policy`` wraps the hot loop in
    retry-with-backoff (and powers ``--inject-fault`` drills).
    """
    mesh = mesh if mesh is not None else make_sample_mesh(None)
    if problem is None:
        if k is None or eps is None:
            raise TypeError("solve() needs either problem= or the (k, eps) "
                            "pair")
        problem = IMProblem(k=k, eps=eps)
    if problem.t_rounds is not None:
        raise ValueError("the sharded queue engine samples the plain node "
                         "space; solve MRIM via IMMSolver(g).solve(problem)")
    g_rev = csr.reverse(g)
    engine = ShardedQueueEngine(
        g_rev, ShardedQueueEngine.Config(batch=batch_per_dev), mesh=mesh,
        root_weights=problem.node_weights)
    solver = IMMSolver(g, engine=engine, seed=seed, selection=selection,
                       eval_batch=eval_batch, mesh=mesh,
                       fault_policy=fault_policy,
                       checkpoint_dir=checkpoint_dir,
                       checkpoint_every=checkpoint_every)
    resumed_step = None
    if checkpoint_dir is not None:
        from repro.ckpt import checkpoint as ckpt_mod
        if ckpt_mod.latest_step(checkpoint_dir) is not None:
            resumed_step = solver.restore_pool(checkpoint_dir)
    res = solver.solve_problem(problem)
    stats = res.stats
    return res.seeds, res.spread, dict(
        theta=stats.theta, sampled=stats.n_rr_sampled,
        selection=stats.selection, variant=stats.variant,
        n_seeds=len(res.seeds), cost=res.cost,
        devices=engine.mesh.devices.size,
        mesh_shape=stats.mesh_shape,
        pool_sharding=stats.pool_sharding,
        per_device_pool_bytes=stats.per_device_pool_bytes,
        resumed_step=resumed_step)


def _node_vector(spec: str, g, *, seed: int, name: str):
    """CLI node-vector spec -> (n,) float array: 'degree' (out-degree + 1),
    'random' (uniform [1, 2)), or a comma-separated list of n floats.
    Validated here, at parse time, so a bad spec is a one-line error
    instead of a traceback from deep inside the solver."""
    n = g.n_nodes
    if spec == "degree":
        return (np.diff(np.asarray(g.offsets)) + 1.0).astype(np.float32)
    if spec == "random":
        rng = np.random.default_rng(seed)
        return (1.0 + rng.random(n)).astype(np.float32)
    try:
        vals = np.asarray([float(x) for x in spec.split(",")], np.float32)
    except ValueError:
        raise SystemExit(
            f"--{name}: expected 'degree', 'random', or a comma-separated "
            f"list of floats, got {spec!r}") from None
    if vals.shape != (n,):
        raise SystemExit(
            f"--{name}: list has {vals.shape[0]} entries but the graph has "
            f"n={n} nodes — the vector must give one value per node")
    return vals


def _candidate_ids(spec: str, g):
    """CLI candidate spec -> id array: 'top:N' (highest out-degree) or a
    comma-separated id list.  Ids are range-checked against the graph at
    parse time (out-of-range ids used to surface as an opaque traceback
    from the selection kernels)."""
    n = g.n_nodes
    if spec.startswith("top:"):
        try:
            top = int(spec[4:])
        except ValueError:
            raise SystemExit(
                f"--candidates: 'top:N' needs an integer N, got "
                f"{spec!r}") from None
        if not 1 <= top <= n:
            raise SystemExit(
                f"--candidates: top:{top} out of range for a graph with "
                f"n={n} nodes (need 1 <= N <= n)")
        deg = np.diff(np.asarray(g.offsets))
        return np.argsort(-deg, kind="stable")[:top]
    try:
        ids = np.asarray([int(x) for x in spec.split(",")])
    except ValueError:
        raise SystemExit(
            f"--candidates: expected 'top:N' or a comma-separated list of "
            f"node ids, got {spec!r}") from None
    if ids.size == 0:
        raise SystemExit("--candidates: candidate set must be non-empty")
    bad = ids[(ids < 0) | (ids >= n)]
    if bad.size:
        raise SystemExit(
            f"--candidates: ids {sorted(set(bad.tolist()))} out of range "
            f"for a graph with n={n} nodes (valid ids are 0..{n - 1})")
    return ids


def _fault_policy(spec: str):
    """CLI fault-drill spec ``SITE[:N]`` -> FaultPolicy injecting one
    failure at the N-th crossing (default 1) of the named boundary.
    Site names are validated at parse time against ``ft.failures.SITES``
    so a typo is a one-line error, not a deep-solver traceback."""
    from repro.ft.failures import SITES, FaultInjector, FaultPolicy
    site, _, occ = spec.partition(":")
    if site not in SITES:
        raise SystemExit(
            f"--inject-fault: unknown site {site!r}; valid sites: "
            + ", ".join(SITES))
    if occ:
        try:
            n = int(occ)
        except ValueError:
            raise SystemExit(
                f"--inject-fault: occurrence must be an integer, got "
                f"{occ!r} (format: SITE or SITE:N)") from None
        if n < 1:
            raise SystemExit(
                f"--inject-fault: occurrence must be >= 1, got {n}")
    else:
        n = 1
    return FaultPolicy(injector=FaultInjector(fail_at={site: {n}}))


def _serve(args, g):
    """``--serve``: start the network serving surface
    (:class:`repro.serve.IMNetServer`) on an ephemeral local port, drive a
    generated mixed workload (varying k/candidates, repeats for cache
    hits) over real HTTP through :class:`repro.serve.IMClient`, and print
    the ServeStats counters read back from ``/statsz`` (DESIGN.md §7/§11).
    Ctrl-C drains cleanly — admission stops, in-flight batches flush,
    the loop shuts down — instead of a traceback."""
    import asyncio
    import signal

    from repro.serve import IMClient, IMNetServer, ServeConfig, \
        build_service

    theta = args.serve_theta
    deg = np.diff(np.asarray(g.offsets))
    top = np.argsort(-deg, kind="stable")
    base = [IMProblem(k=k, theta=theta) for k in (1, 2, args.k)]
    base += [IMProblem(k=1, theta=theta, candidates=top[:m])
             for m in (g.n_nodes // 4, g.n_nodes // 2)]
    workload = [base[i % len(base)] for i in range(args.serve)]

    async def run():
        svc = build_service({"graph": g}, ServeConfig(
            max_batch=8, batch_window_s=0.002,
            solver_opts={"batch": 64, "seed": 0,
                         "selection": args.selection}))
        server = IMNetServer(svc, host="127.0.0.1", port=0)
        await server.start()
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for s in (signal.SIGINT, signal.SIGTERM):
            loop.add_signal_handler(s, stop.set)
        client = IMClient("127.0.0.1", server.port)
        print(f"serving on http://127.0.0.1:{server.port} "
              f"({len(workload)} requests over HTTP)")
        t0 = time.time()
        work = asyncio.ensure_future(asyncio.gather(
            *(client.solve("graph", p) for p in workload),
            return_exceptions=True))
        stopper = asyncio.ensure_future(stop.wait())
        await asyncio.wait({work, stopper},
                           return_when=asyncio.FIRST_COMPLETED)
        if stop.is_set():
            work.cancel()
            try:
                await work
            except asyncio.CancelledError:
                pass
            await server.shutdown()
            print("\ninterrupted: admission stopped, in-flight batches "
                  "flushed, server drained cleanly")
            return
        stopper.cancel()
        sv = (await client.stats())["serve"]
        await server.shutdown()
        print(f"served={sv['served']} cache_hits={sv['cache_hits']} "
              f"batches={sv['batches']} "
              f"occupancy_mean={sv['batch_occupancy_mean']:.2f} "
              f"occur_fastpath={sv['occur_fastpath']} "
              f"stacked={sv['stacked_requests']} shed={sv['shed']} "
              f"expired={sv['expired']} time={time.time() - t0:.2f}s")
        print(f"registry: solvers={sv['registry']['solvers']} "
              f"bytes_in_use={sv['registry']['bytes_in_use']}")
    asyncio.run(run())


def _stream(args, g):
    """``--stream-deltas``: streaming-graph demo (DESIGN.md §9) — one cold
    solve, then ROUNDS random edge-delta batches through
    ``resolve_incremental``, printing the pool-reuse bookkeeping per
    round (kept rows never resample; θ tops back up on the mutated
    graph)."""
    from repro.core import stream

    rng = np.random.default_rng(11)
    problem = IMProblem(k=args.k, theta=args.stream_theta)
    solver = IMMSolver(g, engine="queue", batch=128, seed=0,
                       selection=args.selection, eval_batch=args.eval_batch)
    t0 = time.time()
    res = solver.solve(problem)
    print(f"cold: theta={res.stats.theta} "
          f"seeds={sorted(res.seeds.tolist())} estimate={res.spread:.1f} "
          f"time={time.time() - t0:.2f}s")
    n = g.n_nodes
    for r in range(args.stream_deltas):
        e = args.stream_edges
        deltas = stream.make_deltas(adds=(
            rng.integers(0, n, e), rng.integers(0, n, e),
            (0.05 + 0.25 * rng.random(e)).astype(np.float32)))
        t0 = time.time()
        res = solver.resolve_incremental(problem, deltas)
        info = solver.last_incremental
        print(f"delta[{r}]: +{deltas.n_adds} edges "
              f"affected={info['affected_nodes']} "
              f"kept={info['rows_kept']}/{info['n_rr_before']} "
              f"({info['surviving_fraction']:.1%}) "
              f"reused={info['reused']} "
              f"seeds={sorted(res.seeds.tolist())} "
              f"estimate={res.spread:.1f} time={time.time() - t0:.2f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--r", type=int, default=4)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--eps", type=float, default=0.4)
    ap.add_argument("--serve", type=int, default=None, metavar="REQUESTS",
                    help="serve a generated mixed workload of REQUESTS "
                         "requests through the micro-batched front instead "
                         "of one solve (DESIGN.md §7)")
    ap.add_argument("--serve-theta", type=int, default=4096,
                    help="fixed θ for --serve requests (θ-pinned requests "
                         "are bit-identical to cold solves)")
    ap.add_argument("--stream-deltas", type=int, default=None,
                    metavar="ROUNDS",
                    help="streaming-graph demo: apply ROUNDS random "
                         "edge-delta batches through the incremental "
                         "re-solve path, reusing untouched RR sets "
                         "(DESIGN.md §9)")
    ap.add_argument("--stream-edges", type=int, default=8,
                    help="edges added per --stream-deltas batch (default 8)")
    ap.add_argument("--stream-theta", type=int, default=4096,
                    help="fixed θ for --stream-deltas solves (default 4096)")
    ap.add_argument("--selection", default="auto",
                    choices=("auto", "fused", "bitset", "celf-sketch"),
                    help="seed-selection backend (DESIGN.md §3)")
    ap.add_argument("--eval-batch", type=int, default=None,
                    help="CELF exact-verification batch width (celf-sketch "
                         "selection); default: backend default (32).  Swept "
                         "by benchmarks/perf_im_engines --selection-only")
    ap.add_argument("--mesh", default=None,
                    help="device count or axis spec for the sampling mesh "
                         "(e.g. '4' or 'samples:8'; default: all devices)")
    ap.add_argument("--weights", default=None, metavar="SPEC",
                    help="weighted IM node weights: 'degree', 'random', or "
                         "a comma-separated list (DESIGN.md §6)")
    ap.add_argument("--costs", default=None, metavar="SPEC",
                    help="budgeted IM per-node costs (same specs as "
                         "--weights); requires --budget")
    ap.add_argument("--budget", type=float, default=None,
                    help="budgeted IM total budget (replaces --k)")
    ap.add_argument("--candidates", default=None, metavar="SPEC",
                    help="candidate restriction: 'top:N' (by out-degree) "
                         "or comma-separated node ids")
    ap.add_argument("--t-rounds", type=int, default=None,
                    help="MRIM round count (solved on the tagged mrim "
                         "engine, single-device pool)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="durable solve: checkpoint the pool into DIR every "
                         "--checkpoint-every rounds and auto-resume from an "
                         "existing checkpoint (DESIGN.md §8)")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    metavar="ROUNDS",
                    help="sampling rounds between pool checkpoints "
                         "(with --checkpoint-dir; default 8)")
    ap.add_argument("--inject-fault", default=None, metavar="SITE[:N]",
                    help="fault drill: inject one transient failure at the "
                         "N-th crossing of SITE (sample/append/grow/select/"
                         "executor; default N=1) and recover via the retry "
                         "policy")
    args = ap.parse_args()
    if args.checkpoint_every < 1:
        raise SystemExit("--checkpoint-every: must be >= 1, got "
                         f"{args.checkpoint_every}")
    fault_policy = (None if args.inject_fault is None
                    else _fault_policy(args.inject_fault))
    src, dst = generators.barabasi_albert(args.n, args.r, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, args.n))
    if args.serve is not None:
        _serve(args, g)
        return
    if args.stream_deltas is not None:
        if args.stream_deltas < 1 or args.stream_edges < 1:
            raise SystemExit("--stream-deltas/--stream-edges: must be >= 1")
        _stream(args, g)
        return
    problem = IMProblem(
        k=None if args.budget is not None else args.k,
        eps=args.eps,
        node_weights=(None if args.weights is None
                      else _node_vector(args.weights, g, seed=1,
                                        name="weights")),
        costs=(None if args.costs is None
               else _node_vector(args.costs, g, seed=2, name="costs")),
        budget=args.budget,
        candidates=(None if args.candidates is None
                    else _candidate_ids(args.candidates, g)),
        t_rounds=args.t_rounds)
    t0 = time.time()
    if args.t_rounds is not None:
        from repro.core.imm import imm_result
        res = imm_result(g, problem, selection=args.selection)
        print(f"variant={res.stats.variant} theta={res.stats.theta} "
              f"sampled={res.stats.n_rr_sampled} "
              f"selection={res.stats.selection} time={time.time() - t0:.2f}s")
        print(f"seeds_per_round={res.seeds_per_round()} "
              f"estimate={res.spread:.1f}")
        return
    seeds, est, stats = solve(g, selection=args.selection,
                              eval_batch=args.eval_batch,
                              mesh=make_sample_mesh(args.mesh),
                              problem=problem, fault_policy=fault_policy,
                              checkpoint_dir=args.checkpoint_dir,
                              checkpoint_every=args.checkpoint_every)
    print(f"devices={stats['devices']} mesh={stats['pool_sharding']} "
          f"pool_bytes/dev={stats['per_device_pool_bytes']} "
          f"theta={stats['theta']} sampled={stats['sampled']} "
          f"selection={stats['selection']} variant={stats['variant']} "
          f"cost={stats['cost']:.1f} time={time.time() - t0:.2f}s")
    if stats["resumed_step"] is not None:
        print(f"resumed from checkpoint step={stats['resumed_step']} "
              f"({args.checkpoint_dir})")
    if fault_policy is not None:
        inj = fault_policy.injector
        print(f"fault drill: injected={inj.fires} at={inj.fired_log} "
              f"retries={fault_policy.retries} "
              f"oom_recoveries={fault_policy.oom_recoveries} "
              f"gave_up={fault_policy.gave_up}")
    print(f"seeds={sorted(seeds.tolist())} estimate={est:.1f}")


if __name__ == "__main__":
    main()

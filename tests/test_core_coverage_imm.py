"""Coverage greedy vs. numpy oracle (exact), IMM end-to-end, LT, MRIM."""
import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov
from repro.core import oracle, lt as lt_mod, forward, mrim
from repro.core.imm import imm as imm_solve


def _wc_graph(n=60, m=240, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _random_rr_sets(n, count, rng, max_len=8):
    sets = []
    for _ in range(count):
        ln = int(rng.integers(1, max_len))
        sets.append(rng.choice(n, size=ln, replace=False).tolist())
    return sets


def test_greedy_matches_oracle_exactly():
    rng = np.random.default_rng(0)
    n, k = 50, 6
    rr = _random_rr_sets(n, 300, rng)
    store = cov.build_store(rr, n)
    res = cov.select_seeds(store, k)
    seeds_o, frac_o = oracle.greedy_max_coverage(rr, n, k)
    assert np.asarray(res.seeds).tolist() == seeds_o
    assert abs(float(res.frac) - frac_o) < 1e-6


def test_occur_histogram():
    rng = np.random.default_rng(1)
    n = 30
    rr = _random_rr_sets(n, 100, rng)
    store = cov.build_store(rr, n)
    occ = np.asarray(cov.occur_histogram(store))
    expect = np.zeros(n, dtype=np.int64)
    for row in rr:
        for v in row:
            expect[v] += 1
    np.testing.assert_array_equal(occ, expect)


def test_build_store_from_padded_arrays():
    nodes = np.asarray([[3, 1, 0, 0], [2, 0, 0, 0], [4, 5, 6, 0]])
    lens = np.asarray([2, 1, 3])
    store = cov.build_store((nodes, lens), 8)
    assert store.n_rr == 3
    flat = np.asarray(store.rr_flat)[np.asarray(store.valid)]
    assert flat.tolist() == [3, 1, 2, 4, 5, 6]
    ids = np.asarray(store.rr_ids)[np.asarray(store.valid)]
    assert ids.tolist() == [0, 0, 1, 2, 2, 2]


def test_merge_stores():
    s1 = cov.build_store([[0, 1], [2]], 5)
    s2 = cov.build_store([[3], [4, 0]], 5)
    m = cov.merge_stores([s1, s2])
    assert m.n_rr == 4
    res = cov.select_seeds(m, 1)
    assert int(res.seeds[0]) == 0  # node 0 covers 2 of 4 sets


def test_imm_pipeline_end_to_end_quality():
    """IMM (both engines) reaches the oracle IMM's influence spread."""
    g = _wc_graph(n=80, m=400, seed=2)
    k, eps = 4, 0.4
    # oracle IMM
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    seeds_o, _, theta_o = oracle.imm_oracle(offs, idx, w, g.n_nodes, k, eps,
                                            seed=0)
    rng = np.random.default_rng(123)
    foffs = np.asarray(g.offsets); fidx = np.asarray(g.indices)
    fw = np.asarray(g.weights)
    spread_o = oracle.forward_ic_spread(foffs, fidx, fw, seeds_o, rng, 300)
    for engine in ("queue", "dense"):
        seeds, est, stats = imm_solve(g, k, eps, engine=engine, batch=128,
                                    seed=1)
        assert len(set(seeds.tolist())) == k
        assert stats.theta > 0 and stats.n_rr_sampled >= stats.theta
        spread = oracle.forward_ic_spread(foffs, fidx, fw, seeds.tolist(),
                                          rng, 300)
        # same quality within 15% (both are (1-1/e-eps) approximations)
        assert spread >= 0.85 * spread_o, (engine, spread, spread_o)


def test_rr_spread_estimator_matches_forward_mc():
    """Eq. (3): n * Pr[S cap RR != 0] ~= E[I(S)] (statistical)."""
    g = _wc_graph(n=50, m=250, seed=4)
    g_rev = csr_mod.reverse(g)
    seeds = [0, 7, 13]
    from repro.core import rrset
    hits, total = 0, 0
    for i in range(8):
        s = rrset.sample_rrsets_queue(jax.random.key(i), g_rev, 256,
                                      qcap=g.n_nodes)
        for row in rrset.to_lists(s):
            total += 1
            if set(row) & set(seeds):
                hits += 1
    est_ris = g.n_nodes * hits / total
    est_fwd = forward.ic_spread(jax.random.key(99), g, seeds, n_sims=2048)
    assert abs(est_ris - est_fwd) / est_fwd < 0.15, (est_ris, est_fwd)


# ---------------------------------------------------------------------- LT

def test_lt_walk_validity():
    g = _wc_graph(n=50, m=300, seed=5)   # WC: in-weights sum to 1 -> valid LT
    g_rev = csr_mod.reverse(g)
    s = lt_mod.sample_rrsets_lt(jax.random.key(0), g_rev, batch=64,
                                qcap=g.n_nodes)
    nodes = np.asarray(s.nodes); lens = np.asarray(s.lengths)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    for b in range(64):
        row = nodes[b, :lens[b]].tolist()
        assert len(set(row)) == len(row)
        # consecutive nodes connected in reverse graph
        for u, v in zip(row, row[1:]):
            assert v in idx[offs[u]:offs[u + 1]].tolist()


def test_lt_matches_oracle_statistically():
    g = _wc_graph(n=40, m=240, seed=6)
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rng = np.random.default_rng(0)
    total = 1024
    occ_o = np.zeros(g.n_nodes)
    for _ in range(total):
        for v in oracle.rr_set_lt(offs, idx, w, int(rng.integers(g.n_nodes)), rng):
            occ_o[v] += 1
    occ_j = np.zeros(g.n_nodes)
    for i in range(total // 128):
        s = lt_mod.sample_rrsets_lt(jax.random.key(i), g_rev, 128,
                                    qcap=g.n_nodes)
        nodes = np.asarray(s.nodes); lens = np.asarray(s.lengths)
        for b in range(128):
            occ_j[nodes[b, :lens[b]]] += 1
    p_o, p_j = occ_o / total, occ_j / total
    se = np.sqrt((p_o * (1 - p_o) + p_j * (1 - p_j)) / total) + 1e-9
    z = np.abs(p_o - p_j) / se
    assert z.max() < 4.5, f"max z={z.max():.2f}"


def test_imm_lt_model_runs():
    g = _wc_graph(n=60, m=300, seed=7)
    seeds, est, stats = imm_solve(g, 3, 0.45, model="lt", batch=128, seed=3)
    assert len(set(seeds.tolist())) == 3
    # estimate within 25% of forward LT MC
    fwd = forward.lt_spread(jax.random.key(5), g, seeds.tolist(), n_sims=1024)
    assert abs(est - fwd) / fwd < 0.25, (est, fwd)


# -------------------------------------------------------------------- MRIM

def test_mrim_budgets_and_quality():
    g = _wc_graph(n=50, m=250, seed=8)
    res = mrim.solve_mrim(g, k=2, t_rounds=3, n_rr=512, batch=64, seed=0)
    assert len(res.seeds_per_round) == 3
    for s in res.seeds_per_round:
        assert len(s) == 2
    # spread of T rounds of k seeds >= spread of single round (monotonicity)
    single = mrim.solve_mrim(g, k=2, t_rounds=1, n_rr=512, batch=64, seed=0)
    assert res.spread_estimate >= single.spread_estimate * 0.95

"""Mesh-agnostic checkpointing (fault tolerance + elastic resume).

Design (no orbax in this container, so built from primitives):

* state pytrees are saved as host numpy arrays in an ``.npz`` per checkpoint,
  plus a json manifest (step, pytree structure, value metadata);
* writes are atomic (tmp dir + ``os.replace``) so a mid-write failure never
  corrupts the latest checkpoint;
* ``keep`` rotation; ``latest_step`` discovery for restart;
* arrays are saved **unsharded** (host-gathered), so a checkpoint written on
  a 256-chip mesh restores onto any other mesh — elastic scaling is a load
  with different shardings, verified in tests/test_ckpt_ft.py.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(k), v) for k, v in flat], treedef


def save(ckpt_dir: str, step: int, state, *, keep: int = 3,
         meta: dict | None = None) -> str:
    """``meta``: json-serializable dict stored alongside the arrays in the
    manifest — format/version tags, problem digests, anything the restorer
    needs before it can build a ``like`` pytree."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten_with_paths(state)
    arrays = {f"a{i}": np.asarray(v) for i, (k, v) in enumerate(flat)}
    manifest = {
        "step": int(step),
        "keys": [k for k, _ in flat],
        "dtypes": [str(np.asarray(v).dtype) for _, v in flat],
        "shapes": [list(np.asarray(v).shape) for _, v in flat],
        "meta": meta or {},
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:012d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.startswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """Read a checkpoint's manifest (including ``meta``) without touching
    the arrays — lets a restorer validate format/digest before rebuilding."""
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore_items(ckpt_dir: str, step: int) -> dict[str, np.ndarray]:
    """Load a checkpoint as a flat ``{keystr: host array}`` dict, no ``like``
    pytree needed.  Used by pool restore, where buffer shapes aren't known
    until the saved manifest has been read."""
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    return {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for direct sharded device_put (elastic re-mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = _flatten_with_paths(like)
    keys_saved = manifest["keys"]
    if [k for k, _ in flat_like] != keys_saved:
        raise ValueError("checkpoint structure mismatch:\n"
                         f"saved={keys_saved[:5]}...\n"
                         f"want={[k for k, _ in flat_like][:5]}...")
    arrays = [data[f"a{i}"] for i in range(len(keys_saved))]
    leaves_like = [v for _, v in flat_like]
    for a, l in zip(arrays, leaves_like):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {l.shape}")
    if shardings is not None:
        flat_sh = [v for _, v in _flatten_with_paths(shardings)[0]]
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jax.device_put(a.astype(l.dtype))
                  for a, l in zip(arrays, leaves_like)]
    _, treedef2 = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef2, arrays)

"""Pure-jnp oracles for every Pallas kernel (bit-exact where applicable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_M1 = jnp.uint32(0x85EBCA6B)
_M2 = jnp.uint32(0xC2B2AE35)
_GOLDEN = jnp.uint32(0x9E3779B9)


def membership_rows_ref(rows, lengths, u):
    lane = jnp.arange(rows.shape[1], dtype=jnp.int32)[None, :]
    valid = lane < lengths[:, None]
    return ((rows == jnp.int32(u)) & valid).any(axis=1)


def _hash_mix(x):
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def counter_uniform_u32_ref(seed, counter):
    x = counter.astype(jnp.uint32) * _GOLDEN + jnp.uint32(seed)
    return _hash_mix(_hash_mix(x) ^ _GOLDEN)


def bernoulli_edges_ref(weights, seed):
    idx = jnp.arange(weights.shape[0], dtype=jnp.uint32)
    bits = counter_uniform_u32_ref(seed, idx)
    u01 = bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)
    return u01 < weights.astype(jnp.float32)


def pack_bits_ref(bits):
    b, n = bits.shape
    b3 = bits.reshape(b, n // 32, 32).astype(jnp.uint32)
    shift = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    return (b3 << shift).sum(axis=2).astype(jnp.uint32)


def bitset_or_ref(a, b):
    return a | b


def bitset_andnot_ref(a, b):
    return a & ~b


def popcount_words_ref(words):
    v = words
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (((v * jnp.uint32(0x01010101)) >> 24)).astype(jnp.int32)


def occur_from_bitset_ref(words):
    b, w = words.shape
    shift = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((words[:, :, None] >> shift) & jnp.uint32(1)).astype(jnp.int32)
    return bits.sum(axis=0).reshape(w * 32)


def flash_attention_ref(q, k, v, causal=True):
    """Full-materialization oracle for the flash kernel (B,S,H,D)."""
    import math
    b, s, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(qi >= ki, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

"""The four assigned GNN architectures + their step builders.

Each arch provides full/reduced configs parameterized by the shape's feature
dim (the shape table carries d_feat/n_classes), and three step kinds:

* full-batch (full_graph_sm / ogb_products): COO edge arrays + node feats;
* minibatch_lg: the neighbor sampler's union subgraph (seeds ∪ hop1 ∪ hop2,
  bipartite child→parent edges) — the arch's full conv stack runs on the
  sampled subgraph and the loss reads the seed rows (GraphSAINT-style);
* molecule: vmap over a batch of small graphs, graph-level readout.
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.models import gnn
from repro.optim import AdamWConfig, adamw_update


def make_arch(arch_id: str, shape: dict, *, reduced: bool = False):
    """Returns the arch config for a shape (d_feat/n_classes from shape)."""
    d_in = shape.get("d_feat", 16)
    n_cls = shape.get("n_classes", 2)
    if arch_id == "gat-cora":
        cfg = gnn.GATConfig(n_layers=2, d_hidden=8, n_heads=8, d_in=d_in,
                            n_classes=n_cls)
        return replace(cfg, d_hidden=4, n_heads=2) if reduced else cfg
    if arch_id == "gin-tu":
        cfg = gnn.GINConfig(n_layers=5, d_hidden=64, d_in=d_in,
                            n_classes=n_cls)
        return replace(cfg, n_layers=2, d_hidden=8) if reduced else cfg
    if arch_id == "egnn":
        cfg = gnn.EGNNConfig(n_layers=4, d_hidden=64, d_in=d_in)
        return replace(cfg, n_layers=2, d_hidden=8) if reduced else cfg
    if arch_id == "graphcast":
        cfg = gnn.GraphCastConfig(n_layers=16, d_hidden=512, d_in=d_in,
                                  d_out=n_cls, mesh_refinement=6)
        return replace(cfg, n_layers=2, d_hidden=16) if reduced else cfg
    raise KeyError(arch_id)


def init_params(arch_id, key, cfg, n_classes, dtype=jnp.float32):
    if arch_id == "gat-cora":
        return gnn.gat_init(key, cfg, dtype)
    if arch_id == "gin-tu":
        return gnn.gin_init(key, cfg, dtype)
    if arch_id == "graphcast":
        return gnn.graphcast_init(key, cfg, dtype)
    if arch_id == "egnn":
        p = gnn.egnn_init(key, cfg, dtype)
        khead = jax.random.fold_in(key, 1)
        return {"egnn": p,
                "head": (jax.random.normal(khead, (cfg.d_hidden, n_classes))
                         * 0.1).astype(dtype)}
    raise KeyError(arch_id)


def node_logits(arch_id, params, cfg, x, src, dst, mask, coords=None,
                shard_axes=None, comm_bf16=False):
    if arch_id == "gat-cora":
        return gnn.gat_apply(params, cfg, x, src, dst, mask)
    if arch_id == "gin-tu":
        return gnn.gin_apply(params, cfg, x, src, dst, mask)
    if arch_id == "graphcast":
        return gnn.graphcast_apply(params, cfg, x, src, dst, mask,
                                   shard_axes=shard_axes,
                                   comm_bf16=comm_bf16)
    if arch_id == "egnn":
        h, _ = gnn.egnn_apply(params["egnn"], cfg, x, coords, src, dst, mask)
        return h @ params["head"].astype(h.dtype)
    raise KeyError(arch_id)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _loss_boundary(x, bwd_dtype):
    """fwd: upcast to f32 for a stable loss; bwd: cotangent in the compute
    dtype so the whole backward pass stays bf16 (§Perf/H4d — without this,
    the f32 cotangent from the loss promotes every backward matmul and the
    node-state all-reduces to f32)."""
    return x.astype(jnp.float32)


_loss_boundary.defvjp(lambda x, d: (x.astype(jnp.float32), None),
                      lambda d, res, ct: (ct.astype(d),))


def _ce(logits, labels):
    if logits.dtype != jnp.float32:
        logits = _loss_boundary(logits, str(logits.dtype))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return (logz - gold).mean()


def build_node_train_step(arch_id, cfg, opt_cfg: AdamWConfig, *,
                          n_labeled: int | None = None, shard_axes=None,
                          comm_bf16: bool = False):
    """(state, x, src, dst, mask, labels, coords) -> (state, loss).

    ``n_labeled``: loss over the first n rows only (minibatch seeds);
    None = all nodes (full-batch).  coords is ignored unless egnn.
    shard_axes/comm_bf16: §Perf/H4 distributed-aggregation knobs.
    """
    def loss_fn(params, x, src, dst, mask, labels, coords):
        dt = jax.tree.leaves(params)[0].dtype
        logits = node_logits(arch_id, params, cfg, x.astype(dt), src, dst,
                             mask, coords.astype(dt) if coords is not None
                             else None,
                             shard_axes=shard_axes, comm_bf16=comm_bf16)
        if n_labeled is not None:
            logits = logits[:n_labeled]
        return _ce(logits, labels)

    def step(state, x, src, dst, mask, labels, coords):
        params, opt = state
        loss, grads = jax.value_and_grad(loss_fn)(params, x, src, dst, mask,
                                                  labels, coords)
        params, opt = adamw_update(grads, opt, params, opt_cfg)
        return (params, opt), loss

    return step


def build_molecule_train_step(arch_id, cfg, opt_cfg: AdamWConfig):
    """vmap over a batch of small graphs; mean-pool graph readout."""
    def graph_logits(params, x, src, dst, mask, coords):
        out = node_logits(arch_id, params, cfg, x, src, dst, mask, coords)
        return out.mean(axis=0)

    def loss_fn(params, xb, srcb, dstb, maskb, labels, coordsb):
        logits = jax.vmap(graph_logits, in_axes=(None, 0, 0, 0, 0, 0))(
            params, xb, srcb, dstb, maskb, coordsb)
        return _ce(logits, labels)

    def step(state, xb, srcb, dstb, maskb, labels, coordsb):
        params, opt = state
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, srcb, dstb,
                                                  maskb, labels, coordsb)
        params, opt = adamw_update(grads, opt, params, opt_cfg)
        return (params, opt), loss

    return step


def minibatch_union_sizes(shape: dict) -> tuple[int, int]:
    """(n_union_nodes, n_union_edges) for the sampled-block union graph."""
    b = shape["batch_nodes"]
    counts = [b]
    for f in shape["fanout"]:
        counts.append(counts[-1] * f)
    n_nodes = sum(counts)
    n_edges = sum(counts[1:])
    return n_nodes, n_edges

"""Train a qwen2-family LM with the full production substrate:
deterministic sharded data, AdamW + cosine schedule, checkpoint/restart
(kill it mid-run and re-launch — it resumes), straggler monitoring.

Default is a ~15M-param config so a few hundred steps finish on CPU; pass
``--arch qwen2-0.5b --full`` on a real accelerator for the 0.5B run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models.transformer import LMConfig
from repro.optim import AdamWConfig, cosine_with_warmup
from repro.train.steps import init_train_state, build_lm_train_step
from repro.data import tokens as tok
from repro.ckpt import checkpoint as ckpt
from repro.ft.straggler import StepTimer


def small_cfg():
    return LMConfig(name="qwen2-mini", n_layers=4, d_model=256, n_heads=8,
                    n_kv_heads=2, head_dim=32, d_ff=1024, vocab=4096,
                    qkv_bias=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-mini")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.arch == "qwen2-mini":
        cfg = small_cfg()
    else:
        cfg = registry.lm_config(args.arch, reduced=not args.full)
    ocfg = AdamWConfig(lr=3e-4)
    sched = functools.partial(cosine_with_warmup, peak_lr=ocfg.lr,
                              warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(build_lm_train_step(cfg, ocfg, schedule=sched))

    state = init_train_state(jax.random.key(0), cfg, ocfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(state.params))
    print(f"[model] {cfg.name}: {n_params / 1e6:.1f}M params")

    latest = ckpt.latest_step(args.ckpt)
    start = 0
    if latest is not None:
        state = ckpt.restore(args.ckpt, latest, state)
        start = latest + 1
        print(f"[resume] from step {latest}")

    timer = StepTimer()
    for step in range(start, args.steps):
        batch = jnp.asarray(tok.shard_for(step, 0, 1,
                                          global_batch=args.batch,
                                          seq_len=args.seq,
                                          vocab=cfg.vocab, seed=0))
        timer.start()
        state, metrics = step_fn(state, batch)
        dt = timer.stop()
        if timer.is_straggler(dt):
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(median {timer.median:.2f}s)")
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"{dt:.2f}s/step")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            ckpt.save(args.ckpt, step, state, keep=2)
    print("[done]")


if __name__ == "__main__":
    main()

"""§Perf/IM: engine comparison in *parallel time* (lockstep micro-steps).

On this single scalar core the vectorized engines run their B×EC lanes
sequentially, so CPU wall-clock says nothing about TPU/GPU throughput
(table2 reports it anyway, honestly).  The hardware-transferable metric is
the number of lockstep micro-steps: one micro-step = one EC-wide chunk on
every lane = one parallel time unit on width-B vector hardware.

  modelled parallel speedup = serial edge-operations / engine micro-steps

which is exactly the quantity the paper's GPU measures (they report 33-220x
on a 2560-warp V100; we report the same ratio for the 512-lane config).
Also measures the round->refill utilization win (paper Alg. 6 structure).

Both engines are driven through the SamplerEngine protocol: the benchmark
sees only ``engine.sample(key) -> RRBatch`` and the canonical ``steps``
counter, so any registered engine can be dropped into the comparison.

Second half (``BENCH_pipeline.json``): *wall-clock* end-to-end ``imm()`` per
engine on the default benchmark graph — the device-resident pipeline's
figure of merit.  Wall time on this CPU container is meaningful here because
it measures exactly what the device pipeline changed: host↔device bounces,
per-round recompiles, and the O(EC²) dedup — not vector throughput.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from benchmarks.common import OUT_DIR, ba_graph, write_csv, report
from repro.graph import csr as csr_mod
from repro.core import coverage as cov
from repro.core.engine import make_engine
from repro.core.imm import imm

N, R, QUOTA, B = 20000, 8, 2048, 512
PIPELINE_ENGINES = ("queue", "refill", "dense", "lt")
SELECTION_PATHS = ("fused", "bitset", "celf-sketch")


def bench_selection(n=2000, r=4, k=10, pool_rows=2048, batch=256,
                    sketch_k=512, reps=3, seed=0):
    """Time the three selection backends on one shared RR pool.

    The pool is sampled once (queue engine) into a ``DeviceRRStore`` with an
    incremental coverage sketch; each path then selects the same k seeds.
    First call per path is reported separately as compile+run; steady-state
    is the min over ``reps`` repeats.  Writes BENCH_selection.json.
    """
    g = ba_graph(n, r)
    g_rev = csr_mod.reverse(g)
    eng = make_engine("queue", g_rev, batch=batch)
    store = cov.DeviceRRStore(n, sketch_k=sketch_k)
    i = 0
    while store.n_rr < pool_rows:
        store.append_batch(eng.sample(jax.random.key(seed * 100003 + i)))
        i += 1
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "pool": {"rows": store.n_rr, "elements": store.n_elems,
                    "sketch_k": store.sketch_k, "batch": batch},
           "params": {"k": k, "reps": reps, "seed": seed},
           "paths": {}}
    seeds_by_path = {}
    for path in SELECTION_PATHS:
        method = {"fused": "flat", "bitset": "bitset",
                  "celf-sketch": "celf"}[path]
        t0 = time.perf_counter()
        if method == "celf":
            stats = {}
            res = cov.select_seeds_celf(store, k, stats_out=stats)
        else:
            res = store.select(k, method=method)
        jax.block_until_ready(res.seeds)
        first = time.perf_counter() - t0
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            if method == "celf":
                res = cov.select_seeds_celf(store, k)
            else:
                res = store.select(k, method=method)
            jax.block_until_ready(res.seeds)
            best = min(best, time.perf_counter() - t0)
        seeds = np.asarray(res.seeds).tolist()
        seeds_by_path[path] = seeds
        out["paths"][path] = {
            "first_call_s": round(first, 4),
            "steady_s": round(best, 4),
            "seeds": seeds,
            "frac": round(float(res.frac), 6),
        }
        if method == "celf":
            out["paths"][path]["exact_evals"] = stats["n_exact_evals"]
            out["paths"][path]["eval_calls"] = stats["n_eval_calls"]
        report(f"perf_im/selection/{path}", best * 1e6,
               f"steady={best * 1e3:.1f}ms;first={first:.2f}s")
    out["seeds_identical"] = all(
        s == seeds_by_path[SELECTION_PATHS[0]] for s in seeds_by_path.values())
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_selection.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def bench_pipeline(n=N, r=R, k=10, eps=0.4, max_theta=4096, batch=512,
                   engines=PIPELINE_ENGINES, seed=0):
    """Time end-to-end ``imm()`` per engine; returns the result dict."""
    g = ba_graph(n, r)
    out = {"graph": {"kind": "barabasi_albert", "n": n, "r": r,
                     "weights": "wc"},
           "params": {"k": k, "eps": eps, "max_theta": max_theta,
                      "batch": batch, "seed": seed},
           # same imm() call measured on the parent commit (host-pipeline
           # IncrementalRRStore + per-escalation recompiles + O(EC²) dedup),
           # same machine/config; recorded for the device-pipeline A/B
           "baseline_main": ({"queue": {"wall_s": 98.57},
                              "refill": {"wall_s": 34.54},
                              "commit": "5812556"}
                             if (n, r, k, eps, max_theta, batch) ==
                                (20000, 8, 10, 0.4, 4096, 512) else None),
           "engines": {}}
    for name in engines:
        t0 = time.perf_counter()
        seeds, est, stats = imm(g, k, eps, engine=name, batch=batch,
                                seed=seed, max_theta=max_theta)
        dt = time.perf_counter() - t0
        out["engines"][name] = {
            "wall_s": round(dt, 3),
            "theta": stats.theta,
            "rr_sets": stats.n_rr_sampled,
            "rounds": stats.rounds,
            "micro_steps": stats.sampling_steps,
            "lb_iters": stats.lb_iters,
            "spread_estimate": round(float(est), 1),
        }
        report(f"perf_im/pipeline/{name}", dt * 1e6,
               f"wall={dt:.2f}s;rr={stats.n_rr_sampled};"
               f"rounds={stats.rounds}")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main(n=N, r=R, quota=QUOTA, b=B, pipeline_kw=None, selection_kw=None):
    g = ba_graph(n, r)
    g_rev = csr_mod.reverse(g)
    deg = np.diff(np.asarray(g_rev.offsets))
    rows = []
    # serial work model: ops = nodes visited + edges examined (the oracle
    # walks each adjacency once per visited node)
    # --- round engine
    round_eng = make_engine("queue", g_rev, batch=b, qcap=n)
    steps_round = 0
    serial_ops = 0
    done = 0
    i = 0
    while done < quota:
        b_ = round_eng.sample(jax.random.key(i))
        steps_round += int(b_.steps)
        nodes = np.asarray(b_.nodes); lens = np.asarray(b_.lengths)
        for row in range(b_.n_sets):
            vis = nodes[row, :lens[row]]
            serial_ops += lens[row] + deg[vis].sum()
        done += b_.n_sets
        i += 1
    # --- refill engine (same quota, B persistent lanes)
    refill_eng = make_engine("refill", g_rev, batch=quota, lanes=b,
                             out_cap=8 * quota // b * 64)
    bf = refill_eng.sample(jax.random.key(99))
    steps_refill = int(bf.steps)
    n_sets = bf.n_sets
    speedup_round = serial_ops / max(steps_round, 1)
    speedup_refill = serial_ops / max(steps_refill, 1) * done / max(n_sets, 1)
    rows.append(["round", done, steps_round, int(serial_ops),
                 round(speedup_round, 1)])
    rows.append(["refill", n_sets, steps_refill, int(serial_ops),
                 round(speedup_refill, 1)])
    write_csv("perf_im_engines",
              ["engine", "rr_sets", "micro_steps", "serial_ops",
               "modelled_parallel_speedup"], rows)
    report("perf_im/round", steps_round, f"par_speedup={speedup_round:.0f}x")
    report("perf_im/refill", steps_refill,
           f"par_speedup={speedup_refill:.0f}x;"
           f"step_win={steps_round / max(steps_refill, 1):.2f}x")
    bench_pipeline(n=n, r=r, **(pipeline_kw or {}))
    bench_selection(**(selection_kw or {}))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=N)
    ap.add_argument("--r", type=int, default=R)
    ap.add_argument("--quota", type=int, default=QUOTA)
    ap.add_argument("--b", type=int, default=B)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--eps", type=float, default=0.4)
    ap.add_argument("--max-theta", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--engines", default=",".join(PIPELINE_ENGINES))
    ap.add_argument("--pipeline-only", action="store_true",
                    help="skip the micro-step section (CI smoke)")
    ap.add_argument("--selection-only", action="store_true",
                    help="run only the selection-backend comparison")
    ap.add_argument("--pool-rows", type=int, default=2048,
                    help="RR pool size for --selection-only")
    ap.add_argument("--sketch-k", type=int, default=512)
    args = ap.parse_args()
    pkw = dict(k=args.k, eps=args.eps, max_theta=args.max_theta,
               batch=args.batch, engines=tuple(args.engines.split(",")))
    skw = dict(n=args.n, r=args.r, k=args.k, pool_rows=args.pool_rows,
               batch=args.batch, sketch_k=args.sketch_k)
    if args.selection_only:
        bench_selection(**skw)
    elif args.pipeline_only:
        bench_pipeline(n=args.n, r=args.r, **pkw)
    else:
        main(n=args.n, r=args.r, quota=args.quota, b=args.b, pipeline_kw=pkw,
             selection_kw=skw)

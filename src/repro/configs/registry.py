"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from repro.configs import lm as lm_cfg
from repro.configs.shapes import LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES

ARCHS = {
    # --- LM family -------------------------------------------------------
    "qwen2-0.5b": dict(family="lm", shapes=list(LM_SHAPES),
                       full=lm_cfg.qwen2_0_5b),
    "olmo-1b": dict(family="lm", shapes=list(LM_SHAPES),
                    full=lm_cfg.olmo_1b),
    "gemma3-12b": dict(family="lm", shapes=list(LM_SHAPES),
                       full=lm_cfg.gemma3_12b),
    "deepseek-v3-671b": dict(family="lm", shapes=list(LM_SHAPES),
                             full=lm_cfg.deepseek_v3_671b),
    "llama4-scout-17b-a16e": dict(family="lm", shapes=list(LM_SHAPES),
                                  full=lm_cfg.llama4_scout),
    # --- GNN family ------------------------------------------------------
    "gat-cora": dict(family="gnn", shapes=list(GNN_SHAPES)),
    "egnn": dict(family="gnn", shapes=list(GNN_SHAPES)),
    "gin-tu": dict(family="gnn", shapes=list(GNN_SHAPES)),
    "graphcast": dict(family="gnn", shapes=list(GNN_SHAPES)),
    # --- RecSys ----------------------------------------------------------
    "deepfm": dict(family="recsys", shapes=list(RECSYS_SHAPES)),
}


def family_of(arch_id: str) -> str:
    return ARCHS[arch_id]["family"]


def lm_config(arch_id: str, *, reduced: bool = False):
    full = ARCHS[arch_id]["full"]()
    return lm_cfg.reduced_lm(full) if reduced else full


def shape_table(arch_id: str) -> dict:
    fam = family_of(arch_id)
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
            "recsys": RECSYS_SHAPES}[fam]

"""Network serving surface + cluster + stacked selection (DESIGN.md §11).

Contracts under test (ISSUE 10 acceptance criteria):
* consistent-hash ring: worker join/leave moves only the minimal key
  range (join: everything that moved now belongs to the joiner; leave:
  ownership returns exactly to the pre-join mapping);
* exhaustive ``ServeError`` -> HTTP status mapping: every subclass maps
  to a *distinct* status and none falls through to the generic 500;
* stacked-vs-solo selection bit-identity for mixed k / candidates /
  budget / MRIM batches on 1 and 8 fake devices, running under
  ``jax.transfer_guard("disallow")``;
* HTTP answers are bit-identical to in-process ``IMService.submit`` (the
  JSON float round-trip is exact) and errors arrive as the same typed
  subclass through the client;
* ring rebalance hands warm pools off as ``PoolLease`` exports and the
  moved keys keep answering bit-identically;
* SIGTERM-style drain: ``/readyz`` flips 503, new solves are rejected
  typed, warm pools spill through the registry's durable path.
"""
import asyncio
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.serve import (ERROR_STATUS, HashRing, IMClient, IMCluster,
                         IMNetServer, ServeConfig, ServeError,
                         SolverFailedError, build_service, execute_batch,
                         status_for)
from repro.serve.net import service_statsz


def ba(n=220, r=4, seed=0):
    src, dst = generators.barabasi_albert(n, r, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


# -- consistent-hash ring ----------------------------------------------------

def test_ring_minimal_movement_on_join_and_leave():
    ring = HashRing(vnodes=64)
    for w in range(4):
        ring.add(w)
    keys = [f"digest{i}|pool{i}|{i}|exact" for i in range(2000)]
    before = {k: ring.owner(k) for k in keys}
    ring.add(4)
    after = {k: ring.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # every moved key moved TO the joiner — nothing reshuffled between
    # the old workers — and the joiner took roughly its 1/5 share
    assert moved and all(after[k] == 4 for k in moved)
    assert len(moved) < 2 * len(keys) / 5
    ring.remove(4)
    restored = {k: ring.owner(k) for k in keys}
    assert restored == before


def test_ring_guards():
    ring = HashRing()
    with pytest.raises(RuntimeError):
        ring.owner("x")
    ring.add(0)
    with pytest.raises(ValueError):
        ring.add(0)


# -- error -> status mapping -------------------------------------------------

def _all_subclasses(cls):
    out = []
    stack = list(cls.__subclasses__())
    while stack:
        c = stack.pop()
        out.append(c)
        stack.extend(c.__subclasses__())
    return out


def test_error_status_mapping_exhaustive():
    subs = _all_subclasses(ServeError)
    assert len(subs) >= 6
    statuses = {}
    for cls in subs:
        status = status_for(cls("boom"))
        # no subclass falls through to the generic 500 (SolverFailedError
        # IS the explicit 500; it must be an exact entry, not a fallback)
        if status == 500:
            assert cls in ERROR_STATUS or any(
                base in ERROR_STATUS and ERROR_STATUS[base] == 500
                for base in cls.__mro__), cls
        statuses.setdefault(status, cls)
    # explicit entries are pairwise distinct
    vals = list(ERROR_STATUS.values())
    assert len(vals) == len(set(vals))
    assert status_for(SolverFailedError("x")) == 500
    # the base class (never raised, but defensively) maps to 500
    assert status_for(ServeError("x")) == 500
    # every subclass has a distinct code too (the client rebuilds from it)
    codes = [c.code for c in subs]
    assert len(codes) == len(set(codes))


# -- stacked selection bit-identity ------------------------------------------

def _mixed_problems(n, theta):
    cand = np.zeros(n, bool)
    cand[: n // 4] = True
    costs = (np.abs(np.random.default_rng(3).normal(1.0, 0.3, n))
             + 0.1).astype(np.float32)
    return [
        IMProblem(k=2, theta=theta),
        IMProblem(k=5, theta=theta),
        IMProblem(k=3, theta=theta, candidates=np.flatnonzero(cand)),
        IMProblem(k=None, budget=2.5, costs=costs, theta=theta),
        IMProblem(k=4, theta=theta),
    ]


def _assert_result_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.seeds), np.asarray(b.seeds))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    assert a.frac == b.frac and a.spread == b.spread and a.cost == b.cost


def test_stacked_matches_solo_mesh1():
    g = ba()
    theta = 256
    probs = _mixed_problems(g.n_nodes, theta)
    solo = IMMSolver(g, batch=64, seed=0)
    ref = [solo.solve_problem(p) for p in probs]
    stk = IMMSolver(g, batch=64, seed=0)
    got = stk.solve_stacked(probs)
    for a, b in zip(ref, got):
        _assert_result_equal(a, b)


def test_stacked_mrim_and_guards():
    g = ba()
    theta = 256
    mrim = [IMProblem(k=2, theta=theta, t_rounds=2),
            IMProblem(k=1, theta=theta, t_rounds=2)]
    solo = IMMSolver(g, batch=64, seed=0)
    ref = [solo.solve_problem(p) for p in mrim]
    stk = IMMSolver(g, batch=64, seed=0)
    for a, b in zip(ref, stk.solve_stacked(mrim)):
        _assert_result_equal(a, b)
    with pytest.raises(ValueError):   # mixed θ
        stk.solve_stacked([IMProblem(k=1, theta=128),
                           IMProblem(k=1, theta=256)])
    with pytest.raises(ValueError):   # LB-loop problems can't stack
        stk.solve_stacked([IMProblem(k=1), IMProblem(k=2)])
    with pytest.raises(ValueError):   # approximate mode goes solo
        stk.solve_stacked([IMProblem(k=1, theta=128, mode="approximate"),
                           IMProblem(k=2, theta=128, mode="approximate")])


def test_execute_batch_stacked_parity_and_counters():
    g = ba()
    theta = 256
    probs = _mixed_problems(g.n_nodes, theta) \
        + [IMProblem(k=1, theta=theta)]       # fastpath rider
    s_a = IMMSolver(g, batch=64, seed=0)
    s_b = IMMSolver(g, batch=64, seed=0)
    stats: dict = {}
    with jax.transfer_guard("disallow"):
        res_stacked = execute_batch(s_a, probs, stacked=True,
                                    stats_out=stats)
        res_solo = execute_batch(s_b, probs, stacked=False)
    for a, b in zip(res_solo, res_stacked):
        _assert_result_equal(a, b)
    assert stats["stacked_batches"] == 1
    assert stats["stacked_requests"] == len(probs) - 1  # k=1 went fastpath


MESH8_STACKED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.graph import csr as csr_mod, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem

assert len(jax.devices()) == 8
mesh8 = Mesh(np.asarray(jax.devices()), ("samples",))
src, dst = generators.barabasi_albert(160, 4, seed=0)
g = weights.wc_weights(csr_mod.from_edges(src, dst, 160))
theta, n = 192, 160
cand = np.zeros(n, bool); cand[: n // 4] = True
costs = (np.abs(np.random.default_rng(3).normal(1.0, 0.3, n))
         + 0.1).astype(np.float32)
probs = [IMProblem(k=2, theta=theta), IMProblem(k=4, theta=theta),
         IMProblem(k=3, theta=theta, candidates=np.flatnonzero(cand)),
         IMProblem(k=None, budget=2.0, costs=costs, theta=theta)]

def run(mesh, stacked):
    solver = IMMSolver(g, batch=64, seed=0, mesh=mesh)
    if stacked:
        res = solver.solve_stacked(probs)
    else:
        res = [solver.solve_problem(p) for p in probs]
    return [(np.asarray(r.seeds), np.asarray(r.gains), r.frac, r.spread,
             r.cost) for r in res]

outs = {(w, s): run(m, s)
        for w, m in ((1, None), (8, mesh8)) for s in (False, True)}
base = outs[(1, False)]
for key, got in outs.items():
    for b, r in zip(base, got):
        assert np.array_equal(b[0], r[0]), (key, b[0], r[0])
        assert np.array_equal(b[1], r[1]), (key,)
        assert b[2:] == r[2:], (key, b[2:], r[2:])
print("OK")
"""


def test_stacked_mesh8_bit_identity():
    # subprocess: the forced 8-device platform must be set before jax
    # imports.  Solo-vs-stacked at widths 1 and 8, all four ways equal;
    # solvers run their solve under transfer_guard("disallow") internally.
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + (
        os.pathsep + env["PYTHONPATH"] if "PYTHONPATH" in env else "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MESH8_STACKED_SCRIPT],
                       env=env, capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "OK" in r.stdout


# -- HTTP end to end ---------------------------------------------------------

def test_http_end_to_end_parity_errors_and_drain(tmp_path):
    g = ba()
    theta = 256
    probs = _mixed_problems(g.n_nodes, theta)

    async def run():
        svc = build_service({"graph": g}, ServeConfig(
            max_batch=8, batch_window_s=0.002,
            solver_opts={"batch": 64, "seed": 0},
            spill_dir=str(tmp_path)))
        server = IMNetServer(svc, port=0)
        await server.start()
        c = IMClient("127.0.0.1", server.port)
        assert (await c.healthz())[0] == 200
        assert (await c.readyz()) == (200, {"ready": True,
                                            "draining": False})
        docs = await asyncio.gather(*(c.solve("graph", p) for p in probs))
        # approximate tier through the wire (satellite): routed to the
        # sketch solver under the same registry, footprint in /statsz
        approx = await c.solve("graph", IMProblem(k=3, theta=theta,
                                                  mode="approximate"))
        assert approx["result"]["spread_bounds"] is not None
        # θ-pinned parity: HTTP json == in-process submit == cold solve
        inproc = await asyncio.gather(*(svc.submit("graph", p)
                                        for p in probs))
        cold = IMMSolver(g, batch=64, seed=0)
        for p, doc, ip in zip(probs, docs, inproc):
            res = doc["result"]
            assert res["seeds"] == np.asarray(ip.result.seeds).tolist()
            assert res["gains"] == np.asarray(ip.result.gains).tolist()
            assert res["spread"] == float(ip.result.spread)
            assert res["frac"] == float(ip.result.frac)
            ref = cold.solve_problem(p)
            assert res["seeds"] == np.asarray(ref.seeds).tolist()
            assert res["spread"] == float(ref.spread)
        # typed errors over the wire: client rebuilds the exact class
        from repro.serve import UnknownGraphError
        with pytest.raises(UnknownGraphError):
            await c.solve("nope", probs[0])
        # malformed problem body (k=0 can't even be built client-side)
        status, doc = await c.request(
            "POST", "/v1/solve", {"graph": "graph", "problem": {"k": 0}})
        assert status == 400
        assert doc["error"]["code"] == "invalid_problem"
        status, _doc = await c.request("GET", "/nope")
        assert status == 404
        status, _doc = await c.request("GET", "/v1/solve")
        assert status == 405
        st = await c.stats()
        assert st["serve"]["served"] >= len(probs) + 1
        assert st["serve"]["stacked_requests"] >= 2
        fp = st["approx_footprint"]
        assert fp["approx_entries"] == 1 and fp["exact_entries"] >= 1
        assert fp["exact_over_approx_ratio"] > 1.0
        assert any(e["mode"] == "approximate" for e in st["entries"])
        # drain: readyz flips 503, solves rejected typed, pools spill
        server.draining = True
        assert (await c.readyz())[0] == 503
        status, doc = await c.solve_raw("graph", probs[0])
        assert status == 503 and doc["error"]["code"] == "draining"
        server.draining = False
        await server.shutdown()
        assert server.draining
        assert svc.registry.snapshot().spills >= 1
        assert len(svc.registry.entries) == 0
        assert any(os.scandir(tmp_path))

    asyncio.run(run())


def test_statsz_payload_shape():
    g = ba(120)

    async def run():
        svc = build_service({"graph": g}, ServeConfig(
            solver_opts={"batch": 64, "seed": 0}))
        async with svc:
            await svc.submit("graph", IMProblem(k=2, theta=128))
            payload = service_statsz(svc)
        assert payload["serve"]["served"] == 1
        assert payload["entries"][0]["mode"] == "exact"
        assert payload["approx_footprint"]["approx_entries"] == 0
        import json
        json.dumps(payload)   # the whole tree must be JSON-serializable

    asyncio.run(run())


# -- cluster -----------------------------------------------------------------

def test_cluster_routing_handoff_parity():
    g = ba(160)
    thetas = list(range(128, 140))

    async def run():
        cl = IMCluster({"graph": g}, ServeConfig(
            max_batch=8, solver_opts={"batch": 64, "seed": 0}), workers=2)
        await cl.start()
        try:
            base = {}
            for t in thetas:
                r = await cl.submit("graph", IMProblem(k=3, theta=t))
                base[t] = (np.asarray(r.result.seeds).tolist(),
                           float(r.result.spread))
            # each warm pool lives on exactly one worker
            per_worker = [set(w.service.registry.entries.keys())
                          for w in cl._workers.values()]
            all_keys = set().union(*per_worker)
            assert sum(len(s) for s in per_worker) == len(all_keys)
            # every key sits on its ring owner
            for w in cl._workers.values():
                for key, entry in w.service.registry.entries.items():
                    route = cl._entry_route(w.service.registry, key, entry)
                    assert cl.ring.owner(route) == w.wid
            wid = cl.add_worker()
            hand = cl.handoffs
            # invariant restored after the join, warm pools travelled
            for w in cl._workers.values():
                for key, entry in w.service.registry.entries.items():
                    route = cl._entry_route(w.service.registry, key, entry)
                    assert cl.ring.owner(route) == w.wid
            # moved keys answer bit-identically on their new owner
            for t in thetas:
                r = await cl.submit("graph", IMProblem(k=3, theta=t))
                assert (np.asarray(r.result.seeds).tolist(),
                        float(r.result.spread)) == base[t], t
            moved_back = cl.remove_worker(wid)
            assert cl.handoffs == hand + moved_back
            for t in thetas:
                r = await cl.submit("graph", IMProblem(k=3, theta=t))
                assert np.asarray(r.result.seeds).tolist() == base[t][0]
            stz = await cl.statsz()
            assert stz["cluster"] and len(stz["workers"]) == 2
            # the departed worker took its counters with it, so only the
            # survivors' totals remain — still at least two full rounds
            assert stz["serve_total"]["served"] >= 2 * len(thetas)
            reg_hand = sum(
                s["serve"]["registry"]["handoffs_in"]
                for s in stz["per_worker"])
            assert reg_hand >= moved_back
        finally:
            await cl.stop()

    asyncio.run(run())


def test_cluster_unknown_graph_typed():
    g = ba(120)

    async def run():
        cl = IMCluster({"graph": g}, ServeConfig(
            solver_opts={"batch": 64, "seed": 0}), workers=1)
        await cl.start()
        try:
            from repro.serve import UnknownGraphError
            with pytest.raises(UnknownGraphError):
                await cl.submit("nope", IMProblem(k=1, theta=64))
        finally:
            await cl.stop()

    asyncio.run(run())

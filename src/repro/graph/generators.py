"""Host-side graph generators (numpy).

* :func:`barabasi_albert` — the paper's §4.6 scalability workload (n=1e6,
  r=2..32).  Implemented with the repeated-endpoints trick so attachment is
  proportional to degree, O(n·r).
* :func:`erdos_renyi` — fixed edge-count G(n, m) for tests/benchmarks.
* :func:`icosahedral_multimesh` — GraphCast's refined icosahedron mesh
  (refinement R => 10·4^R + 2 nodes), multimesh = union of all levels' edges.
* :func:`two_tier_social` — small directed "core-periphery" graph with known
  structure, used by unit tests.
"""
from __future__ import annotations

import numpy as np


def barabasi_albert(n: int, r: int, seed: int = 0):
    """Undirected BA preferential-attachment graph -> directed both ways.

    Returns (src, dst) with both edge directions, as the paper treats the BA
    graphs as undirected social graphs.
    """
    if r < 1 or n <= r:
        raise ValueError("need n > r >= 1")
    rng = np.random.default_rng(seed)
    # initial clique of r0 = r+1 nodes
    r0 = r + 1
    init_src, init_dst = np.triu_indices(r0, k=1)
    srcs = [init_src.astype(np.int64)]
    dsts = [init_dst.astype(np.int64)]
    # repeated-endpoint pool: node id appears once per incident edge end
    pool = np.concatenate([init_src, init_dst]).astype(np.int64)
    pool_list = [pool]
    pool_size = pool.shape[0]
    new_nodes = np.arange(r0, n, dtype=np.int64)
    for start in range(r0, n, 65536):
        stop = min(start + 65536, n)
        block = np.arange(start, stop, dtype=np.int64)
        # grow pool array lazily
        pool = np.concatenate(pool_list)
        pool_size = pool.shape[0]
        blk_src = np.repeat(block, r)
        picks = rng.integers(0, pool_size, size=blk_src.shape[0])
        blk_dst = pool[picks]
        # NOTE: sampling the pool "frozen" per 64k block is the standard
        # batched-BA approximation; degree distribution stays power-law.
        srcs.append(blk_src)
        dsts.append(blk_dst)
        pool_list.append(np.concatenate([blk_src, blk_dst]))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    # drop self loops (possible via pool picks), symmetrize
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def erdos_renyi(n: int, m: int, seed: int = 0, directed: bool = True):
    """G(n, m): m directed edges sampled uniformly (self-loops removed)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=int(m * 1.1) + 8)
    dst = rng.integers(0, n, size=src.shape[0])
    keep = src != dst
    src, dst = src[keep][:m], dst[keep][:m]
    if not directed:
        return np.concatenate([src, dst]), np.concatenate([dst, src])
    return src.astype(np.int64), dst.astype(np.int64)


def two_tier_social(n_core: int = 8, n_leaf_per_core: int = 4):
    """Directed test graph: a core ring + leaves pointing into their core node.

    Every leaf l of core c has edge (c -> l); ring edges (c -> c+1).  Known
    reachability structure for unit tests.
    """
    src, dst = [], []
    n = n_core * (1 + n_leaf_per_core)
    for c in range(n_core):
        src.append(c)
        dst.append((c + 1) % n_core)
        for j in range(n_leaf_per_core):
            leaf = n_core + c * n_leaf_per_core + j
            src.append(c)
            dst.append(leaf)
    return np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64), n


def icosahedral_multimesh(refinement: int):
    """GraphCast-style icosphere multimesh.

    Returns (vertices (V,3) float32, src, dst) where the edge set is the
    union of the refined mesh edges at every level 0..refinement, both
    directions (GraphCast processor operates on the symmetric multimesh).
    """
    phi = (1.0 + np.sqrt(5.0)) / 2.0
    verts = np.asarray(
        [(-1, phi, 0), (1, phi, 0), (-1, -phi, 0), (1, -phi, 0),
         (0, -1, phi), (0, 1, phi), (0, -1, -phi), (0, 1, -phi),
         (phi, 0, -1), (phi, 0, 1), (-phi, 0, -1), (-phi, 0, 1)],
        dtype=np.float64,
    )
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.asarray(
        [(0, 11, 5), (0, 5, 1), (0, 1, 7), (0, 7, 10), (0, 10, 11),
         (1, 5, 9), (5, 11, 4), (11, 10, 2), (10, 7, 6), (7, 1, 8),
         (3, 9, 4), (3, 4, 2), (3, 2, 6), (3, 6, 8), (3, 8, 9),
         (4, 9, 5), (2, 4, 11), (6, 2, 10), (8, 6, 7), (9, 8, 1)],
        dtype=np.int64,
    )
    verts_list = [v for v in verts]
    all_edges = set()

    def face_edges(fs):
        e = set()
        for a, b, c in fs:
            for u, v in ((a, b), (b, c), (c, a)):
                e.add((min(u, v), max(u, v)))
        return e

    all_edges |= face_edges(faces)
    midpoint_cache: dict[tuple[int, int], int] = {}

    def midpoint(a: int, b: int) -> int:
        key = (min(a, b), max(a, b))
        if key in midpoint_cache:
            return midpoint_cache[key]
        mid = verts_list[a] + verts_list[b]
        mid /= np.linalg.norm(mid)
        verts_list.append(mid)
        idx = len(verts_list) - 1
        midpoint_cache[key] = idx
        return idx

    for _ in range(refinement):
        new_faces = []
        for a, b, c in faces:
            ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
            new_faces += [(a, ab, ca), (b, bc, ab), (c, ca, bc), (ab, bc, ca)]
        faces = np.asarray(new_faces, dtype=np.int64)
        all_edges |= face_edges(faces)

    und = np.asarray(sorted(all_edges), dtype=np.int64)
    src = np.concatenate([und[:, 0], und[:, 1]])
    dst = np.concatenate([und[:, 1], und[:, 0]])
    return np.asarray(verts_list, dtype=np.float32), src, dst

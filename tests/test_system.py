"""End-to-end system behaviour tests (the paper's full pipeline + substrate
integration beyond unit level)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.graph import csr, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.core import forward, oracle
from repro.models import transformer as T
from repro.models import attention as A


def test_im_pipeline_beats_random_seeds():
    """Full solve produces seeds that beat random selection by a margin."""
    src, dst = generators.barabasi_albert(600, 4, seed=0)
    g = weights.wc_weights(csr.from_edges(src, dst, 600))
    solver = IMMSolver(g, engine="queue", batch=256, seed=0)
    res = solver.solve(IMProblem(k=8, eps=0.4))
    seeds, est = res.seeds, res.spread
    mc = forward.ic_spread(jax.random.key(1), g, seeds.tolist(), n_sims=256)
    rng = np.random.default_rng(0)
    worst = 0.0
    for trial in range(3):
        rnd = rng.choice(600, size=8, replace=False)
        worst = max(worst, forward.ic_spread(jax.random.key(2 + trial), g,
                                             rnd.tolist(), n_sims=256))
    assert mc > worst, (mc, worst)
    # the RIS estimate agrees with the forward simulation
    assert abs(est - mc) / mc < 0.2


def test_im_solver_is_deterministic():
    src, dst = generators.erdos_renyi(200, 800, seed=1)
    g = weights.wc_weights(csr.from_edges(src, dst, 200))
    r1 = IMMSolver(g, batch=128, seed=7).solve(IMProblem(k=5, eps=0.45))
    r2 = IMMSolver(g, batch=128, seed=7).solve(IMProblem(k=5, eps=0.45))
    assert r1.seeds.tolist() == r2.seeds.tolist()
    assert r1.spread == r2.spread


def test_ic_lt_models_differ_but_both_valid():
    src, dst = generators.erdos_renyi(150, 900, seed=2)
    g = weights.wc_weights(csr.from_edges(src, dst, 150))
    r_ic = IMMSolver(g, model="ic", batch=128, seed=0).solve(
        IMProblem(k=5, eps=0.45))
    r_lt = IMMSolver(g, model="lt", batch=128, seed=0).solve(
        IMProblem(k=5, eps=0.45))
    assert len(set(r_ic.seeds.tolist())) == 5
    assert len(set(r_lt.seeds.tolist())) == 5
    mc_lt = forward.lt_spread(jax.random.key(3), g, r_lt.seeds.tolist(),
                              n_sims=512)
    assert abs(r_lt.spread - mc_lt) / mc_lt < 0.3


def test_absorbed_mla_decode_matches_standard():
    """§Perf/H5: the absorbed-matmul MLA decode is numerically identical."""
    import dataclasses
    cfg = T.LMConfig(
        name="tiny-ds", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        head_dim=8, d_ff=64, vocab=64,
        mla=A.MLAConfig(n_heads=4, q_lora_rank=16, kv_lora_rank=8,
                        qk_nope_head_dim=8, qk_rope_head_dim=4,
                        v_head_dim=8))
    cfg_abs = dataclasses.replace(cfg, absorbed_mla_decode=True)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab)
    c1 = T.init_cache(cfg, batch=2, max_len=8)
    c2 = T.init_cache(cfg_abs, batch=2, max_len=8)
    for t in range(6):
        l1, c1 = T.serve_step(params, cfg, tokens[:, t:t + 1], c1,
                              jnp.int32(t))
        l2, c2 = T.serve_step(params, cfg_abs, tokens[:, t:t + 1], c2,
                              jnp.int32(t))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4,
                               rtol=1e-4)


def test_scatter_cache_update_matches_dus():
    import dataclasses
    cfg = T.LMConfig(name="tiny-q", n_layers=2, d_model=32, n_heads=4,
                     n_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
                     qkv_bias=True)
    cfg_sc = dataclasses.replace(cfg, scatter_cache_update=True)
    params = T.lm_init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (1, 5), 0, cfg.vocab)
    c1 = T.init_cache(cfg, batch=1, max_len=8)
    c2 = T.init_cache(cfg_sc, batch=1, max_len=8)
    for t in range(5):
        l1, c1 = T.serve_step(params, cfg, tokens[:, t:t + 1], c1,
                              jnp.int32(t))
        l2, c2 = T.serve_step(params, cfg_sc, tokens[:, t:t + 1], c2,
                              jnp.int32(t))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_grouped_moe_in_tiny_lm_train():
    """dispatch_groups engages in a full train step without NaNs."""
    import dataclasses
    from repro.models import moe as M
    from repro.optim import AdamWConfig
    from repro.train.steps import init_train_state, build_lm_train_step
    cfg = T.LMConfig(
        name="tiny-moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=64, vocab=64,
        moe=M.MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                        capacity_factor=2.0, dispatch_groups=2))
    ocfg = AdamWConfig(lr=1e-3)
    state = init_train_state(jax.random.key(0), cfg, ocfg)
    step = jax.jit(build_lm_train_step(cfg, ocfg))
    tokens = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)
    state, metrics = step(state, tokens)
    assert np.isfinite(float(metrics["loss"]))

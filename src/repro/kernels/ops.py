"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to backend-aware dispatch: interpret mode on CPU
(this container), compiled Mosaic on an accelerator backend.  The backend
is consulted lazily at call time — resolving it at import would initialize
JAX's platform as a side effect and freeze a stale choice.  The module
level ``INTERPRET`` override is kept for tests and debugging — set
``repro.kernels.ops.INTERPRET = True/False`` to force either mode for every
kernel at once (per-call ``interpret=`` still wins); ``None`` means auto.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import membership as _membership
from repro.kernels import bernoulli as _bernoulli
from repro.kernels import bitset as _bitset

INTERPRET: bool | None = None    # None = auto: cpu -> interpret

_ENV_FLAG = "REPRO_KERNELS_INTERPRET"   # CI interpret-mode job sets this


def _env_interpret() -> bool | None:
    v = os.environ.get(_ENV_FLAG, "").strip().lower()
    if v in ("1", "true", "yes", "on"):
        return True
    if v in ("0", "false", "no", "off"):
        return False
    return None


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Per-call flag > module override > env override > backend default."""
    if interpret is not None:
        return interpret
    if INTERPRET is not None:
        return INTERPRET
    env = _env_interpret()
    if env is not None:
        return env
    return jax.default_backend() == "cpu"


def membership_rows(rows, lengths, u, *, block_rows: int = 256,
                    interpret: bool | None = None):
    return _membership.membership_rows(
        rows, lengths, u, block_rows=block_rows,
        interpret=resolve_interpret(interpret))


def bernoulli_edges(weights, seed, *, block: int = 1024,
                    interpret: bool | None = None):
    return _bernoulli.bernoulli_edges(
        weights, seed, block=block,
        interpret=resolve_interpret(interpret))


def pack_bits(bits, *, interpret: bool | None = None):
    return _bitset.pack_bits(
        bits, interpret=resolve_interpret(interpret))


def bitset_or(a, b, *, interpret: bool | None = None):
    return _bitset.bitset_or(
        a, b, interpret=resolve_interpret(interpret))


def bitset_andnot(a, b, *, interpret: bool | None = None):
    return _bitset.bitset_andnot(
        a, b, interpret=resolve_interpret(interpret))


def popcount_words(words, *, interpret: bool | None = None):
    return _bitset.popcount_words(
        words, interpret=resolve_interpret(interpret))


def occur_from_bitset(words, *, interpret: bool | None = None):
    return _bitset.occur_from_bitset(
        words, interpret=resolve_interpret(interpret))


def occur_from_bitset_masked(words, rowmask, *, interpret: bool | None = None):
    return _bitset.occur_from_bitset_masked(
        words, rowmask, interpret=resolve_interpret(interpret))


def sketch_union_popcount(words, cov, *, interpret: bool | None = None):
    from repro.kernels import sketch as _sketch
    return _sketch.sketch_union_popcount(
        words, cov, interpret=resolve_interpret(interpret))


def sketch_scatter_or(words, v, bucket, *, interpret: bool | None = None):
    from repro.kernels import sketch as _sketch
    return _sketch.sketch_scatter_or(
        words, v, bucket, interpret=resolve_interpret(interpret))


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret: bool | None = None):
    from repro.kernels import flashattn as _fa
    return _fa.flash_attention(
        q, k, v, causal=causal, bq=bq, bk=bk,
        interpret=resolve_interpret(interpret))

"""GNN model zoo on the shared segment-op message-passing substrate.

All message passing is expressed as gather(src) -> edge compute ->
``jax.ops.segment_*`` scatter to dst (per the assignment: JAX sparse is
BCOO-only, so SpMM/SDDMM become explicit edge-index segment ops — the same
CSR/COO layer the IM core samples from).

Models: GAT (attn aggregator, SDDMM + segment-softmax), GIN (sum + learnable
eps), EGNN (E(n)-equivariant coordinate updates), GraphCast-style
encoder-processor-decoder with residual node/edge MLPs.
Full-batch COO signature: apply(params, x, src, dst, mask) — vmap-able over a
leading batch dim for the ``molecule`` shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dense


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _gather_typed_grad(x, idx, meta):
    """meta = (n_rows, dtype_str) — static."""
    return x[idx]


def _gather_fwd(x, idx, meta):
    return x[idx], idx


def _gather_bwd(meta, idx, ct):
    n_rows, dtype = meta
    # force the cotangent scatter-accumulation into the forward dtype —
    # XLA otherwise promotes gather backward to f32, which doubles the
    # node-state all-reduce payloads (§Perf/H4c)
    g = jnp.zeros((n_rows,) + ct.shape[1:], dtype).at[idx].add(
        ct.astype(dtype))
    return g, None


_gather_typed_grad.defvjp(_gather_fwd, _gather_bwd)


def _gather_bf16_grad(x, idx):
    return _gather_typed_grad(x, idx, (x.shape[0], str(x.dtype)))


def _segment_softmax(scores, dst, n, mask):
    scores = jnp.where(mask, scores, -1e30)
    mx = jax.ops.segment_max(scores, dst, num_segments=n)
    ex = jnp.where(mask, jnp.exp(scores - mx[dst]), 0.0)
    z = jax.ops.segment_sum(ex, dst, num_segments=n)
    return ex / jnp.maximum(z[dst], 1e-9)


def mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, a, b, bias=True, dtype=dtype)
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def mlp(ps, x, act=jax.nn.relu, final_act=False):
    for i, p in enumerate(ps):
        x = dense(p, x)
        if i < len(ps) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------- GAT

@dataclass(frozen=True)
class GATConfig:
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7


def gat_init(key, cfg: GATConfig, dtype=jnp.float32):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        heads = cfg.n_heads if i < cfg.n_layers - 1 else 1
        d_out = cfg.d_hidden if i < cfg.n_layers - 1 else cfg.n_classes
        layers.append({
            "w": dense_init(k1, d_in, heads * d_out, dtype=dtype),
            "a_src": (jax.random.normal(k2, (heads, d_out)) * 0.1).astype(dtype),
            "a_dst": (jax.random.normal(k3, (heads, d_out)) * 0.1).astype(dtype),
        })
        d_in = heads * d_out if i < cfg.n_layers - 1 else d_out
    return {"layers": layers}


def gat_apply(params, cfg: GATConfig, x, src, dst, mask):
    n = x.shape[0]
    for i, p in enumerate(params["layers"]):
        heads = cfg.n_heads if i < cfg.n_layers - 1 else 1
        d_out = p["w"]["w"].shape[1] // heads
        h = dense(p["w"], x).reshape(n, heads, d_out)
        e_src = (h * p["a_src"][None]).sum(-1)       # (n, heads)
        e_dst = (h * p["a_dst"][None]).sum(-1)
        scores = jax.nn.leaky_relu(e_src[src] + e_dst[dst], 0.2)  # (m, heads)
        alpha = jax.vmap(lambda s: _segment_softmax(s, dst, n, mask),
                         in_axes=1, out_axes=1)(scores)
        msg = h[src] * alpha[:, :, None]
        agg = jax.ops.segment_sum(
            jnp.where(mask[:, None, None], msg, 0), dst, num_segments=n)
        x = agg.reshape(n, heads * d_out)
        if i < cfg.n_layers - 1:
            x = jax.nn.elu(x)
    return x


# ---------------------------------------------------------------------- GIN

@dataclass(frozen=True)
class GINConfig:
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 16
    n_classes: int = 2


def gin_init(key, cfg: GINConfig, dtype=jnp.float32):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, key = jax.random.split(key)
        layers.append({
            "mlp": mlp_init(k1, [d_in, cfg.d_hidden, cfg.d_hidden], dtype),
            "eps": jnp.zeros((), dtype),   # learnable ε (GIN-ε)
        })
        d_in = cfg.d_hidden
    khead, key = jax.random.split(key)
    return {"layers": layers,
            "head": dense_init(khead, cfg.d_hidden, cfg.n_classes, bias=True,
                               dtype=dtype)}


def gin_apply(params, cfg: GINConfig, x, src, dst, mask):
    n = x.shape[0]
    for p in params["layers"]:
        agg = jax.ops.segment_sum(
            jnp.where(mask[:, None], x[src], 0), dst, num_segments=n)
        x = mlp(p["mlp"], (1.0 + p["eps"]) * x + agg, final_act=True)
    return dense(params["head"], x)


def gin_graph_logits(params, cfg: GINConfig, x, src, dst, mask):
    """Whole-graph classification: sum-pool then head (for molecule shape)."""
    n = x.shape[0]
    h = x
    for p in params["layers"]:
        agg = jax.ops.segment_sum(
            jnp.where(mask[:, None], h[src], 0), dst, num_segments=n)
        h = mlp(p["mlp"], (1.0 + p["eps"]) * h + agg, final_act=True)
    return dense(params["head"], h.sum(axis=0))


# --------------------------------------------------------------------- EGNN

@dataclass(frozen=True)
class EGNNConfig:
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16


def egnn_init(key, cfg: EGNNConfig, dtype=jnp.float32):
    k0, key = jax.random.split(key)
    layers = []
    for _ in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        d = cfg.d_hidden
        layers.append({
            "phi_e": mlp_init(k1, [2 * d + 1, d, d], dtype),
            "phi_x": mlp_init(k2, [d, d, 1], dtype),
            "phi_h": mlp_init(k3, [2 * d, d, d], dtype),
        })
    return {"embed": dense_init(k0, cfg.d_in, cfg.d_hidden, bias=True,
                                dtype=dtype),
            "layers": layers}


def egnn_apply(params, cfg: EGNNConfig, h, x, src, dst, mask):
    """h (n,d_in) invariant feats; x (n,3) coordinates.  E(n)-equivariant."""
    n = h.shape[0]
    h = dense(params["embed"], h)
    for p in params["layers"]:
        diff = x[src] - x[dst]                                 # (m, 3)
        dist2 = (diff ** 2).sum(-1, keepdims=True)
        m_ij = mlp(p["phi_e"],
                   jnp.concatenate([h[src], h[dst], dist2], -1),
                   final_act=True)
        m_ij = jnp.where(mask[:, None], m_ij, 0)
        # coordinate update (mean-normalized, E(n)-equivariant)
        coef = mlp(p["phi_x"], m_ij)
        wsum = jax.ops.segment_sum(diff * coef, dst, num_segments=n)
        deg = jax.ops.segment_sum(mask.astype(x.dtype), dst, num_segments=n)
        x = x + wsum / jnp.maximum(deg, 1)[:, None]
        # feature update
        agg = jax.ops.segment_sum(m_ij, dst, num_segments=n)
        h = h + mlp(p["phi_h"], jnp.concatenate([h, agg], -1))
    return h, x


# ----------------------------------------------------------------- GraphCast

@dataclass(frozen=True)
class GraphCastConfig:
    n_layers: int = 16
    d_hidden: int = 512
    d_in: int = 227          # n_vars
    d_out: int = 227
    mesh_refinement: int = 6


def graphcast_init(key, cfg: GraphCastConfig, dtype=jnp.float32):
    ke, kd, kp = jax.random.split(key, 3)
    d = cfg.d_hidden
    proc = []
    for _ in range(cfg.n_layers):
        k1, k2, kp = jax.random.split(kp, 3)
        proc.append({
            "edge_mlp": mlp_init(k1, [3 * d, d, d], dtype),
            "node_mlp": mlp_init(k2, [2 * d, d, d], dtype),
        })
    k3, k4, ke = jax.random.split(ke, 3)
    return {
        "encoder": mlp_init(k3, [cfg.d_in, d, d], dtype),
        "edge_embed": mlp_init(k4, [1, d, d], dtype),
        "processor": proc,
        "decoder": mlp_init(kd, [d, d, cfg.d_out], dtype),
    }


def graphcast_apply(params, cfg: GraphCastConfig, x, src, dst, mask,
                    edge_feat=None, shard_axes=None, comm_bf16=False):
    """Encoder -> n_layers residual message passing -> decoder (sum agg).

    ``shard_axes``: mesh axes to keep node/edge states sharded on (forces
    reduce-scatter-style aggregation instead of full all-reduce under
    GSPMD); ``comm_bf16``: cast messages/states at the shard boundary to
    bf16 (halves the collective payload).  Both are §Perf/H4 knobs.
    """
    from jax.sharding import PartitionSpec as P

    def con_nodes(z):
        if shard_axes is None:
            return z
        return jax.lax.with_sharding_constraint(z, P(shard_axes, None))

    def comm(z):
        return z.astype(jnp.bfloat16) if comm_bf16 else z

    n = x.shape[0]
    h = con_nodes(mlp(params["encoder"], x, final_act=True))
    if edge_feat is None:
        edge_feat = jnp.ones((src.shape[0], 1), h.dtype)
    e = mlp(params["edge_embed"], edge_feat, final_act=True)
    take = (_gather_bf16_grad if (comm_bf16 or h.dtype == jnp.bfloat16)
            else lambda z, i: z[i])
    for p in params["processor"]:
        hs, hd = take(comm(h), src), take(comm(h), dst)
        msg = mlp(p["edge_mlp"],
                  jnp.concatenate([hs, hd, e.astype(hs.dtype)], -1)
                  .astype(h.dtype),
                  final_act=True)
        e = e + jnp.where(mask[:, None], msg, 0)
        agg = jax.ops.segment_sum(comm(jnp.where(mask[:, None], msg, 0)),
                                  dst, num_segments=n)
        agg = con_nodes(agg).astype(h.dtype)
        h = con_nodes(h + mlp(p["node_mlp"], jnp.concatenate([h, agg], -1),
                              final_act=True))
    return mlp(params["decoder"], h)


# ------------------------------------------------------- minibatch (SAGE)

def sage_minibatch_apply(w_layers, sub, feats):
    """GraphSAGE-style forward over a SampledSubgraph (minibatch_lg shape).

    w_layers: list of dense params, one per hop (innermost hop first);
    sub: SampledSubgraph; feats: (n_total, d) global feature table (or a
    gather proxy).  Aggregation child -> parent via segment-mean.
    """
    layer_feats = [jnp.take(feats, sub.seeds, axis=0)]
    for blk in sub.blocks:
        layer_feats.append(jnp.take(feats, blk.nodes, axis=0))
    # aggregate from outermost hop inward
    h = layer_feats[-1]
    for depth in range(len(sub.blocks) - 1, -1, -1):
        blk = sub.blocks[depth]
        parent = layer_feats[depth]
        n_par = parent.shape[0]
        msg = jnp.where(blk.mask[:, None], h, 0)
        agg = jax.ops.segment_sum(msg, blk.parent_idx, num_segments=n_par)
        cnt = jax.ops.segment_sum(blk.mask.astype(h.dtype), blk.parent_idx,
                                  num_segments=n_par)
        agg = agg / jnp.maximum(cnt, 1)[:, None]
        h = jax.nn.relu(dense(w_layers[depth],
                              jnp.concatenate([parent, agg], -1)))
    return h

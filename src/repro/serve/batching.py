"""Micro-batch execution: N compatible requests on one warm solver.

A *batch* is a list of problems that share a registry key — same graph
(by name *and* content digest: a replaced or delta-mutated graph keys
apart, so a batch can never mix pools across graph versions), same pool
signature (model / ``t_rounds`` / ``node_weights`` / ``mode`` — so
``mode="approximate"`` requests are batch-compatible only with each
other, their pool-free sketch store being a different species of pool),
same θ-mode (``WarmSolverRegistry.solver_key``).  Within a batch the requests may
differ in everything selection-side: ``k``, ``candidates``, ``costs`` +
``budget``, ``eps``/``ell``/``max_theta`` (the compatibility matrix of
DESIGN.md §7).  Execution shares the sampled pool across all of them —
the pool is paid for once — and runs one selection per request.

**Shared-Occur fast path.**  Top-1 requests (``k=1``, fixed θ, no
budget/rounds/row-weighting — "who is the most influential node [in
candidate set C]?") need no greedy scan at all: the first greedy pick is
``argmax`` of the Occur histogram masked to the candidates, its gain *is*
``Occur[u]``, and ties resolve to the lowest id exactly like
``jnp.argmax``.  The batch computes the psum-reduced Occur histogram
**once** (one explicit device fetch) and answers every such request from
it, mirroring the device scan's arithmetic (single float32 rounding for
``F_R``) so the results remain bit-identical to a full solve.

Everything here is synchronous — the asyncio front runs it on its worker
thread; tests drive it directly under ``jax.transfer_guard("disallow")``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np
import jax

from repro.core import coverage as cov
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem, IMResult, ResolvedProblem


def occur_fastpath_eligible(solver: IMMSolver, p: IMProblem) -> bool:
    """True iff the request's selection is exactly "argmax of (masked)
    Occur": single seed, fixed θ (no LB-loop selections), counting
    objective (no budget/cost-ratio, no per-round groups, no row-weighted
    estimator — weight-proportional *root* sampling is fine: its selection
    is the plain counting program).  Approximate-mode requests never
    qualify: their pool-free store has no flat pool to histogram (and their
    contract is the certified sketch estimate, not an exact Occur count)."""
    return (p.theta is not None and p.k == 1 and p.t_rounds is None
            and p.budget is None and p.mode != "approximate"
            and not solver._row_weight_mode)


def _solve_from_occur(solver: IMMSolver, r: ResolvedProblem,
                      occur: np.ndarray, n_rr: int) -> Optional[IMResult]:
    """Answer a top-1 request from the shared Occur histogram, matching the
    device scan bit-for-bit (argmax ties -> lowest id; gain == Occur[u]
    because nothing is covered before the first pick; F_R rounds once in
    float32 like the device division).  Returns None when no candidate is
    feasible (caller falls back to the full solve)."""
    p = r.problem
    mask = r.cand_mask_items
    if mask is None:
        u = int(np.argmax(occur))
    else:
        # select_variant's pick: -1 on infeasible ids, argmax, ok iff >= 0
        masked = np.where(mask, occur, np.int32(-1))
        u = int(np.argmax(masked))
        if masked[u] < 0:
            return None
    gain = int(occur[u])
    frac = float(np.float32(np.float32(gain)
                            / np.float32(max(n_rr, 1))))
    st = solver._stats
    st.theta = p.theta
    st.lb = 1.0
    st.frac_covered = frac
    st.variant = p.variant
    st.budget_spent = 0.0
    return IMResult(seeds=np.array([u], np.int32), spread=r.scale * frac,
                    gains=np.array([gain], np.int32), frac=frac,
                    stats=solver.stats, problem=p, n_nodes=solver.n,
                    cost=0.0)


def stacked_eligible(solver: IMMSolver, p: IMProblem) -> bool:
    """True iff the request can ride the batch's single stacked selection
    scan (:meth:`IMMSolver.solve_stacked`): fixed θ (one shared pool state,
    no LB loop) and a counting objective the stacked program expresses
    (exact mode, no row-weighted estimator).  ``k=1`` Occur-fastpath
    requests are cheaper still and get peeled off first; deadline-bearing
    requests go solo (the stacked scan has no mid-flight degrade point)."""
    return (p.theta is not None and p.mode != "approximate"
            and not solver._row_weight_mode)


def execute_batch(solver: IMMSolver, problems: List[IMProblem],
                  deadlines: Optional[List[Optional[float]]] = None,
                  *, stacked: bool = True,
                  stats_out: Optional[dict] = None) -> List[IMResult]:
    """Run one micro-batch on a warm solver; returns results aligned with
    ``problems``.

    All problems must share the solver's pool signature and θ-mode (the
    caller batches by registry key).  The pool is sampled at most once;
    eligible top-1 requests share a single Occur pass; two or more
    remaining fixed-θ requests share ONE stacked selection scan
    (``stacked=True``, the default — DESIGN.md §11); everything else goes
    through the full ``solve_problem`` (which reuses the pool).  Every
    route is bit-identical to the solo solve, so the flag is purely a
    throughput knob.  ``solver.prepare`` runs host-side construction up
    front, so the whole call after it is legal under an outer
    ``jax.transfer_guard("disallow")``.

    ``deadlines`` (aligned with ``problems``): per-request remaining
    seconds, forwarded to ``solve_problem(deadline_s=...)`` so an
    over-budget solve degrades to a sketch-bound answer mid-flight instead
    of blowing the deadline (the fast path ignores it — answering from the
    already-fetched histogram is strictly cheaper than degrading).

    ``stats_out``: mutated with ``stacked_batches`` / ``stacked_requests``
    counters when the stacked path runs (the front surfaces them in
    ``/statsz``).
    """
    if not problems:
        return []
    if deadlines is None:
        deadlines = [None] * len(problems)
    occur = None          # shared histogram, fetched at most once per batch
    n_rr = 0
    results: List[Optional[IMResult]] = [None] * len(problems)
    stack_idx: List[int] = []
    for i, (p, dl) in enumerate(zip(problems, deadlines)):
        if occur_fastpath_eligible(solver, p):
            r = solver.prepare(p)
            if occur is None:
                with jax.transfer_guard(solver._guard):
                    solver.sample_until(p.theta)
                store = solver.store
                fns = cov._mesh_select_fns(store.mesh)
                occur = np.asarray(jax.device_get(fns.occur(
                    store._flat, store._valid, n=store.n_nodes)))
                n_rr = store.n_rr
            res = _solve_from_occur(solver, r, occur, n_rr)
            if res is not None:
                results[i] = res
                continue
        if stacked and dl is None and stacked_eligible(solver, p):
            stack_idx.append(i)
            continue
        results[i] = solver.solve_problem(p, deadline_s=dl)
    # group by θ so a hand-built batch with mixed fixed θs still stacks
    # per θ-cohort (front-built batches share one θ via the registry key)
    groups: dict = {}
    for i in stack_idx:
        groups.setdefault(problems[i].theta, []).append(i)
    for idx in groups.values():
        if len(idx) < 2:
            i = idx[0]
            results[i] = solver.solve_problem(problems[i])
            continue
        for i, res in zip(idx, solver.solve_stacked(
                [problems[i] for i in idx])):
            results[i] = res
        if stats_out is not None:
            stats_out["stacked_batches"] = \
                stats_out.get("stacked_batches", 0) + 1
            stats_out["stacked_requests"] = \
                stats_out.get("stacked_requests", 0) + len(idx)
    return results

"""Compressed-sparse-row graph representation (paper §3.2, Fig. 1).

The paper stores G as three arrays: row offsets ``R`` (n+1), column indices
``C`` (m) and edge weights ``W`` (m), in input order (no pre-sorting).  We keep
exactly that layout.  Construction happens host-side in numpy; the resulting
arrays are ordinary jnp arrays usable inside jit/shard_map.

RR-set sampling runs a randomized BFS on the *transposed* instance graph
(paper §3.1), so :func:`reverse` builds the CSC/transpose with the original
edge weight p_uv carried onto the reversed edge (v -> u).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp


class CSRGraph(NamedTuple):
    """CSR adjacency. ``offsets[i]:offsets[i+1]`` indexes node i's out-edges."""

    offsets: jnp.ndarray  # (n+1,) int32
    indices: jnp.ndarray  # (m,)  int32
    weights: jnp.ndarray  # (m,)  float32

    @property
    def n_nodes(self) -> int:
        return int(self.offsets.shape[0]) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def out_degree(self):
        return self.offsets[1:] - self.offsets[:-1]


def from_edges(src, dst, n: int, weights=None, sort: bool = True) -> CSRGraph:
    """Build CSR from an edge list (numpy, host-side).

    ``sort=True`` groups edges by source (stable, preserving relative input
    order within a row, matching the paper's no-reordering statement).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError("src/dst shape mismatch")
    m = src.shape[0]
    if weights is None:
        weights = np.ones(m, dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    if m and (src.min() < 0 or src.max() >= n or dst.min() < 0 or dst.max() >= n):
        raise ValueError("edge endpoint out of range")
    if sort and m:
        order = np.argsort(src, kind="stable")
        src, dst, weights = src[order], dst[order], weights[order]
    counts = np.bincount(src, minlength=n).astype(np.int64)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSRGraph(
        offsets=jnp.asarray(offsets, dtype=jnp.int32),
        indices=jnp.asarray(dst, dtype=jnp.int32),
        weights=jnp.asarray(weights, dtype=jnp.float32),
    )


def to_edges(g: CSRGraph):
    """Return (src, dst, w) numpy edge arrays."""
    offsets = np.asarray(g.offsets, dtype=np.int64)
    n = offsets.shape[0] - 1
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(offsets))
    return src, np.asarray(g.indices, dtype=np.int64), np.asarray(g.weights)


def reverse(g: CSRGraph) -> CSRGraph:
    """Transpose: edge (u,v,w) becomes (v,u,w).  RR sampling runs on this."""
    src, dst, w = to_edges(g)
    return from_edges(dst, src, g.n_nodes, weights=w)


def degrees(g: CSRGraph):
    """(out_degree, in_degree) as numpy int64 arrays."""
    offsets = np.asarray(g.offsets, dtype=np.int64)
    out_deg = np.diff(offsets)
    in_deg = np.bincount(np.asarray(g.indices, dtype=np.int64),
                         minlength=offsets.shape[0] - 1)
    return out_deg, in_deg


def max_out_degree(g: CSRGraph) -> int:
    out_deg, _ = degrees(g)
    return int(out_deg.max()) if out_deg.size else 0

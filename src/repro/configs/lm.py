"""The five assigned LM architectures — exact public configs.

[sources per the assignment brief: arXiv:2407.10671 (qwen2), arXiv:2402.00838
(olmo), hf:google/gemma-3 (gemma3-12b), arXiv:2412.19437 (deepseek-v3),
hf:meta-llama/Llama-4-Scout (llama4)].
"""
from __future__ import annotations

from repro.models.transformer import LMConfig
from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig


def qwen2_0_5b(dtype="bfloat16") -> LMConfig:
    # 24L d896 14H GQA(kv=2) dff4864 vocab 151936; QKV bias; tied embeddings
    return LMConfig(name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14,
                    n_kv_heads=2, head_dim=64, d_ff=4864, vocab=151936,
                    qkv_bias=True, norm="rms", act="swiglu",
                    rope_theta=1e6, tie_embeddings=True, dtype=dtype)


def olmo_1b(dtype="bfloat16") -> LMConfig:
    # 16L d2048 16H (kv=16 => MHA) dff8192 vocab 50304; non-parametric LN
    return LMConfig(name="olmo-1b", n_layers=16, d_model=2048, n_heads=16,
                    n_kv_heads=16, head_dim=128, d_ff=8192, vocab=50304,
                    norm="nonparam", act="swiglu", rope_theta=10000.0,
                    tie_embeddings=False, dtype=dtype)


def gemma3_12b(dtype="bfloat16") -> LMConfig:
    # 48L d3840 16H GQA(kv=8) dff15360 vocab 262144; 5 local (w=1024) : 1
    # global; GeGLU; head_dim 256
    return LMConfig(name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
                    n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
                    norm="rms", act="geglu", rope_theta=1e6,
                    local_global=(5, 1024), tie_embeddings=True, dtype=dtype)


def deepseek_v3_671b(dtype="bfloat16") -> LMConfig:
    # 61L d7168; MLA 128H; MoE 1 shared + 256 routed top-8 (dff 2048);
    # first 3 layers dense (dff 18432); MTP; vocab 129280
    return LMConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=2048, vocab=129280,
        mla=MLAConfig(n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                      d_ff_shared=2048, capacity_factor=1.25,
                      router_score="sigmoid"),
        n_dense_layers=3, d_ff_dense=18432, mtp=True,
        norm="rms", act="swiglu", rope_theta=10000.0,
        tie_embeddings=False, dtype=dtype)


def llama4_scout(dtype="bfloat16") -> LMConfig:
    # 48L d5120 40H GQA(kv=8) ; MoE 16 routed top-1 + 1 shared (dff 8192);
    # vocab 202048.  Early-fusion modality frontend is a STUB per the brief
    # (input_specs provides token ids; patch embeddings would enter the same
    # embedding table space).
    return LMConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1,
                      d_ff_shared=8192, capacity_factor=1.25,
                      router_score="sigmoid"),
        norm="rms", act="swiglu", rope_theta=500000.0,
        tie_embeddings=False, dtype=dtype)


def reduced_lm(full: LMConfig) -> LMConfig:
    """Family-preserving smoke config: few layers, thin width, tiny vocab."""
    kw = dict(
        name=f"{full.name}-smoke", n_layers=2 + (1 if full.n_dense_layers else 0),
        d_model=32, n_heads=4, n_kv_heads=min(4, max(1, full.n_kv_heads // 4)),
        head_dim=8, d_ff=64, vocab=128, qkv_bias=full.qkv_bias,
        norm=full.norm, act=full.act, rope_theta=full.rope_theta,
        tie_embeddings=full.tie_embeddings, mtp=full.mtp, dtype="float32")
    if full.local_global is not None:
        kw["local_global"] = (1, 4)
    if full.moe is not None:
        kw["moe"] = full.moe._replace(n_experts=4, top_k=min(2, full.moe.top_k),
                                      d_ff_expert=32, d_ff_shared=32,
                                      capacity_factor=2.0)
        kw["n_dense_layers"] = 1 if full.n_dense_layers else 0
        kw["d_ff_dense"] = 64 if full.n_dense_layers else None
    if full.mla is not None:
        kw["mla"] = MLAConfig(n_heads=4, q_lora_rank=16, kv_lora_rank=8,
                              qk_nope_head_dim=8, qk_rope_head_dim=4,
                              v_head_dim=8)
    return LMConfig(**kw)

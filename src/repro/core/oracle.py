"""Pure-numpy serial reference for the RIS/IMM pipeline.

This is the "IMM on one CPU core" baseline the paper compares against
(Table 2), and the correctness oracle for the JAX engines:

* :func:`rr_set_ic` — one RR set under IC: randomized reverse BFS.
* :func:`rr_set_lt` — one RR set under LT: reverse random walk.
* :func:`greedy_max_coverage` — Alg. 1 lines 6-10 (lazy-free exact greedy).
* :func:`imm_oracle` — full serial IMM (Alg. 2 + θ sampling + selection).
"""
from __future__ import annotations

import math

import numpy as np


def rr_set_ic(offsets, indices, weights, root: int, rng: np.random.Generator):
    """Randomized BFS on the reverse graph CSR (pass the *reverse* CSR)."""
    visited = {int(root)}
    queue = [int(root)]
    head = 0
    while head < len(queue):
        u = queue[head]
        head += 1
        s, e = offsets[u], offsets[u + 1]
        if e > s:
            keep = rng.random(e - s) < weights[s:e]
            for v in indices[s:e][keep]:
                v = int(v)
                if v not in visited:
                    visited.add(v)
                    queue.append(v)
    return queue  # visit order; queue == RR set


def rr_set_lt(offsets, indices, weights, root: int, rng: np.random.Generator):
    """LT RR set: reverse walk picking at most one in-edge per node."""
    visited = {int(root)}
    walk = [int(root)]
    u = int(root)
    while True:
        s, e = offsets[u], offsets[u + 1]
        if e == s:
            return walk
        w = weights[s:e]
        r = rng.random()
        cum = np.cumsum(w)
        if r >= cum[-1]:
            return walk  # stopped: total prob <= 1
        j = int(np.searchsorted(cum, r, side="right"))
        v = int(indices[s + j])
        if v in visited:
            return walk
        visited.add(v)
        walk.append(v)
        u = v


def greedy_max_coverage(rr_sets: list[list[int]], n: int, k: int):
    """Exact greedy (ties -> lowest node id, matching the JAX argmax rule)."""
    occur = np.zeros(n, dtype=np.int64)
    node_to_rr: dict[int, list[int]] = {}
    for i, rr in enumerate(rr_sets):
        for v in rr:
            occur[v] += 1
            node_to_rr.setdefault(v, []).append(i)
    covered = np.zeros(len(rr_sets), dtype=bool)
    seeds = []
    n_covered = 0
    for _ in range(k):
        u = int(np.argmax(occur))
        seeds.append(u)
        for i in node_to_rr.get(u, []):
            if not covered[i]:
                covered[i] = True
                n_covered += 1
                for v in rr_sets[i]:
                    occur[v] -= 1
    frac = n_covered / max(len(rr_sets), 1)
    return seeds, frac


def greedy_max_coverage_weighted(rr_sets: list[list[int]], n: int, k: int,
                                 row_weights):
    """Weighted greedy reference: each RR row carries a weight (its root's
    node weight under the importance-weighted estimator); greedy maximizes
    the covered *weight* (ties -> lowest node id, matching the JAX argmax).
    Returns (seeds, covered_weight / total_weight)."""
    w = np.asarray(row_weights, dtype=np.float64)
    occur = np.zeros(n, dtype=np.float64)
    node_to_rr: dict[int, list[int]] = {}
    for i, rr in enumerate(rr_sets):
        for v in rr:
            occur[v] += w[i]
            node_to_rr.setdefault(v, []).append(i)
    covered = np.zeros(len(rr_sets), dtype=bool)
    seeds = []
    w_covered = 0.0
    for _ in range(k):
        u = int(np.argmax(occur))
        seeds.append(u)
        for i in node_to_rr.get(u, []):
            if not covered[i]:
                covered[i] = True
                w_covered += w[i]
                for v in rr_sets[i]:
                    occur[v] -= w[i]
    total = float(w.sum())
    return seeds, w_covered / max(total, 1e-300)


def budgeted_greedy_cost_ratio(rr_sets: list[list[int]], n: int, costs,
                               budget: float, candidates=None):
    """Budgeted IM reference: lazy-free cost-ratio greedy.  Picks the
    affordable candidate maximizing marginal-coverage / cost (ties ->
    lowest node id) until nothing affordable with positive gain remains.
    Returns (seeds, frac_covered, total_cost)."""
    costs = np.asarray(costs, dtype=np.float64)
    cand = (np.ones(n, bool) if candidates is None
            else np.asarray(candidates, bool))
    occur = np.zeros(n, dtype=np.float64)
    node_to_rr: dict[int, list[int]] = {}
    for i, rr in enumerate(rr_sets):
        for v in rr:
            occur[v] += 1.0
            node_to_rr.setdefault(v, []).append(i)
    covered = np.zeros(len(rr_sets), dtype=bool)
    seeds = []
    spent = 0.0
    n_covered = 0
    while True:
        feas = cand & (costs <= budget - spent) & (occur > 0)
        if not feas.any():
            break
        score = np.where(feas, occur / costs, -np.inf)
        u = int(np.argmax(score))
        seeds.append(u)
        spent += float(costs[u])
        for i in node_to_rr.get(u, []):
            if not covered[i]:
                covered[i] = True
                n_covered += 1
                for v in rr_sets[i]:
                    occur[v] -= 1.0
    frac = n_covered / max(len(rr_sets), 1)
    return seeds, frac, spent


def log_cnk(n: int, k: int) -> float:
    return math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)


def imm_theta_params(n: int, k: int, eps: float, ell: float = 1.0):
    """IMM's λ', λ* (Tang et al. 2015, Eqs. 9 & 6), with the ℓ adjustment."""
    ell = ell * (1.0 + math.log(2) / math.log(n))
    eps_p = math.sqrt(2.0) * eps
    lcnk = log_cnk(n, k)
    lam_p = ((2.0 + 2.0 / 3.0 * eps_p)
             * (lcnk + ell * math.log(n) + math.log(math.log2(n)))
             * n / (eps_p ** 2))
    alpha = math.sqrt(ell * math.log(n) + math.log(2))
    beta = math.sqrt((1.0 - 1.0 / math.e) * (lcnk + ell * math.log(n) + math.log(2)))
    lam_star = 2.0 * n * (((1.0 - 1.0 / math.e) * alpha + beta) ** 2) / (eps ** 2)
    return lam_p, lam_star, eps_p, ell


def imm_oracle(offsets_rev, indices_rev, weights_rev, n: int, k: int, eps: float,
               seed: int = 0, model: str = "ic", max_theta: int | None = None):
    """Serial IMM.  Returns (seeds, rr_sets, theta)."""
    rng = np.random.default_rng(seed)
    lam_p, lam_star, eps_p, _ = imm_theta_params(n, k, eps)
    sample = rr_set_ic if model == "ic" else rr_set_lt

    def draw(count):
        return [sample(offsets_rev, indices_rev, weights_rev,
                       int(rng.integers(n)), rng) for _ in range(count)]

    rr_sets: list[list[int]] = []
    lb = 1.0
    for i in range(1, max(int(math.log2(n)), 2)):
        x = n / (2.0 ** i)
        theta_i = int(math.ceil(lam_p / x))
        if max_theta:
            theta_i = min(theta_i, max_theta)
        if len(rr_sets) < theta_i:
            rr_sets += draw(theta_i - len(rr_sets))
        seeds, frac = greedy_max_coverage(rr_sets, n, k)
        if n * frac >= (1.0 + eps_p) * x:
            lb = n * frac / (1.0 + eps_p)
            break
    theta = int(math.ceil(lam_star / lb))
    if max_theta:
        theta = min(theta, max_theta)
    if len(rr_sets) < theta:
        rr_sets += draw(theta - len(rr_sets))
    seeds, frac = greedy_max_coverage(rr_sets, n, k)
    return seeds, rr_sets, theta


def forward_ic_spread(offsets, indices, weights, seeds, rng,
                      n_sims: int = 200, node_weights=None):
    """Forward Monte-Carlo spread under IC on the *forward* CSR (oracle).

    Unweighted: E[|I(S)|].  With ``node_weights``: the weight-aware spread
    ``E[Σ_{v ∈ I(S)} w_v]`` — the objective of weighted IM, used as the
    conformance reference for the weight-proportional RIS estimator.
    """
    n = len(offsets) - 1
    w = None if node_weights is None else np.asarray(node_weights,
                                                     dtype=np.float64)
    total = 0.0
    for _ in range(n_sims):
        active = set(int(s) for s in seeds)
        queue = list(active)
        head = 0
        while head < len(queue):
            u = queue[head]
            head += 1
            s, e = offsets[u], offsets[u + 1]
            if e > s:
                keep = rng.random(e - s) < weights[s:e]
                for v in indices[s:e][keep]:
                    v = int(v)
                    if v not in active:
                        active.add(v)
                        queue.append(v)
        total += (len(active) if w is None
                  else float(w[np.fromiter(active, int)].sum()))
    return total / n_sims

import numpy as np
import jax
import jax.numpy as jnp
import networkx as nx
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights, sampler


def _random_edges(n=50, m=200, seed=0):
    return generators.erdos_renyi(n, m, seed=seed)


def test_csr_roundtrip():
    src, dst = _random_edges()
    g = csr_mod.from_edges(src, dst, 50)
    s2, d2, _ = csr_mod.to_edges(g)
    assert sorted(zip(s2.tolist(), d2.tolist())) == sorted(zip(src.tolist(), dst.tolist()))


def test_csr_rows_match_adjacency():
    src, dst = _random_edges(seed=3)
    g = csr_mod.from_edges(src, dst, 50)
    offs = np.asarray(g.offsets)
    idx = np.asarray(g.indices)
    adj = {}
    for s, d in zip(src, dst):
        adj.setdefault(int(s), []).append(int(d))
    for u in range(50):
        row = sorted(idx[offs[u]:offs[u + 1]].tolist())
        assert row == sorted(adj.get(u, []))


def test_reverse_is_transpose():
    src, dst = _random_edges(seed=1)
    g = csr_mod.from_edges(src, dst, 50, weights=np.arange(len(src), dtype=np.float32))
    gt = csr_mod.reverse(g)
    s, d, w = csr_mod.to_edges(g)
    s2, d2, w2 = csr_mod.to_edges(gt)
    fwd = sorted(zip(s.tolist(), d.tolist(), w.tolist()))
    rev = sorted(zip(d2.tolist(), s2.tolist(), w2.tolist()))
    assert fwd == rev


def test_wc_weights_sum_to_one_per_node():
    src, dst = _random_edges(seed=2)
    g = weights.wc_weights(csr_mod.from_edges(src, dst, 50))
    s, d, w = csr_mod.to_edges(g)
    sums = np.zeros(50)
    np.add.at(sums, d, w)
    indeg = np.bincount(d, minlength=50)
    np.testing.assert_allclose(sums[indeg > 0], 1.0, rtol=1e-5)


def test_barabasi_albert_properties():
    src, dst = generators.barabasi_albert(2000, 3, seed=0)
    assert np.all(src != dst)
    # symmetric edge set
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in fwd for s, d in list(fwd)[:500])
    # power-law-ish: max degree much larger than mean
    deg = np.bincount(src, minlength=2000)
    assert deg.max() > 5 * deg.mean()


def test_icosahedral_multimesh_counts():
    verts, src, dst = generators.icosahedral_multimesh(2)
    # 10*4^R + 2 vertices
    assert verts.shape == (162, 3)
    np.testing.assert_allclose(np.linalg.norm(verts, axis=1), 1.0, rtol=1e-5)
    # symmetric, no self loops
    assert np.all(src != dst)
    e = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in e for s, d in list(e)[:200])


def test_two_tier_reachability():
    src, dst, n = generators.two_tier_social(4, 2)
    g = csr_mod.from_edges(src, dst, n)
    G = nx.DiGraph(list(zip(src.tolist(), dst.tolist())))
    # every leaf reachable from core 0 through the ring
    reach = nx.descendants(G, 0) | {0}
    assert len(reach) == n


def test_neighbor_sampler_shapes_and_validity():
    src, dst = _random_edges(n=30, m=120, seed=5)
    g = csr_mod.from_edges(src, dst, 30)
    seeds = jnp.asarray([0, 3, 7, 11], dtype=jnp.int32)
    sub = sampler.sample_subgraph(jax.random.key(0), g, seeds, (5, 3))
    b1, b2 = sub.blocks
    assert b1.nodes.shape == (4 * 5,)
    assert b2.nodes.shape == (4 * 5 * 3,)
    offs = np.asarray(g.offsets); idx = np.asarray(g.indices)
    nodes1 = np.asarray(b1.nodes); mask1 = np.asarray(b1.mask)
    parents = np.asarray(seeds)[np.asarray(b1.parent_idx)]
    for nb, p, mk in zip(nodes1, parents, mask1):
        if mk:
            assert nb in idx[offs[p]:offs[p + 1]]
        else:
            assert nb == p  # self-loop padding


def test_partition_edges_covers_all():
    from repro.graph import partition
    src, dst = _random_edges(seed=7)
    g = csr_mod.from_edges(src, dst, 50, weights=np.arange(len(src), dtype=np.float32))
    sh = partition.partition_edges(g, 8)
    assert sh.src.shape[0] == 8
    m = len(src)
    assert int(sh.mask.sum()) == m
    flat = sorted(zip(np.asarray(sh.src).ravel()[np.asarray(sh.mask).ravel()].tolist(),
                      np.asarray(sh.dst).ravel()[np.asarray(sh.mask).ravel()].tolist()))
    assert flat == sorted(zip(src.tolist(), dst.tolist()))


def test_from_edges_sort_false_requires_grouped_input():
    # grouped (src non-decreasing) input builds the same CSR as sort=True
    src = np.array([0, 0, 1, 3]); dst = np.array([2, 1, 3, 0])
    g = csr_mod.from_edges(src, dst, 4, sort=False)
    g2 = csr_mod.from_edges(src, dst, 4, sort=True)
    assert np.array_equal(np.asarray(g.offsets), np.asarray(g2.offsets))
    assert np.array_equal(np.asarray(g.indices), np.asarray(g2.indices))
    # ungrouped input used to produce a silently corrupt CSR (bincount
    # offsets paired with input-order indices); now it raises
    with pytest.raises(ValueError, match="source-grouped"):
        csr_mod.from_edges([2, 0, 1], [0, 1, 2], 3, sort=False)
    # the graph/weights.py callers feed to_edges output (grouped by
    # construction) into sort=False — they must keep passing
    rs, rd = _random_edges(seed=11)
    wg = weights.wc_weights(csr_mod.from_edges(rs, rd, 50))
    assert wg.n_edges == len(rs)


def test_graph_digest_content_identity():
    src, dst = _random_edges(seed=4)
    w = np.random.default_rng(0).random(len(src)).astype(np.float32)
    g = csr_mod.from_edges(src, dst, 50, weights=w)
    g_same = csr_mod.from_edges(src.copy(), dst.copy(), 50, weights=w.copy())
    assert csr_mod.graph_digest(g) == csr_mod.graph_digest(g_same)
    # any content change — weights or topology — changes the digest
    w2 = w.copy(); w2[0] += 0.25
    g_w = csr_mod.from_edges(src, dst, 50, weights=w2)
    assert csr_mod.graph_digest(g_w) != csr_mod.graph_digest(g)
    g_t = csr_mod.from_edges(src[:-1], dst[:-1], 50, weights=w[:-1])
    assert csr_mod.graph_digest(g_t) != csr_mod.graph_digest(g)

"""§Perf/Serving: load test for the IM-as-a-service front (DESIGN.md §7).

An asyncio open-loop load generator drives the micro-batched request front
with a mixed θ-pinned workload — varying ``k``, candidate restrictions, and
repeated requests (the cache's food) — at ≥2 offered QPS levels, and
records per-level:

* latency percentiles (p50/p95/p99) and mean, measured submit→response;
* achieved throughput (served requests / wall time);
* batch occupancy (mean/max requests per executed micro-batch);
* cache-hit rate and shed/expired counts.

Before the load levels run, a **parity gate** solves a probe subset of the
workload on *fresh single-request solvers* (same solver_opts) and asserts
the served seeds/gains/spread are bit-identical — the θ-in-key contract
the registry guarantees (ISSUE 6 acceptance criterion).

Writes ``experiments/bench/BENCH_serving.json``.

``--smoke`` (CI's serve-smoke job): small graph, ~50 requests, asserts
nonzero cache hits and zero shed requests, then exits 0.

``--chaos`` (CI's chaos-smoke job, DESIGN.md §8): reruns the workload with
a ~10% seeded fault rate injected across every pipeline boundary
(sample/append/grow/select/executor) and asserts the service's
fault-tolerance contract: **every** request resolves to a typed outcome
(served, degraded, or a ``ServeError`` subclass — zero hangs, zero
untyped exceptions), and every non-degraded answer is bit-identical to a
fault-free fresh solve (which also proves no quarantined pool ever
served).  Writes ``experiments/bench/BENCH_chaos.json``.

CPU-container scaling note (benchmarks/common.py): offered QPS here
exercises the *front* (admission, batching, cache) — per-request solve cost
on this single scalar core is milliseconds, so the interesting numbers are
occupancy and hit-rate, not absolute latency.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np

from benchmarks.common import OUT_DIR, ba_graph
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.serve import ServeConfig, ServeError, build_service

SOLVER_OPTS = {"batch": 64, "seed": 0}


def make_workload(g, requests: int, theta: int, seed: int = 0):
    """Mixed θ-pinned request stream: varying k, two candidate pools, and a
    zipf-ish repeat pattern so the cache sees realistic re-asks."""
    deg = np.diff(np.asarray(g.offsets))
    top = np.argsort(-deg, kind="stable")
    distinct = [IMProblem(k=k, theta=theta) for k in (1, 2, 5, 10)]
    distinct += [IMProblem(k=1, theta=theta, candidates=top[:m])
                 for m in (g.n_nodes // 4, g.n_nodes // 2)]
    distinct += [IMProblem(k=3, theta=theta,
                           candidates=top[:g.n_nodes // 4])]
    rng = np.random.default_rng(seed)
    # zipf-like popularity: low indices re-asked often
    idx = np.minimum(rng.zipf(1.5, size=requests) - 1, len(distinct) - 1)
    return [distinct[i] for i in idx], distinct


def parity_gate(g, probe, served_by_digest):
    """Assert serving answers == fresh single-request cold solves."""
    for p in probe:
        fresh = IMMSolver(g, **SOLVER_OPTS).solve(p)
        got = served_by_digest[p.signature_digest()]
        np.testing.assert_array_equal(fresh.seeds, got.seeds)
        np.testing.assert_array_equal(fresh.gains, got.gains)
        assert fresh.frac == got.frac
        assert fresh.spread == got.spread
    return len(probe)


async def run_level(g, workload, qps: float, *, max_batch: int,
                    deadline_s=None, queue_cap: int = 256):
    """Open-loop load: submit at the offered rate regardless of completion
    (closed-loop load generators hide queueing collapse)."""
    svc = build_service({"g": g}, ServeConfig(
        max_batch=max_batch, queue_cap=queue_cap, batch_window_s=0.002,
        default_deadline_s=deadline_s, solver_opts=SOLVER_OPTS))
    lat, shed, results = [], 0, {}

    async def one(p):
        nonlocal shed
        t0 = time.perf_counter()
        try:
            resp = await svc.submit("g", p)
        except Exception:
            shed += 1
            return
        lat.append(time.perf_counter() - t0)
        results[p.signature_digest()] = resp.result

    interval = 1.0 / qps
    t_start = time.perf_counter()
    async with svc:
        tasks = []
        for i, p in enumerate(workload):
            # open loop: sleep to the scheduled submit time, don't await
            lag = t_start + i * interval - time.perf_counter()
            if lag > 0:
                await asyncio.sleep(lag)
            tasks.append(asyncio.ensure_future(one(p)))
        await asyncio.gather(*tasks)
        wall = time.perf_counter() - t_start
        st = svc.stats()
    lat_ms = np.asarray(sorted(lat)) * 1e3
    pct = (lambda q: float(np.percentile(lat_ms, q)) if lat_ms.size else 0.0)
    return {
        "offered_qps": qps,
        "requests": len(workload),
        "served": st.served,
        "shed": st.shed,
        "expired": st.expired,
        "achieved_qps": st.served / wall if wall > 0 else 0.0,
        "latency_ms": {"p50": pct(50), "p95": pct(95), "p99": pct(99),
                       "mean": float(lat_ms.mean()) if lat_ms.size else 0.0},
        "batches": st.batches,
        "batch_occupancy_mean": st.batch_occupancy_mean,
        "batch_occupancy_max": st.batch_occupancy_max,
        "occur_fastpath": st.occur_fastpath,
        "cache_hit_rate": st.cache.hit_rate,
        "cache_hits": st.cache_hits,
        "registry_solvers": st.registry.solvers,
        "registry_bytes": st.registry.bytes_in_use,
    }, results


async def run_chaos(g, workload, *, max_batch: int, rate: float,
                    deadline_probes: int, probe_theta: int,
                    timeout_s: float = 120.0):
    """Chaos run: seeded Bernoulli faults at every pipeline boundary, every
    request wrapped in ``wait_for`` so a hang is an *observed outcome*, not
    a stuck bench.  ``deadline_probes`` extra requests carry a deadline too
    tight for their big-θ cold solve, exercising the degraded path."""
    from repro.ft.failures import SITES, FaultInjector, FaultPolicy

    # the executor boundary is crossed once per *batch* (tens of crossings
    # vs thousands of solver-loop ones), so it gets a higher rate to make
    # the quarantine + isolation path actually fire in a short smoke run
    rates = {s: rate for s in SITES}
    rates["executor"] = min(1.0, 3.0 * rate)
    injector = FaultInjector(rate=rates, seed=1234)
    policy = FaultPolicy(injector=injector, backoff_base_s=0.001,
                         backoff_cap_s=0.01)
    svc = build_service({"g": g}, ServeConfig(
        max_batch=max_batch, queue_cap=512, batch_window_s=0.002,
        solver_opts={**SOLVER_OPTS, "fault_policy": policy, "sketch_k": 64},
        breaker_threshold=5, breaker_cooldown_s=0.05))
    outcomes: dict = {"served": 0, "degraded": 0, "hang": 0}
    results, degraded_bounds_ok = {}, []

    async def one(p, dl=None):
        try:
            resp = await asyncio.wait_for(
                svc.submit("g", p, deadline_s=dl), timeout_s)
        except asyncio.TimeoutError:
            outcomes["hang"] += 1
        except ServeError as e:
            outcomes[e.code] = outcomes.get(e.code, 0) + 1
        except Exception as e:        # untyped leak: the gate will fail
            tag = f"untyped:{type(e).__name__}"
            outcomes[tag] = outcomes.get(tag, 0) + 1
        else:
            if resp.degraded:
                outcomes["degraded"] += 1
                lo, hi = resp.result.spread_bounds
                degraded_bounds_ok.append(lo <= resp.result.spread <= hi)
            else:
                outcomes["served"] += 1
                results[p.signature_digest()] = resp.result
    t0 = time.perf_counter()
    async with svc:
        # tight-deadline probes on a big-θ cold key go in first (before the
        # queue builds up): sampling outlasts the budget, so these degrade
        # to certified sketch answers (or expire in-queue — both typed)
        tasks = [asyncio.ensure_future(
            one(IMProblem(k=3 + i, theta=probe_theta), dl=0.3))
            for i in range(deadline_probes)]
        tasks += [asyncio.ensure_future(one(p)) for p in workload]
        await asyncio.gather(*tasks)
        wall = time.perf_counter() - t0
        st = svc.stats()
    return outcomes, results, degraded_bounds_ok, st, policy, wall


def chaos_main(args):
    n = args.n or 300
    requests = args.requests or 120
    theta = args.theta or 1024
    g = ba_graph(n, 4)
    workload, distinct = make_workload(g, requests, theta)
    outcomes, results, dbounds, st, policy, wall = asyncio.run(run_chaos(
        g, workload, max_batch=args.max_batch, rate=args.fault_rate,
        deadline_probes=4, probe_theta=16 * theta))
    inj = policy.injector
    total = requests + 4
    typed = sum(v for k, v in outcomes.items()
                if not k.startswith("untyped:") and k != "hang")
    fires_by_site = {}
    for site, _ in inj.fired_log:
        fires_by_site[site] = fires_by_site.get(site, 0) + 1
    print(f"chaos outcomes: {outcomes}")
    print(f"chaos faults: fires={inj.fires} by_site={fires_by_site} "
          f"retries={policy.retries} oom_recoveries={policy.oom_recoveries} "
          f"gave_up={policy.gave_up}")
    print(f"chaos service: quarantines={st.quarantines} "
          f"isolated_retries={st.isolated_retries} "
          f"breaker_trips={st.breaker_trips} wall={wall:.1f}s")

    # gate 1: the run actually injected faults (a quiet run proves nothing)
    assert inj.fires > 0, "chaos: no faults fired — raise --fault-rate"
    # gate 2: zero hangs, 100% typed outcomes
    assert outcomes["hang"] == 0, f"chaos: {outcomes['hang']} hung requests"
    assert typed == total, f"chaos: {total - typed}/{total} untyped outcomes"
    # gate 3: degraded answers honour their certified bounds
    assert all(dbounds), "chaos: degraded estimate escaped spread_bounds"
    # gate 4: every non-degraded answer bit-identical to a fault-free fresh
    # solve — this is also the quarantine proof: a partially-appended pool
    # that served would fork the stream and fail here
    probe = [p for p in distinct if p.signature_digest() in results]
    probe += [p for p in (IMProblem(k=3 + i, theta=16 * theta)
                          for i in range(4))
              if p.signature_digest() in results]
    n_checked = parity_gate(g, probe, results)
    print(f"chaos parity: {n_checked} non-degraded answers bit-identical "
          "to fault-free solves")

    out = {
        "config": {"n": n, "r": 4, "theta": theta, "requests": total,
                   "max_batch": args.max_batch, "fault_rate": args.fault_rate,
                   "solver_opts": SOLVER_OPTS},
        "outcomes": outcomes,
        "faults": {"fires": inj.fires, "fires_by_site": fires_by_site,
                   "checks_by_site": dict(inj.counts),
                   "retries": policy.retries,
                   "oom_recoveries": policy.oom_recoveries,
                   "gave_up": policy.gave_up},
        "service": {"quarantines": st.quarantines,
                    "isolated_retries": st.isolated_retries,
                    "breaker_trips": st.breaker_trips,
                    "degraded": st.degraded,
                    "solver_retries": st.solver_retries},
        "parity": {"checked": n_checked, "bit_identical": True},
        "wall_s": wall,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_chaos.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.relpath(path)}")
    print(f"chaos OK: typed={typed}/{total} hangs=0 fires={inj.fires} "
          f"parity={n_checked}")


async def run_net_level(client, workload, qps: float):
    """Open-loop load against the HTTP server: latencies here include the
    network hop (socket connect + JSON both ways), statuses are counted
    raw so the zero-5xx gate sees everything."""
    lat, statuses, results = [], {}, {}

    async def one(p):
        t0 = time.perf_counter()
        try:
            status, doc = await client.solve_raw("graph", p)
        except Exception:
            statuses["transport"] = statuses.get("transport", 0) + 1
            return
        lat.append(time.perf_counter() - t0)
        statuses[status] = statuses.get(status, 0) + 1
        if status == 200:
            results[p.signature_digest()] = doc["result"]

    interval = 1.0 / qps
    t_start = time.perf_counter()
    tasks = []
    for i, p in enumerate(workload):
        lag = t_start + i * interval - time.perf_counter()
        if lag > 0:
            await asyncio.sleep(lag)
        tasks.append(asyncio.ensure_future(one(p)))
    await asyncio.gather(*tasks)
    wall = time.perf_counter() - t_start
    lat_ms = np.asarray(sorted(lat)) * 1e3
    pct = (lambda q: float(np.percentile(lat_ms, q)) if lat_ms.size else 0.0)
    n5xx = sum(v for k, v in statuses.items()
               if isinstance(k, int) and k >= 500)
    return {
        "offered_qps": qps,
        "requests": len(workload),
        "statuses": {str(k): v for k, v in statuses.items()},
        "n_5xx": n5xx,
        "achieved_qps": len(lat) / wall if wall > 0 else 0.0,
        "latency_ms": {"p50": pct(50), "p95": pct(95), "p99": pct(99),
                       "mean": float(lat_ms.mean()) if lat_ms.size else 0.0},
    }, results


def stacked_throughput(g, theta: int, reps: int = 5):
    """Stacked-vs-solo selection throughput at equal batch occupancy: the
    same 8 θ-pinned requests (no k=1, so the occur fastpath peels nothing)
    run through one padded scan vs 8 sequential solo selections on an
    equally warm pool.  Compile + sampling are excluded by warmup."""
    from repro.serve.batching import execute_batch
    probs = [IMProblem(k=k, theta=theta) for k in (2, 3, 4, 5)]
    deg = np.diff(np.asarray(g.offsets))
    top = np.argsort(-deg, kind="stable")
    probs += [IMProblem(k=k, theta=theta, candidates=top[:g.n_nodes // 2])
              for k in (2, 3, 4, 5)]

    def timed(stacked):
        solver = IMMSolver(g, **SOLVER_OPTS)
        execute_batch(solver, probs, stacked=stacked)     # warm pool+compile
        t0 = time.perf_counter()
        for _ in range(reps):
            res = execute_batch(solver, probs, stacked=stacked)
        dt = time.perf_counter() - t0
        return reps * len(probs) / dt, res

    solo_rps, res_solo = timed(False)
    stacked_rps, res_stacked = timed(True)
    for a, b in zip(res_solo, res_stacked):               # parity, again
        np.testing.assert_array_equal(a.seeds, b.seeds)
        assert a.spread == b.spread
    return {"batch": len(probs), "reps": reps,
            "solo_rps": solo_rps, "stacked_rps": stacked_rps,
            "speedup": stacked_rps / solo_rps}


def net_main(args):
    """--net: spawn the HTTP server as a subprocess, drive the mixed
    workload (plus the approximate tier) through repro.serve.client at two
    offered QPS levels, gate on zero 5xx + cache hits + θ-pinned parity
    against a fresh in-process solve, measure stacked-vs-solo selection
    throughput, then SIGTERM the server and assert a clean drain."""
    import signal
    import socket
    import subprocess
    import sys
    import tempfile

    from repro.serve.client import IMClient

    n = args.n or (300 if args.smoke else 600)
    requests = args.requests or (40 if args.smoke else 120)
    theta = args.theta or 1024
    qps_levels = args.qps or ([100.0, 400.0] if args.smoke
                              else [100.0, 500.0])

    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + (
        os.pathsep + env["PYTHONPATH"] if "PYTHONPATH" in env else "")
    cmd = [sys.executable, "-m", "repro.serve.net",
           "--host", "127.0.0.1", "--port", str(port),
           "--n", str(n), "--r", "4", "--graph-seed", "0",
           "--max-batch", str(args.max_batch),
           "--batch", str(SOLVER_OPTS["batch"]),
           "--seed", str(SOLVER_OPTS["seed"])]
    logf = tempfile.NamedTemporaryFile("w+", suffix=".log", delete=False)
    proc = subprocess.Popen(cmd, env=env, stdout=logf, stderr=logf)
    client = IMClient("127.0.0.1", port, timeout_s=120.0)

    def server_log():
        logf.flush()
        with open(logf.name) as f:
            return f.read()[-3000:]

    async def wait_ready():
        deadline = time.perf_counter() + 120.0
        while time.perf_counter() < deadline:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"server died rc={proc.returncode}\n{server_log()}")
            try:
                status, _ = await asyncio.wait_for(client.readyz(), 2.0)
                if status == 200:
                    return
            except Exception:
                pass
            await asyncio.sleep(0.25)
        raise RuntimeError(f"server never ready\n{server_log()}")

    g = ba_graph(n, 4)
    workload, distinct = make_workload(g, requests, theta)
    # the approximate tier rides the same wire (satellite): sketch-mode
    # answers plus their pool-footprint ratio in /statsz
    approx = [IMProblem(k=3, theta=theta, mode="approximate"),
              IMProblem(k=5, theta=theta, mode="approximate")]
    workload = workload + approx
    distinct = distinct + approx

    try:
        asyncio.run(wait_ready())
        levels, results = [], {}
        for qps in qps_levels:
            level, res = asyncio.run(run_net_level(client, workload, qps))
            results.update(res)
            levels.append(level)
            print(f"net qps={qps:g}: "
                  f"p50={level['latency_ms']['p50']:.1f}ms "
                  f"p99={level['latency_ms']['p99']:.1f}ms "
                  f"achieved={level['achieved_qps']:.0f}/s "
                  f"5xx={level['n_5xx']}")
        st = asyncio.run(client.stats())

        # drain: SIGTERM -> admission stops, in-flight flushes, exit 0
        proc.send_signal(signal.SIGTERM)
        drain_rc = proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()

    # gates ------------------------------------------------------------
    total_5xx = sum(l["n_5xx"] for l in levels)
    assert total_5xx == 0, f"net: {total_5xx} 5xx responses\n{server_log()}"
    assert all("transport" not in l["statuses"] for l in levels), levels
    assert st["serve"]["cache_hits"] > 0, "net: expected cache hits"
    assert drain_rc == 0, f"net: drain exit {drain_rc}\n{server_log()}"

    # θ-pinned parity: every served JSON doc vs a fresh in-process solve
    n_checked = 0
    for p in distinct:
        doc = results.get(p.signature_digest())
        if doc is None:
            continue
        fresh = IMMSolver(g, **SOLVER_OPTS).solve(p)
        assert doc["seeds"] == np.asarray(fresh.seeds).tolist(), p
        assert doc["gains"] == np.asarray(fresh.gains).tolist(), p
        assert doc["spread"] == float(fresh.spread), p
        assert doc["frac"] == float(fresh.frac), p
        n_checked += 1
    print(f"net parity: {n_checked} served answers bit-identical to fresh "
          "in-process solves (JSON float round-trip is exact)")

    thr = stacked_throughput(g, theta)
    print(f"stacked selection: {thr['stacked_rps']:.1f} req/s vs solo "
          f"{thr['solo_rps']:.1f} req/s "
          f"(x{thr['speedup']:.2f} at occupancy {thr['batch']})")
    if args.smoke:
        # soft floor in CI (shared runners jitter); the committed artifact
        # shows the real improvement
        assert thr["speedup"] >= 0.8, thr

    fp = st.get("approx_footprint", {})
    out = {
        "config": {"n": n, "r": 4, "theta": theta,
                   "requests": len(workload), "qps_levels": qps_levels,
                   "max_batch": args.max_batch, "solver_opts": SOLVER_OPTS},
        "levels": levels,
        "serve": {k: st["serve"][k] for k in
                  ("served", "batches", "batch_occupancy_mean",
                   "batch_occupancy_max", "cache_hits", "occur_fastpath",
                   "stacked_batches", "stacked_requests", "shed",
                   "expired")},
        "approx_footprint": fp,
        "stacked_selection": thr,
        "parity": {"checked": n_checked, "bit_identical": True},
        "drain": {"signal": "SIGTERM", "exit_code": drain_rc},
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serving_net.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.relpath(path)}")
    print(f"net OK: 5xx=0 cache_hits={st['serve']['cache_hits']} "
          f"parity={n_checked} drain_rc={drain_rc} "
          f"stacked_x{thr['speedup']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small graph, ~50 requests, assert "
                         "cache hits > 0 and shed == 0")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--theta", type=int, default=None)
    ap.add_argument("--qps", type=float, nargs="+", default=None,
                    help="offered load levels (default: two levels)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--chaos", action="store_true",
                    help="CI gate: rerun the workload under ~10%% injected "
                         "faults; assert typed outcomes, zero hangs, and "
                         "fault-free parity (DESIGN.md §8)")
    ap.add_argument("--fault-rate", type=float, default=0.1,
                    help="per-boundary Bernoulli fault rate for --chaos")
    ap.add_argument("--net", action="store_true",
                    help="CI gate (serve-net-smoke): drive the HTTP server "
                         "subprocess through repro.serve.client; assert "
                         "zero 5xx, cache hits, θ-pinned parity, clean "
                         "SIGTERM drain (DESIGN.md §11)")
    args = ap.parse_args()

    if args.chaos:
        chaos_main(args)
        return
    if args.net:
        net_main(args)
        return

    n = args.n or (300 if args.smoke else 2000)
    requests = args.requests or (50 if args.smoke else 200)
    theta = args.theta or (1024 if args.smoke else 4096)
    qps_levels = args.qps or ([200.0, 1000.0] if args.smoke
                              else [100.0, 500.0])

    g = ba_graph(n, 4)
    workload, distinct = make_workload(g, requests, theta)

    levels = []
    results = {}
    for qps in qps_levels:
        level, res = asyncio.run(run_level(
            g, workload, qps, max_batch=args.max_batch))
        results.update(res)
        levels.append(level)
        print(f"serving qps={qps:g}: "
              f"p50={level['latency_ms']['p50']:.1f}ms "
              f"p99={level['latency_ms']['p99']:.1f}ms "
              f"achieved={level['achieved_qps']:.0f}/s "
              f"occ={level['batch_occupancy_mean']:.2f} "
              f"hit={level['cache_hit_rate']:.2f} shed={level['shed']}")

    # bit-identity parity gate: every distinct problem that was actually
    # served vs a fresh cold solver
    probe = [p for p in distinct if p.signature_digest() in results]
    n_checked = parity_gate(g, probe, results)
    print(f"serving parity: {n_checked}/{len(distinct)} distinct requests "
          "bit-identical to fresh solvers")

    out = {
        "config": {"n": n, "r": 4, "theta": theta, "requests": requests,
                   "max_batch": args.max_batch, "solver_opts": SOLVER_OPTS,
                   "distinct_problems": len(distinct)},
        "levels": levels,
        "parity": {"checked": n_checked, "bit_identical": True},
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.relpath(path)}")

    if args.smoke:
        total_hits = sum(l["cache_hits"] for l in levels)
        total_shed = sum(l["shed"] for l in levels)
        assert total_hits > 0, "smoke: expected nonzero cache hits"
        assert total_shed == 0, f"smoke: {total_shed} requests shed"
        print(f"smoke OK: cache_hits={total_hits} shed=0 "
              f"parity={n_checked}")


if __name__ == "__main__":
    main()

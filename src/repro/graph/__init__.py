from repro.graph.csr import CSRGraph, from_edges, reverse, degrees
from repro.graph.weights import wc_weights, uniform_weights, trivalency_weights
from repro.graph import generators, sampler, partition

__all__ = [
    "CSRGraph", "from_edges", "reverse", "degrees",
    "wc_weights", "uniform_weights", "trivalency_weights",
    "generators", "sampler", "partition",
]

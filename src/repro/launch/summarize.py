"""Render experiments/dryrun/*.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRY = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "dryrun")


def load(mesh=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        r = json.load(open(p))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def roofline_table(recs) -> str:
    hdr = ("| arch | shape | mesh | opt | t_comp (s) | t_mem (s) | "
           "t_coll (s) | dominant | roofline frac | useful FLOPs | "
           "wire GB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL | | | | | | |")
            continue
        rf = r["roofline"]
        tmax = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        frac = rf["t_compute"] / tmax if tmax > 0 else 0.0
        useful = (f"{rf['useful_flops_ratio']:.2f}"
                  if rf.get("useful_flops_ratio") else "-")
        opt = "opt" if r.get("opt") else "base"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {opt} | "
            f"{rf['t_compute']:.3e} | {rf['t_memory']:.3e} | "
            f"{rf['t_collective']:.3e} | {rf['dominant']} | {frac:.3f} | "
            f"{useful} | {rf['wire_bytes_per_chip'] / 1e9:.1f} |")
    return hdr + "\n".join(rows)


def memory_table(recs) -> str:
    hdr = ("| arch | shape | mesh | args GiB/chip | temp GiB/chip | "
           "out GiB/chip | compile s |\n|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        if not r.get("ok"):
            continue
        m = r["memory"]
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{fmt_bytes(m['argument_bytes'])} | "
                    f"{fmt_bytes(m['temp_bytes'])} | "
                    f"{fmt_bytes(m['output_bytes'])} | "
                    f"{r.get('t_compile_s', '-')} |")
    return hdr + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load(args.mesh)
    print("## Roofline\n")
    print(roofline_table(recs))
    print("\n## Dry-run memory\n")
    print(memory_table(recs))


if __name__ == "__main__":
    main()

"""IMProblem variant spec: one solve(problem) API for plain / weighted /
budgeted / candidate-restricted / MRIM influence maximization.

Contracts under test (ISSUE acceptance criteria):
* plain problems through ``solve(IMProblem(...))`` match ``solve_problem``
  bit-identically on all three selection backends;
* the removed ``solve(k, eps)`` shim raises TypeError (never warns, never
  samples);
* ``imm()`` raises TypeError on unknown kwargs (the old whitelist filter
  silently swallowed typos);
* variant solves are deterministic conformant with the numpy references
  (weighted greedy, budgeted cost-ratio greedy) on the *same* RR pool;
* candidate restriction and budgets are honored, all three backends agree;
* MRIM routes through the unified backends (``_greedy_mrim`` is gone) with
  per-round quotas;
* the sketch-driven θ early exit provably never changes seeds/θ;
* variant solves run under ``jax.transfer_guard("disallow")``.
"""
import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import coverage as cov, mrim, oracle
from repro.core.engine import make_engine
from repro.core.imm import IMMSolver, imm, imm_result
from repro.core.problem import IMProblem, IMResult

SELECTIONS = ("fused", "bitset", "celf-sketch")


def _wc_graph(n=50, m=250, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _pool_lists(store):
    """Reconstruct python RR-set lists from a store snapshot (conformance
    references run on the exact pool the solver selected from)."""
    snap = store.snapshot()
    flat = np.asarray(snap.rr_flat)[np.asarray(snap.valid)]
    ids = np.asarray(snap.rr_ids)[np.asarray(snap.valid)]
    return [flat[ids == i].tolist() for i in range(snap.n_rr)]


# ------------------------------------------------------------- validation

def test_improblem_validation():
    with pytest.raises(ValueError, match="exactly one of"):
        IMProblem()                                  # neither k nor budget
    with pytest.raises(ValueError, match="exactly one of"):
        IMProblem(k=3, budget=2.0)                   # both
    with pytest.raises(ValueError, match="costs= requires budget="):
        IMProblem(k=3, costs=np.ones(5))
    with pytest.raises(ValueError, match="budgeted MRIM"):
        IMProblem(budget=2.0, t_rounds=2)
    with pytest.raises(ValueError, match="IC-only"):
        IMProblem(k=2, t_rounds=2, model="lt")
    with pytest.raises(ValueError, match="positive int"):
        IMProblem(k=0)
    p = IMProblem(k=3, node_weights=[1, 2], candidates=[0])
    with pytest.raises(ValueError, match="node_weights"):
        p.resolve(5)                                 # wrong weight length
    with pytest.raises(ValueError, match="candidate ids"):
        IMProblem(k=2, candidates=[7]).resolve(5)
    with pytest.raises(ValueError, match="affordable"):
        IMProblem(budget=1.0, costs=np.full(5, 9.0)).resolve(5)
    assert IMProblem(k=2).variant == "plain"
    assert IMProblem(budget=1.0, node_weights=np.ones(3)).variant == \
        "weighted+budgeted"


# --------------------------------------- plain parity + shim removal

@pytest.mark.parametrize("selection", SELECTIONS)
def test_plain_problem_solve_and_solve_problem_agree(selection):
    g = _wc_graph()
    res = IMMSolver(g, batch=64, seed=3, selection=selection).solve(
        IMProblem(k=4, eps=0.5, max_theta=256))
    res2 = IMMSolver(g, batch=64, seed=3,
                     selection=selection).solve_problem(
        IMProblem(k=4, eps=0.5, max_theta=256))
    assert isinstance(res, IMResult)
    np.testing.assert_array_equal(res.seeds, res2.seeds)
    assert res.spread == res2.spread
    assert res.stats.theta == res2.stats.theta
    assert res.stats.variant == "plain"


def test_removed_solve_k_eps_form_raises_typeerror():
    """The solve(k, eps) deprecation shim is gone: the positional/kwarg
    forms raise a TypeError that points at IMProblem, never warn, and
    never run a solve."""
    g = _wc_graph()
    solver = IMMSolver(g, batch=64, seed=0)
    with pytest.raises(TypeError, match="IMProblem"):
        solver.solve(2, 0.5)
    with pytest.raises(TypeError, match="removed"):
        solver.solve(2, 0.5, max_theta=64)
    with pytest.raises(TypeError, match="IMProblem"):
        solver.solve(k=2, eps=0.5)
    assert solver._stats.rounds == 0    # the shim path never sampled


def test_solve_rejects_extra_args():
    g = _wc_graph()
    with pytest.raises(TypeError, match="IMProblem"):
        IMMSolver(g, batch=64).solve(IMProblem(k=2, eps=0.5), 0.4)
    with pytest.raises(TypeError, match="IMProblem"):
        IMMSolver(g, batch=64).solve(IMProblem(k=2, eps=0.5), k=5)


def test_tagged_engine_instance_solves_matching_t_rounds():
    """A tagged (MRIM) engine *instance* defers the item-space check to the
    first solve, which must carry the matching t_rounds; a plain solve on
    it still raises."""
    from repro.core.engine import MRIMEngine
    g = _wc_graph(seed=4)
    eng = MRIMEngine(csr_mod.reverse(g),
                     MRIMEngine.Config(batch=16, t_rounds=3))
    res = IMMSolver(g, engine=eng, seed=1).solve(
        IMProblem(k=2, t_rounds=3, theta=128))
    assert len(res.seeds_per_round()) == 3
    with pytest.raises(ValueError, match="item space"):
        IMMSolver(g, engine=eng, seed=1).solve(IMProblem(k=2, eps=0.5))


def test_imm_unknown_kwargs_raise_typeerror():
    """Regression: the old whitelist filter silently dropped typos like
    ``sketchk=64`` (the user thought they had configured the sketch)."""
    g = _wc_graph()
    with pytest.raises(TypeError, match="sketchk"):
        imm(g, 3, 0.5, sketchk=64)
    with pytest.raises(TypeError, match="slection"):
        imm(g, 3, 0.5, slection="fused")
    with pytest.raises(TypeError, match="foo"):
        imm_result(g, IMProblem(k=2, eps=0.5), foo=1)
    # known keys still work end to end
    seeds, est, st = imm(g, 3, 0.5, engine="queue", batch=64, max_theta=128,
                         sketch_k=64, selection="celf-sketch")
    assert len(seeds) == 3 and est > 0


# ------------------------------------------------------------- variants

def test_candidate_restriction_honored_all_backends():
    g = _wc_graph(seed=1)
    cand = np.arange(0, 50, 3)
    outs = {}
    for sel in SELECTIONS:
        res = IMMSolver(g, batch=64, seed=2, selection=sel).solve(
            IMProblem(k=4, eps=0.5, max_theta=256, candidates=cand))
        assert set(res.seeds.tolist()) <= set(cand.tolist())
        outs[sel] = (res.seeds.tolist(), res.gains.tolist())
    assert len(set(map(str, outs.values()))) == 1, outs


def test_candidate_exhaustion_never_duplicates_seeds():
    """Regression: with fewer productive candidates than k, the variant
    greedy must stop (trimmed sentinels), never pad the result by
    re-picking an already-selected seed at zero gain."""
    g = _wc_graph(seed=1)
    cand = [7, 9]
    for sel in SELECTIONS:
        res = IMMSolver(g, batch=64, seed=2, selection=sel).solve(
            IMProblem(k=5, eps=0.5, theta=256, candidates=cand))
        s = res.seeds.tolist()
        assert len(s) == len(set(s)), (sel, s)
        assert set(s) <= set(cand) and len(s) <= len(cand)


def test_problem_model_overrides_solver_default():
    """Regression: an explicit model="ic" on the problem must override a
    solver constructed with model="lt" (None inherits)."""
    g = _wc_graph(seed=2)
    solver = IMMSolver(g, model="lt", batch=64, seed=0)
    solver.solve(IMProblem(k=2, eps=0.5, theta=128, model="ic"))
    assert solver.engine_name == "queue"
    solver.solve(IMProblem(k=2, eps=0.5, theta=128))   # None -> inherit lt
    assert solver.engine_name == "lt"
    with pytest.raises(ValueError, match="IC-only"):
        solver.solve(IMProblem(k=2, t_rounds=2, theta=128))


def test_budgeted_solve_honors_budget_and_matches_reference():
    g = _wc_graph(seed=2)
    rng = np.random.default_rng(5)
    costs = rng.integers(1, 5, 50).astype(np.float32)
    budget = 7.0
    outs = {}
    for sel in SELECTIONS:
        solver = IMMSolver(g, batch=64, seed=4, selection=sel)
        res = solver.solve(IMProblem(eps=0.5, theta=512, costs=costs,
                                     budget=budget))
        assert res.cost <= budget + 1e-6
        assert res.cost == pytest.approx(float(costs[res.seeds].sum()))
        outs[sel] = res.seeds.tolist()
        if sel == "fused":
            # deterministic conformance: numpy cost-ratio greedy on the
            # exact pool the solver selected from
            ref_seeds, ref_frac, ref_spent = oracle.budgeted_greedy_cost_ratio(
                _pool_lists(solver.store), 50, costs, budget)
            assert res.seeds.tolist() == ref_seeds
            assert res.frac == pytest.approx(ref_frac, abs=1e-6)
            assert res.cost == pytest.approx(ref_spent)
    assert len(set(map(str, outs.values()))) == 1, outs


def test_weighted_row_estimator_matches_numpy_reference():
    """Row-weighted (importance-weighted) selection — the fallback for
    engines without weighted-root sampling — equals the weighted numpy
    greedy on the same pool, for all three backends."""
    g = _wc_graph(seed=3)
    w = (np.arange(50) % 7 + 1).astype(np.float32)
    eng = make_engine("queue", csr_mod.reverse(g), batch=64)  # uniform roots
    outs = {}
    for sel in SELECTIONS:
        solver = IMMSolver(g, engine=eng, seed=6, selection=sel)
        res = solver.solve(IMProblem(k=4, eps=0.5, theta=512,
                                     node_weights=w))
        assert solver._row_weight_mode        # fallback estimator engaged
        outs[sel] = (res.seeds.tolist(),
                     np.round(res.gains, 4).tolist())
        if sel == "fused":
            rr = _pool_lists(solver.store)
            roww = w[[r[0] for r in rr]]      # queue rows are root-first
            ref_seeds, ref_frac = oracle.greedy_max_coverage_weighted(
                rr, 50, 4, roww)
            assert res.seeds.tolist() == ref_seeds
            assert res.frac == pytest.approx(ref_frac, rel=1e-5)
            assert res.spread == pytest.approx(float(w.sum()) * ref_frac,
                                               rel=1e-5)
    assert len(set(map(str, outs.values()))) == 1, outs


def test_plain_problem_on_weighted_engine_instance_raises():
    """Regression: a weighted-root engine instance under a plain problem
    would silently estimate the weighted objective on the uniform scale —
    the solver must refuse instead (and accept the matching weighted
    problem in weight-proportional mode)."""
    g = _wc_graph(seed=3)
    w = (np.arange(50) % 3 + 1).astype(np.float32)
    eng = make_engine("queue", csr_mod.reverse(g), batch=32, root_weights=w)
    solver = IMMSolver(g, engine=eng, seed=0)    # deferred prepare
    with pytest.raises(ValueError, match="no node_weights"):
        solver.solve(IMProblem(k=2, eps=0.5, theta=128))
    res = IMMSolver(g, engine=eng, seed=0).solve(
        IMProblem(k=2, eps=0.5, theta=128, node_weights=w))
    assert len(res.seeds) == 2
    assert not np.asarray(res.gains).sum() == 0


def test_weighted_solve_uses_weight_proportional_roots():
    """Named engines get the alias table: the solver samples roots ∝ w and
    selection stays the plain (row-unweighted) program."""
    g = _wc_graph(seed=4)
    w = np.zeros(50, np.float32)
    w[:10] = 1.0                               # only nodes 0..9 draw roots
    solver = IMMSolver(g, batch=64, seed=1)
    res = solver.solve(IMProblem(k=3, eps=0.5, theta=256, node_weights=w))
    assert not solver._row_weight_mode
    assert solver.engine.root_weights is not None
    rr = _pool_lists(solver.store)
    assert all(r[0] < 10 for r in rr)          # every root came from support
    assert res.spread <= float(w.sum()) + 1e-6  # scale is Σw, frac <= 1


# ----------------------------------------------------------------- MRIM

def test_mrim_routes_through_unified_backends():
    assert not hasattr(mrim, "_greedy_mrim")   # dedicated scan deleted
    g = _wc_graph(seed=8)
    outs = {}
    for sel in SELECTIONS:
        res = IMMSolver(g, seed=0, batch=32, selection=sel).solve(
            IMProblem(k=2, t_rounds=3, theta=512))
        per_round = res.seeds_per_round()
        assert len(per_round) == 3
        assert all(len(s) == 2 for s in per_round)   # per-round quota
        outs[sel] = res.seeds.tolist()
    assert len(set(map(str, outs.values()))) == 1, outs
    # the wrapper is a thin IMProblem(t_rounds=T) shim over the same path
    wrapped = mrim.solve_mrim(g, k=2, t_rounds=3, n_rr=512, batch=32, seed=0)
    assert wrapped.seeds_per_round == \
        IMMSolver(g, seed=0, batch=32).solve(
            IMProblem(k=2, t_rounds=3, theta=512)).seeds_per_round()


# ------------------------------------------------------- θ early exit

def test_early_exit_preserves_seeds_and_theta():
    g = _wc_graph(n=60, m=180, seed=1)
    base = IMMSolver(g, batch=64, seed=5).solve(IMProblem(k=3, eps=0.5))
    gated = IMMSolver(g, batch=64, seed=5).solve(
        IMProblem(k=3, eps=0.5, early_exit=True))
    np.testing.assert_array_equal(base.seeds, gated.seeds)
    assert base.stats.theta == gated.stats.theta
    assert base.spread == gated.spread
    assert gated.stats.early_exit_skips > 0    # the gate actually fired
    skips = [h for h in gated.stats.history if h[0] == "lb_skip"]
    assert len(skips) == gated.stats.early_exit_skips


def test_early_exit_noop_outside_exact_safe_regime():
    """With a sketch smaller than θ_1 the gate must stand down (occupancy
    is no longer the exact count, so the bound would be unsound)."""
    g = _wc_graph(n=60, m=180, seed=1)
    base = IMMSolver(g, batch=64, seed=5).solve(IMProblem(k=3, eps=0.5))
    gated = IMMSolver(g, batch=64, seed=5, sketch_k=32).solve(
        IMProblem(k=3, eps=0.5, early_exit=True))
    np.testing.assert_array_equal(base.seeds, gated.seeds)
    assert base.stats.theta == gated.stats.theta


# ------------------------------------------------- transfer-guard hygiene

@pytest.mark.parametrize("variant", ("weighted", "budgeted", "candidates",
                                     "mrim"))
def test_variant_solve_under_transfer_guard(variant):
    g = _wc_graph(seed=9)
    w = (np.arange(50) % 5 + 1).astype(np.float32)
    problem = {
        "weighted": IMProblem(k=3, eps=0.5, max_theta=256, node_weights=w),
        "budgeted": IMProblem(eps=0.5, max_theta=256,
                              costs=np.ones(50, np.float32), budget=3.0),
        "candidates": IMProblem(k=3, eps=0.5, max_theta=256,
                                candidates=np.arange(25)),
        "mrim": IMProblem(k=2, t_rounds=2, theta=256),
    }[variant]
    solver = IMMSolver(g, batch=64, seed=7)
    solver.prepare(problem)    # host-side construction outside the guard
    with jax.transfer_guard("disallow"):
        res = solver.solve(problem)
    assert len(res.seeds) >= 1


def test_prepare_reuses_pool_for_same_signature():
    g = _wc_graph(seed=9)
    solver = IMMSolver(g, batch=64, seed=7)
    r1 = solver.solve(IMProblem(k=2, eps=0.5, max_theta=128))
    pool = solver.store.n_rr
    r2 = solver.solve(IMProblem(k=3, eps=0.5, max_theta=128))
    assert solver.store.n_rr >= pool           # pool reused, not reset
    w = np.ones(50, np.float32)
    solver.solve(IMProblem(k=2, eps=0.5, max_theta=128, node_weights=w))
    # weights change the engine signature -> fresh pool
    assert solver.engine.root_weights is not None

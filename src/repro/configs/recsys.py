"""DeepFM arch config + steps (train / serve / bulk / retrieval)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import deepfm
from repro.optim import AdamWConfig, adamw_update


def make_deepfm(*, reduced: bool = False) -> deepfm.DeepFMConfig:
    if reduced:
        return deepfm.DeepFMConfig(n_sparse=5, embed_dim=4, mlp_dims=(16, 8),
                                   field_vocabs=tuple([64] * 5),
                                   n_dense_feats=4)
    return deepfm.DeepFMConfig()   # 39 fields, dim 10, MLP 400-400-400


def build_train_step(cfg, opt_cfg: AdamWConfig, lookup_fn=None):
    def step(state, ids, dense_x, labels):
        params, opt = state
        loss, grads = jax.value_and_grad(
            lambda p: deepfm.deepfm_loss(p, cfg, ids, dense_x, labels,
                                         lookup_fn))(params)
        params, opt = adamw_update(grads, opt, params, opt_cfg)
        return (params, opt), loss

    return step


def build_serve_step(cfg, lookup_fn=None):
    def step(params, ids, dense_x):
        return deepfm.deepfm_logits(params, cfg, ids, dense_x, lookup_fn)
    return step


def build_retrieval_step(top_k: int):
    def step(query_emb, cand_emb):
        return deepfm.retrieval_topk(query_emb, cand_emb, top_k)
    return step

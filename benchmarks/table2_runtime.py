"""Paper Table 2: end-to-end IM runtime, gIM engines vs. serial IMM oracle.

Datasets are BA stand-ins at reduced scale (see common.py note); k and eps
reduced for CPU.  Reports wall time per solver and the speedup ratio — the
paper's headline metric.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import imm
from repro.core import oracle
from repro.graph import csr as csr_mod

DATASETS = [
    ("epinions-mini", 4000, 4),
    ("slashdot-mini", 6000, 6),
    ("higgs-mini", 10000, 8),
]
K, EPS = 10, 0.4


def main():
    rows = []
    for name, n, r in DATASETS:
        g = ba_graph(n, r)
        g_rev = csr_mod.reverse(g)
        offs = np.asarray(g_rev.offsets)
        idx = np.asarray(g_rev.indices)
        w = np.asarray(g_rev.weights)
        t0 = time.perf_counter()
        seeds_o, rr, theta = oracle.imm_oracle(offs, idx, w, n, K, EPS,
                                               seed=0)
        t_imm = time.perf_counter() - t0
        t0 = time.perf_counter()
        seeds_q, est_q, st_q = imm(g, K, EPS, engine="queue", batch=512,
                                   seed=0)
        t_q = time.perf_counter() - t0
        t0 = time.perf_counter()
        seeds_d, est_d, st_d = imm(g, K, EPS, engine="dense", batch=256,
                                   seed=0)
        t_d = time.perf_counter() - t0
        rows.append([name, n, g.n_edges, theta, round(t_imm, 3),
                     round(t_q, 3), round(t_d, 3),
                     round(t_imm / t_q, 2), round(t_imm / t_d, 2)])
        report(f"table2/{name}/imm_oracle", t_imm * 1e6,
               f"theta={theta}")
        report(f"table2/{name}/gim_queue", t_q * 1e6,
               f"speedup={t_imm / t_q:.2f}x")
        report(f"table2/{name}/gim_dense", t_d * 1e6,
               f"speedup={t_imm / t_d:.2f}x")
    write_csv("table2_runtime",
              ["dataset", "n", "m", "theta", "t_imm_s", "t_queue_s",
               "t_dense_s", "speedup_queue", "speedup_dense"], rows)


if __name__ == "__main__":
    main()

"""Vectorized padded-row packing shared by the engine adapters.

``pack_rows`` is the host (numpy) variant with a data-dependent output
width; ``pack_rows_device`` is its jit-safe twin with a *static* width (the
mask's column count), used on the device-resident engine paths where shape
stability matters more than trailing padding.  Low-level (imports nothing
from core) so both the samplers and the engine layer can use it without
cycles.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def pack_rows(values: np.ndarray, mask: np.ndarray):
    """Left-compact masked elements of each row into a padded matrix.

    values, mask: (B, C).  Returns (rows (B, W), lengths (B,)) where W is the
    max per-row count; column order is preserved.  Fully vectorized: rank =
    prefix count of the mask, then one scatter.
    """
    mask = np.asarray(mask, bool)
    values = np.asarray(values)
    lens = mask.sum(axis=1).astype(np.int64)
    width = max(int(lens.max()) if lens.size else 0, 1)
    out = np.zeros((mask.shape[0], width), values.dtype)
    rank = mask.cumsum(axis=1) - 1
    r, c = np.nonzero(mask)
    out[r, rank[r, c]] = values[r, c]
    return out, lens


def rank_positions(csum, width: int, size: int):
    """Positions of the 1st..``width``-th set elements of a flat mask, given
    its inclusive prefix sum ``csum`` (length ``size``).

    Vectorized lower-bound binary search — log(size) gather steps, no
    scatter (XLA:CPU lowers scatter to a serial per-update loop).  Entries
    beyond the true count converge to ``size - 1``; callers mask by count.
    Batched callers vmap over the leading axis.  Shared by the sampler
    chunk pack (rrset) and the device store's packed append (coverage).
    """
    tgt = jnp.arange(1, width + 1, dtype=jnp.int32)
    lo = jnp.zeros((width,), jnp.int32)
    hi = jnp.full((width,), size - 1, jnp.int32)
    for _ in range(max(int(np.ceil(np.log2(max(size, 2)))), 1)):
        mid = (lo + hi) >> 1
        go_right = csum[mid] < tgt
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return jnp.clip(lo, 0, size - 1)


def pack_rows_device(values, mask):
    """jnp twin of :func:`pack_rows` (traceable, device-resident).

    Output width is static (= ``mask.shape[1]``); rows are left-compacted in
    column order, the tail is zero padding.  Returns (rows (B, C), lengths
    (B,) int32).
    """
    b, c = mask.shape
    lens = mask.sum(axis=1, dtype=jnp.int32)
    rank = jnp.cumsum(mask, axis=1) - 1
    dest = jnp.where(mask, rank, c)                  # OOB -> dropped
    rows_idx = jnp.arange(b)[:, None]
    out = jnp.zeros((b, c), values.dtype).at[rows_idx, dest].set(
        values, mode="drop")
    return out, lens

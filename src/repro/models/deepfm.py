"""DeepFM [arXiv:1703.04247]: FM + deep MLP over shared sparse embeddings.

n_sparse=39 categorical fields (Criteo layout), embed_dim=10, MLP 400-400-400.
The embedding tables are the hot path: one concatenated row-space (sum of all
field vocabs, ~34M rows by default) so a single (possibly row-sharded) table
serves all fields; ids arrive pre-offset per field.

FM second-order term uses the sum-square trick:
  0.5 * ((Σ_f v_f)^2 - Σ_f v_f^2) summed over embed dims.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, dense


def default_field_vocabs(n_sparse: int = 39) -> tuple[int, ...]:
    """Criteo-like skew: a few huge id spaces, many small ones (~34M total)."""
    sizes = []
    for i in range(n_sparse):
        if i < 3:
            sizes.append(10_000_000)
        elif i < 8:
            sizes.append(500_000)
        elif i < 16:
            sizes.append(100_000)
        else:
            sizes.append(2_000)
    return tuple(sizes)


@dataclass(frozen=True)
class DeepFMConfig:
    n_sparse: int = 39
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    field_vocabs: tuple[int, ...] = field(default_factory=default_field_vocabs)
    n_dense_feats: int = 13      # Criteo numeric features

    @property
    def total_rows(self) -> int:
        return int(sum(self.field_vocabs))

    @property
    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.field_vocabs)[:-1]])


def deepfm_init(key, cfg: DeepFMConfig, dtype=jnp.float32):
    ke, kw, km, kd = jax.random.split(key, 4)
    d_concat = cfg.n_sparse * cfg.embed_dim + cfg.n_dense_feats
    mlp = []
    d_in = d_concat
    for i, d_out in enumerate(cfg.mlp_dims):
        km, sub = jax.random.split(km)
        mlp.append(dense_init(sub, d_in, d_out, bias=True, dtype=dtype))
        d_in = d_out
    return {
        "embed": (jax.random.normal(ke, (cfg.total_rows, cfg.embed_dim))
                  * 0.01).astype(dtype),
        "lin": (jax.random.normal(kw, (cfg.total_rows,)) * 0.01).astype(dtype),
        "dense_lin": dense_init(kd, cfg.n_dense_feats, 1, bias=True,
                                dtype=dtype),
        "mlp": mlp,
        "head": dense_init(jax.random.fold_in(km, 7), cfg.mlp_dims[-1], 1,
                           bias=True, dtype=dtype),
    }


def deepfm_logits(params, cfg: DeepFMConfig, sparse_ids, dense_feats,
                  lookup_fn=None):
    """sparse_ids (B, F) pre-offset global row ids; dense_feats (B, n_dense).

    ``lookup_fn(table, ids)`` defaults to ``jnp.take`` (single-host); the
    distributed path passes a row-sharded lookup (models/embedding.py).
    """
    b = sparse_ids.shape[0]
    take = lookup_fn or (lambda t, i: jnp.take(t, i, axis=0))
    v = take(params["embed"], sparse_ids)            # (B, F, D)
    first = take(params["lin"][:, None], sparse_ids)[..., 0].sum(-1)  # (B,)
    first = first + dense(params["dense_lin"], dense_feats)[:, 0]
    s = v.sum(axis=1)                                # (B, D)
    fm = 0.5 * ((s ** 2) - (v ** 2).sum(axis=1)).sum(-1)             # (B,)
    h = jnp.concatenate([v.reshape(b, -1), dense_feats], axis=-1)
    for p in params["mlp"]:
        h = jax.nn.relu(dense(p, h))
    deep = dense(params["head"], h)[:, 0]
    return first + fm + deep


def deepfm_loss(params, cfg: DeepFMConfig, sparse_ids, dense_feats, labels,
                lookup_fn=None):
    logits = deepfm_logits(params, cfg, sparse_ids, dense_feats, lookup_fn)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))     # stable BCE-with-logits


def retrieval_scores(query_emb, cand_emb):
    """retrieval_cand shape: 1 query vs N candidates — batched dot."""
    return cand_emb @ query_emb


def retrieval_topk(query_emb, cand_emb, k: int):
    scores = retrieval_scores(query_emb, cand_emb)
    return jax.lax.top_k(scores, k)

"""Fault-tolerant solve & serve (DESIGN.md §8).

Contracts under test (ISSUE acceptance criteria):
* durable pool checkpoints: ``save_pool`` → process restart →
  ``restore_pool`` → the continued solve is bit-identical to an
  uninterrupted one, on mesh=1 AND an 8-fake-device mesh, with the
  restore + solve legal under ``jax.transfer_guard("disallow")``;
* resumable sampling: injected faults at the sample/append/grow/select
  boundaries are retried by ``FaultPolicy`` and the result stream stays
  bit-identical (transactional RNG cursor: a retried round replays the
  same subkey against unchanged buffers);
* growth-allocation failure recovery: ``on_oom`` hooks run, the packed
  append falls back to the exact-need allocation, and the solve completes
  bit-identically;
* ε-driven LB-loop crash/resume: the checkpoint's ``lb_completed``
  watermark + ``active_solve`` digest let a restarted process skip
  completed LB iterations instead of re-running them over a larger pool
  (which would fork the stream);
* serving failure isolation: one poisoned request among healthy
  batch-mates fails alone with a typed error (satellite regression), the
  executing entry is quarantined and never serves again, spill-on-evict
  rehydrates bit-identically, the per-key circuit breaker walks
  closed → open → half-open → closed, and degraded answers carry certified
  bounds and are never cached.
"""
import asyncio
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.ft.failures import (DeadlineExceeded, FaultInjector, FaultPolicy,
                               InjectedFailure, PoolAllocError, is_transient)
from repro.serve import (CircuitOpenError, ServeConfig, SolverFailedError,
                         WarmSolverRegistry, build_service, execute_batch)

OPTS = {"batch": 32, "seed": 7}
THETA = 1024


def _wc_graph(n=60, m=300, seed=0):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


@pytest.fixture(scope="module")
def g():
    return _wc_graph()


@pytest.fixture(scope="module")
def ref(g):
    """Uninterrupted fixed-θ baseline every bit-identity test compares to."""
    return IMMSolver(g, **OPTS).solve(IMProblem(k=3, theta=THETA))


def _same(a, b):
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(a.gains, b.gains)
    assert a.frac == b.frac and a.spread == b.spread


# ------------------------------------------------ fault taxonomy / policy

def test_is_transient_classification():
    assert is_transient(InjectedFailure("x"))
    assert is_transient(PoolAllocError("x"))
    assert not is_transient(ValueError("x"))
    assert not is_transient(DeadlineExceeded("x"))

    class XlaRuntimeError(RuntimeError):
        pass
    assert is_transient(XlaRuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert not is_transient(XlaRuntimeError("INTERNAL: device lost"))


def test_injector_validates_sites_and_counts():
    with pytest.raises(ValueError):
        FaultInjector(fail_at={"bogus": {1}})
    inj = FaultInjector(fail_at={"sample": {2}})
    inj.check("sample")                      # crossing 1: clean
    with pytest.raises(InjectedFailure):
        inj.check("sample")                  # crossing 2 fires exactly once
    inj.check("sample")
    assert inj.fires == 1 and inj.fired_log == [("sample", 2)]


def test_policy_backoff_capped_and_gives_up():
    sleeps = []
    pol = FaultPolicy(injector=FaultInjector(rate=1.0), max_retries=3,
                      backoff_base_s=0.01, backoff_cap_s=0.02,
                      sleep=sleeps.append)
    with pytest.raises(InjectedFailure):
        pol.run(lambda: 1, "sample")
    assert pol.gave_up == 1
    assert pol.retries == 4                  # 3 retried + the final attempt
    assert sleeps == [0.01, 0.02, 0.02]      # 0.01·2^i capped at 0.02


# ------------------------------------- resumable sampling (tentpole, §8)

def test_injected_faults_retry_bit_identical(g, ref):
    pol = FaultPolicy(injector=FaultInjector(
        fail_at={"sample": {2, 5}, "append": {4}, "select": {1}}),
        sleep=lambda s: None)
    got = IMMSolver(g, fault_policy=pol, **OPTS).solve(
        IMProblem(k=3, theta=THETA))
    _same(ref, got)
    assert pol.injector.fires == 4 and pol.retries == 4 and pol.gave_up == 0


def test_growth_fault_recovers_bit_identical(g):
    """Allocation failures during capacity doubling first fall back to the
    exact (un-padded) footprint inside the store, then escalate to the
    policy, whose on_oom hooks run before the append retries — and the
    solve still matches the fault-free stream.  θ is set well past the
    store's initial element capacity so growth genuinely happens."""
    p = IMProblem(k=3, theta=8192)
    clean = IMMSolver(g, batch=256, seed=7).solve(p)
    freed = []
    pol = FaultPolicy(injector=FaultInjector(fail_at={"grow": {1, 2}}),
                      sleep=lambda s: None)
    pol.on_oom.append(lambda: freed.append(1) or 1)
    s = IMMSolver(g, batch=256, seed=7, fault_policy=pol)
    _same(clean, s.solve(p))
    assert pol.injector.fires == 2
    assert freed and pol.oom_recoveries >= 1
    assert pol.injector.counts["grow"] >= 3      # the retried alloc passed


def test_midstream_checkpoint_restore_bit_identical(g, ref, tmp_path):
    """Same-process restart drill: sample partway, save_pool, rebuild a
    fresh solver, restore_pool, finish — bit-identical result and the
    RNG cursor positions match the uninterrupted solver's."""
    d = str(tmp_path / "ck")
    s1 = IMMSolver(g, **OPTS)
    s1.prepare(IMProblem(k=3, theta=THETA))
    s1.sample_until(THETA // 2)
    step = int(s1.stats.rounds)
    s1.save_pool(d)
    s2 = IMMSolver(g, **OPTS)
    assert s2.restore_pool(d) == step
    assert np.array_equal(np.asarray(jax.random.key_data(s1.key)),
                          np.asarray(jax.random.key_data(s2.key)))
    got = s2.solve(IMProblem(k=3, theta=THETA))
    _same(ref, got)


def test_restore_pool_rejects_foreign_and_missing_checkpoints(g, tmp_path):
    from repro.ckpt import checkpoint as ckpt_mod
    s = IMMSolver(g, **OPTS)
    with pytest.raises(FileNotFoundError):
        s.restore_pool(str(tmp_path / "nope"))
    # a train-loop checkpoint is not an im-pool checkpoint
    d = str(tmp_path / "train")
    ckpt_mod.save(d, 1, {"w": np.zeros(3)}, meta={"format": "train"})
    with pytest.raises(ValueError, match="im-pool"):
        s.restore_pool(d)


def test_eps_lb_loop_crash_resume_bit_identical(g, tmp_path):
    """ε-driven solve killed mid-LB-loop resumes from the checkpoint's
    lb_completed watermark + active_solve digest and lands bit-identical
    to the uninterrupted run (theta, rounds, seeds, spread)."""
    d = str(tmp_path / "ck")
    p = IMProblem(k=3, eps=0.4, max_theta=2048)
    clean = IMMSolver(g, **OPTS).solve(p)

    inj = FaultInjector(fail_at={"sample": {9}})
    pol = FaultPolicy(injector=inj, max_retries=0, sleep=lambda s: None)
    s1 = IMMSolver(g, fault_policy=pol, checkpoint_dir=d,
                   checkpoint_every=1, **OPTS)
    with pytest.raises(InjectedFailure):
        s1.solve_problem(p)

    s2 = IMMSolver(g, checkpoint_dir=d, checkpoint_every=1, **OPTS)
    s2.restore_pool(d)
    assert s2._active_solve == p.signature_digest()   # in-flight marker
    got = s2.solve_problem(p)
    assert s2._active_solve is None                   # cleared on success
    _same(clean, got)
    assert clean.stats.theta == got.stats.theta
    assert clean.stats.rounds == got.stats.rounds


def test_resilient_solve_eps_driven(g, tmp_path):
    from repro.ft.runner import resilient_solve
    p = IMProblem(k=3, eps=0.4, max_theta=2048)
    clean = IMMSolver(g, **OPTS).solve(p)
    d = str(tmp_path / "ck")
    inj = FaultInjector(fail_at={"sample": {6}, "select": {2}})

    def make_solver():
        pol = FaultPolicy(injector=inj, max_retries=0, sleep=lambda s: None)
        return IMMSolver(g, fault_policy=pol, checkpoint_dir=d,
                         checkpoint_every=2, **OPTS)

    got, report = resilient_solve(make_solver, p, d)
    assert report.completed and report.restarts == 2
    _same(clean, got)


# ------------------------------------ subprocess restart (satellite c)

RESTART_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import numpy as np, jax
from jax.sharding import Mesh
from repro.graph import csr as csr_mod, generators, weights
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem
from repro.ft.elastic import pool_restore_mesh

assert len(jax.devices()) == {ndev}
src, dst = generators.erdos_renyi(60, 300, seed=0)
g = weights.wc_weights(csr_mod.from_edges(src, dst, 60))
mesh = None if {ndev} == 1 else pool_restore_mesh({ndev})
opts = dict(engine="queue", batch=64, seed=3, mesh=mesh)
p = IMProblem(k=4, theta=2048)
if {save}:
    ref = IMMSolver(g, **opts).solve(p)
    print("RESULT", ref.seeds.tolist(), ref.gains.tolist(), repr(ref.frac),
          repr(ref.spread))
    s = IMMSolver(g, **opts)
    s.prepare(p)
    with jax.transfer_guard("disallow"):
        s.sample_until(700)
    s.save_pool(r"{d}")
    print("SAVED", s.stats.rounds, s.store.n_rr)
else:
    s = IMMSolver(g, **opts)
    # restore_pool = prepare(): host-side engine construction, run outside
    # the guard like any cold prepare; the continued sample/select rounds
    # must then be transfer-guard legal
    step = s.restore_pool(r"{d}")
    with jax.transfer_guard("disallow"):
        got = s.solve_problem(p)
    print("RESUMED", step)
    print("RESULT", got.seeds.tolist(), got.gains.tolist(), repr(got.frac),
          repr(got.spread))
"""


def _run_restart(ndev, save, d):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c",
         RESTART_SCRIPT.format(ndev=ndev, save=save, d=d)],
        env=env, capture_output=True, text=True, cwd="/root/repo",
        timeout=600)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


@pytest.mark.parametrize("ndev", [1, 8])
def test_save_restart_restore_bit_identical_across_processes(ndev, tmp_path):
    """The durable-checkpoint contract across a REAL process boundary:
    process A solves (reference) and saves a mid-sampling checkpoint;
    process B — a fresh interpreter — restores it and finishes the solve
    under ``transfer_guard("disallow")``, bit-identical to A's reference.
    ndev=8 runs the whole drill on a forced 8-device mesh (sharded store
    rows restored onto the device that owned them)."""
    d = str(tmp_path / "ck")
    out_a = _run_restart(ndev, 1, d)
    out_b = _run_restart(ndev, 0, d)
    res_a = [l for l in out_a.splitlines() if l.startswith("RESULT")]
    res_b = [l for l in out_b.splitlines() if l.startswith("RESULT")]
    assert "RESUMED" in out_b
    assert res_a == res_b, (res_a, res_b)


# -------------------------------------------- degraded answers (§8)

def test_degraded_result_bounds_certify_returned_seed_set(g):
    """The degraded answer's ``spread_bounds`` certify the *returned* seed
    set: its exact union coverage over the pool lies inside [lo, hi], the
    estimate is clamped into the bounds, and the exact greedy answer (a
    no-worse seed set) is at least the certified lower bound."""
    solver = IMMSolver(g, sketch_k=64, **OPTS)
    exact, deg = execute_batch(
        solver, [IMProblem(k=3, theta=THETA), IMProblem(k=3, theta=THETA)],
        deadlines=[None, 0.0])
    assert not exact.degraded and deg.degraded
    lo, hi = deg.spread_bounds
    assert lo <= deg.spread <= hi
    assert exact.spread >= lo
    assert len(deg.seeds) == 3
    # recompute the degraded set's exact coverage host-side (mesh=1: the
    # store's row ids are global) and check the certificate
    st = solver.store.state()
    flat, ids = st["flat"].reshape(-1), st["ids"].reshape(-1)
    valid = st["valid"].reshape(-1)
    covered = np.unique(ids[valid & np.isin(flat, deg.seeds)]).size
    cov_spread = g.n_nodes * covered / solver.store.n_rr
    assert lo <= cov_spread <= hi + 1e-9, (lo, cov_spread, hi)


def test_degraded_without_sketch_falls_back_to_occur(g):
    solver = IMMSolver(g, **OPTS)            # no sketch configured
    _, deg = execute_batch(
        solver, [IMProblem(k=2, theta=THETA), IMProblem(k=2, theta=THETA)],
        deadlines=[None, 0.0])
    assert deg.degraded and deg.spread_bounds[0] > 0


def test_degraded_ineligible_objective_raises_typed(g):
    """Budgeted objectives have no certified sketch answer: an expired
    deadline surfaces as DeadlineExceeded, not a silent wrong result."""
    solver = IMMSolver(g, **OPTS)
    solver.solve(IMProblem(k=2, theta=THETA))        # pool is warm
    costs = np.ones(g.n_nodes, np.float32)
    with pytest.raises(DeadlineExceeded):
        solver.solve_problem(IMProblem(theta=THETA, costs=costs, budget=3.0),
                             deadline_s=0.0)


# ---------------------------------- serving isolation (satellite a)

def _poison_k9():
    """Policy whose injector kills any solve of a k=9 problem at its
    selection — the 'poisoned request' of the isolation tests."""
    return FaultPolicy(injector=FaultInjector(
        rate=1.0,
        match=lambda site, ctx: (site == "select" and isinstance(ctx, dict)
                                 and getattr(ctx.get("problem"), "k", None)
                                 == 9)),
        max_retries=0, sleep=lambda s: None)


def test_poisoned_request_fails_alone_batchmates_served(g):
    """Blast-radius regression: one poisoned problem in a batch of three
    compatible requests fails with a typed error by itself; the healthy
    batch-mates are re-run in isolation and served bit-identically."""
    opts = {**OPTS, "fault_policy": _poison_k9()}

    async def run():
        svc = build_service({"g": g}, ServeConfig(
            max_batch=8, batch_window_s=0.02, solver_opts=opts,
            breaker_threshold=100))
        async with svc:
            return await asyncio.gather(
                svc.submit("g", IMProblem(k=2, theta=THETA)),
                svc.submit("g", IMProblem(k=9, theta=THETA)),
                svc.submit("g", IMProblem(k=3, theta=THETA)),
                return_exceptions=True), svc.stats()
    results, st = asyncio.run(run())
    ok = [r for r in results if not isinstance(r, BaseException)]
    bad = [r for r in results if isinstance(r, BaseException)]
    assert len(ok) == 2 and len(bad) == 1
    assert isinstance(bad[0], SolverFailedError)
    assert "InjectedFailure" in str(bad[0])
    assert st.served == 2 and st.failed == 1
    assert st.quarantines >= 1 and st.isolated_retries >= 1
    for r, k in zip(ok, (2, 3)):
        fresh = IMMSolver(g, **OPTS).solve(IMProblem(k=k, theta=THETA))
        _same(fresh, r.result)


def test_breaker_opens_then_halfopen_probe_heals(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(
            solver_opts={**OPTS, "fault_policy": _poison_k9()},
            breaker_threshold=2, breaker_cooldown_s=0.2))
        outcomes = []
        async with svc:
            for _ in range(2):
                try:
                    await svc.submit("g", IMProblem(k=9, theta=THETA))
                    outcomes.append("served")
                except Exception as e:
                    outcomes.append(type(e).__name__)
            # same registry key: the open breaker rejects healthy work too
            try:
                await svc.submit("g", IMProblem(k=2, theta=THETA))
                outcomes.append("served")
            except Exception as e:
                outcomes.append(type(e).__name__)
            mid = svc.stats()
            await asyncio.sleep(0.25)        # cooldown -> half-open probe
            await svc.submit("g", IMProblem(k=2, theta=THETA))
            outcomes.append("served")
            return outcomes, mid, svc.stats()
    outcomes, mid, end = asyncio.run(run())
    assert outcomes[0] == "SolverFailedError"
    assert "CircuitOpenError" in outcomes[1:3]
    assert outcomes[-1] == "served"
    assert mid.breakers_open >= 1 and mid.breaker_trips >= 1
    assert end.breakers_open == 0            # probe success closed it


def test_spill_on_evict_rehydrate_on_miss_bit_identical(g, tmp_path):
    reg = WarmSolverRegistry(solver_opts=OPTS, spill_dir=str(tmp_path))
    reg.add_graph("g", g)
    p = IMProblem(k=2, theta=THETA)
    e1 = reg.get("g", p)
    e1.solver.solve(p)
    reg.account(e1)
    reg.evict(reg.solver_key("g", p))
    assert reg.snapshot().spills == 1
    # uninterrupted reference: warm solver continuing 1024 -> 2048
    s_ref = IMMSolver(g, **OPTS)
    s_ref.solve(p)
    ref2 = s_ref.solve(IMProblem(k=2, theta=2 * THETA))
    # miss -> rehydrate instead of resample; continuation bit-identical
    e2 = reg.get("g", p)
    assert reg.snapshot().rehydrations == 1 and e2.bytes > 0
    _same(ref2, e2.solver.solve(IMProblem(k=2, theta=2 * THETA)))


def test_quarantine_drops_without_spilling(g, tmp_path):
    reg = WarmSolverRegistry(solver_opts=OPTS, spill_dir=str(tmp_path))
    reg.add_graph("g", g)
    p = IMProblem(k=2, theta=THETA)
    entry = reg.get("g", p)
    entry.solver.solve(p)
    reg.account(entry)
    key = reg.solver_key("g", p)
    freed = reg.quarantine(key)
    assert freed > 0 and key not in reg.entries
    st = reg.snapshot()
    assert st.quarantined == 1 and st.spills == 0    # never spilled
    assert reg.quarantine(key) == 0                  # unknown key: no-op
    # the next miss cold-starts (no snapshot exists) and still serves the
    # canonical answer
    fresh = reg.get("g", p)
    assert fresh.solver is not entry.solver
    _same(IMMSolver(g, **OPTS).solve(p), fresh.solver.solve(p))


def test_degraded_response_never_cached(g):
    async def run():
        svc = build_service({"g": g}, ServeConfig(
            solver_opts={**OPTS, "sketch_k": 64}))
        async with svc:
            r1 = await svc.submit("g", IMProblem(k=3, theta=1 << 16),
                                  deadline_s=0.05)
            # same problem, no deadline: must recompute exactly, not
            # replay the degraded answer from the cache
            r2 = await svc.submit("g", IMProblem(k=3, theta=1 << 16))
        return r1, r2, svc.stats()
    r1, r2, st = asyncio.run(run())
    assert r1.degraded and not r2.degraded and not r2.cached
    assert st.degraded == 1
    lo, hi = r1.result.spread_bounds
    assert lo <= r1.result.spread <= hi
    # the exact greedy set can only cover more than the degraded set's
    # certified floor (its UB certifies the degraded set, not the optimum)
    assert r2.result.spread >= lo

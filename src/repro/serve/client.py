"""Asyncio HTTP client for the ``repro.serve.net`` wire protocol.

``IMClient.solve`` posts a problem and either returns the decoded 200
payload or raises the *same* :class:`~repro.serve.front.ServeError`
subclass the server raised — the typed error body carries the subclass
``code``, and the client rebuilds the exception from it, so in-process and
over-the-wire callers handle failures identically.  One connection per
request (``Connection: close``): serving batches are milliseconds of
device time, so connection reuse is not the bottleneck and the client
stays trivially cancellation-safe (Ctrl-C in the demo just drops
sockets).
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional, Tuple

from repro.core.problem import IMProblem, problem_state
from repro.serve.front import ServeError


def _error_classes():
    """code -> ServeError subclass, walking the whole subclass tree."""
    out = {}
    stack = list(ServeError.__subclasses__())
    while stack:
        cls = stack.pop()
        out[cls.code] = cls
        stack.extend(cls.__subclasses__())
    return out


class ServeHTTPError(Exception):
    """Non-2xx response whose error code maps to no ServeError subclass
    (transport-level rejections: drained server, bad route, ...)."""

    def __init__(self, status: int, code: str, message: str):
        super().__init__(f"HTTP {status} [{code}]: {message}")
        self.status = status
        self.code = code


class IMClient:
    """Minimal client over asyncio streams (stdlib only, like the server).

    ``solve`` raises typed errors; ``solve_raw`` returns ``(status, doc)``
    untouched for load drivers that count status codes.
    """

    def __init__(self, host: str, port: int, *,
                 timeout_s: Optional[float] = 120.0):
        self.host = host
        self.port = port
        self.timeout_s = timeout_s
        self._codes = _error_classes()

    async def request(self, method: str, path: str, body: Optional[dict]
                      = None, headers: Optional[dict] = None
                      ) -> Tuple[int, dict]:
        payload = b"" if body is None else json.dumps(body).encode()
        head = [f"{method} {path} HTTP/1.1",
                f"host: {self.host}:{self.port}",
                "connection: close",
                "content-type: application/json",
                f"content-length: {len(payload)}"]
        for k, v in (headers or {}).items():
            head.append(f"{k}: {v}")
        raw = ("\r\n".join(head) + "\r\n\r\n").encode("latin1") + payload

        async def _do():
            reader, writer = await asyncio.open_connection(self.host,
                                                           self.port)
            try:
                writer.write(raw)
                await writer.drain()
                status_line = await reader.readline()
                status = int(status_line.split()[1])
                length = None
                while True:
                    h = await reader.readline()
                    if h in (b"\r\n", b"\n", b""):
                        break
                    name, _, value = h.decode("latin1").partition(":")
                    if name.strip().lower() == "content-length":
                        length = int(value)
                data = (await reader.readexactly(length)
                        if length is not None else await reader.read())
                return status, json.loads(data.decode() or "{}")
            finally:
                writer.close()
                try:
                    await writer.wait_closed()
                except Exception:
                    pass

        if self.timeout_s is None:
            return await _do()
        return await asyncio.wait_for(_do(), self.timeout_s)

    def _typed(self, status: int, doc: dict) -> Exception:
        err = (doc.get("error") or {})
        code = err.get("code", "error")
        msg = err.get("message", "")
        cls = self._codes.get(code)
        if cls is not None:
            return cls(msg)
        return ServeHTTPError(status, code, msg)

    async def solve_raw(self, graph: str, problem: IMProblem, *,
                        deadline_s: Optional[float] = None
                        ) -> Tuple[int, dict]:
        body = {"graph": graph, "problem": problem_state(problem)}
        headers = ({"x-deadline-s": repr(float(deadline_s))}
                   if deadline_s is not None else None)
        return await self.request("POST", "/v1/solve", body, headers)

    async def solve(self, graph: str, problem: IMProblem, *,
                    deadline_s: Optional[float] = None) -> dict:
        status, doc = await self.solve_raw(graph, problem,
                                           deadline_s=deadline_s)
        if status != 200:
            raise self._typed(status, doc)
        return doc

    async def healthz(self) -> Tuple[int, dict]:
        return await self.request("GET", "/healthz")

    async def readyz(self) -> Tuple[int, dict]:
        return await self.request("GET", "/readyz")

    async def stats(self) -> dict:
        status, doc = await self.request("GET", "/statsz")
        if status != 200:
            raise self._typed(status, doc)
        return doc

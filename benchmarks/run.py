"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
Prints ``name,us_per_call,derived`` CSV lines; writes per-table CSVs to
experiments/bench/ and, when dry-run artifacts exist, the roofline summary.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (table2_runtime, fig3_breakdown, fig45_k_sweep,
                        fig6_eps_sweep, fig7_density, fig8_tuning,
                        table3_mrim, perf_im_engines)

ALL = [
    ("table2_runtime", table2_runtime.main),
    ("fig3_breakdown", fig3_breakdown.main),
    ("fig45_k_sweep", fig45_k_sweep.main),
    ("fig6_eps_sweep", fig6_eps_sweep.main),
    ("fig7_density", fig7_density.main),
    ("fig8_tuning", fig8_tuning.main),
    ("table3_mrim", table3_mrim.main),
    ("perf_im_engines", perf_im_engines.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, fn in ALL:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()

"""AdamW with optional block-quantized int8 moments (fits 671B on one pod).

The int8 state path quantizes m and v per 256-element block with a float32
scale (absmax quantization), cutting optimizer memory from 8 bytes/param to
~2.03 bytes/param — the enabler for deepseek-v3 training on a single v5e pod
(see EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    int8_states: bool = False
    block: int = 256
    grad_clip: Optional[float] = 1.0


# ------------------------------------------------------- int8 quantization
#
# Shape-preserving absmax quantization along the LAST axis: q keeps the
# param's shape (int8), scales keep all leading axes (last axis / block).
# This makes the optimizer-state sharding identical to the param sharding
# (scales: same spec with the last axis replicated) — see launch/sharding.py.

def _block_for(last: int, block: int) -> int:
    return block if (last % block == 0) else last


def _quantize(x, block):
    x = x if x.ndim else x.reshape(1)
    last = x.shape[-1]
    b = _block_for(last, block)
    xr = x.reshape(*x.shape[:-1], last // b, b)
    scale = jnp.max(jnp.abs(xr), axis=-1) / 127.0
    q = jnp.round(xr / jnp.maximum(scale, 1e-20)[..., None])
    return (q.astype(jnp.int8).reshape(x.shape),
            scale.astype(jnp.float32))


def _dequantize(q, scale, shape=None):
    nb = scale.shape[-1]
    b = q.shape[-1] // nb
    qr = q.reshape(*q.shape[:-1], nb, b).astype(jnp.float32)
    out = (qr * scale[..., None]).reshape(q.shape)
    return out.reshape(shape) if shape is not None else out


# ----------------------------------------------------------------- update

class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: any
    v: any


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    if cfg.int8_states:
        zeros = jax.tree.map(
            lambda p: _quantize(jnp.zeros_like(p, jnp.float32), cfg.block), params)
    else:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    step = state.step + 1
    if cfg.grad_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if cfg.int8_states:
            m_f = _dequantize(m[0], m[1], p.shape)
            v_f = _dequantize(v[0], v[1], p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / bc1
        vhat = v_f / bc2
        new_p = (p.astype(jnp.float32)
                 - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                             + cfg.weight_decay * p.astype(jnp.float32)))
        if cfg.int8_states:
            return (new_p.astype(p.dtype), _quantize(m_f, cfg.block),
                    _quantize(v_f, cfg.block))
        return new_p.astype(p.dtype), m_f, v_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)

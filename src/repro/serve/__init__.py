"""IM-as-a-service: warm-solver registry, micro-batched asyncio request
front, and result cache over the :class:`~repro.core.problem.IMProblem`
API.  DESIGN.md §7 documents the architecture and contracts; §8 the fault
model (failure isolation, quarantine, circuit breakers, degraded serves,
pool spill/rehydrate); §11 the network surface (``repro.serve.net``),
the consistent-hash cluster (``repro.serve.cluster``) and batched
stacked selection."""
from repro.serve.batching import (execute_batch, occur_fastpath_eligible,
                                  stacked_eligible)
from repro.serve.cache import CacheStats, ResultCache
from repro.serve.client import IMClient, ServeHTTPError
from repro.serve.cluster import HashRing, IMCluster
from repro.serve.front import (
    CircuitOpenError,
    DeadlineExpiredError,
    IMService,
    InvalidProblemError,
    QueueFullError,
    ServeConfig,
    ServeError,
    ServeResponse,
    ServeStats,
    SolverFailedError,
    UnknownGraphError,
    build_service,
)
from repro.serve.net import ERROR_STATUS, IMNetServer, status_for
from repro.serve.registry import RegistryStats, WarmEntry, WarmSolverRegistry

__all__ = [
    "CacheStats",
    "CircuitOpenError",
    "DeadlineExpiredError",
    "ERROR_STATUS",
    "HashRing",
    "IMClient",
    "IMCluster",
    "IMNetServer",
    "IMService",
    "InvalidProblemError",
    "QueueFullError",
    "RegistryStats",
    "ResultCache",
    "ServeConfig",
    "ServeError",
    "ServeHTTPError",
    "ServeResponse",
    "ServeStats",
    "SolverFailedError",
    "UnknownGraphError",
    "WarmEntry",
    "WarmSolverRegistry",
    "build_service",
    "execute_batch",
    "occur_fastpath_eligible",
    "stacked_eligible",
    "status_for",
]

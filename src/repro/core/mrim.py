"""Multi-round influence maximization (paper §4.8; CR-NAIMM of Sun et al.'18).

Influence propagates over T independent rounds; we pick k seeds *per round*
to maximize the number of nodes influenced at least once.  Per the paper:
"after selecting a random node, we initiate a random BFS originating from
the selected node as many times as the number of rounds.  Each element in a
random RR set is a tuple of node-id and round number."

Implementation: the T per-round BFS of one RR sample run as T adjacent lanes
of the queue engine sharing one root; elements are encoded as
``round * n + node`` so the whole coverage machinery (occur histogram,
membership scan, decrement) is reused verbatim on an item space of size n·T.
The cross-round greedy of CR-NAIMM — mask rounds whose per-round budget k is
exhausted — is a *group budget* on the unified selection backends
(``SelectionSpec(n_group=n, n_groups=T, group_quota=k)``), so MRIM is just
``IMMSolver.solve(IMProblem(k=k, t_rounds=T, ...))``: the dedicated
``_greedy_mrim`` scan of earlier revisions is gone, and all three selection
backends (fused scan, Pallas bitset, CELF-sketch) solve MRIM on any mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.graph.csr import CSRGraph
from repro.core import rrset as rr_queue
from repro.core.engine import MRIMEngine
from repro.core.imm import IMMSolver
from repro.core.problem import IMProblem


def sample_mrim_round(key, g_rev: CSRGraph, batch: int, t_rounds: int,
                      qcap: int, ec: int = rr_queue.EC_DEFAULT):
    """Sample ``batch`` MRIM RR sets (each = T tagged BFS from a shared root).

    Thin compatibility wrapper over :class:`~repro.core.engine.MRIMEngine`.
    Returns (nodes (B, W) encoded ids, lengths (B,), overflowed (B,)).
    """
    eng = MRIMEngine(g_rev, MRIMEngine.Config(batch=batch, t_rounds=t_rounds,
                                              qcap=qcap, ec=ec))
    b = eng.sample(key)
    return np.asarray(b.nodes), np.asarray(b.lengths), np.asarray(b.overflowed)


class MRIMResult(NamedTuple):
    seeds_per_round: list    # T lists of k node ids
    spread_estimate: float
    n_rr: int


def solve_mrim(g: CSRGraph, k: int, t_rounds: int, n_rr: int, *,
               qcap: int | None = None, batch: int = 64, seed: int = 0,
               selection: str = "auto") -> MRIMResult:
    """Fixed-θ MRIM solve — a thin wrapper over the unified problem API:
    ``IMMSolver(g, engine=...).solve(IMProblem(k=k, t_rounds=T, theta=n_rr))``
    (the paper's Table-3 experiment uses fixed ε; the IMM θ machinery
    composes identically — drop ``theta=`` from the problem to run the full
    Alg. 2 schedule)."""
    solver = IMMSolver(g, batch=batch, qcap=qcap, seed=seed,
                       selection=selection)
    res = solver.solve(IMProblem(k=k, t_rounds=t_rounds, theta=n_rr))
    frac = res.frac
    return MRIMResult(seeds_per_round=res.seeds_per_round(),
                      spread_estimate=g.n_nodes * frac,
                      n_rr=res.stats.n_rr_sampled)

"""Pallas TPU kernel: fused counter-based Bernoulli edge trials (Alg. 3 L18-19).

gIM draws one curand uniform per (thread, edge) and compares against p_uv.  On
TPU we fuse generation+comparison so no uniform array ever round-trips through
HBM: each lane hashes (seed, global_edge_index) with a murmur3-style finalizer
(a counter-based RNG, like the threefry the host engine uses) and compares the
32-bit result against the edge probability.

Each edge index is hashed exactly once per RR sample, so trials are
independent across edges and across (seed-distinguished) samples — the same
argument the paper makes for per-thread curand streams.

The identical hash is implemented in ref.py (pure jnp) and the kernel is
validated bit-exactly against it across shapes/dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def hash_mix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 — full avalanche on uint32."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def counter_uniform_u32(seed: jnp.ndarray, counter: jnp.ndarray) -> jnp.ndarray:
    """uint32 uniform stream at (seed, counter); double-mixed."""
    x = counter.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + \
        seed.astype(jnp.uint32)
    return hash_mix(hash_mix(x) ^ jnp.uint32(0x9E3779B9))


def _bernoulli_kernel(seed_ref, w_ref, keep_ref, *, block: int):
    i = pl.program_id(0)
    seed = seed_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.uint32, (block,), 0) + \
        jnp.uint32(i * block)
    bits = counter_uniform_u32(seed, idx)
    # compare in [0,1): float32 keeps 24 bits — bias < 2^-24 per trial
    u01 = bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)
    keep_ref[...] = u01 < w_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bernoulli_edges(weights: jnp.ndarray, seed: jnp.ndarray, *,
                    block: int = 1024, interpret: bool = True):
    """keep (E,) bool — one fused Bernoulli(p=weights[e]) trial per edge."""
    e = weights.shape[0]
    blk = min(block, e)
    grid = (pl.cdiv(e, blk),)
    return pl.pallas_call(
        functools.partial(_bernoulli_kernel, block=blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.bool_),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.uint32).reshape(1), weights.astype(jnp.float32))

"""§Perf/IM: engine comparison in *parallel time* (lockstep micro-steps).

On this single scalar core the vectorized engines run their B×EC lanes
sequentially, so CPU wall-clock says nothing about TPU/GPU throughput
(table2 reports it anyway, honestly).  The hardware-transferable metric is
the number of lockstep micro-steps: one micro-step = one EC-wide chunk on
every lane = one parallel time unit on width-B vector hardware.

  modelled parallel speedup = serial edge-operations / engine micro-steps

which is exactly the quantity the paper's GPU measures (they report 33-220x
on a 2560-warp V100; we report the same ratio for the 512-lane config).
Also measures the round->refill utilization win (paper Alg. 6 structure).

Both engines are driven through the SamplerEngine protocol: the benchmark
sees only ``engine.sample(key) -> RRBatch`` and the canonical ``steps``
counter, so any registered engine can be dropped into the comparison.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import ba_graph, write_csv, report
from repro.graph import csr as csr_mod
from repro.core.engine import make_engine

N, R, QUOTA, B = 20000, 8, 2048, 512


def main():
    g = ba_graph(N, R)
    g_rev = csr_mod.reverse(g)
    deg = np.diff(np.asarray(g_rev.offsets))
    rows = []
    # serial work model: ops = nodes visited + edges examined (the oracle
    # walks each adjacency once per visited node)
    # --- round engine
    round_eng = make_engine("queue", g_rev, batch=B, qcap=N)
    steps_round = 0
    serial_ops = 0
    done = 0
    i = 0
    while done < QUOTA:
        b = round_eng.sample(jax.random.key(i))
        steps_round += int(b.steps)
        nodes = np.asarray(b.nodes); lens = np.asarray(b.lengths)
        for r in range(b.n_sets):
            vis = nodes[r, :lens[r]]
            serial_ops += lens[r] + deg[vis].sum()
        done += b.n_sets
        i += 1
    # --- refill engine (same quota, B persistent lanes)
    refill_eng = make_engine("refill", g_rev, batch=QUOTA, lanes=B,
                             out_cap=8 * QUOTA // B * 64)
    bf = refill_eng.sample(jax.random.key(99))
    steps_refill = int(bf.steps)
    n_sets = bf.n_sets
    speedup_round = serial_ops / max(steps_round, 1)
    speedup_refill = serial_ops / max(steps_refill, 1) * done / max(n_sets, 1)
    rows.append(["round", done, steps_round, int(serial_ops),
                 round(speedup_round, 1)])
    rows.append(["refill", n_sets, steps_refill, int(serial_ops),
                 round(speedup_refill, 1)])
    write_csv("perf_im_engines",
              ["engine", "rr_sets", "micro_steps", "serial_ops",
               "modelled_parallel_speedup"], rows)
    report("perf_im/round", steps_round, f"par_speedup={speedup_round:.0f}x")
    report("perf_im/refill", steps_refill,
           f"par_speedup={speedup_refill:.0f}x;"
           f"step_win={steps_round / max(steps_refill, 1):.2f}x")


if __name__ == "__main__":
    main()

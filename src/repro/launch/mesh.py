"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)

"""gin-tu [arXiv:1810.00826]: 5L d_hidden=64 sum aggregator, learnable eps."""
from repro.configs.gnn_archs import make_arch
ARCH_ID = "gin-tu"
def full_config(shape):
    return make_arch(ARCH_ID, shape)
def reduced_config(shape):
    return make_arch(ARCH_ID, shape, reduced=True)

"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = wire_bytes_per_chip / link_bw

``cost_analysis()`` of the SPMD-partitioned executable reports *per-chip*
flops/bytes (the partitioned module is the per-device program), so the terms
above divide by single-chip peaks.  collective bytes are parsed from the
optimized HLO text: per collective op we estimate per-chip wire bytes with
ring-algorithm factors (all-reduce 2x payload, all-gather/reduce-scatter/
all-to-all ~1x, collective-permute 1x).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<types>.+?)\s+"
    r"(?P<op>all-reduce(?:-start)?|all-gather(?:-start)?|"
    r"reduce-scatter|all-to-all|collective-permute(?:-start)?|"
    r"collective-broadcast)\(")

_TYPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_WIRE_FACTOR = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
}


def _type_bytes(types_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(types_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    n_ops: int = 0
    wire_bytes: float = 0.0
    by_op: dict = None


_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*"
                      r"\([^)]*\)? -> .*\{\s*$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-~!]+)\s+\(.*\{\s*$")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-~!]+)")
_WHILE_RE = re.compile(r"\bwhile\(.*?\bcondition=%?([\w\.\-~!]+).*?"
                       r"\bbody=%?([\w\.\-~!]+)|\bwhile\(.*?"
                       r"\bbody=%?([\w\.\-~!]+).*?\bcondition=%?([\w\.\-~!]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Scan trip count: the largest integer constant compared in the cond."""
    best = 1
    for line in cond_lines:
        if "constant(" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


def _multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Execution-count multiplier per computation (while bodies x trip)."""
    # call edges: (caller -> callee, weight)
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            mw = re.search(r"\bwhile\(", line)
            callees = _CALLEE_RE.findall(line)
            if mw:
                cond = body = None
                m1 = re.search(r"condition=%?([\w\.\-~!]+)", line)
                m2 = re.search(r"body=%?([\w\.\-~!]+)", line)
                cond = m1.group(1) if m1 else None
                body = m2.group(1) if m2 else None
                trip = _trip_count(comps.get(cond, [])) if cond else 1
                if body in comps:
                    edges[name].append((body, float(trip)))
                if cond in comps:
                    edges[name].append((cond, float(trip)))
            else:
                for c in callees:
                    if c in comps:
                        edges[name].append((c, 1.0))
    # roots: computations never referenced (the entry); propagate with
    # sum-over-call-sites semantics by fixed-point relaxation (call graph
    # is a DAG, so this converges within its depth)
    referenced = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in comps if c not in referenced]
    mult = {c: (1.0 if c in roots else 0.0) for c in comps}
    for _ in range(80):
        new = {c: (1.0 if c in roots else 0.0) for c in comps}
        for caller, outs in edges.items():
            for callee, w in outs:
                new[callee] += mult[caller] * w
        if all(abs(new[c] - mult[c]) < 1e-9 for c in comps):
            mult = new
            break
        mult = new
    return mult


def _multipliers_kinds(comps: dict[str, list[str]]):
    """Two multiplier maps: one following all call edges (flops), one
    excluding fusion/to_apply edges (bytes — XLA counts a fusion as its
    operands+outputs, not its interior)."""
    all_edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    loop_edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            m2 = re.search(r"body=%?([\w\.\-~!]+)", line)
            m1 = re.search(r"condition=%?([\w\.\-~!]+)", line)
            if " while(" in line and m2:
                cond = m1.group(1) if m1 else None
                body = m2.group(1)
                trip = _trip_count(comps.get(cond, [])) if cond else 1
                for tgt in (body, cond):
                    if tgt in comps:
                        all_edges[name].append((tgt, float(trip)))
                        loop_edges[name].append((tgt, float(trip)))
                continue
            for c in _CALLEE_RE.findall(line):
                if c in comps:
                    all_edges[name].append((c, 1.0))

    def solve(edges):
        referenced = {c for outs in edges.values() for c, _ in outs}
        roots = [c for c in comps if c not in referenced]
        # roots for loop_edges include fusion comps (unreachable) — zero
        # them unless they are true entry roots of the *all* graph
        mult = {c: (1.0 if c in roots else 0.0) for c in comps}
        for _ in range(80):
            new = {c: (1.0 if c in roots else 0.0) for c in comps}
            for caller, outs in edges.items():
                for callee, w in outs:
                    new[callee] += mult[caller] * w
            if all(abs(new[c] - mult[c]) < 1e-9 for c in comps):
                return new
            mult = new
        return mult

    all_mult = solve(all_edges)
    # bytes graph: roots = same entry as all-graph; fusion callees excluded
    ref_all = {c for outs in all_edges.values() for c, _ in outs}
    entry_roots = [c for c in comps if c not in ref_all]
    bytes_mult = {c: (1.0 if c in entry_roots else 0.0) for c in comps}
    for _ in range(80):
        new = {c: (1.0 if c in entry_roots else 0.0) for c in comps}
        for caller, outs in loop_edges.items():
            for callee, w in outs:
                new[callee] += bytes_mult[caller] * w
        if all(abs(new[c] - bytes_mult[c]) < 1e-9 for c in comps):
            bytes_mult = new
            break
        bytes_mult = new
    return all_mult, bytes_mult


_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-~!]+)\s*=\s*(.+?)\s+"
                     r"([a-z][a-z0-9\-]*)\(")
_SHAPE1_RE = re.compile(r"^\(?([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-~!]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims(s: str) -> list[int]:
    return [int(d) for d in s.split(",") if d]


def hlo_cost(hlo_text: str) -> tuple[float, float]:
    """(flops, bytes_accessed) with while-body trip weighting.

    flops: 2 * prod(out) * prod(contracted lhs dims) per dot op (matmul
    convention; elementwise flops are negligible for these workloads).
    bytes: per op, output + operand tensor bytes (the XLA bytes-accessed
    convention), fusion interiors excluded; while bodies weighted by trip.
    """
    comps = _split_computations(hlo_text)
    fmult, bmult = _multipliers_kinds(comps)
    flops = 0.0
    byts = 0.0
    for name, lines in comps.items():
        fm = fmult.get(name, 0.0)
        bm = bmult.get(name, 0.0)
        if fm <= 0 and bm <= 0:
            continue
        # symbol table: op name -> (bytes, dims-of-first-shape)
        table: dict[str, tuple[int, list[int] | None]] = {}
        parsed = []
        for line in lines:
            m = _LHS_RE.match(line)
            if not m:
                continue
            lhs_name, type_str, opkind = m.groups()
            b = _type_bytes(type_str)
            ms = _SHAPE1_RE.match(type_str.strip())
            dims = _dims(ms.group(2)) if ms else None
            table[lhs_name] = (b, dims)
            parsed.append((lhs_name, type_str, opkind, line))
        for lhs_name, type_str, opkind, line in parsed:
            rest = line.split(opkind + "(", 1)[1] if opkind + "(" in line \
                else ""
            args = rest.split(")", 1)[0]
            operands = [o for o in _OPERAND_RE.findall(args) if o in table]
            if fm > 0 and opkind == "dot":
                mc = _LHS_CONTRACT_RE.search(line)
                out_dims = table[lhs_name][1] or []
                lhs_dims = (table[operands[0]][1] or []) if operands else []
                if mc is not None:
                    k = 1
                    for d in _dims(mc.group(1)):
                        if d < len(lhs_dims):
                            k *= lhs_dims[d]
                    n = 1
                    for d in out_dims:
                        n *= d
                    flops += 2.0 * n * k * fm
            if bm > 0 and opkind not in ("parameter", "constant",
                                         "get-tuple-element", "tuple",
                                         "bitcast"):
                total = table[lhs_name][0]
                total += sum(table[o][0] for o in operands)
                byts += total * bm
    return flops, byts


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Collective wire bytes, with while-body ops multiplied by trip count
    (XLA prints / cost-counts loop bodies once)."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps) if comps else {}
    by_op: dict[str, float] = {}
    n = 0

    def scan_lines(lines, m):
        nonlocal n
        for line in lines:
            mm = _COLL_RE.search(line)
            if not mm:
                continue
            op = mm.group("op").replace("-start", "")
            payload = _type_bytes(mm.group("types"))
            by_op[op] = by_op.get(op, 0.0) + payload * _WIRE_FACTOR[op] * m
            n += 1

    if comps:
        for name, lines in comps.items():
            scan_lines(lines, max(mult.get(name, 0.0), 0.0) or 0.0)
    else:
        scan_lines(hlo_text.splitlines(), 1.0)
    return CollectiveStats(n_ops=n, wire_bytes=sum(by_op.values()),
                           by_op=by_op)


@dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float | None = None
    useful_flops_ratio: float | None = None
    n_collectives: int = 0
    collectives_by_op: dict = None

    def to_dict(self):
        return asdict(self)


def roofline_from(cost: dict, hlo_text: str, *, chips: int,
                  model_flops: float | None = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll.wire_bytes / LINK_BW
    dominant = max((("compute", t_c), ("memory", t_m),
                    ("collective", t_x)), key=lambda kv: kv[1])[0]
    ratio = None
    if model_flops:
        total_hlo = flops * chips
        ratio = model_flops / total_hlo if total_hlo else None
    return Roofline(flops_per_chip=flops, bytes_per_chip=byts,
                    wire_bytes_per_chip=coll.wire_bytes,
                    t_compute=t_c, t_memory=t_m, t_collective=t_x,
                    dominant=dominant, model_flops=model_flops,
                    useful_flops_ratio=ratio, n_collectives=coll.n_ops,
                    collectives_by_op=coll.by_op)


def lm_model_flops(cfg, tokens: int, *, training: bool) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); D = tokens processed."""
    import jax
    import numpy as np
    from repro.models import transformer as T

    shapes = jax.eval_shape(lambda: T.lm_init(jax.random.key(0), cfg))
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        p = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        if "'embed'" in p:
            # lookup is a gather, not a matmul; tied head counted below
            continue
        if "experts" in p and cfg.moe is not None:
            active += n * cfg.moe.top_k // cfg.moe.n_experts
        else:
            active += n
    if cfg.tie_embeddings:
        active += cfg.vocab * cfg.d_model      # LM head matmul
    mult = 3.0 if training else 1.0            # fwd + 2x bwd
    return 2.0 * active * tokens * mult

"""Greedy max-coverage seed selection (paper Alg. 1 L6-10 / Alg. 7), TPU-adapted.

RR sets are stored exactly like the paper's memory-optimized layout (Alg. 6):
one flat concatenated array ``rr_flat`` plus ``rr_offsets`` (CSR-of-RR).  For
vectorized processing we carry ``rr_ids`` = the row id of every flat element
(the inverse of Offsets_RR), so the Alg. 7 kernel becomes:

  argmax(Occur)                 -> jnp.argmax of the psum-reduced histogram
  per-RR membership scan of u   -> equality scan + segment_max by rr_ids
  Covered flag + decrement      -> mask + segment scatter-sub on Occur

The pool itself is *mesh-resident* (:class:`ShardedDeviceRRStore`): the flat
buffers carry a leading shard dimension equal to the device-mesh size and
stay sharded over the ``samples`` axis — each device keeps the rows it was
dealt, rr_ids are **local**, and appends are per-shard jit'd rank-scatters
into donated doubling buffers.  Every selection backend (fused scan, Pallas
bitset, CELF-sketch) runs as a ``shard_map`` over the same sharded views:
Occur is psum-reduced, argmax is replicated math, coverage updates stay
local — per seed the only collective is one ``psum(n)`` (plus one scalar
psum for the gain).  A single device is simply the mesh=1 special case of
the same code path; there is no separate single-device implementation.

The per-node coverage sketch is maintained **as packed uint32 words**
(``core/sketch.py``), replicated across the mesh: every device folds the
identical full batch into its replica (cheaper than any cross-device OR of
sketch deltas — see DESIGN.md §5), and the CELF sweep scores a disjoint
stripe of candidates per device, combined by one psum.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked, pvary
from repro.core import sketch as sketch_mod
from repro.core.packing import rank_positions
from repro.ft.failures import PoolAllocError
from repro.kernels import ops as kops
from repro.kernels.bitset import _popcount


def _ceil_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


class RRStore(NamedTuple):
    """CSR-of-RR.  ``rr_flat[rr_offsets[i]:rr_offsets[i+1]]`` is RR set i."""
    rr_flat: jnp.ndarray     # (T,) int32 node ids (padded tail = n, masked out)
    rr_ids: jnp.ndarray      # (T,) int32 row id per element
    valid: jnp.ndarray       # (T,) bool
    n_rr: int                # number of RR sets
    n_nodes: int


def _compact_padded(nodes, lens, base: int = 0):
    """(B, W) padded rows + lengths -> (flat elements, row ids + base), the
    CSR-of-RR compaction shared by ``build_store`` and the incremental
    store (paper Alg. 6 lines 4-11, vectorized).

    Lengths are clamped to ``[0, W]`` exactly like the device append path
    (:func:`_append_scatter_local`): an overflowed lane may report its true
    pre-truncation length while ``nodes`` only materializes ``W`` columns —
    without the clamp the element count (masked by width) and the row-id
    count (repeated by raw length) drift apart and the host mirror
    diverges from the device store.
    """
    nodes = np.asarray(nodes)
    lens = np.clip(np.asarray(lens, dtype=np.int64), 0, nodes.shape[1])
    mask = np.arange(nodes.shape[1])[None, :] < lens[:, None]
    flat = nodes[mask].astype(np.int64)
    ids = np.repeat(np.arange(len(lens), dtype=np.int64) + base, lens)
    return flat, ids, lens


def build_store(rr_lists_or_arrays, n: int, pad_to: int | None = None) -> RRStore:
    """Host-side compaction (paper Alg. 6 lines 4-11)."""
    if isinstance(rr_lists_or_arrays, list):
        lens = np.asarray([len(r) for r in rr_lists_or_arrays], dtype=np.int64)
        flat = (np.concatenate([np.asarray(r, dtype=np.int64)
                                for r in rr_lists_or_arrays])
                if lens.sum() else np.zeros(0, np.int64))
        ids = np.repeat(np.arange(len(lens), dtype=np.int64), lens)
    else:  # (nodes (B, Q), lengths (B,)) padded arrays from the samplers
        flat, ids, lens = _compact_padded(*rr_lists_or_arrays)
    t = flat.shape[0]
    t_pad = pad_to if pad_to is not None else t
    if t_pad < t:
        raise ValueError("pad_to smaller than payload")
    valid = np.zeros(t_pad, bool); valid[:t] = True
    flat = np.concatenate([flat, np.full(t_pad - t, n, np.int64)])
    ids = np.concatenate([ids, np.full(t_pad - t, len(lens), np.int64)])
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(ids, jnp.int32),
                   valid=jnp.asarray(valid),
                   n_rr=int(len(lens)), n_nodes=n)


class IncrementalRRStore:
    """Growing CSR-of-RR with amortized-O(1)-per-element ``append_batch``.

    The Alg. 2 LB loop selects seeds after every θ_i escalation; rebuilding
    the store from the per-round pool each time is O(rounds · T) host work
    per selection (O(rounds²) over the loop).  Here each round's batch is
    compacted exactly once into doubling flat/ids buffers, and ``snapshot``
    returns a cached device-resident :class:`RRStore` view (invalidated only
    by the next append).
    """

    def __init__(self, n_nodes: int, capacity: int = 1024):
        self.n_nodes = n_nodes
        self._flat = np.empty(max(capacity, 1), np.int64)
        self._ids = np.empty(max(capacity, 1), np.int64)
        self._t = 0
        self._n_rr = 0
        self._cache: RRStore | None = None

    @property
    def n_rr(self) -> int:
        return self._n_rr

    def _reserve(self, extra: int):
        need = self._t + extra
        if need <= self._flat.shape[0]:
            return
        cap = self._flat.shape[0]
        while cap < need:
            cap *= 2
        for name in ("_flat", "_ids"):
            buf = np.empty(cap, np.int64)
            buf[:self._t] = getattr(self, name)[:self._t]
            setattr(self, name, buf)

    def append_batch(self, batch) -> None:
        """Append one engine batch: an ``RRBatch`` or a ``(nodes, lengths)``
        pair of padded arrays (the ``build_store`` array form).  Rows with
        length 0 are *padding rows* (no RR set — fixed-shape device engine
        paths emit them) and are dropped: they get no row id and do not count
        toward ``n_rr``."""
        nodes, lens = (batch.nodes, batch.lengths) if hasattr(batch, "nodes") \
            else batch
        flat, ids, lens = _compact_padded(nodes, lens)
        row_rank = np.cumsum(lens > 0) - 1           # compact out empty rows
        self._reserve(flat.shape[0])
        self._flat[self._t:self._t + flat.shape[0]] = flat
        self._ids[self._t:self._t + flat.shape[0]] = \
            self._n_rr + row_rank[ids]
        self._t += flat.shape[0]
        self._n_rr += int((lens > 0).sum())
        self._cache = None

    def snapshot(self) -> RRStore:
        if self._cache is None:
            self._cache = RRStore(
                rr_flat=jnp.asarray(self._flat[:self._t], jnp.int32),
                rr_ids=jnp.asarray(self._ids[:self._t], jnp.int32),
                valid=jnp.ones(self._t, bool),
                n_rr=self._n_rr, n_nodes=self.n_nodes)
        return self._cache


# ---------------------------------------------------------------------------
# Mesh-sharded device-resident RR pool (paper §3.5 layout × DiFuseR sharding).
# ---------------------------------------------------------------------------

_PACK = 1 << 15   # packed-append window (elements per DUS write)

_EVAL_CHUNK = 8   # broadcast width of one exact-eval pass


def _default_mesh() -> Mesh:
    """The mesh=1 special case: a single-device mesh over the default
    device.  Single-device execution is *not* a separate code path — it is
    this mesh driving the same shard_map programs with psum over one shard."""
    return Mesh(np.asarray(jax.devices()[:1]), ("samples",))


@functools.partial(jax.jit, static_argnames=("pad", "n"))
def _pad_batch_rows(nodes, lens, *, pad, n):
    """Append ``pad`` zero-length sentinel rows so the batch divides the
    shard count (jitted: ``jnp.full`` outside jit commits the fill scalar
    host->device and trips the transfer guard)."""
    w = nodes.shape[1]
    return (jnp.concatenate([nodes, jnp.full((pad, w), n, nodes.dtype)]),
            jnp.concatenate([lens, jnp.zeros((pad,), lens.dtype)]))


@functools.partial(jax.jit, static_argnames=("pad",))
def _pad_row_weights(roww, *, pad):
    """Zero-weight sentinel rows matching :func:`_pad_batch_rows`."""
    return jnp.concatenate([roww, jnp.zeros((pad,), roww.dtype)])


@functools.partial(jax.jit, static_argnames=("d", "width"))
def _shard_counts(lens, *, d, width):
    """Per-shard (elements, valid rows) of one padded batch: (D, 2) int32."""
    l = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), width)
    l = l.reshape(d, -1)
    return jnp.stack([l.sum(axis=1, dtype=jnp.int32),
                      (l > 0).sum(axis=1, dtype=jnp.int32)], axis=1)


def _append_scatter_local(flat, ids, valid, t, n_rr, nodes, lens,
                          ew=None, wsum=None, roww=None):
    """Rank-scatter one padded batch into one shard's live buffers.

    Element ranks are a row-major prefix sum of the validity mask (rows stay
    contiguous, matching the host compaction order exactly); rows with
    length 0 are padding and receive no row id.  Row ids are shard-*local*.

    Row-weighted stores pass ``ew``/``wsum``/``roww`` (all three or none):
    the row weight lands on every element of its row (weighted Occur is
    then one scatter-add of ``ew``) and the shard's total valid-row weight
    accumulates into ``wsum`` (the weighted F_R denominator).  The
    unweighted trace is unchanged by the extra parameters.
    """
    cap = flat.shape[0]
    r, w = nodes.shape
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    fm = mask.reshape(-1)
    dest = t + jnp.cumsum(fm, dtype=jnp.int32) - 1
    dest = jnp.where(fm, dest, cap)                  # OOB -> dropped
    flat = flat.at[dest].set(nodes.reshape(-1).astype(jnp.int32), mode="drop")
    valid = valid.at[dest].set(True, mode="drop")
    row_valid = lens > 0
    rid = n_rr + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
    ids = ids.at[dest].set(
        jnp.broadcast_to(rid[:, None], (r, w)).reshape(-1), mode="drop")
    t_out = t + fm.sum(dtype=jnp.int32)
    nrr_out = n_rr + row_valid.sum(dtype=jnp.int32)
    if ew is None:
        return flat, ids, valid, t_out, nrr_out
    roww = roww.astype(jnp.float32)
    ew = ew.at[dest].set(
        jnp.broadcast_to(roww[:, None], (r, w)).reshape(-1), mode="drop")
    wsum = wsum + jnp.where(row_valid, roww, 0.0).sum(dtype=jnp.float32)
    return flat, ids, valid, ew, t_out, nrr_out, wsum


def _append_packed_local(flat, ids, valid, t, n_rr, nodes, lens, *, pack, n,
                         ew=None, wsum=None, roww=None):
    """Rank-scatter append, packed variant for wide batches (one shard).

    XLA:CPU lowers scatter to a serial per-update loop, so the plain
    rank-scatter costs O(R·W) scatter updates even though only
    ``sum(lens)`` elements are real.  Here the valid elements are gathered
    into a ``pack``-wide window first (vectorized binary search over the
    mask prefix sum — log(R·W) gather steps) and written with *contiguous*
    ``dynamic_update_slice`` ops; positions past the batch's element count
    get the virgin-buffer values (sentinel/0/False), which the next append
    overwrites.  Host picks this path whenever R·W ≫ elements ≤ pack.

    ``ew``/``wsum``/``roww`` (all three or none) are the row-weighted
    extension — see :func:`_append_scatter_local`.
    """
    r, w = nodes.shape
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    fm = mask.reshape(-1)
    csum = jnp.cumsum(fm.astype(jnp.int32))
    total = csum[-1]
    size = r * w
    src = rank_positions(csum, pack, size)
    jvalid = jnp.arange(1, pack + 1, dtype=jnp.int32) <= total
    fnodes = nodes.reshape(-1).astype(jnp.int32)[src]
    row_valid = lens > 0
    rid = n_rr + jnp.cumsum(row_valid.astype(jnp.int32)) - 1
    upd_flat = jnp.where(jvalid, fnodes, n)
    upd_ids = jnp.where(jvalid, rid[src // w], 0)
    flat = jax.lax.dynamic_update_slice(flat, upd_flat, (t,))
    ids = jax.lax.dynamic_update_slice(ids, upd_ids, (t,))
    valid = jax.lax.dynamic_update_slice(valid, jvalid, (t,))
    t_out = t + total
    nrr_out = n_rr + row_valid.sum(dtype=jnp.int32)
    if ew is None:
        return flat, ids, valid, t_out, nrr_out
    roww = roww.astype(jnp.float32)
    ew = jax.lax.dynamic_update_slice(
        ew, jnp.where(jvalid, roww[src // w], 0.0), (t,))
    wsum = wsum + jnp.where(row_valid, roww, 0.0).sum(dtype=jnp.float32)
    return flat, ids, valid, ew, t_out, nrr_out, wsum


def _bitset_from_flat_local(flat, ids, valid, *, num_rows, n_words):
    """Pack one shard's flat pool into a (num_rows, n_words) bit matrix.

    Elements are row-unique (RRBatch contract), so within one (row, word)
    cell every scattered bit is distinct and scatter-add == scatter-or.
    """
    w = jnp.where(valid, flat >> 5, n_words)         # sentinel -> dropped
    bit = jnp.where(
        valid,
        jnp.left_shift(jnp.uint32(1), (flat & 31).astype(jnp.uint32)),
        jnp.uint32(0))
    return jnp.zeros((num_rows, n_words), jnp.uint32).at[
        jnp.clip(ids, 0, num_rows - 1), w].add(bit, mode="drop")


@functools.lru_cache(maxsize=None)
def _mesh_store_fns(mesh: Mesh):
    """Per-mesh jitted shard_map programs for the pool (append/grow/sketch).

    Cached on the mesh so every store on the same mesh shares one jit cache
    (shapes recompile only at capacity doublings, as before).
    """
    ax = mesh.axis_names[0]
    buf, vec, b3 = P(ax, None), P(ax), P(ax, None, None)

    def _wrap_append(local_fn):
        def local(flat, ids, valid, t, nrr, nodes, lens):
            out = local_fn(flat[0], ids[0], valid[0], t[0], nrr[0],
                           nodes[0], lens[0])
            return tuple(x[None] for x in out)
        return shard_map_unchecked(
            local, mesh=mesh,
            in_specs=(buf, buf, buf, vec, vec, b3, buf),
            out_specs=(buf, buf, buf, vec, vec))

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
    def append_scatter(flat, ids, valid, t, nrr, nodes, lens):
        return _wrap_append(_append_scatter_local)(
            flat, ids, valid, t, nrr, nodes, lens)

    @functools.partial(jax.jit, static_argnames=("pack", "n"),
                       donate_argnums=(0, 1, 2, 3, 4))
    def append_packed(flat, ids, valid, t, nrr, nodes, lens, *, pack, n):
        return _wrap_append(functools.partial(
            _append_packed_local, pack=pack, n=n))(
            flat, ids, valid, t, nrr, nodes, lens)

    def _wrap_append_w(local_fn):
        def local(flat, ids, valid, ew, t, nrr, wsum, nodes, lens, roww):
            out = local_fn(flat[0], ids[0], valid[0], t[0], nrr[0],
                           nodes[0], lens[0], ew=ew[0], wsum=wsum[0],
                           roww=roww[0])
            return tuple(x[None] for x in out)
        return shard_map_unchecked(
            local, mesh=mesh,
            in_specs=(buf, buf, buf, buf, vec, vec, vec, b3, buf, buf),
            out_specs=(buf, buf, buf, buf, vec, vec, vec))

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
    def append_scatter_w(flat, ids, valid, ew, t, nrr, wsum, nodes, lens,
                         roww):
        return _wrap_append_w(_append_scatter_local)(
            flat, ids, valid, ew, t, nrr, wsum, nodes, lens, roww)

    @functools.partial(jax.jit, static_argnames=("pack", "n"),
                       donate_argnums=(0, 1, 2, 3, 4, 5, 6))
    def append_packed_w(flat, ids, valid, ew, t, nrr, wsum, nodes, lens,
                        roww, *, pack, n):
        return _wrap_append_w(functools.partial(
            _append_packed_local, pack=pack, n=n))(
            flat, ids, valid, ew, t, nrr, wsum, nodes, lens, roww)

    @functools.partial(jax.jit, static_argnames=("newcap",))
    def grow_ew(ew, *, newcap):
        def local(e):
            pad = newcap - e.shape[1]
            return jnp.concatenate(
                [e, jnp.zeros((1, pad), jnp.float32)], 1)
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf,), out_specs=buf)(ew)

    @functools.partial(jax.jit, static_argnames=("newcap", "n"))
    def grow(flat, ids, valid, *, newcap, n):
        # no donation: the outputs are larger than the inputs, so aliasing
        # is impossible — growth is the one amortized O(cap) device copy
        def local(f, i, v):
            pad = newcap - f.shape[1]
            return (jnp.concatenate([f, jnp.full((1, pad), n, jnp.int32)], 1),
                    jnp.concatenate([i, jnp.zeros((1, pad), jnp.int32)], 1),
                    jnp.concatenate([v, jnp.zeros((1, pad), bool)], 1))
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf),
            out_specs=(buf, buf, buf))(flat, ids, valid)

    @functools.partial(jax.jit, static_argnames=("k", "mode"),
                       donate_argnums=(0,))
    def sketch_fold(sk, nodes, lens, base, *, k, mode):
        # replication beats sharding for the fold: every device folds the
        # identical full batch into its replica — zero collectives, and the
        # packed fold is O(batch · log batch) regardless of sketch size
        def local(sk, nodes, lens, base):
            return sketch_mod.fold_batch_packed(
                sk[0], nodes, lens, base, k=k, mode=mode)[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(b3, P(), P(), P()),
            out_specs=b3)(sk, nodes, lens, base)

    @functools.partial(jax.jit, static_argnames=("num_rows", "n_words"))
    def bitset_build(flat, ids, valid, *, num_rows, n_words):
        def local(flat, ids, valid):
            return _bitset_from_flat_local(
                flat[0], ids[0], valid[0],
                num_rows=num_rows, n_words=n_words)[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf),
            out_specs=b3)(flat, ids, valid)

    @functools.partial(jax.jit, static_argnames=("n_rows", "k", "mode"))
    def sketch_from_pool(flat, ids, valid, *, n_rows, k, mode):
        # on-demand sketch for stores built without an incremental one:
        # per-shard partial fold by *local* row ids (collisions across
        # shards only cost precision, never soundness — Δocc stays a lower
        # bound), combined into identical replicas by one psum-OR
        # (all_gather + OR-reduce over the shard axis)
        def local(flat, ids, valid):
            v, b = sketch_mod.flat_to_packed_bits(
                flat[0], ids[0], valid[0], n_rows=n_rows, k=k, mode=mode)
            part = sketch_mod.scatter_or_bits(
                jnp.zeros((n_rows, k // 32), jnp.uint32), v, b)
            g = jax.lax.all_gather(part, ax)
            return jax.lax.reduce(g, jnp.uint32(0),
                                  jax.lax.bitwise_or, (0,))[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf),
            out_specs=b3)(flat, ids, valid)

    class Fns:
        pass

    fns = Fns()
    fns.append_scatter = append_scatter
    fns.append_packed = append_packed
    fns.append_scatter_w = append_scatter_w
    fns.append_packed_w = append_packed_w
    fns.grow = grow
    fns.grow_ew = grow_ew
    fns.sketch_fold = sketch_fold
    fns.bitset_build = bitset_build
    fns.sketch_from_pool = sketch_from_pool
    return fns


class ShardedDeviceRRStore:
    """Growing CSR-of-RR pool sharded over a device mesh (DESIGN.md §5).

    The flat pool (``flat``/``ids``/``valid``) carries a leading shard
    dimension equal to the mesh size and is sharded over the ``samples``
    axis: each device keeps the rows it was dealt, row ids are *local*, and
    ``append_batch`` is one jit'd ``shard_map`` rank-scatter per shard into
    donated doubling buffers (amortized O(1) growth, like the paper's
    Alg. 6 pool but per device).  Batches are dealt to shards in contiguous
    row blocks; a batch that is already sharded on the same mesh (a sharded
    engine's ``sample_sharded``) is re-laid-out by one explicit
    ``device_put`` with no host round-trip.

    The per-node coverage sketch is maintained **directly as packed uint32
    words** — (D, n_pad, k/32), a replica per shard, folded by every device
    from the identical replicated batch with canonical *global* (batch
    order) row numbering.  No (n+1, k) bool occupancy buffer exists on the
    append path (the ~8× sketch-memory cut of the ROADMAP).

    Host knowledge: exact per-shard element/row counts are mirrored on the
    host via one *explicit* (D, 2) scalar fetch per append — the same
    per-relaunch ``N_RR`` readback gIM's Alg. 6 host loop performs, and the
    only host↔device traffic an append causes.  Explicit transfers are
    permitted under ``jax.transfer_guard("disallow")``, which the IMM
    driver holds over the whole sampling+selection loop — on a mesh of any
    size.

    ``DeviceRRStore`` (the historical single-device pool) is this class on
    a 1-device mesh: shard_map over one shard, psum over one device.
    """

    DEFAULT_SKETCH_K = 1024

    def __init__(self, n_nodes: int, capacity: int = 4096,
                 sketch_k: int | None = None, sketch_mode: str = "mod",
                 mesh: Mesh | None = None, row_weighted: bool = False):
        if n_nodes >= np.iinfo(np.int32).max:
            raise ValueError("item space must fit int32")
        self.n_nodes = n_nodes
        self.row_weighted = row_weighted
        self.mesh = mesh if mesh is not None else _default_mesh()
        self.axis = self.mesh.axis_names[0]
        self.n_shards = d = int(self.mesh.devices.size)
        self._sh_buf = NamedSharding(self.mesh, P(self.axis, None))
        self._sh_vec = NamedSharding(self.mesh, P(self.axis))
        self._sh_b3 = NamedSharding(self.mesh, P(self.axis, None, None))
        self._sh_rep = NamedSharding(self.mesh, P())
        cap = _ceil_pow2(max(-(-capacity // d), 1))
        self._flat = jax.device_put(
            np.full((d, cap), n_nodes, np.int32), self._sh_buf)
        self._ids = jax.device_put(np.zeros((d, cap), np.int32), self._sh_buf)
        self._valid = jax.device_put(np.zeros((d, cap), bool), self._sh_buf)
        self._t_dev = jax.device_put(np.zeros(d, np.int32), self._sh_vec)
        self._nrr_dev = jax.device_put(np.zeros(d, np.int32), self._sh_vec)
        self._t_loc = np.zeros(d, np.int64)      # host mirrors (exact)
        self._nrr_loc = np.zeros(d, np.int64)
        # weighted rows (weighted IM, importance-weighted estimator): ew is
        # the per-*element* row weight (weighted Occur = one scatter-add of
        # ew), _w_dev the per-shard total valid-row weight (the weighted
        # F_R denominator, psum'd at selection)
        self._ew = (jax.device_put(np.zeros((d, cap), np.float32),
                                   self._sh_buf) if row_weighted else None)
        self._w_dev = (jax.device_put(np.zeros(d, np.float32), self._sh_vec)
                       if row_weighted else None)
        self._cache: RRStore | None = None
        self._bitset = None              # (D, num_rows, n_words) cache
        self.sketch_mode = sketch_mode
        self.sketch_k = (sketch_mod.resolve_sketch_k(sketch_k)
                         if sketch_k is not None else None)
        # sketch rows padded to a multiple of the shard count so the CELF
        # sweep can stripe candidates evenly across devices
        self.sketch_rows = -(-(n_nodes + 1) // d) * d
        self._sk_words = (jax.device_put(
            np.zeros((d, self.sketch_rows, self.sketch_k // 32), np.uint32),
            self._sh_b3) if self.sketch_k is not None else None)
        self._sk_cache = None            # on-demand (no incremental sketch)
        # optional pre-allocation gate, called (store, newcap) before any
        # growth allocation; may raise PoolAllocError (fault policy / real
        # memory-budget enforcement).  append_batch stays un-mutated until
        # every allocation has passed this gate, so a refused growth is
        # retryable (DESIGN.md §8).
        self.alloc_check = None
        # per-append ("sampling round") row/element watermarks, one (D,)
        # int64 vector each — the granularity windowed eviction drops at
        # (oldest round first; DESIGN.md §9)
        self._round_rows: list[np.ndarray] = []
        self._round_elems: list[np.ndarray] = []
        self._fns = _mesh_store_fns(self.mesh)

    # -- sizes -------------------------------------------------------------
    @property
    def n_rr(self) -> int:
        return int(self._nrr_loc.sum())

    @property
    def n_elems(self) -> int:
        return int(self._t_loc.sum())

    @property
    def capacity(self) -> int:
        """Per-shard element capacity."""
        return int(self._flat.shape[1])

    @property
    def n_rr_dev(self):
        """Per-shard row counts as a sharded (D,) device vector (selection
        psums it for the F_R denominator under the guard)."""
        return self._nrr_dev

    @property
    def n_rounds(self) -> int:
        """Sampling rounds (appends) still represented in the pool — the
        windowed-eviction granularity."""
        return len(self._round_rows)

    def per_device_pool_bytes(self) -> int:
        """Live pool bytes on each device: flat + ids + valid buffers
        (+ the element-weight buffer on row-weighted stores)."""
        return self.capacity * (4 + 4 + 1 + (4 if self.row_weighted else 0))

    def sketch_bytes(self) -> int:
        """Per-replica packed sketch bytes (0 without an incremental
        sketch).  The deleted bool occupancy would be 8× this."""
        if self._sk_words is None:
            return 0
        return self.sketch_rows * (self.sketch_k // 32) * 4

    # -- append ------------------------------------------------------------
    def append_batch(self, batch, row_w=None) -> None:
        """Compact one batch (``RRBatch`` or ``(nodes, lengths)``) into the
        sharded pool.  Zero-length rows are padding (fixed-shape device
        engine paths emit them) and are dropped.  Rows are dealt to shards
        in contiguous blocks; the tail shard absorbs the divisibility
        padding.

        ``row_w`` — (R,) per-row weights, required on ``row_weighted``
        stores (ignored entries on padding rows): the weight lands on every
        element of the row (``ew``), making weighted Occur one scatter-add.
        """
        nodes, lens = (batch.nodes, batch.lengths) if hasattr(batch, "nodes") \
            else batch
        nodes = jnp.asarray(nodes)
        lens = jnp.asarray(lens)
        if nodes.ndim != 2 or lens.shape != (nodes.shape[0],):
            raise ValueError("append_batch wants padded (R, W) nodes + (R,) "
                             "lengths")
        if self.row_weighted:
            if row_w is None:
                raise ValueError("row_weighted store needs row_w= per append")
            roww = jnp.asarray(row_w, jnp.float32)
            if roww.shape != (nodes.shape[0],):
                raise ValueError("row_w must be (R,) aligned with the batch")
        elif row_w is not None:
            raise ValueError("row_w given but the store was built without "
                             "row_weighted=True")
        r, w = nodes.shape
        d = self.n_shards
        rloc = -(-r // d)
        pad = rloc * d - r
        if pad:
            nodes, lens = _pad_batch_rows(nodes, lens, pad=pad,
                                          n=self.n_nodes)
            if self.row_weighted:
                roww = _pad_row_weights(roww, pad=pad)
        counts = np.asarray(jax.device_get(
            _shard_counts(lens, d=d, width=w)), np.int64)
        elems_l, rows_l = counts[:, 0], counts[:, 1]
        # wide batches (device engine padding ≫ payload) go through the
        # packed append: gather-pack + contiguous writes beat a serial
        # R·W-update scatter by orders of magnitude on CPU
        packed = rloc * w > _PACK and int(elems_l.max()) <= _PACK
        need = int(((self._t_loc + _PACK) if packed
                    else (self._t_loc + elems_l)).max())
        # growth runs *before* the sketch fold so an allocation failure
        # leaves the store completely un-mutated — the whole append is then
        # safe to retry after the caller frees memory (DESIGN.md §8)
        if need > self.capacity:
            try:
                self._grow_to(need)
            except PoolAllocError:
                # halve the growth step: the packed path reserves _PACK
                # headroom per shard; retry at the exact scatter footprint
                # before pushing the failure up to the fault policy
                exact = int((self._t_loc + elems_l).max())
                if not packed or exact >= need:
                    raise
                packed, need = False, exact
                if need > self.capacity:
                    self._grow_to(need)
        if self._sk_words is not None:
            # fold the batch into the packed coverage sketch *before* the
            # append advances the row counters: bucketing uses canonical
            # global (batch-order) row ids, identical on any mesh size
            nodes_rep = jax.device_put(nodes, self._sh_rep)
            lens_rep = jax.device_put(lens, self._sh_rep)
            base = jax.device_put(np.int32(self.n_rr), self._sh_rep)
            self._sk_words = self._fns.sketch_fold(
                self._sk_words, nodes_rep, lens_rep, base,
                k=self.sketch_k, mode=self.sketch_mode)
        nodes_sh = jax.device_put(nodes.reshape(d, rloc, w), self._sh_b3)
        lens_sh = jax.device_put(lens.reshape(d, rloc), self._sh_buf)
        if self.row_weighted:
            roww_sh = jax.device_put(roww.reshape(d, rloc), self._sh_buf)
            fn = (functools.partial(self._fns.append_packed_w, pack=_PACK,
                                    n=self.n_nodes)
                  if packed else self._fns.append_scatter_w)
            (self._flat, self._ids, self._valid, self._ew, self._t_dev,
             self._nrr_dev, self._w_dev) = fn(
                self._flat, self._ids, self._valid, self._ew, self._t_dev,
                self._nrr_dev, self._w_dev, nodes_sh, lens_sh, roww_sh)
        elif packed:
            (self._flat, self._ids, self._valid, self._t_dev,
             self._nrr_dev) = self._fns.append_packed(
                self._flat, self._ids, self._valid, self._t_dev,
                self._nrr_dev, nodes_sh, lens_sh,
                pack=_PACK, n=self.n_nodes)
        else:
            (self._flat, self._ids, self._valid, self._t_dev,
             self._nrr_dev) = self._fns.append_scatter(
                self._flat, self._ids, self._valid, self._t_dev,
                self._nrr_dev, nodes_sh, lens_sh)
        self._t_loc += elems_l
        self._nrr_loc += rows_l
        if rows_l.sum():
            self._round_rows.append(rows_l.copy())
            self._round_elems.append(elems_l.copy())
        self._cache = None
        self._bitset = None
        self._sk_cache = None

    def _grow_to(self, need: int) -> None:
        """Double the per-shard capacity until ``need`` fits, gated by
        ``alloc_check`` (which may raise :class:`PoolAllocError` *before*
        the donated buffers are re-allocated)."""
        newcap = self.capacity
        while newcap < need:
            newcap *= 2
        if self.alloc_check is not None:
            self.alloc_check(self, newcap)
        self._flat, self._ids, self._valid = self._fns.grow(
            self._flat, self._ids, self._valid,
            newcap=newcap, n=self.n_nodes)
        if self.row_weighted:
            self._ew = self._fns.grow_ew(self._ew, newcap=newcap)

    # -- checkpoint state --------------------------------------------------
    def state(self) -> dict:
        """Every append-relevant buffer as host numpy arrays (one explicit
        ``device_get``, legal under ``transfer_guard("disallow")``) — the
        array half of a durable pool checkpoint.  Restoring this dict via
        :meth:`from_state` reproduces the store bit-identically: flat pool,
        packed sketch words, device counters and the exact host mirrors."""
        arrs = {"flat": self._flat, "ids": self._ids, "valid": self._valid,
                "t_dev": self._t_dev, "nrr_dev": self._nrr_dev}
        if self.row_weighted:
            arrs["ew"] = self._ew
            arrs["w_dev"] = self._w_dev
        if self._sk_words is not None:
            arrs["sk_words"] = self._sk_words
        host = {k: np.asarray(v) for k, v in jax.device_get(arrs).items()}
        host["t_loc"] = self._t_loc.copy()
        host["nrr_loc"] = self._nrr_loc.copy()
        if self._round_rows:
            # (rounds, D) watermark history — windowed eviction keeps its
            # per-round granularity across a checkpoint round-trip
            host["round_rows"] = np.stack(self._round_rows)
            host["round_elems"] = np.stack(self._round_elems)
        return host

    def config(self) -> dict:
        """json-serializable construction parameters matching :meth:`state`
        (stored in the checkpoint manifest's ``meta``)."""
        return {"n_nodes": int(self.n_nodes),
                "per_shard_capacity": int(self.capacity),
                "n_shards": int(self.n_shards),
                "sketch_k": self.sketch_k,
                "sketch_mode": self.sketch_mode,
                "row_weighted": bool(self.row_weighted)}

    @classmethod
    def from_state(cls, state: dict, config: dict, mesh: Mesh | None = None):
        """Rebuild a store from :meth:`state` + :meth:`config` onto ``mesh``.

        The mesh must have the same shard count the state was saved with:
        rows carry *local* ids plus a shard dimension, so re-dealing them
        across a different D would renumber rows and break bit-identity.
        (Elastic re-meshing belongs to a compaction pass, not restore.)
        """
        store = cls(config["n_nodes"],
                    capacity=config["per_shard_capacity"] * config["n_shards"],
                    sketch_k=config["sketch_k"],
                    sketch_mode=config["sketch_mode"],
                    mesh=mesh, row_weighted=config["row_weighted"])
        if store.n_shards != int(config["n_shards"]):
            raise ValueError(
                f"pool checkpoint was saved on {config['n_shards']} shard(s) "
                f"but the restore mesh has {store.n_shards}; restore onto a "
                "same-size mesh")
        if store.capacity != int(config["per_shard_capacity"]):
            raise ValueError("per-shard capacity drifted across restore")
        store._flat = jax.device_put(state["flat"], store._sh_buf)
        store._ids = jax.device_put(state["ids"], store._sh_buf)
        store._valid = jax.device_put(state["valid"], store._sh_buf)
        store._t_dev = jax.device_put(state["t_dev"], store._sh_vec)
        store._nrr_dev = jax.device_put(state["nrr_dev"], store._sh_vec)
        if store.row_weighted:
            store._ew = jax.device_put(state["ew"], store._sh_buf)
            store._w_dev = jax.device_put(state["w_dev"], store._sh_vec)
        if store._sk_words is not None:
            store._sk_words = jax.device_put(state["sk_words"], store._sh_b3)
        store._t_loc = np.asarray(state["t_loc"], np.int64).copy()
        store._nrr_loc = np.asarray(state["nrr_loc"], np.int64).copy()
        rr = state.get("round_rows")
        if rr is not None:
            store._round_rows = [np.asarray(r, np.int64).copy() for r in rr]
            store._round_elems = [np.asarray(r, np.int64).copy()
                                  for r in state["round_elems"]]
        elif store._nrr_loc.any():
            # pre-watermark checkpoint: degrade to whole-pool granularity
            store._round_rows = [store._nrr_loc.copy()]
            store._round_elems = [store._t_loc.copy()]
        return store

    # -- windowed eviction (streaming graphs, DESIGN.md §9) -----------------
    def _rewrite(self, keep) -> dict:
        """Rebuild the pool keeping only the rows ``keep`` selects.

        ``keep(shard, flat, ids, ew) -> (flat', ids', ew', n_rows')`` maps
        one shard's compacted valid elements (host int64 arrays, ids local)
        to the surviving elements with dense renumbered local ids in
        ``[0, n_rows')``.  A maintenance operation in the spirit of
        :meth:`snapshot` — *not* on the guarded hot loop: shards gather to
        the host (explicit transfers, legal under the guard), buffers
        re-pack at the smallest power-of-two capacity, and the packed
        sketch rebuilds from the surviving flat pool via
        :func:`~repro.core.sketch.sketch_packed_from_flat` with shard-major
        global row numbering — the numbering future ``append_batch`` folds
        continue from (``base = n_rr``); any injective renumbering of
        survivors composes correctly because bucketing only ever reads row
        ids, never pool positions.
        """
        d = self.n_shards
        old_rows, old_elems = self.n_rr, self.n_elems
        arrs = (self._flat, self._ids, self._valid) + \
            ((self._ew,) if self.row_weighted else ())
        host = [np.asarray(a) for a in jax.device_get(arrs)]
        flat, ids, valid = host[0], host[1], host[2]
        ew = host[3] if self.row_weighted else None
        fs, iss, ews = [], [], []
        t_new = np.zeros(d, np.int64)
        r_new = np.zeros(d, np.int64)
        for s in range(d):
            m = valid[s]
            f2, i2, e2, rows_s = keep(
                s, flat[s][m].astype(np.int64), ids[s][m].astype(np.int64),
                ew[s][m] if ew is not None else None)
            fs.append(np.asarray(f2, np.int64))
            iss.append(np.asarray(i2, np.int64))
            ews.append(None if e2 is None else np.asarray(e2, np.float32))
            t_new[s] = int(fs[s].shape[0])
            r_new[s] = int(rows_s)
        cap = _ceil_pow2(max(int(t_new.max()), 1))
        nf = np.full((d, cap), self.n_nodes, np.int32)
        ni = np.zeros((d, cap), np.int32)
        nv = np.zeros((d, cap), bool)
        ne = np.zeros((d, cap), np.float32) if self.row_weighted else None
        w_new = np.zeros(d, np.float32) if self.row_weighted else None
        for s in range(d):
            t = int(t_new[s])
            nf[s, :t] = fs[s]
            ni[s, :t] = iss[s]
            nv[s, :t] = True
            if self.row_weighted and t:
                ne[s, :t] = ews[s]
                # the per-row weight sits on every element of the row; sum
                # one representative element per surviving row
                _, first = np.unique(iss[s], return_index=True)
                w_new[s] = np.float32(ews[s][first].sum())
        self._flat = jax.device_put(nf, self._sh_buf)
        self._ids = jax.device_put(ni, self._sh_buf)
        self._valid = jax.device_put(nv, self._sh_buf)
        self._t_dev = jax.device_put(t_new.astype(np.int32), self._sh_vec)
        self._nrr_dev = jax.device_put(r_new.astype(np.int32), self._sh_vec)
        if self.row_weighted:
            self._ew = jax.device_put(ne, self._sh_buf)
            self._w_dev = jax.device_put(w_new, self._sh_vec)
        self._t_loc = t_new.copy()
        self._nrr_loc = r_new.copy()
        if self._sk_words is not None:
            prefix = np.concatenate([[0], np.cumsum(r_new)[:-1]])
            gids = np.concatenate(
                [iss[s] + prefix[s] for s in range(d)]).astype(np.int32)
            fall = np.concatenate(fs).astype(np.int32)
            words = np.asarray(jax.device_get(
                sketch_mod.sketch_packed_from_flat(
                    jax.device_put(fall), jax.device_put(gids),
                    jax.device_put(np.ones(fall.shape[0], bool)),
                    n_rows=self.sketch_rows, k=self.sketch_k,
                    mode=self.sketch_mode)))
            self._sk_words = jax.device_put(
                np.broadcast_to(words[None], (d,) + words.shape).copy(),
                self._sh_b3)
        self._cache = None
        self._bitset = None
        self._sk_cache = None
        return {"rows_dropped": old_rows - self.n_rr,
                "rows_kept": self.n_rr,
                "elems_dropped": old_elems - self.n_elems,
                "per_shard_capacity": self.capacity}

    def evict_earliest_rounds(self, n_rounds: int) -> dict:
        """Drop the ``n_rounds`` earliest sampling rounds (windowed pool).

        Per-shard local row ids are append-ordered, so the earliest rounds
        occupy exactly the id prefix ``[0, thr)`` on every shard: surviving
        rows keep their relative order and renumber by one subtraction.
        The packed sketch rebuilds from the surviving flat pool (the
        rebuild the bit-identity conformance test pins).  Returns the
        :meth:`_rewrite` stats dict.
        """
        n_rounds = max(0, min(int(n_rounds), self.n_rounds))
        if n_rounds == 0:
            return {"rows_dropped": 0, "rows_kept": self.n_rr,
                    "elems_dropped": 0,
                    "per_shard_capacity": self.capacity}
        thr = np.sum(self._round_rows[:n_rounds], axis=0).astype(np.int64)
        old_nrr = self._nrr_loc.copy()

        def keep(s, f, i, e):
            m = i >= thr[s]
            return (f[m], i[m] - thr[s],
                    e[m] if e is not None else None,
                    int(old_nrr[s] - thr[s]))

        stats = self._rewrite(keep)
        self._round_rows = self._round_rows[n_rounds:]
        self._round_elems = self._round_elems[n_rounds:]
        stats["rounds_dropped"] = n_rounds
        return stats

    def evict_to_bytes(self, max_bytes_per_device: int) -> dict:
        """Drop earliest rounds until :meth:`per_device_pool_bytes` fits
        ``max_bytes_per_device``.  Best effort: the latest round is always
        kept (a bound smaller than one round cannot be met — the returned
        ``met`` flag says whether the bound holds).  When no round needs
        dropping but allocated capacity alone exceeds the bound (append
        growth over-allocates), the pool compacts in place to the smallest
        power-of-two capacity without touching any row.
        """
        bpe = 4 + 4 + 1 + (4 if self.row_weighted else 0)
        elems = (np.stack(self._round_elems) if self._round_elems
                 else np.zeros((0, self.n_shards), np.int64))

        def bytes_after(j):
            surv = (elems[j:].sum(axis=0) if j < elems.shape[0]
                    else np.zeros(self.n_shards, np.int64))
            return _ceil_pow2(max(int(surv.max()), 1)) * bpe

        drop = 0
        while drop < max(elems.shape[0] - 1, 0) and \
                bytes_after(drop) > max_bytes_per_device:
            drop += 1
        if drop == 0 and \
                self.per_device_pool_bytes() > max_bytes_per_device:
            nloc = self._nrr_loc.copy()
            stats = self._rewrite(
                lambda s, f, i, e: (f, i, e, int(nloc[s])))
            stats["rounds_dropped"] = 0
        else:
            stats = self.evict_earliest_rounds(drop)
        stats["met"] = self.per_device_pool_bytes() <= max_bytes_per_device
        return stats

    def evict_rows_containing(self, nodes) -> dict:
        """Drop every RR row containing any of ``nodes`` — the delta
        invalidation primitive of ``IMMSolver.resolve_incremental``
        (``nodes`` = the reverse-adjacency rows an edge-delta batch
        touches, :func:`repro.core.stream.affected_nodes`).  Surviving rows
        renumber densely per shard; the round watermark history collapses
        to one synthetic round (membership eviction cuts across rounds).
        """
        aff = np.unique(np.asarray(nodes, np.int64).reshape(-1))

        def keep(s, f, i, e):
            bad = np.unique(i[np.isin(f, aff)])
            m = ~np.isin(i, bad)
            f2, i_old = f[m], i[m]
            u = np.unique(i_old)
            return (f2, np.searchsorted(u, i_old),
                    e[m] if e is not None else None, int(u.shape[0]))

        stats = self._rewrite(keep)
        self._round_rows = [self._nrr_loc.copy()] if self.n_rr else []
        self._round_elems = [self._t_loc.copy()] if self.n_rr else []
        stats["affected_nodes"] = int(aff.shape[0])
        return stats

    # -- views -------------------------------------------------------------
    def snapshot(self) -> RRStore:
        """Back-compat :class:`RRStore` view (valid until the next append).

        On a 1-device mesh this is a device-side slice of the live extent
        with the exact single-device layout.  On a multi-device mesh the
        shards are gathered to the host and renumbered shard-major (local
        ids + per-shard offsets) — a debugging/compat view; the hot paths
        never call it.
        """
        if self._cache is not None:
            return self._cache
        if self.n_shards == 1:
            t = int(self._t_loc[0])
            self._cache = RRStore(
                rr_flat=_slice_extent(self._flat, t=t),
                rr_ids=_slice_extent(self._ids, t=t),
                valid=_slice_extent(self._valid, t=t),
                n_rr=self.n_rr, n_nodes=self.n_nodes)
            return self._cache
        flat, ids, valid = (np.asarray(x) for x in jax.device_get(
            (self._flat, self._ids, self._valid)))
        parts_f, parts_i, base = [], [], 0
        for s in range(self.n_shards):
            m = valid[s]
            parts_f.append(flat[s][m])
            parts_i.append(ids[s][m] + base)
            base += int(self._nrr_loc[s])
        ff = np.concatenate(parts_f) if parts_f else np.zeros(0, np.int64)
        ii = np.concatenate(parts_i) if parts_i else np.zeros(0, np.int64)
        self._cache = RRStore(
            rr_flat=jax.device_put(ff.astype(np.int32)),
            rr_ids=jax.device_put(ii.astype(np.int32)),
            valid=jax.device_put(np.ones(ff.shape[0], bool)),
            n_rr=self.n_rr, n_nodes=self.n_nodes)
        return self._cache

    def row_capacity(self) -> int:
        """Static per-shard row bound for selection: next power of two ≥
        the largest shard's row count (and ≥ 32 so the Covered bitset packs
        whole words).  Selection recompiles only when this doubles."""
        return max(32, _ceil_pow2(max(int(self._nrr_loc.max()), 1)))

    def bitset_matrix(self):
        """(D, row_capacity, ceil(n/32)) packed membership matrix, one
        block per shard (cached)."""
        num_rows = self.row_capacity()
        n_words = (self.n_nodes + 31) // 32
        if self._bitset is None or \
                self._bitset.shape[1:] != (num_rows, n_words):
            self._bitset = self._fns.bitset_build(
                self._flat, self._ids, self._valid,
                num_rows=num_rows, n_words=n_words)
        return self._bitset

    def sketch_words_mesh(self, k: int | None = None):
        """(D, sketch_rows, k/32) packed per-node coverage sketch — one
        replica per shard.

        Stores constructed with ``sketch_k`` return the incrementally
        maintained fold (bit-identical on any mesh size); otherwise the
        sketch is built on demand from the sharded flat pool (per-shard
        partial folds by local row ids, combined by one psum-OR).
        """
        if self._sk_words is not None:
            if k is not None and \
                    sketch_mod.resolve_sketch_k(k) != self.sketch_k:
                raise ValueError(
                    f"store maintains an incremental sketch of k="
                    f"{self.sketch_k}; requested k={k} cannot be honored")
            return self._sk_words
        kk = sketch_mod.resolve_sketch_k(k if k is not None
                                         else self.DEFAULT_SKETCH_K)
        if self._sk_cache is None or self._sk_cache.shape[2] != kk // 32:
            self._sk_cache = self._fns.sketch_from_pool(
                self._flat, self._ids, self._valid,
                n_rows=self.sketch_rows, k=kk, mode=self.sketch_mode)
        return self._sk_cache

    def sketch_words(self, k: int | None = None):
        """Single-replica (n+1, k/32) view of the packed sketch (the mesh
        replicas pad rows to a multiple of the shard count for the striped
        sweep; the canonical view slices that padding off, so the view is
        identical on any mesh size)."""
        return _slice_extent(self.sketch_words_mesh(k), t=self.n_nodes + 1)

    def select(self, k: int, method: str = "auto",
               spec: "SelectionSpec | None" = None,
               eval_batch: int | None = None) -> "CoverageResult":
        if method in ("celf", "celf-sketch"):
            if eval_batch is not None:
                return select_seeds_celf(self, k, spec=spec,
                                         eval_batch=eval_batch)
            return select_seeds_celf(self, k, spec=spec)
        if spec is not None:
            return select_variant(self, spec, method=method)
        return select_seeds_device(self, k, method=method)


# the historical single-device pool IS the mesh=1 case — same class, same
# code path, a 1-device mesh by default
DeviceRRStore = ShardedDeviceRRStore


@functools.partial(jax.jit, static_argnames=("t",))
def _slice_extent(x, *, t):
    return x[0, :t]


@functools.lru_cache(maxsize=None)
def _mesh_sketch_fns(mesh: Mesh):
    """Per-mesh jitted shard_map program for the *pool-free* frontier fold
    (``mode="approximate"``, DESIGN.md §10).

    Unlike the exact store's replicated ``sketch_fold`` (every device folds
    the identical full batch — cheap next to pool appends it rides along
    with), here the fold IS the hot loop, so the batch is split: each shard
    scatter-ORs only its contiguous ``rloc``-row block (D× less work per
    device) into a zero delta, and the deltas merge by one psum-OR
    (all_gather + OR-reduce).  Row ids are computed over the *full*
    replicated batch before slicing, so bucketing is canonical batch-order
    numbering — identical on any mesh size; OR is associative and
    commutative, so the merged words are bit-identical at any shard count.
    """
    ax = mesh.axis_names[0]
    b3 = P(ax, None, None)

    @functools.partial(jax.jit,
                       static_argnames=("k", "mode", "rloc", "interpret"),
                       donate_argnums=(0,))
    def frontier_fold(sk, nodes, lens, base, *, k, mode, rloc, interpret):
        def local(sk, nodes, lens, base):
            w = nodes.shape[1]
            row_valid = lens.astype(jnp.int32) > 0
            rid = base + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
            i = jax.lax.axis_index(ax)
            nb = jax.lax.dynamic_slice(nodes, (i * rloc, 0), (rloc, w))
            lb = jax.lax.dynamic_slice(lens, (i * rloc,), (rloc,))
            rb = jax.lax.dynamic_slice(rid, (i * rloc,), (rloc,))
            # interpret resolved by the caller outside this trace: it picks
            # the fold algorithm (kernel vs sort-based), so a stale baked-in
            # resolution must not survive the jit cache
            part = sketch_mod.fold_frontier_rows(
                jnp.zeros_like(sk[0]), nb, lb, rb, k=k, mode=mode,
                interpret=interpret)
            g = jax.lax.all_gather(part, ax)
            delta = jax.lax.reduce(g, jnp.uint32(0),
                                   jax.lax.bitwise_or, (0,))
            return (sk[0] | delta)[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(b3, P(), P(), P()),
            out_specs=b3)(sk, nodes, lens, base)

    class Fns:
        pass

    fns = Fns()
    fns.frontier_fold = frontier_fold
    return fns


class SketchRRStore:
    """Pool-free sketch-only RR "store" — the ``mode="approximate"`` engine
    state (DiFuseR mode, DESIGN.md §10).

    Every sampling micro-step's frontier folds straight into the packed
    (D, sketch_rows, k/32) per-node occupancy words via the Pallas
    scatter-OR kernel; the flat pool / ids / valid buffers of
    :class:`ShardedDeviceRRStore` are **never allocated** — O(n·k/8) bytes
    per device independent of θ, vs the exact pool's O(θ·E[|RR|]).  The
    only sampling state besides the words is the per-shard row counter
    (host mirror of the same explicit (D, 2) scalar fetch the exact store
    performs per append), which drives the IMM θ accounting.

    What is *lost* relative to the exact store is the exact-acceptance
    contract: no pool exists to verify marginals against, so selection
    (:func:`select_seeds_sketch`) runs on linear-counting estimates and
    results carry a certified error bound instead of exactness.  Row
    weights, budgets and MRIM tags all need the pool and are rejected at
    the :class:`~repro.core.problem.IMProblem` layer.
    """

    pool_free = True
    row_weighted = False

    def __init__(self, n_nodes: int, sketch_k: int | None = None,
                 sketch_mode: str = "mod", mesh: Mesh | None = None):
        if n_nodes >= np.iinfo(np.int32).max:
            raise ValueError("item space must fit int32")
        self.n_nodes = n_nodes
        self.mesh = mesh if mesh is not None else _default_mesh()
        self.axis = self.mesh.axis_names[0]
        self.n_shards = d = int(self.mesh.devices.size)
        self._sh_buf = NamedSharding(self.mesh, P(self.axis, None))
        self._sh_vec = NamedSharding(self.mesh, P(self.axis))
        self._sh_b3 = NamedSharding(self.mesh, P(self.axis, None, None))
        self._sh_rep = NamedSharding(self.mesh, P())
        self.sketch_mode = sketch_mode
        self.sketch_k = sketch_mod.resolve_sketch_k(
            sketch_k if sketch_k is not None
            else ShardedDeviceRRStore.DEFAULT_SKETCH_K)
        self.sketch_rows = -(-(n_nodes + 1) // d) * d
        self._sk_words = jax.device_put(
            np.zeros((d, self.sketch_rows, self.sketch_k // 32), np.uint32),
            self._sh_b3)
        self._nrr_loc = np.zeros(d, np.int64)    # the θ row counter
        self._t_loc = np.zeros(d, np.int64)      # element count (stats only)
        self.alloc_check = None                  # API compat; never grows
        self._fns = _mesh_sketch_fns(self.mesh)

    # -- sizes -------------------------------------------------------------
    @property
    def n_rr(self) -> int:
        return int(self._nrr_loc.sum())

    @property
    def n_elems(self) -> int:
        return int(self._t_loc.sum())

    def per_device_pool_bytes(self) -> int:
        """No pool buffers exist — the point of the mode."""
        return 0

    def sketch_bytes(self) -> int:
        return self.sketch_rows * (self.sketch_k // 32) * 4

    # -- append (the fused sample→sketch hot path) -------------------------
    def append_batch(self, batch, row_w=None) -> None:
        """Fold one padded frontier batch into the packed words — the
        entire "append".  Same calling convention as the exact store (the
        engines and the solver's fault-policy retry wrapper are shared);
        the one explicit host fetch is the (D, 2) shard-count scalar."""
        from repro.kernels import ops as kops
        if row_w is not None:
            raise ValueError("pool-free sketch store is unweighted")
        nodes, lens = (batch.nodes, batch.lengths) \
            if hasattr(batch, "nodes") else batch
        nodes = jnp.asarray(nodes)
        lens = jnp.asarray(lens)
        if nodes.ndim != 2 or lens.shape != (nodes.shape[0],):
            raise ValueError("append_batch wants padded (R, W) nodes + (R,) "
                             "lengths")
        r, w = nodes.shape
        d = self.n_shards
        rloc = -(-r // d)
        pad = rloc * d - r
        if pad:
            nodes, lens = _pad_batch_rows(nodes, lens, pad=pad,
                                          n=self.n_nodes)
        counts = np.asarray(jax.device_get(
            _shard_counts(lens, d=d, width=w)), np.int64)
        base = jax.device_put(np.int32(self.n_rr), self._sh_rep)
        nodes_rep = jax.device_put(nodes, self._sh_rep)
        lens_rep = jax.device_put(lens, self._sh_rep)
        self._sk_words = self._fns.frontier_fold(
            self._sk_words, nodes_rep, lens_rep, base,
            k=self.sketch_k, mode=self.sketch_mode, rloc=rloc,
            interpret=kops.resolve_interpret(None))
        self._t_loc += counts[:, 0]
        self._nrr_loc += counts[:, 1]

    # -- checkpoint state (im-pool v2 sub-kind) ----------------------------
    def state(self) -> dict:
        return {"sk_words": np.asarray(jax.device_get(self._sk_words)),
                "t_loc": self._t_loc.copy(),
                "nrr_loc": self._nrr_loc.copy()}

    def config(self) -> dict:
        return {"kind": "sketch",
                "n_nodes": int(self.n_nodes),
                "n_shards": int(self.n_shards),
                "sketch_k": self.sketch_k,
                "sketch_mode": self.sketch_mode,
                "row_weighted": False}

    @classmethod
    def from_state(cls, state: dict, config: dict, mesh: Mesh | None = None):
        store = cls(config["n_nodes"], sketch_k=config["sketch_k"],
                    sketch_mode=config["sketch_mode"], mesh=mesh)
        if store.n_shards != int(config["n_shards"]):
            raise ValueError(
                f"sketch checkpoint was saved on {config['n_shards']} "
                f"shard(s) but the restore mesh has {store.n_shards}; "
                "restore onto a same-size mesh")
        store._sk_words = jax.device_put(state["sk_words"], store._sh_b3)
        store._t_loc = np.asarray(state["t_loc"], np.int64).copy()
        store._nrr_loc = np.asarray(state["nrr_loc"], np.int64).copy()
        return store

    # -- views + selection -------------------------------------------------
    def sketch_words_mesh(self, k: int | None = None):
        if k is not None and \
                sketch_mod.resolve_sketch_k(k) != self.sketch_k:
            raise ValueError(
                f"store maintains an incremental sketch of k="
                f"{self.sketch_k}; requested k={k} cannot be honored")
        return self._sk_words

    def sketch_words(self, k: int | None = None):
        return _slice_extent(self.sketch_words_mesh(k), t=self.n_nodes + 1)

    def select(self, k: int, method: str = "auto",
               spec: "SelectionSpec | None" = None,
               eval_batch: int | None = None) -> "CoverageResult":
        if spec is not None:
            raise ValueError("pool-free sketch store supports plain (or "
                             "candidate-masked) selection only; weighted/"
                             "budgeted/MRIM specs need the exact store")
        return select_seeds_sketch(self, k)


def merge_stores(stores: list[RRStore]) -> RRStore:
    n = stores[0].n_nodes
    flats, ids, valids, base = [], [], [], 0
    for s in stores:
        flats.append(np.asarray(s.rr_flat)[np.asarray(s.valid)])
        ids.append(np.asarray(s.rr_ids)[np.asarray(s.valid)] + base)
        base += s.n_rr
    flat = np.concatenate(flats) if flats else np.zeros(0, np.int64)
    rid = np.concatenate(ids) if ids else np.zeros(0, np.int64)
    return RRStore(rr_flat=jnp.asarray(flat, jnp.int32),
                   rr_ids=jnp.asarray(rid, jnp.int32),
                   valid=jnp.ones(flat.shape[0], bool),
                   n_rr=base, n_nodes=n)


def occur_histogram(store: RRStore) -> jnp.ndarray:
    """Occur[n]: #RR sets containing each node (elements are row-unique)."""
    ones = store.valid.astype(jnp.int32)
    return jnp.zeros(store.n_nodes + 1, jnp.int32).at[store.rr_flat].add(
        ones, mode="drop")[:store.n_nodes]


@functools.partial(jax.jit, static_argnames=("n_rr", "n", "k"))
def _greedy(rr_flat, rr_ids, valid, occur0, *, n_rr, n, k):
    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        match = (rr_flat == u) & valid                       # membership scan
        row_has = jax.ops.segment_max(match.astype(jnp.int32), rr_ids,
                                      num_segments=n_rr + 1,
                                      indices_are_sorted=True)[:n_rr] > 0
        newly = row_has & ~covered
        elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
            jnp.clip(rr_ids, 0, n_rr)] & valid
        dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            elem_newly.astype(jnp.int32), mode="drop")[:n]
        occur = occur - dec
        covered = covered | row_has
        gain = newly.sum(dtype=jnp.int32)
        return (occur, covered), (u, gain)

    covered = jnp.zeros(n_rr, bool)
    (occur, covered), (seeds, gains) = jax.lax.scan(
        step, (occur0, covered), None, length=k)
    return seeds, gains, covered


class CoverageResult(NamedTuple):
    seeds: jnp.ndarray    # (k,) int32
    gains: jnp.ndarray    # (k,) int32 — newly covered RR sets per seed
    frac: jnp.ndarray     # () float32 — F_R(S): covered fraction


def select_seeds(store: RRStore, k: int) -> CoverageResult:
    occur0 = occur_histogram(store)
    seeds, gains, covered = _greedy(store.rr_flat, store.rr_ids, store.valid,
                                    occur0, n_rr=store.n_rr,
                                    n=store.n_nodes, k=k)
    frac = gains.sum() / jnp.maximum(store.n_rr, 1)
    return CoverageResult(seeds=seeds, gains=gains, frac=frac.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Mesh-sharded selection backends (fused scan / Pallas bitset / CELF).
# ---------------------------------------------------------------------------

def _unpack_covered(cov_words):
    """(nw,) packed uint32 Covered bitset -> (nw*32,) bool rows."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (((cov_words[:, None] >> shifts[None, :])
             & jnp.uint32(1)) != 0).reshape(cov_words.shape[0] * 32)


def _pack_covered(rows):
    """(nw*32,) bool rows -> (nw,) packed uint32 words."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (rows.reshape(-1, 32).astype(jnp.uint32)
            << shifts[None, :]).sum(axis=1)


def _newly_rows(flat, ids, valid, covered, u):
    """Rows containing ``u`` that are not yet covered — THE membership pass.

    Single shared body for the fused scan step, the CELF exact-eval batch
    (vmapped over candidates) and the CELF commit; every caller runs it
    per shard on local rows, so the celf==fused parity contract hangs on
    every path computing newly-covered rows identically.
    """
    match = (flat == u) & valid
    row_has = jax.ops.segment_max(match.astype(jnp.int32), ids,
                                  num_segments=covered.shape[0]) > 0
    return row_has & ~covered


@functools.lru_cache(maxsize=None)
def _mesh_select_fns(mesh: Mesh):
    """Per-mesh jitted shard_map selection programs.

    Every backend reads the same sharded pool views: Occur partials are
    psum-reduced, argmax is replicated math, Covered stays shard-local, and
    per seed the only collectives are one ``psum(n)`` (decrement) and one
    scalar psum (gain) — exactly the protocol of DESIGN.md §5.  Replicated
    outputs come back through ``out_specs=P()``, so no host-side slicing
    (which would commit an index scalar under the transfer guard) is
    needed.
    """
    ax = mesh.axis_names[0]
    buf, vec, b3 = P(ax, None), P(ax), P(ax, None, None)

    @functools.partial(jax.jit, static_argnames=("num_rows", "n", "k"))
    def fused(flat, ids, valid, nrr, *, num_rows, n, k):
        """Alg. 7 as ONE scan over the capacity-padded sharded buffers.

        Operands are the pool's *capacity* buffers (shapes change only at
        doublings, so the LB loop re-selects without recompiling), the row
        counts arrive as per-shard device scalars (only the F_R denominator
        needs their psum), and Covered lives as a packed per-shard
        ``(num_rows/32,)`` uint32 bitset — per-seed gains are popcount
        arithmetic on the newly-covered words.  The Occur decrement stays a
        masked scatter over the local flat elements: on a sparse pool that
        is O(elements/D) per device, strictly less work than any dense
        per-node pass (the bit-matrix variant is :func:`bitset`).
        """
        def local(flat, ids, valid, nrr):
            flat, ids, valid = flat[0], ids[0], valid[0]
            occur0 = jnp.zeros(n + 1, jnp.int32).at[flat].add(
                valid.astype(jnp.int32), mode="drop")[:n]
            occur0 = jax.lax.psum(occur0, ax)
            nrr_tot = jax.lax.psum(nrr[0], ax)

            def step(carry, _):
                occur, cov_words = carry
                u = jnp.argmax(occur).astype(jnp.int32)
                newly = _newly_rows(flat, ids, valid,
                                    _unpack_covered(cov_words), u)
                new_words = _pack_covered(newly)
                gain = jax.lax.psum(
                    _popcount(new_words).sum(dtype=jnp.int32), ax)
                elem_newly = newly[jnp.clip(ids, 0, num_rows - 1)] & valid
                dec = jnp.zeros(n + 1, jnp.int32).at[flat].add(
                    elem_newly.astype(jnp.int32), mode="drop")[:n]
                occur = occur - jax.lax.psum(dec, ax)
                return (occur, cov_words | new_words), (u, gain)

            cov0 = pvary(jnp.zeros(num_rows // 32, jnp.uint32), ax)
            _, (seeds, gains) = jax.lax.scan(
                step, (occur0, cov0), None, length=k)
            frac = gains.sum(dtype=jnp.int32) / jnp.maximum(nrr_tot, 1)
            return seeds, gains, frac.astype(jnp.float32)

        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf, vec),
            out_specs=(P(), P(), P()))(flat, ids, valid, nrr)

    @functools.partial(jax.jit, static_argnames=("k",))
    def bitset(m_words, nrr, *, k):
        """Alg. 7 on the per-shard packed membership matrices, via the
        Pallas bitset kernels (each shard runs the kernels on its local
        block; Occur and its decrement are psum-reduced).  Work per seed is
        O(num_rows · n/32) per device regardless of sparsity, so this path
        wins when RR sets are dense (mean size ≳ n/32)."""
        from repro.kernels import ops as kops

        def local(m, nrr):
            m = m[0]
            occur0 = jax.lax.psum(kops.occur_from_bitset(m), ax)
            nrr_tot = jax.lax.psum(nrr[0], ax)

            def step(carry, _):
                occur, covered = carry
                u = jnp.argmax(occur).astype(jnp.int32)
                col = m[:, u >> 5]
                hit = ((col >> (u & 31).astype(jnp.uint32))
                       & jnp.uint32(1)) != 0
                newly = hit & ~covered
                dec = jax.lax.psum(
                    kops.occur_from_bitset_masked(m, newly), ax)
                gain = jax.lax.psum(newly.sum(dtype=jnp.int32), ax)
                return (occur - dec, covered | hit), (u, gain)

            covered0 = pvary(jnp.zeros(m.shape[0], bool), ax)
            _, (seeds, gains) = jax.lax.scan(
                step, (occur0, covered0), None, length=k)
            frac = gains.sum(dtype=jnp.int32) / jnp.maximum(nrr_tot, 1)
            return seeds, gains, frac.astype(jnp.float32)

        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(b3, vec),
            out_specs=(P(), P(), P()))(m_words, nrr)

    @functools.partial(jax.jit, static_argnames=("n",))
    def occur(flat, valid, *, n):
        """Exact psum-reduced Occur histogram (CELF's upper-bound init)."""
        def local(flat, valid):
            h = jnp.zeros(n + 1, jnp.int32).at[flat[0]].add(
                valid[0].astype(jnp.int32), mode="drop")[:n]
            return jax.lax.psum(h, ax)
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf),
            out_specs=P())(flat, valid)

    @jax.jit
    def eval_batch(flat, ids, valid, cov_words, cands):
        """Exact marginal coverage of C candidates vs the covered bitset.

        The membership pass is broadcast over ``_EVAL_CHUNK`` candidates at
        a time under ``lax.map`` per shard, so peak memory is
        O(local elements · _EVAL_CHUNK) — a *fixed* multiple of the pool
        shard, independent of ``eval_batch``.  ``cands`` is replicated and
        may be padded with -1 (matches nothing, gain 0); per-shard counts
        are psum-reduced into the replicated exact gains.
        """
        def local(flat, ids, valid, cov_words, cands):
            flat, ids, valid = flat[0], ids[0], valid[0]
            covered = _unpack_covered(cov_words[0])
            c = cands.shape[0]
            pad = (-c) % _EVAL_CHUNK
            cs = jnp.concatenate(
                [cands, jnp.full((pad,), -1, cands.dtype)]) if pad else cands

            def chunk(cc):
                newly = jax.vmap(
                    lambda u: _newly_rows(flat, ids, valid, covered, u))(cc)
                return newly.sum(axis=1, dtype=jnp.int32)

            gains = jax.lax.map(chunk, cs.reshape(-1, _EVAL_CHUNK))
            return jax.lax.psum(gains.reshape(-1)[:c], ax)

        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf, buf, P()),
            out_specs=P())(flat, ids, valid, cov_words, cands)

    @jax.jit
    def apply_seed(flat, ids, valid, cov_words, u):
        """Commit seed ``u``: OR its rows into each shard's packed Covered
        bitset and psum the exact gain."""
        def local(flat, ids, valid, cov_words, u):
            newly = _newly_rows(flat[0], ids[0], valid[0],
                                _unpack_covered(cov_words[0]), u)
            new_words = _pack_covered(newly)
            gain = jax.lax.psum(_popcount(new_words).sum(dtype=jnp.int32), ax)
            return (cov_words[0] | new_words)[None], gain
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf, buf, P()),
            out_specs=(buf, P()))(flat, ids, valid, cov_words, u)

    # -- variant programs (weighted Occur / candidate mask / cost-ratio /
    # group budgets) — the generalized Alg. 7 all four IM variants share.
    # Plain problems never route here (they keep the bit-identical fast
    # paths above); every variant knob composes inside one scan.

    def _variant_locals(weighted):
        """Shared scan body pieces for the fused/bitset variant programs."""

        def occur_init(flat, ids, valid, ew, *, n, num_rows):
            if weighted:
                ew_l = jnp.where(valid, ew, 0.0)
                occ = jnp.zeros(n + 1, jnp.float32).at[flat].add(
                    ew_l, mode="drop")[:n]
                # per-row weight for gains: every element of a row carries
                # the row weight, so a segment max recovers it (>= 0 floors
                # the -inf of element-less padding rows)
                roww = jnp.maximum(jax.ops.segment_max(
                    ew_l, jnp.clip(ids, 0, num_rows - 1),
                    num_segments=num_rows), 0.0)
                return occ, ew_l, roww
            occ = jnp.zeros(n + 1, jnp.int32).at[flat].add(
                valid.astype(jnp.int32), mode="drop")[:n]
            return occ, None, None

        def pick(occur, feas, costs, budget, spent, *, n, use_costs):
            """Argmax of the variant score; returns (u, ok) with u == n (the
            sentinel, matching nothing) when no feasible pick exists.  Ties
            resolve to the lowest id (jnp.argmax), exactly like the plain
            scan."""
            if use_costs:
                feas = feas & (costs <= budget - spent) & (occur > 0)
                score = jnp.where(feas, occur.astype(jnp.float32) / costs,
                                  -jnp.inf)
                best = jnp.argmax(score).astype(jnp.int32)
                ok = score[best] > -jnp.inf
            else:
                zero = jnp.float32(-1.0) if weighted else jnp.int32(-1)
                masked = jnp.where(feas, occur, zero)
                best = jnp.argmax(masked).astype(jnp.int32)
                ok = masked[best] >= 0
            return jnp.where(ok, best, n).astype(jnp.int32), ok

        def gain_of(newly, new_words, roww):
            if weighted:
                return jnp.where(newly, roww, 0.0).sum(dtype=jnp.float32)
            return _popcount(new_words).sum(dtype=jnp.int32)

        def dec_of(flat, ids, valid, ew_l, newly, *, n, num_rows):
            elem_newly = newly[jnp.clip(ids, 0, num_rows - 1)] & valid
            if weighted:
                return jnp.zeros(n + 1, jnp.float32).at[flat].add(
                    jnp.where(elem_newly, ew_l, 0.0), mode="drop")[:n]
            return jnp.zeros(n + 1, jnp.int32).at[flat].add(
                elem_newly.astype(jnp.int32), mode="drop")[:n]

        return occur_init, pick, gain_of, dec_of

    def _make_variant(weighted, use_bitset):
        occur_init, pick, gain_of, dec_of = _variant_locals(weighted)
        statics = ("num_rows", "n", "k_steps", "n_group", "n_groups",
                   "group_quota", "use_costs")

        def program(flat, ids, valid, nrr, wvec, m_words, ew, cand, costs,
                    budget, *, num_rows, n, k_steps, n_group, n_groups,
                    group_quota, use_costs):
            def local(flat, ids, valid, nrr, wvec, m_words, ew, cand, costs,
                      budget):
                flat, ids, valid = flat[0], ids[0], valid[0]
                ew_sh = ew[0] if weighted else None
                m = m_words[0] if use_bitset else None
                occur0, ew_l, roww = occur_init(flat, ids, valid, ew_sh,
                                                n=n, num_rows=num_rows)
                occur0 = jax.lax.psum(occur0, ax)
                nrr_tot = jax.lax.psum(nrr[0], ax)
                denom = (jax.lax.psum(wvec[0], ax) if weighted
                         else nrr_tot.astype(jnp.float32))
                group_of = jnp.arange(n, dtype=jnp.int32) // n_group

                def step(carry, _):
                    occur, cov_words, spent, gbud, picked = carry
                    # ~picked: a seed is never re-selected — once chosen its
                    # marginal is 0 forever (submodularity), so re-picking
                    # could only pad the result with duplicates (the plain
                    # scan tolerates that; the variant result must not)
                    feas = (gbud[group_of] > 0) & cand & ~picked
                    u, ok = pick(occur, feas, costs, budget, spent,
                                 n=n, use_costs=use_costs)
                    covered = _unpack_covered(cov_words)
                    if use_bitset:
                        col = m[:, jnp.minimum(u >> 5, m.shape[1] - 1)]
                        hit = ((col >> (u & 31).astype(jnp.uint32))
                               & jnp.uint32(1)) != 0
                        newly = hit & ~covered & (u < n)
                    else:
                        newly = _newly_rows(flat, ids, valid, covered, u)
                    new_words = _pack_covered(newly)
                    gain = jax.lax.psum(gain_of(newly, new_words, roww), ax)
                    dec = jax.lax.psum(
                        dec_of(flat, ids, valid, ew_l, newly,
                               n=n, num_rows=num_rows), ax)
                    if use_costs:
                        spent = spent + jnp.where(
                            ok, costs[jnp.minimum(u, n - 1)], 0.0)
                    gbud = gbud.at[jnp.where(ok, u // n_group, n_groups)].add(
                        -1, mode="drop")
                    picked = picked.at[u].set(True, mode="drop")
                    occur = occur - dec
                    if weighted:
                        # f32 decrement chains can drift a saturated node's
                        # marginal to ~-1ulp; clamping keeps the feasibility
                        # test (occur >= 0 / > 0) aligned with CELF's fresh
                        # exact sums, which are never negative
                        occur = jnp.maximum(occur, 0.0)
                    return ((occur, cov_words | new_words, spent, gbud,
                             picked), (u, gain))

                cov0 = pvary(jnp.zeros(num_rows // 32, jnp.uint32), ax)
                carry0 = (occur0, cov0, jnp.float32(0.0),
                          jnp.full((n_groups,), group_quota, jnp.int32),
                          jnp.zeros(n, bool))
                (_, _, spent, _, _), (seeds, gains) = jax.lax.scan(
                    step, carry0, None, length=k_steps)
                frac = (gains.sum(dtype=gains.dtype)
                        / jnp.maximum(denom, jnp.float32(1e-30))
                        ).astype(jnp.float32)
                return seeds, gains, frac, spent

            dummy = P()
            return shard_map_unchecked(
                local, mesh=mesh,
                in_specs=(buf, buf, buf, vec,
                          vec if weighted else dummy,
                          b3 if use_bitset else dummy,
                          buf if weighted else dummy,
                          dummy, dummy, dummy),
                out_specs=(P(), P(), P(), P()))(
                flat, ids, valid, nrr, wvec, m_words, ew, cand, costs,
                budget)

        return jax.jit(program, static_argnames=statics)

    fused_variant = _make_variant(weighted=False, use_bitset=False)
    fused_variant_w = _make_variant(weighted=True, use_bitset=False)
    bitset_variant = _make_variant(weighted=False, use_bitset=True)
    bitset_variant_w = _make_variant(weighted=True, use_bitset=True)

    @functools.partial(jax.jit, static_argnames=(
        "num_rows", "n", "k_max", "n_group", "n_groups"))
    def stacked(flat, ids, valid, nrr, cand, costs, budget, ks, quota,
                plain, use_costs, *, num_rows, n, k_max, n_group, n_groups):
        """R selections in ONE padded scan over the shared pool (serving's
        batched-selection path).

        Per scan step a vmapped body picks one node per request — the plain
        unmasked argmax for ``plain`` rows, the variant score (candidate
        mask, group budgets, optional cost ratio) otherwise — then the
        per-request newly-covered rows, gain and Occur decrement are
        computed shard-locally and the stacked ``(R, n)`` decrement /
        ``(R,)`` gain arrays are psum-reduced in one collective each, so
        the per-step collective count does not grow with R.  Every
        per-request expression (pick, tie-break, gain popcount, decrement
        scatter, spent/group-budget updates, the final frac division)
        mirrors :func:`fused` / the variant scan verbatim; inactive steps
        (``t >= ks[r]``) emit the sentinel ``u == n`` which matches no pool
        element and mutates nothing — so each row of the output is
        bit-identical to the solo program at any mesh width.
        """
        def local(flat, ids, valid, nrr, cand, costs, budget, ks, quota,
                  plain, use_costs):
            flat, ids, valid = flat[0], ids[0], valid[0]
            occur0 = jnp.zeros(n + 1, jnp.int32).at[flat].add(
                valid.astype(jnp.int32), mode="drop")[:n]
            occur0 = jax.lax.psum(occur0, ax)
            nrr_tot = jax.lax.psum(nrr[0], ax)
            r_count = ks.shape[0]
            group_of = jnp.arange(n, dtype=jnp.int32) // n_group

            def step(carry, t):
                occur, cov, spent, gbud, picked = carry

                def pick_one(occ_r, spent_r, gbud_r, picked_r, cand_r,
                             costs_r, budget_r, plain_r, usec_r, k_r):
                    active = t < k_r
                    u_plain = jnp.argmax(occ_r).astype(jnp.int32)
                    feas = (gbud_r[group_of] > 0) & cand_r & ~picked_r
                    feas_c = feas & (costs_r <= budget_r - spent_r) \
                        & (occ_r > 0)
                    score = jnp.where(
                        feas_c, occ_r.astype(jnp.float32) / costs_r,
                        -jnp.inf)
                    best_c = jnp.argmax(score).astype(jnp.int32)
                    ok_c = score[best_c] > -jnp.inf
                    masked = jnp.where(feas, occ_r, jnp.int32(-1))
                    best_m = jnp.argmax(masked).astype(jnp.int32)
                    ok_m = masked[best_m] >= 0
                    ok_v = jnp.where(usec_r, ok_c, ok_m)
                    u_var = jnp.where(
                        ok_v, jnp.where(usec_r, best_c, best_m),
                        jnp.int32(n))
                    u = jnp.where(plain_r, u_plain, u_var)
                    ok = jnp.where(plain_r, True, ok_v) & active
                    return jnp.where(active, u, jnp.int32(n)), ok

                u, ok = jax.vmap(pick_one)(
                    occur, spent, gbud, picked, cand, costs, budget,
                    plain, use_costs, ks)

                def cover_one(cov_r, u_r):
                    newly = _newly_rows(flat, ids, valid,
                                        _unpack_covered(cov_r), u_r)
                    new_words = _pack_covered(newly)
                    g_loc = _popcount(new_words).sum(dtype=jnp.int32)
                    elem_newly = newly[jnp.clip(ids, 0, num_rows - 1)] \
                        & valid
                    dec_loc = jnp.zeros(n + 1, jnp.int32).at[flat].add(
                        elem_newly.astype(jnp.int32), mode="drop")[:n]
                    return cov_r | new_words, g_loc, dec_loc

                cov, g_loc, dec_loc = jax.vmap(cover_one)(cov, u)
                gain = jax.lax.psum(g_loc, ax)
                dec = jax.lax.psum(dec_loc, ax)
                rows = jnp.arange(r_count)
                spent = spent + jnp.where(
                    ok & use_costs, costs[rows, jnp.minimum(u, n - 1)], 0.0)
                gbud = gbud.at[rows, jnp.where(ok, u // n_group,
                                               n_groups)].add(
                    -1, mode="drop")
                picked = picked.at[rows, u].set(True, mode="drop")
                occur = occur - dec
                return (occur, cov, spent, gbud, picked), (u, gain)

            cov0 = pvary(jnp.zeros((r_count, num_rows // 32), jnp.uint32),
                         ax)
            carry0 = (jnp.broadcast_to(occur0, (r_count, n)), cov0,
                      jnp.zeros(r_count, jnp.float32),
                      jnp.broadcast_to(quota[:, None],
                                       (r_count, n_groups)).astype(
                                           jnp.int32),
                      jnp.zeros((r_count, n), bool))
            (_, _, spent, _, _), (seeds, gains) = jax.lax.scan(
                step, carry0, jnp.arange(k_max, dtype=jnp.int32))
            seeds, gains = seeds.T, gains.T          # (R, k_max)
            gsum = gains.sum(axis=1, dtype=jnp.int32)
            # plain rows use the solo fused division (int/int); variant
            # rows the solo variant one (int over f32 denom) — IEEE-equal
            # for any sampled pool, but kept distinct for exact bit-parity
            frac = jnp.where(
                plain, gsum / jnp.maximum(nrr_tot, 1),
                gsum / jnp.maximum(nrr_tot.astype(jnp.float32),
                                   jnp.float32(1e-30))).astype(jnp.float32)
            return seeds, gains, frac, spent

        return shard_map_unchecked(
            local, mesh=mesh,
            in_specs=(buf, buf, buf, vec,
                      P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(), P(), P(), P()))(
            flat, ids, valid, nrr, cand, costs, budget, ks, quota,
            plain, use_costs)

    @functools.partial(jax.jit, static_argnames=("n",))
    def occur_weighted(flat, valid, ew, *, n):
        """Weighted Occur histogram (CELF's upper-bound init): one
        psum-reduced scatter-add of the element weights."""
        def local(flat, valid, ew):
            h = jnp.zeros(n + 1, jnp.float32).at[flat[0]].add(
                jnp.where(valid[0], ew[0], 0.0), mode="drop")[:n]
            return jax.lax.psum(h, ax)
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf),
            out_specs=P())(flat, valid, ew)

    @functools.partial(jax.jit, static_argnames=("num_rows",))
    def row_weights(ids, valid, ew, *, num_rows):
        """Per-shard (D, num_rows) row-weight vectors from the element
        weights — computed once per CELF selection (it only changes on
        append), then fed to ``eval_batch_w``/``apply_seed_w`` as an
        operand instead of being re-derived per call."""
        def local(ids, valid, ew):
            ew_l = jnp.where(valid[0], ew[0], 0.0)
            return jnp.maximum(jax.ops.segment_max(
                ew_l, jnp.clip(ids[0], 0, num_rows - 1),
                num_segments=num_rows), 0.0)[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf),
            out_specs=buf)(ids, valid, ew)

    @jax.jit
    def eval_batch_w(flat, ids, valid, roww, cov_words, cands):
        """Weighted twin of ``eval_batch``: per-candidate marginal *covered
        weight* (sum of row weights over newly covered rows), psum-reduced.
        Same per-shard accumulation as the weighted fused scan, so the
        celf==fused parity holds bit for bit."""
        def local(flat, ids, valid, roww, cov_words, cands):
            flat, ids, valid, roww = flat[0], ids[0], valid[0], roww[0]
            covered = _unpack_covered(cov_words[0])
            c = cands.shape[0]
            pad = (-c) % _EVAL_CHUNK
            cs = jnp.concatenate(
                [cands, jnp.full((pad,), -1, cands.dtype)]) if pad else cands

            def chunk(cc):
                newly = jax.vmap(
                    lambda u: _newly_rows(flat, ids, valid, covered, u))(cc)
                return jnp.where(newly, roww[None, :], 0.0).sum(
                    axis=1, dtype=jnp.float32)

            gains = jax.lax.map(chunk, cs.reshape(-1, _EVAL_CHUNK))
            return jax.lax.psum(gains.reshape(-1)[:c], ax)

        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf, buf, buf, P()),
            out_specs=P())(flat, ids, valid, roww, cov_words, cands)

    @jax.jit
    def apply_seed_w(flat, ids, valid, roww, cov_words, u):
        """Weighted twin of ``apply_seed``: commit + weighted gain psum."""
        def local(flat, ids, valid, roww, cov_words, u):
            flat, ids, valid = flat[0], ids[0], valid[0]
            newly = _newly_rows(flat, ids, valid,
                                _unpack_covered(cov_words[0]), u)
            new_words = _pack_covered(newly)
            gain = jax.lax.psum(
                jnp.where(newly, roww[0], 0.0).sum(dtype=jnp.float32), ax)
            return (cov_words[0] | new_words)[None], gain
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, buf, buf, buf, buf, P()),
            out_specs=(buf, P()))(flat, ids, valid, roww, cov_words, u)

    @jax.jit
    def total_weight(wvec):
        """psum of the per-shard valid-row weight sums (weighted F_R
        denominator), as a replicated device scalar."""
        def local(wvec):
            return jax.lax.psum(wvec[0], ax)
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(vec,), out_specs=P())(wvec)

    @functools.partial(jax.jit, static_argnames=("stripe", "interpret"))
    def sweep(sk, cov_sk, *, stripe, interpret=None):
        """Δocc lower bounds for every node in one mesh-parallel sweep:
        each device scores its contiguous stripe of candidates against its
        sketch replica; one psum of the disjoint stripes yields the full
        replicated vector (the sketch sweep is embarrassingly parallel).
        ``interpret`` must be resolved by the caller outside the trace —
        it picks the popcount algorithm (kernel vs SWAR fallback)."""
        def local(sk, cov):
            i = jax.lax.axis_index(ax)
            g = sketch_mod.union_gains_stripe(
                sk[0], cov[0], i * stripe, stripe, interpret=interpret)
            full = jax.lax.dynamic_update_slice(
                jnp.zeros(sk.shape[1], jnp.int32), g, (i * stripe,))
            return jax.lax.psum(full, ax)
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(b3, buf),
            out_specs=P())(sk, cov_sk)

    @jax.jit
    def union(cov_sk, sk, u):
        """Fold one accepted seed into every replica of the union sketch —
        the per-seed psum-OR of k/32 words (zero-cost here: replicas are
        identical, so each shard ORs its own copy)."""
        def local(cov, sk, u):
            return (cov[0] | sk[0, u])[None]
        return shard_map_unchecked(
            local, mesh=mesh, in_specs=(buf, b3, P()),
            out_specs=buf)(cov_sk, sk, u)

    class Fns:
        pass

    fns = Fns()
    fns.fused = fused
    fns.bitset = bitset
    fns.occur = occur
    fns.eval_batch = eval_batch
    fns.apply_seed = apply_seed
    fns.sweep = sweep
    fns.union = union
    fns.fused_variant = fused_variant
    fns.fused_variant_w = fused_variant_w
    fns.bitset_variant = bitset_variant
    fns.bitset_variant_w = bitset_variant_w
    fns.occur_weighted = occur_weighted
    fns.row_weights = row_weights
    fns.eval_batch_w = eval_batch_w
    fns.apply_seed_w = apply_seed_w
    fns.total_weight = total_weight
    fns.stacked = stacked
    return fns


def select_seeds_device(store: "ShardedDeviceRRStore", k: int,
                        method: str = "auto") -> CoverageResult:
    """Fused greedy selection directly on a :class:`ShardedDeviceRRStore`.

    ``method``: ``"flat"`` (scatter decrement, optimal for sparse RR pools),
    ``"bitset"`` (Pallas bit-matrix path, optimal for dense pools), or
    ``"auto"`` — bitset iff the per-shard bit matrix is no larger than the
    per-shard flat capacity buffers it replaces (i.e. mean RR size ≳ n/32).
    Everything stays on the mesh; the returned ``frac`` uses the psum of
    the per-shard device row counts, so the call is legal under
    ``jax.transfer_guard("disallow")`` on a mesh of any size.
    """
    fns = _mesh_select_fns(store.mesh)
    num_rows = store.row_capacity()
    if method == "auto":
        n_words = (store.n_nodes + 31) // 32
        method = "bitset" if num_rows * n_words <= store.capacity else "flat"
    if method == "flat":
        seeds, gains, frac = fns.fused(
            store._flat, store._ids, store._valid, store.n_rr_dev,
            num_rows=num_rows, n=store.n_nodes, k=k)
    elif method == "bitset":
        seeds, gains, frac = fns.bitset(store.bitset_matrix(),
                                        store.n_rr_dev, k=k)
    else:
        raise ValueError(f"unknown selection method {method!r}")
    return CoverageResult(seeds=seeds, gains=gains, frac=frac)


class SelectionSpec(NamedTuple):
    """Variant knobs for the generalized Alg. 7 (host-side; numpy arrays).

    ``n_group``/``n_groups``/``group_quota`` express partition-budget
    constraints over the item space (MRIM: groups are rounds, quota is the
    per-round k; plain variants: one group of quota ``k_steps``).  ``cand``
    masks the argmax to a candidate set; ``costs``+``budget`` switch the
    greedy to cost-ratio (argmax marginal-gain/cost among affordable
    nodes); ``weighted`` reads the store's per-row weights into Occur and
    the gains (the importance-weighted estimator).  Plain top-k problems
    never build a spec — they keep the untouched bit-identical fast paths.
    """
    k_steps: int                       # scan length / max seeds
    n_group: int                       # group width over the item space
    n_groups: int = 1
    group_quota: int = 1
    cand: object = None                # (n_items,) bool or None
    costs: object = None               # (n_items,) float32 or None
    budget: object = None              # float or None
    weighted: bool = False


class VariantResult(NamedTuple):
    """CoverageResult + the budget actually spent.  ``seeds`` may contain
    the sentinel ``n_items`` on steps where no feasible pick existed
    (budget exhausted) — callers trim them (gain 0, no state change)."""
    seeds: jnp.ndarray
    gains: jnp.ndarray    # int32 rows covered, or float32 covered weight
    frac: jnp.ndarray     # () float32 — covered rows (or weight) fraction
    spent: jnp.ndarray    # () float32 — total cost of the picked seeds


def _spec_operands(store: "ShardedDeviceRRStore", spec: SelectionSpec):
    """Normalize a spec's host arrays into replicated device operands (+
    defaults for the unused slots — explicit device_puts, guard-legal)."""
    n = store.n_nodes
    rep = store._sh_rep
    cand = jax.device_put(
        np.ones(n, bool) if spec.cand is None else
        np.asarray(spec.cand, bool), rep)
    costs = jax.device_put(
        np.ones(n, np.float32) if spec.costs is None else
        np.asarray(spec.costs, np.float32), rep)
    budget = jax.device_put(
        np.float32(np.inf if spec.budget is None else spec.budget), rep)
    return cand, costs, budget


def select_variant(store: "ShardedDeviceRRStore", spec: SelectionSpec,
                   method: str = "flat") -> VariantResult:
    """Generalized greedy (weighted / candidate-masked / cost-ratio /
    group-budgeted) over the sharded pool — the scan twin of
    :func:`select_seeds_device` for non-plain :class:`SelectionSpec`.

    Runs as the same shard_map protocol as the plain backends (Occur psum,
    replicated argmax, shard-local Covered), so results are bit-identical
    across mesh sizes whenever the weight sums are exact in float32 (always
    for unweighted specs; for weighted ones use integer-valued weights if
    bit-parity across meshes matters — float psum association differs).
    """
    if spec.weighted and store._ew is None:
        raise ValueError("weighted selection needs a row_weighted store")
    fns = _mesh_select_fns(store.mesh)
    num_rows = store.row_capacity()
    n = store.n_nodes
    cand, costs, budget = _spec_operands(store, spec)
    dummy = jax.device_put(np.zeros(1, np.float32), store._sh_rep)
    wvec = store._w_dev if spec.weighted else dummy
    ew = store._ew if spec.weighted else dummy
    if method == "auto":
        method = "flat"
    if method == "bitset":
        m_words = store.bitset_matrix()
        program = (fns.bitset_variant_w if spec.weighted
                   else fns.bitset_variant)
    elif method == "flat":
        m_words = dummy
        program = (fns.fused_variant_w if spec.weighted
                   else fns.fused_variant)
    else:
        raise ValueError(f"unknown selection method {method!r}")
    seeds, gains, frac, spent = program(
        store._flat, store._ids, store._valid, store.n_rr_dev, wvec,
        m_words, ew, cand, costs, budget,
        num_rows=num_rows, n=n, k_steps=spec.k_steps,
        n_group=spec.n_group, n_groups=spec.n_groups,
        group_quota=spec.group_quota,
        use_costs=spec.budget is not None)
    return VariantResult(seeds=seeds, gains=gains, frac=frac, spent=spent)


class StackedRequest(NamedTuple):
    """One request's selection knobs inside a stacked batch (host-side).

    ``plain`` rows replay the unmasked plain scan (duplicates tolerated,
    like :func:`select_seeds_device`); variant rows carry the
    candidate-mask / costs / budget / group-quota knobs of a
    :class:`SelectionSpec`.  The group geometry (``n_group``/``n_groups``)
    is batch-level — it derives from ``t_rounds``, which is part of the
    pool signature, so one stacked batch can only ever see one geometry.
    """
    k_steps: int
    plain: bool = True
    cand: object = None                # (n_items,) bool or None
    costs: object = None               # (n_items,) float32 or None
    budget: object = None              # float or None
    quota: int = 0                     # group quota; 0 -> k_steps


class StackedResult(NamedTuple):
    """Device outputs of :func:`select_seeds_stacked` — row r of each array
    is bit-identical to the solo program's output for request r.  Rows are
    padded to ``n_requests <= seeds.shape[0]`` and columns to a pow2
    ``k_max``; callers slice ``[r, :k_steps_r]`` and trim the ``n_items``
    sentinel exactly as for :class:`VariantResult`."""
    seeds: jnp.ndarray    # (R_pad, k_max) int32
    gains: jnp.ndarray    # (R_pad, k_max) int32
    frac: jnp.ndarray     # (R_pad,) float32
    spent: jnp.ndarray    # (R_pad,) float32
    n_requests: int


def select_seeds_stacked(store: "ShardedDeviceRRStore",
                         reqs: "list[StackedRequest]", *,
                         n_group: int | None = None,
                         n_groups: int = 1) -> StackedResult:
    """Batched selection: R mixed (k, candidates, variant) requests in ONE
    padded scan over the shared pool instead of R sequential scans.

    The request count and scan length are padded to powers of two (dummy
    rows run zero active steps), so serving traffic compiles O(log) stacked
    program variants per pool shape rather than one per batch composition.
    Guard-legal: operands go up as explicit replicated device_puts, outputs
    stay on device.  Row-weighted stores are not stackable — the weighted
    estimator changes the Occur dtype per request; callers route those to
    the solo path.
    """
    if store.row_weighted:
        raise ValueError("stacked selection does not support row-weighted "
                         "stores — route weighted requests to the solo path")
    if not reqs:
        raise ValueError("select_seeds_stacked needs at least one request")
    n = store.n_nodes
    if n_group is None:
        n_group = n
    fns = _mesh_select_fns(store.mesh)
    r_pad = _ceil_pow2(len(reqs))
    k_max = _ceil_pow2(max(max(r.k_steps for r in reqs), 1))
    cand = np.ones((r_pad, n), bool)
    costs = np.ones((r_pad, n), np.float32)
    budget = np.full(r_pad, np.inf, np.float32)
    ks = np.zeros(r_pad, np.int32)
    quota = np.zeros(r_pad, np.int32)
    plain = np.ones(r_pad, bool)
    use_costs = np.zeros(r_pad, bool)
    for i, r in enumerate(reqs):
        ks[i] = r.k_steps
        quota[i] = r.quota if r.quota else r.k_steps
        plain[i] = r.plain
        use_costs[i] = r.budget is not None
        if r.cand is not None:
            cand[i] = np.asarray(r.cand, bool)
        if r.costs is not None:
            costs[i] = np.asarray(r.costs, np.float32)
        if r.budget is not None:
            budget[i] = np.float32(r.budget)
    rep = store._sh_rep
    ops = [jax.device_put(x, rep)
           for x in (cand, costs, budget, ks, quota, plain, use_costs)]
    seeds, gains, frac, spent = fns.stacked(
        store._flat, store._ids, store._valid, store.n_rr_dev, *ops,
        num_rows=store.row_capacity(), n=n, k_max=k_max,
        n_group=n_group, n_groups=n_groups)
    return StackedResult(seeds=seeds, gains=gains, frac=frac, spent=spent,
                         n_requests=len(reqs))


def select_seeds_celf(store: "ShardedDeviceRRStore", k: int, *,
                      eval_batch: int = 32, use_sketch: bool = True,
                      spec: SelectionSpec | None = None,
                      stats_out: dict | None = None) -> CoverageResult:
    """CELF lazy greedy selection with sketch-first candidate ordering.

    The fused scan pays one full O(elements) pool pass per argmax round.
    Here marginal gains are *lazily* verified: a host priority array holds
    each node's last exact marginal gain (initialized from the exact Occur
    histogram) — a valid upper bound under submodularity — and per seed only
    the candidates that could still win are re-evaluated exactly, in batches
    of ``eval_batch``.  The packed per-node coverage sketch
    (``core/sketch.py``) orders that verification: its union-estimate Δocc
    (one mesh-parallel popcount sweep over all nodes) is a certified *lower*
    bound on the marginal gain, so the likeliest winners are verified first
    and acceptance usually triggers on the first pop.

    On a multi-device mesh, exact re-evaluation shards over the pool like
    the fused scan (each device scans its local rows; per-shard counts are
    psum-reduced), the sweep stripes candidates across devices, and the
    union sketch is one psum-OR of k/32 words per accepted seed — so the
    backend accepts the same sharded pool views as the other two.

    Correctness is structural, not statistical: a candidate is accepted only
    when its freshly-computed exact gain is ≥ every remaining upper bound
    (ties resolved to the lowest node id, matching ``jnp.argmax``), so the
    returned seeds are *identical* to the fused-scan path for any sketch
    size and any mesh size — the sketch only changes how many exact
    evaluations happen.  With ``sketch_k >= n_rr`` (mod bucketing) the
    estimates are themselves exact and one verification batch per seed
    suffices.  The (1−1/e−ε) guarantee of Alg. 2 is therefore preserved
    verbatim.

    All device interaction is explicit (``device_put``/``device_get``), so
    the call is legal under ``jax.transfer_guard("disallow")``; shapes are
    the pool's capacity buffers (compiles only at doublings, like the fused
    path) plus the fixed-size sketch.

    ``spec`` switches to the generalized variant loop (weighted gains,
    candidate mask, cost-ratio lazy greedy, group budgets) — see
    :func:`_celf_variant`; the plain path below is untouched.
    """
    if spec is not None:
        return _celf_variant(store, spec, eval_batch=eval_batch,
                             use_sketch=use_sketch, stats_out=stats_out)
    n = store.n_nodes
    num_rows = store.row_capacity()
    nw = num_rows // 32
    d = store.n_shards
    fns = _mesh_select_fns(store.mesh)
    flat, ids, valid = store._flat, store._ids, store._valid
    c = max(1, min(eval_batch, n))

    ub = np.asarray(jax.device_get(
        fns.occur(flat, valid, n=n)), dtype=np.int64).copy()
    fresh = np.zeros(n, bool)
    # explicit placement: plain jnp.zeros is an implicit h2d transfer and
    # would trip the solver's transfer_guard("disallow")
    cov_words = jax.device_put(np.zeros((d, nw), np.uint32), store._sh_buf)
    if use_sketch:
        sk_words = store.sketch_words_mesh()
        sk_k = int(sk_words.shape[2]) * 32
        stripe = store.sketch_rows // d
        itp = kops.resolve_interpret(None)
        cov_sk = jax.device_put(
            np.zeros((d, sk_words.shape[2]), np.uint32), store._sh_buf)
    n_evals = 0
    n_eval_calls = 0
    node_ids = np.arange(n)

    def eval_exact(cands):
        nonlocal n_evals, n_eval_calls
        cands = np.asarray(cands, np.int32)
        pad = np.full(c, -1, np.int32)
        pad[:len(cands)] = cands
        g = np.asarray(jax.device_get(fns.eval_batch(
            flat, ids, valid, cov_words,
            jax.device_put(pad, store._sh_rep))))
        ub[cands] = g[:len(cands)]
        fresh[cands] = True
        n_evals += len(cands)
        n_eval_calls += 1

    seeds, gains = [], []
    for _ in range(k):
        fresh[:] = False
        if use_sketch:
            # sketch sweep: Δocc lower bounds for every node in one
            # mesh-parallel pass; verify the likeliest winners exactly
            # before entering the lazy loop (O(n) top-c selection —
            # eval-batch composition affects only the eval count, never
            # the accepted seed)
            deltas = np.asarray(jax.device_get(
                fns.sweep(sk_words, cov_sk, stripe=stripe, interpret=itp)))[:n]
            key = deltas.astype(np.int64) * (n + 1) - node_ids
            eval_exact(np.argpartition(-key, c - 1)[:c])
        while True:
            u = int(np.argmax(ub))       # first max == lowest id on ties
            if fresh[u]:
                break
            # verify the c highest-bound stale candidates, lowest id first
            # on ties (they are the ones that block acceptance).  Composite
            # int64 key keeps this O(n) — ub <= n_rr and id < n both fit
            # int32, so ub*(n+1) - id cannot overflow.  The set always
            # contains the stale argmax, so the loop makes progress.
            stale_idx = node_ids[~fresh]
            cc = min(c, len(stale_idx))
            key = ub[stale_idx] * (n + 1) - stale_idx
            eval_exact(stale_idx[np.argpartition(-key, cc - 1)[:cc]])
        u_dev = jax.device_put(np.int32(u), store._sh_rep)
        cov_words, gain_dev = fns.apply_seed(flat, ids, valid, cov_words,
                                             u_dev)
        if use_sketch:
            cov_sk = fns.union(cov_sk, sk_words, u_dev)
        gain = int(jax.device_get(gain_dev))
        ub[u] = 0                        # exact: u's rows are now covered
        seeds.append(u)
        gains.append(gain)

    if stats_out is not None:
        stats_out.update(n_exact_evals=n_evals, n_eval_calls=n_eval_calls,
                         sketch_k=(sk_k if use_sketch else 0),
                         n_rr=store.n_rr)
    frac = sum(gains) / max(store.n_rr, 1)
    return CoverageResult(
        seeds=jax.device_put(np.asarray(seeds, np.int32)),
        gains=jax.device_put(np.asarray(gains, np.int32)),
        frac=jax.device_put(np.float32(frac)))


def select_seeds_sketch(store, k: int, *, cand=None,
                        info_out: dict | None = None) -> CoverageResult:
    """Greedy selection on sketch estimates alone — no exact verification.

    The approximate-mode (pool-free) selection path: per seed one
    mesh-parallel Δocc sweep over all candidates (striped across devices,
    psum of disjoint int32 stripes — bit-identical at any shard count), a
    host argmax (first max == lowest id, matching ``jnp.argmax``), and one
    psum-OR union fold.  No pool exists to verify against, so this is the
    documented departure from the exact-acceptance contract of
    :func:`select_seeds_celf`; what survives is a *certified* error
    estimate from linear counting, surfaced via ``info_out`` and
    ``IMResult.spread_bounds``:

    * ``lo_rows`` — Δocc never exceeds the exact marginal (new buckets need
      new rows), so the summed gains are a deterministic lower bound on the
      rows the seed set covers.
    * ``hi_rows`` — the linear-counting estimate widened by the z-sigma
      relative StdErr at the realized load
      (:func:`~repro.core.sketch.linear_count_rel_error`); on a
      *saturated* union row the estimate carries no information beyond its
      k·ln(k) ceiling, so the upper bound widens to all of ``n_rr`` rather
      than reporting a silently-finite estimate.

    **Exact regime:** with ``"mod"`` bucketing and ``n_rr <= sketch_k`` the
    bucketing is injective, Δocc *is* the exact marginal gain, and the
    seeds are bit-identical to the fused scan (ties to lowest id in both;
    a zero-gain argmax is still picked, matching the scan's fixed-length
    behavior).  The estimate is then ``occ_union`` itself, error 0.

    Works on any store exposing the sketch surface (``SketchRRStore`` or a
    sketch-maintaining ``ShardedDeviceRRStore``).  ``cand`` optionally
    masks selection to a candidate set.
    """
    n = store.n_nodes
    d = store.n_shards
    fns = _mesh_select_fns(store.mesh)
    sk_words = store.sketch_words_mesh()
    sk_k = int(sk_words.shape[2]) * 32
    stripe = store.sketch_rows // d
    itp = kops.resolve_interpret(None)
    cov_sk = jax.device_put(
        np.zeros((d, sk_words.shape[2]), np.uint32), store._sh_buf)
    mask = (np.ones(n, bool) if cand is None
            else np.asarray(cand, bool)[:n].copy())
    n_rr = store.n_rr
    seeds, gains = [], []
    for _ in range(k):
        deltas = np.asarray(jax.device_get(
            fns.sweep(sk_words, cov_sk, stripe=stripe, interpret=itp)))[:n].astype(np.int64)
        score = np.where(mask, deltas, -1)
        u = int(np.argmax(score))        # first max == lowest id on ties
        if score[u] < 0:                 # no feasible candidate left
            break
        seeds.append(u)
        gains.append(int(deltas[u]))
        mask[u] = False
        cov_sk = fns.union(cov_sk, sk_words,
                           jax.device_put(np.int32(u), store._sh_rep))
    occ_union = int(sum(gains))
    exact_regime = (store.sketch_mode == "mod" and n_rr <= sk_k)
    if exact_regime:
        est_rows, lo_rows, hi_rows = float(occ_union), occ_union, occ_union
        saturated, rel_err = False, 0.0
    else:
        est_arr, sat_arr = sketch_mod.linear_count_saturated(
            [occ_union], sk_k)
        saturated = bool(sat_arr[0])
        est_rows = min(float(est_arr[0]), float(n_rr))
        rel_err = float(np.asarray(
            sketch_mod.linear_count_rel_error(est_arr, sk_k))[0])
        lo_rows = min(occ_union, n_rr)   # certified: Δocc <= exact marginal
        hi_rows = (n_rr if saturated
                   else min(float(n_rr), est_rows * (1.0 + rel_err)))
    if info_out is not None:
        info_out.update(occ_union=occ_union, est_rows=est_rows,
                        lo_rows=lo_rows, hi_rows=hi_rows,
                        saturated=saturated, rel_error=rel_err,
                        exact_regime=exact_regime, sketch_k=sk_k, n_rr=n_rr)
    # pad to k with the sentinel item (trimmed by the solver's live mask),
    # matching the fixed-length contract of the device backends
    pad = [n] * (k - len(seeds))
    frac = est_rows / max(n_rr, 1)
    return CoverageResult(
        seeds=jax.device_put(np.asarray(seeds + pad, np.int32)),
        gains=jax.device_put(np.asarray(gains + [0] * len(pad), np.int32)),
        frac=jax.device_put(np.float32(frac)))


def _celf_variant(store: "ShardedDeviceRRStore", spec: SelectionSpec, *,
                  eval_batch: int = 32, use_sketch: bool = True,
                  stats_out: dict | None = None) -> VariantResult:
    """CELF lazy greedy generalized to the variant spec.

    The acceptance logic is the plain path's, applied to the variant score
    (``ub`` for cardinality specs, ``ub/cost`` for budgeted ones, both
    masked to feasible candidates): a node is accepted only when its
    *fresh* exact score is the argmax of all remaining upper-bound scores
    (ties -> lowest id), so the returned seeds are identical to
    :func:`select_variant`'s fused scan for any sketch size — submodularity
    makes ``ub >= exact`` an invariant, and positive costs preserve it
    under division.  Feasibility (candidate mask, group budgets, remaining
    budget) only ever shrinks, so masked-out nodes never need their bounds
    refreshed.

    Weighted caveat: the fused scan maintains Occur by f32 decrement chains
    while CELF re-sums fresh gains, so with *fractional* row weights the
    two can disagree on ulp-level near-ties (the scan clamps drift at 0, so
    seed counts still match); weights whose partial sums are exact in
    float32 — integer-valued weights below 2^24 — make the parity exact,
    and are what the conformance suite pins.
    """
    if spec.weighted and store._ew is None:
        raise ValueError("weighted selection needs a row_weighted store")
    n = store.n_nodes
    num_rows = store.row_capacity()
    nw = num_rows // 32
    d = store.n_shards
    fns = _mesh_select_fns(store.mesh)
    flat, ids, valid = store._flat, store._ids, store._valid
    c = max(1, min(eval_batch, n))
    weighted = spec.weighted
    use_costs = spec.budget is not None
    # costs/budget bookkeeping in float32, mirroring the fused scan's
    # device arithmetic exactly (same rounding -> same feasibility set and
    # the same cost-ratio ordering, keeping the celf==fused seed contract)
    costs = (np.asarray(spec.costs, np.float32) if spec.costs is not None
             else np.ones(n, np.float32))
    cand = (np.asarray(spec.cand, bool) if spec.cand is not None
            else np.ones(n, bool))
    group_of = np.arange(n) // spec.n_group
    gbud = np.full(spec.n_groups, spec.group_quota, np.int64)
    budget32 = np.float32(spec.budget) if use_costs else np.float32(np.inf)
    spent32 = np.float32(0.0)

    if weighted:
        ub = np.asarray(jax.device_get(fns.occur_weighted(
            flat, valid, store._ew, n=n)), np.float64).copy()
        denom = float(jax.device_get(fns.total_weight(store._w_dev)))
        roww_dev = fns.row_weights(ids, valid, store._ew, num_rows=num_rows)
    else:
        ub = np.asarray(jax.device_get(
            fns.occur(flat, valid, n=n)), np.float64).copy()
        denom = float(max(store.n_rr, 1))
    fresh = np.zeros(n, bool)
    cov_words = jax.device_put(np.zeros((d, nw), np.uint32), store._sh_buf)
    if use_sketch:
        sk_words = store.sketch_words_mesh()
        sk_k = int(sk_words.shape[2]) * 32
        stripe = store.sketch_rows // d
        itp = kops.resolve_interpret(None)
        cov_sk = jax.device_put(
            np.zeros((d, sk_words.shape[2]), np.uint32), store._sh_buf)
    n_evals = 0
    n_eval_calls = 0
    node_ids = np.arange(n)

    def eval_exact(cands):
        nonlocal n_evals, n_eval_calls
        cands = np.asarray(cands, np.int32)
        pad = np.full(c, -1, np.int32)
        pad[:len(cands)] = cands
        pad_dev = jax.device_put(pad, store._sh_rep)
        if weighted:
            g = np.asarray(jax.device_get(fns.eval_batch_w(
                flat, ids, valid, roww_dev, cov_words, pad_dev)))
        else:
            g = np.asarray(jax.device_get(fns.eval_batch(
                flat, ids, valid, cov_words, pad_dev)))
        ub[cands] = g[:len(cands)]
        fresh[cands] = True
        n_evals += len(cands)
        n_eval_calls += 1

    def scores(feas):
        if use_costs:
            # float32 division, bit-identical to the device scan's
            # occur.astype(f32) / costs (ub holds exact gains: int counts
            # or f32-representable weighted sums, so the f32 cast is exact)
            return np.where(feas & (ub > 0),
                            ub.astype(np.float32) / costs, -np.inf)
        return np.where(feas, ub, -np.inf)

    def top_stale(feas, sc, k_top):
        """Highest-score stale feasible candidates, lowest id first on
        ties (float scores -> lexsort instead of the plain path's int
        composite key)."""
        idx = node_ids[~fresh & feas & (sc > -np.inf)]
        order = np.lexsort((idx, -sc[idx]))
        return idx[order[:k_top]]

    seeds, gains_out = [], []
    picked = np.zeros(n, bool)
    for _ in range(spec.k_steps):
        # ~picked mirrors the fused variant scan: seeds are never
        # re-selected (their marginal is 0 forever under submodularity)
        feas = cand & (gbud[group_of] > 0) & ~picked
        if use_costs:
            feas = feas & (costs <= budget32 - spent32)
        if not feas.any():
            break
        fresh[:] = False
        if use_sketch:
            deltas = np.asarray(jax.device_get(
                fns.sweep(sk_words, cov_sk, stripe=stripe, interpret=itp)))[:n]
            est = np.where(feas, deltas / costs if use_costs
                           else deltas.astype(np.float64), -np.inf)
            order = np.lexsort((node_ids, -est))
            eval_exact(order[:c])
        accepted = None
        while True:
            sc = scores(feas)
            u = int(np.argmax(sc))       # first max == lowest id on ties
            if sc[u] == -np.inf:
                # only reachable for budgeted specs (ub > 0 filter): every
                # remaining affordable candidate has zero gain, exactly
                # where the fused scan starts emitting sentinels.  For
                # cardinality specs feas.any() guarantees a >= 0 score
                # (ub is a non-negative coverage bound), so the lazy loop
                # always accepts — zero-gain lowest-id picks included,
                # matching the fused argmax semantics.
                break
            if fresh[u]:
                accepted = u
                break
            stale = top_stale(feas, sc, c)
            eval_exact(stale)
        if accepted is None:
            break
        u = accepted
        u_dev = jax.device_put(np.int32(u), store._sh_rep)
        if weighted:
            cov_words, gain_dev = fns.apply_seed_w(flat, ids, valid,
                                                   roww_dev, cov_words,
                                                   u_dev)
        else:
            cov_words, gain_dev = fns.apply_seed(flat, ids, valid, cov_words,
                                                 u_dev)
        if use_sketch:
            cov_sk = fns.union(cov_sk, sk_words, u_dev)
        gain = jax.device_get(gain_dev)
        ub[u] = 0.0
        picked[u] = True
        gbud[group_of[u]] -= 1
        if use_costs:
            spent32 = np.float32(spent32 + costs[u])
        seeds.append(u)
        gains_out.append(gain)

    if stats_out is not None:
        stats_out.update(n_exact_evals=n_evals, n_eval_calls=n_eval_calls,
                         sketch_k=(sk_k if use_sketch else 0),
                         n_rr=store.n_rr)
    gdtype = np.float32 if weighted else np.int32
    frac = float(np.asarray(gains_out, np.float64).sum()) / max(denom, 1e-30)
    return VariantResult(
        seeds=jax.device_put(np.asarray(seeds, np.int32)),
        gains=jax.device_put(np.asarray(gains_out, gdtype)),
        frac=jax.device_put(np.float32(frac)),
        spent=jax.device_put(np.float32(spent32 if use_costs else 0.0)))


class PaddedStore(NamedTuple):
    """2D tile layout for the Pallas membership kernel (DESIGN.md §2):
    TPU prefers rectangular VMEM tiles over the GPU's ragged flat array."""
    rows: jnp.ndarray     # (R, L) int32, padded with n
    lengths: jnp.ndarray  # (R,) int32
    n_nodes: int


def build_padded_store(rr_lists, n: int, row_len: int | None = None,
                       pad_rows_to: int = 8) -> PaddedStore:
    lens = np.asarray([len(r) for r in rr_lists], dtype=np.int64)
    l = row_len if row_len is not None else int(max(lens.max(), 1))
    l = ((l + 127) // 128) * 128                       # lane-align
    r = ((len(rr_lists) + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
    rows = np.full((r, l), n, dtype=np.int32)
    for i, rr in enumerate(rr_lists):
        if len(rr) > l:
            raise ValueError("row_len too small")
        rows[i, :len(rr)] = rr
    lengths = np.zeros(r, np.int32)
    lengths[:len(lens)] = lens
    return PaddedStore(rows=jnp.asarray(rows), lengths=jnp.asarray(lengths),
                       n_nodes=n)


def select_seeds_padded(store: PaddedStore, k: int) -> CoverageResult:
    """Greedy selection with the Pallas membership kernel as the Alg. 7 scan.

    One fused ``lax.scan`` over the k seeds (the former per-seed python loop
    unrolled k kernel launches and re-traced per call): the membership scan
    (R×L element compares per seed) runs in the kernel; Covered flags and
    the Occur decrement (scatter-add) stay in XLA, which lowers scatter
    natively on TPU.
    """
    from repro.kernels import ops as kops
    rows, lengths, n = store.rows, store.lengths, store.n_nodes
    r, l = rows.shape
    lane = jnp.arange(l, dtype=jnp.int32)[None, :]
    valid = lane < lengths[:, None]
    occur0 = jnp.zeros(n + 1, jnp.int32).at[rows].add(
        valid.astype(jnp.int32), mode="drop")[:n]

    def step(carry, _):
        occur, covered = carry
        u = jnp.argmax(occur).astype(jnp.int32)
        hit = kops.membership_rows(rows, lengths, u)
        newly = hit & ~covered
        dec = jnp.zeros(n + 1, jnp.int32).at[rows].add(
            (valid & newly[:, None]).astype(jnp.int32), mode="drop")[:n]
        return (occur - dec, covered | hit), (u, newly.sum(dtype=jnp.int32))

    _, (seeds, gains) = jax.lax.scan(step, (occur0, jnp.zeros(r, bool)),
                                     None, length=k)
    n_rr = int((lengths > 0).sum())
    return CoverageResult(seeds=seeds, gains=gains,
                          frac=(gains.sum() / jnp.maximum(n_rr, 1)
                                ).astype(jnp.float32))


def shard_stores(per_shard_rr: list[list[list[int]]], n: int) -> RRStore:
    """Stack per-device RR pools into a leading-shard-dim RRStore.

    Pads every shard to the max flat length and max row count so the arrays
    stack; ``n_rr`` becomes rows-per-shard (uniform after padding with empty
    rows, which are never covered and never matched).
    """
    n_shards = len(per_shard_rr)
    rows = max(len(p) for p in per_shard_rr)
    per_shard_rr = [p + [[]] * (rows - len(p)) for p in per_shard_rr]
    stores = [build_store(p, n) for p in per_shard_rr]
    t_max = max(int(s.rr_flat.shape[0]) for s in stores)
    stores = [build_store(p, n, pad_to=t_max) for p in per_shard_rr]
    return RRStore(
        rr_flat=jnp.stack([s.rr_flat for s in stores]),
        rr_ids=jnp.stack([s.rr_ids for s in stores]),
        valid=jnp.stack([s.valid for s in stores]),
        n_rr=rows, n_nodes=n)


# ---------------------------------------------------------------------------
# Legacy distributed variant on host-built shard stacks (pre-dates the
# mesh-native ShardedDeviceRRStore; kept for the host shard_stores API).
# ---------------------------------------------------------------------------

def select_seeds_sharded(mesh, store_shards, k: int, n: int, axis_names):
    """store_shards: RRStore pytree whose arrays carry a leading shard dim
    equal to the mesh size (one row per device); rr_ids are *local* row ids.
    Per-seed collective cost: one psum over (n,) int32 — see DESIGN.md §5.
    """
    from repro.compat import shard_map

    local_n_rr = store_shards.n_rr  # rows per shard (uniform)

    def local_fn(rr_flat, rr_ids, valid):
        rr_flat, rr_ids, valid = rr_flat[0], rr_ids[0], valid[0]
        occur = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
            valid.astype(jnp.int32), mode="drop")[:n]
        occur = jax.lax.psum(occur, axis_names)

        def step(carry, _):
            occur, covered = carry
            u = jnp.argmax(occur).astype(jnp.int32)
            match = (rr_flat == u) & valid
            row_has = jax.ops.segment_max(
                match.astype(jnp.int32), rr_ids,
                num_segments=local_n_rr + 1,
                indices_are_sorted=True)[:local_n_rr] > 0
            newly = row_has & ~covered
            elem_newly = jnp.concatenate([newly, jnp.zeros(1, bool)])[
                jnp.clip(rr_ids, 0, local_n_rr)] & valid
            dec = jnp.zeros(n + 1, jnp.int32).at[rr_flat].add(
                elem_newly.astype(jnp.int32), mode="drop")[:n]
            occur = occur - jax.lax.psum(dec, axis_names)
            gain = jax.lax.psum(newly.sum(dtype=jnp.int32), axis_names)
            return (occur, covered | row_has), (u, gain)

        covered = pvary(jnp.zeros(local_n_rr, bool), axis_names)
        (_, covered), (seeds, gains) = jax.lax.scan(
            step, (occur, covered), None, length=k)
        return seeds[None], gains[None]

    specs = P(axis_names if isinstance(axis_names, str) else tuple(axis_names))
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(specs, specs, specs),
                   out_specs=(specs, specs))
    seeds, gains = fn(store_shards.rr_flat, store_shards.rr_ids,
                      store_shards.valid)
    return seeds[0], gains[0]

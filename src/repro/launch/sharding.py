"""Sharding rules: param/state pytree -> PartitionSpec trees per family.

Scheme (single pod mesh = (data=16, model=16); multi-pod adds a leading
'pod' axis that shards only the batch — pure DP across pods):

* LM params: FSDP over 'data' + TP over 'model':
    wq/wk/wv/w_gate/w_up : (L, D, F)   -> (None, data, model)
    wo/w_down            : (L, F, D)   -> (None, model, data)
    MoE experts          : (L, E, D, F)-> (None, model(EP), data, None)
    embed                : (V, D)      -> (model, None)
  int8 optimizer states: q shards exactly like its param (shape-preserving
  quantization); block scales use the param spec with the last axis
  replicated (they are 1/block the size).
* LM batch: tokens (B, S) -> ((pod, data), None).
* decode caches: batch over (pod, data) when B > 1, else the KV sequence
  axis over (data, model) — the long-context 500k layout.
* GNN: params replicated (they are tiny vs. the graph); nodes/edges sharded
  over all mesh axes.
* recsys: embedding tables row-sharded over 'model' (EP-style), batch over
  (pod, data); retrieval candidates over all axes.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes, all_axes


# ------------------------------------------------------------- LM params

def lm_param_spec(path: str, ndim: int, dx: str | tuple, mx: str):
    """dx: FSDP axis name(s); mx: tensor axis name."""
    stacked = "block" in path

    def wrap(*spec):
        return P(None, *spec) if stacked else P(*spec)

    if "embed" in path:
        return P(mx, None)
    if "lm_head" in path:
        return P(None, mx)
    if "mtp_proj" in path:
        return P(dx, mx) if path.endswith("'w']") else P(mx)
    if "norm" in path:
        return P(*([None] * ndim))
    if "experts" in path:
        if "w_down" in path:
            return wrap(mx, None, dx)
        return wrap(mx, dx, None)          # w_gate / w_up
    if "router" in path:
        return wrap(dx, None)
    if re.search(r"w(q|k|v)'\]\['b", path) or "]['b']" in path:
        # biases: (L, F) where F followed the 'model'-sharded output dim
        if "wo" in path or "w_down" in path:
            return wrap(dx)
        return wrap(mx)
    if any(t in path for t in ("wq_down", "wq_up", "wk_up", "wv_up")):
        return wrap(dx, mx)
    if "wkv_down" in path:
        return wrap(dx, None)
    if any(t in path for t in ("wo", "w_down")):
        return wrap(mx, dx)
    if any(t in path for t in ("wq", "wk", "wv", "w_gate", "w_up")):
        return wrap(dx, mx)
    # default: replicate
    return P(*([None] * ndim))


def _strip_opt_prefix(path: str):
    """'.opt.m[...]' / '.opt.v[...][0|1]' -> (param_path, which) where
    which in {None, 'q', 'scale'}."""
    m = re.match(r"^\.opt\.(m|v)(.*)$", path)
    if not m:
        return None, None
    rest = m.group(2)
    tup = re.search(r"\[([01])\]$", rest)
    if tup:
        return rest[: tup.start()], ("q" if tup.group(1) == "0" else "scale")
    return rest, None


def lm_state_specs(state_shapes, mesh):
    """PartitionSpec pytree matching a TrainState (or bare params dict).

    FSDP axis = every batch axis: ('pod','data') on the multi-pod mesh, so
    ZeRO-3 sharding spans pods and per-chip bytes halve at 2 pods."""
    dx = data_axes(mesh)
    dx = dx[0] if len(dx) == 1 else dx
    mx = "model"
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    specs = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        ndim = len(leaf.shape)
        if path.endswith(".step") or path == ".step":
            specs.append(P())
            continue
        ppath, which = _strip_opt_prefix(path)
        if ppath is None:
            # raw param leaf (".params[...]" or a bare dict)
            spec = lm_param_spec(path, ndim, dx, mx)
        else:
            spec = lm_param_spec(ppath, ndim if which != "scale" else
                                 ndim, dx, mx)
            if which == "scale":
                spec = P(*(list(spec)[:-1] + [None])) if len(spec) else P()
        if len(spec) > ndim:
            spec = P(*list(spec)[:ndim])
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------- caches

def lm_cache_specs(cache_shapes, mesh):
    """BlockCache list: batch-shard when B>1, sequence-shard when B==1."""
    da = data_axes(mesh)

    def one(leaf):
        shape = leaf.shape            # (L, B, S, ...) or pos (L, B, S)
        b = shape[1]
        if b > 1:
            return P(None, da, *([None] * (len(shape) - 2)))
        return P(None, None, ("data", "model"),
                 *([None] * (len(shape) - 3)))

    return jax.tree_util.tree_map(one, cache_shapes)


# ------------------------------------------------------------ full cells

def cell_shardings(arch_id, shape_id, args, meta, mesh):
    """in_shardings tuple matching build_cell's args."""
    from repro.configs import registry
    fam = registry.family_of(arch_id)
    da = data_axes(mesh)
    aa = all_axes(mesh)
    kind = meta["kind"]
    if fam == "lm":
        if kind == "train":
            state, tokens = args
            return (lm_state_specs(state, mesh), P(da, None))
        if kind == "prefill":
            params, tokens = args
            return (lm_state_specs(params, mesh), P(da, None))
        params, tok, caches, pos = args
        tok_spec = P(da, None) if tok.shape[0] > 1 else P(None, None)
        return (lm_state_specs(params, mesh), tok_spec,
                lm_cache_specs(caches, mesh), P())
    if fam == "gnn":
        state = args[0]
        state_spec = jax.tree_util.tree_map(
            lambda l: P(*([None] * len(l.shape))), state)
        if shape_id == "molecule":
            # (state, xb, srcb, dstb, maskb, labels, coordsb): batch-sharded
            return (state_spec, P(da, None, None), P(da, None), P(da, None),
                    P(da, None), P(da), P(da, None, None))
        # node/edge arrays sharded over every axis
        return (state_spec, P(aa, None), P(aa), P(aa), P(aa), P(aa),
                P(aa, None))
    # recsys
    if kind == "train":
        state, ids, dx_, lb = args
        return (_deepfm_state_specs(state, mesh), P(da, None), P(da, None),
                P(da))
    if kind == "serve":
        params, ids, dx_ = args
        return (_deepfm_state_specs(params, mesh), P(da, None), P(da, None))
    return (P(), P(aa, None))    # retrieval: query replicated, cands sharded


def _deepfm_state_specs(state_shapes, mesh):
    def spec_for(path, leaf):
        ndim = len(leaf.shape)
        if "embed" in path or path.endswith("['lin']") or "'lin'" in path:
            return P("model", *([None] * (ndim - 1)))
        if path.endswith("step") :
            return P()
        return P(*([None] * ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(jax.tree_util.keystr(kp), leaf)
                  for kp, leaf in flat])

"""Statistical conformance suite: do the engines sample the right law?

Structural tests (root-first, uniqueness, reachability) cannot see a biased
sampler that emits *valid but wrongly distributed* RR sets — e.g. a dedup
micro-step that double-counts a multi-edge, or a refill lane that discards
in-flight sets (size-biased).  Here every registered engine's RR-set *size
distribution* is compared against the serial numpy oracle with a two-sample
Kolmogorov-Smirnov test on small fixed-RNG graphs.

KS on integer sizes is conservative (ties can only shrink the statistic),
so ``p > 0.01`` is a sound acceptance bar; a deliberately mismatched pair
(IC sizes vs LT sizes) is kept as a power control so the suite cannot pass
vacuously.  Engines and oracle use independent RNGs — this is a two-sample
test of laws, not a replay test.

Also here: deterministic conformance of the sampler micro-step rebuild —
segmented chunk dedup vs the sort fallback vs a dense reference on
adversarial duplicate patterns, and ``coalesce_ic`` probability equivalence
(the hypothesis-based twins live in test_properties.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from scipy import stats as sps

from repro.graph import csr as csr_mod
from repro.graph import generators, weights
from repro.core import oracle, rrset
from repro.core.engine import make_engine

P_MIN = 0.01
N_SIZES = 320


def _graph(n=30, m=150, seed=2):
    src, dst = generators.erdos_renyi(n, m, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def _engine_sizes(name, g_rev, count, *, key_seed=0, **opts):
    eng = make_engine(name, g_rev, **opts)
    sizes = []
    i = 0
    while len(sizes) < count:
        b = eng.sample(jax.random.key(key_seed + i))
        lens = np.asarray(b.lengths)
        sizes += lens[lens > 0].tolist()
        i += 1
    return np.asarray(sizes[:count])


def _oracle_sizes_ic(g_rev, count, seed=1):
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    return np.asarray([
        len(oracle.rr_set_ic(offs, idx, w, int(rng.integers(n)), rng))
        for _ in range(count)])


def _oracle_sizes_lt(g_rev, count, seed=1):
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    return np.asarray([
        len(oracle.rr_set_lt(offs, idx, w, int(rng.integers(n)), rng))
        for _ in range(count)])


def _oracle_sizes_mrim(g_rev, count, t_rounds, seed=1):
    """MRIM law: one shared root, T independent IC BFS, tagged union size ==
    sum of the per-round sizes (tags make all elements distinct)."""
    rng = np.random.default_rng(seed)
    offs = np.asarray(g_rev.offsets)
    idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    n = g_rev.n_nodes
    out = []
    for _ in range(count):
        root = int(rng.integers(n))
        out.append(sum(len(oracle.rr_set_ic(offs, idx, w, root, rng))
                       for _ in range(t_rounds)))
    return np.asarray(out)


# ----------------------------------------------- KS suite: all six engines

@pytest.mark.parametrize("engine", ("queue", "dense", "refill",
                                    "queue_sharded"))
def test_ks_ic_engines_match_oracle(engine):
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes(engine, g_rev, N_SIZES, batch=64)
    ref = _oracle_sizes_ic(g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (engine, res, sizes.mean(), ref.mean())


def test_ks_lt_engine_matches_oracle():
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes("lt", g_rev, N_SIZES, batch=64)
    ref = _oracle_sizes_lt(g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (res, sizes.mean(), ref.mean())


def test_ks_mrim_engine_matches_oracle():
    g_rev = csr_mod.reverse(_graph())
    sizes = _engine_sizes("mrim", g_rev, N_SIZES, batch=32, t_rounds=2)
    ref = _oracle_sizes_mrim(g_rev, N_SIZES, t_rounds=2)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (res, sizes.mean(), ref.mean())


@pytest.mark.parametrize("engine,model", (("queue", "ic"), ("lt", "lt")))
def test_ks_second_graph(engine, model):
    """Same laws on a denser second topology (BA attachment)."""
    src, dst = generators.barabasi_albert(40, 3, seed=7)
    g_rev = csr_mod.reverse(
        weights.wc_weights(csr_mod.from_edges(src, dst, 40)))
    sizes = _engine_sizes(engine, g_rev, N_SIZES, batch=64)
    ref = (_oracle_sizes_ic if model == "ic" else _oracle_sizes_lt)(
        g_rev, N_SIZES)
    res = sps.ks_2samp(sizes, ref)
    assert res.pvalue > P_MIN, (engine, res, sizes.mean(), ref.mean())


def test_ks_power_control_rejects_wrong_law():
    """The suite must be able to fail: IC BFS sizes vs LT walk sizes on the
    same graph are different laws and KS must reject them."""
    g_rev = csr_mod.reverse(_graph())
    ic = _oracle_sizes_ic(g_rev, N_SIZES, seed=3)
    lt = _oracle_sizes_lt(g_rev, N_SIZES, seed=4)
    res = sps.ks_2samp(ic, lt)
    assert res.pvalue < P_MIN, res


# ------------------------------- micro-step conformance (deterministic)

def _dense_first_occurrence(nbr, cand):
    """O(EC^2) reference: j accepted iff it is the first candidate position
    in its lane carrying nbr[b, j] (the historical dense mask)."""
    b, ec = nbr.shape
    out = np.zeros_like(cand)
    for i in range(b):
        seen = set()
        for j in range(ec):
            if cand[i, j] and nbr[i, j] not in seen:
                out[i, j] = True
                seen.add(nbr[i, j])
    return out


def _adversarial_chunks(rng, b=8, ec=32, n=16):
    """Duplicate-heavy chunk: long runs of repeated destinations."""
    reps = []
    for _ in range(b):
        row, v = [], 0
        while len(row) < ec:
            run = int(rng.integers(1, 6))
            row += [v] * run
            v += int(rng.integers(0, 2))     # sometimes repeat across runs
        reps.append(row[:ec])
    nbr = np.asarray(reps, np.int32) % n
    cand = rng.random((b, ec)) < 0.6
    return nbr, cand


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_dedup_segmented_matches_sort_and_dense_reference(seed):
    rng = np.random.default_rng(seed)
    nbr_np, cand_np = _adversarial_chunks(rng)
    # segmented mode requires duplicates adjacent: runs are sorted per row
    order = np.argsort(nbr_np, axis=1, kind="stable")
    nbr_np = np.take_along_axis(nbr_np, order, axis=1)
    cand_np = np.take_along_axis(cand_np, order, axis=1)
    nbr, cand = jnp.asarray(nbr_np), jnp.asarray(cand_np)
    ar = jnp.arange(nbr.shape[1], dtype=jnp.int32)
    ref = _dense_first_occurrence(nbr_np, cand_np)
    seg = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="segmented"))
    srt = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="sort"))
    np.testing.assert_array_equal(seg, ref)
    np.testing.assert_array_equal(srt, ref)


def test_dedup_sort_handles_unsorted_chunks():
    rng = np.random.default_rng(3)
    nbr_np, cand_np = _adversarial_chunks(rng)    # NOT sorted: runs shuffled
    perm = rng.permutation(nbr_np.shape[1])
    nbr_np, cand_np = nbr_np[:, perm], cand_np[:, perm]
    nbr, cand = jnp.asarray(nbr_np), jnp.asarray(cand_np)
    ar = jnp.arange(nbr.shape[1], dtype=jnp.int32)
    srt = np.asarray(rrset._first_occurrence(nbr, cand, ar, mode="sort"))
    np.testing.assert_array_equal(srt, _dense_first_occurrence(nbr_np,
                                                               cand_np))


def test_coalesce_probability_equivalence_random_multigraph():
    """p' = 1 - prod(1 - p_i) for every parallel-edge group, and coalescing
    is idempotent (deterministic twin of the hypothesis property)."""
    rng = np.random.default_rng(6)
    n, m = 12, 160
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.random(m).astype(np.float32) * 0.9
    g = csr_mod.from_edges(src, dst, n, weights=w)
    gc = csr_mod.coalesce_ic(g)
    s2, d2, w2 = csr_mod.to_edges(gc)
    got = dict(zip(zip(s2.tolist(), d2.tolist()), w2.tolist()))
    expect = {}
    for u, v, p in zip(src.tolist(), dst.tolist(), w.tolist()):
        expect[(u, v)] = 1.0 - (1.0 - expect.get((u, v), 0.0)) * (1.0 - p)
    assert set(got) == set(expect)
    for key in expect:
        assert got[key] == pytest.approx(expect[key], abs=1e-6), key
    assert csr_mod.coalesce_ic(gc) is gc            # idempotent, same object
    assert rrset.detect_dedup_mode(gc) == "none"

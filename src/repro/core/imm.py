"""IMM driver (paper Alg. 2 + θ sampling + seed selection), engine-agnostic.

The host orchestrates rounds of RR batches (exactly like gIM's persistent
N_b-block kernel relaunches, Alg. 6) against any registered
:class:`~repro.core.engine.SamplerEngine` — ``queue`` (gIM-faithful),
``dense`` (frontier-SpMV), ``refill`` (persistent lanes), ``lt`` (LT walks),
or a caller-supplied engine instance (e.g. the sharded launcher's).  Every
round is ``batch = engine.sample(key)`` → ``store.append_batch(batch)``; the
solver never inspects engine internals.

All martingale math (λ', λ*, the Alg. 2 LB loop) follows IMM [Tang et al.'15]
and is shared with the numpy oracle (core/oracle.py) so both sides compute
identical θ schedules.  The RR pool is an incremental CSR-of-RR
(:class:`~repro.core.coverage.IncrementalRRStore`), so the LB loop's repeated
selections reuse one growing store instead of re-merging every round.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import numpy as np

from repro.graph.csr import CSRGraph, reverse
from repro.core import coverage as cov
from repro.core.oracle import imm_theta_params
from repro.core.engine import (SamplerEngine, make_engine, resolve_engine_name)


@dataclass
class IMMStats:
    theta: int = 0
    n_rr_sampled: int = 0
    lb: float = 1.0
    lb_iters: int = 0
    rounds: int = 0
    overflow_fraction: float = 0.0
    frac_covered: float = 0.0
    sampling_steps: int = 0
    history: list = field(default_factory=list)


class IMMSolver:
    """Stateful solver: owns the RR pool so Alg. 2 reuses earlier samples.

    ``engine`` is a registered engine name or a ready ``SamplerEngine``
    instance; ``batch``/``qcap``/``ec`` are forwarded to the engine's config
    (each engine takes the subset it understands).  ``model="lt"`` keeps its
    historical meaning by resolving to the ``lt`` engine.
    """

    def __init__(self, g: CSRGraph, *,
                 engine: Union[str, SamplerEngine] = "queue",
                 batch: Optional[int] = None, qcap: Optional[int] = None,
                 ec: Optional[int] = None, model: Optional[str] = None,
                 seed: int = 0):
        self.g = g
        self.n = g.n_nodes
        if isinstance(engine, str):
            name = resolve_engine_name(engine, model or "ic")
            self.g_rev = reverse(g)
            # None options fall through to each engine Config's own defaults
            self.engine: SamplerEngine = make_engine(
                name, self.g_rev, batch=batch, qcap=qcap, ec=ec)
        else:
            # engine instance passed in: it owns its graph + configuration,
            # so sampling options on the solver would be silently ignored
            if any(v is not None for v in (batch, qcap, ec, model)):
                raise ValueError(
                    "batch/qcap/ec/model have no effect when an engine "
                    "instance is passed; configure the engine instead")
            self.engine = engine
            self.g_rev = getattr(engine, "g_rev", None)
        if self.engine.item_space != self.n:
            # e.g. engine="mrim": its ids are round*n+node encodings that
            # would leak out of solve() as nonsense seeds — route those
            # through their own solver (solve_mrim)
            raise ValueError(
                f"engine {getattr(self.engine, 'name', '?')!r} samples an "
                f"item space of {self.engine.item_space}, not the graph's "
                f"{self.n} nodes; IMMSolver needs a plain node-id engine "
                "(tagged engines like 'mrim' have dedicated solvers)")
        self.engine_name = getattr(self.engine, "name",
                                   type(self.engine).__name__)
        self.key = jax.random.key(seed)
        self.store = cov.IncrementalRRStore(self.engine.item_space)
        self.stats = IMMStats()

    # -- sampling ----------------------------------------------------------
    def _round(self):
        self.key, sub = jax.random.split(self.key)
        batch = self.engine.sample(sub)
        self.store.append_batch(batch)
        self.stats.rounds += 1
        self.stats.n_rr_sampled += batch.n_sets
        self.stats.sampling_steps += int(batch.steps)
        overflow = np.asarray(batch.overflowed)
        self.stats.overflow_fraction = (
            (self.stats.overflow_fraction * (self.stats.rounds - 1)
             + float(overflow.mean() if overflow.size else 0.0))
            / self.stats.rounds)

    def sample_until(self, theta: int):
        while self.stats.n_rr_sampled < theta:
            self._round()

    def _store(self) -> cov.RRStore:
        return self.store.snapshot()

    # -- full IMM ----------------------------------------------------------
    def solve(self, k: int, eps: float, ell: float = 1.0,
              max_theta: Optional[int] = None):
        n = self.n
        lam_p, lam_star, eps_p, _ = imm_theta_params(n, k, eps, ell)
        lb = 1.0
        for i in range(1, max(int(math.log2(n)), 2)):           # Alg. 2
            x = n / (2.0 ** i)
            theta_i = int(math.ceil(lam_p / x))
            if max_theta:
                theta_i = min(theta_i, max_theta)
            self.sample_until(theta_i)
            res = cov.select_seeds(self._store(), k)
            est = n * float(res.frac)
            self.stats.lb_iters = i
            self.stats.history.append(("lb_iter", i, theta_i, est))
            if est >= (1.0 + eps_p) * x:                         # Alg. 2 L7
                lb = est / (1.0 + eps_p)                         # Alg. 2 L8
                break
        theta = int(math.ceil(lam_star / lb))
        if max_theta:
            theta = min(theta, max_theta)
        self.stats.theta = theta
        self.stats.lb = lb
        self.sample_until(theta)
        res = cov.select_seeds(self._store(), k)
        self.stats.frac_covered = float(res.frac)
        spread_est = n * float(res.frac)                         # Eq. (3)
        return np.asarray(res.seeds), spread_est, self.stats


def imm(g: CSRGraph, k: int, eps: float, **kw):
    """One-shot convenience wrapper; returns (seeds, spread_estimate, stats)."""
    solver_kw = {k_: v for k_, v in kw.items()
                 if k_ in ("engine", "batch", "qcap", "ec", "model", "seed")}
    solve_kw = {k_: v for k_, v in kw.items() if k_ in ("ell", "max_theta")}
    solver = IMMSolver(g, **solver_kw)
    return solver.solve(k, eps, **solve_kw)

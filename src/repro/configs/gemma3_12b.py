"""gemma3-12b [hf:google/gemma-3]: 48L d3840 16H kv8 dff15360 v262144; 5:1."""
from repro.configs.lm import gemma3_12b as full_config, reduced_lm
ARCH_ID = "gemma3-12b"
def reduced_config():
    return reduced_lm(full_config())

"""Pallas TPU kernels for the bit-packed Visited structures (DESIGN.md §2).

gIM keeps one byte-per-node ``Visited`` array per block in GPU global memory
(§3.5 shows this dominating memory: 465 GB if naively replicated).  The TPU
adaptation packs visited sets as (B, W=ceil(n/32)) uint32 — 32× smaller — and
these kernels provide the hot bit-level ops:

* :func:`pack_bits`       — (B, n) bool  -> (B, W) uint32
* :func:`bitset_or`       — visited |= new       (elementwise tiles)
* :func:`bitset_andnot`   — frontier = new & ~visited
* :func:`popcount_words`  — per-word popcount (SWAR)
* :func:`occur_from_bitset` — Occur[n] = Σ_lanes bit_v  (the paper's
  atomicAdd(Occur) recast as a cross-lane bit-column reduction; grid
  accumulates over lane blocks into one VMEM-resident histogram tile)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ------------------------------------------------------------------ pack

def _pack_kernel(bits_ref, words_ref):
    bits = bits_ref[...]                       # (BB, n) bool
    bb, n = bits.shape
    w = n // 32
    b3 = bits.reshape(bb, w, 32).astype(jnp.uint32)
    shift = jax.lax.broadcasted_iota(jnp.uint32, (bb, w, 32), 2)
    words_ref[...] = (b3 << shift).sum(axis=2).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def pack_bits(bits: jnp.ndarray, *, block_b: int = 8, interpret: bool = True):
    b, n = bits.shape
    if n % 32:
        raise ValueError("n must be a multiple of 32 (pad first)")
    bb = min(block_b, b)
    return pl.pallas_call(
        _pack_kernel,
        grid=(pl.cdiv(b, bb),),
        in_specs=[pl.BlockSpec((bb, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, n // 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n // 32), jnp.uint32),
        interpret=interpret,
    )(bits)


# ------------------------------------------------------- elementwise pair

def _or_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] | b_ref[...]


def _andnot_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] & ~b_ref[...]


def _binary_op(kernel, a, b, block_b, interpret):
    bsz, w = a.shape
    bb = min(block_b, bsz)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(bsz, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0)),
                  pl.BlockSpec((bb, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, w), jnp.uint32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def bitset_or(a, b, *, block_b: int = 64, interpret: bool = True):
    return _binary_op(_or_kernel, a, b, block_b, interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def bitset_andnot(a, b, *, block_b: int = 64, interpret: bool = True):
    """a & ~b."""
    return _binary_op(_andnot_kernel, a, b, block_b, interpret)


# -------------------------------------------------------------- popcount

def _popcount(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount on uint32."""
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> 24


def _popcount_kernel(w_ref, o_ref):
    o_ref[...] = _popcount(w_ref[...]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def popcount_words(words, *, block_b: int = 64, interpret: bool = True):
    """Per-word popcount (e.g. RR-set sizes from packed membership)."""
    b, w = words.shape
    bb = min(block_b, b)
    return pl.pallas_call(
        _popcount_kernel,
        grid=(pl.cdiv(b, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w), jnp.int32),
        interpret=interpret,
    )(words)


# ------------------------------------------------------ occur histogram

def _occur_masked_kernel(words_ref, rowmask_ref, occur_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        occur_ref[...] = jnp.zeros_like(occur_ref)

    words = words_ref[...]                       # (BB, W)
    keep = rowmask_ref[...]                      # (BB,) int32 0/1
    words = words * keep[:, None].astype(jnp.uint32)
    bb, w = words.shape
    shift = jax.lax.broadcasted_iota(jnp.uint32, (bb, w, 32), 2)
    bits = ((words[:, :, None] >> shift) & jnp.uint32(1)).astype(jnp.int32)
    occur_ref[...] += bits.sum(axis=0).reshape(w * 32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def occur_from_bitset_masked(words, rowmask, *, block_b: int = 8,
                             interpret: bool = True):
    """Occur[v] = number of *selected* lanes with bit v set.

    ``rowmask`` (B,) bool/int32 selects the lanes that contribute — this is
    the popcount-arithmetic Occur *decrement* of the fused greedy selection
    (dec over newly covered RR rows), replacing the per-seed flat scatter.
    """
    b, w = words.shape
    bb = min(block_b, b)
    return pl.pallas_call(
        _occur_masked_kernel,
        grid=(pl.cdiv(b, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0)),
                  pl.BlockSpec((bb,), lambda i: (i,))],
        out_specs=pl.BlockSpec((w * 32,), lambda i: (0,)),  # accumulated
        out_shape=jax.ShapeDtypeStruct((w * 32,), jnp.int32),
        interpret=interpret,
    )(words, rowmask.astype(jnp.int32))


def _occur_kernel(words_ref, occur_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        occur_ref[...] = jnp.zeros_like(occur_ref)

    words = words_ref[...]                       # (BB, W)
    bb, w = words.shape
    shift = jax.lax.broadcasted_iota(jnp.uint32, (bb, w, 32), 2)
    bits = ((words[:, :, None] >> shift) & jnp.uint32(1)).astype(jnp.int32)
    occur_ref[...] += bits.sum(axis=0).reshape(w * 32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def occur_from_bitset(words, *, block_b: int = 8, interpret: bool = True):
    """Occur[v] = number of lanes with bit v set.  Output length W*32."""
    b, w = words.shape
    bb = min(block_b, b)
    return pl.pallas_call(
        _occur_kernel,
        grid=(pl.cdiv(b, bb),),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((w * 32,), lambda i: (0,)),  # accumulated
        out_shape=jax.ShapeDtypeStruct((w * 32,), jnp.int32),
        interpret=interpret,
    )(words)

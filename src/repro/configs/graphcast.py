"""graphcast [arXiv:2212.12794]: 16L d512 encoder-processor-decoder, R6 mesh."""
from repro.configs.gnn_archs import make_arch
ARCH_ID = "graphcast"
def full_config(shape):
    return make_arch(ARCH_ID, shape)
def reduced_config(shape):
    return make_arch(ARCH_ID, shape, reduced=True)

"""gat-cora [arXiv:1710.10903]: 2L d_hidden=8 8 heads, attn aggregator."""
from repro.configs.gnn_archs import make_arch
ARCH_ID = "gat-cora"
def full_config(shape):
    return make_arch(ARCH_ID, shape)
def reduced_config(shape):
    return make_arch(ARCH_ID, shape, reduced=True)

"""Shared benchmark helpers.

CPU-container scaling note: the paper's experiments use SNAP graphs with up
to 1e8 edges and eps=0.05 on a V100.  This container is a single CPU core,
so every benchmark keeps the *methodology* (same machinery, same sweeps) at
reduced n/eps, and records the configuration next to each number.  The
TPU-target throughput story lives in EXPERIMENTS.md §Roofline instead.
"""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.graph import csr as csr_mod
from repro.graph import generators, weights

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def ba_graph(n: int, r: int, seed: int = 0):
    src, dst = generators.barabasi_albert(n, r, seed=seed)
    return weights.wc_weights(csr_mod.from_edges(src, dst, n))


def timed(fn, *args, repeat: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def write_csv(name: str, header: list[str], rows: list[list]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def report(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")

"""Paper Fig. 7 (§4.6): BA-graph density sweep — speedup grows with r.

The paper's explanation: N_th threads process a node's edges in parallel, so
denser graphs keep more lanes busy.  The JAX analog: the EC-wide edge chunk
is fuller per micro-step, so sets/second rises with average degree.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import IMMSolver
from repro.core import oracle
from repro.graph import csr as csr_mod

N, THETA = 10000, 2048


def main():
    rows = []
    for r in (2, 4, 8, 16):
        g = ba_graph(N, r, seed=r)
        g_rev = csr_mod.reverse(g)
        offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
        w = np.asarray(g_rev.weights)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for _ in range(THETA):
            oracle.rr_set_ic(offs, idx, w, int(rng.integers(N)), rng)
        t_o = time.perf_counter() - t0
        solver = IMMSolver(g, engine="queue", batch=512, seed=0)
        t0 = time.perf_counter()
        solver.sample_until(THETA)
        t_j = time.perf_counter() - t0
        rows.append([r, g.n_edges, round(t_o, 3), round(t_j, 3),
                     round(t_o / t_j, 2)])
        report(f"fig7/r={r}", t_j * 1e6, f"speedup={t_o / t_j:.2f}x")
    write_csv("fig7_density", ["r", "m", "t_imm_s", "t_gim_s", "speedup"],
              rows)


if __name__ == "__main__":
    main()

"""deepfm [arXiv:1703.04247]: 39 sparse fields, dim 10, MLP 400-400-400, FM."""
from repro.configs.recsys import make_deepfm
ARCH_ID = "deepfm"
def full_config():
    return make_deepfm()
def reduced_config():
    return make_deepfm(reduced=True)

"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Dispatch is the modern sort-based formulation (MegaBlocks/MaxText style, no
(T, E, C) one-hot einsum): flatten (token, choice) pairs, sort by expert,
compute position-in-expert, drop beyond capacity, gather into the (E, C, d)
expert batch.  Under pjit the expert dim carries a sharding constraint on the
'model'/'expert' mesh axis, so XLA materializes the dispatch/combine as
all-to-alls across the EP group.

Supports DeepSeek-V3 (1 shared + 256 routed, top-8, sigmoid scores with
normalized top-k gates) and Llama4-Scout (1 shared + 16 routed, top-1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
from jax.ad_checkpoint import checkpoint_name
import jax.numpy as jnp

from repro.models.layers import ffn_init, ffn, dense_init


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: Optional[int] = None
    capacity_factor: float = 1.25
    router_score: str = "softmax"  # or "sigmoid" (DeepSeek-V3 / Llama4)
    # group-local dispatch: sort/scatter/gather stay within one group of
    # tokens (= one data shard), so the only cross-device traffic is the
    # (group, expert) all-to-all.  None = single global group (baseline —
    # GSPMD lowers the global gathers as full-buffer masked all-reduces;
    # see EXPERIMENTS.md §Perf/deepseek).
    dispatch_groups: Optional[int] = None


def moe_init(key, d_model, cfg: MoEConfig, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    p = {
        "router": dense_init(kr, d_model, cfg.n_experts, dtype=jnp.float32),
        "experts": jax.vmap(
            lambda k: ffn_init(k, d_model, cfg.d_ff_expert, dtype=dtype)
        )(jax.random.split(ke, cfg.n_experts)),
    }
    if cfg.n_shared:
        d_sh = (cfg.d_ff_shared or cfg.d_ff_expert) * cfg.n_shared
        p["shared"] = ffn_init(ks, d_model, d_sh, dtype=dtype)
    return p


def route(p_router, x2d, cfg: MoEConfig):
    """x2d: (T, d) -> (expert_choice (T,k), gate (T,k), aux_loss)."""
    logits = (x2d.astype(jnp.float32) @ p_router["w"])          # (T, E)
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(scores, cfg.top_k)                # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * mean(frac_tokens * frac_prob)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_prob = probs.mean(axis=0)                              # (E,)
    onehot = jax.nn.one_hot(idx[:, 0], cfg.n_experts)
    frac_tok = onehot.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(frac_prob * frac_tok)
    return idx.astype(jnp.int32), gate.astype(x2d.dtype), aux


def moe_apply(p, x, cfg: MoEConfig, *, act="swiglu",
              ep_axis: str | None = None, dp_axis=None):
    """x: (..., d).  Returns (y, aux_loss).

    ``ep_axis``: mesh axis for the expert dim of the dispatch buffers (EP);
    ``dp_axis``: mesh axis/axes for the capacity dim (keeps the dispatched
    tokens batch-sharded so the dispatch lowers to all-to-alls rather than
    gathers of the full buffer)."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    groups = cfg.dispatch_groups or 1
    tl = t // groups
    cap = int(max(1, (tl * k * cfg.capacity_factor) // e))

    def dispatch_group(xg, idx, gate):
        """One token group: sort-by-expert, capacity-drop, (E, C, d)."""
        flat_expert = idx.reshape(-1)                           # (Tl*k,)
        flat_token = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        flat_gate = gate.reshape(-1)
        order = jnp.argsort(flat_expert)                        # stable
        s_expert = flat_expert[order]
        s_token = flat_token[order]
        s_gate = flat_gate[order]
        seg_sizes = jnp.zeros(e, jnp.int32).at[flat_expert].add(1)
        seg_starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                      jnp.cumsum(seg_sizes)[:-1]])
        pos = jnp.arange(tl * k, dtype=jnp.int32) - seg_starts[s_expert]
        keep = pos < cap
        xe = jnp.zeros((e, cap, d), xg.dtype)
        xe = xe.at[jnp.where(keep, s_expert, e),
                   jnp.where(keep, pos, 0)].set(xg[s_token], mode="drop")
        return xe, (s_expert, s_token, s_gate, pos, keep)

    def combine_group(ye, meta, tl_):
        s_expert, s_token, s_gate, pos, keep = meta
        vals = ye[jnp.where(keep, s_expert, 0), jnp.where(keep, pos, 0)]
        vals = jnp.where(keep[:, None], vals, 0) * s_gate[:, None]
        return jnp.zeros((tl_, d), vals.dtype).at[s_token].add(vals)

    def combine_group_scatter(ye, meta, tl_):
        """§Perf/H1b: scatter *from* the (E, C, d) buffer instead of
        gathering across the expert-sharded axis — under GSPMD the
        expert-sharded scatter becomes local partials + one psum(Tl, d)
        instead of a masked all-reduce of the (Tl*k, d) gather result."""
        s_expert, s_token, s_gate, pos, keep = meta
        e_idx = jnp.where(keep, s_expert, e)
        c_idx = jnp.where(keep, pos, 0)
        tok_ec = jnp.full((e, cap), tl_, jnp.int32).at[e_idx, c_idx].set(
            jnp.where(keep, s_token, tl_), mode="drop")
        gate_ec = jnp.zeros((e, cap), ye.dtype).at[e_idx, c_idx].set(
            jnp.where(keep, s_gate, 0).astype(ye.dtype), mode="drop")
        contrib = (ye * gate_ec[..., None]).reshape(e * cap, d)
        return jnp.zeros((tl_, d), ye.dtype).at[
            tok_ec.reshape(-1)].add(contrib, mode="drop")

    idx, gate, aux = route(p["router"], x2d, cfg)

    def con(z, spec):
        if ep_axis is None:
            return z
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(z, P(*spec))

    if groups == 1:
        xe, meta = dispatch_group(x2d, idx, gate)
        xe = con(xe, (ep_axis, dp_axis, None))
        ye = jax.vmap(lambda pp, xx: ffn(pp, xx, act=act))(p["experts"], xe)
        ye = con(ye, (ep_axis, dp_axis, None))
        y = combine_group(ye, meta, t)
    else:
        xg = x2d.reshape(groups, tl, d)
        xg = con(xg, (dp_axis, None, None))
        xe, meta = jax.vmap(dispatch_group)(
            xg, idx.reshape(groups, tl, k), gate.reshape(groups, tl, k))
        # (G, E, C, d): groups on the data axis, experts on the EP axis —
        # building this from data-sharded groups is the all-to-all
        xe = con(xe, (dp_axis, ep_axis, None, None))
        xe = checkpoint_name(xe, "moe_dispatch")
        # expert FFN over the (G*C) rows of each expert
        xeT = con(xe.transpose(1, 0, 2, 3).reshape(e, groups * cap, d),
                  (ep_axis, dp_axis, None))
        yeT = jax.vmap(lambda pp, xx: ffn(pp, xx, act=act))(p["experts"],
                                                            xeT)
        yeT = checkpoint_name(yeT, "moe_out")
        ye = con(yeT.reshape(e, groups, cap, d).transpose(1, 0, 2, 3),
                 (dp_axis, ep_axis, None, None))
        y = jax.vmap(lambda yy, mm: combine_group_scatter(yy, mm, tl))(
            ye, meta)
        y = con(y, (dp_axis, None, None)).reshape(t, d).astype(x2d.dtype)
    if cfg.n_shared:
        y = y + ffn(p["shared"], x2d, act=act)
    return y.reshape(orig_shape), aux
